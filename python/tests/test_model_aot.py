"""L2/AOT tests: model shapes, HLO text generation, numeric round-trip."""

import numpy as np
import pytest

from compile.model import batched_weighted_hops, lower_batched_weighted_hops
from compile.aot import to_hlo_text, SHAPES
from compile.kernels.ref import weighted_hops_ref


def test_model_output_shape():
    r, e, d = 4, 2048, 6
    rng = np.random.default_rng(0)
    src = rng.uniform(0, 4, (r, e, d)).astype(np.float32)
    dst = rng.uniform(0, 4, (r, e, d)).astype(np.float32)
    w = rng.uniform(0, 1, (e,)).astype(np.float32)
    dims = np.full(d, 8.0, np.float32)
    wrap = np.ones(d, np.float32)
    (out,) = batched_weighted_hops(src, dst, w, dims, wrap)
    assert out.shape == (r,)
    want = np.asarray(weighted_hops_ref(src, dst, w, dims, wrap))
    np.testing.assert_allclose(np.asarray(out), want, rtol=1e-5, atol=1e-2)


@pytest.mark.parametrize("r,e,d", [(2, 1024, 6)])
def test_lower_to_hlo_text(r, e, d):
    text = to_hlo_text(lower_batched_weighted_hops(r, e, d))
    # Sanity on the interchange format the rust loader expects.
    assert "HloModule" in text
    assert f"f32[{r},{e},{d}]" in text
    # return_tuple=True: root is a tuple of one f32[r].
    assert f"(f32[{r}])" in text or f"f32[{r}]" in text


def test_manifest_shapes_are_block_aligned():
    from compile.kernels.whops import BLOCK_E

    for r, e, d in SHAPES:
        assert e % BLOCK_E == 0 or e < BLOCK_E
        assert 1 <= r <= 64 and 1 <= d <= 8


def test_hlo_numeric_roundtrip_via_jax_cpu():
    """Compile the lowered module with jax's own CPU client and compare."""
    r, e, d = 2, 1024, 6
    lowered = lower_batched_weighted_hops(r, e, d)
    compiled = lowered.compile()
    rng = np.random.default_rng(3)
    dims = np.array([4, 8, 2, 16, 3, 1], np.float32)
    src = (rng.integers(0, 1000, (r, e, d)) % dims).astype(np.float32)
    dst = (rng.integers(0, 1000, (r, e, d)) % dims).astype(np.float32)
    w = rng.uniform(0, 2, (e,)).astype(np.float32)
    wrap = np.array([1, 1, 0, 1, 0, 1], np.float32)
    (got,) = compiled(src, dst, w, dims, wrap)
    want = np.asarray(weighted_hops_ref(src, dst, w, dims, wrap))
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-5, atol=1e-2)
