"""L1 correctness: the Pallas whops kernel vs the pure-jnp oracle.

This is the core build-time correctness signal for the kernel layer:
hypothesis sweeps shapes and coordinate/weight contents, and the kernel must
match kernels/ref.py to f32 tolerance for every case.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.ref import weighted_hops_ref, hop_distance_ref
from compile.kernels.whops import whops_pallas


def _rand_case(rng, r, e, d, max_extent=16, torus=True):
    dims = rng.integers(1, max_extent + 1, size=d).astype(np.float32)
    src = (rng.integers(0, 1 << 20, size=(r, e, d)) % dims).astype(np.float32)
    dst = (rng.integers(0, 1 << 20, size=(r, e, d)) % dims).astype(np.float32)
    w = rng.uniform(0.0, 8.0, size=e).astype(np.float32)
    wrap = (
        np.ones(d, dtype=np.float32)
        if torus
        else rng.integers(0, 2, size=d).astype(np.float32)
    )
    return src, dst, w, dims, wrap


def _check(src, dst, w, dims, wrap, block_e):
    got = np.asarray(whops_pallas(src, dst, w, dims, wrap, block_e=block_e))
    want = np.asarray(weighted_hops_ref(src, dst, w, dims, wrap))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-3)


@pytest.mark.parametrize("r,e,d,block_e", [
    (1, 64, 1, 64),
    (2, 128, 3, 64),
    (4, 256, 6, 128),
    (36, 512, 6, 256),
    (3, 1024, 5, 1024),
])
def test_kernel_matches_ref_fixed(r, e, d, block_e):
    rng = np.random.default_rng(42 + r + e + d)
    _check(*_rand_case(rng, r, e, d), block_e=block_e)


@settings(max_examples=25, deadline=None)
@given(
    r=st.integers(1, 8),
    eb=st.integers(1, 8),
    d=st.integers(1, 6),
    torus=st.booleans(),
    seed=st.integers(0, 2**31 - 1),
)
def test_kernel_matches_ref_hypothesis(r, eb, d, torus, seed):
    rng = np.random.default_rng(seed)
    e = eb * 32
    src, dst, w, dims, wrap = _rand_case(rng, r, e, d, torus=torus)
    _check(src, dst, w, dims, wrap, block_e=32)


def test_padding_edges_contribute_zero():
    """Padding contract: w=0 edges must not change the result."""
    rng = np.random.default_rng(7)
    src, dst, w, dims, wrap = _rand_case(rng, 3, 128, 4)
    base = np.asarray(whops_pallas(src, dst, w, dims, wrap, block_e=64))
    src2 = np.concatenate([src, rng.uniform(0, 5, (3, 64, 4)).astype(np.float32)], axis=1)
    dst2 = np.concatenate([dst, rng.uniform(0, 5, (3, 64, 4)).astype(np.float32)], axis=1)
    w2 = np.concatenate([w, np.zeros(64, np.float32)])
    padded = np.asarray(whops_pallas(src2, dst2, w2, dims, wrap, block_e=64))
    np.testing.assert_allclose(padded, base, rtol=1e-6)


def test_padding_dims_contribute_zero():
    """Padding contract: size-1 torus dims add zero hops."""
    rng = np.random.default_rng(8)
    src, dst, w, dims, wrap = _rand_case(rng, 2, 128, 3)
    base = np.asarray(whops_pallas(src, dst, w, dims, wrap, block_e=128))
    pad = lambda a: np.concatenate([a, np.zeros(a.shape[:-1] + (2,), np.float32)], axis=-1)
    dims2 = np.concatenate([dims, np.ones(2, np.float32)])
    wrap2 = np.concatenate([wrap, np.ones(2, np.float32)])
    padded = np.asarray(whops_pallas(pad(src), pad(dst), w, dims2, wrap2, block_e=128))
    np.testing.assert_allclose(padded, base, rtol=1e-6)


def test_torus_vs_mesh_distance():
    """Known-answer: on a ring of 8, dist(0,7) is 1 (torus) vs 7 (mesh)."""
    src = np.zeros((1, 32, 1), np.float32)
    dst = np.full((1, 32, 1), 7.0, np.float32)
    w = np.ones(32, np.float32)
    dims = np.array([8.0], np.float32)
    got_t = np.asarray(whops_pallas(src, dst, w, dims, np.ones(1, np.float32), block_e=32))
    got_m = np.asarray(whops_pallas(src, dst, w, dims, np.zeros(1, np.float32), block_e=32))
    assert got_t[0] == pytest.approx(32.0)
    assert got_m[0] == pytest.approx(224.0)


def test_hop_distance_ref_symmetry():
    rng = np.random.default_rng(11)
    src, dst, _, dims, wrap = _rand_case(rng, 1, 256, 5)
    a = np.asarray(hop_distance_ref(src, dst, dims, wrap))
    b = np.asarray(hop_distance_ref(dst, src, dims, wrap))
    np.testing.assert_allclose(a, b)
