"""L2 JAX model: the batched mapping-quality evaluator.

The rust coordinator's rotation sweep (Section 4.3 of the paper) produces a
batch of candidate mappings; each candidate determines, for every task-graph
edge, the router coordinates of the two endpoints. This module is the
compute graph that scores the whole batch in one call — it wraps the L1
Pallas kernel (kernels/whops.py) so that both lower into the same HLO
module.

`aot.py` lowers `batched_weighted_hops` at a fixed set of padded shapes and
writes HLO text artifacts; rust/src/runtime/ loads and executes them via
PJRT with zero Python on the request path.
"""

from __future__ import annotations

import jax

from .kernels.whops import whops_pallas, BLOCK_E


def batched_weighted_hops(src, dst, w, dims, wrap):
    """WeightedHops for a batch of candidate mappings.

    src, dst : f32[R, E, D] mapped router coordinates per edge endpoint
    w        : f32[E]       message volumes (0 = padding edge)
    dims     : f32[D]       machine extent per dimension (1 = padding dim)
    wrap     : f32[D]       1.0 where the dimension is a torus ring
    returns  : f32[R]
    """
    block_e = BLOCK_E if src.shape[1] % BLOCK_E == 0 else src.shape[1]
    return (whops_pallas(src, dst, w, dims, wrap, block_e=block_e),)


def lower_batched_weighted_hops(r: int, e: int, d: int):
    """jax.jit(...).lower at a concrete padded shape (AOT entry point)."""
    import jax.numpy as jnp

    f32 = jnp.float32
    spec = jax.ShapeDtypeStruct
    return jax.jit(batched_weighted_hops).lower(
        spec((r, e, d), f32),
        spec((r, e, d), f32),
        spec((e,), f32),
        spec((d,), f32),
        spec((d,), f32),
    )
