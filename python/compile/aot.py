"""AOT compile path: lower the L2 model to HLO *text* artifacts.

Run once at build time (`make artifacts`); the rust coordinator loads the
text with `HloModuleProto::from_text_file` and compiles it on the PJRT CPU
client. HLO text — NOT `.serialize()` — is the interchange format: jax
>= 0.5 emits HloModuleProto with 64-bit instruction ids which the crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Artifacts are written to --outdir together with manifest.json describing
the padded shapes, so the rust runtime can pick the smallest artifact that
fits a request and pad up to it.
"""

from __future__ import annotations

import argparse
import json
import os

from jax._src.lib import xla_client as xc

from .model import lower_batched_weighted_hops

# (R candidates, E padded edges, D padded machine dims).
#   - r36_* serves the full 3D rotation sweep (3! x 3! = 36 candidates).
#   - r8_*  serves chunked sweeps and the +E / reduced-dimension variants.
#   - r2_e1024 is the cheap smoke/test artifact.
# D = 6 covers every machine in the paper (3D Gemini boxed to 6D by the
# Z2_3 transform, 5D BG/Q + 1 padding dim).
SHAPES = [
    (2, 1024, 6),
    (8, 16384, 6),
    (36, 32768, 6),
]


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--outdir", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.outdir, exist_ok=True)

    manifest = {"kernel": "batched_weighted_hops", "artifacts": []}
    for r, e, d in SHAPES:
        name = f"whops_r{r}_e{e}_d{d}.hlo.txt"
        path = os.path.join(args.outdir, name)
        text = to_hlo_text(lower_batched_weighted_hops(r, e, d))
        with open(path, "w") as f:
            f.write(text)
        manifest["artifacts"].append({"file": name, "r": r, "e": e, "d": d})
        print(f"wrote {path} ({len(text)} chars)")

    mpath = os.path.join(args.outdir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {mpath}")


if __name__ == "__main__":
    main()
