"""Pure-jnp correctness oracle for the whops Pallas kernel.

Implements Eqn. 3 (WeightedHops) directly: for every edge, the torus/mesh
shortest-path hop count between the mapped router coordinates of its two
endpoints, times the message volume, summed. No Pallas, no tiling — this is
the ground truth that pytest (and the rust native evaluator) compare
against.
"""

from __future__ import annotations

import jax.numpy as jnp


def hop_distance_ref(src, dst, dims, wrap):
    """Per-edge hop distance. src/dst f32[..., D], dims/wrap f32[D]."""
    ad = jnp.abs(src - dst)
    torus_hop = jnp.minimum(ad, dims - ad)
    hop = jnp.where(wrap > 0.0, torus_hop, ad)
    return jnp.sum(hop, axis=-1)


def weighted_hops_ref(src, dst, w, dims, wrap):
    """Batched WeightedHops. src/dst f32[R,E,D], w f32[E] -> f32[R]."""
    hops = hop_distance_ref(src, dst, dims, wrap)  # [R, E]
    return jnp.sum(w[None, :] * hops, axis=-1)
