"""L1 Pallas kernel: batched weighted torus-hop reduction.

This is the numeric hot spot of the paper's rotation sweep (Section 4.3):
for each candidate mapping (a "rotation"), every task-graph edge is scored
by the shortest-path hop distance between the routers its endpoints were
mapped to, weighted by the message volume (Eqn. 3, WeightedHops).

Inputs (per artifact, fixed shapes — rust pads to these):
  src  : f32[R, E, D]  router coordinates of the edge source, per candidate
  dst  : f32[R, E, D]  router coordinates of the edge destination
  w    : f32[E]        message volume per edge (0 for padding edges)
  dims : f32[D]        torus extent per machine dimension (1 for padding dims)
  wrap : f32[D]        1.0 if the dimension has wraparound links, else 0.0
Output:
  out  : f32[R]        WeightedHops per candidate mapping

Hop distance per dimension: mesh |d|, torus min(|d|, dims - |d|), selected
per dimension by `wrap` so a single artifact serves mesh, torus, and mixed
(e.g. BG/Q E-dimension) machines.

TPU shaping notes (see DESIGN.md §Hardware-Adaptation): the edge list is
streamed through VMEM in (1, BLOCK_E, D) blocks; the computation is a pure
VPU elementwise + reduction (no MXU), so the kernel is bandwidth-bound and
the only structural knob is the block size. The accumulator lives in the
output ref; grid iteration over the E axis is sequential, which makes the
`when(pid==0) zero; o += partial` accumulation pattern safe. Coordinates are
small integers held in f32 (exact below 2^24).

interpret=True is mandatory here: the CPU PJRT plugin cannot execute Mosaic
custom-calls, and correctness is validated against kernels/ref.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Block size along the edge axis. 1024 edges x 6 dims x 4 B x 2 operands
# = 48 KiB of VMEM per block plus 4 KiB of weights: comfortably inside a
# 16 MiB VMEM budget with double-buffering headroom (DESIGN.md section 7).
# Perf note (EXPERIMENTS.md §Perf): A/B-measured against BLOCK_E=4096 on
# the CPU-PJRT path (102 ms vs 112 ms for the r36/e32768 artifact) — the
# smaller block wins there and keeps the TPU VMEM footprint minimal, so
# 1024 stays.
BLOCK_E = 1024


def _whops_block_kernel(dims_ref, wrap_ref, src_ref, dst_ref, w_ref, o_ref):
    """One (candidate r, edge-block e) grid step: o[r] += sum(w * hops)."""
    delta = src_ref[...] - dst_ref[...]          # [1, BLOCK_E, D]
    ad = jnp.abs(delta)
    dims = dims_ref[...]                          # [D] broadcast over block
    wrap = wrap_ref[...]
    torus_hop = jnp.minimum(ad, dims - ad)
    hop = jnp.where(wrap > 0.0, torus_hop, ad)    # [1, BLOCK_E, D]
    hops = jnp.sum(hop, axis=-1)                  # [1, BLOCK_E]
    partial = jnp.sum(w_ref[...] * hops[0])       # scalar

    @pl.when(pl.program_id(1) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += partial


@functools.partial(jax.jit, static_argnames=("block_e",))
def whops_pallas(src, dst, w, dims, wrap, *, block_e: int = BLOCK_E):
    """Batched WeightedHops via the Pallas kernel.

    Shapes: src/dst f32[R,E,D], w f32[E], dims/wrap f32[D] -> f32[R].
    E must be a multiple of `block_e` (rust pads edges with w=0).
    """
    r, e, d = src.shape
    if e % block_e != 0:
        raise ValueError(f"E={e} must be a multiple of block_e={block_e}")
    grid = (r, e // block_e)
    return pl.pallas_call(
        _whops_block_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((d,), lambda i, j: (0,)),            # dims
            pl.BlockSpec((d,), lambda i, j: (0,)),            # wrap
            pl.BlockSpec((1, block_e, d), lambda i, j: (i, j, 0)),  # src
            pl.BlockSpec((1, block_e, d), lambda i, j: (i, j, 0)),  # dst
            pl.BlockSpec((block_e,), lambda i, j: (j,)),      # w
        ],
        out_specs=pl.BlockSpec((1,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((r,), jnp.float32),
        interpret=True,
    )(dims, wrap, src, dst, w)
