//! The composable incremental evaluator: one scoring abstraction for every
//! level of the mapper.
//!
//! Before this module, the scoring layer had three parallel arms — the
//! rotation sweep's `CandidateScorer::{Whops, Routed, Numa}`, the
//! hop-priced `MinVolume` refinement, and the congestion refinement over
//! [`CongestionState`] — and each (network objective × NUMA) combination
//! needed its own hard-wired path, which is why routed congestion could not
//! compose with depth-3 NUMA mapping. This module replaces the arms with
//! one abstraction that *layers* two terms:
//!
//! * a **network term** — either plain weighted hops (`scale · hops` per
//!   unit weight between nodes, the [`HopEval`] implementation) or routed
//!   per-link loads reduced by a congestion objective
//!   ([`RoutedEval`], backed by [`CongestionState`]);
//! * an optional **intra-node NUMA term** — before the socket split exists,
//!   every intra-node edge is priced at the flat `socket_cost` upper bound
//!   ([`crate::machine::NumaNodeCosts::socket`]); the depth-3 socket level
//!   later tightens exactly this term (see [`crate::hier::socket`]). For
//!   the hop network term the NUMA term folds into the hop table's
//!   diagonal (bit-identical to the pre-refactor `min_volume_refine_numa`
//!   path); for routed network terms it is tracked as a separate
//!   intra-node weight, which is what makes **routed congestion × NUMA**
//!   expressible at all.
//!
//! Which combination runs is a pure-data [`EvalSpec`] (`objective` ×
//! `numa`), the handle `Z2Config`/`SweepConfig`/`HierConfig` and the
//! service thread through the stack. All six combinations (3 objectives ×
//! {NUMA, plain}) are supported; [`EvalSpec::validate`] is the seam where
//! a future unsupported pairing becomes a structured error instead of a
//! silently different objective.
//!
//! # The swap-gain contract
//!
//! [`IncrementalEval`] is the refinement-side interface:
//!
//! * [`value`](IncrementalEval::value) — the cached objective value of the
//!   current assignment (maintained across commits);
//! * [`full_eval`](IncrementalEval::full_eval) — a from-scratch
//!   re-evaluation of an arbitrary assignment: the arbiter. For every
//!   implementation, `swap_eval(..).gain == full_eval(before) −
//!   full_eval(after)` up to f64 rounding — pinned by the
//!   `prop_blended_incremental_gain_equals_full_eval` property test;
//! * [`swap_eval`](IncrementalEval::swap_eval) — the gain of swapping two
//!   tasks between their nodes, computed by re-pricing only the edges
//!   incident to the pair (O(degree) for the hop term, O(degree ·
//!   path-length) for the routed term), plus whatever post-swap state a
//!   commit needs (bottleneck latency, latency sum, intra-node weight);
//! * [`commit`](IncrementalEval::commit) — apply the swap evaluated by the
//!   *immediately preceding* `swap_eval` on the same scratch. The caller
//!   then updates its own `node_of` array;
//! * [`best_partner`](IncrementalEval::best_partner) — the propose-phase
//!   hook: the best strictly-improving partner for one task against a
//!   frozen snapshot. The default implementation loops `swap_gain`;
//!   [`HopEval`] overrides it with the hoisted arithmetic the hop
//!   refinement always used, term-for-term identical to its `swap_eval`
//!   so the sequential apply phase re-derives the exact same f64 gains.
//!
//! Determinism: evaluators are immutable (`&self`) during the parallel
//! propose phase and mutated only by the sequential apply phase, so every
//! refinement built on them stays bit-identical at every thread count.
//!
//! # Full (batch) evaluation
//!
//! The rotation sweep scores whole candidate mappings, not swaps:
//! [`numa_node_score`] (hop network term × NUMA term, one f64 pass in edge
//! order — unchanged from the depth-3 sweep arm it replaces),
//! [`blended_candidate_score`] (routed network term × NUMA term), and
//! [`combined_value`] (the response-side composition of an
//! [`crate::metrics::eval_full`] run with an
//! [`crate::objective::NumaMetrics`] breakdown, used by the service and
//! the experiment tables). The plain paths keep their original arithmetic,
//! so default-objective and whops×NUMA sweeps score bit-identically to the
//! pre-refactor code.
//!
//! The depth-4 cache level is a one-term extension of this module: a
//! `cache_cost < socket_cost` becomes a second intra-node term the same
//! way the socket term composes today, not a fourth scoring arm.

use super::{CongestionState, LinkCosts, NumaMetrics, ObjectiveKind};
use crate::apps::TaskGraph;
use crate::machine::{Allocation, NumaNodeCosts, NumaTopology, Topology};
use crate::metrics::{LinkAccumulator, Metrics};

/// Which evaluator to build: the network objective plus the optional
/// intra-node NUMA pricing. Pure data (`Copy`), so it travels through the
/// `Copy` sweep configuration exactly like [`ObjectiveKind`] does.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct EvalSpec {
    /// The network term: `WeightedHops` prices hops, the routed kinds
    /// price per-link latencies through [`CongestionState`].
    pub objective: ObjectiveKind,
    /// The intra-node term: when set, intra-node edges cost
    /// `numa.socket` per unit weight (the pre-split upper bound). For the
    /// `WeightedHops` objective `numa.hop` additionally scales the network
    /// term; routed objectives price links by bandwidth, so they require
    /// `numa.hop == 1` (see [`EvalSpec::validate`]).
    pub numa: Option<NumaNodeCosts>,
}

impl EvalSpec {
    pub fn new(objective: ObjectiveKind, numa: Option<NumaNodeCosts>) -> EvalSpec {
        EvalSpec { objective, numa }
    }

    /// Whether this spec layers both a routed network term and a NUMA term
    /// (the combination the pre-refactor scoring arms could not express).
    pub fn is_blended(&self) -> bool {
        self.numa.is_some() && self.objective != ObjectiveKind::WeightedHops
    }

    /// Reject combinations the evaluator cannot express, with a message
    /// suitable for surfacing to service clients. Today that is exactly
    /// one: a routed objective with a non-unit `hop` cost — link latencies
    /// are priced by bandwidth, not hops, so scaling them by `hop` would
    /// silently score a different objective than requested.
    pub fn validate(&self) -> Result<(), String> {
        if let Some(c) = self.numa {
            if self.objective != ObjectiveKind::WeightedHops && c.hop != 1.0 {
                return Err(format!(
                    "numa.hop_cost {} does not compose with the {} objective: \
                     routed link latencies are priced by bandwidth, so hop_cost \
                     must be 1 (scale bandwidths instead)",
                    c.hop,
                    self.objective.name()
                ));
            }
        }
        Ok(())
    }

    /// Reporting name, e.g. `"maxload+numa"`.
    pub fn name(&self) -> String {
        match self.numa {
            None => self.objective.name().to_string(),
            Some(_) => format!("{}+numa", self.objective.name()),
        }
    }
}

/// Compressed adjacency of a task graph (both directions per edge): the
/// edge-iteration substrate every incremental evaluator prices swaps over.
pub struct Adjacency {
    off: Vec<u32>,
    nbr: Vec<u32>,
    w: Vec<f64>,
}

impl Adjacency {
    pub fn build(graph: &TaskGraph) -> Adjacency {
        let n = graph.num_tasks;
        let mut deg = vec![0u32; n];
        for e in &graph.edges {
            deg[e.u as usize] += 1;
            deg[e.v as usize] += 1;
        }
        let mut off = vec![0u32; n + 1];
        for t in 0..n {
            off[t + 1] = off[t] + deg[t];
        }
        let total = off[n] as usize;
        let mut nbr = vec![0u32; total];
        let mut w = vec![0f64; total];
        let mut cursor = off.clone();
        for e in &graph.edges {
            let (u, v) = (e.u as usize, e.v as usize);
            nbr[cursor[u] as usize] = e.v;
            w[cursor[u] as usize] = e.w;
            cursor[u] += 1;
            nbr[cursor[v] as usize] = e.u;
            w[cursor[v] as usize] = e.w;
            cursor[v] += 1;
        }
        Adjacency { off, nbr, w }
    }

    /// `(neighbor task, edge weight)` pairs of task `t`, in build order.
    #[inline]
    pub fn neighbors(&self, t: usize) -> impl Iterator<Item = (u32, f64)> + '_ {
        let (lo, hi) = (self.off[t] as usize, self.off[t + 1] as usize);
        self.nbr[lo..hi].iter().copied().zip(self.w[lo..hi].iter().copied())
    }
}

/// Per-worker evaluator scratch: the routed evaluators' re-route delta
/// accumulator (lazily allocated on first use; the hop evaluator needs
/// none). One per refinement worker; never shared between concurrent
/// workers. After [`IncrementalEval::swap_eval`] it holds that swap's
/// link-load delta, which the paired [`IncrementalEval::commit`] applies.
#[derive(Default)]
pub struct EvalScratch {
    routed: Option<LinkAccumulator>,
}

impl EvalScratch {
    pub fn new() -> EvalScratch {
        EvalScratch::default()
    }
}

/// Result of [`IncrementalEval::swap_eval`]: the objective gain plus the
/// post-swap state a commit needs (opaque to callers).
#[derive(Clone, Copy, Debug)]
pub struct SwapEval {
    /// Objective gain (strictly positive = improvement), exact with
    /// respect to [`IncrementalEval::full_eval`] re-evaluation.
    pub gain: f64,
    new_max: f64,
    new_sum: f64,
    new_intra: f64,
}

/// The incremental-evaluator contract (see the module docs for the full
/// swap-gain contract and determinism argument).
pub trait IncrementalEval: Sync {
    /// Cached objective value of the current assignment.
    fn value(&self) -> f64;

    /// From-scratch evaluation of an arbitrary assignment — the arbiter
    /// the incremental gains are pinned against.
    fn full_eval(&self, graph: &TaskGraph, node_of: &[u32]) -> f64;

    /// Evaluate swapping tasks `u` and `b` between their (distinct) nodes.
    /// The scratch afterwards holds whatever delta
    /// [`commit`](IncrementalEval::commit) needs.
    fn swap_eval(
        &self,
        node_of: &[u32],
        adj: &Adjacency,
        u: usize,
        b: usize,
        scratch: &mut EvalScratch,
    ) -> SwapEval;

    /// Gain only (see [`swap_eval`](IncrementalEval::swap_eval)).
    fn swap_gain(
        &self,
        node_of: &[u32],
        adj: &Adjacency,
        u: usize,
        b: usize,
        scratch: &mut EvalScratch,
    ) -> f64 {
        self.swap_eval(node_of, adj, u, b, scratch).gain
    }

    /// Apply the swap evaluated by the immediately preceding
    /// [`swap_eval`](IncrementalEval::swap_eval) on the same scratch. The
    /// caller updates its `node_of` array itself.
    fn commit(&mut self, ev: &SwapEval, scratch: &EvalScratch);

    /// Observability: O(links) congestion rescans taken so far by this
    /// evaluator's routed state (always 0 for hop-priced evaluators).
    /// Refinement attributes the per-pass delta to its trace spans.
    fn rescans(&self) -> u64 {
        0
    }

    /// Propose-phase hook: the best strictly-improving swap partner for
    /// task `u` among the tasks of `targets` nodes, against the frozen
    /// snapshot `node_of`. Ties keep the earlier (smaller) partner index.
    /// The default loops [`swap_gain`](IncrementalEval::swap_gain);
    /// implementations may hoist partner-independent work as long as the
    /// computed gains stay bit-identical to `swap_eval`'s.
    fn best_partner(
        &self,
        node_of: &[u32],
        adj: &Adjacency,
        u: usize,
        targets: &[u32],
        tasks_by_node: &[Vec<u32>],
        scratch: &mut EvalScratch,
    ) -> Option<(f64, u32)> {
        let mut best: Option<(f64, u32)> = None;
        for &bn in targets {
            for &b in &tasks_by_node[bn as usize] {
                let g = self.swap_gain(node_of, adj, u, b as usize, scratch);
                let better = match best {
                    None => g > 0.0,
                    // Strictly-greater gain wins; ties keep the earlier
                    // (smaller) partner index.
                    Some((bg, bb)) => g > bg || (g == bg && b < bb && g > 0.0),
                };
                if better && g > 0.0 {
                    best = Some((g, b));
                }
            }
        }
        best
    }
}

/// Node-pair communication costs: hop distances scaled by `scale`, with a
/// configurable `diag` for same-node pairs (0 in the pure Section 3 model;
/// the flat NUMA socket cost at depth 3). A dense table while `nn²` stays
/// cheap (the common case — the whole point of the hierarchy is
/// `nn << nranks`), else computed on the fly from the topology.
struct NodeHops<'a> {
    nn: usize,
    table: Option<Vec<f64>>,
    topo: &'a dyn Topology,
    routers: &'a [u32],
    scale: f64,
    diag: f64,
}

/// Largest dense table: 4M entries (32 MB). Beyond that (only the very
/// largest `--full` sweeps) distances are recomputed per lookup.
const MAX_TABLE_ENTRIES: usize = 1 << 22;

impl<'a> NodeHops<'a> {
    fn build(topo: &'a dyn Topology, routers: &'a [u32], scale: f64, diag: f64) -> NodeHops<'a> {
        let nn = routers.len();
        let table = if nn * nn <= MAX_TABLE_ENTRIES {
            // The fill seeds every diagonal entry with `diag`; only the
            // off-diagonal pairs are overwritten below.
            let mut hops = vec![diag; nn * nn];
            for a in 0..nn {
                for b in (a + 1)..nn {
                    let h = topo.hop_dist_ids(routers[a] as usize, routers[b] as usize) as f64
                        * scale;
                    hops[a * nn + b] = h;
                    hops[b * nn + a] = h;
                }
            }
            Some(hops)
        } else {
            None
        };
        NodeHops {
            nn,
            table,
            topo,
            routers,
            scale,
            diag,
        }
    }

    #[inline]
    fn get(&self, a: u32, b: u32) -> f64 {
        match &self.table {
            Some(t) => t[a as usize * self.nn + b as usize],
            None if a == b => self.diag,
            None => {
                self.topo.hop_dist_ids(
                    self.routers[a as usize] as usize,
                    self.routers[b as usize] as usize,
                ) as f64
                    * self.scale
            }
        }
    }
}

/// Cost of placing task `t` on node `x`: Σ over t's edges of
/// `w · hops(x, node(neighbor))`.
#[inline]
fn move_cost(adj: &Adjacency, hops: &NodeHops<'_>, node_of: &[u32], t: usize, x: u32) -> f64 {
    let mut c = 0f64;
    for (n, w) in adj.neighbors(t) {
        c += w * hops.get(x, node_of[n as usize]);
    }
    c
}

/// Hop-priced evaluator: the network term is `scale · hops` per unit
/// weight, the intra-node term the table diagonal (`diag`; 0 without NUMA
/// pricing). This is the pre-refactor hop refinement expressed through the
/// evaluator contract — gains and tie-breaks are bit-identical to it.
pub struct HopEval<'a> {
    hops: NodeHops<'a>,
    value: f64,
}

impl<'a> HopEval<'a> {
    pub fn build(
        topo: &'a dyn Topology,
        routers: &'a [u32],
        graph: &TaskGraph,
        node_of: &[u32],
        scale: f64,
        diag: f64,
    ) -> HopEval<'a> {
        assert_eq!(node_of.len(), graph.num_tasks);
        let hops = NodeHops::build(topo, routers, scale, diag);
        let mut value = 0f64;
        for e in &graph.edges {
            value += e.w * hops.get(node_of[e.u as usize], node_of[e.v as usize]);
        }
        HopEval { hops, value }
    }

    /// Gain of swapping task `u` (on node `a`) with task `b` (on node
    /// `bn`). The `2·w(u,b)·(hops(a,bn) − diag)` correction accounts for a
    /// direct edge between the pair, whose cost is unchanged by the swap
    /// but double-counted by the two move costs (each move cost prices it
    /// once at the cross-node rate and once at the same-node `diag` rate).
    fn hop_swap_gain(&self, node_of: &[u32], adj: &Adjacency, u: usize, b: usize) -> f64 {
        let (a, bn) = (node_of[u], node_of[b]);
        debug_assert_ne!(a, bn, "swap within one node is a no-op");
        let mut direct = 0f64;
        for (n, w) in adj.neighbors(u) {
            if n as usize == b {
                direct += w;
            }
        }
        move_cost(adj, &self.hops, node_of, u, a) + move_cost(adj, &self.hops, node_of, b, bn)
            - move_cost(adj, &self.hops, node_of, u, bn)
            - move_cost(adj, &self.hops, node_of, b, a)
            - 2.0 * direct * (self.hops.get(a, bn) - self.hops.diag)
    }
}

impl IncrementalEval for HopEval<'_> {
    fn value(&self) -> f64 {
        self.value
    }

    fn full_eval(&self, graph: &TaskGraph, node_of: &[u32]) -> f64 {
        assert_eq!(node_of.len(), graph.num_tasks);
        let mut value = 0f64;
        for e in &graph.edges {
            value += e.w * self.hops.get(node_of[e.u as usize], node_of[e.v as usize]);
        }
        value
    }

    fn swap_eval(
        &self,
        node_of: &[u32],
        adj: &Adjacency,
        u: usize,
        b: usize,
        _scratch: &mut EvalScratch,
    ) -> SwapEval {
        SwapEval {
            gain: self.hop_swap_gain(node_of, adj, u, b),
            new_max: 0.0,
            new_sum: 0.0,
            new_intra: 0.0,
        }
    }

    fn commit(&mut self, ev: &SwapEval, _scratch: &EvalScratch) {
        self.value -= ev.gain;
    }

    fn best_partner(
        &self,
        node_of: &[u32],
        adj: &Adjacency,
        u: usize,
        targets: &[u32],
        tasks_by_node: &[Vec<u32>],
        _scratch: &mut EvalScratch,
    ) -> Option<(f64, u32)> {
        // Hoist the partner-independent halves of the gain: cost(u, a)
        // per task, cost(u, bn) per target node. The summation order
        // matches `hop_swap_gain` term-for-term, so the apply phase's
        // re-check recomputes the exact same f64.
        let a = node_of[u];
        let cost_u_a = move_cost(adj, &self.hops, node_of, u, a);
        let mut best: Option<(f64, u32)> = None;
        for &bn in targets {
            let cost_u_bn = move_cost(adj, &self.hops, node_of, u, bn);
            let h_ab = self.hops.get(a, bn);
            for &b in &tasks_by_node[bn as usize] {
                let mut direct = 0f64;
                for (n, w) in adj.neighbors(u) {
                    if n == b {
                        direct += w;
                    }
                }
                let g = cost_u_a + move_cost(adj, &self.hops, node_of, b as usize, bn)
                    - cost_u_bn
                    - move_cost(adj, &self.hops, node_of, b as usize, a)
                    - 2.0 * direct * (h_ab - self.hops.diag);
                let better = match best {
                    None => g > 0.0,
                    // Strictly-greater gain wins; ties keep the earlier
                    // (smaller) partner index.
                    Some((bg, bb)) => g > bg || (g == bg && b < bb && g > 0.0),
                };
                if better && g > 0.0 {
                    best = Some((g, b));
                }
            }
        }
        best
    }
}

/// Σ weight over intra-node edges of an assignment: the quantity the
/// pre-split NUMA term prices at `socket_cost`.
fn intra_node_weight(graph: &TaskGraph, node_of: &[u32]) -> f64 {
    let mut w = 0f64;
    for e in &graph.edges {
        if node_of[e.u as usize] == node_of[e.v as usize] {
            w += e.w;
        }
    }
    w
}

/// Δ(intra-node weight) of swapping tasks `u` and `b` between their
/// nodes, over the pair's incident edges. The direct edge `u–b` (if any)
/// stays cross-node and is skipped.
fn intra_delta(node_of: &[u32], adj: &Adjacency, u: usize, b: usize) -> f64 {
    let (a, bn) = (node_of[u], node_of[b]);
    debug_assert_ne!(a, bn, "swap within one node is a no-op");
    let mut d = 0f64;
    for (n, w) in adj.neighbors(u) {
        if n as usize == b {
            continue;
        }
        let x = node_of[n as usize];
        if x == a {
            d -= w; // was intra on a, now cross from bn
        } else if x == bn {
            d += w; // was cross, now intra on bn
        }
    }
    for (n, w) in adj.neighbors(b) {
        if n as usize == u {
            continue;
        }
        let x = node_of[n as usize];
        if x == bn {
            d -= w;
        } else if x == a {
            d += w;
        }
    }
    d
}

/// Routed evaluator: the network term is a congestion objective over
/// incrementally-maintained per-link loads ([`CongestionState`]); the
/// optional `intra_cost` layers the NUMA term — `intra_cost · Σ w` over
/// intra-node edges — on top. With `intra_cost == None` the gains are
/// bit-identical to the pre-refactor congestion refinement.
pub struct RoutedEval<'a> {
    state: CongestionState<'a>,
    kind: ObjectiveKind,
    intra_cost: Option<f64>,
    intra_weight: f64,
}

impl<'a> RoutedEval<'a> {
    pub fn build(
        topo: &'a dyn Topology,
        routers: &'a [u32],
        graph: &TaskGraph,
        node_of: &[u32],
        kind: ObjectiveKind,
        intra_cost: Option<f64>,
    ) -> RoutedEval<'a> {
        let state = CongestionState::build(topo, routers, graph, node_of, kind);
        let intra_weight = if intra_cost.is_some() {
            intra_node_weight(graph, node_of)
        } else {
            0.0
        };
        RoutedEval {
            state,
            kind,
            intra_cost,
            intra_weight,
        }
    }
}

impl IncrementalEval for RoutedEval<'_> {
    fn value(&self) -> f64 {
        match self.intra_cost {
            None => self.state.value(),
            Some(c) => self.state.value() + c * self.intra_weight,
        }
    }

    fn full_eval(&self, graph: &TaskGraph, node_of: &[u32]) -> f64 {
        let fresh =
            CongestionState::build(self.state.topo, self.state.routers, graph, node_of, self.kind);
        match self.intra_cost {
            None => fresh.value(),
            Some(c) => fresh.value() + c * intra_node_weight(graph, node_of),
        }
    }

    fn swap_eval(
        &self,
        node_of: &[u32],
        adj: &Adjacency,
        u: usize,
        b: usize,
        scratch: &mut EvalScratch,
    ) -> SwapEval {
        let acc = scratch
            .routed
            .get_or_insert_with(|| LinkAccumulator::new(self.state.topo));
        let (net_gain, new_max, new_sum) =
            self.state
                .swap_eval(node_of, u, b, adj.neighbors(u), adj.neighbors(b), acc);
        match self.intra_cost {
            None => SwapEval {
                gain: net_gain,
                new_max,
                new_sum,
                new_intra: 0.0,
            },
            Some(c) => {
                let d = intra_delta(node_of, adj, u, b);
                SwapEval {
                    // Blended gain: (net_before + c·w) − (net_after +
                    // c·(w + d)) = net_gain − c·d.
                    gain: net_gain - c * d,
                    new_max,
                    new_sum,
                    new_intra: self.intra_weight + d,
                }
            }
        }
    }

    fn commit(&mut self, ev: &SwapEval, scratch: &EvalScratch) {
        let acc = scratch
            .routed
            .as_ref()
            .expect("commit must follow swap_eval on the same scratch");
        self.state.commit_evaluated(acc, ev.new_max, ev.new_sum);
        if self.intra_cost.is_some() {
            self.intra_weight = ev.new_intra;
        }
    }

    fn rescans(&self) -> u64 {
        self.state.rescan_count()
    }
}

/// The evaluator behind an [`EvalSpec`] — what `CandidateScorer` and the
/// unified `MinVolume` refinement dispatch over.
pub enum Eval<'a> {
    Hops(HopEval<'a>),
    Routed(RoutedEval<'a>),
}

/// Build the evaluator for `spec` over the node-level assignment
/// `node_of` (task `t` on node `node_of[t]`, node `x` at router
/// `routers[x]`).
pub fn build_eval<'a>(
    topo: &'a dyn Topology,
    routers: &'a [u32],
    graph: &TaskGraph,
    node_of: &[u32],
    spec: EvalSpec,
) -> Eval<'a> {
    match (spec.objective, spec.numa) {
        (ObjectiveKind::WeightedHops, None) => {
            Eval::Hops(HopEval::build(topo, routers, graph, node_of, 1.0, 0.0))
        }
        (ObjectiveKind::WeightedHops, Some(c)) => {
            Eval::Hops(HopEval::build(topo, routers, graph, node_of, c.hop, c.socket))
        }
        (kind, numa) => Eval::Routed(RoutedEval::build(
            topo,
            routers,
            graph,
            node_of,
            kind,
            numa.map(|c| c.socket),
        )),
    }
}

impl IncrementalEval for Eval<'_> {
    fn value(&self) -> f64 {
        match self {
            Eval::Hops(e) => e.value(),
            Eval::Routed(e) => e.value(),
        }
    }

    fn full_eval(&self, graph: &TaskGraph, node_of: &[u32]) -> f64 {
        match self {
            Eval::Hops(e) => e.full_eval(graph, node_of),
            Eval::Routed(e) => e.full_eval(graph, node_of),
        }
    }

    fn swap_eval(
        &self,
        node_of: &[u32],
        adj: &Adjacency,
        u: usize,
        b: usize,
        scratch: &mut EvalScratch,
    ) -> SwapEval {
        match self {
            Eval::Hops(e) => e.swap_eval(node_of, adj, u, b, scratch),
            Eval::Routed(e) => e.swap_eval(node_of, adj, u, b, scratch),
        }
    }

    fn commit(&mut self, ev: &SwapEval, scratch: &EvalScratch) {
        match self {
            Eval::Hops(e) => e.commit(ev, scratch),
            Eval::Routed(e) => e.commit(ev, scratch),
        }
    }

    fn rescans(&self) -> u64 {
        match self {
            Eval::Hops(e) => e.rescans(),
            Eval::Routed(e) => e.rescans(),
        }
    }

    fn best_partner(
        &self,
        node_of: &[u32],
        adj: &Adjacency,
        u: usize,
        targets: &[u32],
        tasks_by_node: &[Vec<u32>],
        scratch: &mut EvalScratch,
    ) -> Option<(f64, u32)> {
        match self {
            Eval::Hops(e) => e.best_partner(node_of, adj, u, targets, tasks_by_node, scratch),
            Eval::Routed(e) => e.best_partner(node_of, adj, u, targets, tasks_by_node, scratch),
        }
    }
}

/// NUMA pricing of a node-level candidate mapping: inter-node edges at
/// `hop` per network hop, intra-node edges at the flat `socket` upper
/// bound (the socket split is not decided yet at sweep time). One
/// sequential f64 pass in edge order — a pure function of the mapping, so
/// sweeps stay bit-identical at every thread count.
pub fn numa_node_score(
    graph: &TaskGraph,
    mapping: &[u32],
    alloc: &Allocation,
    costs: NumaNodeCosts,
) -> f64 {
    assert_eq!(mapping.len(), graph.num_tasks);
    let machine = &alloc.machine;
    let mut total = 0f64;
    for e in &graph.edges {
        let ra = mapping[e.u as usize] as usize;
        let rb = mapping[e.v as usize] as usize;
        if alloc.core_node[ra] == alloc.core_node[rb] {
            total += costs.socket * e.w;
        } else {
            let h = machine.hop_dist_ids(
                alloc.core_router[ra] as usize,
                alloc.core_router[rb] as usize,
            );
            total += costs.hop * e.w * h as f64;
        }
    }
    total
}

/// Blended candidate score: the routed objective over inter-node edges
/// plus `socket_cost` per unit weight for intra-node edges — the full-
/// evaluation counterpart of [`RoutedEval`], used by the rotation sweep.
pub fn blended_candidate_score(
    graph: &TaskGraph,
    mapping: &[u32],
    alloc: &Allocation,
    kind: ObjectiveKind,
    socket_cost: f64,
    costs: &LinkCosts,
    acc: &mut LinkAccumulator,
) -> f64 {
    let (summary, intra) = super::routed_summary_with_intra(graph, mapping, alloc, costs, acc);
    kind.get().reduce(&summary) + socket_cost * intra
}

/// Combined (network × NUMA) objective value of a finished mapping, from
/// an [`crate::metrics::eval_full`] run plus (optionally) its
/// [`crate::objective::eval_numa`] breakdown — the composition rule the
/// service's map/eval responses and the experiment tables report:
///
/// * no NUMA model: the plain objective value;
/// * `WeightedHops` × NUMA: the three-level [`NumaMetrics::value`]
///   (`hop_cost` scales the network term);
/// * routed × NUMA: the routed objective value plus
///   `socket_cost · socket_weight + core_cost · core_weight`.
pub fn combined_value(
    objective: ObjectiveKind,
    metrics: &Metrics,
    numa: Option<(&NumaTopology, &NumaMetrics)>,
) -> f64 {
    match numa {
        None => objective.value_from_metrics(metrics),
        Some((topo, nm)) => match objective {
            ObjectiveKind::WeightedHops => nm.value,
            _ => {
                objective.value_from_metrics(metrics)
                    + topo.socket_cost * nm.socket_weight
                    + topo.core_cost * nm.core_weight
            }
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::stencil::stencil_graph;
    use crate::machine::Torus;

    fn chain_setup() -> (TaskGraph, Torus, Vec<u32>, Vec<u32>) {
        let g = stencil_graph(&[16], false, 2.0);
        let torus = Torus::torus(&[4]);
        let routers: Vec<u32> = vec![0, 1, 2, 3];
        let node_of: Vec<u32> = (0..16).map(|t| (t % 4) as u32).collect();
        (g, torus, routers, node_of)
    }

    fn all_specs() -> Vec<EvalSpec> {
        let costs = NumaNodeCosts {
            hop: 1.0,
            socket: 0.4,
        };
        let mut specs = Vec::new();
        for kind in ObjectiveKind::ALL {
            specs.push(EvalSpec::new(kind, None));
            specs.push(EvalSpec::new(kind, Some(costs)));
        }
        specs
    }

    #[test]
    fn spec_validation_and_names() {
        assert_eq!(EvalSpec::default().name(), "whops");
        let blended = EvalSpec::new(
            ObjectiveKind::MaxLinkLoad,
            Some(NumaNodeCosts {
                hop: 1.0,
                socket: 0.5,
            }),
        );
        assert!(blended.is_blended());
        assert_eq!(blended.name(), "maxload+numa");
        assert!(blended.validate().is_ok());
        // Non-unit hop cost cannot scale a routed objective.
        let bad = EvalSpec::new(
            ObjectiveKind::CongestionBlend,
            Some(NumaNodeCosts {
                hop: 0.5,
                socket: 0.5,
            }),
        );
        assert!(bad.validate().unwrap_err().contains("hop_cost"));
        // ...but it scales WeightedHops fine.
        let wh = EvalSpec::new(
            ObjectiveKind::WeightedHops,
            Some(NumaNodeCosts {
                hop: 0.5,
                socket: 0.5,
            }),
        );
        assert!(wh.validate().is_ok());
        assert!(!wh.is_blended());
    }

    #[test]
    fn every_spec_gain_matches_full_reevaluation() {
        let (g, torus, routers, start) = chain_setup();
        let adj = Adjacency::build(&g);
        for spec in all_specs() {
            let mut node_of = start.clone();
            let mut eval = build_eval(&torus, &routers, &g, &node_of, spec);
            let mut scratch = EvalScratch::new();
            for (u, b) in [(0usize, 5usize), (2, 15), (1, 10), (7, 12)] {
                if node_of[u] == node_of[b] {
                    continue;
                }
                let before = eval.full_eval(&g, &node_of);
                let ev = eval.swap_eval(&node_of, &adj, u, b, &mut scratch);
                eval.commit(&ev, &scratch);
                node_of.swap(u, b);
                let after = eval.full_eval(&g, &node_of);
                let tol = 1e-9 * after.abs().max(1.0);
                assert!(
                    (ev.gain - (before - after)).abs() <= tol,
                    "{}: gain {} vs full delta {}",
                    spec.name(),
                    ev.gain,
                    before - after
                );
                assert!(
                    (eval.value() - after).abs() <= tol,
                    "{}: cached {} vs full {}",
                    spec.name(),
                    eval.value(),
                    after
                );
            }
        }
    }

    #[test]
    fn initial_value_matches_full_eval() {
        let (g, torus, routers, node_of) = chain_setup();
        for spec in all_specs() {
            let eval = build_eval(&torus, &routers, &g, &node_of, spec);
            let full = eval.full_eval(&g, &node_of);
            assert!(
                (eval.value() - full).abs() <= 1e-12 * full.abs().max(1.0),
                "{}: {} vs {}",
                spec.name(),
                eval.value(),
                full
            );
        }
    }

    #[test]
    fn blended_value_layers_both_terms() {
        // The blended evaluator's value must equal the routed value plus
        // socket_cost times the intra-node weight, term by term.
        let (g, torus, routers, node_of) = chain_setup();
        let socket = 0.4;
        let spec = EvalSpec::new(
            ObjectiveKind::MaxLinkLoad,
            Some(NumaNodeCosts { hop: 1.0, socket }),
        );
        let blended = build_eval(&torus, &routers, &g, &node_of, spec);
        let plain = build_eval(
            &torus,
            &routers,
            &g,
            &node_of,
            EvalSpec::new(ObjectiveKind::MaxLinkLoad, None),
        );
        let intra = intra_node_weight(&g, &node_of);
        assert!(intra > 0.0, "chain stride assignment has intra edges");
        assert_eq!(blended.value(), plain.value() + socket * intra);
    }

    #[test]
    fn hop_best_partner_matches_default_loop() {
        // The hoisted hop propose hook must agree with the generic
        // swap_gain loop on both the chosen partner and the gain.
        let (g, torus, routers, node_of) = chain_setup();
        let adj = Adjacency::build(&g);
        let eval = HopEval::build(&torus, &routers, &g, &node_of, 1.0, 0.3);
        let mut tasks_by_node: Vec<Vec<u32>> = vec![Vec::new(); 4];
        for (t, &x) in node_of.iter().enumerate() {
            tasks_by_node[x as usize].push(t as u32);
        }
        let mut scratch = EvalScratch::new();
        for u in 0..16usize {
            let a = node_of[u];
            let mut targets: Vec<u32> = adj
                .neighbors(u)
                .map(|(n, _)| node_of[n as usize])
                .filter(|&x| x != a)
                .collect();
            targets.sort_unstable();
            targets.dedup();
            if targets.is_empty() {
                continue;
            }
            let hoisted =
                eval.best_partner(&node_of, &adj, u, &targets, &tasks_by_node, &mut scratch);
            // The default loop from the trait, run against the same eval.
            let mut best: Option<(f64, u32)> = None;
            for &bn in &targets {
                for &b in &tasks_by_node[bn as usize] {
                    let g = eval.swap_gain(&node_of, &adj, u, b as usize, &mut scratch);
                    let better = match best {
                        None => g > 0.0,
                        Some((bg, bb)) => g > bg || (g == bg && b < bb && g > 0.0),
                    };
                    if better && g > 0.0 {
                        best = Some((g, b));
                    }
                }
            }
            assert_eq!(hoisted, best, "task {u}");
        }
    }

    #[test]
    fn combined_value_composes_per_rule() {
        use crate::machine::Allocation;
        use crate::metrics::eval_full;
        use crate::objective::eval_numa;
        // 2 nodes x 2 ranks on a 4-ring; edge (0,1) intra-node, (1,2)
        // cross-node at 1 hop.
        let alloc = Allocation::heterogeneous(Torus::torus(&[4]), &[0, 1], &[2, 2]).unwrap();
        let g = {
            use crate::apps::{Edge, TaskGraph};
            use crate::geom::Coords;
            TaskGraph {
                num_tasks: 4,
                edges: vec![
                    Edge { u: 0, v: 1, w: 5.0 },
                    Edge { u: 1, v: 2, w: 3.0 },
                ],
                coords: Coords::from_axes(vec![vec![0.0; 4]]),
            }
        };
        let mapping: Vec<u32> = (0..4).collect();
        let topo = NumaTopology::new(2, 1, 0.5, 0.0, 1.0);
        let m = eval_full(&g, &mapping, &alloc);
        let nm = eval_numa(&g, &mapping, &alloc, &topo);
        // WeightedHops x NUMA: the three-level NumaAware value.
        assert_eq!(
            combined_value(ObjectiveKind::WeightedHops, &m, Some((&topo, &nm))),
            nm.value
        );
        // Routed x NUMA: routed value plus the intra-node terms.
        let maxload = ObjectiveKind::MaxLinkLoad.value_from_metrics(&m);
        assert_eq!(
            combined_value(ObjectiveKind::MaxLinkLoad, &m, Some((&topo, &nm))),
            maxload + 0.5 * nm.socket_weight
        );
        // No NUMA: the plain objective.
        assert_eq!(
            combined_value(ObjectiveKind::MaxLinkLoad, &m, None),
            maxload
        );
    }
}
