//! Pluggable mapping objectives: what "a good mapping" means, as a value.
//!
//! The paper's quality model is not just weighted hops: Eqns 4–7 judge a
//! mapping by the data routed over each link and the serialization latency
//! of the bottleneck link, and its congestion results are what justify
//! geometric mapping at scale. This module turns the scorer from a single
//! hard-wired kernel into a subsystem: one [`Objective`] trait with three
//! implementations, selected by a [`ObjectiveKind`] carried through
//! `Z2Config`/`SweepConfig`/`HierConfig` and the service protocol.
//!
//! * [`WeightedHops`] — Eqn 3, `Σ_e w(e)·hops(e)`. The rotation sweep keeps
//!   scoring this one on the batched f32 kernel (native or PJRT artifact);
//!   the trait implementation here is the f64 arbiter used everywhere else.
//! * [`MaxLinkLoad`] — Eqn 7, `max_l Data(l)/bw(l)`: the serialization
//!   latency of the bottleneck link under dimension-ordered routing.
//! * [`CongestionBlend`] — `½·max_l Data(l)/bw(l) + ½·avg_l Data(l)/bw(l)`.
//!   The max term alone is a plateau (most swaps leave the bottleneck link
//!   untouched, so greedy refinement stalls); the average term — which by
//!   data conservation is the bandwidth-aware weighted-hops volume spread
//!   over the links — restores a gradient between plateaus. Both terms are
//!   link latencies, so the blend is unit-consistent.
//!
//! # Entry points
//!
//! * **Batch** — [`Objective::score_batch`] / [`Objective::score_one`]:
//!   full evaluation of candidate mappings. Routed objectives accumulate
//!   per-link loads through a reusable [`LinkAccumulator`]; each mapping is
//!   scored by one sequential pass in fixed edge order, so scores are pure
//!   functions of the mapping — **bit-identical at every thread count** no
//!   matter how candidates are fanned out (pinned by property tests).
//! * **Incremental delta** — [`CongestionState`]: per-link loads of one
//!   task→node assignment, maintained across refinement swaps.
//!   [`CongestionState::swap_gain`] re-routes only the edges incident to
//!   the swapped pair (O(degree · path-length) via
//!   [`LinkAccumulator::add_pair`]) and computes the exact new objective:
//!   the new bottleneck is `max(old max, max over touched links)` unless
//!   every link attaining the old max was touched and decreased, in which
//!   case (rare: exactly the swaps that improve the bottleneck) a full
//!   rescan resolves it. Gains therefore equal full re-evaluation (an
//!   equivalence property test pins this against [`crate::metrics::eval_full`]).
//!
//! # The seam
//!
//! Everything that scores mappings now goes through this module: the
//! rotation sweep (`SweepConfig::objective`), `MinVolume` refinement
//! (`HierConfig::objective`), the coordinator's `objective` experiment, the
//! service (`"objective"` request field), and `bench_objective`.
//!
//! What used to be three parallel scoring arms (a WeightedHops kernel
//! path, a routed-congestion path, a NUMA path) is now one **composable
//! incremental evaluator** — [`eval`] layers a network term (hop-priced or
//! routed) with an optional intra-node NUMA term behind a single
//! [`eval::EvalSpec`] handle and one [`eval::IncrementalEval`] swap-gain
//! contract, which is what lets routed congestion compose with depth-3
//! NUMA mapping (`MaxLinkLoad` × `xk7` and friends).
//!
//! The deeper-level objective itself is [`numa::NumaAware`]: it prices
//! node/socket/core levels from a [`crate::machine::NumaTopology`] —
//! inter-node edges per network hop, same-node cross-socket edges at a
//! flat socket cost, same-socket edges at the (usually zero) core cost.
//! It is selected structurally (`HierConfig::numa` / the service `"numa"`
//! field) rather than by [`ObjectiveKind`], because its value depends on
//! the allocation's socket structure, which link statistics alone cannot
//! express; the depth-3 hierarchical mapper optimizes it end to end and
//! [`numa::placement_swap_gain`] provides the exact O(degree) incremental
//! swap gains its socket-level refinement runs on.

pub mod eval;
pub mod numa;

use crate::apps::TaskGraph;
use crate::machine::{Allocation, Topology};
use crate::metrics::{eval_hops, LinkAccumulator, Metrics};
use crate::par::{self, Parallelism};

pub use eval::{
    build_eval, combined_value, numa_node_score, Adjacency, Eval, EvalScratch, EvalSpec,
    IncrementalEval, SwapEval,
};
pub use numa::{
    eval_numa, eval_numa_placement, placement_swap_gain, NumaAware, NumaMetrics,
};

/// Weight of the bottleneck (max) term in [`CongestionBlend`]; the rest is
/// the average-link-latency term.
pub const BLEND_MAX_WEIGHT: f64 = 0.5;

/// Routed link statistics an [`Objective`] reduces to its scalar value.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct LinkSummary {
    /// Eqn 7: max `Data(l)/bw(l)` over existing directed links.
    pub max_latency: f64,
    /// Σ `Data(l)/bw(l)` over existing directed links.
    pub sum_latency: f64,
    /// Number of existing directed links.
    pub num_links: usize,
    /// Eqn 3 weighted hops (only meaningful for [`WeightedHops`]).
    pub weighted_hops: f64,
}

impl LinkSummary {
    /// Extract the summary from a full metrics evaluation
    /// ([`crate::metrics::eval_full`] result).
    pub fn from_metrics(m: &Metrics) -> LinkSummary {
        let lm = m.link.as_ref().expect("link metrics require eval_full");
        LinkSummary {
            max_latency: lm.max_latency,
            sum_latency: lm.sum_latency,
            num_links: lm.num_links,
            weighted_hops: m.weighted_hops,
        }
    }
}

/// A mapping objective: lower values are better. Implementations are
/// stateless unit structs shared across threads (`Sync`).
pub trait Objective: Sync {
    fn name(&self) -> &'static str;

    /// Whether scoring needs routed per-link loads. `false` means the
    /// objective is a pure function of per-edge hop distances, so the
    /// batched f32 WeightedHops kernel path applies.
    fn needs_routing(&self) -> bool;

    /// Reduce routed link statistics to the scalar objective value.
    fn reduce(&self, link: &LinkSummary) -> f64;

    /// Full (f64) evaluation of one mapping. `costs`/`scratch` are reused
    /// across calls; hop-based objectives ignore them.
    fn score_one(
        &self,
        graph: &TaskGraph,
        mapping: &[u32],
        alloc: &Allocation,
        costs: &LinkCosts,
        scratch: &mut LinkAccumulator,
    ) -> f64 {
        if self.needs_routing() {
            self.reduce(&routed_summary(graph, mapping, alloc, costs, scratch))
        } else {
            eval_hops(graph, mapping, alloc).weighted_hops
        }
    }

    /// Batch entry point: score several mappings under a thread budget.
    /// Mappings land in input order and each is scored sequentially, so the
    /// result is bit-identical at every thread count.
    fn score_batch(
        &self,
        graph: &TaskGraph,
        mappings: &[Vec<u32>],
        alloc: &Allocation,
        par: Parallelism,
    ) -> Vec<f64> {
        let costs = LinkCosts::new(&alloc.machine);
        par::map_with(
            par,
            mappings,
            || LinkAccumulator::new(&alloc.machine),
            |scratch, _i, m| self.score_one(graph, m, alloc, &costs, scratch),
        )
    }
}

/// Eqn 3: volume-weighted hops (the paper's headline scalar).
pub struct WeightedHops;

impl Objective for WeightedHops {
    fn name(&self) -> &'static str {
        "whops"
    }

    fn needs_routing(&self) -> bool {
        false
    }

    fn reduce(&self, link: &LinkSummary) -> f64 {
        link.weighted_hops
    }
}

/// Eqn 7: serialization latency of the bottleneck link.
pub struct MaxLinkLoad;

impl Objective for MaxLinkLoad {
    fn name(&self) -> &'static str {
        "maxload"
    }

    fn needs_routing(&self) -> bool {
        true
    }

    fn reduce(&self, link: &LinkSummary) -> f64 {
        link.max_latency
    }
}

/// Bottleneck latency blended with the average link latency (see the
/// module docs for why the average term matters for greedy refinement).
pub struct CongestionBlend;

impl Objective for CongestionBlend {
    fn name(&self) -> &'static str {
        "blend"
    }

    fn needs_routing(&self) -> bool {
        true
    }

    fn reduce(&self, link: &LinkSummary) -> f64 {
        BLEND_MAX_WEIGHT * link.max_latency
            + (1.0 - BLEND_MAX_WEIGHT) * link.sum_latency / link.num_links.max(1) as f64
    }
}

static WHOPS: WeightedHops = WeightedHops;
static MAXLOAD: MaxLinkLoad = MaxLinkLoad;
static BLEND: CongestionBlend = CongestionBlend;

/// Copyable configuration handle for the three objectives — what travels
/// through `Z2Config`/`SweepConfig`/`HierConfig` and the service protocol.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum ObjectiveKind {
    #[default]
    WeightedHops,
    MaxLinkLoad,
    CongestionBlend,
}

impl ObjectiveKind {
    pub const ALL: [ObjectiveKind; 3] = [
        ObjectiveKind::WeightedHops,
        ObjectiveKind::MaxLinkLoad,
        ObjectiveKind::CongestionBlend,
    ];

    /// The objective implementation behind this handle.
    pub fn get(self) -> &'static dyn Objective {
        match self {
            ObjectiveKind::WeightedHops => &WHOPS,
            ObjectiveKind::MaxLinkLoad => &MAXLOAD,
            ObjectiveKind::CongestionBlend => &BLEND,
        }
    }

    pub fn name(self) -> &'static str {
        self.get().name()
    }

    /// Parse a protocol/CLI name. Accepts the canonical names plus the
    /// long-form aliases used in prose.
    pub fn parse(s: &str) -> Option<ObjectiveKind> {
        match s.to_ascii_lowercase().as_str() {
            "whops" | "weighted_hops" | "weightedhops" => Some(ObjectiveKind::WeightedHops),
            "maxload" | "max_link_load" | "maxlinkload" => Some(ObjectiveKind::MaxLinkLoad),
            "blend" | "congestion_blend" | "congestionblend" => {
                Some(ObjectiveKind::CongestionBlend)
            }
            _ => None,
        }
    }

    /// Objective value of a full metrics evaluation (used where
    /// [`crate::metrics::eval_full`] has already run, e.g. the service's
    /// `eval` op and the experiment tables).
    pub fn value_from_metrics(self, m: &Metrics) -> f64 {
        self.get().reduce(&LinkSummary::from_metrics(m))
    }
}

/// Per-topology link costs: `1/bw` per directed link (0 for mesh-boundary
/// links that do not exist — routing never uses them) and the count of
/// existing links. Built once per sweep/refinement and shared immutably by
/// all workers.
pub struct LinkCosts {
    inv_bw: Vec<f64>,
    num_links: usize,
}

impl LinkCosts {
    pub fn new(topo: &dyn Topology) -> LinkCosts {
        let mut inv_bw = vec![0f64; topo.num_directed_links()];
        let mut num_links = 0usize;
        topo.for_each_link(&mut |l, _class, _dir, bw| {
            inv_bw[l] = 1.0 / bw;
            num_links += 1;
        });
        LinkCosts { inv_bw, num_links }
    }

    #[inline]
    pub fn inv_bw(&self, link: usize) -> f64 {
        self.inv_bw[link]
    }

    pub fn num_links(&self) -> usize {
        self.num_links
    }
}

/// Route every inter-node edge of `mapping` and reduce the loads to a
/// [`LinkSummary`]. One sequential pass in edge order — the per-candidate
/// scoring kernel of the routed objectives.
pub fn routed_summary(
    graph: &TaskGraph,
    mapping: &[u32],
    alloc: &Allocation,
    costs: &LinkCosts,
    acc: &mut LinkAccumulator,
) -> LinkSummary {
    routed_summary_with_intra(graph, mapping, alloc, costs, acc).0
}

/// [`routed_summary`] plus the total weight of intra-node edges — the
/// quantity the blended (routed × NUMA) evaluator prices at the socket
/// cost. The network accumulation is identical to [`routed_summary`]'s
/// (the intra sum is a separate accumulator), so plain routed scores are
/// unaffected.
pub(crate) fn routed_summary_with_intra(
    graph: &TaskGraph,
    mapping: &[u32],
    alloc: &Allocation,
    costs: &LinkCosts,
    acc: &mut LinkAccumulator,
) -> (LinkSummary, f64) {
    assert_eq!(mapping.len(), graph.num_tasks);
    let machine = &alloc.machine;
    acc.reset();
    let mut weighted_hops = 0f64;
    let mut intra_weight = 0f64;
    for e in &graph.edges {
        let ra = mapping[e.u as usize] as usize;
        let rb = mapping[e.v as usize] as usize;
        if alloc.core_node[ra] == alloc.core_node[rb] {
            intra_weight += e.w;
            continue; // intra-node: never enters the network
        }
        let (qa, qb) = (alloc.core_router[ra] as usize, alloc.core_router[rb] as usize);
        weighted_hops += e.w * machine.hop_dist_ids(qa, qb) as f64;
        acc.add_pair(machine, qa, qb, e.w);
    }
    let mut max_latency = 0f64;
    let mut sum_latency = 0f64;
    for &l in acc.touched() {
        let lat = acc.load(l as usize) * costs.inv_bw(l as usize);
        sum_latency += lat;
        if lat > max_latency {
            max_latency = lat;
        }
    }
    (
        LinkSummary {
            max_latency,
            sum_latency,
            num_links: costs.num_links,
            weighted_hops,
        },
        intra_weight,
    )
}

/// Incrementally-maintained routed link loads of a task→node assignment:
/// the state behind congestion-objective `MinVolume` swap gains.
///
/// The assignment is represented exactly like the hierarchical mapper's
/// node level: task `t` lives on node `node_of[t]`, node `x` sits at router
/// `routers[x]`, and an edge between tasks on the same node never enters
/// the network. [`swap_gain`](CongestionState::swap_gain) evaluates a
/// candidate swap by re-routing only the incident edges into a caller-held
/// [`LinkAccumulator`] delta; [`commit`](CongestionState::commit) applies
/// that delta in O(touched) (plus a rescan only when the bottleneck link
/// itself improves). The cached objective value therefore always equals a
/// full re-evaluation of the current assignment, modulo f64 rounding.
pub struct CongestionState<'a> {
    topo: &'a dyn Topology,
    routers: &'a [u32],
    costs: LinkCosts,
    obj: &'static dyn Objective,
    load: Vec<f64>,
    sum_latency: f64,
    max_latency: f64,
    /// O(links) bottleneck rescans taken (the `max_after` slow path),
    /// counted unconditionally — the increment is noise next to the scan
    /// itself — and surfaced through [`CongestionState::rescan_count`]
    /// into refinement traces.
    rescans: std::cell::Cell<u64>,
}

impl<'a> CongestionState<'a> {
    /// Build the state for `node_of` over `graph`. `kind` must be a routed
    /// objective ([`Objective::needs_routing`]).
    pub fn build(
        topo: &'a dyn Topology,
        routers: &'a [u32],
        graph: &TaskGraph,
        node_of: &[u32],
        kind: ObjectiveKind,
    ) -> CongestionState<'a> {
        let obj = kind.get();
        assert!(
            obj.needs_routing(),
            "CongestionState is for routed objectives; {} dispatches to the hop path",
            obj.name()
        );
        assert_eq!(node_of.len(), graph.num_tasks);
        let costs = LinkCosts::new(topo);
        let mut acc = LinkAccumulator::new(topo);
        for e in &graph.edges {
            let (a, b) = (node_of[e.u as usize], node_of[e.v as usize]);
            if a != b {
                let (qa, qb) = (routers[a as usize] as usize, routers[b as usize] as usize);
                acc.add_pair(topo, qa, qb, e.w);
            }
        }
        let mut state = CongestionState {
            topo,
            routers,
            costs,
            obj,
            load: vec![0f64; topo.num_directed_links()],
            sum_latency: 0.0,
            max_latency: 0.0,
            rescans: std::cell::Cell::new(0),
        };
        for &l in acc.touched() {
            state.load[l as usize] = acc.load(l as usize);
        }
        let (max, sum) = state.scan_latencies(None);
        state.max_latency = max;
        state.sum_latency = sum;
        state
    }

    /// Current objective value of the assignment.
    pub fn value(&self) -> f64 {
        self.obj.reduce(&LinkSummary {
            max_latency: self.max_latency,
            sum_latency: self.sum_latency,
            num_links: self.costs.num_links,
            weighted_hops: 0.0,
        })
    }

    /// O(links) bottleneck rescans taken so far (the rare `max_after`
    /// slow path, hit when a swap improves the bottleneck link itself).
    pub fn rescan_count(&self) -> u64 {
        self.rescans.get()
    }

    /// (max, sum) link latency over all links, optionally with a virtual
    /// delta applied. O(links) — the rescan fallback.
    fn scan_latencies(&self, delta: Option<&LinkAccumulator>) -> (f64, f64) {
        if delta.is_some() {
            // Only delta scans are "rescans": the one delta-free scan at
            // build time is initialization, not a fallback.
            self.rescans.set(self.rescans.get() + 1);
        }
        let mut max = 0f64;
        let mut sum = 0f64;
        for (l, &load) in self.load.iter().enumerate() {
            let d = delta.map_or(0.0, |acc| acc.load(l));
            let lat = (load + d) * self.costs.inv_bw(l);
            sum += lat;
            if lat > max {
                max = lat;
            }
        }
        (max, sum)
    }

    /// Exact max latency after applying `delta`. Fast path: the new max is
    /// `max(old max, max over touched)` unless some touched link attained
    /// the old max and every touched link ends strictly below it — only
    /// then (the bottleneck may have moved) rescan.
    fn max_after(&self, delta: &LinkAccumulator) -> f64 {
        let mut touched_max = f64::NEG_INFINITY;
        let mut old_max_touched = false;
        for &l in delta.touched() {
            let l = l as usize;
            let d = delta.load(l);
            if d != 0.0 && self.load[l] * self.costs.inv_bw(l) >= self.max_latency {
                old_max_touched = true;
            }
            let lat = (self.load[l] + d) * self.costs.inv_bw(l);
            if lat > touched_max {
                touched_max = lat;
            }
        }
        if touched_max >= self.max_latency {
            touched_max
        } else if old_max_touched {
            self.scan_latencies(Some(delta)).0
        } else {
            self.max_latency
        }
    }

    /// Collect the link-load delta of swapping tasks `u` and `b` between
    /// their nodes into `acc` (reset first). `nbrs_u`/`nbrs_b` yield each
    /// task's `(neighbor task, weight)` pairs; the direct edge `u–b` (if
    /// any) moves between the same node pair and is skipped. O(degree ·
    /// path-length).
    fn collect_delta(
        &self,
        node_of: &[u32],
        u: usize,
        b: usize,
        nbrs_u: impl Iterator<Item = (u32, f64)>,
        nbrs_b: impl Iterator<Item = (u32, f64)>,
        acc: &mut LinkAccumulator,
    ) {
        acc.reset();
        let (a, bn) = (node_of[u], node_of[b]);
        debug_assert_ne!(a, bn, "swap within one node is a no-op");
        let router = |x: u32| self.routers[x as usize] as usize;
        let (ra, rbn) = (router(a), router(bn));
        for (n, w) in nbrs_u {
            if n as usize == b {
                continue;
            }
            let x = node_of[n as usize];
            if x != a {
                acc.add_pair(self.topo, ra, router(x), -w);
            }
            if x != bn {
                acc.add_pair(self.topo, rbn, router(x), w);
            }
        }
        for (n, w) in nbrs_b {
            if n as usize == u {
                continue;
            }
            let x = node_of[n as usize];
            if x != bn {
                acc.add_pair(self.topo, rbn, router(x), -w);
            }
            if x != a {
                acc.add_pair(self.topo, ra, router(x), w);
            }
        }
    }

    /// Objective gain (strictly positive = improvement) of swapping tasks
    /// `u` and `b` between their current nodes, exact with respect to a
    /// full re-evaluation. The computed delta is left in `acc`; pass it to
    /// [`commit`](CongestionState::commit) to apply the swap (the caller
    /// then updates `node_of` itself).
    pub fn swap_gain(
        &self,
        node_of: &[u32],
        u: usize,
        b: usize,
        nbrs_u: impl Iterator<Item = (u32, f64)>,
        nbrs_b: impl Iterator<Item = (u32, f64)>,
        acc: &mut LinkAccumulator,
    ) -> f64 {
        self.swap_eval(node_of, u, b, nbrs_u, nbrs_b, acc).0
    }

    /// [`swap_gain`](CongestionState::swap_gain) plus the post-swap
    /// `(max, sum)` latencies, so an accepting caller can
    /// [`commit_evaluated`](CongestionState::commit_evaluated) without
    /// recomputing the (possibly O(links)) bottleneck scan.
    pub fn swap_eval(
        &self,
        node_of: &[u32],
        u: usize,
        b: usize,
        nbrs_u: impl Iterator<Item = (u32, f64)>,
        nbrs_b: impl Iterator<Item = (u32, f64)>,
        acc: &mut LinkAccumulator,
    ) -> (f64, f64, f64) {
        self.collect_delta(node_of, u, b, nbrs_u, nbrs_b, acc);
        let new_max = self.max_after(acc);
        let mut new_sum = self.sum_latency;
        for &l in acc.touched() {
            new_sum += acc.load(l as usize) * self.costs.inv_bw(l as usize);
        }
        let gain = self.value()
            - self.obj.reduce(&LinkSummary {
                max_latency: new_max,
                sum_latency: new_sum,
                num_links: self.costs.num_links,
                weighted_hops: 0.0,
            });
        (gain, new_max, new_sum)
    }

    /// Apply a delta produced by [`swap_gain`](CongestionState::swap_gain),
    /// recomputing the post-swap bottleneck. Prefer
    /// [`commit_evaluated`](CongestionState::commit_evaluated) when the
    /// `(max, sum)` from [`swap_eval`](CongestionState::swap_eval) are at
    /// hand.
    pub fn commit(&mut self, acc: &LinkAccumulator) {
        let new_max = self.max_after(acc);
        let mut new_sum = self.sum_latency;
        for &l in acc.touched() {
            new_sum += acc.load(l as usize) * self.costs.inv_bw(l as usize);
        }
        self.commit_evaluated(acc, new_max, new_sum);
    }

    /// Apply a delta whose post-swap `(max, sum)` were already computed by
    /// [`swap_eval`](CongestionState::swap_eval) on the identical delta.
    pub fn commit_evaluated(&mut self, acc: &LinkAccumulator, new_max: f64, new_sum: f64) {
        for &l in acc.touched() {
            self.load[l as usize] += acc.load(l as usize);
        }
        self.max_latency = new_max;
        self.sum_latency = new_sum;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::stencil::stencil_graph;
    use crate::machine::{Allocation, BwModel, Network, Torus};
    use crate::metrics::eval_full;

    fn ring_alloc(n: usize) -> Allocation {
        Allocation {
            machine: Network::torus(&[n]),
            core_router: (0..n as u32).collect(),
            core_node: (0..n as u32).collect(),
            ranks_per_node: 1,
        }
    }

    #[test]
    fn kind_names_round_trip() {
        for kind in ObjectiveKind::ALL {
            assert_eq!(ObjectiveKind::parse(kind.name()), Some(kind));
        }
        assert_eq!(ObjectiveKind::parse("weighted_hops"), Some(ObjectiveKind::WeightedHops));
        assert_eq!(ObjectiveKind::parse("max_link_load"), Some(ObjectiveKind::MaxLinkLoad));
        assert!(ObjectiveKind::parse("bogus").is_none());
        assert_eq!(ObjectiveKind::default(), ObjectiveKind::WeightedHops);
    }

    #[test]
    fn link_costs_count_mesh_boundaries() {
        // 1D mesh of 4: 6 existing directed links of 12 dense slots.
        let mesh = Torus::mesh(&[4]);
        let costs = LinkCosts::new(&mesh);
        assert_eq!(costs.num_links(), 6);
        // 1D torus of 4: all 8 exist.
        assert_eq!(LinkCosts::new(&Torus::torus(&[4])).num_links(), 8);
    }

    #[test]
    fn routed_scores_match_eval_full() {
        // Every objective's score_one must agree with the reduction of a
        // full eval_full run (the engines share the routing model).
        let g = stencil_graph(&[4, 4], false, 2.5);
        let alloc = Allocation {
            machine: Network::new(vec![4, 4], vec![true, true], BwModel::PerDim(vec![2.0, 4.0])),
            core_router: (0..16u32).collect(),
            core_node: (0..16u32).collect(),
            ranks_per_node: 1,
        };
        let m: Vec<u32> = (0..16u32).map(|i| (i * 5) % 16).collect();
        let full = eval_full(&g, &m, &alloc);
        let costs = LinkCosts::new(&alloc.machine);
        let mut acc = LinkAccumulator::new(&alloc.machine);
        for kind in ObjectiveKind::ALL {
            let got = kind.get().score_one(&g, &m, &alloc, &costs, &mut acc);
            let want = kind.value_from_metrics(&full);
            assert!(
                (got - want).abs() <= 1e-9 * want.abs().max(1.0),
                "{}: {got} vs {want}",
                kind.name()
            );
        }
    }

    #[test]
    fn score_batch_bit_identical_across_threads() {
        let g = stencil_graph(&[6, 6], true, 1.3);
        let alloc = ring_alloc(36);
        let mappings: Vec<Vec<u32>> = (0..7)
            .map(|s| (0..36u32).map(|i| (i * 5 + s) % 36).collect())
            .collect();
        for kind in ObjectiveKind::ALL {
            let obj = kind.get();
            let seq = obj.score_batch(&g, &mappings, &alloc, Parallelism::sequential());
            for threads in [2, 8] {
                let par = obj.score_batch(&g, &mappings, &alloc, Parallelism::threads(threads));
                assert_eq!(par, seq, "{} threads={threads}", kind.name());
            }
        }
    }

    #[test]
    fn congestion_state_matches_fresh_build_after_swaps() {
        // Apply a series of swaps through the incremental state; after each,
        // the cached value must match a from-scratch rebuild (and eval_full
        // on the induced node-level pseudo-allocation).
        let g = stencil_graph(&[12], false, 1.0);
        let torus = Torus::torus(&[4]);
        let routers: Vec<u32> = vec![0, 1, 2, 3];
        let start: Vec<u32> = (0..12).map(|t| (t % 4) as u32).collect();
        let adj: Vec<Vec<(u32, f64)>> = {
            let mut a = vec![Vec::new(); 12];
            for e in &g.edges {
                a[e.u as usize].push((e.v, e.w));
                a[e.v as usize].push((e.u, e.w));
            }
            a
        };
        for kind in [ObjectiveKind::MaxLinkLoad, ObjectiveKind::CongestionBlend] {
            let mut node_of = start.clone();
            let mut state = CongestionState::build(&torus, &routers, &g, &node_of, kind);
            let mut acc = LinkAccumulator::new(&torus);
            for (u, b) in [(0usize, 4usize), (1, 9), (2, 7), (5, 11), (3, 6)] {
                if node_of[u] == node_of[b] {
                    continue;
                }
                let gain = state.swap_gain(
                    &node_of,
                    u,
                    b,
                    adj[u].iter().copied(),
                    adj[b].iter().copied(),
                    &mut acc,
                );
                let before = state.value();
                state.commit(&acc);
                node_of.swap(u, b);
                let fresh = CongestionState::build(&torus, &routers, &g, &node_of, kind);
                let tol = 1e-9 * fresh.value().abs().max(1.0);
                assert!(
                    (state.value() - fresh.value()).abs() <= tol,
                    "{}: incremental {} vs fresh {}",
                    kind.name(),
                    state.value(),
                    fresh.value()
                );
                assert!(
                    (gain - (before - fresh.value())).abs() <= tol,
                    "{}: gain {gain} vs re-eval {}",
                    kind.name(),
                    before - fresh.value()
                );
            }
        }
    }

    #[test]
    fn max_rescan_triggers_when_bottleneck_improves() {
        // Two tasks hammer one link; swapping one of them away must lower
        // the max — the rescan path.
        use crate::apps::{Edge, TaskGraph};
        use crate::geom::Coords;
        let torus = Torus::torus(&[4]);
        let routers: Vec<u32> = vec![0, 1, 2, 3];
        // Tasks 0,1 on node 0; 2,3 on node 1; 4,5 on nodes 2,3.
        let mut node_of: Vec<u32> = vec![0, 0, 1, 1, 2, 3];
        // Edges (0,2) and (1,3) both cross node 0 -> 1 (the hot link);
        // (4,5) is background traffic elsewhere.
        let mk_edge = |u: u32, v: u32, w: f64| Edge { u, v, w };
        let graph = TaskGraph {
            num_tasks: 6,
            edges: vec![
                mk_edge(0, 2, 10.0),
                mk_edge(1, 3, 10.0),
                mk_edge(4, 5, 1.0),
            ],
            coords: Coords::from_axes(vec![vec![0.0; 6]]),
        };
        let mut state =
            CongestionState::build(&torus, &routers, &graph, &node_of, ObjectiveKind::MaxLinkLoad);
        assert_eq!(state.value(), 20.0); // both hot edges share link 0->1
        let adj: Vec<Vec<(u32, f64)>> = vec![
            vec![(2, 10.0)],
            vec![(3, 10.0)],
            vec![(0, 10.0)],
            vec![(1, 10.0)],
            vec![(5, 1.0)],
            vec![(4, 1.0)],
        ];
        // Swap task 1 (node 0) with task 4 (node 2): one hot edge now runs
        // 2 -> 1 instead of 0 -> 1, halving the bottleneck.
        let mut acc = LinkAccumulator::new(&torus);
        let gain = state.swap_gain(
            &node_of,
            1,
            4,
            adj[1].iter().copied(),
            adj[4].iter().copied(),
            &mut acc,
        );
        state.commit(&acc);
        node_of.swap(1, 4);
        let fresh =
            CongestionState::build(&torus, &routers, &graph, &node_of, ObjectiveKind::MaxLinkLoad);
        assert!((state.value() - fresh.value()).abs() < 1e-12);
        assert!(state.value() < 20.0, "bottleneck did not improve: {}", state.value());
        assert!((gain - (20.0 - state.value())).abs() < 1e-12);
        // The slow path was taken at least once (gain eval + commit), and
        // the fresh state — which never evaluated a delta — took none.
        assert!(state.rescan_count() >= 1, "rescan counter did not move");
        assert_eq!(fresh.rescan_count(), 0);
    }
}
