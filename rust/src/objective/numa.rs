//! The NUMA-aware objective: three-level pricing of a mapping.
//!
//! [`NumaAware`] extends the Section 3 model one level below the network:
//! a mapping is charged `hop_cost` per network hop per unit weight for
//! inter-node edges (exactly WeightedHops when `hop_cost == 1`), a flat
//! `socket_cost` per unit weight for edges between ranks of the same node
//! but different sockets, and `core_cost` (usually 0) within a socket.
//! The socket of a rank comes from its position in the node's default rank
//! order ([`NumaTopology::socket_of_ranks`]), so evaluation needs only the
//! allocation plus the topology — no extra per-rank metadata.
//!
//! Two evaluation granularities:
//!
//! * **Final mappings** — [`eval_numa`] prices a task→rank assignment
//!   (the [`Objective`] impl dispatches here), reporting the per-level
//!   breakdown as [`NumaMetrics`].
//! * **Placements** — [`eval_numa_placement`] prices a task-level
//!   `(node, socket)` placement before ranks are assigned (placement
//!   within a socket never changes the value, so the depth-3 mapper can
//!   refine sockets first and hand out ranks later), and
//!   [`placement_swap_gain`] computes the exact objective gain of swapping
//!   two tasks' placements by re-pricing only their incident edges —
//!   O(degree) per candidate swap, the engine behind the socket-level
//!   `MinVolume` refinement. A property test pins the incremental gains
//!   against full [`eval_numa_placement`] re-evaluation.

use super::{LinkSummary, Objective};
use crate::apps::TaskGraph;
use crate::machine::{Allocation, NumaTopology, Topology};
use crate::metrics::LinkAccumulator;
use crate::objective::LinkCosts;

/// Per-level breakdown of a mapping's NUMA-aware cost.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct NumaMetrics {
    /// Σ over inter-node edges of `w · hops` (the Section 3 WeightedHops
    /// restricted to the network).
    pub network_weighted_hops: f64,
    /// Σ over same-node, cross-socket edges of `w`.
    pub socket_weight: f64,
    /// Σ over same-socket edges of `w`.
    pub core_weight: f64,
    /// `hop_cost · network + socket_cost · socket + core_cost · core`.
    pub value: f64,
}

/// Cost of one edge between placements `(na, sa)` and `(nb, sb)` under
/// `topo` (unit weight): hop-priced network distance across nodes, flat
/// socket/core cost inside a node.
#[inline]
fn pair_cost(
    topo: &NumaTopology,
    net: &dyn Topology,
    node_routers: &[u32],
    na: u32,
    sa: u32,
    nb: u32,
    sb: u32,
) -> f64 {
    if na == nb {
        if sa == sb {
            topo.core_cost
        } else {
            topo.socket_cost
        }
    } else {
        let h = net.hop_dist_ids(
            node_routers[na as usize] as usize,
            node_routers[nb as usize] as usize,
        );
        topo.hop_cost * h as f64
    }
}

/// Price a task-level `(node, socket)` placement: `node_of[t]` is the node
/// of task `t`, `sock_of[t]` its within-node socket, and node `x` sits at
/// router `node_routers[x]`. One sequential pass in edge order.
pub fn eval_numa_placement(
    graph: &TaskGraph,
    node_of: &[u32],
    sock_of: &[u32],
    node_routers: &[u32],
    net: &dyn Topology,
    topo: &NumaTopology,
) -> NumaMetrics {
    assert_eq!(node_of.len(), graph.num_tasks);
    assert_eq!(sock_of.len(), graph.num_tasks);
    let mut m = NumaMetrics::default();
    for e in &graph.edges {
        let (u, v) = (e.u as usize, e.v as usize);
        let (na, nb) = (node_of[u], node_of[v]);
        if na != nb {
            m.network_weighted_hops += e.w
                * net.hop_dist_ids(
                    node_routers[na as usize] as usize,
                    node_routers[nb as usize] as usize,
                ) as f64;
        } else if sock_of[u] != sock_of[v] {
            m.socket_weight += e.w;
        } else {
            m.core_weight += e.w;
        }
    }
    m.value = topo.hop_cost * m.network_weighted_hops
        + topo.socket_cost * m.socket_weight
        + topo.core_cost * m.core_weight;
    m
}

/// Price a finished task→rank mapping: nodes and sockets are derived from
/// the allocation (socket = position in the node's default rank order).
pub fn eval_numa(
    graph: &TaskGraph,
    task_to_rank: &[u32],
    alloc: &Allocation,
    topo: &NumaTopology,
) -> NumaMetrics {
    assert_eq!(task_to_rank.len(), graph.num_tasks);
    let rank_sock = topo.socket_of_ranks(alloc);
    let node_of: Vec<u32> = task_to_rank
        .iter()
        .map(|&r| alloc.core_node[r as usize])
        .collect();
    let sock_of: Vec<u32> = task_to_rank.iter().map(|&r| rank_sock[r as usize]).collect();
    eval_numa_placement(
        graph,
        &node_of,
        &sock_of,
        &alloc.node_routers(),
        &alloc.machine,
        topo,
    )
}

/// Exact NUMA-aware objective gain (positive = improvement) of swapping
/// the placements of tasks `u` and `b`, re-pricing only their incident
/// edges. `nbrs_u`/`nbrs_b` yield `(neighbor task, weight)` pairs; the
/// direct edge `u–b` (if any) swaps symmetric endpoints, so its cost is
/// unchanged and skipped. Works for same-node swaps (where only the
/// socket/core terms move) and cross-node swaps alike; a property test
/// pins it against full [`eval_numa_placement`] re-evaluation.
#[allow(clippy::too_many_arguments)]
pub fn placement_swap_gain(
    topo: &NumaTopology,
    net: &dyn Topology,
    node_routers: &[u32],
    node_of: &[u32],
    sock_of: &[u32],
    u: usize,
    b: usize,
    nbrs_u: impl Iterator<Item = (u32, f64)>,
    nbrs_b: impl Iterator<Item = (u32, f64)>,
) -> f64 {
    let (nu, su) = (node_of[u], sock_of[u]);
    let (nb, sb) = (node_of[b], sock_of[b]);
    let mut gain = 0f64;
    for (n, w) in nbrs_u {
        if n as usize == b {
            continue;
        }
        let (nx, sx) = (node_of[n as usize], sock_of[n as usize]);
        gain += w
            * (pair_cost(topo, net, node_routers, nu, su, nx, sx)
                - pair_cost(topo, net, node_routers, nb, sb, nx, sx));
    }
    for (n, w) in nbrs_b {
        if n as usize == u {
            continue;
        }
        let (nx, sx) = (node_of[n as usize], sock_of[n as usize]);
        gain += w
            * (pair_cost(topo, net, node_routers, nb, sb, nx, sx)
                - pair_cost(topo, net, node_routers, nu, su, nx, sx));
    }
    gain
}

/// The NUMA-aware [`Objective`]: node/socket/core pricing of a task→rank
/// mapping from a [`NumaTopology`]. Unlike the routed objectives it needs
/// the socket structure, not per-link loads, so it stays off the routing
/// path; [`Objective::reduce`] (which only sees link statistics) reports
/// the network term alone — use [`Objective::score_one`] / [`eval_numa`]
/// for the full three-level value.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct NumaAware {
    pub topo: NumaTopology,
}

impl NumaAware {
    pub fn new(topo: NumaTopology) -> NumaAware {
        NumaAware { topo }
    }
}

impl Objective for NumaAware {
    fn name(&self) -> &'static str {
        "numa"
    }

    fn needs_routing(&self) -> bool {
        false
    }

    fn reduce(&self, link: &LinkSummary) -> f64 {
        // Link statistics carry no socket structure: only the network term
        // is derivable here.
        self.topo.hop_cost * link.weighted_hops
    }

    fn score_one(
        &self,
        graph: &TaskGraph,
        mapping: &[u32],
        alloc: &Allocation,
        _costs: &LinkCosts,
        _scratch: &mut LinkAccumulator,
    ) -> f64 {
        eval_numa(graph, mapping, alloc, &self.topo).value
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::{Edge, TaskGraph};
    use crate::geom::Coords;
    use crate::machine::{Allocation, Torus};
    use crate::par::Parallelism;

    /// 2 nodes x 2 sockets x 2 ranks on a 4-ring (routers 0 and 2).
    fn alloc() -> Allocation {
        Allocation::heterogeneous(Torus::torus(&[4]), &[0, 2], &[4, 4]).unwrap()
    }

    fn topo() -> NumaTopology {
        NumaTopology::new(2, 2, 0.5, 0.125, 1.0)
    }

    fn graph() -> TaskGraph {
        // Edges: (0,1) same socket, (0,2) cross socket, (0,4) cross node
        // (2 hops on the 4-ring), (5,7) cross socket on node 1.
        TaskGraph {
            num_tasks: 8,
            edges: vec![
                Edge { u: 0, v: 1, w: 3.0 },
                Edge { u: 0, v: 2, w: 2.0 },
                Edge { u: 0, v: 4, w: 1.5 },
                Edge { u: 5, v: 7, w: 4.0 },
            ],
            coords: Coords::from_axes(vec![(0..8).map(|i| i as f64).collect()]),
        }
    }

    #[test]
    fn eval_prices_all_three_levels() {
        let m = eval_numa(&graph(), &(0..8u32).collect::<Vec<_>>(), &alloc(), &topo());
        assert_eq!(m.core_weight, 3.0);
        assert_eq!(m.socket_weight, 6.0);
        assert_eq!(m.network_weighted_hops, 1.5 * 2.0);
        assert_eq!(m.value, 1.0 * 3.0 + 0.5 * 6.0 + 0.125 * 3.0);
    }

    #[test]
    fn objective_impl_matches_eval() {
        let g = graph();
        let a = alloc();
        let obj = NumaAware::new(topo());
        assert_eq!(obj.name(), "numa");
        assert!(!obj.needs_routing());
        let mapping: Vec<u32> = (0..8u32).rev().collect();
        let scores = obj.score_batch(&g, &[mapping.clone()], &a, Parallelism::sequential());
        assert_eq!(scores[0], eval_numa(&g, &mapping, &a, &topo()).value);
    }

    #[test]
    fn bgq_topology_reduces_to_internode_whops() {
        // One socket, zero socket/core cost: the value is exactly the
        // inter-node WeightedHops of the mapping.
        use crate::metrics::eval_hops;
        let g = graph();
        let a = alloc();
        let t = NumaTopology::bgq();
        let mapping: Vec<u32> = (0..8u32).collect();
        let m = eval_numa(&g, &mapping, &a, &t);
        assert_eq!(m.socket_weight, 0.0);
        assert_eq!(m.value, eval_hops(&g, &mapping, &a).weighted_hops);
    }

    #[test]
    fn swap_gain_matches_full_reevaluation() {
        let g = graph();
        let a = alloc();
        let t = topo();
        let routers = a.node_routers();
        let mut node_of: Vec<u32> = (0..8).map(|i| (i / 4) as u32).collect();
        let mut sock_of: Vec<u32> = (0..8).map(|i| ((i / 2) % 2) as u32).collect();
        let mut adj: Vec<Vec<(u32, f64)>> = vec![Vec::new(); 8];
        for e in &g.edges {
            adj[e.u as usize].push((e.v, e.w));
            adj[e.v as usize].push((e.u, e.w));
        }
        for (u, b) in [(0usize, 2usize), (0, 4), (1, 7), (3, 5)] {
            let before = eval_numa_placement(&g, &node_of, &sock_of, &routers, &a.machine, &t);
            let gain = placement_swap_gain(
                &t,
                &a.machine,
                &routers,
                &node_of,
                &sock_of,
                u,
                b,
                adj[u].iter().copied(),
                adj[b].iter().copied(),
            );
            node_of.swap(u, b);
            sock_of.swap(u, b);
            let after = eval_numa_placement(&g, &node_of, &sock_of, &routers, &a.machine, &t);
            assert!(
                (gain - (before.value - after.value)).abs() < 1e-12,
                "swap ({u},{b}): gain {gain} vs delta {}",
                before.value - after.value
            );
        }
    }
}
