// The `simd` feature routes `metrics::native::whops_row` through
// `std::simd::f32x8` (nightly-only `portable_simd`); the default build
// never sees this attribute.
#![cfg_attr(feature = "simd", feature(portable_simd))]
//! # taskmap — geometric partitioning and ordering strategies for task
//! mapping on parallel computers
//!
//! A full reproduction of Deveci et al., *"Geometric Partitioning and
//! Ordering Strategies for Task Mapping on Parallel Computers"* (2018) as a
//! three-layer rust + JAX + Pallas system:
//!
//! * **L3 (this crate)** — the geometric task mapper (Multi-Jagged
//!   partitioning, Z/Gray/FZ/MFZ/Hilbert orderings, the Z2 strategy
//!   pipelines), machine models for Cray XK7 Gemini and IBM BG/Q toruses,
//!   allocation simulators, the Section 3 metrics, a communication-time
//!   model, and the experiment coordinator that regenerates every table and
//!   figure of the paper.
//! * **L2/L1 (python, build-time only)** — the batched WeightedHops
//!   evaluator (`python/compile/model.py`) wrapping a Pallas kernel
//!   (`python/compile/kernels/whops.py`), AOT-lowered to HLO text.
//! * **Runtime** — [`runtime`] loads those artifacts via the PJRT CPU
//!   client; the rotation sweep scores candidate mappings through it with
//!   no Python on the request path.
//!
//! Quick start: see `examples/quickstart.rs`; experiments: `repro --help`.
//!
//! Beyond the paper's flat mapper, [`hier`] adds a hierarchical
//! node→socket→core mapping subsystem: an MJ rotation sweep over *node*
//! coordinates picks a capacity-balanced task→node assignment
//! (heterogeneous ranks-per-node allocations included), pluggable
//! intra-node strategies place tasks on cores (platform order,
//! Hilbert-curve order, or greedy `MinVolume` boundary refinement of the
//! node assignment), and intra-node messages stay off the network per the
//! Section 3 model. With a [`machine::NumaTopology`] configured
//! (`HierConfig::numa`), the mapper runs at **depth 3**: a geometric
//! socket split plus cross-socket refinement inside each node, scored by
//! the [`objective::NumaAware`] node/socket/core cost model.
//!
//! What the mapper *optimizes* is pluggable too: [`objective`] provides
//! `WeightedHops` (Eqn 3), `MaxLinkLoad` (Eqn 7 routed bottleneck
//! latency), and `CongestionBlend` behind one trait, selected per run via
//! `Z2Config::objective`, `HierConfig::objective`, or the service's
//! `"objective"` field — and the scoring layer itself is one composable
//! incremental evaluator ([`objective::eval`]): a network term (hop-priced
//! or routed) layered with an optional intra-node NUMA term, so every
//! objective composes with depth-3 NUMA mapping (including the blended
//! routed-congestion × NUMA pipeline) and the rotation sweep, `MinVolume`
//! refinement, and socket refinement all price swaps under the same
//! objective end to end.
//!
//! Every layer is instrumented through [`obs`], a zero-dependency
//! tracing + metrics subsystem (RAII spans, log-bucketed latency
//! histograms, a `chrome://tracing`-convertible `TASKMAP_TRACE` JSONL
//! sink) that is compiled in but disabled by default — the hot path pays
//! one branch, and enabling it never changes a mapping bit.
//!
//! For task counts far beyond the paper's 128K ranks, [`coarsen`] adds a
//! multilevel V-cycle in front of the sweep (`HierConfig::coarsen` /
//! `Z2Config::coarsen` / the service `"coarsen"` object): matched task
//! pairs collapse into supertasks (summed weights, weight-averaged
//! coordinates) until the graph fits a size budget, the sweep solves the
//! coarsest instance, and bounded `MinVolume` refinement polishes the
//! projected mapping at every level on the way back up — million-task
//! graphs map in seconds with quality within a few percent of the direct
//! sweep.
//!
//! The map-and-score hot path (MJ partitioning, the rotation sweep, batched
//! WeightedHops scoring) is parallel and allocation-free in steady state:
//! [`par`] provides deterministic fork–join primitives (results are
//! bit-identical to the sequential path at every thread count), and the
//! `MjScratch`/`ScoreScratch` arenas are reused across candidates. Set
//! `TASKMAP_THREADS=N` to bound (or with `N=1`, disable) the *default*
//! parallelism — it sizes [`par::Parallelism::auto`]; call sites passing
//! an explicit thread budget are unaffected.

pub mod apps;
pub mod coarsen;
pub mod coordinator;
pub mod geom;
pub mod hier;
pub mod machine;
pub mod mapping;
pub mod metrics;
pub mod mj;
pub mod objective;
pub mod obs;
pub mod par;
pub mod runtime;
pub mod sfc;
pub mod simulate;
pub mod testutil;
pub mod util;
