//! Minimal property-testing harness (no `proptest` in the vendor set).
//!
//! `check(name, cases, |rng| ...)` runs a closure against `cases` seeded
//! random inputs; on failure it reports the failing case seed so the exact
//! input can be replayed with `replay(seed, f)`. There is no shrinking —
//! generators in this codebase are parameterized small enough that raw
//! failing seeds are debuggable.

use super::rng::Rng;
use crate::geom::Coords;
use crate::sfc::PartOrdering;

/// Thread counts the parallel-vs-sequential determinism properties sweep:
/// the sequential reference, the smallest real fork, and an oversubscribed
/// budget.
pub const THREAD_COUNTS: [usize; 3] = [1, 2, 8];

/// Random coordinate set: `n` points, `dim` axes, integer-valued entries in
/// `[0, extent)`. Shared by the partitioner/mapping/sweep properties and
/// the benches.
pub fn random_coords(rng: &mut Rng, n: usize, dim: usize, extent: usize) -> Coords {
    let mut c = Coords::with_capacity(dim, n);
    let mut p = vec![0f64; dim];
    for _ in 0..n {
        for x in p.iter_mut() {
            *x = rng.below(extent) as f64;
        }
        c.push(&p);
    }
    c
}

/// A random MJ part-numbering ordering (never `Hilbert`, which the MJ
/// kernel rejects).
pub fn random_part_ordering(rng: &mut Rng) -> PartOrdering {
    match rng.below(4) {
        0 => PartOrdering::Z,
        1 => PartOrdering::Gray,
        2 => PartOrdering::FZ,
        _ => PartOrdering::MFZ,
    }
}

/// Run `f` for `cases` deterministically-derived seeds. Each invocation gets
/// a fresh `Rng`; `f` returns `Err(msg)` to fail the property.
pub fn check<F>(name: &str, cases: u64, mut f: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    for case in 0..cases {
        let seed = 0x5EED_0000_0000 ^ case;
        let mut rng = Rng::new(seed);
        if let Err(msg) = f(&mut rng) {
            panic!("property `{name}` failed at case {case} (seed {seed:#x}): {msg}");
        }
    }
}

/// Replay a single failing case.
pub fn replay<F>(seed: u64, mut f: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    let mut rng = Rng::new(seed);
    if let Err(msg) = f(&mut rng) {
        panic!("replayed property failed (seed {seed:#x}): {msg}");
    }
}

/// Assert two floats are within relative-or-absolute tolerance.
pub fn approx_eq(a: f64, b: f64, rtol: f64, atol: f64) -> Result<(), String> {
    let diff = (a - b).abs();
    let tol = atol + rtol * a.abs().max(b.abs());
    if diff <= tol {
        Ok(())
    } else {
        Err(format!("{a} != {b} (diff {diff:.3e} > tol {tol:.3e})"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_passes_trivial_property() {
        check("x+0==x", 20, |rng| {
            let x = rng.next_u64();
            if x.wrapping_add(0) == x {
                Ok(())
            } else {
                Err("arithmetic broken".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property `always-fails`")]
    fn check_reports_failures() {
        check("always-fails", 1, |_| Err("nope".into()));
    }

    #[test]
    fn approx_eq_tolerances() {
        assert!(approx_eq(1.0, 1.0 + 1e-9, 1e-6, 0.0).is_ok());
        assert!(approx_eq(1.0, 1.1, 1e-6, 0.0).is_err());
        assert!(approx_eq(0.0, 1e-9, 0.0, 1e-6).is_ok());
    }
}
