//! Minimal property-testing harness (no `proptest` in the vendor set).
//!
//! `check(name, cases, |rng| ...)` runs a closure against `cases` seeded
//! random inputs; on failure it reports the failing case seed so the exact
//! input can be replayed with `replay(seed, f)`. There is no shrinking —
//! generators in this codebase are parameterized small enough that raw
//! failing seeds are debuggable.

use super::rng::Rng;

/// Run `f` for `cases` deterministically-derived seeds. Each invocation gets
/// a fresh `Rng`; `f` returns `Err(msg)` to fail the property.
pub fn check<F>(name: &str, cases: u64, mut f: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    for case in 0..cases {
        let seed = 0x5EED_0000_0000 ^ case;
        let mut rng = Rng::new(seed);
        if let Err(msg) = f(&mut rng) {
            panic!("property `{name}` failed at case {case} (seed {seed:#x}): {msg}");
        }
    }
}

/// Replay a single failing case.
pub fn replay<F>(seed: u64, mut f: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    let mut rng = Rng::new(seed);
    if let Err(msg) = f(&mut rng) {
        panic!("replayed property failed (seed {seed:#x}): {msg}");
    }
}

/// Assert two floats are within relative-or-absolute tolerance.
pub fn approx_eq(a: f64, b: f64, rtol: f64, atol: f64) -> Result<(), String> {
    let diff = (a - b).abs();
    let tol = atol + rtol * a.abs().max(b.abs());
    if diff <= tol {
        Ok(())
    } else {
        Err(format!("{a} != {b} (diff {diff:.3e} > tol {tol:.3e})"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_passes_trivial_property() {
        check("x+0==x", 20, |rng| {
            let x = rng.next_u64();
            if x.wrapping_add(0) == x {
                Ok(())
            } else {
                Err("arithmetic broken".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property `always-fails`")]
    fn check_reports_failures() {
        check("always-fails", 1, |_| Err("nope".into()));
    }

    #[test]
    fn approx_eq_tolerances() {
        assert!(approx_eq(1.0, 1.0 + 1e-9, 1e-6, 0.0).is_ok());
        assert!(approx_eq(1.0, 1.1, 1e-6, 0.0).is_err());
        assert!(approx_eq(0.0, 1e-9, 0.0, 1e-6).is_ok());
    }
}
