//! Test/bench support: seeded PRNG, a tiny property-testing harness, and a
//! minimal JSON writer.
//!
//! The offline vendor set has no `rand`, `proptest`, `criterion` or `serde`,
//! so the handful of primitives the library and its tests need live here.

pub mod bench;
pub mod faults;
pub mod graphs;
pub mod json;
pub mod prop;
pub mod rng;

pub use rng::Rng;
