//! Deterministic, seeded fault injection for chaos testing.
//!
//! Production code marks interesting points with [`failpoint`]`("site.name")`.
//! With no plan installed the call is a single relaxed atomic load — there is
//! no compile-time feature gate and no cost worth measuring on the happy
//! path. Tests install a [`FaultPlan`] (a seed plus per-site probabilities
//! and actions) via [`install`]; while the returned [`FaultGuard`] lives,
//! matching failpoints panic or sleep according to the plan.
//!
//! # Determinism contract
//!
//! Whether the `k`-th *hit* of a site fires is a pure function
//! [`would_fire`]`(seed, site, k, p)` — no global RNG state, no ordering
//! dependence between sites. Replaying the same seed therefore replays the
//! exact same fire/no-fire decision sequence per site. Under concurrency the
//! *assignment* of hit indices to threads depends on scheduling, but the
//! decision sequence itself — and thus the total number of fires among the
//! first `n` hits — is bit-reproducible at every thread count. Chaos tests
//! with one in-flight request at a time can predict each individual outcome;
//! concurrent tests assert exact counts.
//!
//! Plans are process-global. [`install`] holds a lock for the lifetime of
//! the guard, so chaos tests serialize against each other even when the test
//! harness runs them on multiple threads; tests that need *no* faults but
//! must not see another test's plan install an empty plan to hold the lock.

use crate::util::hash::{fnv1a_raw, splitmix64, GOLDEN};
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard, OnceLock, RwLock};

/// What an armed failpoint does when it fires.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultAction {
    /// Panic with a message naming the site (exercises `catch_unwind` paths).
    Panic,
    /// Sleep for the given number of milliseconds (simulates slow work).
    SleepMs(u64),
}

/// One armed site: fire with `probability` on each hit, at most `max_fires`
/// times in total.
#[derive(Clone, Debug)]
struct FaultSpec {
    action: FaultAction,
    probability: f64,
    max_fires: u64,
}

/// Per-site counters (hits observed, fires triggered).
#[derive(Default)]
struct SiteState {
    hits: AtomicU64,
    fires: AtomicU64,
}

/// A seeded set of armed failpoint sites.
pub struct FaultPlan {
    seed: u64,
    specs: BTreeMap<String, FaultSpec>,
    state: BTreeMap<String, SiteState>,
}

impl FaultPlan {
    pub fn new(seed: u64) -> FaultPlan {
        FaultPlan {
            seed,
            specs: BTreeMap::new(),
            state: BTreeMap::new(),
        }
    }

    /// Arm `site` to perform `action` with probability `p` on each hit.
    pub fn site(self, site: &str, action: FaultAction, p: f64) -> FaultPlan {
        self.site_limited(site, action, p, u64::MAX)
    }

    /// Like [`FaultPlan::site`] but fires at most `max_fires` times.
    pub fn site_limited(
        mut self,
        site: &str,
        action: FaultAction,
        p: f64,
        max_fires: u64,
    ) -> FaultPlan {
        assert!((0.0..=1.0).contains(&p), "probability must be in [0,1]");
        self.specs.insert(
            site.to_string(),
            FaultSpec {
                action,
                probability: p,
                max_fires,
            },
        );
        self.state.insert(site.to_string(), SiteState::default());
        self
    }

    /// Record a hit at `site` and decide whether it fires. Returns the action
    /// to perform, or `None` (unarmed site, probability miss, or fire budget
    /// exhausted).
    pub fn fire(&self, site: &str) -> Option<FaultAction> {
        let spec = self.specs.get(site)?;
        let state = &self.state[site];
        let hit = state.hits.fetch_add(1, Ordering::Relaxed);
        if !would_fire(self.seed, site, hit, spec.probability) {
            return None;
        }
        // Claim a fire slot; losers of the race past max_fires do nothing.
        let claimed = state
            .fires
            .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |n| {
                if n < spec.max_fires {
                    Some(n + 1)
                } else {
                    None
                }
            })
            .is_ok();
        if claimed {
            Some(spec.action)
        } else {
            None
        }
    }

    /// Total hits observed at `site` (0 if unarmed).
    pub fn hits(&self, site: &str) -> u64 {
        self.state
            .get(site)
            .map_or(0, |s| s.hits.load(Ordering::Relaxed))
    }

    /// Total fires triggered at `site` (0 if unarmed).
    pub fn fires(&self, site: &str) -> u64 {
        self.state
            .get(site)
            .map_or(0, |s| s.fires.load(Ordering::Relaxed))
    }
}

/// Pure decision function: does hit number `hit` of `site` fire under `seed`
/// with probability `probability`? This is the whole determinism story —
/// no state, so any (seed, site, hit) triple always answers the same.
/// The hash primitives live in [`crate::util::hash`] now, but the decision
/// value is bit-for-bit what it always was (`fnv1a_raw` is the historical
/// un-avalanched FNV-1a), so pinned chaos seeds keep their fire counts.
pub fn would_fire(seed: u64, site: &str, hit: u64, probability: f64) -> bool {
    if probability <= 0.0 {
        return false;
    }
    if probability >= 1.0 {
        return true;
    }
    let h = splitmix64(seed ^ fnv1a_raw(site) ^ hit.wrapping_mul(GOLDEN));
    // Same 53-bit uniform construction as testutil::rng::Rng::f64.
    let u = (h >> 11) as f64 * (1.0 / (1u64 << 53) as f64);
    u < probability
}

/// Fast-path flag: failpoints skip all locking while no plan is installed.
static ACTIVE: AtomicBool = AtomicBool::new(false);
static PLAN: OnceLock<RwLock<Option<Arc<FaultPlan>>>> = OnceLock::new();
/// Serializes chaos tests: held for the lifetime of each [`FaultGuard`].
static INSTALL_LOCK: OnceLock<Mutex<()>> = OnceLock::new();

fn plan_cell() -> &'static RwLock<Option<Arc<FaultPlan>>> {
    PLAN.get_or_init(|| RwLock::new(None))
}

/// Keeps a plan installed; uninstalls on drop and releases the global
/// install lock so the next chaos test can proceed.
pub struct FaultGuard {
    plan: Arc<FaultPlan>,
    _serial: MutexGuard<'static, ()>,
}

impl FaultGuard {
    /// The installed plan (for reading hit/fire counters in assertions).
    pub fn plan(&self) -> &Arc<FaultPlan> {
        &self.plan
    }
}

impl Drop for FaultGuard {
    fn drop(&mut self) {
        ACTIVE.store(false, Ordering::SeqCst);
        match plan_cell().write() {
            Ok(mut w) => *w = None,
            Err(poisoned) => *poisoned.into_inner() = None,
        }
    }
}

/// Install `plan` process-wide until the returned guard drops. Blocks while
/// another guard is alive (chaos tests serialize on this).
pub fn install(plan: FaultPlan) -> FaultGuard {
    let serial = match INSTALL_LOCK.get_or_init(|| Mutex::new(())).lock() {
        Ok(g) => g,
        // A previous chaos test panicked while holding the lock; the plan
        // was still cleared by its guard's Drop, so the lock is safe to take.
        Err(poisoned) => poisoned.into_inner(),
    };
    let plan = Arc::new(plan);
    match plan_cell().write() {
        Ok(mut w) => *w = Some(Arc::clone(&plan)),
        Err(poisoned) => *poisoned.into_inner() = Some(Arc::clone(&plan)),
    }
    ACTIVE.store(true, Ordering::SeqCst);
    FaultGuard {
        plan,
        _serial: serial,
    }
}

/// Decide whether `site` fires right now (recording a hit). `None` unless a
/// plan is installed and arms this site and the seeded decision says fire.
pub fn fire(site: &str) -> Option<FaultAction> {
    if !ACTIVE.load(Ordering::Relaxed) {
        return None;
    }
    let guard = match plan_cell().read() {
        Ok(g) => g,
        Err(poisoned) => poisoned.into_inner(),
    };
    guard.as_ref().and_then(|p| p.fire(site))
}

/// Production-side marker: perform whatever fault is armed at `site`.
/// No-op (one relaxed load) when no plan is installed.
pub fn failpoint(site: &str) {
    match fire(site) {
        Some(FaultAction::Panic) => panic!("injected fault at failpoint \"{site}\""),
        Some(FaultAction::SleepMs(ms)) => {
            std::thread::sleep(std::time::Duration::from_millis(ms))
        }
        None => {}
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn would_fire_is_pure_and_seeded() {
        for hit in 0..64 {
            assert_eq!(
                would_fire(42, "a.site", hit, 0.3),
                would_fire(42, "a.site", hit, 0.3)
            );
        }
        // Different seeds give different decision sequences.
        let a: Vec<bool> = (0..64).map(|h| would_fire(1, "s", h, 0.5)).collect();
        let b: Vec<bool> = (0..64).map(|h| would_fire(2, "s", h, 0.5)).collect();
        assert_ne!(a, b);
        // Different sites decouple under the same seed.
        let c: Vec<bool> = (0..64).map(|h| would_fire(1, "t", h, 0.5)).collect();
        assert_ne!(a, c);
    }

    #[test]
    fn would_fire_edge_probabilities() {
        assert!(!would_fire(7, "x", 0, 0.0));
        assert!(would_fire(7, "x", 0, 1.0));
    }

    #[test]
    fn would_fire_rate_tracks_probability() {
        let fires = (0..10_000)
            .filter(|&h| would_fire(99, "rate", h, 0.25))
            .count();
        assert!((2000..3000).contains(&fires), "fires = {fires}");
    }

    #[test]
    fn plan_fire_matches_pure_function_sequentially() {
        let plan = FaultPlan::new(5).site("s", FaultAction::Panic, 0.4);
        for hit in 0..100 {
            let expect = would_fire(5, "s", hit, 0.4);
            assert_eq!(plan.fire("s").is_some(), expect, "hit {hit}");
        }
        assert_eq!(plan.hits("s"), 100);
        let expected_fires = (0..100).filter(|&h| would_fire(5, "s", h, 0.4)).count();
        assert_eq!(plan.fires("s"), expected_fires as u64);
    }

    #[test]
    fn unarmed_sites_never_fire() {
        let plan = FaultPlan::new(5).site("armed", FaultAction::Panic, 1.0);
        assert_eq!(plan.fire("other"), None);
        assert_eq!(plan.hits("other"), 0);
        assert!(plan.fire("armed").is_some());
    }

    #[test]
    fn max_fires_caps_total_fires() {
        let plan = FaultPlan::new(5).site_limited("s", FaultAction::SleepMs(1), 1.0, 3);
        let fired = (0..10).filter(|_| plan.fire("s").is_some()).count();
        assert_eq!(fired, 3);
        assert_eq!(plan.hits("s"), 10);
        assert_eq!(plan.fires("s"), 3);
    }

    #[test]
    fn install_guard_arms_and_disarms_failpoints() {
        {
            let guard = install(FaultPlan::new(11).site("t.x", FaultAction::SleepMs(0), 1.0));
            assert_eq!(fire("t.x"), Some(FaultAction::SleepMs(0)));
            assert_eq!(guard.plan().hits("t.x"), 1);
            failpoint("t.x"); // sleeps 0ms; must not panic
            assert_eq!(guard.plan().hits("t.x"), 2);
        }
        assert_eq!(fire("t.x"), None);
    }

    #[test]
    fn failpoint_panic_action_panics_with_site_name() {
        let guard = install(FaultPlan::new(11).site("t.boom", FaultAction::Panic, 1.0));
        let err = std::panic::catch_unwind(|| failpoint("t.boom")).unwrap_err();
        let msg = err
            .downcast_ref::<String>()
            .cloned()
            .unwrap_or_default();
        assert!(msg.contains("t.boom"), "msg = {msg:?}");
        drop(guard);
    }
}
