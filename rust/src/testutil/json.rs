//! Minimal JSON reading/writing (no `serde` in the vendor set).
//!
//! Only what the repo needs: a writer for experiment reports and the mapping
//! service protocol, and a tolerant reader good enough for
//! `artifacts/manifest.json` and service requests (flat objects of numbers,
//! strings, arrays).

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value (subset: no exponent-form output, objects are ordered).
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write(out);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    /// Parse a JSON document. Returns `Err` with a byte offset on failure.
    pub fn parse(text: &str) -> Result<Json, String> {
        let bytes = text.as_bytes();
        let mut pos = 0usize;
        let v = parse_value(bytes, &mut pos)?;
        skip_ws(bytes, &mut pos);
        if pos != bytes.len() {
            return Err(format!("trailing garbage at byte {pos}"));
        }
        Ok(v)
    }
}

fn skip_ws(b: &[u8], pos: &mut usize) {
    while *pos < b.len() && matches!(b[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn parse_value(b: &[u8], pos: &mut usize) -> Result<Json, String> {
    skip_ws(b, pos);
    if *pos >= b.len() {
        return Err("unexpected end of input".into());
    }
    match b[*pos] {
        b'{' => parse_obj(b, pos),
        b'[' => parse_arr(b, pos),
        b'"' => Ok(Json::Str(parse_string(b, pos)?)),
        b't' => parse_lit(b, pos, "true", Json::Bool(true)),
        b'f' => parse_lit(b, pos, "false", Json::Bool(false)),
        b'n' => parse_lit(b, pos, "null", Json::Null),
        _ => parse_num(b, pos),
    }
}

fn parse_lit(b: &[u8], pos: &mut usize, lit: &str, v: Json) -> Result<Json, String> {
    if b[*pos..].starts_with(lit.as_bytes()) {
        *pos += lit.len();
        Ok(v)
    } else {
        Err(format!("bad literal at byte {pos}", pos = *pos))
    }
}

fn parse_num(b: &[u8], pos: &mut usize) -> Result<Json, String> {
    let start = *pos;
    while *pos < b.len()
        && matches!(b[*pos], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
    {
        *pos += 1;
    }
    std::str::from_utf8(&b[start..*pos])
        .ok()
        .and_then(|s| s.parse::<f64>().ok())
        .map(Json::Num)
        .ok_or_else(|| format!("bad number at byte {start}"))
}

fn parse_string(b: &[u8], pos: &mut usize) -> Result<String, String> {
    debug_assert_eq!(b[*pos], b'"');
    *pos += 1;
    let mut s = String::new();
    while *pos < b.len() {
        match b[*pos] {
            b'"' => {
                *pos += 1;
                return Ok(s);
            }
            b'\\' => {
                *pos += 1;
                if *pos >= b.len() {
                    break;
                }
                match b[*pos] {
                    b'"' => s.push('"'),
                    b'\\' => s.push('\\'),
                    b'/' => s.push('/'),
                    b'n' => s.push('\n'),
                    b't' => s.push('\t'),
                    b'r' => s.push('\r'),
                    b'b' => s.push('\u{8}'),
                    b'f' => s.push('\u{c}'),
                    b'u' => {
                        if *pos + 4 >= b.len() {
                            return Err("bad \\u escape".into());
                        }
                        let hex = std::str::from_utf8(&b[*pos + 1..*pos + 5])
                            .map_err(|_| "bad \\u escape")?;
                        let code = u32::from_str_radix(hex, 16)
                            .map_err(|_| "bad \\u escape")?;
                        s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        *pos += 4;
                    }
                    c => return Err(format!("bad escape \\{}", c as char)),
                }
                *pos += 1;
            }
            c => {
                // Multi-byte UTF-8: copy the whole scalar.
                let text = std::str::from_utf8(&b[*pos..]).map_err(|_| "bad utf8")?;
                let ch = text.chars().next().unwrap();
                s.push(ch);
                *pos += ch.len_utf8();
                let _ = c;
            }
        }
    }
    Err("unterminated string".into())
}

fn parse_arr(b: &[u8], pos: &mut usize) -> Result<Json, String> {
    *pos += 1; // '['
    let mut items = Vec::new();
    loop {
        skip_ws(b, pos);
        if *pos < b.len() && b[*pos] == b']' {
            *pos += 1;
            return Ok(Json::Arr(items));
        }
        items.push(parse_value(b, pos)?);
        skip_ws(b, pos);
        match b.get(*pos) {
            Some(b',') => *pos += 1,
            Some(b']') => {}
            _ => return Err(format!("expected , or ] at byte {pos}", pos = *pos)),
        }
    }
}

fn parse_obj(b: &[u8], pos: &mut usize) -> Result<Json, String> {
    *pos += 1; // '{'
    let mut map = BTreeMap::new();
    loop {
        skip_ws(b, pos);
        if *pos < b.len() && b[*pos] == b'}' {
            *pos += 1;
            return Ok(Json::Obj(map));
        }
        if *pos >= b.len() || b[*pos] != b'"' {
            return Err(format!("expected key at byte {pos}", pos = *pos));
        }
        let key = parse_string(b, pos)?;
        skip_ws(b, pos);
        if b.get(*pos) != Some(&b':') {
            return Err(format!("expected : at byte {pos}", pos = *pos));
        }
        *pos += 1;
        let val = parse_value(b, pos)?;
        map.insert(key, val);
        skip_ws(b, pos);
        match b.get(*pos) {
            Some(b',') => *pos += 1,
            Some(b'}') => {}
            _ => return Err(format!("expected , or }} at byte {pos}", pos = *pos)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_simple() {
        let j = Json::obj(vec![
            ("name", Json::Str("whops".into())),
            ("r", Json::Num(36.0)),
            ("ok", Json::Bool(true)),
            ("xs", Json::Arr(vec![Json::Num(1.0), Json::Num(2.5)])),
        ]);
        let s = j.to_string();
        let back = Json::parse(&s).unwrap();
        assert_eq!(j, back);
    }

    #[test]
    fn parse_manifest_like() {
        let text = r#"{ "kernel": "batched_weighted_hops",
          "artifacts": [ {"file": "a.hlo.txt", "r": 2, "e": 1024, "d": 6} ] }"#;
        let j = Json::parse(text).unwrap();
        let arts = j.get("artifacts").unwrap().as_arr().unwrap();
        assert_eq!(arts.len(), 1);
        assert_eq!(arts[0].get("e").unwrap().as_usize(), Some(1024));
    }

    #[test]
    fn parse_escapes() {
        let j = Json::parse(r#""a\n\"b\"A""#).unwrap();
        assert_eq!(j.as_str(), Some("a\n\"b\"A"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_ok()); // tolerant of trailing comma
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse("{} extra").is_err());
    }
}
