//! Deterministic, seedable PRNG (xoshiro256** seeded via SplitMix64).
//!
//! Used by the allocation simulators, k-means initialization, property
//! tests, and benchmarks. All experiment entry points take explicit seeds so
//! every paper table/figure is exactly reproducible.

/// xoshiro256** (Blackman & Vigna), seeded with SplitMix64.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // SplitMix64 expansion of the seed into the xoshiro state, via the
        // shared primitive: `splitmix64(z)` advances by GOLDEN before
        // mixing, so stepping `x` afterwards reproduces the historical
        // inline generator output-for-output (state word k is
        // splitmix64(seed + k·GOLDEN)).
        use crate::util::hash::{splitmix64, GOLDEN};
        let mut x = seed;
        let mut next = || {
            let v = splitmix64(x);
            x = x.wrapping_add(GOLDEN);
            v
        };
        let s = [next(), next(), next(), next()];
        Rng { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, n)`. Lemire-style rejection-free (biased < 2^-64·n,
    /// irrelevant for simulation purposes but documented).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo)
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in `[lo, hi)`.
    #[inline]
    pub fn f64_range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    pub fn bool(&mut self) -> bool {
        self.next_u64() & 1 == 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `[0, n)` (partial Fisher–Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = self.range(i, n);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seed_expansion_matches_historical_inline_splitmix() {
        // The pre-`util::hash` expander advanced the state *before* mixing;
        // pin that exact stream so the shared-primitive rewrite can never
        // silently shift every seeded simulation/test in the crate.
        let mut x = 42u64;
        let mut old = || {
            x = x.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        let expect = [old(), old(), old(), old()];
        assert_eq!(Rng::new(42).s, expect);
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn below_in_range() {
        let mut r = Rng::new(7);
        for _ in 0..1000 {
            assert!(r.below(17) < 17);
        }
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(9);
        for _ in 0..1000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(3);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(4);
        let s = r.sample_indices(100, 20);
        let mut t = s.clone();
        t.sort_unstable();
        t.dedup();
        assert_eq!(t.len(), 20);
    }
}
