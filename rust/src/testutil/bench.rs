//! Minimal benchmark harness (no `criterion` in the vendor set): adaptive
//! iteration count, warmup, median-of-samples reporting. Used by the
//! `harness = false` bench targets.
//!
//! [`BenchRecorder`] additionally persists results as machine-readable JSON
//! (default `BENCH_mapping.json`, override with `TASKMAP_BENCH_OUT`) so the
//! bench trajectory — e.g. the rotation-sweep speedup per thread count —
//! is diffable across commits. Writes merge with the existing file, so the
//! bench binaries compose into one trajectory file.

use super::json::Json;
use std::collections::BTreeMap;
use std::path::PathBuf;
use std::time::{Duration, Instant};

/// Result of one benchmark.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub median: Duration,
    pub min: Duration,
    pub max: Duration,
    pub samples: usize,
    pub iters_per_sample: u64,
}

impl BenchResult {
    pub fn per_iter_ns(&self) -> f64 {
        self.median.as_nanos() as f64 / self.iters_per_sample as f64
    }

    pub fn report(&self) {
        let ns = self.per_iter_ns();
        let (val, unit) = if ns >= 1e9 {
            (ns / 1e9, "s")
        } else if ns >= 1e6 {
            (ns / 1e6, "ms")
        } else if ns >= 1e3 {
            (ns / 1e3, "us")
        } else {
            (ns, "ns")
        };
        println!(
            "{:<52} {:>10.3} {:<3} (min {:.3e} ns, max {:.3e} ns, {} x {} iters)",
            self.name,
            val,
            unit,
            self.min.as_nanos() as f64 / self.iters_per_sample as f64,
            self.max.as_nanos() as f64 / self.iters_per_sample as f64,
            self.samples,
            self.iters_per_sample
        );
    }
}

/// Benchmark a closure: warm up, pick an iteration count targeting
/// ~`target_ms` per sample, run `samples` samples, report the median.
/// The closure's return value is black-boxed to prevent dead-code
/// elimination.
pub fn bench<T, F: FnMut() -> T>(name: &str, mut f: F) -> BenchResult {
    bench_cfg(name, 5, 200.0, &mut f)
}

/// Quick variant for expensive end-to-end benches.
pub fn bench_quick<T, F: FnMut() -> T>(name: &str, mut f: F) -> BenchResult {
    bench_cfg(name, 3, 300.0, &mut f)
}

fn bench_cfg<T, F: FnMut() -> T>(
    name: &str,
    samples: usize,
    target_ms: f64,
    f: &mut F,
) -> BenchResult {
    // Warmup + calibration.
    let t0 = Instant::now();
    std::hint::black_box(f());
    let once = t0.elapsed().max(Duration::from_nanos(50));
    let iters = ((target_ms * 1e6) / once.as_nanos() as f64)
        .clamp(1.0, 1e7) as u64;
    let mut times = Vec::with_capacity(samples);
    for _ in 0..samples {
        let t = Instant::now();
        for _ in 0..iters {
            std::hint::black_box(f());
        }
        times.push(t.elapsed());
    }
    times.sort_unstable();
    let result = BenchResult {
        name: name.to_string(),
        median: times[times.len() / 2],
        min: times[0],
        max: *times.last().unwrap(),
        samples,
        iters_per_sample: iters,
    };
    result.report();
    result
}

/// Machine-readable bench-trajectory writer (see module docs).
pub struct BenchRecorder {
    path: PathBuf,
    entries: BTreeMap<String, Json>,
}

impl BenchRecorder {
    /// Open a recorder targeting `default_path` (or `$TASKMAP_BENCH_OUT`),
    /// pre-loading any entries already present so writes merge.
    pub fn open(default_path: &str) -> Self {
        let path: PathBuf = std::env::var("TASKMAP_BENCH_OUT")
            .unwrap_or_else(|_| default_path.to_string())
            .into();
        let entries = std::fs::read_to_string(&path)
            .ok()
            .and_then(|text| Json::parse(&text).ok())
            .and_then(|json| match json.get("benches") {
                Some(Json::Obj(m)) => Some(m.clone()),
                _ => None,
            })
            .unwrap_or_default();
        BenchRecorder { path, entries }
    }

    /// Record one result under its bench name, with numeric metadata (e.g.
    /// `("threads", 8.0)`). Re-recording a name overwrites it.
    pub fn record(&mut self, result: &BenchResult, meta: &[(&str, f64)]) {
        let mut fields = vec![
            ("ns_per_iter", Json::Num(result.per_iter_ns())),
            (
                "min_ns_per_iter",
                Json::Num(result.min.as_nanos() as f64 / result.iters_per_sample as f64),
            ),
            (
                "max_ns_per_iter",
                Json::Num(result.max.as_nanos() as f64 / result.iters_per_sample as f64),
            ),
            ("samples", Json::Num(result.samples as f64)),
            ("iters_per_sample", Json::Num(result.iters_per_sample as f64)),
        ];
        for &(k, v) in meta {
            fields.push((k, Json::Num(v)));
        }
        self.entries.insert(result.name.clone(), Json::obj(fields));
    }

    /// Record a derived scalar (e.g. a speedup ratio) under a name of its
    /// own.
    pub fn record_scalar(&mut self, name: &str, key: &str, value: f64) {
        self.entries
            .insert(name.to_string(), Json::obj(vec![(key, Json::Num(value))]));
    }

    /// Write the merged trajectory file.
    pub fn write(&self) -> std::io::Result<()> {
        let json = Json::obj(vec![("benches", Json::Obj(self.entries.clone()))]);
        std::fs::write(&self.path, json.to_string() + "\n")?;
        println!("wrote {} bench entries to {}", self.entries.len(), self.path.display());
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let r = bench_cfg("noop-ish", 3, 1.0, &mut || {
            std::hint::black_box((0..100u64).sum::<u64>())
        });
        assert!(r.per_iter_ns() > 0.0);
        assert!(r.iters_per_sample >= 1);
    }

    #[test]
    fn recorder_merges_and_round_trips() {
        let path = std::env::temp_dir().join(format!(
            "taskmap-bench-recorder-test-{}.json",
            std::process::id()
        ));
        let _ = std::fs::remove_file(&path);
        let path_str = path.to_str().unwrap().to_string();
        let result = bench_cfg("recorder/unit", 3, 1.0, &mut || {
            std::hint::black_box((0..10u64).sum::<u64>())
        });
        let mut rec = BenchRecorder {
            path: path_str.clone().into(),
            entries: BTreeMap::new(),
        };
        rec.record(&result, &[("threads", 4.0)]);
        rec.write().unwrap();
        // Reopen: the entry must survive, and new entries must merge.
        let mut rec2 = BenchRecorder {
            path: path_str.clone().into(),
            entries: std::fs::read_to_string(&path)
                .ok()
                .and_then(|t| Json::parse(&t).ok())
                .and_then(|j| match j.get("benches") {
                    Some(Json::Obj(m)) => Some(m.clone()),
                    _ => None,
                })
                .unwrap_or_default(),
        };
        assert!(rec2.entries.contains_key("recorder/unit"));
        rec2.record_scalar("recorder/speedup", "speedup_8t", 3.5);
        rec2.write().unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let json = Json::parse(&text).unwrap();
        let benches = json.get("benches").unwrap();
        assert!(benches.get("recorder/unit").is_some());
        assert_eq!(
            benches
                .get("recorder/speedup")
                .and_then(|s| s.get("speedup_8t"))
                .and_then(|v| v.as_f64()),
            Some(3.5)
        );
        let threads = benches
            .get("recorder/unit")
            .and_then(|u| u.get("threads"))
            .and_then(|v| v.as_f64());
        assert_eq!(threads, Some(4.0));
        let _ = std::fs::remove_file(&path);
    }
}
