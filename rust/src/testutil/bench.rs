//! Minimal benchmark harness (no `criterion` in the vendor set): adaptive
//! iteration count, warmup, median-of-samples reporting. Used by the
//! `harness = false` bench targets.

use std::time::{Duration, Instant};

/// Result of one benchmark.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub median: Duration,
    pub min: Duration,
    pub max: Duration,
    pub samples: usize,
    pub iters_per_sample: u64,
}

impl BenchResult {
    pub fn per_iter_ns(&self) -> f64 {
        self.median.as_nanos() as f64 / self.iters_per_sample as f64
    }

    pub fn report(&self) {
        let ns = self.per_iter_ns();
        let (val, unit) = if ns >= 1e9 {
            (ns / 1e9, "s")
        } else if ns >= 1e6 {
            (ns / 1e6, "ms")
        } else if ns >= 1e3 {
            (ns / 1e3, "us")
        } else {
            (ns, "ns")
        };
        println!(
            "{:<52} {:>10.3} {:<3} (min {:.3e} ns, max {:.3e} ns, {} x {} iters)",
            self.name,
            val,
            unit,
            self.min.as_nanos() as f64 / self.iters_per_sample as f64,
            self.max.as_nanos() as f64 / self.iters_per_sample as f64,
            self.samples,
            self.iters_per_sample
        );
    }
}

/// Benchmark a closure: warm up, pick an iteration count targeting
/// ~`target_ms` per sample, run `samples` samples, report the median.
/// The closure's return value is black-boxed to prevent dead-code
/// elimination.
pub fn bench<T, F: FnMut() -> T>(name: &str, mut f: F) -> BenchResult {
    bench_cfg(name, 5, 200.0, &mut f)
}

/// Quick variant for expensive end-to-end benches.
pub fn bench_quick<T, F: FnMut() -> T>(name: &str, mut f: F) -> BenchResult {
    bench_cfg(name, 3, 300.0, &mut f)
}

fn bench_cfg<T, F: FnMut() -> T>(
    name: &str,
    samples: usize,
    target_ms: f64,
    f: &mut F,
) -> BenchResult {
    // Warmup + calibration.
    let t0 = Instant::now();
    std::hint::black_box(f());
    let once = t0.elapsed().max(Duration::from_nanos(50));
    let iters = ((target_ms * 1e6) / once.as_nanos() as f64)
        .clamp(1.0, 1e7) as u64;
    let mut times = Vec::with_capacity(samples);
    for _ in 0..samples {
        let t = Instant::now();
        for _ in 0..iters {
            std::hint::black_box(f());
        }
        times.push(t.elapsed());
    }
    times.sort_unstable();
    let result = BenchResult {
        name: name.to_string(),
        median: times[times.len() / 2],
        min: times[0],
        max: *times.last().unwrap(),
        samples,
        iters_per_sample: iters,
    };
    result.report();
    result
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let r = bench_cfg("noop-ish", 3, 1.0, &mut || {
            std::hint::black_box((0..100u64).sum::<u64>())
        });
        assert!(r.per_iter_ns() > 0.0);
        assert!(r.iters_per_sample >= 1);
    }
}
