//! Seeded random geometric task graphs for tests and scale benchmarks.
//!
//! The coarsening and scale suites need graphs that are (a) reproducible
//! from a seed, (b) geometrically meaningful (edges connect nearby tasks,
//! so a geometric coarsener has structure to find), and (c) degree-bounded
//! (so adjacency-walking code can't go quadratic on a fluke). The MiniGhost
//! and stencil generators are deterministic lattices; this module is the
//! *random* counterpart, so those suites don't have to hand-roll point
//! clouds and edge lists (the MJ bench previously did exactly that).

use crate::apps::{Edge, TaskGraph};
use crate::geom::Coords;
use crate::testutil::Rng;

/// `n` points uniform in `[0, extent)^dim`, deterministic per seed.
pub fn random_points(n: usize, dim: usize, extent: f64, seed: u64) -> Coords {
    assert!(dim >= 1, "dim must be >= 1");
    let mut rng = Rng::new(seed);
    let mut coords = Coords::with_capacity(dim, n);
    let mut p = vec![0f64; dim];
    for _ in 0..n {
        for x in p.iter_mut() {
            *x = rng.f64_range(0.0, extent);
        }
        coords.push(&p);
    }
    coords
}

/// Seeded, degree-bounded random geometric graph: `n` tasks uniform in a
/// `[0, s)^dim` box with `s ≈ n^(1/dim)` (about one task per unit cell),
/// each linked to its up-to-`degree` nearest neighbors among the tasks of
/// its own and adjacent grid cells. Every task *proposes* at most `degree`
/// edges, so the final degree is bounded by `2 * degree`; duplicate
/// proposals are merged. Edge weights are a pure function of `(seed, u, v)`
/// in `[0.5, 2)`, so the graph is bit-identical however it is traversed.
pub fn random_sparse(n: usize, dim: usize, degree: usize, seed: u64) -> TaskGraph {
    assert!(n >= 1, "need at least one task");
    assert!((1..=4).contains(&dim), "dim {dim} out of the supported 1..=4");
    let extent = (n as f64).powf(1.0 / dim as f64).ceil().max(1.0);
    let coords = random_points(n, dim, extent, seed);
    let cells = extent as usize;
    // Bucket tasks on the unit grid (ascending task order within a cell).
    let num_cells = cells.pow(dim as u32);
    let mut bucket: Vec<Vec<u32>> = vec![Vec::new(); num_cells];
    let cell_of = |t: usize| -> usize {
        let mut id = 0usize;
        for d in 0..dim {
            let c = (coords.get(d, t) as usize).min(cells - 1);
            id = id * cells + c;
        }
        id
    };
    for t in 0..n {
        bucket[cell_of(t)].push(t as u32);
    }
    let dist2 = |a: usize, b: usize| -> f64 {
        (0..dim)
            .map(|d| {
                let dx = coords.get(d, a) - coords.get(d, b);
                dx * dx
            })
            .sum()
    };
    // For each task: candidates from the 3^dim surrounding cells, keep the
    // `degree` nearest (ties by index), emit normalized (min, max) pairs.
    let mut pairs: Vec<(u32, u32)> = Vec::new();
    let mut cand: Vec<(f64, u32)> = Vec::new();
    let mut cell_idx = vec![0usize; dim];
    for t in 0..n {
        for (d, slot) in cell_idx.iter_mut().enumerate() {
            *slot = (coords.get(d, t) as usize).min(cells - 1);
        }
        cand.clear();
        // Odometer over the {-1, 0, +1}^dim neighbor-cell offsets.
        let mut offs = vec![-1i64; dim];
        'cells: loop {
            let mut id = 0usize;
            let mut in_grid = true;
            for d in 0..dim {
                let c = cell_idx[d] as i64 + offs[d];
                if c < 0 || c >= cells as i64 {
                    in_grid = false;
                    break;
                }
                id = id * cells + c as usize;
            }
            if in_grid {
                for &v in &bucket[id] {
                    if v as usize != t {
                        cand.push((dist2(t, v as usize), v));
                    }
                }
            }
            for o in offs.iter_mut() {
                *o += 1;
                if *o <= 1 {
                    continue 'cells;
                }
                *o = -1;
            }
            break;
        }
        cand.sort_unstable_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
        for &(_, v) in cand.iter().take(degree) {
            let (a, b) = ((t as u32).min(v), (t as u32).max(v));
            pairs.push((a, b));
        }
    }
    pairs.sort_unstable();
    pairs.dedup();
    let edges: Vec<Edge> = pairs
        .into_iter()
        .map(|(u, v)| {
            // Per-edge weight from a hash of (seed, u, v): independent of
            // construction order, stable across refactors of this loop.
            let mut r = Rng::new(seed ^ (((u as u64) << 32) | v as u64));
            Edge {
                u,
                v,
                w: r.f64_range(0.5, 2.0),
            }
        })
        .collect();
    TaskGraph {
        num_tasks: n,
        edges,
        coords,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let a = random_sparse(200, 3, 6, 42);
        let b = random_sparse(200, 3, 6, 42);
        assert_eq!(a.edges, b.edges);
        for d in 0..3 {
            assert_eq!(a.coords.axis(d), b.coords.axis(d));
        }
        let c = random_sparse(200, 3, 6, 43);
        assert_ne!(a.edges, c.edges);
    }

    #[test]
    fn valid_and_degree_bounded() {
        let cases = [(1usize, 2usize, 4usize, 1u64), (64, 2, 3, 7), (500, 3, 6, 9)];
        for (n, dim, degree, seed) in cases {
            let g = random_sparse(n, dim, degree, seed);
            g.validate().expect("random_sparse builds a valid graph");
            for &d in &g.degrees() {
                assert!(
                    (d as usize) <= 2 * degree,
                    "degree {d} exceeds the 2x{degree} bound"
                );
            }
        }
    }

    #[test]
    fn edges_connect_nearby_tasks() {
        // Neighbors come from the task's own or an adjacent unit cell, so
        // per-axis separation is < 2 and dist^2 < 4 * dim.
        let dim = 2;
        let g = random_sparse(400, dim, 4, 5);
        let max2 = 4.0 * dim as f64;
        for e in &g.edges {
            let d2: f64 = (0..dim)
                .map(|d| {
                    let dx = g.coords.get(d, e.u as usize) - g.coords.get(d, e.v as usize);
                    dx * dx
                })
                .sum();
            assert!(d2 <= max2, "edge ({}, {}) spans {d2}", e.u, e.v);
        }
    }

    #[test]
    fn random_points_in_box() {
        let c = random_points(100, 3, 8.0, 11);
        assert_eq!(c.len(), 100);
        for d in 0..3 {
            for &x in c.axis(d) {
                assert!((0.0..8.0).contains(&x));
            }
        }
    }
}
