//! Multilevel coarsening for the task graph: the "V" in the V-cycle.
//!
//! The rotation sweep scores every candidate against the full task set, so
//! its cost grows with task count and it tops out around the paper's 128K
//! ranks. The hierarchical process-mapping line (arXiv:1702.04164,
//! arXiv:2504.01726) reaches millions of tasks by shrinking the graph
//! first: collapse matched task pairs into *supertasks* (summed weights,
//! weight-averaged coordinates), repeat until the graph fits a size budget,
//! solve the coarsest instance with the existing sweep, then walk back up
//! projecting the mapping and running a few bounded refinement passes per
//! level:
//!
//! ```text
//!   fine graph  n tasks   ── coarsen ──▶  level 0   (~n/2 supertasks)
//!                                           │ coarsen
//!                                           ▼
//!                                         level 1   (~n/4)
//!                                           │  ⋮
//!                                           ▼
//!                                         level L-1 (coarsest, ≥ floor)
//!                                           │ rotation sweep + refine
//!                                           ▼
//!                                     coarse mapping
//!                                           │ project + refine (per level)
//!                                           ▼
//!   fine mapping  ◀── project + refine ── level 0 mapping
//! ```
//!
//! This module owns the left leg and the projections; the driver that runs
//! the sweep and the uncoarsening refinement lives in [`crate::hier`].
//!
//! ## Level record schema
//!
//! Each [`Level`] fully describes one coarsening step:
//!
//! | field            | meaning                                              |
//! |------------------|------------------------------------------------------|
//! | `fine_to_coarse` | for every task of the *finer* graph, its supertask id |
//! | `graph`          | the coarse [`TaskGraph`] (merged edges, averaged coords) |
//! | `weights`        | per-supertask summed task weight (finest tasks weigh 1) |
//! | `matched`        | contracted pairs this step (`coarse n = fine n - matched`) |
//!
//! Supertask ids ascend by smallest member index, so the coarse graph's
//! task order — and everything downstream of it — is independent of thread
//! count: matching *proposes* in parallel over a frozen adjacency and
//! *applies* sequentially in ascending task order, the same discipline as
//! every other parallel path in the crate.
//!
//! ## Sizing invariant
//!
//! One step contracts at most half the tasks (`m >= ceil(n/2)`), so
//! [`coarsen`] loops `while n >= 2 * target_tasks`: the coarsest graph
//! always lands in `[target_tasks, 2 * target_tasks)` (unless `max_levels`
//! or a matching dead-end stops it early) and never undershoots the floor.
//! Callers mapping onto `N` nodes pass `target_tasks >= N` so the coarse
//! solve stays in the count-balanced regime of the sweep.

use crate::apps::{Edge, TaskGraph};
use crate::geom::Coords;
use crate::obs;
use crate::par::{self, Parallelism};

/// How candidate partners are ranked when matching.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MatchingKind {
    /// Heaviest edge first; ties broken by coordinate proximity, then by
    /// smallest neighbor index. The classic multilevel choice: absorbing
    /// the heaviest edges removes the most volume from the coarse graph.
    HeavyEdge,
    /// Nearest neighbor first; ties broken by heaviest edge, then smallest
    /// index. Keeps supertasks geometrically tight, which suits the
    /// coordinate-driven sweep when edge weights are near-uniform.
    Geometric,
}

impl MatchingKind {
    pub fn name(self) -> &'static str {
        match self {
            MatchingKind::HeavyEdge => "heavy_edge",
            MatchingKind::Geometric => "geometric",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "heavy_edge" => Some(MatchingKind::HeavyEdge),
            "geometric" => Some(MatchingKind::Geometric),
            _ => None,
        }
    }

    /// `true` if `(w_a, d2_a, a)` beats `(w_b, d2_b, b)` under this kind.
    /// Total order (via `f64::total_cmp`), so argmax is unambiguous.
    fn better(self, a: (f64, f64, u32), b: (f64, f64, u32)) -> bool {
        let (wa, da, ia) = a;
        let (wb, db, ib) = b;
        let ord = match self {
            MatchingKind::HeavyEdge => wb.total_cmp(&wa).then(da.total_cmp(&db)).then(ia.cmp(&ib)),
            MatchingKind::Geometric => da.total_cmp(&db).then(wb.total_cmp(&wa)).then(ia.cmp(&ib)),
        };
        ord == std::cmp::Ordering::Less
    }
}

/// Size budget for [`coarsen`]. See the module doc for the sizing
/// invariant: `target_tasks` is a floor the coarsest level never goes
/// below, and the result lands in `[target_tasks, 2 * target_tasks)` when
/// neither `max_levels` nor a matching dead-end intervenes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CoarsenConfig {
    /// Stop coarsening once the next level would drop below this many
    /// supertasks (clamped to at least 1).
    pub target_tasks: usize,
    /// Hard cap on coarsening steps (a ~1M-task graph needs ~8 levels to
    /// reach 4096, so the default 20 is never the binding constraint).
    pub max_levels: usize,
    /// Partner-ranking rule for the matching.
    pub matching: MatchingKind,
}

impl Default for CoarsenConfig {
    fn default() -> Self {
        CoarsenConfig {
            target_tasks: 4096,
            max_levels: 20,
            matching: MatchingKind::HeavyEdge,
        }
    }
}

/// One coarsening step: the projection from the finer graph plus the
/// coarse graph it produced. See the module doc for the field schema.
#[derive(Clone, Debug)]
pub struct Level {
    /// `fine_to_coarse[t]` = supertask id of finer-graph task `t`.
    pub fine_to_coarse: Vec<u32>,
    /// The coarse graph: merged edges, weight-averaged coordinates.
    pub graph: TaskGraph,
    /// Summed task weight per supertask (finest-level tasks weigh 1.0).
    pub weights: Vec<f64>,
    /// Number of pairs contracted in this step.
    pub matched: usize,
}

/// The full coarsening stack for one task graph, finest to coarsest.
#[derive(Clone, Debug)]
pub struct Hierarchy {
    /// Task count of the original (finest) graph.
    pub fine_tasks: usize,
    /// Levels in coarsening order: `levels[0]` is one step below the
    /// original graph, `levels.last()` is the coarsest.
    pub levels: Vec<Level>,
}

impl Hierarchy {
    pub fn num_levels(&self) -> usize {
        self.levels.len()
    }

    /// The coarsest level, if any coarsening happened.
    pub fn coarsest(&self) -> Option<&Level> {
        self.levels.last()
    }

    /// Supertask count per level, finest to coarsest.
    pub fn level_tasks(&self) -> Vec<usize> {
        self.levels.iter().map(|l| l.graph.num_tasks).collect()
    }

    /// Project a per-supertask value at `level` one step down, to the
    /// finer graph below it: every member of a supertask inherits its
    /// value. Exact — no arithmetic, just indexing.
    pub fn project_step(&self, level: usize, coarse: &[u32]) -> Vec<u32> {
        let l = &self.levels[level];
        assert_eq!(coarse.len(), l.graph.num_tasks, "value/level mismatch");
        l.fine_to_coarse
            .iter()
            .map(|&c| coarse[c as usize])
            .collect()
    }

    /// Project a coarsest-level assignment all the way to the original
    /// task set.
    pub fn project(&self, coarse: &[u32]) -> Vec<u32> {
        let mut cur = coarse.to_vec();
        for level in (0..self.levels.len()).rev() {
            cur = self.project_step(level, &cur);
        }
        cur
    }

    /// Push a per-task assignment down to the coarsest level: each
    /// supertask takes the value of its smallest-index member. Inverse of
    /// [`Hierarchy::project`] on projected data:
    /// `restrict(project(x)) == x` bit for bit.
    pub fn restrict(&self, fine: &[u32]) -> Vec<u32> {
        let mut cur = fine.to_vec();
        for l in &self.levels {
            assert_eq!(cur.len(), l.fine_to_coarse.len(), "value/level mismatch");
            let mut out = vec![0u32; l.graph.num_tasks];
            // Reverse order so the smallest member index writes last.
            for t in (0..cur.len()).rev() {
                out[l.fine_to_coarse[t] as usize] = cur[t];
            }
            cur = out;
        }
        cur
    }
}

/// Aggregated CSR adjacency: per-task neighbor lists with duplicate
/// (u, v) edges merged by weight sum, rows sorted by neighbor index.
struct Adj {
    offsets: Vec<usize>,
    /// `(neighbor, summed weight)` entries, row-major.
    entries: Vec<(u32, f64)>,
}

impl Adj {
    fn build(num_tasks: usize, edges: &[Edge]) -> Adj {
        // One global sort of both-direction triples, then a merge-sum
        // sweep: no per-row sorts, no hashing, deterministic for a given
        // edge list.
        let mut triples: Vec<(u32, u32, f64)> = Vec::with_capacity(edges.len() * 2);
        for e in edges {
            triples.push((e.u, e.v, e.w));
            triples.push((e.v, e.u, e.w));
        }
        triples.sort_unstable_by(|a, b| a.0.cmp(&b.0).then(a.1.cmp(&b.1)));
        let mut offsets = vec![0usize; num_tasks + 1];
        let mut entries: Vec<(u32, f64)> = Vec::with_capacity(triples.len());
        let mut i = 0;
        while i < triples.len() {
            let (u, v, mut w) = triples[i];
            i += 1;
            while i < triples.len() && triples[i].0 == u && triples[i].1 == v {
                w += triples[i].2;
                i += 1;
            }
            entries.push((v, w));
            offsets[u as usize + 1] += 1;
        }
        for t in 0..num_tasks {
            offsets[t + 1] += offsets[t];
        }
        Adj { offsets, entries }
    }

    fn row(&self, t: usize) -> &[(u32, f64)] {
        &self.entries[self.offsets[t]..self.offsets[t + 1]]
    }
}

/// Squared distance between two points of `coords`.
fn dist2(coords: &Coords, a: usize, b: usize) -> f64 {
    (0..coords.dim())
        .map(|d| {
            let dx = coords.get(d, a) - coords.get(d, b);
            dx * dx
        })
        .sum()
}

/// Best neighbor of `t` under `kind` among `row` entries passing `keep`,
/// or `u32::MAX` if none qualifies.
fn best_neighbor(
    kind: MatchingKind,
    coords: &Coords,
    t: usize,
    row: &[(u32, f64)],
    keep: impl Fn(u32) -> bool,
) -> u32 {
    let mut best: Option<(f64, f64, u32)> = None;
    for &(v, w) in row {
        if !keep(v) {
            continue;
        }
        let cand = (w, dist2(coords, t, v as usize), v);
        let wins = match best {
            None => true,
            Some(b) => kind.better(cand, b),
        };
        if wins {
            best = Some(cand);
        }
    }
    best.map_or(u32::MAX, |(_, _, v)| v)
}

/// One coarsening step over an explicit (tasks, edges, coords, weights)
/// quadruple. `coords` are the coordinates the downstream sweep uses (for
/// the finest graph, the *task* coordinates handed to the mapper, which
/// may differ from `graph.coords`); `weights` is the per-task weight
/// (all 1.0 at the finest level).
///
/// Deterministic at every thread count: the parallel phase only computes
/// per-task proposals against the frozen adjacency (index-addressed
/// output, no shared state), and the sequential apply phase resolves them
/// in ascending task order.
pub fn coarsen_once(
    num_tasks: usize,
    edges: &[Edge],
    coords: &Coords,
    weights: &[f64],
    kind: MatchingKind,
    par: Parallelism,
) -> Level {
    assert_eq!(weights.len(), num_tasks, "one weight per task");
    assert_eq!(coords.len(), num_tasks, "one point per task");
    let adj = Adj::build(num_tasks, edges);

    let mut sp = obs::span("coarsen.match");
    // Propose phase (parallel): each task independently names its best
    // neighbor. Pure function of the adjacency — thread-count invariant.
    let ids: Vec<u32> = (0..num_tasks as u32).collect();
    let proposals: Vec<u32> = par::map_with(
        par,
        &ids,
        || (),
        |_, _, &t| best_neighbor(kind, coords, t as usize, adj.row(t as usize), |_| true),
    );

    // Apply phase (sequential, ascending task id). Every task with index
    // < u is already resolved when u is visited, so an unresolved partner
    // always has a larger index and supertask ids ascend by smallest
    // member index.
    let mut fine_to_coarse = vec![u32::MAX; num_tasks];
    let mut next = 0u32;
    let mut matched = 0usize;
    for u in 0..num_tasks {
        if fine_to_coarse[u] != u32::MAX {
            continue;
        }
        let p = proposals[u];
        let partner = if p != u32::MAX && fine_to_coarse[p as usize] == u32::MAX {
            p
        } else {
            // Proposal taken (or none): fall back to the best still-free
            // neighbor under the same ranking.
            best_neighbor(kind, coords, u, adj.row(u), |v| {
                fine_to_coarse[v as usize] == u32::MAX
            })
        };
        fine_to_coarse[u] = next;
        if partner != u32::MAX {
            fine_to_coarse[partner as usize] = next;
            matched += 1;
        }
        next += 1;
    }
    let m = next as usize;
    sp.record("fine_tasks", num_tasks as f64);
    sp.record("matched", matched as f64);
    drop(sp);

    // Contract: summed weights, weight-averaged coordinates.
    let dim = coords.dim();
    let mut coarse_w = vec![0f64; m];
    let mut accum = vec![0f64; m * dim];
    for t in 0..num_tasks {
        let c = fine_to_coarse[t] as usize;
        coarse_w[c] += weights[t];
        for d in 0..dim {
            accum[c * dim + d] += weights[t] * coords.get(d, t);
        }
    }
    let mut coarse_coords = Coords::with_capacity(dim, m);
    let mut p = vec![0f64; dim];
    for c in 0..m {
        for (d, slot) in p.iter_mut().enumerate() {
            // Weights are sums of positive task weights, so the divide is
            // always well-defined.
            *slot = accum[c * dim + d] / coarse_w[c];
        }
        coarse_coords.push(&p);
    }

    // Coarse edges: map endpoints, drop now-internal edges, merge-sum
    // duplicates after one sort of normalized pairs.
    let mut mapped: Vec<(u32, u32, f64)> = Vec::with_capacity(edges.len());
    for e in edges {
        let cu = fine_to_coarse[e.u as usize];
        let cv = fine_to_coarse[e.v as usize];
        if cu != cv {
            mapped.push((cu.min(cv), cu.max(cv), e.w));
        }
    }
    mapped.sort_unstable_by(|a, b| a.0.cmp(&b.0).then(a.1.cmp(&b.1)));
    let mut coarse_edges: Vec<Edge> = Vec::with_capacity(mapped.len());
    let mut i = 0;
    while i < mapped.len() {
        let (u, v, mut w) = mapped[i];
        i += 1;
        while i < mapped.len() && mapped[i].0 == u && mapped[i].1 == v {
            w += mapped[i].2;
            i += 1;
        }
        coarse_edges.push(Edge { u, v, w });
    }

    Level {
        fine_to_coarse,
        graph: TaskGraph {
            num_tasks: m,
            edges: coarse_edges,
            coords: coarse_coords,
        },
        weights: coarse_w,
        matched,
    }
}

/// Coarsen `(num_tasks, edges, coords)` until the next step would drop
/// below `cfg.target_tasks` supertasks (or `cfg.max_levels` / a matching
/// dead-end stops it). Emits one `coarsen.level` span per step with
/// `level`, `tasks`, `edges`, and `matched` fields (a `coarsen.match`
/// child covers the matching itself).
///
/// The returned hierarchy may be empty (`levels.is_empty()`) when the
/// graph is already at or near the target — callers fall back to the
/// direct path.
pub fn coarsen(
    num_tasks: usize,
    edges: &[Edge],
    coords: &Coords,
    cfg: CoarsenConfig,
    par: Parallelism,
) -> Hierarchy {
    let floor = cfg.target_tasks.max(1);
    let base_weights = vec![1f64; num_tasks];
    let mut levels: Vec<Level> = Vec::new();
    let mut cur_n = num_tasks;
    while cur_n >= 2 * floor && levels.len() < cfg.max_levels {
        let mut sp = obs::span("coarsen.level");
        let lvl = {
            let (e, c, w): (&[Edge], &Coords, &[f64]) = match levels.last() {
                None => (edges, coords, &base_weights),
                Some(l) => (&l.graph.edges, &l.graph.coords, &l.weights),
            };
            coarsen_once(cur_n, e, c, w, cfg.matching, par)
        };
        sp.record("level", levels.len() as f64);
        sp.record("tasks", lvl.graph.num_tasks as f64);
        sp.record("edges", lvl.graph.edges.len() as f64);
        sp.record("matched", lvl.matched as f64);
        drop(sp);
        if lvl.matched == 0 {
            // No edge to contract anywhere (e.g. an empty or fully
            // disconnected graph): a further level would be a copy.
            break;
        }
        cur_n = lvl.graph.num_tasks;
        levels.push(lvl);
    }
    Hierarchy {
        fine_tasks: num_tasks,
        levels,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::graphs::random_sparse;

    fn line_graph(n: usize, heavy_at: usize) -> TaskGraph {
        // 1D line 0-1-2-...; one designated edge is much heavier.
        let mut coords = Coords::with_capacity(1, n);
        for t in 0..n {
            coords.push(&[t as f64]);
        }
        let edges = (0..n - 1)
            .map(|t| Edge {
                u: t as u32,
                v: t as u32 + 1,
                w: if t == heavy_at { 10.0 } else { 1.0 },
            })
            .collect();
        TaskGraph {
            num_tasks: n,
            edges,
            coords,
        }
    }

    #[test]
    fn heavy_edge_pair_is_contracted_together() {
        let g = line_graph(6, 2);
        let lvl = coarsen_once(
            6,
            &g.edges,
            &g.coords,
            &[1.0; 6],
            MatchingKind::HeavyEdge,
            Parallelism::sequential(),
        );
        // Tasks 2 and 3 share the weight-10 edge: they must share a
        // supertask even though task 2's proposal race includes task 1.
        assert_eq!(lvl.fine_to_coarse[2], lvl.fine_to_coarse[3]);
        lvl.graph.validate().expect("coarse graph is valid");
        assert_eq!(lvl.graph.num_tasks, 6 - lvl.matched);
    }

    #[test]
    fn weights_sum_and_coords_average() {
        let g = line_graph(4, 0);
        let lvl = coarsen_once(
            4,
            &g.edges,
            &g.coords,
            &[1.0; 4],
            MatchingKind::HeavyEdge,
            Parallelism::sequential(),
        );
        let total_w: f64 = lvl.weights.iter().sum();
        assert_eq!(total_w, 4.0);
        // Mass center is preserved by weight-averaging.
        let fine_sum: f64 = (0..4).map(|t| g.coords.get(0, t)).sum();
        let coarse_sum: f64 = (0..lvl.graph.num_tasks)
            .map(|c| lvl.weights[c] * lvl.graph.coords.get(0, c))
            .sum();
        assert!((fine_sum - coarse_sum).abs() < 1e-9);
    }

    #[test]
    fn supertask_ids_ascend_by_smallest_member() {
        let g = random_sparse(300, 2, 5, 17);
        let lvl = coarsen_once(
            300,
            &g.edges,
            &g.coords,
            &[1.0; 300],
            MatchingKind::HeavyEdge,
            Parallelism::sequential(),
        );
        // First occurrence order of supertask ids must be 0, 1, 2, ...
        let mut seen = 0u32;
        for &c in &lvl.fine_to_coarse {
            assert!(c <= seen, "id {c} appears before all of 0..{seen}");
            if c == seen {
                seen += 1;
            }
        }
        assert_eq!(seen as usize, lvl.graph.num_tasks);
    }

    #[test]
    fn coarsest_respects_the_floor() {
        for (n, target) in [(1000usize, 100usize), (513, 64), (200, 1)] {
            let g = random_sparse(n, 2, 6, 3);
            let cfg = CoarsenConfig {
                target_tasks: target,
                ..CoarsenConfig::default()
            };
            let h = coarsen(n, &g.edges, &g.coords, cfg, Parallelism::sequential());
            let coarsest = h.coarsest().map_or(n, |l| l.graph.num_tasks);
            assert!(coarsest >= target, "coarsest {coarsest} under floor {target}");
            // Level sizes strictly decrease.
            let mut prev = n;
            for l in &h.levels {
                assert!(l.graph.num_tasks < prev);
                prev = l.graph.num_tasks;
            }
        }
    }

    #[test]
    fn hierarchy_is_empty_when_already_small_or_edgeless() {
        let g = random_sparse(50, 2, 4, 1);
        let cfg = CoarsenConfig {
            target_tasks: 40,
            ..CoarsenConfig::default()
        };
        let h = coarsen(50, &g.edges, &g.coords, cfg, Parallelism::sequential());
        assert_eq!(h.num_levels(), 0, "50 < 2*40: nothing to do");

        let lonely = random_sparse(64, 2, 4, 1);
        let cfg = CoarsenConfig {
            target_tasks: 8,
            ..CoarsenConfig::default()
        };
        let h = coarsen(64, &[], &lonely.coords, cfg, Parallelism::sequential());
        assert_eq!(h.num_levels(), 0, "edgeless graph cannot contract");
    }

    #[test]
    fn projection_round_trips_exactly() {
        let g = random_sparse(400, 3, 6, 23);
        let cfg = CoarsenConfig {
            target_tasks: 30,
            ..CoarsenConfig::default()
        };
        let h = coarsen(400, &g.edges, &g.coords, cfg, Parallelism::sequential());
        assert!(h.num_levels() >= 2, "expected a multi-level hierarchy");
        let m = h.coarsest().unwrap().graph.num_tasks;
        // Arbitrary (but distinct-per-supertask) coarse assignment.
        let coarse: Vec<u32> = (0..m as u32).map(|c| c.wrapping_mul(7) % 13).collect();
        let fine = h.project(&coarse);
        assert_eq!(fine.len(), 400);
        assert_eq!(h.restrict(&fine), coarse, "restrict(project(x)) == x");
    }

    #[test]
    fn matching_is_thread_invariant() {
        let g = random_sparse(600, 3, 6, 41);
        let cfg = CoarsenConfig {
            target_tasks: 32,
            matching: MatchingKind::Geometric,
            ..CoarsenConfig::default()
        };
        let base = coarsen(600, &g.edges, &g.coords, cfg, Parallelism::sequential());
        assert!(base.num_levels() >= 2);
        for threads in [2usize, 8] {
            let par = Parallelism::threads(threads).with_grain(1);
            let h = coarsen(600, &g.edges, &g.coords, cfg, par);
            assert_eq!(h.num_levels(), base.num_levels());
            for (a, b) in h.levels.iter().zip(&base.levels) {
                assert_eq!(a.fine_to_coarse, b.fine_to_coarse, "{threads} threads");
                assert_eq!(a.graph.edges, b.graph.edges);
                assert_eq!(a.graph.coords, b.graph.coords);
                assert_eq!(a.weights, b.weights);
            }
        }
    }

    #[test]
    fn matching_kind_names_round_trip() {
        for k in [MatchingKind::HeavyEdge, MatchingKind::Geometric] {
            assert_eq!(MatchingKind::parse(k.name()), Some(k));
        }
        assert_eq!(MatchingKind::parse("nope"), None);
    }
}
