//! Communication-time simulator.
//!
//! The paper measures wall-clock communication time on Titan and Mira; this
//! module provides the analytic stand-in (see DESIGN.md section 2). The
//! model combines exactly the effects the paper identifies as decisive:
//!
//! * **Serialization on the bottleneck link** — `max_e Data(e)/bw(e)`
//!   (Eqn. 7). Dominates when messages are large ("Because HOMME's messages
//!   are large, these bandwidth-based metrics are more important",
//!   Section 5.3.1).
//! * **Injection** — a node's NIC drains its ranks' traffic at a finite
//!   rate.
//! * **Per-message cost with distance sensitivity** — `alpha + hops *
//!   t_hop` per message, maximized over ranks. Dominates for small-message
//!   apps (MiniGhost: "reducing Latency while doubling AverageHops does not
//!   improve performance", Section 5.3.2).
//!
//! * **Congested volume** — total bytes x hops over the allocation's
//!   aggregate link capacity, scaled by a congestion multiplier: the
//!   WeightedHops-proportional component the paper's measurements track.
//!
//! `T_comm = max(T_serial, T_inject, T_volume) + T_msg`, with
//! per-network-dimension attribution for Figs 12 and 15.

use crate::apps::TaskGraph;
use crate::machine::{Allocation, Topology};
use crate::metrics;

/// Model constants. One calibration for all experiments (per DESIGN.md §6):
/// these are Gemini/BG/Q-era magnitudes, not per-experiment fits.
#[derive(Clone, Copy, Debug)]
pub struct CommModel {
    /// Per-message software latency, seconds (MPI pt2pt overhead).
    pub alpha: f64,
    /// Additional per-hop, per-message latency, seconds.
    pub t_hop: f64,
    /// Node injection bandwidth, bytes/s.
    pub inj_bw: f64,
    /// Scale from the topology's bandwidth units (GB/s in the presets) to
    /// bytes/s.
    pub bw_unit: f64,
    /// Exchange rounds per reported interval (e.g. timesteps): scales all
    /// terms equally, so it only matters for absolute numbers.
    pub rounds: f64,
    /// Congestion multiplier for the volume term: traffic is not spread
    /// uniformly over the allocation's links (hot spots, dimension-ordered
    /// routing, interfering jobs), so effective utilization is a multiple
    /// of the uniform-spread lower bound. Calibrated once (DESIGN.md §6).
    pub congestion: f64,
}

impl Default for CommModel {
    fn default() -> Self {
        CommModel {
            alpha: 1.5e-6,
            t_hop: 1.0e-7,
            // Gemini/BGQ NIC injection is ~20 GB/s; the network bottleneck
            // link (the mapping-sensitive term) is usually the binding
            // constraint, as in the paper's congestion analysis.
            inj_bw: 2.0e10,
            bw_unit: 1.0e9,
            rounds: 1.0,
            congestion: 20.0,
        }
    }
}

/// Simulated communication time and its decomposition.
#[derive(Clone, Debug, Default)]
pub struct CommTime {
    /// Total modeled communication time, seconds.
    pub total: f64,
    /// Bottleneck-link serialization term (Eqn. 7 scaled).
    pub t_serial: f64,
    /// Node injection term.
    pub t_inject: f64,
    /// Per-message (alpha + hops) term, max over ranks.
    pub t_msg: f64,
    /// Congested volume term: bytes x hops over the allocation's aggregate
    /// link capacity — the WeightedHops-proportional component that the
    /// paper's measurements track (Figs 13/14).
    pub t_volume: f64,
    /// Per-(dimension, direction) serialization time: `[dim][0]=+`,
    /// `[dim][1]=-` (Figs 12/15).
    pub per_dim_serial: Vec<[f64; 2]>,
    /// Per-dimension share of the hop term, split by each message's hops
    /// per dimension (Fig 15's per-dimension exchange times).
    pub per_dim_msg: Vec<f64>,
}

/// Simulate communication time for a mapping.
pub fn comm_time(
    graph: &TaskGraph,
    task_to_rank: &[u32],
    alloc: &Allocation,
    model: &CommModel,
) -> CommTime {
    let net = &alloc.machine;
    let torus = net.as_torus();
    // Per-message hop attribution buckets: torus dimensions, or the
    // topology's link classes (tree levels / local-global) otherwise.
    let dim = torus.map_or(net.num_link_classes(), |t| t.dim());
    let nranks = alloc.num_ranks();
    let nnodes = alloc.num_nodes().max(1);

    // Pass 1: link loads (shared with the metrics engine).
    let mut load = vec![0f64; net.num_directed_links()];
    // Per-rank message and weighted-hop aggregates; per-node injected bytes.
    let mut rank_alpha_hops = vec![0f64; nranks];
    let mut node_bytes = vec![0f64; nnodes];
    let mut per_dim_msg = vec![0f64; dim];
    let mut weighted_hops_bytes = 0f64;
    let mut ca = vec![0usize; torus.map_or(0, |t| t.dim())];
    let mut cb = vec![0usize; ca.len()];
    for e in &graph.edges {
        let ra = task_to_rank[e.u as usize] as usize;
        let rb = task_to_rank[e.v as usize] as usize;
        if alloc.core_node[ra] == alloc.core_node[rb] {
            continue;
        }
        let (qa, qb) = (alloc.core_router[ra] as usize, alloc.core_router[rb] as usize);
        net.route_ids(qa, qb, &mut |l| load[l] += e.w);
        net.route_ids(qb, qa, &mut |l| load[l] += e.w);
        let hops_total = if let Some(torus) = torus {
            torus.coords_into(qa, &mut ca);
            torus.coords_into(qb, &mut cb);
            let mut hops = 0f64;
            for d in 0..dim {
                let h = torus.signed_dist(d, ca[d], cb[d]).unsigned_abs() as f64;
                hops += h;
                per_dim_msg[d] += 2.0 * (model.alpha + h * model.t_hop);
            }
            hops
        } else {
            // No per-dimension structure: attribute the whole message to
            // the bucket of the path's first link class (class 0 when the
            // pair shares a router).
            let h = net.hop_dist_ids(qa, qb) as f64;
            per_dim_msg[0] += 2.0 * (model.alpha + h * model.t_hop);
            h
        };
        let msg_cost = model.alpha + hops_total * model.t_hop;
        rank_alpha_hops[ra] += msg_cost;
        rank_alpha_hops[rb] += msg_cost;
        node_bytes[alloc.core_node[ra] as usize] += e.w;
        node_bytes[alloc.core_node[rb] as usize] += e.w;
        weighted_hops_bytes += 2.0 * e.w * hops_total; // both directions
    }

    // Serialization per link -> max + per-dim maxima.
    let lm = metrics::summarize_links(net, &load);
    let t_serial = lm.max_latency / model.bw_unit;
    let per_dim_serial: Vec<[f64; 2]> = lm
        .per_dim
        .iter()
        .map(|dd| {
            [
                dd[0].max_latency / model.bw_unit,
                dd[1].max_latency / model.bw_unit,
            ]
        })
        .collect();

    let t_inject = node_bytes.iter().cloned().fold(0.0, f64::max) / model.inj_bw;
    let t_msg = rank_alpha_hops.iter().cloned().fold(0.0, f64::max);

    // Aggregate link capacity of the allocated region: each allocated node
    // contributes its router's share of directed links at the mean
    // bandwidth. The torus keeps its historical per-(dimension, coordinate)
    // average so pre-trait outputs are bit-identical.
    let (avg_bw, links_per_router) = if let Some(torus) = torus {
        let mut bw_sum = 0f64;
        let mut bw_cnt = 0usize;
        for d in 0..dim {
            for c in 0..torus.sizes[d] {
                bw_sum += torus.bw.bandwidth(d, c);
                bw_cnt += 1;
            }
        }
        (bw_sum / bw_cnt.max(1) as f64, (2 * dim) as f64)
    } else {
        let mut bw_sum = 0f64;
        let mut bw_cnt = 0usize;
        net.for_each_link(&mut |_l, _class, _dir, bw| {
            bw_sum += bw;
            bw_cnt += 1;
        });
        (
            bw_sum / bw_cnt.max(1) as f64,
            bw_cnt as f64 / net.num_routers().max(1) as f64,
        )
    };
    let capacity = nnodes as f64 * links_per_router * (avg_bw * model.bw_unit);
    let t_volume = model.congestion * weighted_hops_bytes / capacity;

    let total = (model.rounds) * (t_serial.max(t_inject).max(t_volume) + t_msg);
    CommTime {
        total,
        t_serial: model.rounds * t_serial,
        t_inject: model.rounds * t_inject,
        t_msg: model.rounds * t_msg,
        t_volume: model.rounds * t_volume,
        per_dim_serial: per_dim_serial
            .into_iter()
            .map(|[a, b]| [model.rounds * a, model.rounds * b])
            .collect(),
        per_dim_msg: per_dim_msg
            .into_iter()
            .map(|x| model.rounds * x / nranks as f64)
            .collect(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::stencil::stencil_graph;
    use crate::machine::{Allocation, Network};

    fn ring_alloc(n: usize) -> Allocation {
        Allocation {
            machine: Network::torus(&[n]),
            core_router: (0..n as u32).collect(),
            core_node: (0..n as u32).collect(),
            ranks_per_node: 1,
        }
    }

    #[test]
    fn identity_ring_time() {
        let g = stencil_graph(&[8], true, 1e6, );
        let alloc = ring_alloc(8);
        let m: Vec<u32> = (0..8).collect();
        let t = comm_time(&g, &m, &alloc, &CommModel::default());
        assert!(t.total > 0.0);
        // Every directed link carries exactly one 1 MB message at 1 GB/s
        // (unit bw * 1e9) = 1 ms on the bottleneck link.
        assert!((t.t_serial - 1e-3).abs() < 1e-9, "{}", t.t_serial);
    }

    #[test]
    fn worse_mapping_costs_more() {
        let g = stencil_graph(&[16], true, 1e6);
        let alloc = ring_alloc(16);
        let good: Vec<u32> = (0..16).collect();
        let bad: Vec<u32> = (0..16).map(|i| (i * 5) % 16).collect();
        let model = CommModel::default();
        let tg = comm_time(&g, &good, &alloc, &model);
        let tb = comm_time(&g, &bad, &alloc, &model);
        assert!(tb.total > tg.total, "{} !> {}", tb.total, tg.total);
    }

    #[test]
    fn intra_node_is_free() {
        let g = stencil_graph(&[4], false, 1e6);
        // All four ranks in one node.
        let alloc = Allocation {
            machine: Network::torus(&[2]),
            core_router: vec![0, 0, 0, 0],
            core_node: vec![0, 0, 0, 0],
            ranks_per_node: 4,
        };
        let t = comm_time(&g, &[0, 1, 2, 3], &alloc, &CommModel::default());
        assert_eq!(t.total, 0.0);
    }

    #[test]
    fn rounds_scale_linearly() {
        let g = stencil_graph(&[8], true, 1e6);
        let alloc = ring_alloc(8);
        let m: Vec<u32> = (0..8).collect();
        let t1 = comm_time(&g, &m, &alloc, &CommModel::default());
        let t20 = comm_time(
            &g,
            &m,
            &alloc,
            &CommModel {
                rounds: 20.0,
                ..Default::default()
            },
        );
        assert!((t20.total - 20.0 * t1.total).abs() < 1e-12);
    }

    #[test]
    fn per_dim_attribution_sums() {
        let g = stencil_graph(&[4, 4], true, 1e5);
        let alloc = Allocation {
            machine: Network::torus(&[4, 4]),
            core_router: (0..16u32).collect(),
            core_node: (0..16u32).collect(),
            ranks_per_node: 1,
        };
        let m: Vec<u32> = (0..16).collect();
        let t = comm_time(&g, &m, &alloc, &CommModel::default());
        assert_eq!(t.per_dim_serial.len(), 2);
        assert_eq!(t.per_dim_msg.len(), 2);
        // Symmetric workload: both dims roughly equal.
        let r = t.per_dim_msg[0] / t.per_dim_msg[1];
        assert!(r > 0.9 && r < 1.1);
    }
}
