//! Artifact runtime: loads the AOT-compiled `batched_weighted_hops`
//! HLO-text artifacts produced by `python/compile/aot.py` and executes
//! their contract from the L3 hot path. Python never runs at request time.
//!
//! Artifacts have fixed padded shapes `(R, E, D)`; requests are chunked
//! over candidates and edges and padded per the kernel's contract
//! (zero-weight edges and size-1 wrapped dims contribute nothing).
//!
//! Execution: the offline vendor set carries no PJRT FFI crate, so the
//! runtime executes each padded artifact-shaped chunk through the native
//! kernel twin (`metrics::native`), which is pinned bit-for-bit against the
//! Pallas kernel's f32 accumulation contract by `tests/runtime_pjrt.rs`
//! and the L2 tests. Linking the real PJRT CPU client back in is a ROADMAP
//! item; every seam (manifest, shapes, chunking, padding, the
//! `executions`/`fallbacks` telemetry) is preserved so only the
//! execute-one-chunk call changes.
//!
//! The runtime is shared across rotation-sweep workers: `eval` takes
//! `&self` and the telemetry counters are mutex-guarded, so concurrent
//! scoring is safe.

use crate::mapping::rotations::WhopsBackend;
use crate::metrics::native::batched_weighted_hops_native;
use crate::testutil::json::Json;
use std::fmt;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

/// Runtime loading/execution error (message-carrying; the offline vendor
/// set has no `anyhow`).
#[derive(Debug)]
pub struct RuntimeError(pub String);

impl fmt::Display for RuntimeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for RuntimeError {}

fn err(msg: impl Into<String>) -> RuntimeError {
    RuntimeError(msg.into())
}

type Result<T> = std::result::Result<T, RuntimeError>;

/// One compiled artifact: its padded shape and the HLO text location.
struct Artifact {
    r: usize,
    e: usize,
    d: usize,
    #[allow(dead_code)]
    path: PathBuf,
}

/// The artifact evaluator: the loaded artifact set plus execution telemetry.
pub struct PjrtRuntime {
    artifacts: Vec<Artifact>,
    /// Number of artifact executions performed (telemetry for
    /// benches/tests).
    pub executions: Mutex<u64>,
}

impl PjrtRuntime {
    /// Load every artifact listed in `dir/manifest.json` (written by
    /// `make artifacts`) and validate the files exist.
    pub fn load(dir: &Path) -> Result<Self> {
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path).map_err(|e| {
            err(format!(
                "reading {manifest_path:?} (run `make artifacts`): {e}"
            ))
        })?;
        let manifest = Json::parse(&text).map_err(|e| err(format!("bad manifest.json: {e}")))?;
        let entries = manifest
            .get("artifacts")
            .and_then(|a| a.as_arr())
            .ok_or_else(|| err("manifest.json: missing artifacts array"))?;
        let mut artifacts = Vec::new();
        for entry in entries {
            let file = entry
                .get("file")
                .and_then(|f| f.as_str())
                .ok_or_else(|| err("artifact entry missing file"))?;
            let r = entry
                .get("r")
                .and_then(|x| x.as_usize())
                .ok_or_else(|| err("artifact entry missing r"))?;
            let e = entry
                .get("e")
                .and_then(|x| x.as_usize())
                .ok_or_else(|| err("artifact entry missing e"))?;
            let d = entry
                .get("d")
                .and_then(|x| x.as_usize())
                .ok_or_else(|| err("artifact entry missing d"))?;
            if r == 0 || e == 0 || d == 0 {
                return Err(err(format!("artifact {file}: degenerate shape ({r},{e},{d})")));
            }
            let path: PathBuf = dir.join(file);
            if !path.is_file() {
                return Err(err(format!("artifact file missing: {path:?}")));
            }
            artifacts.push(Artifact { r, e, d, path });
        }
        if artifacts.is_empty() {
            return Err(err(format!("no artifacts in {dir:?}")));
        }
        Ok(PjrtRuntime {
            artifacts,
            executions: Mutex::new(0),
        })
    }

    /// Load from the conventional `artifacts/` directory next to the repo
    /// root (or `$TASKMAP_ARTIFACTS`).
    pub fn load_default() -> Result<Self> {
        let dir = std::env::var("TASKMAP_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
        Self::load(Path::new(&dir))
    }

    /// Pick the artifact minimizing padded work for an `(r, e, d)` request.
    fn pick(&self, r: usize, e: usize, d: usize) -> Option<&Artifact> {
        self.artifacts.iter().filter(|a| a.d >= d).min_by_key(|a| {
            let chunks = r.div_ceil(a.r) * e.div_ceil(a.e);
            chunks * a.r * a.e * a.d
        })
    }

    /// Batched WeightedHops through the artifact contract. Errors if no
    /// artifact can serve `d`.
    #[allow(clippy::too_many_arguments)]
    pub fn eval(
        &self,
        src: &[f32],
        dst: &[f32],
        w: &[f32],
        dims: &[f32],
        wrap: &[f32],
        r: usize,
        e: usize,
        d: usize,
    ) -> Result<Vec<f32>> {
        let art = self
            .pick(r, e, d)
            .ok_or_else(|| err(format!("no artifact with D >= {d}")))?;
        let (ar, ae, ad) = (art.r, art.e, art.d);
        // Padded dims/wrap: size-1 torus dims are inert.
        let mut pdims = vec![1f32; ad];
        let mut pwrap = vec![1f32; ad];
        pdims[..d].copy_from_slice(dims);
        pwrap[..d].copy_from_slice(wrap);

        let mut out = vec![0f32; r];
        let mut psrc = vec![0f32; ar * ae * ad];
        let mut pdst = vec![0f32; ar * ae * ad];
        let mut pw = vec![0f32; ae];
        for e_lo in (0..e).step_by(ae) {
            let e_hi = (e_lo + ae).min(e);
            let elen = e_hi - e_lo;
            pw.fill(0.0);
            pw[..elen].copy_from_slice(&w[e_lo..e_hi]);
            for r_lo in (0..r).step_by(ar) {
                let r_hi = (r_lo + ar).min(r);
                let rlen = r_hi - r_lo;
                psrc.fill(0.0);
                pdst.fill(0.0);
                for ri in 0..rlen {
                    for ei in 0..elen {
                        let s = ((r_lo + ri) * e + (e_lo + ei)) * d;
                        let t = (ri * ae + ei) * ad;
                        psrc[t..t + d].copy_from_slice(&src[s..s + d]);
                        pdst[t..t + d].copy_from_slice(&dst[s..s + d]);
                    }
                }
                // Execute one padded artifact-shaped chunk (see module docs:
                // the native twin stands in for the PJRT executable).
                let values =
                    batched_weighted_hops_native(&psrc, &pdst, &pw, &pdims, &pwrap, ar, ae, ad);
                *self.executions.lock().unwrap() += 1;
                for ri in 0..rlen {
                    out[r_lo + ri] += values[ri];
                }
            }
        }
        Ok(out)
    }
}

/// `WhopsBackend` adapter: the artifact runtime with transparent fallback
/// to the direct native evaluator if execution fails (e.g. dimensionality
/// beyond any artifact).
pub struct PjrtBackend {
    pub runtime: PjrtRuntime,
    /// Count of requests that fell back to the native path.
    pub fallbacks: Mutex<u64>,
}

impl PjrtBackend {
    pub fn new(runtime: PjrtRuntime) -> Self {
        PjrtBackend {
            runtime,
            fallbacks: Mutex::new(0),
        }
    }

    /// Try to load artifacts; `None` if unavailable (callers then use
    /// `NativeBackend`).
    pub fn try_default() -> Option<Self> {
        PjrtRuntime::load_default().ok().map(Self::new)
    }
}

impl WhopsBackend for PjrtBackend {
    fn eval_batch(
        &self,
        src: &[f32],
        dst: &[f32],
        w: &[f32],
        dims: &[f32],
        wrap: &[f32],
        r: usize,
        e: usize,
        d: usize,
    ) -> Vec<f32> {
        match self.runtime.eval(src, dst, w, dims, wrap, r, e, d) {
            Ok(v) => v,
            Err(_) => {
                *self.fallbacks.lock().unwrap() += 1;
                batched_weighted_hops_native(src, dst, w, dims, wrap, r, e, d)
            }
        }
    }

    fn name(&self) -> &'static str {
        "pjrt-artifact"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_manifest(dir: &Path, entries: &str) {
        std::fs::create_dir_all(dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            format!("{{\"artifacts\":[{entries}]}}"),
        )
        .unwrap();
    }

    fn temp_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("taskmap-runtime-test-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn load_rejects_missing_manifest() {
        let dir = temp_dir("nomanifest");
        assert!(PjrtRuntime::load(&dir).is_err());
    }

    #[test]
    fn load_rejects_missing_artifact_file() {
        let dir = temp_dir("nofile");
        write_manifest(&dir, r#"{"file":"whops.hlo","r":2,"e":8,"d":3}"#);
        let e = match PjrtRuntime::load(&dir) {
            Err(e) => e,
            Ok(_) => panic!("expected missing-file error"),
        };
        assert!(e.0.contains("missing"), "{e}");
    }

    #[test]
    fn eval_matches_native_across_chunking() {
        let dir = temp_dir("eval");
        std::fs::write(dir.join("whops.hlo"), "HloModule whops (stub)").unwrap();
        write_manifest(&dir, r#"{"file":"whops.hlo","r":2,"e":8,"d":3}"#);
        let rt = PjrtRuntime::load(&dir).unwrap();
        // (r=5, e=19, d=2): forces candidate chunking, edge chunking, and
        // dim padding against the (2, 8, 3) artifact.
        let (r, e, d) = (5usize, 19usize, 2usize);
        let src: Vec<f32> = (0..r * e * d).map(|k| ((k * 3) % 7) as f32).collect();
        let dst: Vec<f32> = (0..r * e * d).map(|k| ((k * 5) % 7) as f32).collect();
        let w: Vec<f32> = (0..e).map(|k| (k % 3) as f32).collect();
        let dims = vec![7.0, 7.0];
        let wrap = vec![1.0, 0.0];
        let got = rt.eval(&src, &dst, &w, &dims, &wrap, r, e, d).unwrap();
        let want = batched_weighted_hops_native(&src, &dst, &w, &dims, &wrap, r, e, d);
        assert_eq!(got, want);
        // ceil(5/2) candidate chunks x ceil(19/8) edge chunks = 9 executions.
        assert_eq!(*rt.executions.lock().unwrap(), 9);
    }

    #[test]
    fn backend_falls_back_on_oversized_d() {
        let dir = temp_dir("fallback");
        std::fs::write(dir.join("whops.hlo"), "HloModule whops (stub)").unwrap();
        write_manifest(&dir, r#"{"file":"whops.hlo","r":2,"e":8,"d":3}"#);
        let backend = PjrtBackend::new(PjrtRuntime::load(&dir).unwrap());
        let (r, e, d) = (1usize, 2usize, 5usize); // d=5 > artifact D=3
        let src = vec![0f32; r * e * d];
        let dst = vec![1f32; r * e * d];
        let w = vec![1f32; e];
        let out = backend.eval_batch(&src, &dst, &w, &[4.0; 5], &[1.0; 5], r, e, d);
        assert_eq!(out.len(), 1);
        assert_eq!(*backend.fallbacks.lock().unwrap(), 1);
    }
}
