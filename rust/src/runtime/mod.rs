//! PJRT runtime: loads the AOT-compiled `batched_weighted_hops` HLO-text
//! artifacts produced by `python/compile/aot.py` and executes them on the
//! PJRT CPU client from the L3 hot path. Python never runs at request time.
//!
//! Artifacts have fixed padded shapes `(R, E, D)`; requests are chunked
//! over candidates and edges and padded per the kernel's contract
//! (zero-weight edges and size-1 wrapped dims contribute nothing).

use crate::mapping::rotations::WhopsBackend;
use crate::metrics::native::batched_weighted_hops_native;
use crate::testutil::json::Json;
use anyhow::{bail, Context, Result};
use std::path::{Path, PathBuf};
use std::sync::Mutex;

/// One compiled artifact.
struct Artifact {
    r: usize,
    e: usize,
    d: usize,
    exe: xla::PjRtLoadedExecutable,
}

/// The PJRT evaluator: a CPU client plus the compiled artifact set.
pub struct PjrtRuntime {
    _client: xla::PjRtClient,
    artifacts: Vec<Artifact>,
    /// Number of PJRT executions performed (telemetry for benches/tests).
    pub executions: Mutex<u64>,
}

impl PjrtRuntime {
    /// Load every artifact listed in `dir/manifest.json` (written by
    /// `make artifacts`) and compile them once.
    pub fn load(dir: &Path) -> Result<Self> {
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path)
            .with_context(|| format!("reading {manifest_path:?} (run `make artifacts`)"))?;
        let manifest =
            Json::parse(&text).map_err(|e| anyhow::anyhow!("bad manifest.json: {e}"))?;
        let client = xla::PjRtClient::cpu()?;
        let mut artifacts = Vec::new();
        let entries = manifest
            .get("artifacts")
            .and_then(|a| a.as_arr())
            .context("manifest.json: missing artifacts array")?;
        for entry in entries {
            let file = entry
                .get("file")
                .and_then(|f| f.as_str())
                .context("artifact entry missing file")?;
            let (r, e, d) = (
                entry.get("r").and_then(|x| x.as_usize()).context("r")?,
                entry.get("e").and_then(|x| x.as_usize()).context("e")?,
                entry.get("d").and_then(|x| x.as_usize()).context("d")?,
            );
            let path: PathBuf = dir.join(file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("non-utf8 path")?,
            )?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client.compile(&comp)?;
            artifacts.push(Artifact { r, e, d, exe });
        }
        if artifacts.is_empty() {
            bail!("no artifacts in {dir:?}");
        }
        Ok(PjrtRuntime {
            _client: client,
            artifacts,
            executions: Mutex::new(0),
        })
    }

    /// Load from the conventional `artifacts/` directory next to the repo
    /// root (or `$TASKMAP_ARTIFACTS`).
    pub fn load_default() -> Result<Self> {
        let dir = std::env::var("TASKMAP_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
        Self::load(Path::new(&dir))
    }

    /// Pick the artifact minimizing padded work for an `(r, e, d)` request.
    fn pick(&self, r: usize, e: usize, d: usize) -> Option<&Artifact> {
        self.artifacts
            .iter()
            .filter(|a| a.d >= d)
            .min_by_key(|a| {
                let chunks = r.div_ceil(a.r) * e.div_ceil(a.e);
                chunks * a.r * a.e * a.d
            })
    }

    /// Batched WeightedHops via PJRT. Errors if no artifact can serve `d`.
    pub fn eval(
        &self,
        src: &[f32],
        dst: &[f32],
        w: &[f32],
        dims: &[f32],
        wrap: &[f32],
        r: usize,
        e: usize,
        d: usize,
    ) -> Result<Vec<f32>> {
        let art = self
            .pick(r, e, d)
            .with_context(|| format!("no artifact with D >= {d}"))?;
        let (ar, ae, ad) = (art.r, art.e, art.d);
        // Padded dims/wrap: size-1 torus dims are inert.
        let mut pdims = vec![1f32; ad];
        let mut pwrap = vec![1f32; ad];
        pdims[..d].copy_from_slice(dims);
        pwrap[..d].copy_from_slice(wrap);
        let dims_lit = xla::Literal::vec1(&pdims).reshape(&[ad as i64])?;
        let wrap_lit = xla::Literal::vec1(&pwrap).reshape(&[ad as i64])?;

        let mut out = vec![0f32; r];
        let mut psrc = vec![0f32; ar * ae * ad];
        let mut pdst = vec![0f32; ar * ae * ad];
        let mut pw = vec![0f32; ae];
        for e_lo in (0..e).step_by(ae) {
            let e_hi = (e_lo + ae).min(e);
            let elen = e_hi - e_lo;
            pw.fill(0.0);
            pw[..elen].copy_from_slice(&w[e_lo..e_hi]);
            let w_lit = xla::Literal::vec1(&pw).reshape(&[ae as i64])?;
            for r_lo in (0..r).step_by(ar) {
                let r_hi = (r_lo + ar).min(r);
                let rlen = r_hi - r_lo;
                psrc.fill(0.0);
                pdst.fill(0.0);
                for ri in 0..rlen {
                    for ei in 0..elen {
                        let s = ((r_lo + ri) * e + (e_lo + ei)) * d;
                        let t = (ri * ae + ei) * ad;
                        psrc[t..t + d].copy_from_slice(&src[s..s + d]);
                        pdst[t..t + d].copy_from_slice(&dst[s..s + d]);
                    }
                }
                let src_lit =
                    xla::Literal::vec1(&psrc).reshape(&[ar as i64, ae as i64, ad as i64])?;
                let dst_lit =
                    xla::Literal::vec1(&pdst).reshape(&[ar as i64, ae as i64, ad as i64])?;
                let result = art.exe.execute::<xla::Literal>(&[
                    src_lit,
                    dst_lit,
                    w_lit.clone(),
                    dims_lit.clone(),
                    wrap_lit.clone(),
                ])?[0][0]
                    .to_literal_sync()?;
                // aot.py lowers with return_tuple=True: unwrap the 1-tuple.
                let values = result.to_tuple1()?.to_vec::<f32>()?;
                *self.executions.lock().unwrap() += 1;
                for ri in 0..rlen {
                    out[r_lo + ri] += values[ri];
                }
            }
        }
        Ok(out)
    }
}

/// `WhopsBackend` adapter: PJRT with transparent fallback to the native
/// evaluator if execution fails (e.g. dimensionality beyond any artifact).
pub struct PjrtBackend {
    pub runtime: PjrtRuntime,
    /// Count of requests that fell back to the native path.
    pub fallbacks: Mutex<u64>,
}

impl PjrtBackend {
    pub fn new(runtime: PjrtRuntime) -> Self {
        PjrtBackend {
            runtime,
            fallbacks: Mutex::new(0),
        }
    }

    /// Try to load artifacts; `None` if unavailable (callers then use
    /// `NativeBackend`).
    pub fn try_default() -> Option<Self> {
        PjrtRuntime::load_default().ok().map(Self::new)
    }
}

impl WhopsBackend for PjrtBackend {
    fn eval_batch(
        &self,
        src: &[f32],
        dst: &[f32],
        w: &[f32],
        dims: &[f32],
        wrap: &[f32],
        r: usize,
        e: usize,
        d: usize,
    ) -> Vec<f32> {
        match self.runtime.eval(src, dst, w, dims, wrap, r, e, d) {
            Ok(v) => v,
            Err(_) => {
                *self.fallbacks.lock().unwrap() += 1;
                batched_weighted_hops_native(src, dst, w, dims, wrap, r, e, d)
            }
        }
    }

    fn name(&self) -> &'static str {
        "pjrt"
    }
}
