//! Log-bucketed latency histograms (p50/p95/p99 without storing samples).
//!
//! A [`Histogram`] keeps one bucket per power of two of microseconds:
//! value `v` lands in bucket `⌈log2(v+1)⌉`, so bucket `b` covers
//! `[2^(b-1), 2^b - 1]` (bucket 0 holds exact zeros). Quantiles are read
//! back as the upper bound of the bucket containing the requested rank,
//! clamped to the observed maximum — a ≤2× overestimate in exchange for
//! constant memory and O(1) recording, which is the right trade for
//! service telemetry (the `{"op":"stats"}` per-op table) and span
//! metrics. The exact `count`/`sum`/`max` are kept alongside, so the
//! aggregate fields the histogram replaced (`total_us`, `max_us`,
//! `mean_us`) stay exact.
//!
//! The struct is plain data (no atomics): callers that share one across
//! threads put it behind the lock they already hold (see
//! `coordinator::service::Diagnostics`).

/// Number of log2 buckets: covers the full `u64` microsecond range.
pub const BUCKETS: usize = 64;

/// A log2-bucketed histogram of microsecond latencies.
#[derive(Clone, Debug)]
pub struct Histogram {
    count: u64,
    sum: u64,
    max: u64,
    buckets: [u64; BUCKETS],
}

impl Default for Histogram {
    fn default() -> Histogram {
        Histogram {
            count: 0,
            sum: 0,
            max: 0,
            buckets: [0; BUCKETS],
        }
    }
}

/// Bucket index for a value: 0 for 0, otherwise the bit length of `v`
/// (clamped to the last bucket).
fn bucket_of(v: u64) -> usize {
    ((u64::BITS - v.leading_zeros()) as usize).min(BUCKETS - 1)
}

/// Inclusive upper bound of bucket `b`.
fn bucket_upper(b: usize) -> u64 {
    if b == 0 {
        0
    } else if b >= 63 {
        u64::MAX
    } else {
        (1u64 << b) - 1
    }
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram::default()
    }

    /// Record one microsecond observation.
    pub fn record(&mut self, us: u64) {
        self.count += 1;
        self.sum = self.sum.saturating_add(us);
        self.max = self.max.max(us);
        self.buckets[bucket_of(us)] += 1;
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn sum(&self) -> u64 {
        self.sum
    }

    pub fn max(&self) -> u64 {
        self.max
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }

    /// The `q`-quantile (`0.0 ..= 1.0`) as the upper bound of the bucket
    /// holding the rank-`⌈q·count⌉` observation, clamped to the observed
    /// max. 0 for an empty histogram.
    pub fn quantile(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let rank = ((q * self.count as f64).ceil() as u64).clamp(1, self.count);
        let mut seen = 0u64;
        for (b, &n) in self.buckets.iter().enumerate() {
            seen += n;
            if seen >= rank {
                return bucket_upper(b).min(self.max);
            }
        }
        self.max
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_is_zeroes() {
        let h = Histogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.quantile(0.5), 0);
        assert_eq!(h.mean(), 0.0);
    }

    #[test]
    fn exact_aggregates_survive_bucketing() {
        let mut h = Histogram::new();
        for us in [3u64, 10, 100, 1000, 10_000] {
            h.record(us);
        }
        assert_eq!(h.count(), 5);
        assert_eq!(h.sum(), 11_113);
        assert_eq!(h.max(), 10_000);
        assert!((h.mean() - 11_113.0 / 5.0).abs() < 1e-9);
    }

    #[test]
    fn quantiles_bound_within_a_factor_of_two() {
        let mut h = Histogram::new();
        for us in 1..=1000u64 {
            h.record(us);
        }
        let p50 = h.quantile(0.50);
        let p95 = h.quantile(0.95);
        let p99 = h.quantile(0.99);
        // Upper bucket bounds: never below the true quantile, at most 2x.
        assert!((500..=1023).contains(&p50), "p50={p50}");
        assert!((950..=1023).contains(&p95), "p95={p95}");
        assert!((990..=1023).contains(&p99), "p99={p99}");
        // Clamped to the observed max.
        assert!(h.quantile(1.0) <= 1000);
    }

    #[test]
    fn zero_and_huge_values_have_buckets() {
        let mut h = Histogram::new();
        h.record(0);
        h.record(u64::MAX);
        assert_eq!(h.quantile(0.5), 0);
        assert_eq!(h.quantile(1.0), u64::MAX);
        assert_eq!(h.count(), 2);
    }

    #[test]
    fn bucket_of_is_monotone() {
        let mut prev = 0;
        for v in [0u64, 1, 2, 3, 4, 7, 8, 1023, 1024, u64::MAX] {
            let b = bucket_of(v);
            assert!(b >= prev);
            assert!(v <= bucket_upper(b));
            prev = b;
        }
    }
}
