//! Trace output: the `TASKMAP_TRACE` JSONL sink, the documented
//! line schema and its validator (run by CI over a smoke-run service
//! trace), and span-tree assembly for the `{"op":"trace"}` endpoint.
//!
//! See the [`super`] module docs for the schema. Only completed spans
//! (`"ph":"X"`) and instants (`"ph":"i"`) are written; Start events are
//! implied by the X event's `ts`/`dur`.

use super::{Event, EventKind};
use crate::testutil::json::Json;
use std::fs::File;
use std::io::{BufWriter, Write};
use std::sync::Mutex;

static SINK: Mutex<Option<BufWriter<File>>> = Mutex::new(None);

/// Open (truncating) a JSONL sink at `path`. Subsequent flushed events
/// append one line each.
pub fn install_sink(path: &str) -> std::io::Result<()> {
    let file = File::create(path)?;
    *super::lock_ok(&SINK) = Some(BufWriter::new(file));
    Ok(())
}

/// Drop the sink (tests). Buffered lines are flushed first.
pub fn clear_sink() {
    let mut sink = super::lock_ok(&SINK);
    if let Some(w) = sink.as_mut() {
        let _ = w.flush();
    }
    *sink = None;
}

/// Write a flushed batch of events to the sink, if one is installed.
/// Called from lane-buffer flushes; batches are flushed to the OS so the
/// file is readable while the process lives.
pub(crate) fn write_events(events: &[Event]) {
    let mut sink = super::lock_ok(&SINK);
    let Some(w) = sink.as_mut() else {
        return;
    };
    for e in events {
        if let Some(json) = event_json(e) {
            let _ = writeln!(w, "{}", json.to_string());
        }
    }
    let _ = w.flush();
}

/// The JSONL form of one event: `Some` for End (ph `X`, `ts` = span
/// start) and Instant (ph `i`) events, `None` for Start events (implied).
pub fn event_json(e: &Event) -> Option<Json> {
    let args = Json::Obj(
        e.fields
            .iter()
            .map(|&(k, v)| (k.to_string(), Json::Num(v)))
            .collect(),
    );
    match e.kind {
        EventKind::Start => None,
        EventKind::End => Some(Json::obj(vec![
            ("name", Json::Str(e.name.to_string())),
            ("ph", Json::Str("X".into())),
            ("ts", Json::Num(e.t_us.saturating_sub(e.dur_us) as f64)),
            ("dur", Json::Num(e.dur_us as f64)),
            ("pid", Json::Num(1.0)),
            ("tid", Json::Num(e.lane as f64)),
            ("trace", Json::Num(e.trace as f64)),
            ("args", args),
        ])),
        EventKind::Instant => Some(Json::obj(vec![
            ("name", Json::Str(e.name.to_string())),
            ("ph", Json::Str("i".into())),
            ("ts", Json::Num(e.t_us as f64)),
            ("pid", Json::Num(1.0)),
            ("tid", Json::Num(e.lane as f64)),
            ("trace", Json::Num(e.trace as f64)),
            ("args", args),
        ])),
    }
}

/// Validate one JSONL line against the documented schema.
pub fn validate_jsonl_line(line: &str) -> Result<(), String> {
    let json = Json::parse(line).map_err(|e| format!("not JSON: {e}"))?;
    let Json::Obj(map) = &json else {
        return Err("line is not an object".into());
    };
    let ph = json
        .get("ph")
        .and_then(|v| v.as_str())
        .ok_or("missing \"ph\"")?;
    if ph != "X" && ph != "i" {
        return Err(format!("bad ph {ph:?} (want \"X\" or \"i\")"));
    }
    match json.get("name") {
        Some(Json::Str(s)) if !s.is_empty() => {}
        _ => return Err("missing or empty \"name\"".into()),
    }
    for key in ["ts", "pid", "tid", "trace"] {
        match json.get(key) {
            Some(Json::Num(x)) if *x >= 0.0 => {}
            _ => return Err(format!("missing or negative \"{key}\"")),
        }
    }
    match json.get("dur") {
        Some(Json::Num(x)) if *x >= 0.0 && ph == "X" => {}
        None if ph == "i" => {}
        Some(_) => return Err("\"dur\" only valid (non-negative) on ph \"X\"".into()),
        None => return Err("ph \"X\" requires \"dur\"".into()),
    }
    match json.get("args") {
        Some(Json::Obj(args)) => {
            for (k, v) in args {
                if !matches!(v, Json::Num(_)) {
                    return Err(format!("args.{k} is not a number"));
                }
            }
        }
        _ => return Err("missing \"args\" object".into()),
    }
    const ALLOWED: [&str; 8] = ["name", "ph", "ts", "dur", "pid", "tid", "trace", "args"];
    for k in map.keys() {
        if !ALLOWED.contains(&k.as_str()) {
            return Err(format!("unknown key {k:?}"));
        }
    }
    Ok(())
}

/// Validate a whole JSONL document (empty lines skipped); returns the
/// number of validated events or the first failure with its line number.
pub fn validate_jsonl(text: &str) -> Result<usize, String> {
    let mut n = 0;
    for (i, line) in text.lines().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        validate_jsonl_line(line).map_err(|e| format!("line {}: {e}", i + 1))?;
        n += 1;
    }
    Ok(n)
}

/// Assemble events (pre-sorted by `(trace, lane, seq)`, as
/// [`super::recent_events`] returns them) into per-trace span trees:
/// `[{"trace":N,"spans":[{"name","t_us","dur_us","fields",
/// "children"},...]},...]`. Instants become leaves with `"instant":true`;
/// a Start whose End was lost to ring eviction is closed with
/// `"open":true`.
pub fn span_tree_json(events: &[Event]) -> Json {
    let mut traces: Vec<Json> = Vec::new();
    let mut i = 0;
    while i < events.len() {
        let trace = events[i].trace;
        let mut j = i;
        while j < events.len() && events[j].trace == trace {
            j += 1;
        }
        let spans = build_forest(&events[i..j]);
        traces.push(Json::obj(vec![
            ("trace", Json::Num(trace as f64)),
            ("spans", Json::Arr(spans)),
        ]));
        i = j;
    }
    Json::Arr(traces)
}

/// One partially-built span node.
struct Node {
    name: &'static str,
    t_us: u64,
    dur_us: u64,
    fields: Vec<(&'static str, f64)>,
    children: Vec<Json>,
    open: bool,
}

impl Node {
    fn into_json(self) -> Json {
        let fields = Json::Obj(
            self.fields
                .iter()
                .map(|&(k, v)| (k.to_string(), Json::Num(v)))
                .collect(),
        );
        let mut out = vec![
            ("name", Json::Str(self.name.to_string())),
            ("t_us", Json::Num(self.t_us as f64)),
            ("dur_us", Json::Num(self.dur_us as f64)),
            ("fields", fields),
            ("children", Json::Arr(self.children)),
        ];
        if self.open {
            out.push(("open", Json::Bool(true)));
        }
        Json::obj(out)
    }
}

fn build_forest(events: &[Event]) -> Vec<Json> {
    let mut roots: Vec<Json> = Vec::new();
    let mut stack: Vec<Node> = Vec::new();
    let attach = |stack: &mut Vec<Node>, roots: &mut Vec<Json>, json: Json| {
        match stack.last_mut() {
            Some(parent) => parent.children.push(json),
            None => roots.push(json),
        }
    };
    for e in events {
        match e.kind {
            EventKind::Start => stack.push(Node {
                name: e.name,
                t_us: e.t_us,
                dur_us: 0,
                fields: Vec::new(),
                children: Vec::new(),
                open: true,
            }),
            EventKind::End => {
                if let Some(mut node) = stack.pop() {
                    node.dur_us = e.dur_us;
                    node.fields = e.fields.clone();
                    node.open = false;
                    attach(&mut stack, &mut roots, node.into_json());
                } else {
                    // End without a Start in the window (eviction).
                    let node = Node {
                        name: e.name,
                        t_us: e.t_us.saturating_sub(e.dur_us),
                        dur_us: e.dur_us,
                        fields: e.fields.clone(),
                        children: Vec::new(),
                        open: false,
                    };
                    attach(&mut stack, &mut roots, node.into_json());
                }
            }
            EventKind::Instant => {
                let leaf = Json::obj(vec![
                    ("name", Json::Str(e.name.to_string())),
                    ("t_us", Json::Num(e.t_us as f64)),
                    ("instant", Json::Bool(true)),
                    (
                        "fields",
                        Json::Obj(
                            e.fields
                                .iter()
                                .map(|&(k, v)| (k.to_string(), Json::Num(v)))
                                .collect(),
                        ),
                    ),
                ]);
                attach(&mut stack, &mut roots, leaf);
            }
        }
    }
    // Spans still open at the window edge.
    while let Some(node) = stack.pop() {
        let json = node.into_json();
        attach(&mut stack, &mut roots, json);
    }
    roots
}

/// A timing-free rendering of an event stream: depth, kind, name, and
/// field *names* (values like `elapsed_us` vary run to run; structure and
/// order must not). Two captures of the same pipeline input at the same
/// thread budget must produce equal digests — the span-replay property.
pub fn structural_digest(events: &[Event]) -> String {
    let mut out = String::new();
    for e in events {
        let kind = match e.kind {
            EventKind::Start => "start",
            EventKind::End => "end",
            EventKind::Instant => "instant",
        };
        out.push_str(&format!(
            "{:indent$}{kind} {name}",
            "",
            indent = e.depth as usize * 2,
            name = e.name
        ));
        for (k, _) in &e.fields {
            out.push(' ');
            out.push_str(k);
        }
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(
        kind: EventKind,
        name: &'static str,
        seq: u64,
        depth: u32,
        dur_us: u64,
    ) -> Event {
        Event {
            trace: 1,
            lane: 0,
            seq,
            depth,
            kind,
            name,
            t_us: 100 + seq * 10,
            dur_us,
            fields: vec![("x", 1.0)],
        }
    }

    #[test]
    fn documented_example_line_validates() {
        let line = r#"{"name":"hier.sweep","ph":"X","ts":1042,"dur":3125,"pid":1,"tid":0,"trace":7,"args":{"node_score":412.5,"candidates":12}}"#;
        validate_jsonl_line(line).unwrap();
    }

    #[test]
    fn event_json_roundtrips_through_validator() {
        let end = ev(EventKind::End, "hier.refine", 3, 1, 250);
        let inst = ev(EventKind::Instant, "refine.pass", 4, 2, 0);
        let start = ev(EventKind::Start, "hier.refine", 2, 1, 0);
        assert!(event_json(&start).is_none());
        for e in [end, inst] {
            let line = event_json(&e).unwrap().to_string();
            validate_jsonl_line(&line).unwrap_or_else(|err| panic!("{line}: {err}"));
        }
    }

    #[test]
    fn validator_rejects_malformed_lines() {
        for (line, why) in [
            ("not json", "garbage"),
            (r#"{"ph":"X"}"#, "missing name"),
            (
                r#"{"name":"a","ph":"Q","ts":1,"pid":1,"tid":0,"trace":0,"args":{}}"#,
                "bad ph",
            ),
            (
                r#"{"name":"a","ph":"X","ts":1,"pid":1,"tid":0,"trace":0,"args":{}}"#,
                "X without dur",
            ),
            (
                r#"{"name":"a","ph":"i","ts":1,"pid":1,"tid":0,"trace":0,"args":{"s":"oops"}}"#,
                "non-numeric arg",
            ),
            (
                r#"{"name":"a","ph":"i","ts":1,"pid":1,"tid":0,"trace":0,"args":{},"extra":1}"#,
                "unknown key",
            ),
        ] {
            assert!(validate_jsonl_line(line).is_err(), "{why} accepted: {line}");
        }
    }

    #[test]
    fn validate_jsonl_counts_and_reports_line_numbers() {
        let good = r#"{"name":"a","ph":"i","ts":1,"pid":1,"tid":0,"trace":0,"args":{}}"#;
        let text = format!("{good}\n\n{good}\n");
        assert_eq!(validate_jsonl(&text), Ok(2));
        let bad = format!("{good}\nnope\n");
        let err = validate_jsonl(&bad).unwrap_err();
        assert!(err.starts_with("line 2:"), "{err}");
    }

    #[test]
    fn span_tree_nests_and_attaches_instants() {
        let events = vec![
            ev(EventKind::Start, "service.map", 0, 0, 0),
            ev(EventKind::Start, "hier.sweep", 1, 1, 0),
            ev(EventKind::Instant, "sweep.candidate", 2, 2, 0),
            ev(EventKind::End, "hier.sweep", 3, 1, 40),
            ev(EventKind::End, "service.map", 4, 0, 90),
        ];
        let tree = span_tree_json(&events);
        let traces = tree.as_arr().unwrap();
        assert_eq!(traces.len(), 1);
        let spans = traces[0].get("spans").unwrap().as_arr().unwrap();
        assert_eq!(spans.len(), 1);
        let root = &spans[0];
        assert_eq!(root.get("name").and_then(|v| v.as_str()), Some("service.map"));
        let kids = root.get("children").unwrap().as_arr().unwrap();
        assert_eq!(kids[0].get("name").and_then(|v| v.as_str()), Some("hier.sweep"));
        let grandkids = kids[0].get("children").unwrap().as_arr().unwrap();
        assert_eq!(
            grandkids[0].get("name").and_then(|v| v.as_str()),
            Some("sweep.candidate")
        );
        assert_eq!(grandkids[0].get("instant"), Some(&Json::Bool(true)));
    }

    #[test]
    fn open_spans_are_marked() {
        let events = vec![ev(EventKind::Start, "service.map", 0, 0, 0)];
        let tree = span_tree_json(&events);
        let spans = tree.as_arr().unwrap()[0].get("spans").unwrap().as_arr().unwrap();
        assert_eq!(spans[0].get("open"), Some(&Json::Bool(true)));
    }

    #[test]
    fn structural_digest_ignores_values_but_keeps_shape() {
        let a = vec![
            ev(EventKind::Start, "hier.sweep", 0, 0, 0),
            ev(EventKind::End, "hier.sweep", 1, 0, 40),
        ];
        let mut b = a.clone();
        b[1].dur_us = 9999;
        b[1].t_us = 77;
        b[1].fields = vec![("x", 123.0)];
        assert_eq!(structural_digest(&a), structural_digest(&b));
        let mut c = a.clone();
        c[1].name = "hier.refine";
        assert_ne!(structural_digest(&a), structural_digest(&c));
    }

    #[test]
    fn sink_writes_validating_jsonl() {
        let path = std::env::temp_dir().join(format!(
            "taskmap-obs-sink-test-{}.jsonl",
            std::process::id()
        ));
        let path_str = path.to_str().unwrap();
        install_sink(path_str).unwrap();
        let events = vec![
            ev(EventKind::Start, "test.sink", 0, 0, 0),
            ev(EventKind::Instant, "test.point", 1, 1, 0),
            ev(EventKind::End, "test.sink", 2, 0, 10),
        ];
        write_events(&events);
        clear_sink();
        let text = std::fs::read_to_string(&path).unwrap();
        // Start is implied: two lines, both schema-valid.
        assert_eq!(validate_jsonl(&text), Ok(2));
        let _ = std::fs::remove_file(&path);
    }
}
