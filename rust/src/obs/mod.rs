//! Zero-dependency tracing + metrics for the mapping pipeline.
//!
//! Every layer of the pipeline — rotation sweep, MJ recursion, `MinVolume`
//! refinement, the hierarchical phases, the service — records into this one
//! subsystem, so a mapping run can explain where its time and its objective
//! improvement came from. The recorder is compiled in but **disabled by
//! default**: when neither the global recorder nor a thread-local capture
//! is active, [`span`]/[`instant`] cost one branch and touch nothing else,
//! and (pinned by property tests) enabling them never changes a mapping
//! bit.
//!
//! # Recording model
//!
//! Events go to a **per-thread buffer** (no locks on the recording path);
//! each thread is a *lane* carrying a monotone sequence number. Buffers
//! flush when the thread's outermost span ends: into the bounded global
//! ring (for `{"op":"trace"}`) and the JSONL sink (for `TASKMAP_TRACE`),
//! counting — never silently dropping — evictions. Merging is
//! deterministic the same way every parallel path here is: readers sort by
//! `(trace, lane, seq)`, and pipeline instrumentation emits parallel
//! sections' measurements *from the coordinating lane in item-index
//! order* (workers return their numbers as data, exactly like
//! `par::map_with` writes results into pre-assigned slots). A
//! [`capture`]'d trace therefore replays bit-identically for a fixed
//! input and thread budget.
//!
//! Three surfaces:
//! * [`capture`] — collect the calling thread's events around a closure
//!   (the service uses this for per-request `"profile"` objects);
//! * the global ring — [`recent_events`], served by `{"op":"trace"}` as a
//!   span tree ([`trace::span_tree_json`]);
//! * `TASKMAP_TRACE=<path>` — [`init_from_env`] installs a JSONL sink
//!   whose lines convert directly to `chrome://tracing` (see below).
//!
//! # Naming convention
//!
//! Dotted lowercase `<layer>.<phase>`; spans for regions, instants for
//! points:
//!
//! | name              | kind    | fields                                     |
//! |-------------------|---------|--------------------------------------------|
//! | `service.map`     | span    | root of a `map` request                    |
//! | `service.eval`    | span    | root of an `eval` request                  |
//! | `hier.sweep`      | span    | `node_score`, `candidates`                 |
//! | `hier.refine`     | span    | `swaps`                                    |
//! | `hier.socket`     | span    | `socket_swaps`                             |
//! | `hier.place`      | span    | —                                          |
//! | `map.eval`        | span    | `objective_value`, `objective_delta`       |
//! | `map.partition`   | span    | flat MJ partition of a `map` request       |
//! | `sweep.candidate` | instant | `index`, `score`, `elapsed_us`             |
//! | `refine.pass`     | instant | `pass`, `proposed`, `applied`, `gain`, `congestion_rescans` |
//! | `mj.partition`    | instant | `parts`, `points`, `depth`, `imbalance`    |
//! | `deadline.check`  | instant | `margin_us` (∞ margin omitted)             |
//!
//! Metric names follow the same convention ([`metrics`] registry:
//! counters + [`Histogram`]s, e.g. the service's `service.requests`
//! counter and `service.request_us` histogram).
//!
//! # JSONL schema (`TASKMAP_TRACE`)
//!
//! One event per line. Completed spans are Chrome trace "complete" events
//! (`"ph":"X"`, `ts` = start, `dur` = elapsed, both µs since the recorder
//! epoch); instants are `"ph":"i"`. `tid` is the lane, `trace` the request
//! trace id (0 outside a request), `args` the numeric fields:
//!
//! ```json
//! {"name":"hier.sweep","ph":"X","ts":1042,"dur":3125,"pid":1,"tid":0,"trace":7,"args":{"node_score":412.5,"candidates":12}}
//! ```
//!
//! [`trace::validate_jsonl`] checks a file against this schema (CI runs it
//! over a smoke-run service trace).
//!
//! # Caveats
//!
//! * Lane numbers are assigned per thread at first use, so with the
//!   *global* recorder on, spawned `par` workers that record (e.g. the MJ
//!   instant on an inlined worker-0 range) get process-lifetime lane ids;
//!   cross-run ordering is guaranteed per `(trace, lane)`, and the
//!   determinism property is stated for [`capture`]'d traces, which
//!   record on the coordinating lane only.
//! * [`capture`] is per-thread and not nestable (an inner capture drains
//!   the shared buffer).

pub mod hist;
pub mod trace;

pub use hist::Histogram;

use crate::testutil::json::Json;
use std::cell::{Cell, RefCell};
use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, Ordering};
use std::sync::{Mutex, Once, OnceLock};
use std::time::Instant;

/// Events kept in the global ring for `{"op":"trace"}`.
const RING_CAPACITY: usize = 4096;

/// What an [`Event`] marks.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EventKind {
    /// A span opened.
    Start,
    /// A span closed (`dur_us` is its elapsed time, `fields` its data).
    End,
    /// A point event.
    Instant,
}

/// One recorded trace event.
#[derive(Clone, Debug)]
pub struct Event {
    /// Request trace id (0 outside [`with_trace`]).
    pub trace: u64,
    /// Recording lane (one per thread, assigned at first use).
    pub lane: u32,
    /// Per-lane monotone sequence number (the deterministic sort key).
    pub seq: u64,
    /// Span nesting depth at emission (End events carry the span's depth).
    pub depth: u32,
    pub kind: EventKind,
    pub name: &'static str,
    /// Microseconds since the recorder epoch.
    pub t_us: u64,
    /// Elapsed microseconds (End events only).
    pub dur_us: u64,
    /// Numeric payload.
    pub fields: Vec<(&'static str, f64)>,
}

static ENABLED: AtomicBool = AtomicBool::new(false);
static NEXT_LANE: AtomicU32 = AtomicU32::new(0);
static NEXT_TRACE: AtomicU64 = AtomicU64::new(1);
static DROPPED: AtomicU64 = AtomicU64::new(0);
static RING: Mutex<VecDeque<Event>> = Mutex::new(VecDeque::new());

thread_local! {
    static CAPTURING: Cell<bool> = const { Cell::new(false) };
    static LANE: RefCell<LaneState> = RefCell::new(LaneState::new());
}

struct LaneState {
    trace: u64,
    /// `u32::MAX` = not yet assigned.
    lane: u32,
    depth: u32,
    seq: u64,
    buf: Vec<Event>,
    /// Prefix of `buf` already pushed to the ring/sink (avoids double
    /// emission when capture and the global recorder are both on).
    flushed: usize,
}

impl LaneState {
    fn new() -> LaneState {
        LaneState {
            trace: 0,
            lane: u32::MAX,
            depth: 0,
            seq: 0,
            buf: Vec::new(),
            flushed: 0,
        }
    }

    fn lane_id(&mut self) -> u32 {
        if self.lane == u32::MAX {
            self.lane = NEXT_LANE.fetch_add(1, Ordering::Relaxed);
        }
        self.lane
    }
}

/// Lock a mutex tolerating poison (observability must survive panics —
/// that is when it matters).
fn lock_ok<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    match m.lock() {
        Ok(g) => g,
        Err(poisoned) => poisoned.into_inner(),
    }
}

fn epoch() -> Instant {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    *EPOCH.get_or_init(Instant::now)
}

fn now_us() -> u64 {
    epoch().elapsed().as_micros().min(u128::from(u64::MAX)) as u64
}

/// Is the global recorder on?
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Turn the global recorder on/off (the ring and sink keep their
/// contents).
pub fn set_enabled(on: bool) {
    ENABLED.store(on, Ordering::Relaxed);
}

/// Is anything recording on this thread? This is the hot-path gate: one
/// relaxed load plus a thread-local read.
#[inline]
pub fn recording() -> bool {
    ENABLED.load(Ordering::Relaxed) || CAPTURING.with(|c| c.get())
}

/// Fresh per-request trace id (monotone, process-wide, never 0).
pub fn next_trace_id() -> u64 {
    NEXT_TRACE.fetch_add(1, Ordering::Relaxed)
}

/// Run `f` with the calling thread's events tagged by trace id `id`.
pub fn with_trace<R>(id: u64, f: impl FnOnce() -> R) -> R {
    let prev = LANE.with(|l| std::mem::replace(&mut l.borrow_mut().trace, id));
    let out = f();
    LANE.with(|l| l.borrow_mut().trace = prev);
    out
}

/// RAII span: records a Start event at creation and an End event (with
/// elapsed time and any [`Span::record`]ed fields) on drop. Inert when
/// nothing is recording.
pub struct Span {
    name: &'static str,
    start: Option<Instant>,
    fields: Vec<(&'static str, f64)>,
}

/// Open a span. See the module docs for the naming convention.
pub fn span(name: &'static str) -> Span {
    if !recording() {
        return Span {
            name,
            start: None,
            fields: Vec::new(),
        };
    }
    let t_us = now_us();
    LANE.with(|l| {
        let mut l = l.borrow_mut();
        let (trace, lane, depth) = (l.trace, l.lane_id(), l.depth);
        let seq = l.seq;
        l.seq += 1;
        l.depth += 1;
        l.buf.push(Event {
            trace,
            lane,
            seq,
            depth,
            kind: EventKind::Start,
            name,
            t_us,
            dur_us: 0,
            fields: Vec::new(),
        });
    });
    Span {
        name,
        start: Some(Instant::now()),
        fields: Vec::new(),
    }
}

impl Span {
    /// Attach a numeric field, emitted on the span's End event.
    pub fn record(&mut self, key: &'static str, value: f64) {
        if self.start.is_some() {
            self.fields.push((key, value));
        }
    }

    /// Is this span actually recording?
    pub fn live(&self) -> bool {
        self.start.is_some()
    }
}

impl Drop for Span {
    fn drop(&mut self) {
        let Some(start) = self.start.take() else {
            return;
        };
        let dur_us = start.elapsed().as_micros().min(u128::from(u64::MAX)) as u64;
        let t_us = now_us();
        let name = self.name;
        let fields = std::mem::take(&mut self.fields);
        LANE.with(|l| {
            let mut l = l.borrow_mut();
            l.depth = l.depth.saturating_sub(1);
            let (trace, lane, depth) = (l.trace, l.lane_id(), l.depth);
            let seq = l.seq;
            l.seq += 1;
            l.buf.push(Event {
                trace,
                lane,
                seq,
                depth,
                kind: EventKind::End,
                name,
                t_us,
                dur_us,
                fields,
            });
            if depth == 0 {
                flush(&mut l);
            }
        });
    }
}

/// Record a point event at the current depth.
pub fn instant(name: &'static str, fields: &[(&'static str, f64)]) {
    if !recording() {
        return;
    }
    let t_us = now_us();
    LANE.with(|l| {
        let mut l = l.borrow_mut();
        let (trace, lane, depth) = (l.trace, l.lane_id(), l.depth);
        let seq = l.seq;
        l.seq += 1;
        l.buf.push(Event {
            trace,
            lane,
            seq,
            depth,
            kind: EventKind::Instant,
            name,
            t_us,
            dur_us: 0,
            fields: fields.to_vec(),
        });
        if depth == 0 {
            flush(&mut l);
        }
    });
}

/// Push the unflushed tail of a lane buffer to the ring and sink (global
/// recorder only), then drop it unless a capture wants it.
fn flush(l: &mut LaneState) {
    if ENABLED.load(Ordering::Relaxed) && l.flushed < l.buf.len() {
        let tail = &l.buf[l.flushed..];
        ring_push(tail);
        trace::write_events(tail);
        l.flushed = l.buf.len();
    }
    if !CAPTURING.with(|c| c.get()) {
        l.buf.clear();
        l.flushed = 0;
    }
}

fn ring_push(events: &[Event]) {
    let mut ring = lock_ok(&RING);
    for e in events {
        if ring.len() == RING_CAPACITY {
            ring.pop_front();
            DROPPED.fetch_add(1, Ordering::Relaxed);
        }
        ring.push_back(e.clone());
    }
}

/// Collect the calling thread's events around `f`. Recording is forced on
/// for this thread regardless of the global flag; the global ring/sink
/// still see the events when the global recorder is also on.
pub fn capture<R>(f: impl FnOnce() -> R) -> (R, Vec<Event>) {
    let prev = CAPTURING.with(|c| c.replace(true));
    let out = f();
    let events = LANE.with(|l| {
        let mut l = l.borrow_mut();
        if ENABLED.load(Ordering::Relaxed) && l.flushed < l.buf.len() {
            let tail = &l.buf[l.flushed..];
            ring_push(tail);
            trace::write_events(tail);
        }
        l.flushed = 0;
        std::mem::take(&mut l.buf)
    });
    CAPTURING.with(|c| c.set(prev));
    (out, events)
}

/// Snapshot of the global ring, sorted by `(trace, lane, seq)` — the
/// deterministic merge order.
pub fn recent_events() -> Vec<Event> {
    let mut events: Vec<Event> = lock_ok(&RING).iter().cloned().collect();
    events.sort_by(|a, b| (a.trace, a.lane, a.seq).cmp(&(b.trace, b.lane, b.seq)));
    events
}

/// Events evicted from the ring since process start.
pub fn events_dropped() -> u64 {
    DROPPED.load(Ordering::Relaxed)
}

/// Empty the global ring (tests).
pub fn clear_recent() {
    lock_ok(&RING).clear();
}

/// Read `TASKMAP_TRACE` once and, if set, install the JSONL sink and turn
/// the global recorder on. Called by `Service::start` and the bench/CLI
/// entry points; idempotent.
pub fn init_from_env() {
    static INIT: Once = Once::new();
    INIT.call_once(refresh_env);
}

/// Re-read `TASKMAP_TRACE` unconditionally (tests; [`init_from_env`] is
/// once-only).
pub fn refresh_env() {
    if let Ok(path) = std::env::var("TASKMAP_TRACE") {
        if !path.is_empty() && trace::install_sink(&path).is_ok() {
            set_enabled(true);
        }
    }
}

/// Process-wide metrics registry: named counters plus latency
/// [`Histogram`]s. Updated only while something is recording, so the
/// disabled hot path stays branch-only.
#[derive(Default)]
pub struct Metrics {
    counters: Mutex<BTreeMap<&'static str, u64>>,
    hists: Mutex<BTreeMap<&'static str, Histogram>>,
}

/// The global registry.
pub fn metrics() -> &'static Metrics {
    static METRICS: OnceLock<Metrics> = OnceLock::new();
    METRICS.get_or_init(Metrics::default)
}

impl Metrics {
    /// Bump a counter by `n`.
    pub fn add(&self, name: &'static str, n: u64) {
        *lock_ok(&self.counters).entry(name).or_insert(0) += n;
    }

    /// Record a latency observation.
    pub fn observe_us(&self, name: &'static str, us: u64) {
        lock_ok(&self.hists).entry(name).or_default().record(us);
    }

    /// Current counter value (0 if never bumped).
    pub fn counter(&self, name: &str) -> u64 {
        lock_ok(&self.counters).get(name).copied().unwrap_or(0)
    }

    /// Reset everything (tests).
    pub fn reset(&self) {
        lock_ok(&self.counters).clear();
        lock_ok(&self.hists).clear();
    }

    /// `{"counters":{..},"histograms":{name:{count,mean_us,p50_us,p95_us,
    /// p99_us,max_us}}}`.
    pub fn snapshot_json(&self) -> Json {
        let counters = Json::Obj(
            lock_ok(&self.counters)
                .iter()
                .map(|(k, &v)| (k.to_string(), Json::Num(v as f64)))
                .collect(),
        );
        let hists = Json::Obj(
            lock_ok(&self.hists)
                .iter()
                .map(|(k, h)| {
                    (
                        k.to_string(),
                        Json::obj(vec![
                            ("count", Json::Num(h.count() as f64)),
                            ("mean_us", Json::Num(h.mean())),
                            ("p50_us", Json::Num(h.quantile(0.50) as f64)),
                            ("p95_us", Json::Num(h.quantile(0.95) as f64)),
                            ("p99_us", Json::Num(h.quantile(0.99) as f64)),
                            ("max_us", Json::Num(h.max() as f64)),
                        ]),
                    )
                })
                .collect(),
        );
        Json::obj(vec![("counters", counters), ("histograms", hists)])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_recorder_emits_nothing() {
        // Not capturing, and regardless of the global flag this thread's
        // buffer stays empty through inert spans.
        let s = span("test.inert.unique");
        assert!(!s.live() || enabled());
        drop(s);
        let (_, events) = capture(|| ());
        assert!(events.iter().all(|e| e.name != "test.inert.unique"));
    }

    #[test]
    fn capture_collects_nested_spans_in_order() {
        let ((), events) = capture(|| {
            let mut outer = span("test.outer");
            outer.record("x", 1.5);
            {
                let _inner = span("test.inner");
                instant("test.point", &[("v", 2.0)]);
            }
        });
        let names: Vec<&str> = events.iter().map(|e| e.name).collect();
        assert_eq!(
            names,
            vec!["test.outer", "test.inner", "test.point", "test.inner", "test.outer"]
        );
        // Sequence numbers are strictly increasing within the lane.
        for w in events.windows(2) {
            assert!(w[0].seq < w[1].seq);
            assert_eq!(w[0].lane, w[1].lane);
        }
        // Depth nests: outer start 0, inner start 1, instant depth 2.
        assert_eq!(events[0].depth, 0);
        assert_eq!(events[1].depth, 1);
        assert_eq!(events[2].depth, 2);
        assert_eq!(events[2].kind, EventKind::Instant);
        // The End event carries the recorded field.
        let end = events.last().unwrap();
        assert_eq!(end.kind, EventKind::End);
        assert_eq!(end.fields, vec![("x", 1.5)]);
    }

    #[test]
    fn with_trace_tags_events() {
        let id = next_trace_id();
        let ((), events) = capture(|| {
            with_trace(id, || {
                let _s = span("test.traced");
            });
            let _s = span("test.untraced");
        });
        let traced: Vec<u64> = events.iter().map(|e| e.trace).collect();
        assert_eq!(traced[0], id);
        assert_eq!(*traced.last().unwrap(), 0);
    }

    #[test]
    fn trace_ids_are_unique_and_nonzero() {
        let a = next_trace_id();
        let b = next_trace_id();
        assert_ne!(a, 0);
        assert_ne!(a, b);
    }

    #[test]
    fn metrics_registry_counts_and_buckets() {
        let m = Metrics::default();
        m.add("test.counter", 2);
        m.add("test.counter", 3);
        m.observe_us("test.lat_us", 100);
        m.observe_us("test.lat_us", 200);
        assert_eq!(m.counter("test.counter"), 5);
        let snap = m.snapshot_json();
        assert_eq!(
            snap.get("counters").and_then(|c| c.get("test.counter")).and_then(|v| v.as_f64()),
            Some(5.0)
        );
        let h = snap.get("histograms").and_then(|h| h.get("test.lat_us")).unwrap();
        assert_eq!(h.get("count").and_then(|v| v.as_f64()), Some(2.0));
        assert!(h.get("p99_us").and_then(|v| v.as_f64()).unwrap() >= 200.0);
    }
}
