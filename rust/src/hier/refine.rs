//! MinVolume refinement: greedy boundary swaps on the task→node
//! assignment, generic over the scoring evaluator.
//!
//! The node-level geometric partition minimizes cut volume only implicitly
//! (compact parts have small boundaries); this pass attacks it directly.
//! What a swap is worth comes from one pluggable
//! [`crate::objective::IncrementalEval`], built from an
//! [`EvalSpec`] — the same abstraction at every configuration:
//!
//! * **WeightedHops** (the default): the inter-node weighted hops of the
//!   assignment — `Σ_e w(e) · hops(node(u), node(v))`, exactly the Section
//!   3 metric of any mapping that respects the assignment.
//! * **WeightedHops × NUMA** (depth 3): the same hop pricing scaled by
//!   `hop_cost`, with intra-node edges charged the flat `socket_cost`
//!   upper bound the later socket split tightens.
//! * **Routed congestion** (`MaxLinkLoad` / `CongestionBlend`): swap gains
//!   against incrementally-maintained per-link loads
//!   ([`crate::objective::CongestionState`]) — each candidate swap
//!   re-routes only the edges incident to the swapped pair.
//! * **Routed congestion × NUMA** (blended depth 3): the routed network
//!   term *plus* the socket-cost intra-node term, priced together in one
//!   gain — the combination the pre-evaluator scoring arms could not
//!   express.
//!
//! A swap of two tasks in different nodes preserves per-node task counts,
//! so refinement never breaks the balance the bijection relies on.
//!
//! # Determinism
//!
//! Each pass has two phases:
//! 1. **Propose** (parallel over nodes, [`crate::par::map_with`]): for
//!    every boundary task, find the best swap partner among the tasks of
//!    its neighboring nodes against the *frozen* pass-start assignment and
//!    evaluator state ([`IncrementalEval::best_partner`]). Proposals are
//!    pure functions of that snapshot and land in index-addressed slots,
//!    so they do not depend on the thread budget.
//! 2. **Apply** (sequential): walk proposals in (node, task) order,
//!    re-evaluate each gain against the *current* assignment
//!    ([`IncrementalEval::swap_eval`]), and commit it only if still
//!    strictly improving.
//!
//! Both phases are deterministic, so refinement — like every other level
//! of the hierarchical mapper — is bit-identical at every thread count.

use crate::apps::TaskGraph;
use crate::machine::Topology;
use crate::objective::{
    build_eval, Adjacency, EvalScratch, EvalSpec, IncrementalEval, ObjectiveKind,
};
use crate::par::{self, Parallelism};

/// One proposed swap, produced by the parallel phase.
#[derive(Clone, Copy, Debug)]
struct Swap {
    u: u32,
    b: u32,
}

/// Inter-node weighted hops of an assignment (the default refinement
/// objective; exposed for tests and experiment reporting).
pub fn internode_weighted_hops(
    graph: &TaskGraph,
    node_of: &[u32],
    node_routers: &[u32],
    net: &dyn Topology,
) -> f64 {
    let mut total = 0f64;
    for e in &graph.edges {
        let (a, b) = (node_of[e.u as usize], node_of[e.v as usize]);
        if a != b {
            let h = net.hop_dist_ids(
                node_routers[a as usize] as usize,
                node_routers[b as usize] as usize,
            ) as f64;
            total += e.w * h;
        }
    }
    total
}

/// Run up to `passes` refinement passes over `node_of` (task→node, modified
/// in place) under the default inter-node WeightedHops objective. Returns
/// the number of swaps applied. Deterministic and independent of the
/// thread budget (see the module docs).
pub fn min_volume_refine(
    graph: &TaskGraph,
    node_of: &mut [u32],
    node_routers: &[u32],
    net: &dyn Topology,
    passes: usize,
    par: Parallelism,
) -> usize {
    min_volume_refine_eval(
        graph,
        node_of,
        node_routers,
        net,
        passes,
        par,
        EvalSpec::default(),
    )
}

/// [`min_volume_refine`] under the NUMA node-level pricing of
/// [`crate::machine::NumaNodeCosts`]: inter-node edges cost `hop` per
/// network hop, intra-node edges the flat `socket` upper bound (the
/// socket-level split runs later). With `hop == 1` and `socket == 0` this
/// is bit-identical to [`min_volume_refine`].
pub fn min_volume_refine_numa(
    graph: &TaskGraph,
    node_of: &mut [u32],
    node_routers: &[u32],
    net: &dyn Topology,
    passes: usize,
    par: Parallelism,
    costs: crate::machine::NumaNodeCosts,
) -> usize {
    min_volume_refine_eval(
        graph,
        node_of,
        node_routers,
        net,
        passes,
        par,
        EvalSpec::new(ObjectiveKind::WeightedHops, Some(costs)),
    )
}

/// [`min_volume_refine`] under a selectable network objective (no NUMA
/// term). Deterministic and independent of the thread budget either way.
#[allow(clippy::too_many_arguments)]
pub fn min_volume_refine_with(
    graph: &TaskGraph,
    node_of: &mut [u32],
    node_routers: &[u32],
    net: &dyn Topology,
    passes: usize,
    par: Parallelism,
    objective: ObjectiveKind,
) -> usize {
    min_volume_refine_eval(
        graph,
        node_of,
        node_routers,
        net,
        passes,
        par,
        EvalSpec::new(objective, None),
    )
}

/// The unified refinement entry point: greedy boundary swaps under any
/// [`EvalSpec`] combination (network objective × optional NUMA term),
/// through one loop generic over the [`IncrementalEval`] it builds. All
/// the other `min_volume_refine*` entry points are thin wrappers.
#[allow(clippy::too_many_arguments)]
pub fn min_volume_refine_eval(
    graph: &TaskGraph,
    node_of: &mut [u32],
    node_routers: &[u32],
    net: &dyn Topology,
    passes: usize,
    par: Parallelism,
    spec: EvalSpec,
) -> usize {
    assert_eq!(node_of.len(), graph.num_tasks);
    let nn = node_routers.len();
    if nn < 2 || graph.edges.is_empty() {
        return 0;
    }
    let mut eval = build_eval(net, node_routers, graph, node_of, spec);
    refine_loop(graph, node_of, nn, passes, par, &mut eval)
}

/// The propose-parallel / apply-sequential refinement loop, generic over
/// the evaluator (see the module docs for the determinism argument).
fn refine_loop<E: IncrementalEval>(
    graph: &TaskGraph,
    node_of: &mut [u32],
    nn: usize,
    passes: usize,
    par: Parallelism,
    eval: &mut E,
) -> usize {
    let adj = Adjacency::build(graph);
    let node_ids: Vec<u32> = (0..nn as u32).collect();
    let mut apply_scratch = EvalScratch::new();
    let mut applied_total = 0usize;
    for pass in 0..passes {
        // Tasks grouped by node against the pass-start snapshot.
        let mut tasks_by_node: Vec<Vec<u32>> = vec![Vec::new(); nn];
        for (t, &x) in node_of.iter().enumerate() {
            tasks_by_node[x as usize].push(t as u32);
        }
        // Phase 1: propose, in parallel over nodes, against the frozen
        // snapshot (assignment + evaluator state). &*node_of reborrows
        // immutably for the scope of the map.
        let snapshot: &[u32] = node_of;
        let eval_ref: &E = eval;
        let proposals: Vec<Vec<Swap>> = par::map_with(
            par,
            &node_ids,
            EvalScratch::new,
            |scratch, _i, &a| {
                let mut out = Vec::new();
                for &u in &tasks_by_node[a as usize] {
                    // Candidate target nodes: distinct nodes of u's
                    // neighbors, ascending, excluding u's own.
                    let mut targets: Vec<u32> = adj
                        .neighbors(u as usize)
                        .map(|(n, _)| snapshot[n as usize])
                        .filter(|&x| x != a)
                        .collect();
                    if targets.is_empty() {
                        continue;
                    }
                    targets.sort_unstable();
                    targets.dedup();
                    if let Some((_, b)) = eval_ref.best_partner(
                        snapshot,
                        &adj,
                        u as usize,
                        &targets,
                        &tasks_by_node,
                        scratch,
                    ) {
                        out.push(Swap { u, b });
                    }
                }
                out
            },
        );
        // Phase 2: apply sequentially in (node, task) order, re-checking
        // each gain against the current assignment and committing the
        // evaluator delta incrementally.
        let recording = crate::obs::recording();
        let rescans_before = eval.rescans();
        let mut applied_this_pass = 0usize;
        let mut proposed_this_pass = 0usize;
        let mut gain_this_pass = 0f64;
        for Swap { u, b } in proposals.into_iter().flatten() {
            proposed_this_pass += 1;
            let (a, bn) = (node_of[u as usize], node_of[b as usize]);
            if a == bn {
                continue;
            }
            let ev = eval.swap_eval(node_of, &adj, u as usize, b as usize, &mut apply_scratch);
            if ev.gain > 0.0 {
                eval.commit(&ev, &apply_scratch);
                node_of[u as usize] = bn;
                node_of[b as usize] = a;
                applied_this_pass += 1;
                gain_this_pass += ev.gain;
            }
        }
        if recording {
            // Everything here is a pure function of the pass, never of
            // timing, so traces replay bit-identically.
            crate::obs::instant(
                "refine.pass",
                &[
                    ("pass", pass as f64),
                    ("proposed", proposed_this_pass as f64),
                    ("applied", applied_this_pass as f64),
                    ("gain", gain_this_pass),
                    ("congestion_rescans", (eval.rescans() - rescans_before) as f64),
                ],
            );
        }
        applied_total += applied_this_pass;
        if applied_this_pass == 0 {
            break;
        }
    }
    applied_total
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::stencil::stencil_graph;
    use crate::machine::Torus;
    use crate::objective::CongestionState;

    #[test]
    fn refine_reduces_objective_and_preserves_balance() {
        // 1D chain of 16 tasks, 4 nodes on a 4-ring; scrambled assignment.
        let g = stencil_graph(&[16], false, 1.0);
        let torus = Torus::torus(&[4]);
        let routers: Vec<u32> = vec![0, 1, 2, 3];
        // Stride assignment: maximally non-contiguous.
        let mut node_of: Vec<u32> = (0..16).map(|t| (t % 4) as u32).collect();
        let before = internode_weighted_hops(&g, &node_of, &routers, &torus);
        let swaps =
            min_volume_refine(&g, &mut node_of, &routers, &torus, 8, Parallelism::sequential());
        let after = internode_weighted_hops(&g, &node_of, &routers, &torus);
        assert!(swaps > 0, "no swaps applied on a scrambled assignment");
        assert!(after < before, "objective {after} !< {before}");
        let mut sizes = [0usize; 4];
        for &x in &node_of {
            sizes[x as usize] += 1;
        }
        assert_eq!(sizes, [4, 4, 4, 4]);
    }

    #[test]
    fn refine_is_thread_count_invariant() {
        let g = stencil_graph(&[6, 6], false, 2.0);
        let torus = Torus::torus(&[3, 3]);
        let routers: Vec<u32> = (0..9).collect();
        let start: Vec<u32> = (0..36).map(|t| (t % 9) as u32).collect();
        let mut seq = start.clone();
        min_volume_refine(&g, &mut seq, &routers, &torus, 4, Parallelism::sequential());
        for threads in [2, 8] {
            let mut par_assign = start.clone();
            min_volume_refine(
                &g,
                &mut par_assign,
                &routers,
                &torus,
                4,
                Parallelism::threads(threads).with_grain(1),
            );
            assert_eq!(par_assign, seq, "threads={threads}");
        }
    }

    #[test]
    fn congestion_refine_reduces_its_objective_and_preserves_balance() {
        let g = stencil_graph(&[16], false, 2.0);
        let torus = Torus::torus(&[4]);
        let routers: Vec<u32> = vec![0, 1, 2, 3];
        for kind in [ObjectiveKind::MaxLinkLoad, ObjectiveKind::CongestionBlend] {
            let mut node_of: Vec<u32> = (0..16).map(|t| (t % 4) as u32).collect();
            let before =
                CongestionState::build(&torus, &routers, &g, &node_of, kind).value();
            let swaps = min_volume_refine_with(
                &g,
                &mut node_of,
                &routers,
                &torus,
                8,
                Parallelism::sequential(),
                kind,
            );
            let after = CongestionState::build(&torus, &routers, &g, &node_of, kind).value();
            assert!(swaps > 0, "{kind:?}: no swaps on a scrambled assignment");
            assert!(after < before, "{kind:?}: {after} !< {before}");
            let mut sizes = [0usize; 4];
            for &x in &node_of {
                sizes[x as usize] += 1;
            }
            assert_eq!(sizes, [4, 4, 4, 4], "{kind:?}");
        }
    }

    #[test]
    fn congestion_refine_is_thread_count_invariant() {
        let g = stencil_graph(&[6, 6], false, 2.0);
        let torus = Torus::torus(&[3, 3]);
        let routers: Vec<u32> = (0..9).collect();
        let start: Vec<u32> = (0..36).map(|t| (t % 9) as u32).collect();
        for kind in [ObjectiveKind::MaxLinkLoad, ObjectiveKind::CongestionBlend] {
            let mut seq = start.clone();
            min_volume_refine_with(
                &g,
                &mut seq,
                &routers,
                &torus,
                4,
                Parallelism::sequential(),
                kind,
            );
            for threads in [2, 8] {
                let mut par_assign = start.clone();
                min_volume_refine_with(
                    &g,
                    &mut par_assign,
                    &routers,
                    &torus,
                    4,
                    Parallelism::threads(threads).with_grain(1),
                    kind,
                );
                assert_eq!(par_assign, seq, "{kind:?} threads={threads}");
            }
        }
    }

    #[test]
    fn refine_with_weighted_hops_matches_hop_path() {
        // The dispatching entry point under the default objective must be
        // exactly the hop-weighted refinement.
        let g = stencil_graph(&[6, 6], false, 2.0);
        let torus = Torus::torus(&[3, 3]);
        let routers: Vec<u32> = (0..9).collect();
        let start: Vec<u32> = (0..36).map(|t| (t % 9) as u32).collect();
        let mut direct = start.clone();
        let sd = min_volume_refine(&g, &mut direct, &routers, &torus, 4, Parallelism::sequential());
        let mut via = start.clone();
        let sv = min_volume_refine_with(
            &g,
            &mut via,
            &routers,
            &torus,
            4,
            Parallelism::sequential(),
            ObjectiveKind::WeightedHops,
        );
        assert_eq!((sd, direct), (sv, via));
    }

    #[test]
    fn numa_refine_with_zero_socket_cost_matches_hop_path() {
        // hop = 1, socket = 0 must reproduce the plain hop-weighted
        // refinement bit for bit.
        use crate::machine::NumaNodeCosts;
        let g = stencil_graph(&[6, 6], false, 2.0);
        let torus = Torus::torus(&[3, 3]);
        let routers: Vec<u32> = (0..9).collect();
        let start: Vec<u32> = (0..36).map(|t| (t % 9) as u32).collect();
        let mut plain = start.clone();
        let sp = min_volume_refine(&g, &mut plain, &routers, &torus, 4, Parallelism::sequential());
        let mut numa = start.clone();
        let sn = min_volume_refine_numa(
            &g,
            &mut numa,
            &routers,
            &torus,
            4,
            Parallelism::sequential(),
            NumaNodeCosts {
                hop: 1.0,
                socket: 0.0,
            },
        );
        assert_eq!((sp, plain), (sn, numa));
    }

    #[test]
    fn numa_refine_reduces_node_level_numa_objective() {
        use crate::machine::{Allocation, NumaNodeCosts};
        use crate::mapping::rotations::numa_node_score;
        let g = stencil_graph(&[16], false, 1.0);
        let torus = Torus::torus(&[4]);
        let routers: Vec<u32> = vec![0, 1, 2, 3];
        let costs = NumaNodeCosts {
            hop: 1.0,
            socket: 0.4,
        };
        // Node-level pseudo-allocation to score assignments against.
        let alloc = Allocation {
            machine: torus.clone().into(),
            core_router: routers.clone(),
            core_node: (0..4u32).collect(),
            ranks_per_node: 1,
        };
        let mut node_of: Vec<u32> = (0..16).map(|t| (t % 4) as u32).collect();
        let before = numa_node_score(&g, &node_of, &alloc, costs);
        let swaps = min_volume_refine_numa(
            &g,
            &mut node_of,
            &routers,
            &torus,
            8,
            Parallelism::sequential(),
            costs,
        );
        let after = numa_node_score(&g, &node_of, &alloc, costs);
        assert!(swaps > 0, "no swaps on a scrambled assignment");
        assert!(after < before, "{after} !< {before}");
        // Swaps preserve balance.
        let mut sizes = [0usize; 4];
        for &x in &node_of {
            sizes[x as usize] += 1;
        }
        assert_eq!(sizes, [4, 4, 4, 4]);
    }

    #[test]
    fn numa_refine_is_thread_count_invariant() {
        use crate::machine::NumaNodeCosts;
        let g = stencil_graph(&[6, 6], false, 2.0);
        let torus = Torus::torus(&[3, 3]);
        let routers: Vec<u32> = (0..9).collect();
        let start: Vec<u32> = (0..36).map(|t| (t % 9) as u32).collect();
        let costs = NumaNodeCosts {
            hop: 1.0,
            socket: 0.3,
        };
        let mut seq = start.clone();
        min_volume_refine_numa(
            &g,
            &mut seq,
            &routers,
            &torus,
            4,
            Parallelism::sequential(),
            costs,
        );
        for threads in [2, 8] {
            let mut par_assign = start.clone();
            min_volume_refine_numa(
                &g,
                &mut par_assign,
                &routers,
                &torus,
                4,
                Parallelism::threads(threads).with_grain(1),
                costs,
            );
            assert_eq!(par_assign, seq, "threads={threads}");
        }
    }

    #[test]
    fn blended_refine_reduces_blended_objective() {
        // Routed congestion x NUMA: the unified loop must strictly lower
        // the blended value on a scrambled assignment and preserve
        // balance — the combination the pre-evaluator arms rejected.
        use crate::machine::NumaNodeCosts;
        use crate::objective::{build_eval, IncrementalEval};
        let g = stencil_graph(&[16], false, 2.0);
        let torus = Torus::torus(&[4]);
        let routers: Vec<u32> = vec![0, 1, 2, 3];
        let spec = EvalSpec::new(
            ObjectiveKind::MaxLinkLoad,
            Some(NumaNodeCosts {
                hop: 1.0,
                socket: 0.4,
            }),
        );
        let mut node_of: Vec<u32> = (0..16).map(|t| (t % 4) as u32).collect();
        let before = build_eval(&torus, &routers, &g, &node_of, spec).value();
        let swaps = min_volume_refine_eval(
            &g,
            &mut node_of,
            &routers,
            &torus,
            8,
            Parallelism::sequential(),
            spec,
        );
        let after = build_eval(&torus, &routers, &g, &node_of, spec).value();
        assert!(swaps > 0, "no swaps on a scrambled assignment");
        assert!(after < before, "{after} !< {before}");
        let mut sizes = [0usize; 4];
        for &x in &node_of {
            sizes[x as usize] += 1;
        }
        assert_eq!(sizes, [4, 4, 4, 4]);
    }

    #[test]
    fn blended_refine_is_thread_count_invariant() {
        use crate::machine::NumaNodeCosts;
        let g = stencil_graph(&[6, 6], false, 2.0);
        let torus = Torus::torus(&[3, 3]);
        let routers: Vec<u32> = (0..9).collect();
        let start: Vec<u32> = (0..36).map(|t| (t % 9) as u32).collect();
        for kind in [ObjectiveKind::MaxLinkLoad, ObjectiveKind::CongestionBlend] {
            let spec = EvalSpec::new(
                kind,
                Some(NumaNodeCosts {
                    hop: 1.0,
                    socket: 0.3,
                }),
            );
            let mut seq = start.clone();
            min_volume_refine_eval(
                &g,
                &mut seq,
                &routers,
                &torus,
                4,
                Parallelism::sequential(),
                spec,
            );
            for threads in [2, 8] {
                let mut par_assign = start.clone();
                min_volume_refine_eval(
                    &g,
                    &mut par_assign,
                    &routers,
                    &torus,
                    4,
                    Parallelism::threads(threads).with_grain(1),
                    spec,
                );
                assert_eq!(par_assign, seq, "{kind:?} threads={threads}");
            }
        }
    }

    #[test]
    fn refine_leaves_optimal_assignment_alone() {
        // Contiguous blocks of a chain on a line of nodes: already optimal.
        let g = stencil_graph(&[16], false, 1.0);
        let torus = Torus::torus(&[4]);
        let routers: Vec<u32> = vec![0, 1, 2, 3];
        let mut node_of: Vec<u32> = (0..16).map(|t| (t / 4) as u32).collect();
        let before = node_of.clone();
        let swaps =
            min_volume_refine(&g, &mut node_of, &routers, &torus, 4, Parallelism::sequential());
        assert_eq!(swaps, 0);
        assert_eq!(node_of, before);
    }
}
