//! MinVolume refinement: greedy boundary swaps on the task→node
//! assignment.
//!
//! The node-level geometric partition minimizes cut volume only implicitly
//! (compact parts have small boundaries); this pass attacks it directly.
//! The default objective is the inter-node **weighted hops** of the
//! assignment — `Σ_e w(e) · hops(node(u), node(v))` over the task graph,
//! which is exactly the Section 3 WeightedHops metric of any mapping that
//! respects the assignment (intra-node edges cost zero, and every rank of
//! a node shares its router). [`min_volume_refine_with`] additionally
//! accepts the routed congestion objectives
//! ([`crate::objective::ObjectiveKind`]): swap gains are then computed
//! against per-link loads through an incrementally-maintained
//! [`crate::objective::CongestionState`] — each candidate swap re-routes
//! only the edges incident to the swapped pair (O(degree · path-length))
//! instead of re-evaluating the assignment. A swap of two tasks in
//! different nodes preserves per-node task counts, so refinement never
//! breaks the balance the bijection relies on.
//!
//! # Determinism
//!
//! Each pass has two phases:
//! 1. **Propose** (parallel over nodes, [`crate::par::map`]): for every
//!    boundary task, find the best swap partner among the tasks of its
//!    neighboring nodes against the *frozen* pass-start assignment.
//!    Proposals are pure functions of that snapshot and land in
//!    index-addressed slots, so they do not depend on the thread budget.
//! 2. **Apply** (sequential): walk proposals in (node, task) order,
//!    re-evaluate each gain against the *current* assignment, and apply it
//!    only if still strictly improving.
//!
//! Both phases are deterministic, so refinement — like every other level
//! of the hierarchical mapper — is bit-identical at every thread count.

use crate::apps::TaskGraph;
use crate::machine::Torus;
use crate::metrics::LinkAccumulator;
use crate::objective::{CongestionState, ObjectiveKind};
use crate::par::{self, Parallelism};

/// Compressed adjacency of the task graph (both directions per edge).
pub(crate) struct Adjacency {
    off: Vec<u32>,
    nbr: Vec<u32>,
    w: Vec<f64>,
}

impl Adjacency {
    pub(crate) fn build(graph: &TaskGraph) -> Adjacency {
        let n = graph.num_tasks;
        let mut deg = vec![0u32; n];
        for e in &graph.edges {
            deg[e.u as usize] += 1;
            deg[e.v as usize] += 1;
        }
        let mut off = vec![0u32; n + 1];
        for t in 0..n {
            off[t + 1] = off[t] + deg[t];
        }
        let total = off[n] as usize;
        let mut nbr = vec![0u32; total];
        let mut w = vec![0f64; total];
        let mut cursor = off.clone();
        for e in &graph.edges {
            let (u, v) = (e.u as usize, e.v as usize);
            nbr[cursor[u] as usize] = e.v;
            w[cursor[u] as usize] = e.w;
            cursor[u] += 1;
            nbr[cursor[v] as usize] = e.u;
            w[cursor[v] as usize] = e.w;
            cursor[v] += 1;
        }
        Adjacency { off, nbr, w }
    }

    #[inline]
    pub(crate) fn neighbors(&self, t: usize) -> impl Iterator<Item = (u32, f64)> + '_ {
        let (lo, hi) = (self.off[t] as usize, self.off[t + 1] as usize);
        self.nbr[lo..hi].iter().copied().zip(self.w[lo..hi].iter().copied())
    }
}

/// Node-pair communication costs: hop distances scaled by `scale`, with a
/// configurable `diag` for same-node pairs (0 in the pure Section 3 model;
/// the flat NUMA socket cost under [`min_volume_refine_numa`]). A dense
/// table while `nn²` stays cheap (the common case — the whole point of the
/// hierarchy is `nn << nranks`), else computed on the fly from the torus.
struct NodeHops<'a> {
    nn: usize,
    table: Option<Vec<f64>>,
    torus: &'a Torus,
    routers: &'a [u32],
    scale: f64,
    diag: f64,
}

/// Largest dense table: 4M entries (32 MB). Beyond that (only the very
/// largest `--full` sweeps) distances are recomputed per lookup.
const MAX_TABLE_ENTRIES: usize = 1 << 22;

impl<'a> NodeHops<'a> {
    fn build_scaled(torus: &'a Torus, routers: &'a [u32], scale: f64, diag: f64) -> NodeHops<'a> {
        let nn = routers.len();
        let table = if nn * nn <= MAX_TABLE_ENTRIES {
            // The fill seeds every diagonal entry with `diag`; only the
            // off-diagonal pairs are overwritten below.
            let mut hops = vec![diag; nn * nn];
            for a in 0..nn {
                for b in (a + 1)..nn {
                    let h = torus.hop_dist_ids(routers[a] as usize, routers[b] as usize) as f64
                        * scale;
                    hops[a * nn + b] = h;
                    hops[b * nn + a] = h;
                }
            }
            Some(hops)
        } else {
            None
        };
        NodeHops {
            nn,
            table,
            torus,
            routers,
            scale,
            diag,
        }
    }

    #[inline]
    fn get(&self, a: u32, b: u32) -> f64 {
        match &self.table {
            Some(t) => t[a as usize * self.nn + b as usize],
            None if a == b => self.diag,
            None => {
                self.torus.hop_dist_ids(
                    self.routers[a as usize] as usize,
                    self.routers[b as usize] as usize,
                ) as f64
                    * self.scale
            }
        }
    }
}

/// One proposed swap, produced by the parallel phase.
#[derive(Clone, Copy, Debug)]
struct Swap {
    u: u32,
    b: u32,
}

/// Cost of placing task `t` on node `x`: Σ over t's edges of
/// `w · hops(x, node(neighbor))`.
#[inline]
fn move_cost(adj: &Adjacency, hops: &NodeHops<'_>, node_of: &[u32], t: usize, x: u32) -> f64 {
    let mut c = 0f64;
    for (n, w) in adj.neighbors(t) {
        c += w * hops.get(x, node_of[n as usize]);
    }
    c
}

/// Gain (strictly positive = improvement) of swapping task `u` (on node
/// `a`) with task `b` (on node `bn`). The `2·w(u,b)·(hops(a,bn) − diag)`
/// correction accounts for a direct edge between the pair, whose cost is
/// unchanged by the swap but double-counted by the two move costs (each
/// move cost prices it once at the cross-node rate and once at the
/// same-node `diag` rate).
fn swap_gain(
    adj: &Adjacency,
    hops: &NodeHops<'_>,
    node_of: &[u32],
    u: usize,
    a: u32,
    b: usize,
    bn: u32,
) -> f64 {
    let mut direct = 0f64;
    for (n, w) in adj.neighbors(u) {
        if n as usize == b {
            direct += w;
        }
    }
    move_cost(adj, hops, node_of, u, a) + move_cost(adj, hops, node_of, b, bn)
        - move_cost(adj, hops, node_of, u, bn)
        - move_cost(adj, hops, node_of, b, a)
        - 2.0 * direct * (hops.get(a, bn) - hops.diag)
}

/// Inter-node weighted hops of an assignment (the refinement objective;
/// exposed for tests and experiment reporting).
pub fn internode_weighted_hops(
    graph: &TaskGraph,
    node_of: &[u32],
    node_routers: &[u32],
    torus: &Torus,
) -> f64 {
    let mut total = 0f64;
    for e in &graph.edges {
        let (a, b) = (node_of[e.u as usize], node_of[e.v as usize]);
        if a != b {
            let h = torus.hop_dist_ids(
                node_routers[a as usize] as usize,
                node_routers[b as usize] as usize,
            ) as f64;
            total += e.w * h;
        }
    }
    total
}

/// Run up to `passes` refinement passes over `node_of` (task→node, modified
/// in place). Returns the number of swaps applied. Deterministic and
/// independent of the thread budget (see the module docs).
pub fn min_volume_refine(
    graph: &TaskGraph,
    node_of: &mut [u32],
    node_routers: &[u32],
    torus: &Torus,
    passes: usize,
    par: Parallelism,
) -> usize {
    refine_hops_impl(graph, node_of, node_routers, torus, passes, par, 1.0, 0.0)
}

/// [`min_volume_refine`] under the NUMA node-level pricing of
/// [`crate::machine::NumaNodeCosts`]: inter-node edges cost `hop` per
/// network hop, intra-node edges the flat `socket` upper bound (the
/// socket-level split runs later). With `hop == 1` and `socket == 0` this
/// is bit-identical to [`min_volume_refine`].
pub fn min_volume_refine_numa(
    graph: &TaskGraph,
    node_of: &mut [u32],
    node_routers: &[u32],
    torus: &Torus,
    passes: usize,
    par: Parallelism,
    costs: crate::machine::NumaNodeCosts,
) -> usize {
    refine_hops_impl(
        graph,
        node_of,
        node_routers,
        torus,
        passes,
        par,
        costs.hop,
        costs.socket,
    )
}

/// Shared hop-priced refinement body: node-pair costs are `scale · hops`
/// off the diagonal and `diag` on it (see [`NodeHops`]).
#[allow(clippy::too_many_arguments)]
fn refine_hops_impl(
    graph: &TaskGraph,
    node_of: &mut [u32],
    node_routers: &[u32],
    torus: &Torus,
    passes: usize,
    par: Parallelism,
    scale: f64,
    diag: f64,
) -> usize {
    assert_eq!(node_of.len(), graph.num_tasks);
    let nn = node_routers.len();
    if nn < 2 || graph.edges.is_empty() {
        return 0;
    }
    let adj = Adjacency::build(graph);
    let hops = NodeHops::build_scaled(torus, node_routers, scale, diag);
    let node_ids: Vec<u32> = (0..nn as u32).collect();
    let mut applied_total = 0usize;
    for _pass in 0..passes {
        // Tasks grouped by node against the pass-start snapshot.
        let mut tasks_by_node: Vec<Vec<u32>> = vec![Vec::new(); nn];
        for (t, &x) in node_of.iter().enumerate() {
            tasks_by_node[x as usize].push(t as u32);
        }
        // Phase 1: propose, in parallel over nodes, against the frozen
        // snapshot. &*node_of reborrows immutably for the scope of the map.
        let snapshot: &[u32] = node_of;
        let proposals: Vec<Vec<Swap>> = par::map(par, &node_ids, |_, &a| {
            let mut out = Vec::new();
            for &u in &tasks_by_node[a as usize] {
                // Candidate target nodes: distinct nodes of u's neighbors,
                // ascending, excluding u's own.
                let mut targets: Vec<u32> = adj
                    .neighbors(u as usize)
                    .map(|(n, _)| snapshot[n as usize])
                    .filter(|&x| x != a)
                    .collect();
                if targets.is_empty() {
                    continue;
                }
                targets.sort_unstable();
                targets.dedup();
                let mut best: Option<(f64, u32)> = None;
                // Hoist the partner-independent halves of the gain:
                // cost(u, a) per boundary task, cost(u, bn) per target
                // node. The summation order below matches `swap_gain`
                // term-for-term, so phase 2's re-check recomputes the
                // exact same f64.
                let cost_u_a = move_cost(&adj, &hops, snapshot, u as usize, a);
                for &bn in &targets {
                    let cost_u_bn = move_cost(&adj, &hops, snapshot, u as usize, bn);
                    let h_ab = hops.get(a, bn);
                    for &b in &tasks_by_node[bn as usize] {
                        let mut direct = 0f64;
                        for (n, w) in adj.neighbors(u as usize) {
                            if n == b {
                                direct += w;
                            }
                        }
                        let g = cost_u_a + move_cost(&adj, &hops, snapshot, b as usize, bn)
                            - cost_u_bn
                            - move_cost(&adj, &hops, snapshot, b as usize, a)
                            - 2.0 * direct * (h_ab - hops.diag);
                        let better = match best {
                            None => g > 0.0,
                            // Strictly-greater gain wins; ties keep the
                            // earlier (smaller) partner index.
                            Some((bg, bb)) => g > bg || (g == bg && b < bb && g > 0.0),
                        };
                        if better && g > 0.0 {
                            best = Some((g, b));
                        }
                    }
                }
                if let Some((_, b)) = best {
                    out.push(Swap { u, b });
                }
            }
            out
        });
        // Phase 2: apply sequentially in (node, task) order, re-checking
        // each gain against the current assignment.
        let mut applied_this_pass = 0usize;
        for Swap { u, b } in proposals.into_iter().flatten() {
            let (a, bn) = (node_of[u as usize], node_of[b as usize]);
            if a == bn {
                continue;
            }
            let g = swap_gain(&adj, &hops, node_of, u as usize, a, b as usize, bn);
            if g > 0.0 {
                node_of[u as usize] = bn;
                node_of[b as usize] = a;
                applied_this_pass += 1;
            }
        }
        applied_total += applied_this_pass;
        if applied_this_pass == 0 {
            break;
        }
    }
    applied_total
}

/// [`min_volume_refine`] under a selectable objective: `WeightedHops`
/// dispatches to the hop-weighted path above; the routed congestion
/// objectives run [`congestion_refine`], whose swap gains are computed
/// against incrementally-maintained per-link loads. Deterministic and
/// independent of the thread budget either way.
#[allow(clippy::too_many_arguments)]
pub fn min_volume_refine_with(
    graph: &TaskGraph,
    node_of: &mut [u32],
    node_routers: &[u32],
    torus: &Torus,
    passes: usize,
    par: Parallelism,
    objective: ObjectiveKind,
) -> usize {
    match objective {
        ObjectiveKind::WeightedHops => {
            min_volume_refine(graph, node_of, node_routers, torus, passes, par)
        }
        kind => congestion_refine(graph, node_of, node_routers, torus, passes, par, kind),
    }
}

/// Greedy boundary swaps against a routed congestion objective.
///
/// Same propose-parallel / apply-sequential structure (and therefore the
/// same thread-count-invariance argument) as the hop-weighted path, but
/// gains come from [`CongestionState::swap_gain`]: the per-link load state
/// is frozen for the parallel proposal phase, each candidate swap re-routes
/// only its incident edges into a per-worker [`LinkAccumulator`] delta, and
/// the sequential apply phase re-checks every proposal against the current
/// state before committing its delta in O(path-length) — no full
/// re-evaluation anywhere.
#[allow(clippy::too_many_arguments)]
fn congestion_refine(
    graph: &TaskGraph,
    node_of: &mut [u32],
    node_routers: &[u32],
    torus: &Torus,
    passes: usize,
    par: Parallelism,
    kind: ObjectiveKind,
) -> usize {
    assert_eq!(node_of.len(), graph.num_tasks);
    let nn = node_routers.len();
    if nn < 2 || graph.edges.is_empty() {
        return 0;
    }
    let adj = Adjacency::build(graph);
    let node_ids: Vec<u32> = (0..nn as u32).collect();
    let mut state = CongestionState::build(torus, node_routers, graph, node_of, kind);
    let mut apply_acc = LinkAccumulator::new(torus);
    let mut applied_total = 0usize;
    for _pass in 0..passes {
        let mut tasks_by_node: Vec<Vec<u32>> = vec![Vec::new(); nn];
        for (t, &x) in node_of.iter().enumerate() {
            tasks_by_node[x as usize].push(t as u32);
        }
        // Phase 1: propose in parallel over nodes against the frozen
        // snapshot (assignment + link-load state). Proposals are pure
        // functions of that snapshot, so they never depend on the budget.
        let snapshot: &[u32] = node_of;
        let state_ref = &state;
        let proposals: Vec<Vec<Swap>> = par::map_with(
            par,
            &node_ids,
            || LinkAccumulator::new(torus),
            |acc, _i, &a| {
                let mut out = Vec::new();
                for &u in &tasks_by_node[a as usize] {
                    let mut targets: Vec<u32> = adj
                        .neighbors(u as usize)
                        .map(|(n, _)| snapshot[n as usize])
                        .filter(|&x| x != a)
                        .collect();
                    if targets.is_empty() {
                        continue;
                    }
                    targets.sort_unstable();
                    targets.dedup();
                    let mut best: Option<(f64, u32)> = None;
                    for &bn in &targets {
                        for &b in &tasks_by_node[bn as usize] {
                            let g = state_ref.swap_gain(
                                snapshot,
                                u as usize,
                                b as usize,
                                adj.neighbors(u as usize),
                                adj.neighbors(b as usize),
                                acc,
                            );
                            let better = match best {
                                None => g > 0.0,
                                // Strictly-greater gain wins; ties keep the
                                // earlier (smaller) partner index.
                                Some((bg, bb)) => g > bg || (g == bg && b < bb && g > 0.0),
                            };
                            if better && g > 0.0 {
                                best = Some((g, b));
                            }
                        }
                    }
                    if let Some((_, b)) = best {
                        out.push(Swap { u, b });
                    }
                }
                out
            },
        );
        // Phase 2: apply sequentially in (node, task) order, re-checking
        // each gain against the current state and committing the re-route
        // delta incrementally.
        let mut applied_this_pass = 0usize;
        for Swap { u, b } in proposals.into_iter().flatten() {
            let (a, bn) = (node_of[u as usize], node_of[b as usize]);
            if a == bn {
                continue;
            }
            let (g, new_max, new_sum) = state.swap_eval(
                node_of,
                u as usize,
                b as usize,
                adj.neighbors(u as usize),
                adj.neighbors(b as usize),
                &mut apply_acc,
            );
            if g > 0.0 {
                state.commit_evaluated(&apply_acc, new_max, new_sum);
                node_of[u as usize] = bn;
                node_of[b as usize] = a;
                applied_this_pass += 1;
            }
        }
        applied_total += applied_this_pass;
        if applied_this_pass == 0 {
            break;
        }
    }
    applied_total
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::stencil::stencil_graph;
    use crate::machine::Torus;

    #[test]
    fn refine_reduces_objective_and_preserves_balance() {
        // 1D chain of 16 tasks, 4 nodes on a 4-ring; scrambled assignment.
        let g = stencil_graph(&[16], false, 1.0);
        let torus = Torus::torus(&[4]);
        let routers: Vec<u32> = vec![0, 1, 2, 3];
        // Stride assignment: maximally non-contiguous.
        let mut node_of: Vec<u32> = (0..16).map(|t| (t % 4) as u32).collect();
        let before = internode_weighted_hops(&g, &node_of, &routers, &torus);
        let swaps =
            min_volume_refine(&g, &mut node_of, &routers, &torus, 8, Parallelism::sequential());
        let after = internode_weighted_hops(&g, &node_of, &routers, &torus);
        assert!(swaps > 0, "no swaps applied on a scrambled assignment");
        assert!(after < before, "objective {after} !< {before}");
        let mut sizes = [0usize; 4];
        for &x in &node_of {
            sizes[x as usize] += 1;
        }
        assert_eq!(sizes, [4, 4, 4, 4]);
    }

    #[test]
    fn refine_is_thread_count_invariant() {
        let g = stencil_graph(&[6, 6], false, 2.0);
        let torus = Torus::torus(&[3, 3]);
        let routers: Vec<u32> = (0..9).collect();
        let start: Vec<u32> = (0..36).map(|t| (t % 9) as u32).collect();
        let mut seq = start.clone();
        min_volume_refine(&g, &mut seq, &routers, &torus, 4, Parallelism::sequential());
        for threads in [2, 8] {
            let mut par_assign = start.clone();
            min_volume_refine(
                &g,
                &mut par_assign,
                &routers,
                &torus,
                4,
                Parallelism::threads(threads).with_grain(1),
            );
            assert_eq!(par_assign, seq, "threads={threads}");
        }
    }

    #[test]
    fn congestion_refine_reduces_its_objective_and_preserves_balance() {
        let g = stencil_graph(&[16], false, 2.0);
        let torus = Torus::torus(&[4]);
        let routers: Vec<u32> = vec![0, 1, 2, 3];
        for kind in [ObjectiveKind::MaxLinkLoad, ObjectiveKind::CongestionBlend] {
            let mut node_of: Vec<u32> = (0..16).map(|t| (t % 4) as u32).collect();
            let before =
                CongestionState::build(&torus, &routers, &g, &node_of, kind).value();
            let swaps = min_volume_refine_with(
                &g,
                &mut node_of,
                &routers,
                &torus,
                8,
                Parallelism::sequential(),
                kind,
            );
            let after = CongestionState::build(&torus, &routers, &g, &node_of, kind).value();
            assert!(swaps > 0, "{kind:?}: no swaps on a scrambled assignment");
            assert!(after < before, "{kind:?}: {after} !< {before}");
            let mut sizes = [0usize; 4];
            for &x in &node_of {
                sizes[x as usize] += 1;
            }
            assert_eq!(sizes, [4, 4, 4, 4], "{kind:?}");
        }
    }

    #[test]
    fn congestion_refine_is_thread_count_invariant() {
        let g = stencil_graph(&[6, 6], false, 2.0);
        let torus = Torus::torus(&[3, 3]);
        let routers: Vec<u32> = (0..9).collect();
        let start: Vec<u32> = (0..36).map(|t| (t % 9) as u32).collect();
        for kind in [ObjectiveKind::MaxLinkLoad, ObjectiveKind::CongestionBlend] {
            let mut seq = start.clone();
            min_volume_refine_with(
                &g,
                &mut seq,
                &routers,
                &torus,
                4,
                Parallelism::sequential(),
                kind,
            );
            for threads in [2, 8] {
                let mut par_assign = start.clone();
                min_volume_refine_with(
                    &g,
                    &mut par_assign,
                    &routers,
                    &torus,
                    4,
                    Parallelism::threads(threads).with_grain(1),
                    kind,
                );
                assert_eq!(par_assign, seq, "{kind:?} threads={threads}");
            }
        }
    }

    #[test]
    fn refine_with_weighted_hops_matches_hop_path() {
        // The dispatching entry point under the default objective must be
        // exactly the hop-weighted refinement.
        let g = stencil_graph(&[6, 6], false, 2.0);
        let torus = Torus::torus(&[3, 3]);
        let routers: Vec<u32> = (0..9).collect();
        let start: Vec<u32> = (0..36).map(|t| (t % 9) as u32).collect();
        let mut direct = start.clone();
        let sd = min_volume_refine(&g, &mut direct, &routers, &torus, 4, Parallelism::sequential());
        let mut via = start.clone();
        let sv = min_volume_refine_with(
            &g,
            &mut via,
            &routers,
            &torus,
            4,
            Parallelism::sequential(),
            ObjectiveKind::WeightedHops,
        );
        assert_eq!((sd, direct), (sv, via));
    }

    #[test]
    fn numa_refine_with_zero_socket_cost_matches_hop_path() {
        // hop = 1, socket = 0 must reproduce the plain hop-weighted
        // refinement bit for bit.
        use crate::machine::NumaNodeCosts;
        let g = stencil_graph(&[6, 6], false, 2.0);
        let torus = Torus::torus(&[3, 3]);
        let routers: Vec<u32> = (0..9).collect();
        let start: Vec<u32> = (0..36).map(|t| (t % 9) as u32).collect();
        let mut plain = start.clone();
        let sp = min_volume_refine(&g, &mut plain, &routers, &torus, 4, Parallelism::sequential());
        let mut numa = start.clone();
        let sn = min_volume_refine_numa(
            &g,
            &mut numa,
            &routers,
            &torus,
            4,
            Parallelism::sequential(),
            NumaNodeCosts {
                hop: 1.0,
                socket: 0.0,
            },
        );
        assert_eq!((sp, plain), (sn, numa));
    }

    #[test]
    fn numa_refine_reduces_node_level_numa_objective() {
        use crate::machine::{Allocation, NumaNodeCosts};
        use crate::mapping::rotations::numa_node_score;
        let g = stencil_graph(&[16], false, 1.0);
        let torus = Torus::torus(&[4]);
        let routers: Vec<u32> = vec![0, 1, 2, 3];
        let costs = NumaNodeCosts {
            hop: 1.0,
            socket: 0.4,
        };
        // Node-level pseudo-allocation to score assignments against.
        let alloc = Allocation {
            torus: torus.clone(),
            core_router: routers.clone(),
            core_node: (0..4u32).collect(),
            ranks_per_node: 1,
        };
        let mut node_of: Vec<u32> = (0..16).map(|t| (t % 4) as u32).collect();
        let before = numa_node_score(&g, &node_of, &alloc, costs);
        let swaps = min_volume_refine_numa(
            &g,
            &mut node_of,
            &routers,
            &torus,
            8,
            Parallelism::sequential(),
            costs,
        );
        let after = numa_node_score(&g, &node_of, &alloc, costs);
        assert!(swaps > 0, "no swaps on a scrambled assignment");
        assert!(after < before, "{after} !< {before}");
        // Swaps preserve balance.
        let mut sizes = [0usize; 4];
        for &x in &node_of {
            sizes[x as usize] += 1;
        }
        assert_eq!(sizes, [4, 4, 4, 4]);
    }

    #[test]
    fn numa_refine_is_thread_count_invariant() {
        use crate::machine::NumaNodeCosts;
        let g = stencil_graph(&[6, 6], false, 2.0);
        let torus = Torus::torus(&[3, 3]);
        let routers: Vec<u32> = (0..9).collect();
        let start: Vec<u32> = (0..36).map(|t| (t % 9) as u32).collect();
        let costs = NumaNodeCosts {
            hop: 1.0,
            socket: 0.3,
        };
        let mut seq = start.clone();
        min_volume_refine_numa(
            &g,
            &mut seq,
            &routers,
            &torus,
            4,
            Parallelism::sequential(),
            costs,
        );
        for threads in [2, 8] {
            let mut par_assign = start.clone();
            min_volume_refine_numa(
                &g,
                &mut par_assign,
                &routers,
                &torus,
                4,
                Parallelism::threads(threads).with_grain(1),
                costs,
            );
            assert_eq!(par_assign, seq, "threads={threads}");
        }
    }

    #[test]
    fn refine_leaves_optimal_assignment_alone() {
        // Contiguous blocks of a chain on a line of nodes: already optimal.
        let g = stencil_graph(&[16], false, 1.0);
        let torus = Torus::torus(&[4]);
        let routers: Vec<u32> = vec![0, 1, 2, 3];
        let mut node_of: Vec<u32> = (0..16).map(|t| (t / 4) as u32).collect();
        let before = node_of.clone();
        let swaps =
            min_volume_refine(&g, &mut node_of, &routers, &torus, 4, Parallelism::sequential());
        assert_eq!(swaps, 0);
        assert_eq!(node_of, before);
    }
}
