//! Hierarchical node→socket→core task mapping: the two- and three-level
//! mapper.
//!
//! The flat mapper (Section 4.2) partitions tasks straight down to ranks,
//! but the paper's own Section 3 model prices intra-node messages at zero —
//! ranks of one node share a router, so placement *within* a node never
//! touches the network. On 16–32 ranks/node machines that is most of every
//! rank's neighbor set, and multi-level node→PE mapping (Schulz & Träff,
//! arXiv:1702.04164; Schulz & Woydt, arXiv:2504.01726) exploits it
//! directly. This subsystem does the geometric version:
//!
//! 1. **Node level** — the MJ rotation sweep runs over **node** coordinates
//!    (one point per node, from [`crate::machine::Allocation::node_coords`];
//!    one pseudo-rank per rank slot on heterogeneous allocations, so every
//!    node receives tasks in proportion to its capacity) instead of rank
//!    coordinates, producing a capacity-balanced task→node assignment:
//!    with `tnum == num_ranks`, every node receives exactly its rank
//!    count. Scoring reuses the WeightedHops kernel against node routers,
//!    which prices intra-node edges at zero by construction — or, through
//!    the unified evaluator ([`crate::objective::eval`]), any other
//!    `objective` × `numa` combination: routed congestion objectives,
//!    NUMA node-level pricing that charges still-unsplit intra-node edges
//!    the flat socket cost, or both blended together.
//! 2. **Node refinement** (the [`IntraNodeStrategy::MinVolume`] strategy) —
//!    greedy boundary-task swaps ([`refine`]) directly minimize the
//!    inter-node weighted communication volume the geometric cut only
//!    bounds implicitly (under the NUMA pricing when configured).
//! 3. **Socket level** (depth 3, only with [`MapSpec::numa`]) — inside
//!    each node, a sized geometric bisection ([`socket::split_sockets`])
//!    cuts the node's tasks across its NUMA domains, `MinVolume` runs a
//!    cross-socket swap refinement ([`socket::refine_sockets`]) on the
//!    exact incremental [`crate::objective::placement_swap_gain`], and
//!    tasks keep the per-rank balance of the two-level mapper.
//! 4. **Core level** — each node's (or, at depth 3, each socket's) tasks
//!    are placed on its ranks by the pluggable [`IntraNodeStrategy`]:
//!    platform order, or a Hilbert-curve order over the task coordinates
//!    (cheap cache locality; network metrics are unaffected by
//!    construction).
//!
//! With [`MapSpec::coarsen`] set, the node level runs as a **multilevel
//! V-cycle** ([`crate::coarsen`]): matched task pairs collapse into
//! supertasks (summed weights, weight-averaged coordinates) until the
//! graph fits the size budget — never below the node count, so the coarse
//! solve stays count-balanced — the rotation sweep + refinement solve the
//! coarsest instance, and the assignment projects back level by level
//! with a deterministic count rebalance at the finest level and bounded
//! `MinVolume` refinement at every level. Million-task graphs reach the
//! sweep as a few thousand supertasks; the per-level refinement closes
//! the quality gap to the direct sweep. Ineligible inputs (heterogeneous
//! allocations, edgeless graphs, graphs already within the budget) fall
//! back to the direct path and say so via a `coarsen.skipped` instant.
//!
//! # The contract
//!
//! For any input where `tnum == alloc.num_ranks()`, [`map_hierarchical`]
//! returns a **bijection** task→rank that respects the node assignment:
//! `alloc.core_node[rank(t)] == task_to_node[t]` for every task — and, at
//! depth 3, the socket assignment: the rank's position-derived socket
//! ([`crate::machine::NumaTopology::socket_of_ranks`]) equals
//! `task_to_socket[t]`. With `tnum > num_ranks` tasks are distributed
//! round-robin over their node's (socket's) ranks; with `tnum < num_nodes`
//! a compact node subset is selected (Section 4.2 case 3) and the
//! remaining nodes idle.
//!
//! # Parallelism and determinism
//!
//! Every level runs through the [`crate::par`] budget — the node-level
//! sweep fans candidates out exactly like the flat sweep (reusing
//! `MjScratch`/`MappingScratch`/`ScoreScratch` arenas per worker), both
//! refinements propose swaps in parallel over nodes, the socket split and
//! the core-level placement map over nodes with per-worker scratch. All of
//! it is index-addressed, so the full hierarchical mapping — at depth 2
//! and depth 3 — is **bit-identical to the sequential path at every
//! thread count** (pinned by property tests in `tests/properties.rs`).

pub mod refine;
pub mod socket;

use crate::apps::TaskGraph;
use crate::coarsen::{self, CoarsenConfig};
use crate::geom::Coords;
use crate::machine::{Allocation, NumaTopology, Topology};
use crate::mapping::rotations::{rotation_sweep_cached, SweepCache, SweepConfig, WhopsBackend};
use crate::mapping::shift::shift_torus_coords;
use crate::mapping::{MapConfig, MapSpec};
use crate::objective::{build_eval, Adjacency, EvalSpec, IncrementalEval, ObjectiveKind};
use crate::par::{self, Deadline, DeadlineExceeded, Parallelism};
use crate::sfc::hilbert::hilbert_sort_f64_subset_into;

/// How each node's tasks are placed on its ranks (and, for `MinVolume`,
/// how the node assignment itself is polished first).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum IntraNodeStrategy {
    /// Tasks in index order onto ranks in the platform's default order.
    DefaultOrder,
    /// Tasks ordered along the Hilbert curve over their coordinates, then
    /// onto ranks in order — consecutive ranks get curve-adjacent tasks.
    SfcOrder,
    /// [`refine::min_volume_refine`] boundary swaps on the node assignment
    /// (up to `passes` passes), then default-order placement within nodes.
    MinVolume {
        passes: usize,
    },
}

impl IntraNodeStrategy {
    pub fn name(&self) -> &'static str {
        match self {
            IntraNodeStrategy::DefaultOrder => "default",
            IntraNodeStrategy::SfcOrder => "sfc",
            IntraNodeStrategy::MinVolume { .. } => "minvol",
        }
    }

    /// Parse a strategy name (the service protocol and CLI use these).
    pub fn parse(s: &str) -> Option<IntraNodeStrategy> {
        match s.to_ascii_lowercase().as_str() {
            "default" => Some(IntraNodeStrategy::DefaultOrder),
            "sfc" => Some(IntraNodeStrategy::SfcOrder),
            "minvol" | "minvolume" => Some(IntraNodeStrategy::MinVolume { passes: 4 }),
            _ => None,
        }
    }
}

/// Hierarchical mapper configuration.
#[derive(Clone, Debug)]
pub struct HierConfig {
    /// MJ configuration for the node-level partition (both sides).
    pub node_map: MapConfig,
    /// Intra-node placement strategy.
    pub intra: IntraNodeStrategy,
    /// Torus wraparound shift of the node coordinates before partitioning.
    pub shift: bool,
    /// Node-coordinate dimensions to ignore while partitioning ("+E").
    pub drop_node_dims: Vec<usize>,
    /// Node-level rotation-sweep candidate cap (1 = identity rotation).
    pub max_rotations: usize,
    /// Edge-chunk size for sweep scoring (see [`SweepConfig`]).
    pub chunk_edges: usize,
    /// The shared knobs ([`MapSpec`]): what the node-level sweep and
    /// `MinVolume` refinement optimize (`objective` × `numa` — a set
    /// `numa` switches the mapper to **depth 3**, with the socket-level
    /// split and refinement inside each node), the worker-thread budget,
    /// and the optional multilevel coarsening V-cycle in front of the
    /// node-level sweep (ineligible inputs silently take the direct path;
    /// a `coarsen.skipped` obs instant says why).
    pub spec: MapSpec,
}

impl Default for HierConfig {
    fn default() -> Self {
        HierConfig {
            node_map: MapConfig::default(),
            intra: IntraNodeStrategy::MinVolume { passes: 4 },
            shift: true,
            drop_node_dims: vec![],
            max_rotations: 12,
            chunk_edges: 32768,
            spec: MapSpec::default(),
        }
    }
}

impl From<MapSpec> for HierConfig {
    fn from(spec: MapSpec) -> Self {
        HierConfig {
            spec,
            ..Default::default()
        }
    }
}

impl HierConfig {
    fn parallelism(&self) -> Parallelism {
        self.spec.parallelism()
    }
}

/// Result of a hierarchical mapping.
#[derive(Clone, Debug)]
pub struct HierMapping {
    /// Final task→rank assignment.
    pub task_to_rank: Vec<u32>,
    /// Task→node assignment (post-refinement).
    pub task_to_node: Vec<u32>,
    /// Within-node socket of every task (depth 3 only; `None` without
    /// [`HierConfig::numa`]).
    pub task_to_socket: Option<Vec<u32>>,
    /// Objective value of the chosen node-level sweep candidate, **before**
    /// refinement — inter-node WeightedHops (the sweep's own
    /// f32-accumulated score) under the default objective, otherwise the
    /// composed evaluator's score for the configured `objective` × `numa`
    /// combination. On the V-cycle path this is the sweep winner's score
    /// on the **coarsest** graph (the only instance the sweep saw).
    pub node_score: f64,
    /// Node-boundary swaps applied by `MinVolume` refinement (0 otherwise).
    /// On the V-cycle path, the sum over every uncoarsening level plus the
    /// coarsest-level refinement.
    pub swaps_applied: usize,
    /// Cross-socket swaps applied by the depth-3 socket refinement.
    pub socket_swaps: usize,
    /// Supertask count per coarsening level (finest to coarsest) when the
    /// mapping took the V-cycle path; empty on the direct path (no
    /// [`HierConfig::coarsen`], ineligible input, or a graph already
    /// within the size budget).
    pub coarsen_levels: Vec<usize>,
}

/// Prepare the node coordinates per the config: optional torus shift, then
/// axis dropping. (Node-level partitioning always works on raw embedding
/// coordinates — bandwidth scaling and the box transform are rank-level
/// concerns of the flat pipeline. The wraparound shift consumes torus
/// geometry and is skipped on non-torus machines.)
pub fn prepare_node_coords(alloc: &Allocation, cfg: &HierConfig) -> Coords {
    let mut ncoords = alloc.node_coords();
    if cfg.shift {
        if let Some(torus) = alloc.machine.as_torus() {
            shift_torus_coords(&mut ncoords, &torus.sizes, &torus.wrap);
        }
    }
    if !cfg.drop_node_dims.is_empty() {
        let keep: Vec<usize> = (0..ncoords.dim())
            .filter(|d| !cfg.drop_node_dims.contains(d))
            .collect();
        ncoords = ncoords.select_axes(&keep);
    }
    ncoords
}

/// The node-level allocation the sweep partitions and scores against. On
/// uniform allocations: one pseudo-rank per node, placed on the node's
/// router, so scoring computes exactly the inter-node objective of the
/// induced task→node assignment. On heterogeneous allocations: one
/// pseudo-rank per **rank slot** (still grouped per node), so the balanced
/// MJ split hands each node tasks in proportion to its capacity — MJ's
/// deterministic tie-breaking keeps a node's duplicate coordinates in one
/// part, exactly like the flat mapper's shared-router rank coordinates.
fn node_level_alloc(alloc: &Allocation) -> Allocation {
    let node_routers = alloc.node_routers();
    let sizes = alloc.node_sizes();
    if sizes.iter().all(|&s| s == alloc.ranks_per_node) {
        let nn = node_routers.len();
        return Allocation {
            machine: alloc.machine.clone(),
            core_router: node_routers,
            core_node: (0..nn as u32).collect(),
            ranks_per_node: 1,
        };
    }
    let total: usize = sizes.iter().sum();
    let mut core_router = Vec::with_capacity(total);
    let mut core_node = Vec::with_capacity(total);
    for (n, &size) in sizes.iter().enumerate() {
        for _ in 0..size {
            core_router.push(node_routers[n]);
            core_node.push(n as u32);
        }
    }
    Allocation {
        machine: alloc.machine.clone(),
        core_router,
        core_node,
        ranks_per_node: alloc.ranks_per_node,
    }
}

/// Expand per-node coordinates to per-pseudo-rank coordinates when the
/// node-level allocation carries more than one pseudo-rank per node
/// (heterogeneous allocations).
fn expand_node_coords(ncoords: &Coords, node_alloc: &Allocation) -> Coords {
    let dim = ncoords.dim();
    let mut axes = vec![Vec::with_capacity(node_alloc.num_ranks()); dim];
    for &n in &node_alloc.core_node {
        for (d, axis) in axes.iter_mut().enumerate() {
            axis.push(ncoords.get(d, n as usize));
        }
    }
    Coords::from_axes(axes)
}

/// Run the two-level mapper. `tcoords` are the task coordinates handed to
/// the node-level partition (HOMME passes its cube projection here, like
/// the flat pipeline); scoring always uses the true router coordinates
/// from `alloc`.
pub fn map_hierarchical(
    graph: &TaskGraph,
    tcoords: &Coords,
    alloc: &Allocation,
    cfg: &HierConfig,
    backend: &dyn WhopsBackend,
) -> HierMapping {
    map_hierarchical_budgeted(graph, tcoords, alloc, cfg, backend, Deadline::unlimited())
        .expect("unlimited deadline never expires")
}

/// [`map_hierarchical`] with a cooperative compute budget: the deadline is
/// checked at every phase boundary (before the node-level sweep, before
/// `MinVolume` refinement, before the depth-3 socket phase, and before rank
/// placement), so a pathological request stops at the next boundary instead
/// of running unbounded. `Err` names the phase that ran out of budget; the
/// mapping service turns it into a structured `deadline_exceeded` error.
/// With [`Deadline::unlimited`] this is exactly `map_hierarchical`.
pub fn map_hierarchical_budgeted(
    graph: &TaskGraph,
    tcoords: &Coords,
    alloc: &Allocation,
    cfg: &HierConfig,
    backend: &dyn WhopsBackend,
    deadline: Deadline,
) -> Result<HierMapping, DeadlineExceeded> {
    let shared = HierShared::new(alloc, cfg);
    map_hierarchical_shared(graph, tcoords, alloc, &shared, cfg, backend, deadline)
}

/// Allocation-derived state shared across the hier pipeline — and, through
/// [`map_hierarchical_batch`], across several graphs mapped onto the same
/// allocation: the node-level allocation, node router ids, prepared node
/// coordinates, and a cross-sweep [`SweepCache`] of proc-side partitions.
/// Everything here is a pure function of `(alloc, cfg)` (partitions
/// additionally of the per-graph task count, which is part of the cache
/// key), so sharing it across jobs can never change a mapping bit.
struct HierShared {
    node_alloc: Allocation,
    node_routers: Vec<u32>,
    ncoords: Coords,
    sweep_cache: SweepCache,
}

impl HierShared {
    fn new(alloc: &Allocation, cfg: &HierConfig) -> HierShared {
        let node_alloc = node_level_alloc(alloc);
        let node_routers = alloc.node_routers();
        let mut ncoords = prepare_node_coords(alloc, cfg);
        if node_alloc.num_ranks() != ncoords.len() {
            // Heterogeneous: one coordinate row per pseudo-rank slot.
            ncoords = expand_node_coords(&ncoords, &node_alloc);
        }
        HierShared {
            node_alloc,
            node_routers,
            ncoords,
            sweep_cache: SweepCache::new(),
        }
    }
}

/// One job of [`map_hierarchical_batch`]: a task graph (whose `coords` are
/// the partitioning coordinates), the same coordinates as a [`Coords`]
/// view, and the per-request compute budget.
pub struct HierJob<'a> {
    pub graph: &'a TaskGraph,
    pub tcoords: &'a Coords,
    pub deadline: Deadline,
}

/// Map several task graphs onto the *same* allocation with the *same*
/// config, sharing the allocation-derived state ([`HierShared`]) and the
/// proc-side partition memo across jobs — the service's batching stage
/// fans compatible small requests through this. Each job's mapping is
/// **bit-identical** to a solo [`map_hierarchical_budgeted`] call: the
/// shared state is a pure function of `(alloc, cfg)` and cached proc
/// partitions are pure functions of `(alloc, cfg, task count,
/// permutation)`, so amortization is routing, not approximation. Jobs run
/// in order; each result carries its own deadline verdict.
pub fn map_hierarchical_batch(
    jobs: &[HierJob<'_>],
    alloc: &Allocation,
    cfg: &HierConfig,
    backend: &dyn WhopsBackend,
) -> Vec<Result<HierMapping, DeadlineExceeded>> {
    let shared = HierShared::new(alloc, cfg);
    jobs.iter()
        .map(|j| {
            map_hierarchical_shared(j.graph, j.tcoords, alloc, &shared, cfg, backend, j.deadline)
        })
        .collect()
}

/// The pipeline body behind [`map_hierarchical_budgeted`] and
/// [`map_hierarchical_batch`], running against caller-built [`HierShared`]
/// state.
fn map_hierarchical_shared(
    graph: &TaskGraph,
    tcoords: &Coords,
    alloc: &Allocation,
    shared: &HierShared,
    cfg: &HierConfig,
    backend: &dyn WhopsBackend,
    deadline: Deadline,
) -> Result<HierMapping, DeadlineExceeded> {
    assert_eq!(tcoords.len(), graph.num_tasks);
    let spec = cfg.spec.eval_spec();
    if let Err(e) = spec.validate() {
        panic!("unsupported objective x numa combination: {e}");
    }
    let par = cfg.parallelism();
    let node_alloc = &shared.node_alloc;
    let node_routers = &shared.node_routers;
    let ncoords = &shared.ncoords;

    // Level 1: the task→node assignment — the direct rotation sweep (+
    // MinVolume refinement), or, with `cfg.coarsen` on an eligible input,
    // the multilevel V-cycle. Ineligible inputs emit a `coarsen.skipped`
    // instant (reason 1 = heterogeneous allocation, 2 = edgeless graph,
    // 3 = graph already within the size budget) and take the direct path.
    let mut vres = None;
    if let Some(ccfg) = cfg.spec.coarsen {
        if node_alloc.num_ranks() != alloc.num_nodes() {
            crate::obs::instant("coarsen.skipped", &[("reason", 1.0)]);
        } else if graph.edges.is_empty() {
            crate::obs::instant("coarsen.skipped", &[("reason", 2.0)]);
        } else {
            vres = vcycle_assign(
                graph,
                tcoords,
                ncoords,
                node_alloc,
                node_routers,
                alloc,
                ccfg,
                cfg,
                spec,
                par,
                backend,
                &shared.sweep_cache,
                deadline,
            )?;
        }
    }
    let (task_to_node, node_score, swaps_applied, coarsen_levels) = match vres {
        Some((node_of, score, swaps, levels)) => (node_of, score, swaps, levels),
        None => {
            let (node_of, score, swaps) = sweep_assign(
                graph,
                tcoords,
                ncoords,
                node_alloc,
                node_routers,
                &alloc.machine,
                cfg,
                spec,
                par,
                backend,
                &shared.sweep_cache,
                deadline,
            )?;
            (node_of, score, swaps, Vec::new())
        }
    };

    if let Some(topo) = cfg.spec.numa {
        // Level 2 (depth 3): sized geometric socket split inside each
        // node, cross-socket MinVolume refinement, then socket-aware rank
        // placement — all parallel over nodes.
        deadline.check("hier.socket")?;
        let mut socket_span = crate::obs::span("hier.socket");
        let mut task_to_socket = socket::split_sockets(tcoords, &task_to_node, alloc, &topo, par);
        let socket_swaps = match cfg.intra {
            IntraNodeStrategy::MinVolume { passes } => socket::refine_sockets(
                graph,
                &task_to_node,
                &mut task_to_socket,
                &topo,
                passes,
                par,
            ),
            _ => 0,
        };
        socket_span.record("socket_swaps", socket_swaps as f64);
        drop(socket_span);
        deadline.check("hier.place")?;
        let place_span = crate::obs::span("hier.place");
        let task_to_rank = socket::place_within_sockets(
            tcoords,
            &task_to_node,
            &task_to_socket,
            alloc,
            &topo,
            cfg.intra,
            par,
        );
        drop(place_span);
        return Ok(HierMapping {
            task_to_rank,
            task_to_node,
            task_to_socket: Some(task_to_socket),
            node_score,
            swaps_applied,
            socket_swaps,
            coarsen_levels,
        });
    }

    // Level 2 (depth 2): place each node's tasks on its ranks, in parallel
    // over nodes with per-worker Hilbert scratch.
    deadline.check("hier.place")?;
    let place_span = crate::obs::span("hier.place");
    let task_to_rank = place_within_nodes(tcoords, &task_to_node, alloc, cfg.intra, par);
    drop(place_span);
    Ok(HierMapping {
        task_to_rank,
        task_to_node,
        task_to_socket: None,
        node_score,
        swaps_applied,
        socket_swaps: 0,
        coarsen_levels,
    })
}

/// The factored-out direct path: one rotation sweep over node coordinates
/// ("hier.sweep" phase/span — its "ranks" are nodes, or per-node rank
/// slots on heterogeneous allocations, so the winning mapping induces the
/// task→node assignment) followed by `MinVolume` boundary refinement
/// ("hier.refine") against the same composed evaluator the sweep scored
/// with. Returns `(task_to_node, sweep winner's score, swaps applied)`.
/// The V-cycle calls this on the coarsest graph; the direct path calls it
/// on the input graph.
#[allow(clippy::too_many_arguments)]
fn sweep_assign(
    graph: &TaskGraph,
    tcoords: &Coords,
    ncoords: &Coords,
    node_alloc: &Allocation,
    node_routers: &[u32],
    net: &dyn Topology,
    cfg: &HierConfig,
    spec: EvalSpec,
    par: Parallelism,
    backend: &dyn WhopsBackend,
    cache: &SweepCache,
    deadline: Deadline,
) -> Result<(Vec<u32>, f64, usize), DeadlineExceeded> {
    let sweep_cfg = SweepConfig {
        max_candidates: cfg.max_rotations.max(1),
        chunk_edges: cfg.chunk_edges,
        spec: cfg.spec,
    };
    deadline.check("hier.sweep")?;
    let mut sweep_span = crate::obs::span("hier.sweep");
    let sweep = rotation_sweep_cached(
        graph,
        tcoords,
        ncoords,
        node_alloc,
        &cfg.node_map,
        &sweep_cfg,
        backend,
        cache,
    );
    let node_score = sweep.scores[sweep.chosen];
    sweep_span.record("node_score", node_score);
    sweep_span.record("candidates", sweep.scores.len() as f64);
    drop(sweep_span);
    let mut task_to_node: Vec<u32> = sweep
        .task_to_rank
        .iter()
        .map(|&r| node_alloc.core_node[r as usize])
        .collect();

    // MinVolume boundary refinement, against the same composed evaluator
    // the sweep scored with — hop-weighted volume by default, routed
    // per-link loads for the congestion objectives, the socket-cost NUMA
    // term layered on either at depth 3.
    deadline.check("hier.refine")?;
    let mut refine_span = crate::obs::span("hier.refine");
    let swaps_applied = match cfg.intra {
        IntraNodeStrategy::MinVolume { passes } => refine::min_volume_refine_eval(
            graph,
            &mut task_to_node,
            node_routers,
            net,
            passes,
            par,
            spec,
        ),
        _ => 0,
    };
    refine_span.record("swaps", swaps_applied as f64);
    drop(refine_span);
    Ok((task_to_node, node_score, swaps_applied))
}

/// Refinement pass budget per uncoarsening level when the intra-node
/// strategy is not `MinVolume`: the V-cycle always refines on the way up
/// (that is what closes the quality gap to the direct sweep), just with a
/// small bounded budget.
const DEFAULT_UNCOARSEN_PASSES: usize = 2;

/// The multilevel V-cycle: coarsen the task graph ([`crate::coarsen`],
/// "coarsen.build" deadline phase), solve the coarsest instance with
/// [`sweep_assign`], then uncoarsen level by level — exact projection, a
/// deterministic count rebalance at the finest level, and bounded
/// `MinVolume` refinement per level ("uncoarsen.refine" phase; one span
/// per level with `level`, `tasks`, `edges`, `moves`, `swaps`, and — when
/// recording — `gain` fields). Returns `None` when coarsening produced no
/// level (graph already within budget, or nothing to contract): the
/// caller falls back to the direct path.
#[allow(clippy::too_many_arguments)]
fn vcycle_assign(
    graph: &TaskGraph,
    tcoords: &Coords,
    ncoords: &Coords,
    node_alloc: &Allocation,
    node_routers: &[u32],
    alloc: &Allocation,
    ccfg: CoarsenConfig,
    cfg: &HierConfig,
    spec: EvalSpec,
    par: Parallelism,
    backend: &dyn WhopsBackend,
    cache: &SweepCache,
    deadline: Deadline,
) -> Result<Option<(Vec<u32>, f64, usize, Vec<usize>)>, DeadlineExceeded> {
    let nn = alloc.num_nodes();
    deadline.check("coarsen.build")?;
    // Never coarsen below the node count: the coarse solve must stay in
    // the count-balanced regime of the sweep (supertasks >= nodes).
    let eff = CoarsenConfig {
        target_tasks: ccfg.target_tasks.max(nn),
        ..ccfg
    };
    let hierarchy = coarsen::coarsen(graph.num_tasks, &graph.edges, tcoords, eff, par);
    if hierarchy.num_levels() == 0 {
        crate::obs::instant("coarsen.skipped", &[("reason", 3.0)]);
        return Ok(None);
    }
    let level_tasks = hierarchy.level_tasks();
    let coarsest = hierarchy.coarsest().expect("non-empty hierarchy");
    let (coarse_nodes, node_score, mut swaps) = sweep_assign(
        &coarsest.graph,
        &coarsest.graph.coords,
        ncoords,
        node_alloc,
        node_routers,
        &alloc.machine,
        cfg,
        spec,
        par,
        backend,
        cache,
        deadline,
    )?;

    let passes = match cfg.intra {
        IntraNodeStrategy::MinVolume { passes } => passes,
        _ => DEFAULT_UNCOARSEN_PASSES,
    };
    let mut node_of = coarse_nodes;
    for level in (0..hierarchy.num_levels()).rev() {
        let mut fine = hierarchy.project_step(level, &node_of);
        let fg: &TaskGraph = if level == 0 {
            graph
        } else {
            &hierarchy.levels[level - 1].graph
        };
        deadline.check("uncoarsen.refine")?;
        let mut sp = crate::obs::span("uncoarsen.refine");
        // Projection preserves per-node *supertask* counts, not task
        // counts: at the finest level, repair the drift before refinement
        // so rank placement sees the exact count-balanced distribution.
        let moves = if level == 0 {
            rebalance_counts(graph, &mut fine, nn)
        } else {
            0
        };
        let before = if sp.live() {
            Some(build_eval(&alloc.machine, node_routers, fg, &fine, spec).value())
        } else {
            None
        };
        let applied = refine::min_volume_refine_eval(
            fg,
            &mut fine,
            node_routers,
            &alloc.machine,
            passes,
            par,
            spec,
        );
        sp.record("level", level as f64);
        sp.record("tasks", fg.num_tasks as f64);
        sp.record("edges", fg.edges.len() as f64);
        sp.record("moves", moves as f64);
        sp.record("swaps", applied as f64);
        if let Some(b) = before {
            let after = build_eval(&alloc.machine, node_routers, fg, &fine, spec).value();
            sp.record("gain", b - after);
        }
        drop(sp);
        swaps += applied;
        node_of = fine;
    }
    Ok(Some((node_of, node_score, swaps, level_tasks)))
}

/// Restore the exact count-balanced per-node task counts at the finest
/// level of the V-cycle: node `n` must hold exactly
/// `(n + 1) * tnum / nn - n * tnum / nn` tasks — the same distribution the
/// direct sweep produces — before swap-preserving refinement and rank
/// placement run (the bijection contract depends on it). Deterministic
/// and sequential: overfull nodes drain in ascending node id, evicting
/// their most weakly attached tasks first (least intra-node adjacency
/// weight, ties by smallest task id; attachment measured once per node)
/// into the underfull node holding the most adjacency weight for the task
/// (ties by smallest node id; a task with no underfull neighbor node goes
/// to the smallest-id underfull node). Returns the number of moved tasks.
fn rebalance_counts(graph: &TaskGraph, node_of: &mut [u32], nn: usize) -> usize {
    let tnum = node_of.len();
    let target = |n: usize| (n + 1) * tnum / nn - n * tnum / nn;
    let mut counts = vec![0usize; nn];
    for &n in node_of.iter() {
        counts[n as usize] += 1;
    }
    if (0..nn).all(|n| counts[n] == target(n)) {
        return 0;
    }
    let adj = Adjacency::build(graph);
    let mut tasks_by_node: Vec<Vec<u32>> = vec![Vec::new(); nn];
    for (t, &x) in node_of.iter().enumerate() {
        tasks_by_node[x as usize].push(t as u32);
    }
    // Smallest-id underfull node, advanced monotonically: underfull nodes
    // only ever gain tasks and overfull nodes never drain below target,
    // so no node to the cursor's left becomes underfull again.
    let mut cursor = 0usize;
    let mut moves = 0usize;
    // Scratch for per-destination adjacency weight, cleared sparsely.
    let mut node_w = vec![0f64; nn];
    let mut touched: Vec<u32> = Vec::new();
    for n in 0..nn {
        if counts[n] <= target(n) {
            continue;
        }
        // Tasks can only have left `n` via this loop (receivers are
        // always underfull), so the bucket is still exact here.
        let mut residents: Vec<(f64, u32)> = tasks_by_node[n]
            .iter()
            .map(|&t| {
                let w: f64 = adj
                    .neighbors(t as usize)
                    .filter(|&(v, _)| node_of[v as usize] as usize == n)
                    .map(|(_, w)| w)
                    .sum();
                (w, t)
            })
            .collect();
        residents.sort_unstable_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
        let mut evict = residents.into_iter();
        while counts[n] > target(n) {
            let (_, t) = evict.next().expect("overfull node ran out of tasks");
            for (v, w) in adj.neighbors(t as usize) {
                let d = node_of[v as usize] as usize;
                if d != n && counts[d] < target(d) {
                    node_w[d] += w;
                    touched.push(d as u32);
                }
            }
            let mut best: Option<(f64, usize)> = None;
            for &du in &touched {
                let d = du as usize;
                let wins = match best {
                    None => true,
                    Some((bw, bd)) => match node_w[d].total_cmp(&bw) {
                        std::cmp::Ordering::Greater => true,
                        std::cmp::Ordering::Equal => d < bd,
                        std::cmp::Ordering::Less => false,
                    },
                };
                if wins {
                    best = Some((node_w[d], d));
                }
            }
            for &du in &touched {
                node_w[du as usize] = 0.0;
            }
            touched.clear();
            let dest = match best {
                Some((_, d)) => d,
                None => {
                    while counts[cursor] >= target(cursor) {
                        cursor += 1;
                    }
                    cursor
                }
            };
            node_of[t as usize] = dest as u32;
            counts[n] -= 1;
            counts[dest] += 1;
            moves += 1;
        }
    }
    moves
}

/// Level 2: intra-node placement. Tasks of node `n` (ascending task index)
/// are ordered by the strategy and assigned round-robin to the node's
/// ranks (ascending rank index). Parallel over nodes; index-addressed, so
/// the result is identical at every thread count.
pub fn place_within_nodes(
    tcoords: &Coords,
    task_to_node: &[u32],
    alloc: &Allocation,
    strategy: IntraNodeStrategy,
    par: Parallelism,
) -> Vec<u32> {
    let nn = alloc.num_nodes();
    let ranks_by_node = alloc.ranks_by_node();
    let mut tasks_by_node: Vec<Vec<u32>> = vec![Vec::new(); nn];
    for (t, &n) in task_to_node.iter().enumerate() {
        tasks_by_node[n as usize].push(t as u32);
    }
    if strategy == IntraNodeStrategy::SfcOrder {
        // Hilbert resolution: enough bits to separate distinct coordinates
        // without overflowing the 128-bit index (same policy as the Hilbert
        // partition path in `mapping`). Only SfcOrder reorders within a
        // node; the other strategies keep task-index order and skip the
        // fan-out entirely.
        let bits = (128 / tcoords.dim().max(1)).min(16) as u32;
        let node_ids: Vec<u32> = (0..nn as u32).collect();
        let sorted: Vec<Vec<u32>> = par::map_with(
            par,
            &node_ids,
            Vec::new,
            |keys: &mut Vec<(u128, u32)>, _i, &n| {
                let mut tasks = tasks_by_node[n as usize].clone();
                hilbert_sort_f64_subset_into(tcoords, &mut tasks, bits, keys);
                tasks
            },
        );
        tasks_by_node = sorted;
    }
    let mut task_to_rank = vec![0u32; task_to_node.len()];
    for (n, tasks) in tasks_by_node.iter().enumerate() {
        let ranks = &ranks_by_node[n];
        if tasks.is_empty() {
            continue;
        }
        assert!(!ranks.is_empty(), "node {n} has tasks but no ranks");
        for (k, &t) in tasks.iter().enumerate() {
            task_to_rank[t as usize] = ranks[k % ranks.len()];
        }
    }
    task_to_rank
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::stencil::stencil_graph;
    use crate::machine::{SparseAllocator, Torus};
    use crate::mapping::rotations::NativeBackend;
    use crate::metrics::eval_hops;

    fn toy_alloc() -> Allocation {
        SparseAllocator {
            machine: Torus::torus(&[6, 6, 6]),
            nodes_per_router: 2,
            ranks_per_node: 8,
            occupancy: 0.3,
        }
        .allocate(16, 5) // 128 ranks
    }

    fn cfg(intra: IntraNodeStrategy) -> HierConfig {
        HierConfig {
            intra,
            max_rotations: 4,
            spec: MapSpec {
                threads: 1,
                ..MapSpec::default()
            },
            ..HierConfig::default()
        }
    }

    fn with_numa(mut c: HierConfig, topo: NumaTopology) -> HierConfig {
        c.spec.numa = Some(topo);
        c
    }

    #[test]
    fn all_strategies_produce_node_respecting_bijections() {
        let alloc = toy_alloc();
        let g = stencil_graph(&[8, 4, 4], false, 1.0); // 128 tasks
        for intra in [
            IntraNodeStrategy::DefaultOrder,
            IntraNodeStrategy::SfcOrder,
            IntraNodeStrategy::MinVolume { passes: 2 },
        ] {
            let m = map_hierarchical(&g, &g.coords, &alloc, &cfg(intra), &NativeBackend);
            let mut s = m.task_to_rank.clone();
            s.sort_unstable();
            assert_eq!(s, (0..128u32).collect::<Vec<_>>(), "{intra:?}");
            // The rank-level mapping must respect the node assignment.
            for t in 0..128 {
                assert_eq!(
                    alloc.core_node[m.task_to_rank[t] as usize],
                    m.task_to_node[t],
                    "{intra:?}: task {t}"
                );
            }
        }
    }

    #[test]
    fn routed_objective_runs_end_to_end_and_improves_bottleneck() {
        // Under MaxLinkLoad the whole two-level mapper (sweep + MinVolume)
        // optimizes the routed bottleneck: still a node-respecting
        // bijection, and no worse on max link latency than the same
        // pipeline under WeightedHops.
        use crate::metrics::eval_full;
        let alloc = toy_alloc();
        let g = stencil_graph(&[8, 4, 4], false, 1.0);
        let mk = |objective| {
            let mut c = cfg(IntraNodeStrategy::MinVolume { passes: 4 });
            c.spec.objective = objective;
            c
        };
        let mll = map_hierarchical(
            &g,
            &g.coords,
            &alloc,
            &mk(ObjectiveKind::MaxLinkLoad),
            &NativeBackend,
        );
        let mut s = mll.task_to_rank.clone();
        s.sort_unstable();
        assert_eq!(s, (0..128u32).collect::<Vec<_>>());
        for t in 0..128 {
            assert_eq!(
                alloc.core_node[mll.task_to_rank[t] as usize],
                mll.task_to_node[t]
            );
        }
        // `node_score` is the sweep winner's max link latency; refinement
        // under MaxLinkLoad applies only strictly-improving swaps, so the
        // final mapping's bottleneck (intra-node placement is
        // network-invisible) can only be at or below it.
        let final_lat = eval_full(&g, &mll.task_to_rank, &alloc)
            .link
            .unwrap()
            .max_latency;
        assert!(
            final_lat <= mll.node_score * (1.0 + 1e-9) + 1e-12,
            "refinement worsened MaxLinkLoad: {final_lat} > {}",
            mll.node_score
        );
    }

    #[test]
    fn node_assignment_is_balanced() {
        let alloc = toy_alloc();
        let g = stencil_graph(&[8, 4, 4], false, 1.0);
        let m = map_hierarchical(
            &g,
            &g.coords,
            &alloc,
            &cfg(IntraNodeStrategy::DefaultOrder),
            &NativeBackend,
        );
        let mut sizes = vec![0usize; alloc.num_nodes()];
        for &n in &m.task_to_node {
            sizes[n as usize] += 1;
        }
        assert!(sizes.iter().all(|&s| s == 8), "{sizes:?}");
    }

    #[test]
    fn minvolume_never_worse_than_default_on_internode_whops() {
        // Refinement applies only strictly-improving swaps on exactly this
        // objective, starting from the same node-level sweep result.
        let alloc = toy_alloc();
        let g = stencil_graph(&[8, 4, 4], false, 1.0);
        let dflt = map_hierarchical(
            &g,
            &g.coords,
            &alloc,
            &cfg(IntraNodeStrategy::DefaultOrder),
            &NativeBackend,
        );
        let minv = map_hierarchical(
            &g,
            &g.coords,
            &alloc,
            &cfg(IntraNodeStrategy::MinVolume { passes: 4 }),
            &NativeBackend,
        );
        let wh = |m: &HierMapping| eval_hops(&g, &m.task_to_rank, &alloc).weighted_hops;
        let (wd, wm) = (wh(&dflt), wh(&minv));
        assert!(wm <= wd * (1.0 + 1e-9) + 1e-9, "minvol {wm} > default {wd}");
    }

    #[test]
    fn intra_node_placement_does_not_change_network_metrics() {
        // SfcOrder permutes only within nodes, so hop metrics must equal
        // DefaultOrder's exactly.
        let alloc = toy_alloc();
        let g = stencil_graph(&[8, 4, 4], false, 1.0);
        let dflt = map_hierarchical(
            &g,
            &g.coords,
            &alloc,
            &cfg(IntraNodeStrategy::DefaultOrder),
            &NativeBackend,
        );
        let sfc = map_hierarchical(
            &g,
            &g.coords,
            &alloc,
            &cfg(IntraNodeStrategy::SfcOrder),
            &NativeBackend,
        );
        assert_eq!(dflt.task_to_node, sfc.task_to_node);
        let (md, ms) = (
            eval_hops(&g, &dflt.task_to_rank, &alloc),
            eval_hops(&g, &sfc.task_to_rank, &alloc),
        );
        assert_eq!(md.total_hops, ms.total_hops);
        assert_eq!(md.weighted_hops, ms.weighted_hops);
        assert_eq!(md.total_messages, ms.total_messages);
    }

    #[test]
    fn more_tasks_than_ranks_round_robins_within_nodes() {
        let alloc = toy_alloc(); // 128 ranks, 16 nodes
        let g = stencil_graph(&[8, 8, 4], false, 1.0); // 256 tasks
        let m = map_hierarchical(
            &g,
            &g.coords,
            &alloc,
            &cfg(IntraNodeStrategy::DefaultOrder),
            &NativeBackend,
        );
        let mut loads = vec![0usize; 128];
        for &r in &m.task_to_rank {
            loads[r as usize] += 1;
        }
        assert!(loads.iter().all(|&l| l == 2), "{loads:?}");
    }

    #[test]
    fn fewer_tasks_than_nodes_uses_subset() {
        let alloc = toy_alloc(); // 16 nodes
        let g = stencil_graph(&[2, 4], false, 1.0); // 8 tasks
        let m = map_hierarchical(
            &g,
            &g.coords,
            &alloc,
            &cfg(IntraNodeStrategy::DefaultOrder),
            &NativeBackend,
        );
        let mut nodes_used: Vec<u32> = m.task_to_node.clone();
        nodes_used.sort_unstable();
        nodes_used.dedup();
        assert_eq!(nodes_used.len(), 8);
    }

    #[test]
    fn depth3_respects_node_and_socket_assignments() {
        let alloc = toy_alloc(); // 16 nodes x 8 ranks
        let g = stencil_graph(&[8, 4, 4], false, 1.0); // 128 tasks
        let topo = NumaTopology::new(2, 4, 0.5, 0.0, 1.0);
        let rank_socks = topo.socket_of_ranks(&alloc);
        for intra in [
            IntraNodeStrategy::DefaultOrder,
            IntraNodeStrategy::SfcOrder,
            IntraNodeStrategy::MinVolume { passes: 2 },
        ] {
            let hcfg = with_numa(cfg(intra), topo);
            let m = map_hierarchical(&g, &g.coords, &alloc, &hcfg, &NativeBackend);
            let mut s = m.task_to_rank.clone();
            s.sort_unstable();
            assert_eq!(s, (0..128u32).collect::<Vec<_>>(), "{intra:?}");
            let socks = m.task_to_socket.as_ref().expect("depth 3 reports sockets");
            let mut per_socket = vec![0usize; alloc.num_nodes() * 2];
            for t in 0..128 {
                let rank = m.task_to_rank[t] as usize;
                assert_eq!(alloc.core_node[rank], m.task_to_node[t], "{intra:?}: task {t}");
                assert_eq!(rank_socks[rank], socks[t], "{intra:?}: task {t}");
                per_socket[m.task_to_node[t] as usize * 2 + socks[t] as usize] += 1;
            }
            // 8 tasks per node, 2 sockets x 4 ranks: 4 tasks per socket.
            assert!(per_socket.iter().all(|&c| c == 4), "{intra:?}: {per_socket:?}");
        }
    }

    #[test]
    fn depth3_breakdown_matches_eval_numa() {
        use crate::objective::eval_numa;
        let alloc = toy_alloc();
        let g = stencil_graph(&[8, 4, 4], false, 1.0);
        let topo = NumaTopology::new(2, 4, 0.5, 0.125, 1.0);
        let hcfg = with_numa(cfg(IntraNodeStrategy::MinVolume { passes: 4 }), topo);
        let m = map_hierarchical(&g, &g.coords, &alloc, &hcfg, &NativeBackend);
        let socks = m.task_to_socket.as_ref().unwrap();
        // Recompute the per-level weights from the assignment arrays; the
        // mapping's eval_numa breakdown must agree exactly.
        let routers = alloc.node_routers();
        let (mut network, mut cross, mut same) = (0f64, 0f64, 0f64);
        for e in &g.edges {
            let (u, v) = (e.u as usize, e.v as usize);
            if m.task_to_node[u] != m.task_to_node[v] {
                network += e.w
                    * alloc.machine.hop_dist_ids(
                        routers[m.task_to_node[u] as usize] as usize,
                        routers[m.task_to_node[v] as usize] as usize,
                    ) as f64;
            } else if socks[u] != socks[v] {
                cross += e.w;
            } else {
                same += e.w;
            }
        }
        let nm = eval_numa(&g, &m.task_to_rank, &alloc, &topo);
        assert_eq!(nm.network_weighted_hops, network);
        assert_eq!(nm.socket_weight, cross);
        assert_eq!(nm.core_weight, same);
    }

    #[test]
    fn blended_depth3_runs_end_to_end_and_respects_assignments() {
        // Routed congestion x NUMA: the full three-level pipeline — node
        // sweep + blended MinVolume refinement + socket split/refinement —
        // must still produce a node- and socket-respecting bijection, and
        // refinement must not worsen the blended objective relative to
        // the sweep winner.
        use crate::objective::{build_eval, IncrementalEval};
        let alloc = toy_alloc(); // 16 nodes x 8 ranks
        let g = stencil_graph(&[8, 4, 4], false, 1.0); // 128 tasks
        let topo = NumaTopology::new(2, 4, 0.5, 0.0, 1.0);
        let rank_socks = topo.socket_of_ranks(&alloc);
        for objective in [ObjectiveKind::MaxLinkLoad, ObjectiveKind::CongestionBlend] {
            let mut hcfg = with_numa(cfg(IntraNodeStrategy::MinVolume { passes: 4 }), topo);
            hcfg.spec.objective = objective;
            let m = map_hierarchical(&g, &g.coords, &alloc, &hcfg, &NativeBackend);
            let mut s = m.task_to_rank.clone();
            s.sort_unstable();
            assert_eq!(s, (0..128u32).collect::<Vec<_>>(), "{objective:?}");
            let socks = m.task_to_socket.as_ref().expect("depth 3 reports sockets");
            for t in 0..128 {
                let rank = m.task_to_rank[t] as usize;
                assert_eq!(alloc.core_node[rank], m.task_to_node[t], "{objective:?}: task {t}");
                assert_eq!(rank_socks[rank], socks[t], "{objective:?}: task {t}");
            }
            // The refined node assignment's blended value is at or below
            // the sweep winner's (refinement applies only strictly
            // improving swaps on exactly this evaluator).
            let spec = EvalSpec::new(objective, Some(topo.node_level_costs()));
            let routers = alloc.node_routers();
            let val = build_eval(&alloc.machine, &routers, &g, &m.task_to_node, spec).value();
            assert!(
                val <= m.node_score * (1.0 + 1e-9) + 1e-12,
                "{objective:?}: refinement worsened the blended value: {val} > {}",
                m.node_score
            );
        }
    }

    #[test]
    fn single_socket_topology_reduces_to_depth2() {
        // One socket and zero socket cost (the BG/Q node model scaled to
        // this allocation): depth 3 must reproduce the two-level mapping
        // exactly. Identity rotation only, so the f64 NUMA sweep scoring
        // cannot re-rank candidates against the f32 kernel path.
        let alloc = toy_alloc(); // 8 ranks/node
        let g = stencil_graph(&[8, 4, 4], false, 1.0);
        let topo = NumaTopology::new(1, 8, 0.0, 0.0, 1.0);
        for intra in [
            IntraNodeStrategy::DefaultOrder,
            IntraNodeStrategy::SfcOrder,
            IntraNodeStrategy::MinVolume { passes: 3 },
        ] {
            let mut base = cfg(intra);
            base.max_rotations = 1;
            let d2 = map_hierarchical(&g, &g.coords, &alloc, &base, &NativeBackend);
            let d3cfg = with_numa(base.clone(), topo);
            let d3 = map_hierarchical(&g, &g.coords, &alloc, &d3cfg, &NativeBackend);
            assert_eq!(d3.task_to_node, d2.task_to_node, "{intra:?}");
            assert_eq!(d3.task_to_rank, d2.task_to_rank, "{intra:?}");
            assert_eq!(d3.swaps_applied, d2.swaps_applied, "{intra:?}");
            assert_eq!(d3.socket_swaps, 0, "{intra:?}");
        }
    }

    #[test]
    fn heterogeneous_allocation_gets_capacity_balanced_nodes() {
        // 4 nodes of sizes 8/4/2/2 on a 4-ring: with tnum == num_ranks,
        // every node must receive exactly its rank count, and the mapping
        // stays a bijection through depth 3.
        let alloc = Allocation::heterogeneous(
            Torus::torus(&[4]),
            &[0, 1, 2, 3],
            &[8, 4, 2, 2],
        )
        .unwrap();
        let g = stencil_graph(&[16], false, 1.0);
        let topo = NumaTopology::new(2, 2, 0.5, 0.0, 1.0);
        let hcfg = with_numa(cfg(IntraNodeStrategy::MinVolume { passes: 2 }), topo);
        let m = map_hierarchical(&g, &g.coords, &alloc, &hcfg, &NativeBackend);
        let mut s = m.task_to_rank.clone();
        s.sort_unstable();
        assert_eq!(s, (0..16u32).collect::<Vec<_>>());
        let mut per_node = vec![0usize; 4];
        for &n in &m.task_to_node {
            per_node[n as usize] += 1;
        }
        assert_eq!(per_node, vec![8, 4, 2, 2]);
        // Socket respect holds on heterogeneous nodes too (clamped
        // positions land in the last socket).
        let rank_socks = topo.socket_of_ranks(&alloc);
        let socks = m.task_to_socket.as_ref().unwrap();
        for t in 0..16 {
            assert_eq!(rank_socks[m.task_to_rank[t] as usize], socks[t], "task {t}");
        }
    }

    #[test]
    fn budgeted_mapper_stops_at_first_phase_when_expired() {
        let alloc = toy_alloc();
        let g = stencil_graph(&[8, 4, 4], false, 1.0);
        let err = map_hierarchical_budgeted(
            &g,
            &g.coords,
            &alloc,
            &cfg(IntraNodeStrategy::MinVolume { passes: 2 }),
            &NativeBackend,
            Deadline::within(std::time::Duration::ZERO),
        )
        .unwrap_err();
        assert_eq!(err.phase, "hier.sweep");
    }

    #[test]
    fn budgeted_mapper_with_unlimited_deadline_matches_unbudgeted() {
        let alloc = toy_alloc();
        let g = stencil_graph(&[8, 4, 4], false, 1.0);
        let base = cfg(IntraNodeStrategy::MinVolume { passes: 2 });
        let a = map_hierarchical(&g, &g.coords, &alloc, &base, &NativeBackend);
        let b = map_hierarchical_budgeted(
            &g,
            &g.coords,
            &alloc,
            &base,
            &NativeBackend,
            Deadline::unlimited(),
        )
        .unwrap();
        assert_eq!(a.task_to_rank, b.task_to_rank);
        assert_eq!(a.task_to_node, b.task_to_node);
        assert_eq!(a.swaps_applied, b.swaps_applied);
    }

    #[test]
    fn captured_trace_covers_all_phases_without_changing_mapping() {
        use crate::obs::{self, EventKind};
        let alloc = toy_alloc();
        let g = stencil_graph(&[8, 4, 4], false, 1.0);
        let topo = NumaTopology::new(2, 4, 0.5, 0.0, 1.0);
        let hcfg = with_numa(cfg(IntraNodeStrategy::MinVolume { passes: 2 }), topo);
        let baseline = map_hierarchical(&g, &g.coords, &alloc, &hcfg, &NativeBackend);
        let (traced, events) =
            obs::capture(|| map_hierarchical(&g, &g.coords, &alloc, &hcfg, &NativeBackend));
        assert_eq!(traced.task_to_rank, baseline.task_to_rank);
        assert_eq!(traced.task_to_node, baseline.task_to_node);
        let end = |name: &'static str| -> obs::Event {
            events
                .iter()
                .find(|e| e.kind == EventKind::End && e.name == name)
                .cloned()
                .unwrap_or_else(|| panic!("missing End event for {name}"))
        };
        let field = |e: &obs::Event, k: &str| {
            e.fields
                .iter()
                .find(|(n, _)| *n == k)
                .map(|(_, v)| *v)
                .unwrap_or_else(|| panic!("{}: missing field {k}", e.name))
        };
        let sweep = end("hier.sweep");
        assert_eq!(field(&sweep, "node_score"), baseline.node_score);
        assert_eq!(field(&sweep, "candidates"), 4.0);
        let refine = end("hier.refine");
        assert_eq!(field(&refine, "swaps"), baseline.swaps_applied as f64);
        let socket = end("hier.socket");
        assert_eq!(field(&socket, "socket_swaps"), baseline.socket_swaps as f64);
        end("hier.place");
        // Per-candidate sweep instants nest under the sweep span.
        let cands = events
            .iter()
            .filter(|e| e.name == "sweep.candidate")
            .count();
        assert_eq!(cands, 4);
    }

    fn vcfg(target_tasks: usize) -> HierConfig {
        let mut c = cfg(IntraNodeStrategy::MinVolume { passes: 2 });
        c.spec.coarsen = Some(CoarsenConfig {
            target_tasks,
            ..CoarsenConfig::default()
        });
        c
    }

    #[test]
    fn vcycle_produces_node_respecting_balanced_bijection() {
        let alloc = toy_alloc(); // 16 nodes x 8 ranks
        let g = stencil_graph(&[8, 4, 4], false, 1.0); // 128 tasks
        let m = map_hierarchical(&g, &g.coords, &alloc, &vcfg(16), &NativeBackend);
        // 128 tasks with floor max(16, 16 nodes) = 16: a real hierarchy.
        assert!(!m.coarsen_levels.is_empty(), "expected the V-cycle path");
        let mut prev = 128usize;
        for &n in &m.coarsen_levels {
            assert!(n < prev, "level sizes must strictly decrease");
            prev = n;
        }
        assert!(*m.coarsen_levels.last().unwrap() >= 16, "coarsest under floor");
        let mut s = m.task_to_rank.clone();
        s.sort_unstable();
        assert_eq!(s, (0..128u32).collect::<Vec<_>>());
        let mut sizes = vec![0usize; alloc.num_nodes()];
        for t in 0..128 {
            assert_eq!(
                alloc.core_node[m.task_to_rank[t] as usize],
                m.task_to_node[t],
                "task {t}"
            );
            sizes[m.task_to_node[t] as usize] += 1;
        }
        assert!(sizes.iter().all(|&s| s == 8), "{sizes:?}");
    }

    #[test]
    fn vcycle_falls_back_when_graph_already_small() {
        // Default target_tasks (4096) dwarfs 128 tasks: coarsening is a
        // no-op and the result must equal the direct path bit for bit.
        let alloc = toy_alloc();
        let g = stencil_graph(&[8, 4, 4], false, 1.0);
        let base = cfg(IntraNodeStrategy::MinVolume { passes: 2 });
        let direct = map_hierarchical(&g, &g.coords, &alloc, &base, &NativeBackend);
        let mut with_coarsen = base;
        with_coarsen.spec.coarsen = Some(CoarsenConfig::default());
        let v = map_hierarchical(&g, &g.coords, &alloc, &with_coarsen, &NativeBackend);
        assert!(v.coarsen_levels.is_empty());
        assert_eq!(v.task_to_rank, direct.task_to_rank);
        assert_eq!(v.task_to_node, direct.task_to_node);
        assert_eq!(v.node_score, direct.node_score);
        assert_eq!(v.swaps_applied, direct.swaps_applied);
    }

    #[test]
    fn vcycle_skips_heterogeneous_allocations() {
        let alloc = Allocation::heterogeneous(
            Torus::torus(&[4]),
            &[0, 1, 2, 3],
            &[8, 4, 2, 2],
        )
        .unwrap();
        let g = stencil_graph(&[16], false, 1.0);
        let base = cfg(IntraNodeStrategy::MinVolume { passes: 2 });
        let direct = map_hierarchical(&g, &g.coords, &alloc, &base, &NativeBackend);
        let mut coarse = base;
        coarse.spec.coarsen = Some(CoarsenConfig {
            target_tasks: 1,
            ..CoarsenConfig::default()
        });
        let v = map_hierarchical(&g, &g.coords, &alloc, &coarse, &NativeBackend);
        assert!(v.coarsen_levels.is_empty(), "heterogeneous must skip");
        assert_eq!(v.task_to_rank, direct.task_to_rank);
    }

    #[test]
    fn vcycle_depth3_respects_node_and_socket_assignments() {
        let alloc = toy_alloc(); // 16 nodes x 8 ranks
        let g = stencil_graph(&[8, 4, 4], false, 1.0); // 128 tasks
        let topo = NumaTopology::new(2, 4, 0.5, 0.0, 1.0);
        let rank_socks = topo.socket_of_ranks(&alloc);
        let hcfg = with_numa(vcfg(16), topo);
        let m = map_hierarchical(&g, &g.coords, &alloc, &hcfg, &NativeBackend);
        assert!(!m.coarsen_levels.is_empty(), "expected the V-cycle path");
        let mut s = m.task_to_rank.clone();
        s.sort_unstable();
        assert_eq!(s, (0..128u32).collect::<Vec<_>>());
        let socks = m.task_to_socket.as_ref().expect("depth 3 reports sockets");
        let mut per_socket = vec![0usize; alloc.num_nodes() * 2];
        for t in 0..128 {
            let rank = m.task_to_rank[t] as usize;
            assert_eq!(alloc.core_node[rank], m.task_to_node[t], "task {t}");
            assert_eq!(rank_socks[rank], socks[t], "task {t}");
            per_socket[m.task_to_node[t] as usize * 2 + socks[t] as usize] += 1;
        }
        assert!(per_socket.iter().all(|&c| c == 4), "{per_socket:?}");
    }

    #[test]
    fn vcycle_expired_deadline_stops_at_coarsen_build() {
        let alloc = toy_alloc();
        let g = stencil_graph(&[8, 4, 4], false, 1.0);
        let err = map_hierarchical_budgeted(
            &g,
            &g.coords,
            &alloc,
            &vcfg(16),
            &NativeBackend,
            Deadline::within(std::time::Duration::ZERO),
        )
        .unwrap_err();
        assert_eq!(err.phase, "coarsen.build");
    }

    #[test]
    fn vcycle_trace_covers_levels_without_changing_mapping() {
        use crate::obs::{self, EventKind};
        let alloc = toy_alloc();
        let g = stencil_graph(&[8, 4, 4], false, 1.0);
        let hcfg = vcfg(16);
        let baseline = map_hierarchical(&g, &g.coords, &alloc, &hcfg, &NativeBackend);
        assert!(!baseline.coarsen_levels.is_empty());
        let (traced, events) =
            obs::capture(|| map_hierarchical(&g, &g.coords, &alloc, &hcfg, &NativeBackend));
        assert_eq!(traced.task_to_rank, baseline.task_to_rank);
        assert_eq!(traced.task_to_node, baseline.task_to_node);
        let ends = |name: &'static str| -> Vec<&obs::Event> {
            events
                .iter()
                .filter(|e| e.kind == EventKind::End && e.name == name)
                .collect()
        };
        // One coarsen.level (with a nested coarsen.match) per hierarchy
        // level, one uncoarsen.refine per level on the way back up, and
        // the coarsest solve's own sweep + refine spans.
        let nlevels = baseline.coarsen_levels.len();
        assert_eq!(ends("coarsen.level").len(), nlevels);
        assert!(ends("coarsen.match").len() >= nlevels);
        assert_eq!(ends("hier.sweep").len(), 1);
        let refines = ends("uncoarsen.refine");
        assert_eq!(refines.len(), nlevels);
        for e in refines {
            for key in ["level", "tasks", "edges", "moves", "swaps", "gain"] {
                assert!(
                    e.fields.iter().any(|(n, _)| *n == key),
                    "uncoarsen.refine missing field {key}"
                );
            }
        }
    }

    #[test]
    fn rebalance_counts_restores_exact_targets() {
        // A deliberately lopsided assignment over 4 nodes: rebalance must
        // land every node exactly on its count-balanced target while
        // keeping the assignment a function of graph adjacency only.
        let g = stencil_graph(&[16], false, 1.0); // 1D chain, 16 tasks
        let mut node_of: Vec<u32> = (0..16).map(|t| if t < 10 { 0 } else { 3 }).collect();
        let moves = rebalance_counts(&g, &mut node_of, 4);
        assert!(moves > 0);
        let mut counts = vec![0usize; 4];
        for &n in &node_of {
            counts[n as usize] += 1;
        }
        assert_eq!(counts, vec![4, 4, 4, 4]);
        // Determinism: same input, same result.
        let mut again: Vec<u32> = (0..16).map(|t| if t < 10 { 0 } else { 3 }).collect();
        rebalance_counts(&g, &mut again, 4);
        assert_eq!(again, node_of);
    }

    #[test]
    fn strategy_names_round_trip() {
        for s in ["default", "sfc", "minvol"] {
            assert_eq!(IntraNodeStrategy::parse(s).unwrap().name(), s);
        }
        assert!(IntraNodeStrategy::parse("nope").is_none());
    }
}
