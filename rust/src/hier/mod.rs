//! Hierarchical node→core task mapping: the two-level mapper.
//!
//! The flat mapper (Section 4.2) partitions tasks straight down to ranks,
//! but the paper's own Section 3 model prices intra-node messages at zero —
//! ranks of one node share a router, so placement *within* a node never
//! touches the network. On 16–32 ranks/node machines that is most of every
//! rank's neighbor set, and two-level node→PE mapping (Schulz & Träff,
//! arXiv:1702.04164; Schulz & Woydt, arXiv:2504.01726) exploits it
//! directly. This subsystem does the geometric version:
//!
//! 1. **Node level** — the MJ rotation sweep runs over **node** coordinates
//!    (one point per node, from [`crate::machine::Allocation::node_coords`])
//!    instead of rank coordinates, producing a balanced task→node
//!    assignment: with `tnum == num_ranks`, every node receives exactly its
//!    `ranks_per_node` tasks. Scoring reuses the WeightedHops kernel
//!    against node routers, which prices intra-node edges at zero by
//!    construction.
//! 2. **Refinement** (the [`IntraNodeStrategy::MinVolume`] strategy) —
//!    greedy boundary-task swaps ([`refine`]) directly minimize the
//!    inter-node weighted communication volume the geometric cut only
//!    bounds implicitly.
//! 3. **Core level** — each node's tasks are placed on its ranks by the
//!    pluggable [`IntraNodeStrategy`]: platform order, or a Hilbert-curve
//!    order over the node's task coordinates (cheap cache/NUMA locality;
//!    network metrics are unaffected by construction).
//!
//! # The two-level contract
//!
//! For any input where `tnum == alloc.num_ranks()`, [`map_hierarchical`]
//! returns a **bijection** task→rank that respects the node assignment:
//! `alloc.core_node[rank(t)] == task_to_node[t]` for every task. With
//! `tnum > num_ranks` tasks are distributed round-robin over their node's
//! ranks (the flat mapper's convention); with `tnum < num_nodes` a compact
//! node subset is selected (Section 4.2 case 3) and the remaining nodes
//! idle.
//!
//! # Parallelism and determinism
//!
//! Every level runs through the [`crate::par`] budget — the node-level
//! sweep fans candidates out exactly like the flat sweep (reusing
//! `MjScratch`/`MappingScratch`/`ScoreScratch` arenas per worker), the
//! refinement proposes swaps in parallel over nodes, and the core-level
//! placement maps over nodes with per-worker Hilbert key scratch. All
//! three are index-addressed, so the full hierarchical mapping is
//! **bit-identical to the sequential path at every thread count** (pinned
//! by property tests in `tests/properties.rs`).

pub mod refine;

use crate::apps::TaskGraph;
use crate::geom::Coords;
use crate::machine::Allocation;
use crate::mapping::rotations::{rotation_sweep, SweepConfig, WhopsBackend};
use crate::mapping::shift::shift_torus_coords;
use crate::mapping::MapConfig;
use crate::objective::ObjectiveKind;
use crate::par::{self, Parallelism};
use crate::sfc::hilbert::hilbert_sort_f64_subset_into;

/// How each node's tasks are placed on its ranks (and, for `MinVolume`,
/// how the node assignment itself is polished first).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum IntraNodeStrategy {
    /// Tasks in index order onto ranks in the platform's default order.
    DefaultOrder,
    /// Tasks ordered along the Hilbert curve over their coordinates, then
    /// onto ranks in order — consecutive ranks get curve-adjacent tasks.
    SfcOrder,
    /// [`refine::min_volume_refine`] boundary swaps on the node assignment
    /// (up to `passes` passes), then default-order placement within nodes.
    MinVolume {
        passes: usize,
    },
}

impl IntraNodeStrategy {
    pub fn name(&self) -> &'static str {
        match self {
            IntraNodeStrategy::DefaultOrder => "default",
            IntraNodeStrategy::SfcOrder => "sfc",
            IntraNodeStrategy::MinVolume { .. } => "minvol",
        }
    }

    /// Parse a strategy name (the service protocol and CLI use these).
    pub fn parse(s: &str) -> Option<IntraNodeStrategy> {
        match s.to_ascii_lowercase().as_str() {
            "default" => Some(IntraNodeStrategy::DefaultOrder),
            "sfc" => Some(IntraNodeStrategy::SfcOrder),
            "minvol" | "minvolume" => Some(IntraNodeStrategy::MinVolume { passes: 4 }),
            _ => None,
        }
    }
}

/// Hierarchical mapper configuration.
#[derive(Clone, Debug)]
pub struct HierConfig {
    /// MJ configuration for the node-level partition (both sides).
    pub node_map: MapConfig,
    /// Intra-node placement strategy.
    pub intra: IntraNodeStrategy,
    /// Torus wraparound shift of the node coordinates before partitioning.
    pub shift: bool,
    /// Node-coordinate dimensions to ignore while partitioning ("+E").
    pub drop_node_dims: Vec<usize>,
    /// Node-level rotation-sweep candidate cap (1 = identity rotation).
    pub max_rotations: usize,
    /// Edge-chunk size for sweep scoring (see [`SweepConfig`]).
    pub chunk_edges: usize,
    /// Worker threads: `0` = auto, `1` = the sequential reference path.
    /// The mapping is bit-identical at every thread count.
    pub threads: usize,
    /// What the node-level sweep and `MinVolume` refinement optimize:
    /// inter-node WeightedHops (the default), or a routed congestion
    /// objective whose swap gains are computed incrementally against
    /// per-link loads ([`crate::objective::CongestionState`]).
    pub objective: ObjectiveKind,
}

impl Default for HierConfig {
    fn default() -> Self {
        HierConfig {
            node_map: MapConfig::default(),
            intra: IntraNodeStrategy::MinVolume { passes: 4 },
            shift: true,
            drop_node_dims: vec![],
            max_rotations: 12,
            chunk_edges: 32768,
            threads: 0,
            objective: ObjectiveKind::WeightedHops,
        }
    }
}

impl HierConfig {
    fn parallelism(&self) -> Parallelism {
        match self.threads {
            0 => Parallelism::auto(),
            n => Parallelism::threads(n),
        }
    }
}

/// Result of a hierarchical mapping.
#[derive(Clone, Debug)]
pub struct HierMapping {
    /// Final task→rank assignment.
    pub task_to_rank: Vec<u32>,
    /// Task→node assignment (post-refinement).
    pub task_to_node: Vec<u32>,
    /// Objective value ([`HierConfig::objective`]) of the chosen node-level
    /// sweep candidate, **before** refinement — inter-node WeightedHops
    /// (the sweep's own f32-accumulated score) under the default objective.
    pub node_score: f64,
    /// Boundary swaps applied by `MinVolume` refinement (0 otherwise).
    pub swaps_applied: usize,
}

/// Prepare the node coordinates per the config: optional torus shift, then
/// axis dropping. (Node-level partitioning always works on raw router
/// coordinates — bandwidth scaling and the box transform are rank-level
/// concerns of the flat pipeline.)
pub fn prepare_node_coords(alloc: &Allocation, cfg: &HierConfig) -> Coords {
    let mut ncoords = alloc.node_coords();
    if cfg.shift {
        shift_torus_coords(&mut ncoords, &alloc.torus.sizes, &alloc.torus.wrap);
    }
    if !cfg.drop_node_dims.is_empty() {
        let keep: Vec<usize> = (0..ncoords.dim())
            .filter(|d| !cfg.drop_node_dims.contains(d))
            .collect();
        ncoords = ncoords.select_axes(&keep);
    }
    ncoords
}

/// The node-level allocation: one pseudo-rank per node, placed on the
/// node's router. Sweep scoring against it computes exactly the inter-node
/// WeightedHops of the induced task→node assignment.
fn node_level_alloc(alloc: &Allocation) -> Allocation {
    let node_routers = alloc.node_routers();
    let nn = node_routers.len();
    Allocation {
        torus: alloc.torus.clone(),
        core_router: node_routers,
        core_node: (0..nn as u32).collect(),
        ranks_per_node: 1,
    }
}

/// Run the two-level mapper. `tcoords` are the task coordinates handed to
/// the node-level partition (HOMME passes its cube projection here, like
/// the flat pipeline); scoring always uses the true router coordinates
/// from `alloc`.
pub fn map_hierarchical(
    graph: &TaskGraph,
    tcoords: &Coords,
    alloc: &Allocation,
    cfg: &HierConfig,
    backend: &dyn WhopsBackend,
) -> HierMapping {
    assert_eq!(tcoords.len(), graph.num_tasks);
    let par = cfg.parallelism();
    let node_alloc = node_level_alloc(alloc);
    let node_routers = &node_alloc.core_router;
    let ncoords = prepare_node_coords(alloc, cfg);

    // Level 1: the rotation sweep over node coordinates. Its "ranks" are
    // nodes, so the winning mapping *is* the task→node assignment.
    let sweep_cfg = SweepConfig {
        max_candidates: cfg.max_rotations.max(1),
        chunk_edges: cfg.chunk_edges,
        threads: cfg.threads,
        objective: cfg.objective,
    };
    let sweep = rotation_sweep(
        graph,
        tcoords,
        &ncoords,
        &node_alloc,
        &cfg.node_map,
        &sweep_cfg,
        backend,
    );
    let node_score = sweep.scores[sweep.chosen];
    let mut task_to_node = sweep.task_to_rank;

    // Level 1.5: MinVolume boundary refinement, against the configured
    // objective (hop-weighted volume by default; routed per-link loads for
    // the congestion objectives).
    let swaps_applied = match cfg.intra {
        IntraNodeStrategy::MinVolume { passes } => refine::min_volume_refine_with(
            graph,
            &mut task_to_node,
            node_routers,
            &alloc.torus,
            passes,
            par,
            cfg.objective,
        ),
        _ => 0,
    };

    // Level 2: place each node's tasks on its ranks, in parallel over
    // nodes with per-worker Hilbert scratch.
    let task_to_rank = place_within_nodes(tcoords, &task_to_node, alloc, cfg.intra, par);
    HierMapping {
        task_to_rank,
        task_to_node,
        node_score,
        swaps_applied,
    }
}

/// Level 2: intra-node placement. Tasks of node `n` (ascending task index)
/// are ordered by the strategy and assigned round-robin to the node's
/// ranks (ascending rank index). Parallel over nodes; index-addressed, so
/// the result is identical at every thread count.
pub fn place_within_nodes(
    tcoords: &Coords,
    task_to_node: &[u32],
    alloc: &Allocation,
    strategy: IntraNodeStrategy,
    par: Parallelism,
) -> Vec<u32> {
    let nn = alloc.num_nodes();
    let ranks_by_node = alloc.ranks_by_node();
    let mut tasks_by_node: Vec<Vec<u32>> = vec![Vec::new(); nn];
    for (t, &n) in task_to_node.iter().enumerate() {
        tasks_by_node[n as usize].push(t as u32);
    }
    if strategy == IntraNodeStrategy::SfcOrder {
        // Hilbert resolution: enough bits to separate distinct coordinates
        // without overflowing the 128-bit index (same policy as the Hilbert
        // partition path in `mapping`). Only SfcOrder reorders within a
        // node; the other strategies keep task-index order and skip the
        // fan-out entirely.
        let bits = (128 / tcoords.dim().max(1)).min(16) as u32;
        let node_ids: Vec<u32> = (0..nn as u32).collect();
        let sorted: Vec<Vec<u32>> = par::map_with(
            par,
            &node_ids,
            Vec::new,
            |keys: &mut Vec<(u128, u32)>, _i, &n| {
                let mut tasks = tasks_by_node[n as usize].clone();
                hilbert_sort_f64_subset_into(tcoords, &mut tasks, bits, keys);
                tasks
            },
        );
        tasks_by_node = sorted;
    }
    let mut task_to_rank = vec![0u32; task_to_node.len()];
    for (n, tasks) in tasks_by_node.iter().enumerate() {
        let ranks = &ranks_by_node[n];
        if tasks.is_empty() {
            continue;
        }
        assert!(!ranks.is_empty(), "node {n} has tasks but no ranks");
        for (k, &t) in tasks.iter().enumerate() {
            task_to_rank[t as usize] = ranks[k % ranks.len()];
        }
    }
    task_to_rank
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::stencil::stencil_graph;
    use crate::machine::{SparseAllocator, Torus};
    use crate::mapping::rotations::NativeBackend;
    use crate::metrics::eval_hops;

    fn toy_alloc() -> Allocation {
        SparseAllocator {
            machine: Torus::torus(&[6, 6, 6]),
            nodes_per_router: 2,
            ranks_per_node: 8,
            occupancy: 0.3,
        }
        .allocate(16, 5) // 128 ranks
    }

    fn cfg(intra: IntraNodeStrategy) -> HierConfig {
        HierConfig {
            intra,
            max_rotations: 4,
            threads: 1,
            ..HierConfig::default()
        }
    }

    #[test]
    fn all_strategies_produce_node_respecting_bijections() {
        let alloc = toy_alloc();
        let g = stencil_graph(&[8, 4, 4], false, 1.0); // 128 tasks
        for intra in [
            IntraNodeStrategy::DefaultOrder,
            IntraNodeStrategy::SfcOrder,
            IntraNodeStrategy::MinVolume { passes: 2 },
        ] {
            let m = map_hierarchical(&g, &g.coords, &alloc, &cfg(intra), &NativeBackend);
            let mut s = m.task_to_rank.clone();
            s.sort_unstable();
            assert_eq!(s, (0..128u32).collect::<Vec<_>>(), "{intra:?}");
            // The rank-level mapping must respect the node assignment.
            for t in 0..128 {
                assert_eq!(
                    alloc.core_node[m.task_to_rank[t] as usize],
                    m.task_to_node[t],
                    "{intra:?}: task {t}"
                );
            }
        }
    }

    #[test]
    fn routed_objective_runs_end_to_end_and_improves_bottleneck() {
        // Under MaxLinkLoad the whole two-level mapper (sweep + MinVolume)
        // optimizes the routed bottleneck: still a node-respecting
        // bijection, and no worse on max link latency than the same
        // pipeline under WeightedHops.
        use crate::metrics::eval_full;
        let alloc = toy_alloc();
        let g = stencil_graph(&[8, 4, 4], false, 1.0);
        let mk = |objective| HierConfig {
            objective,
            ..cfg(IntraNodeStrategy::MinVolume { passes: 4 })
        };
        let mll = map_hierarchical(
            &g,
            &g.coords,
            &alloc,
            &mk(ObjectiveKind::MaxLinkLoad),
            &NativeBackend,
        );
        let mut s = mll.task_to_rank.clone();
        s.sort_unstable();
        assert_eq!(s, (0..128u32).collect::<Vec<_>>());
        for t in 0..128 {
            assert_eq!(
                alloc.core_node[mll.task_to_rank[t] as usize],
                mll.task_to_node[t]
            );
        }
        // `node_score` is the sweep winner's max link latency; refinement
        // under MaxLinkLoad applies only strictly-improving swaps, so the
        // final mapping's bottleneck (intra-node placement is
        // network-invisible) can only be at or below it.
        let final_lat = eval_full(&g, &mll.task_to_rank, &alloc)
            .link
            .unwrap()
            .max_latency;
        assert!(
            final_lat <= mll.node_score * (1.0 + 1e-9) + 1e-12,
            "refinement worsened MaxLinkLoad: {final_lat} > {}",
            mll.node_score
        );
    }

    #[test]
    fn node_assignment_is_balanced() {
        let alloc = toy_alloc();
        let g = stencil_graph(&[8, 4, 4], false, 1.0);
        let m = map_hierarchical(
            &g,
            &g.coords,
            &alloc,
            &cfg(IntraNodeStrategy::DefaultOrder),
            &NativeBackend,
        );
        let mut sizes = vec![0usize; alloc.num_nodes()];
        for &n in &m.task_to_node {
            sizes[n as usize] += 1;
        }
        assert!(sizes.iter().all(|&s| s == 8), "{sizes:?}");
    }

    #[test]
    fn minvolume_never_worse_than_default_on_internode_whops() {
        // Refinement applies only strictly-improving swaps on exactly this
        // objective, starting from the same node-level sweep result.
        let alloc = toy_alloc();
        let g = stencil_graph(&[8, 4, 4], false, 1.0);
        let dflt = map_hierarchical(
            &g,
            &g.coords,
            &alloc,
            &cfg(IntraNodeStrategy::DefaultOrder),
            &NativeBackend,
        );
        let minv = map_hierarchical(
            &g,
            &g.coords,
            &alloc,
            &cfg(IntraNodeStrategy::MinVolume { passes: 4 }),
            &NativeBackend,
        );
        let wh = |m: &HierMapping| eval_hops(&g, &m.task_to_rank, &alloc).weighted_hops;
        let (wd, wm) = (wh(&dflt), wh(&minv));
        assert!(wm <= wd * (1.0 + 1e-9) + 1e-9, "minvol {wm} > default {wd}");
    }

    #[test]
    fn intra_node_placement_does_not_change_network_metrics() {
        // SfcOrder permutes only within nodes, so hop metrics must equal
        // DefaultOrder's exactly.
        let alloc = toy_alloc();
        let g = stencil_graph(&[8, 4, 4], false, 1.0);
        let dflt = map_hierarchical(
            &g,
            &g.coords,
            &alloc,
            &cfg(IntraNodeStrategy::DefaultOrder),
            &NativeBackend,
        );
        let sfc = map_hierarchical(
            &g,
            &g.coords,
            &alloc,
            &cfg(IntraNodeStrategy::SfcOrder),
            &NativeBackend,
        );
        assert_eq!(dflt.task_to_node, sfc.task_to_node);
        let (md, ms) = (
            eval_hops(&g, &dflt.task_to_rank, &alloc),
            eval_hops(&g, &sfc.task_to_rank, &alloc),
        );
        assert_eq!(md.total_hops, ms.total_hops);
        assert_eq!(md.weighted_hops, ms.weighted_hops);
        assert_eq!(md.total_messages, ms.total_messages);
    }

    #[test]
    fn more_tasks_than_ranks_round_robins_within_nodes() {
        let alloc = toy_alloc(); // 128 ranks, 16 nodes
        let g = stencil_graph(&[8, 8, 4], false, 1.0); // 256 tasks
        let m = map_hierarchical(
            &g,
            &g.coords,
            &alloc,
            &cfg(IntraNodeStrategy::DefaultOrder),
            &NativeBackend,
        );
        let mut loads = vec![0usize; 128];
        for &r in &m.task_to_rank {
            loads[r as usize] += 1;
        }
        assert!(loads.iter().all(|&l| l == 2), "{loads:?}");
    }

    #[test]
    fn fewer_tasks_than_nodes_uses_subset() {
        let alloc = toy_alloc(); // 16 nodes
        let g = stencil_graph(&[2, 4], false, 1.0); // 8 tasks
        let m = map_hierarchical(
            &g,
            &g.coords,
            &alloc,
            &cfg(IntraNodeStrategy::DefaultOrder),
            &NativeBackend,
        );
        let mut nodes_used: Vec<u32> = m.task_to_node.clone();
        nodes_used.sort_unstable();
        nodes_used.dedup();
        assert_eq!(nodes_used.len(), 8);
    }

    #[test]
    fn strategy_names_round_trip() {
        for s in ["default", "sfc", "minvol"] {
            assert_eq!(IntraNodeStrategy::parse(s).unwrap().name(), s);
        }
        assert!(IntraNodeStrategy::parse("nope").is_none());
    }
}
