//! Socket level of the depth-3 hierarchical mapper: geometric splitting of
//! each node's tasks across its NUMA domains, greedy cross-socket
//! refinement, and socket-aware rank placement.
//!
//! Once the node level has fixed `task_to_node`, nothing a socket decision
//! does can touch the network — so the socket level optimizes exactly the
//! remaining terms of the [`crate::objective::NumaAware`] objective: the
//! cross-socket weight (priced at `socket_cost`) against the same-socket
//! weight (`core_cost`). Three passes, all parallel **over nodes** (nodes
//! are independent at this level, so per-node work is sequential and the
//! fan-out is index-addressed — bit-identical at every thread count):
//!
//! 1. [`split_sockets`] — a sized recursive geometric bisection of the
//!    node's tasks over their coordinates (cut along the longest extent,
//!    deterministic `(coordinate, task id)` tie-break), producing socket
//!    groups whose sizes equal the socket's share of the node's balanced
//!    per-rank load — the depth-2 round-robin loads, summed per socket, so
//!    depth-3 placement keeps exactly the per-rank balance of depth 2.
//! 2. [`refine_sockets`] — greedy within-node task swaps between sockets,
//!    accepted only when strictly improving; gains are the exact
//!    incremental [`crate::objective::placement_swap_gain`] specialized to
//!    same-node swaps: `(socket_cost − core_cost) · Δ(cross-socket
//!    weight)`, O(degree) per candidate. This is the blended evaluator's
//!    gain restricted to within-node swaps: such a swap moves no task
//!    between nodes, so the network term — hop-priced *or* routed
//!    per-link loads — is structurally unchanged and only the NUMA term
//!    moves, which is why the same refinement serves the WeightedHops and
//!    routed-congestion depth-3 pipelines alike.
//! 3. [`place_within_sockets`] — each socket's tasks are ordered by the
//!    [`IntraNodeStrategy`] (ascending, or Hilbert-curve order) and dealt
//!    round-robin onto the socket's ranks (positions `k·ranks_per_socket..`
//!    of the node's default rank order, the same assignment
//!    [`NumaTopology::socket_of_ranks`] reports).

use super::IntraNodeStrategy;
use crate::apps::TaskGraph;
use crate::geom::Coords;
use crate::machine::{Allocation, NumaTopology};
use crate::objective::Adjacency;
use crate::par::{self, Parallelism};
use crate::sfc::hilbert::hilbert_sort_f64_subset_into;

/// Task count each socket of a node should receive: the node's balanced
/// per-rank loads (`num_tasks` dealt over `node_ranks` rank slots, earlier
/// slots taking the remainder — the depth-2 round-robin distribution),
/// summed over the socket's slots.
pub fn socket_targets(num_tasks: usize, node_ranks: usize, topo: &NumaTopology) -> Vec<usize> {
    let mut targets = vec![0usize; topo.sockets_per_node];
    if node_ranks == 0 {
        assert_eq!(num_tasks, 0, "tasks on a node with no ranks");
        return targets;
    }
    let base = num_tasks / node_ranks;
    let rem = num_tasks % node_ranks;
    for j in 0..node_ranks {
        targets[topo.socket_of_pos(j)] += base + usize::from(j < rem);
    }
    targets
}

/// Sized recursive geometric bisection: reorder `tasks` so that the first
/// `targets[0]` land in group 0, the next `targets[1]` in group 1, and so
/// on, with every cut taken along the axis of largest extent over the
/// sub-range and broken deterministically by `(coordinate, task id)`.
fn sized_bisect(tcoords: &Coords, tasks: &mut [u32], targets: &[usize]) {
    debug_assert_eq!(targets.iter().sum::<usize>(), tasks.len());
    if targets.len() <= 1 || tasks.len() <= 1 {
        return;
    }
    let mid = targets.len() / 2;
    let left_total: usize = targets[..mid].iter().sum();
    // Cut axis: largest coordinate extent over this sub-range (ties keep
    // the lower axis).
    let mut axis = 0usize;
    let mut best = f64::NEG_INFINITY;
    for d in 0..tcoords.dim() {
        let mut lo = f64::INFINITY;
        let mut hi = f64::NEG_INFINITY;
        for &t in tasks.iter() {
            let v = tcoords.get(d, t as usize);
            lo = lo.min(v);
            hi = hi.max(v);
        }
        if hi - lo > best {
            best = hi - lo;
            axis = d;
        }
    }
    tasks.sort_unstable_by(|&a, &b| {
        let (va, vb) = (tcoords.get(axis, a as usize), tcoords.get(axis, b as usize));
        va.partial_cmp(&vb).expect("finite coordinates").then(a.cmp(&b))
    });
    let (left, right) = tasks.split_at_mut(left_total);
    sized_bisect(tcoords, left, &targets[..mid]);
    sized_bisect(tcoords, right, &targets[mid..]);
}

/// Geometric socket split: within-node socket index per task (the sized
/// bisection of the module docs), parallel over nodes. Node assignments
/// are taken from `task_to_node`; sockets are sized by [`socket_targets`].
pub fn split_sockets(
    tcoords: &Coords,
    task_to_node: &[u32],
    alloc: &Allocation,
    topo: &NumaTopology,
    par: Parallelism,
) -> Vec<u32> {
    let nn = alloc.num_nodes();
    let node_ranks: Vec<usize> = alloc.ranks_by_node().iter().map(Vec::len).collect();
    let mut tasks_by_node: Vec<Vec<u32>> = vec![Vec::new(); nn];
    for (t, &n) in task_to_node.iter().enumerate() {
        tasks_by_node[n as usize].push(t as u32);
    }
    let node_ids: Vec<u32> = (0..nn as u32).collect();
    let split: Vec<(Vec<u32>, Vec<usize>)> = par::map(par, &node_ids, |_, &n| {
        let mut order = tasks_by_node[n as usize].clone();
        let targets = socket_targets(order.len(), node_ranks[n as usize], topo);
        sized_bisect(tcoords, &mut order, &targets);
        (order, targets)
    });
    let mut task_to_socket = vec![0u32; task_to_node.len()];
    for (order, targets) in &split {
        let mut cursor = 0usize;
        for (sock, &take) in targets.iter().enumerate() {
            for &t in &order[cursor..cursor + take] {
                task_to_socket[t as usize] = sock as u32;
            }
            cursor += take;
        }
    }
    task_to_socket
}

/// Greedy cross-socket refinement: up to `passes` passes of within-node
/// task swaps between sockets, each accepted only when it strictly lowers
/// the NUMA objective — gain `(socket_cost − core_cost) · Δ(cross-socket
/// weight)`, computed incrementally over the pair's intra-node edges.
/// Per-socket task counts are preserved (swaps only). Nodes are refined
/// independently in parallel; per-node work is sequential in `(task,
/// partner)` order, so the result is bit-identical at every thread count.
/// Returns the number of swaps applied.
pub fn refine_sockets(
    graph: &TaskGraph,
    task_to_node: &[u32],
    task_to_socket: &mut [u32],
    topo: &NumaTopology,
    passes: usize,
    par: Parallelism,
) -> usize {
    assert_eq!(task_to_node.len(), graph.num_tasks);
    assert_eq!(task_to_socket.len(), graph.num_tasks);
    if topo.sockets_per_node < 2
        || topo.swap_gain_scale() <= 0.0
        || graph.edges.is_empty()
        || passes == 0
    {
        return 0;
    }
    let num_tasks = graph.num_tasks;
    let nn = task_to_node.iter().map(|&n| n as usize + 1).max().unwrap_or(0);
    let mut tasks_by_node: Vec<Vec<u32>> = vec![Vec::new(); nn];
    for (t, &n) in task_to_node.iter().enumerate() {
        tasks_by_node[n as usize].push(t as u32);
    }
    let adj = Adjacency::build(graph);
    let snapshot: &[u32] = task_to_socket;
    let node_ids: Vec<u32> = (0..nn as u32).collect();
    // Per-worker scratch: a global task -> local-index table, initialized
    // once per worker and restored after each node.
    let results: Vec<(Vec<u32>, usize)> = par::map_with(
        par,
        &node_ids,
        || vec![u32::MAX; num_tasks],
        |local_idx, _i, &n| {
            let tasks = &tasks_by_node[n as usize];
            let mut sock: Vec<u32> =
                tasks.iter().map(|&t| snapshot[t as usize]).collect();
            if tasks.len() < 2 {
                return (sock, 0);
            }
            for (i, &t) in tasks.iter().enumerate() {
                local_idx[t as usize] = i as u32;
            }
            // Δ(cross-socket weight) of moving task `li` from socket `from`
            // to `to`, over its intra-node edges, excluding partner `skip`.
            let cross_delta = |sock: &[u32], li: usize, from: u32, to: u32, skip: usize| {
                let mut delta = 0f64;
                for (nb, w) in adj.neighbors(tasks[li] as usize) {
                    let lj = local_idx[nb as usize];
                    if lj == u32::MAX || lj as usize == skip {
                        continue; // other node, or the swap partner
                    }
                    let sn = sock[lj as usize];
                    delta += w * (i32::from(from != sn) - i32::from(to != sn)) as f64;
                }
                delta
            };
            let mut swaps = 0usize;
            for _pass in 0..passes {
                let mut applied = 0usize;
                for i in 0..tasks.len() {
                    let si = sock[i];
                    let mut best: Option<(f64, usize)> = None;
                    for j in 0..tasks.len() {
                        let sj = sock[j];
                        if sj == si {
                            continue;
                        }
                        let delta = cross_delta(&sock, i, si, sj, j)
                            + cross_delta(&sock, j, sj, si, i);
                        let g = topo.swap_gain_scale() * delta;
                        // Partners scan in ascending j, so the first
                        // strictly-best gain also wins equal-gain ties.
                        if g > 0.0 && best.map_or(true, |(bg, _)| g > bg) {
                            best = Some((g, j));
                        }
                    }
                    if let Some((_, j)) = best {
                        sock.swap(i, j);
                        applied += 1;
                    }
                }
                swaps += applied;
                if applied == 0 {
                    break;
                }
            }
            for &t in tasks.iter() {
                local_idx[t as usize] = u32::MAX;
            }
            (sock, swaps)
        },
    );
    let mut total = 0usize;
    for (n, (sock, swaps)) in results.into_iter().enumerate() {
        for (i, &t) in tasks_by_node[n].iter().enumerate() {
            task_to_socket[t as usize] = sock[i];
        }
        total += swaps;
    }
    total
}

/// Socket-aware rank placement: each `(node, socket)` group's tasks are
/// ordered by `strategy` (`SfcOrder` sorts along the Hilbert curve; the
/// other strategies keep ascending task order) and dealt round-robin onto
/// the socket's ranks. Parallel over nodes with per-worker Hilbert
/// scratch; index-addressed, so the result is identical at every thread
/// count.
pub fn place_within_sockets(
    tcoords: &Coords,
    task_to_node: &[u32],
    task_to_socket: &[u32],
    alloc: &Allocation,
    topo: &NumaTopology,
    strategy: IntraNodeStrategy,
    par: Parallelism,
) -> Vec<u32> {
    let nn = alloc.num_nodes();
    let ranks_by_node = alloc.ranks_by_node();
    let mut tasks_by_node: Vec<Vec<u32>> = vec![Vec::new(); nn];
    for (t, &n) in task_to_node.iter().enumerate() {
        tasks_by_node[n as usize].push(t as u32);
    }
    let bits = (128 / tcoords.dim().max(1)).min(16) as u32;
    let sfc = strategy == IntraNodeStrategy::SfcOrder;
    let node_ids: Vec<u32> = (0..nn as u32).collect();
    let placed: Vec<Vec<(u32, u32)>> = par::map_with(
        par,
        &node_ids,
        Vec::new,
        |keys: &mut Vec<(u128, u32)>, _i, &n| {
            let tasks = &tasks_by_node[n as usize];
            let ranks = &ranks_by_node[n as usize];
            let mut out = Vec::with_capacity(tasks.len());
            if tasks.is_empty() {
                return out;
            }
            assert!(!ranks.is_empty(), "node {n} has tasks but no ranks");
            let rps = topo.ranks_per_socket;
            for k in 0..topo.sockets_per_node {
                let mut members: Vec<u32> = tasks
                    .iter()
                    .copied()
                    .filter(|&t| task_to_socket[t as usize] == k as u32)
                    .collect();
                if members.is_empty() {
                    continue;
                }
                let lo = (k * rps).min(ranks.len());
                let hi = if k + 1 == topo.sockets_per_node {
                    ranks.len()
                } else {
                    ((k + 1) * rps).min(ranks.len())
                };
                let socket_ranks = &ranks[lo..hi];
                assert!(
                    !socket_ranks.is_empty(),
                    "socket {k} of node {n} has tasks but no ranks"
                );
                if sfc {
                    hilbert_sort_f64_subset_into(tcoords, &mut members, bits, keys);
                }
                for (q, &t) in members.iter().enumerate() {
                    out.push((t, socket_ranks[q % socket_ranks.len()]));
                }
            }
            out
        },
    );
    let mut task_to_rank = vec![0u32; task_to_node.len()];
    for pairs in placed {
        for (t, r) in pairs {
            task_to_rank[t as usize] = r;
        }
    }
    task_to_rank
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::stencil::stencil_graph;
    use crate::machine::Torus;
    use crate::objective::eval_numa_placement;

    fn topo2x2() -> NumaTopology {
        NumaTopology::new(2, 2, 0.5, 0.0, 1.0)
    }

    #[test]
    fn targets_match_round_robin_loads() {
        let t = topo2x2(); // 2 sockets x 2 ranks
        assert_eq!(socket_targets(4, 4, &t), vec![2, 2]);
        assert_eq!(socket_targets(8, 4, &t), vec![4, 4]);
        // 5 tasks over 4 ranks: slot 0 takes the remainder -> socket 0.
        assert_eq!(socket_targets(5, 4, &t), vec![3, 2]);
        // Heterogeneous node with 3 ranks: socket 1 has one slot.
        assert_eq!(socket_targets(3, 3, &t), vec![2, 1]);
        // No ranks, no tasks.
        assert_eq!(socket_targets(0, 0, &t), vec![0, 0]);
    }

    #[test]
    fn split_separates_geometry() {
        // 8 tasks on a line, one node, 2 sockets of 2 ranks (4 ranks, so
        // 2 tasks per rank): the split must cut the line in half.
        let g = stencil_graph(&[8], false, 1.0);
        let alloc = Allocation::heterogeneous(Torus::torus(&[2]), &[0], &[4]).unwrap();
        let t2 = topo2x2();
        let node_of = vec![0u32; 8];
        let socks = split_sockets(&g.coords, &node_of, &alloc, &t2, Parallelism::sequential());
        assert_eq!(socks[..4], [0, 0, 0, 0]);
        assert_eq!(socks[4..], [1, 1, 1, 1]);
    }

    #[test]
    fn refine_reduces_cross_socket_weight() {
        // Alternating split of a chain: maximally cross-socket. Refinement
        // must recover contiguous halves (or at least strictly improve).
        let g = stencil_graph(&[8], false, 1.0);
        let node_of = vec![0u32; 8];
        let mut socks: Vec<u32> = (0..8).map(|t| (t % 2) as u32).collect();
        let t2 = topo2x2();
        let torus = Torus::torus(&[2]);
        let routers = vec![0u32];
        let before = eval_numa_placement(&g, &node_of, &socks, &routers, &torus, &t2);
        let swaps = refine_sockets(&g, &node_of, &mut socks, &t2, 8, Parallelism::sequential());
        let after = eval_numa_placement(&g, &node_of, &socks, &routers, &torus, &t2);
        assert!(swaps > 0);
        assert!(after.socket_weight < before.socket_weight);
        // Swaps preserve per-socket counts.
        assert_eq!(socks.iter().filter(|&&s| s == 0).count(), 4);
    }

    #[test]
    fn refine_is_thread_count_invariant() {
        let g = stencil_graph(&[6, 6], false, 1.5);
        let t2 = topo2x2();
        // 3 nodes x 12 tasks, scrambled sockets.
        let node_of: Vec<u32> = (0..36).map(|t| (t % 3) as u32).collect();
        let start: Vec<u32> = (0..36).map(|t| ((t / 3) % 2) as u32).collect();
        let mut seq = start.clone();
        refine_sockets(&g, &node_of, &mut seq, &t2, 4, Parallelism::sequential());
        for threads in [2, 8] {
            let mut par_socks = start.clone();
            refine_sockets(
                &g,
                &node_of,
                &mut par_socks,
                &t2,
                4,
                Parallelism::threads(threads).with_grain(1),
            );
            assert_eq!(par_socks, seq, "threads={threads}");
        }
    }

    #[test]
    fn placement_respects_socket_ranges() {
        // One node of 4 ranks (2 sockets x 2): socket-0 tasks must land on
        // the node's first two ranks, socket-1 tasks on the last two.
        let g = stencil_graph(&[8], false, 1.0);
        let alloc = Allocation::heterogeneous(Torus::torus(&[2]), &[0], &[4]).unwrap();
        let t2 = topo2x2();
        let node_of = vec![0u32; 8];
        let socks = split_sockets(&g.coords, &node_of, &alloc, &t2, Parallelism::sequential());
        let map = place_within_sockets(
            &g.coords,
            &node_of,
            &socks,
            &alloc,
            &t2,
            IntraNodeStrategy::DefaultOrder,
            Parallelism::sequential(),
        );
        let rank_socks = t2.socket_of_ranks(&alloc);
        for t in 0..8 {
            assert_eq!(rank_socks[map[t] as usize], socks[t], "task {t}");
        }
        // Round-robin within sockets: every rank takes exactly 2 tasks.
        let mut loads = vec![0usize; 4];
        for &r in &map {
            loads[r as usize] += 1;
        }
        assert_eq!(loads, vec![2, 2, 2, 2]);
    }
}
