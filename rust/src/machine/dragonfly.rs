//! Dragonfly network model (groups of all-to-all routers, all-to-all
//! global links between groups).
//!
//! `Dragonfly::new(groups, routers_per_group, terminals_per_router)` has
//! `groups * routers_per_group` routers, id `g * routers_per_group + r`.
//! Within a group every router pair is directly linked (one local hop);
//! each ordered group pair `(g, h)` has one directed global link, owned by
//! router `h % routers_per_group` of group `g` (the gateway), landing on
//! router `g % routers_per_group` of group `h`.
//!
//! **Distance** is minimal-path with the global hop priced at a
//! configurable integer [`global_cost`](Dragonfly::with_global_cost)
//! (default 2 — global cables are long): `local? + global_cost + local?`.
//!
//! **Routing** is minimal (local → global → local) by default. With
//! [`with_valiant`](Dragonfly::with_valiant) the *routed load* path set
//! detours inter-group traffic through the deterministic intermediate
//! group `(g_src + g_dst) % groups` (one-hop Valiant load spreading);
//! distance pricing stays minimal either way, so hop-based objectives are
//! unaffected and only routed congestion sees the spread paths.
//!
//! **Embedding** (what the geometric sweep partitions): `(group, router)`
//! as two axes, group first. Groups are the dominant locality boundary
//! (crossing one always pays `global_cost`), so cuts separate groups
//! before routers within a group.
//!
//! **Links**: dense index `router * (R + G) + port`; ports `0..R` are local
//! (port = peer router index in the group, the self-port unused), ports
//! `R..R+G` are global (port − R = destination group, the self-group slot
//! unused on non-gateways and for the own group). Class 0 = local,
//! class 1 = global, dir always 0 (dragonfly links have no natural ± pair;
//! the second direction slot stays empty in per-class stats). Bandwidth is
//! uniform 1.0 on both classes.

use super::topology::Topology;

/// Dragonfly: `groups` fully-connected groups of `routers_per_group`
/// routers, one directed global link per ordered group pair.
#[derive(Clone, Debug)]
pub struct Dragonfly {
    groups: usize,
    routers_per_group: usize,
    /// Compute endpoints per router — informational (capacity planning /
    /// service validation); routing and distance are router-level.
    terminals_per_router: usize,
    global_cost: u64,
    valiant: bool,
}

impl Dragonfly {
    pub fn new(groups: usize, routers_per_group: usize, terminals_per_router: usize) -> Dragonfly {
        assert!(groups >= 1, "dragonfly needs at least one group");
        assert!(routers_per_group >= 1, "dragonfly needs at least one router per group");
        assert!(terminals_per_router >= 1, "terminals_per_router must be >= 1");
        groups
            .checked_mul(routers_per_group)
            .and_then(|n| n.checked_mul(routers_per_group + groups))
            .expect("dragonfly size overflow");
        Dragonfly {
            groups,
            routers_per_group,
            terminals_per_router,
            global_cost: 2,
            valiant: false,
        }
    }

    /// Price of the global hop in [`Topology::hop_dist_ids`] (integer,
    /// >= 1; default 2).
    pub fn with_global_cost(mut self, global_cost: u64) -> Dragonfly {
        assert!(global_cost >= 1, "global_cost must be >= 1");
        self.global_cost = global_cost;
        self
    }

    /// Route inter-group load through the deterministic one-hop-Valiant
    /// intermediate group. Affects [`Topology::route_ids`] only, never
    /// distances.
    pub fn with_valiant(mut self, valiant: bool) -> Dragonfly {
        self.valiant = valiant;
        self
    }

    pub fn groups(&self) -> usize {
        self.groups
    }

    pub fn routers_per_group(&self) -> usize {
        self.routers_per_group
    }

    pub fn terminals_per_router(&self) -> usize {
        self.terminals_per_router
    }

    pub fn global_cost(&self) -> u64 {
        self.global_cost
    }

    pub fn valiant(&self) -> bool {
        self.valiant
    }

    /// Ports per router: `routers_per_group` local + `groups` global.
    #[inline]
    fn ports(&self) -> usize {
        self.routers_per_group + self.groups
    }

    #[inline]
    fn id(&self, g: usize, r: usize) -> usize {
        g * self.routers_per_group + r
    }

    #[inline]
    fn split(&self, id: usize) -> (usize, usize) {
        (id / self.routers_per_group, id % self.routers_per_group)
    }

    /// Gateway router (index within `from`) owning the global link
    /// `from -> to`.
    #[inline]
    fn gateway(&self, to: usize) -> usize {
        to % self.routers_per_group
    }

    #[inline]
    fn local_link(&self, id: usize, peer_r: usize) -> usize {
        id * self.ports() + peer_r
    }

    #[inline]
    fn global_link(&self, id: usize, to_group: usize) -> usize {
        id * self.ports() + self.routers_per_group + to_group
    }

    /// Minimal route `a -> b`: local to the gateway, global, local to the
    /// destination — skipping degenerate hops.
    fn route_minimal(&self, a: usize, b: usize, visit: &mut dyn FnMut(usize)) {
        let (g1, r1) = self.split(a);
        let (g2, r2) = self.split(b);
        if g1 == g2 {
            if r1 != r2 {
                visit(self.local_link(a, r2));
            }
            return;
        }
        let gw_out = self.gateway(g2); // gateway in g1 toward g2
        if r1 != gw_out {
            visit(self.local_link(a, gw_out));
        }
        visit(self.global_link(self.id(g1, gw_out), g2));
        let landing = self.gateway(g1); // arrival router in g2
        if landing != r2 {
            visit(self.local_link(self.id(g2, landing), r2));
        }
    }
}

impl Topology for Dragonfly {
    fn num_routers(&self) -> usize {
        self.groups * self.routers_per_group
    }

    fn hop_dist_ids(&self, a: usize, b: usize) -> u64 {
        let (g1, r1) = self.split(a);
        let (g2, r2) = self.split(b);
        if g1 == g2 {
            return u64::from(r1 != r2);
        }
        u64::from(r1 != self.gateway(g2))
            + self.global_cost
            + u64::from(self.gateway(g1) != r2)
    }

    fn num_directed_links(&self) -> usize {
        self.num_routers() * self.ports()
    }

    fn route_ids(&self, a: usize, b: usize, visit: &mut dyn FnMut(usize)) {
        let (g1, _) = self.split(a);
        let (g2, _) = self.split(b);
        if self.valiant && g1 != g2 {
            let vg = (g1 + g2) % self.groups;
            if vg != g1 && vg != g2 {
                // Land the detour on g2's eventual gateway so the second
                // minimal leg starts exactly where the first one ends.
                let v = self.id(vg, self.gateway(g2));
                self.route_minimal(a, v, visit);
                self.route_minimal(v, b, visit);
                return;
            }
        }
        self.route_minimal(a, b, visit);
    }

    fn for_each_link(&self, visit: &mut dyn FnMut(usize, usize, usize, f64)) {
        for id in 0..self.num_routers() {
            let (g, r) = self.split(id);
            for p in 0..self.routers_per_group {
                if p != r {
                    visit(self.local_link(id, p), 0, 0, 1.0);
                }
            }
            for h in 0..self.groups {
                if h != g && r == self.gateway(h) {
                    visit(self.global_link(id, h), 1, 0, 1.0);
                }
            }
        }
    }

    fn num_link_classes(&self) -> usize {
        2
    }

    fn embed_dim(&self) -> usize {
        2
    }

    fn embed_coords(&self, id: usize, out: &mut [f64]) {
        let (g, r) = self.split(id);
        out[0] = g as f64;
        out[1] = r as f64;
    }

    fn coord_dim(&self) -> usize {
        2
    }

    fn router_of_coords(&self, coords: &[usize]) -> Option<usize> {
        match coords {
            [g, r] if *g < self.groups && *r < self.routers_per_group => Some(self.id(*g, *r)),
            _ => None,
        }
    }

    fn kind_name(&self) -> &'static str {
        "dragonfly"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn distances_price_the_global_hop() {
        let d = Dragonfly::new(4, 4, 2); // default global_cost = 2
        // Same router / same group.
        assert_eq!(d.hop_dist_ids(0, 0), 0);
        assert_eq!(d.hop_dist_ids(0, 3), 1);
        // Gateway to gateway: router 1 of g0 owns the link to g1 (1%4),
        // landing on router 0 of g1 (0%4). id(0,1)=1 -> id(1,0)=4.
        assert_eq!(d.hop_dist_ids(1, 4), 2);
        // Full local-global-local.
        assert_eq!(d.hop_dist_ids(0, 7), 1 + 2 + 1);
        // Custom pricing.
        let d5 = Dragonfly::new(4, 4, 2).with_global_cost(5);
        assert_eq!(d5.hop_dist_ids(0, 7), 1 + 5 + 1);
    }

    #[test]
    fn minimal_route_is_local_global_local() {
        let d = Dragonfly::new(3, 4, 1);
        // a = (0, 0), b = (2, 3): gateway in g0 toward g2 is router 2,
        // landing router in g2 is 0.
        let mut links = Vec::new();
        d.route_ids(0, 11, &mut |l| links.push(l));
        let p = d.ports(); // 7
        assert_eq!(
            links,
            vec![
                0 * p + 2,                 // local (0,0) -> (0,2)
                2 * p + 4 + 2,             // global (0,2) -> g2
                8 * p + 3,                 // local (2,0) -> (2,3)
            ]
        );
        // Hop count (unpriced) is 3; priced distance is 1 + 2 + 1.
        assert_eq!(d.hop_dist_ids(0, 11), 4);
    }

    #[test]
    fn valiant_detours_but_distance_stays_minimal() {
        let base = Dragonfly::new(5, 3, 1);
        let v = base.clone().with_valiant(true);
        // a = (0, 0), b = (3, 1): vg = 3 % 5 = 3 == g2 -> falls back to
        // minimal. Pick b = (2, 1) instead: vg = 2 -> also g2. Use
        // a = (1, 0), b = (4, 1): vg = 0, a detour.
        let (a, b) = (base.id(1, 0), base.id(4, 1));
        let (mut direct, mut detour) = (Vec::new(), Vec::new());
        base.route_ids(a, b, &mut |l| direct.push(l));
        v.route_ids(a, b, &mut |l| detour.push(l));
        assert!(detour.len() > direct.len(), "{detour:?} vs {direct:?}");
        assert_eq!(v.hop_dist_ids(a, b), base.hop_dist_ids(a, b));
        // No link repeats on the detour.
        let mut seen = std::collections::HashSet::new();
        assert!(detour.iter().all(|l| seen.insert(*l)));
        // Intra-group traffic never detours.
        let (mut d1, mut d2) = (Vec::new(), Vec::new());
        base.route_ids(0, 1, &mut |l| d1.push(l));
        v.route_ids(0, 1, &mut |l| d2.push(l));
        assert_eq!(d1, d2);
    }

    #[test]
    fn link_enumeration_counts() {
        let d = Dragonfly::new(4, 3, 1);
        // Local: 12 routers * 2 peers = 24. Global: 4*3 ordered group
        // pairs = 12. Dense space: 12 * (3 + 4) = 84.
        assert_eq!(d.num_directed_links(), 84);
        let (mut local, mut global) = (0usize, 0usize);
        d.for_each_link(&mut |_, class, dir, bw| {
            assert_eq!(dir, 0);
            assert_eq!(bw, 1.0);
            match class {
                0 => local += 1,
                1 => global += 1,
                _ => panic!("class {class}"),
            }
        });
        assert_eq!(local, 24);
        assert_eq!(global, 12);
    }

    #[test]
    fn embedding_and_coords_are_group_router() {
        let d = Dragonfly::new(4, 4, 2);
        let mut out = [0f64; 2];
        d.embed_coords(d.id(2, 3), &mut out);
        assert_eq!(out, [2.0, 3.0]);
        assert_eq!(d.router_of_coords(&[2, 3]), Some(11));
        assert_eq!(d.router_of_coords(&[4, 0]), None);
        assert_eq!(d.router_of_coords(&[0, 4]), None);
        assert_eq!(d.router_of_coords(&[1]), None);
    }

    #[test]
    fn route_length_matches_unpriced_hops_when_global_cost_is_one() {
        // With global_cost = 1 the priced distance equals the link count of
        // the minimal route.
        let d = Dragonfly::new(4, 4, 1).with_global_cost(1);
        for a in 0..16 {
            for b in 0..16 {
                let mut n = 0u64;
                d.route_ids(a, b, &mut |_| n += 1);
                assert_eq!(n, d.hop_dist_ids(a, b), "{a}->{b}");
            }
        }
    }
}
