//! Mesh/torus network topology with per-link bandwidths and
//! dimension-ordered routing.

/// Per-link bandwidth model (GB/s). Links are identified by the dimension
/// they run along and the coordinate of their lower endpoint in that
/// dimension.
#[derive(Clone, Debug, PartialEq)]
pub enum BwModel {
    /// All links identical (IBM BG/Q: "the links have uniform bandwidth
    /// along all dimensions").
    Uniform(f64),
    /// One bandwidth per dimension.
    PerDim(Vec<f64>),
    /// Cray Gemini XK7 heterogeneity (Section 2): X cables 75 GB/s;
    /// Y alternates mezzanine traces (75) and cables (37.5); Z is backplane
    /// traces (120) within 8-router backplanes and cables (75) between them.
    Gemini,
}

impl BwModel {
    /// Bandwidth of the link along `dim` whose lower endpoint has coordinate
    /// `coord` in that dimension.
    #[inline]
    pub fn bandwidth(&self, dim: usize, coord: usize) -> f64 {
        match self {
            BwModel::Uniform(b) => *b,
            BwModel::PerDim(bs) => bs[dim],
            BwModel::Gemini => match dim {
                0 => 75.0,
                1 => {
                    if coord % 2 == 0 {
                        75.0 // mezzanine trace
                    } else {
                        37.5 // Y cable
                    }
                }
                2 => {
                    if coord % 8 == 7 {
                        75.0 // Z cable between backplanes
                    } else {
                        120.0 // backplane trace
                    }
                }
                _ => 75.0,
            },
        }
    }
}

/// A d-dimensional mesh/torus of routers. Router ids are mixed-radix linear
/// indices with dimension 0 fastest-varying.
#[derive(Clone, Debug)]
pub struct Torus {
    pub sizes: Vec<usize>,
    pub wrap: Vec<bool>,
    pub bw: BwModel,
}

impl Torus {
    pub fn new(sizes: Vec<usize>, wrap: Vec<bool>, bw: BwModel) -> Self {
        assert_eq!(sizes.len(), wrap.len());
        assert!(!sizes.is_empty() && sizes.iter().all(|&s| s >= 1));
        Torus { sizes, wrap, bw }
    }

    /// Fully-wrapped torus with uniform bandwidth 1.
    pub fn torus(sizes: &[usize]) -> Self {
        Torus::new(sizes.to_vec(), vec![true; sizes.len()], BwModel::Uniform(1.0))
    }

    /// Unwrapped mesh with uniform bandwidth 1.
    pub fn mesh(sizes: &[usize]) -> Self {
        Torus::new(sizes.to_vec(), vec![false; sizes.len()], BwModel::Uniform(1.0))
    }

    pub fn dim(&self) -> usize {
        self.sizes.len()
    }

    pub fn num_routers(&self) -> usize {
        self.sizes.iter().product()
    }

    /// Linear id of a coordinate vector (dimension 0 fastest).
    #[inline]
    pub fn id_of(&self, coords: &[usize]) -> usize {
        debug_assert_eq!(coords.len(), self.dim());
        let mut id = 0usize;
        for d in (0..self.dim()).rev() {
            debug_assert!(coords[d] < self.sizes[d]);
            id = id * self.sizes[d] + coords[d];
        }
        id
    }

    /// Coordinates of a linear id.
    #[inline]
    pub fn coords_of(&self, mut id: usize) -> Vec<usize> {
        let mut c = vec![0usize; self.dim()];
        for d in 0..self.dim() {
            c[d] = id % self.sizes[d];
            id /= self.sizes[d];
        }
        c
    }

    /// Write coordinates of `id` into `out` without allocating.
    #[inline]
    pub fn coords_into(&self, mut id: usize, out: &mut [usize]) {
        for d in 0..self.dim() {
            out[d] = id % self.sizes[d];
            id /= self.sizes[d];
        }
    }

    /// Shortest signed step count from `a` to `b` along `dim` (wraps if the
    /// dimension is a torus ring; ties broken toward positive direction).
    #[inline]
    pub fn signed_dist(&self, dim: usize, a: usize, b: usize) -> i64 {
        let s = self.sizes[dim] as i64;
        let fwd = (b as i64 - a as i64).rem_euclid(s);
        if !self.wrap[dim] {
            return b as i64 - a as i64;
        }
        if fwd * 2 <= s {
            fwd
        } else {
            fwd - s
        }
    }

    /// Hop distance (shortest path length) between two routers.
    #[inline]
    pub fn hop_dist(&self, a: &[usize], b: &[usize]) -> u64 {
        let mut h = 0u64;
        for d in 0..self.dim() {
            h += self.signed_dist(d, a[d], b[d]).unsigned_abs();
        }
        h
    }

    /// Hop distance between two linear router ids.
    pub fn hop_dist_ids(&self, a: usize, b: usize) -> u64 {
        let mut h = 0u64;
        let (mut a, mut b) = (a, b);
        for d in 0..self.dim() {
            let (ca, cb) = (a % self.sizes[d], b % self.sizes[d]);
            a /= self.sizes[d];
            b /= self.sizes[d];
            h += self.signed_dist(d, ca, cb).unsigned_abs();
        }
        h
    }

    /// Bandwidth of the directed link leaving the router at `coords` along
    /// `dim` in direction `dir` (+1/-1). Links are full-duplex; each
    /// direction sees the full link bandwidth.
    #[inline]
    pub fn link_bandwidth(&self, coords: &[usize], dim: usize, dir: i64) -> f64 {
        // Identify the undirected link by its lower endpoint along `dim`.
        let size = self.sizes[dim];
        let lower = if dir > 0 {
            coords[dim]
        } else {
            (coords[dim] + size - 1) % size
        };
        self.bw.bandwidth(dim, lower)
    }

    /// Walk the dimension-ordered route from `a` to `b`, invoking
    /// `visit(link_router_id, dim, dir)` for every directed link traversed.
    /// `dir` is 0 for + and 1 for -. The `link_router_id` is the id of the
    /// router the message *leaves* over that link.
    pub fn route<F: FnMut(usize, usize, usize)>(&self, a: &[usize], b: &[usize], mut visit: F) {
        let mut cur: Vec<usize> = a.to_vec();
        for d in 0..self.dim() {
            let steps = self.signed_dist(d, a[d], b[d]);
            let dir = if steps >= 0 { 0usize } else { 1usize };
            let s = self.sizes[d];
            for _ in 0..steps.unsigned_abs() {
                let id = self.id_of(&cur);
                visit(id, d, dir);
                cur[d] = if dir == 0 {
                    (cur[d] + 1) % s
                } else {
                    (cur[d] + s - 1) % s
                };
            }
            debug_assert_eq!(cur[d], b[d]);
        }
    }

    /// Total number of directed links (each router has one outgoing link per
    /// dimension per direction on a torus; mesh boundary routers lack the
    /// outward link, but we index densely and never route over missing
    /// links).
    pub fn num_directed_links(&self) -> usize {
        self.num_routers() * self.dim() * 2
    }

    /// Dense index of a directed link.
    #[inline]
    pub fn link_index(&self, router_id: usize, dim: usize, dir: usize) -> usize {
        (router_id * self.dim() + dim) * 2 + dir
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn id_coord_roundtrip() {
        let t = Torus::torus(&[3, 4, 5]);
        for id in 0..t.num_routers() {
            assert_eq!(t.id_of(&t.coords_of(id)), id);
        }
    }

    #[test]
    fn torus_distance_wraps() {
        let t = Torus::torus(&[8]);
        assert_eq!(t.hop_dist(&[0], &[7]), 1);
        assert_eq!(t.hop_dist(&[0], &[4]), 4);
        assert_eq!(t.hop_dist(&[1], &[6]), 3);
    }

    #[test]
    fn mesh_distance_does_not_wrap() {
        let m = Torus::mesh(&[8]);
        assert_eq!(m.hop_dist(&[0], &[7]), 7);
    }

    #[test]
    fn three_hop_diagonal() {
        // Section 2: (i,j,k) to (i+1,j+1,k+1) is a three-hop path.
        let t = Torus::torus(&[4, 4, 4]);
        assert_eq!(t.hop_dist(&[1, 1, 1], &[2, 2, 2]), 3);
    }

    #[test]
    fn route_length_equals_hop_dist() {
        let t = Torus::torus(&[4, 3, 5]);
        let a = [3, 0, 1];
        let b = [0, 2, 4];
        let mut hops = 0;
        t.route(&a, &b, |_, _, _| hops += 1);
        assert_eq!(hops, t.hop_dist(&a, &b));
    }

    #[test]
    fn route_takes_wrap_shortcut() {
        let t = Torus::torus(&[8]);
        let mut links = Vec::new();
        t.route(&[7], &[0], |id, d, dir| links.push((id, d, dir)));
        assert_eq!(links, vec![(7, 0, 0)]); // one +X hop across the seam
    }

    #[test]
    fn route_is_dimension_ordered() {
        let t = Torus::torus(&[4, 4]);
        let mut dims = Vec::new();
        t.route(&[0, 0], &[2, 2], |_, d, _| dims.push(d));
        assert_eq!(dims, vec![0, 0, 1, 1]);
    }

    #[test]
    fn gemini_bandwidths() {
        let bw = BwModel::Gemini;
        assert_eq!(bw.bandwidth(0, 3), 75.0);
        assert_eq!(bw.bandwidth(1, 0), 75.0); // mezzanine
        assert_eq!(bw.bandwidth(1, 1), 37.5); // Y cable
        assert_eq!(bw.bandwidth(2, 0), 120.0); // backplane
        assert_eq!(bw.bandwidth(2, 7), 75.0); // Z cable
    }

    #[test]
    fn hop_dist_ids_matches_coords() {
        let t = Torus::torus(&[3, 5, 2, 4]);
        let n = t.num_routers();
        for a in (0..n).step_by(7) {
            for b in (0..n).step_by(11) {
                assert_eq!(
                    t.hop_dist_ids(a, b),
                    t.hop_dist(&t.coords_of(a), &t.coords_of(b))
                );
            }
        }
    }

    #[test]
    fn signed_dist_tie_breaks_positive() {
        let t = Torus::torus(&[4]);
        assert_eq!(t.signed_dist(0, 0, 2), 2); // exactly half: positive
    }

    #[test]
    fn signed_dist_antisymmetric_off_ties() {
        // |signed_dist(a,b)| == |signed_dist(b,a)| always; the signs are
        // opposite except at the exact-half tie on an even ring (both
        // positive by the tie-break rule). A mesh is exactly antisymmetric.
        for size in [3usize, 4, 5, 8] {
            let t = Torus::torus(&[size]);
            let m = Torus::mesh(&[size]);
            for a in 0..size {
                for b in 0..size {
                    let (f, r) = (t.signed_dist(0, a, b), t.signed_dist(0, b, a));
                    assert_eq!(f.unsigned_abs(), r.unsigned_abs(), "{size}: {a}->{b}");
                    let tie = size % 2 == 0 && f.unsigned_abs() as usize * 2 == size;
                    if tie {
                        assert!(f > 0 && r > 0, "{size}: {a}->{b} tie must go positive");
                    } else {
                        assert_eq!(f, -r, "{size}: {a}->{b}");
                    }
                    assert_eq!(m.signed_dist(0, a, b), -m.signed_dist(0, b, a));
                }
            }
        }
    }

    #[test]
    fn wrap_shortcut_beats_mesh_beyond_half() {
        for size in [5usize, 6, 9] {
            let t = Torus::torus(&[size]);
            let m = Torus::mesh(&[size]);
            for a in 0..size {
                for b in 0..size {
                    let (tw, mw) = (t.hop_dist(&[a], &[b]), m.hop_dist(&[a], &[b]));
                    assert!(tw <= mw);
                    assert!(tw as usize * 2 <= size, "{size}: {a}->{b} over half");
                    if mw as usize * 2 <= size {
                        assert_eq!(tw, mw, "{size}: {a}->{b} under half must agree");
                    }
                }
            }
        }
    }

    #[test]
    fn size_one_dims_are_degenerate() {
        // Size-1 dimensions contribute nothing: distances ignore them, ids
        // round-trip, and routes never step along them (wrapped or not).
        let t = Torus::new(vec![1, 4, 1], vec![true, false, true], BwModel::Uniform(1.0));
        assert_eq!(t.num_routers(), 4);
        for id in 0..4 {
            assert_eq!(t.id_of(&t.coords_of(id)), id);
        }
        assert_eq!(t.signed_dist(0, 0, 0), 0);
        assert_eq!(t.hop_dist(&[0, 0, 0], &[0, 3, 0]), 3);
        let mut dims = Vec::new();
        t.route(&[0, 0, 0], &[0, 3, 0], |_, d, _| dims.push(d));
        assert_eq!(dims, vec![1, 1, 1]);
        // The all-size-1 corner: a single router, zero everywhere.
        let unit = Torus::torus(&[1, 1]);
        assert_eq!(unit.num_routers(), 1);
        assert_eq!(unit.hop_dist_ids(0, 0), 0);
        let mut steps = 0usize;
        unit.route(&[0, 0], &[0, 0], |_, _, _| steps += 1);
        assert_eq!(steps, 0);
    }

    #[test]
    fn link_index_route_roundtrip() {
        // link_index is a dense injection over (router, dim, dir), and the
        // (id, dim, dir) triples route() visits decode back exactly: no two
        // distinct hops of one dimension-ordered path share a link slot.
        let t = Torus::torus(&[3, 4, 2]);
        let nd = t.dim();
        let mut seen = vec![false; t.num_directed_links()];
        for r in 0..t.num_routers() {
            for d in 0..nd {
                for dir in 0..2 {
                    let l = t.link_index(r, d, dir);
                    assert!(l < t.num_directed_links());
                    assert!(!seen[l], "duplicate slot ({r},{d},{dir})");
                    seen[l] = true;
                    // Decode the dense index back.
                    assert_eq!(l % 2, dir);
                    assert_eq!((l / 2) % nd, d);
                    assert_eq!(l / (2 * nd), r);
                }
            }
        }
        assert!(seen.iter().all(|&s| s), "link space must be exactly covered");
        let (a, b) = ([2, 0, 1], [0, 3, 0]);
        let mut path = Vec::new();
        t.route(&a, &b, |id, d, dir| path.push(t.link_index(id, d, dir)));
        assert_eq!(path.len() as u64, t.hop_dist(&a, &b));
        let mut uniq = path.clone();
        uniq.sort_unstable();
        uniq.dedup();
        assert_eq!(uniq.len(), path.len(), "a minimal route repeats no link");
    }
}
