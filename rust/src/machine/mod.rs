//! Machine models: mesh/torus interconnection networks, link-bandwidth
//! models (uniform BG/Q, heterogeneous Cray Gemini), node allocation
//! simulators (contiguous BG/Q blocks, ALPS-style sparse SFC allocations),
//! dimension-ordered routing, and default MPI rank orderings.
//!
//! The paper (Section 2) describes machine topology exclusively through
//! router coordinates plus per-link bandwidths; these modules reproduce that
//! information for the two target platforms:
//!
//! * **Cray XK7 (Titan)** — 3D Gemini torus, 2 compute nodes per router,
//!   16 cores per node, heterogeneous links (X cables 75 GB/s; Y mezzanine
//!   75 / Y cable 37.5; Z backplane 120 / Z cable 75), sparse ALPS
//!   allocations ordered by a space-filling curve.
//! * **IBM BG/Q (Mira)** — 5D torus, uniform links, E dimension of length 2,
//!   contiguous power-of-two block allocations, configurable `ABCDET`-style
//!   rank orderings.

pub mod allocation;
pub mod presets;
pub mod rank_order;
pub mod torus;

pub use allocation::{Allocation, SparseAllocator};
pub use presets::{bgq_block, cray_xk7, titan_full};
pub use torus::{BwModel, Torus};
