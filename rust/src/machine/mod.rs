//! Machine models: mesh/torus interconnection networks, link-bandwidth
//! models (uniform BG/Q, heterogeneous Cray Gemini), node allocation
//! simulators (contiguous BG/Q blocks, ALPS-style sparse SFC allocations),
//! dimension-ordered routing, and default MPI rank orderings.
//!
//! The paper (Section 2) describes machine topology exclusively through
//! router coordinates plus per-link bandwidths; these modules reproduce that
//! information for the two target platforms:
//!
//! * **Cray XK7 (Titan)** — 3D Gemini torus, 2 compute nodes per router,
//!   16 cores per node, heterogeneous links (X cables 75 GB/s; Y mezzanine
//!   75 / Y cable 37.5; Z backplane 120 / Z cable 75), sparse ALPS
//!   allocations ordered by a space-filling curve.
//! * **IBM BG/Q (Mira)** — 5D torus, uniform links, E dimension of length 2,
//!   contiguous power-of-two block allocations, configurable `ABCDET`-style
//!   rank orderings.
//!
//! Beyond the paper's network-only model, [`numa`] adds the cost structure
//! *inside* a node — sockets per node, ranks per socket, per-level unit
//! costs — which the depth-3 hierarchical mapper and the `NumaAware`
//! objective consume. Allocations may be heterogeneous (different rank
//! counts per node, [`Allocation::heterogeneous`]); consistency violations
//! surface as structured [`AllocError`]s instead of silent truncation.
//!
//! # Topologies and their geometric embeddings
//!
//! The network behind an [`Allocation`] is a [`Network`] — any
//! implementation of the [`Topology`] trait ([`topology`] module). The
//! scoring stack (hop distances, routed per-link congestion) is
//! topology-agnostic; what each network must additionally provide is a
//! **coordinate embedding** for the geometric sweep, and the choice of
//! embedding is where the mapping research lives:
//!
//! * **Torus** — the embedding is the literal router coordinates. Geometric
//!   proximity = hop proximity (up to wraparound, which [`crate::mapping::shift`]
//!   repairs), so this is the paper's setting unchanged.
//! * **Fat-tree** ([`FatTree`], levels × radix) — leaves embed as their
//!   base-radix pod digits, most-significant level first. Distance in the
//!   tree is `2·(levels above the nearest common ancestor)`, a purely
//!   hierarchical quantity: the digit embedding makes every multisection
//!   cut a subtree boundary, so cutting coarse axes first keeps traffic
//!   under the lowest possible common ancestor.
//! * **Dragonfly** ([`Dragonfly`], groups × routers/group) — routers embed
//!   as `(group, router)`. Crossing a group always pays the (configurable)
//!   `global_cost`, so the group axis dominates and the sweep packs
//!   communicating tasks into groups before spreading within them; routed
//!   loads can optionally take deterministic one-hop-Valiant detours to
//!   model load-spread global links.

pub mod allocation;
pub mod dragonfly;
pub mod fattree;
pub mod numa;
pub mod presets;
pub mod rank_order;
pub mod topology;
pub mod torus;

pub use allocation::{AllocError, Allocation, SparseAllocator};
pub use dragonfly::Dragonfly;
pub use fattree::FatTree;
pub use numa::{NumaNodeCosts, NumaTopology};
pub use presets::{bgq_block, cray_xk7, titan_full};
pub use topology::{Network, Topology};
pub use torus::{BwModel, Torus};
