//! Machine models: mesh/torus interconnection networks, link-bandwidth
//! models (uniform BG/Q, heterogeneous Cray Gemini), node allocation
//! simulators (contiguous BG/Q blocks, ALPS-style sparse SFC allocations),
//! dimension-ordered routing, and default MPI rank orderings.
//!
//! The paper (Section 2) describes machine topology exclusively through
//! router coordinates plus per-link bandwidths; these modules reproduce that
//! information for the two target platforms:
//!
//! * **Cray XK7 (Titan)** — 3D Gemini torus, 2 compute nodes per router,
//!   16 cores per node, heterogeneous links (X cables 75 GB/s; Y mezzanine
//!   75 / Y cable 37.5; Z backplane 120 / Z cable 75), sparse ALPS
//!   allocations ordered by a space-filling curve.
//! * **IBM BG/Q (Mira)** — 5D torus, uniform links, E dimension of length 2,
//!   contiguous power-of-two block allocations, configurable `ABCDET`-style
//!   rank orderings.
//!
//! Beyond the paper's network-only model, [`numa`] adds the cost structure
//! *inside* a node — sockets per node, ranks per socket, per-level unit
//! costs — which the depth-3 hierarchical mapper and the `NumaAware`
//! objective consume. Allocations may be heterogeneous (different rank
//! counts per node, [`Allocation::heterogeneous`]); consistency violations
//! surface as structured [`AllocError`]s instead of silent truncation.

pub mod allocation;
pub mod numa;
pub mod presets;
pub mod rank_order;
pub mod torus;

pub use allocation::{AllocError, Allocation, SparseAllocator};
pub use numa::{NumaNodeCosts, NumaTopology};
pub use presets::{bgq_block, cray_xk7, titan_full};
pub use torus::{BwModel, Torus};
