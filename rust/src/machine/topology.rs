//! The [`Topology`] abstraction behind the machine model: what the scoring
//! stack actually consumes from a network, as a trait — plus [`Network`],
//! the concrete closed enum of implementations that allocations store.
//!
//! The paper's machinery needs surprisingly little from the interconnect:
//!
//! * a router count and a **hop distance** between router ids (the
//!   WeightedHops objective, NUMA pricing, hierarchical node sweeps),
//! * a **per-link path enumeration** for routed congestion — a visitor
//!   yielding stable dense directed-link indices along the deterministic
//!   route from `a` to `b` ([`Topology::route_ids`]),
//! * a stable **link enumeration** with per-link bandwidth and a coarse
//!   `(class, direction)` tag for reporting ([`Topology::for_each_link`]),
//! * a **coordinate embedding** per router that feeds the geometric
//!   multisection sweep ([`Topology::embed_coords`]) — the research-y part:
//!   the embedding decides what "geometric locality" means on a network
//!   that is not a grid.
//!
//! [`Torus`] is one implementation (the paper's machines); [`FatTree`] and
//! [`Dragonfly`] open the topology axis. All scoring code dispatches
//! through `&dyn Topology` (or through [`Network`], which delegates with
//! static dispatch per arm), and the torus arm performs the exact
//! arithmetic, in the exact order, of the pre-trait code — torus results
//! are bit-identical at every thread count.

use super::dragonfly::Dragonfly;
use super::fattree::FatTree;
use super::torus::{BwModel, Torus};

/// What the mapping/scoring stack consumes from an interconnect. All
/// methods are object-safe; implementations are immutable and `Sync` so one
/// instance is shared by every sweep/refinement worker.
pub trait Topology: Sync {
    /// Number of routers (the targets task ranks are pinned to). Router ids
    /// are dense `0..num_routers()`.
    fn num_routers(&self) -> usize;

    /// Minimal-path hop distance between two router ids, in (integer)
    /// priced hops. Symmetric; zero iff `a == b` (self-distance).
    fn hop_dist_ids(&self, a: usize, b: usize) -> u64;

    /// Size of the dense directed-link index space. Indices returned by
    /// [`route_ids`](Topology::route_ids) / visited by
    /// [`for_each_link`](Topology::for_each_link) are `< num_directed_links()`.
    /// The space may contain unused slots (mesh boundaries, dragonfly
    /// self-ports); routing never yields them.
    fn num_directed_links(&self) -> usize;

    /// Walk the deterministic route from router `a` to router `b`, invoking
    /// `visit(link)` for every directed link traversed, in path order.
    /// The route realizes `hop_dist_ids(a, b)` hops on the torus and
    /// fat-tree; dragonfly may detour (one-hop Valiant) when configured —
    /// distance pricing stays minimal either way.
    fn route_ids(&self, a: usize, b: usize, visit: &mut dyn FnMut(usize));

    /// Enumerate every *existing* directed link once, in a stable order,
    /// as `visit(link, class, dir, bandwidth)`. `class < num_link_classes()`
    /// is the reporting bucket (torus: dimension; fat-tree: child level;
    /// dragonfly: local/global), `dir` is 0 or 1 within the class.
    fn for_each_link(&self, visit: &mut dyn FnMut(usize, usize, usize, f64));

    /// Number of link classes [`for_each_link`](Topology::for_each_link)
    /// reports (per-class stats shape).
    fn num_link_classes(&self) -> usize;

    /// Dimensionality of the geometric embedding.
    fn embed_dim(&self) -> usize;

    /// Write the geometric embedding of router `id` into
    /// `out[..embed_dim()]`. This is what the multisection sweep partitions;
    /// see the per-implementation docs for the embedding choice.
    fn embed_coords(&self, id: usize, out: &mut [f64]);

    /// Number of integer coordinates that name a router externally (the
    /// service's per-rank coordinate columns): torus = `dim()`, fat-tree =
    /// 1 (leaf rank), dragonfly = 2 (group, router).
    fn coord_dim(&self) -> usize;

    /// Resolve external integer coordinates to a router id; `None` if out
    /// of range. Inverse of the external naming, not of `embed_coords`.
    fn router_of_coords(&self, coords: &[usize]) -> Option<usize>;

    /// Short protocol name of the topology family ("torus" | "fattree" |
    /// "dragonfly").
    fn kind_name(&self) -> &'static str;
}

impl Topology for Torus {
    fn num_routers(&self) -> usize {
        Torus::num_routers(self)
    }

    fn hop_dist_ids(&self, a: usize, b: usize) -> u64 {
        Torus::hop_dist_ids(self, a, b)
    }

    fn num_directed_links(&self) -> usize {
        Torus::num_directed_links(self)
    }

    fn route_ids(&self, a: usize, b: usize, visit: &mut dyn FnMut(usize)) {
        // Same coordinate decode + dimension-ordered walk the routed
        // accumulator always performed; stack buffers for the common case.
        let d = self.dim();
        if d <= 8 {
            let (mut ca, mut cb) = ([0usize; 8], [0usize; 8]);
            self.coords_into(a, &mut ca[..d]);
            self.coords_into(b, &mut cb[..d]);
            self.route(&ca[..d], &cb[..d], |id, dm, dir| {
                visit(self.link_index(id, dm, dir))
            });
        } else {
            let (mut ca, mut cb) = (vec![0usize; d], vec![0usize; d]);
            self.coords_into(a, &mut ca);
            self.coords_into(b, &mut cb);
            self.route(&ca, &cb, |id, dm, dir| visit(self.link_index(id, dm, dir)));
        }
    }

    fn for_each_link(&self, visit: &mut dyn FnMut(usize, usize, usize, f64)) {
        // Exactly the historical router -> dim -> dir iteration (with the
        // mesh-boundary skip) that LinkCosts and the metrics summary used:
        // their f64 accumulation order — and therefore every reported
        // value — is unchanged on the torus.
        let dim = self.dim();
        let mut coords = vec![0usize; dim];
        for router in 0..Torus::num_routers(self) {
            self.coords_into(router, &mut coords);
            for d in 0..dim {
                for dir in 0..2 {
                    if !self.wrap[d] {
                        let c = coords[d];
                        if (dir == 0 && c + 1 == self.sizes[d]) || (dir == 1 && c == 0) {
                            continue; // mesh boundary: no outward link
                        }
                    }
                    let bw = self.link_bandwidth(&coords, d, if dir == 0 { 1 } else { -1 });
                    visit(self.link_index(router, d, dir), d, dir, bw);
                }
            }
        }
    }

    fn num_link_classes(&self) -> usize {
        self.dim()
    }

    fn embed_dim(&self) -> usize {
        self.dim()
    }

    fn embed_coords(&self, id: usize, out: &mut [f64]) {
        // The torus embedding is its own integer coordinates — identical to
        // the pre-trait `coords_into` + cast path.
        let mut r = id;
        for (d, &s) in self.sizes.iter().enumerate() {
            out[d] = (r % s) as f64;
            r /= s;
        }
    }

    fn coord_dim(&self) -> usize {
        self.dim()
    }

    fn router_of_coords(&self, coords: &[usize]) -> Option<usize> {
        if coords.len() != self.dim() {
            return None;
        }
        for (d, &c) in coords.iter().enumerate() {
            if c >= self.sizes[d] {
                return None;
            }
        }
        Some(self.id_of(coords))
    }

    fn kind_name(&self) -> &'static str {
        "torus"
    }
}

/// The closed set of network models an [`crate::machine::Allocation`] can
/// hold. Scoring code that works for any topology takes `&dyn Topology` (or
/// `&Network`, which implements the trait by enum delegation — static
/// dispatch per arm); torus-only features (coordinate shifting, bandwidth
/// scaling, the box transform, BG/Q blocks, the f32 WeightedHops kernel)
/// gate on [`Network::as_torus`].
#[derive(Clone, Debug)]
pub enum Network {
    Torus(Torus),
    FatTree(FatTree),
    Dragonfly(Dragonfly),
}

impl Network {
    /// Fully-wrapped torus with uniform bandwidth 1 (mirrors
    /// [`Torus::torus`]).
    pub fn torus(sizes: &[usize]) -> Network {
        Network::Torus(Torus::torus(sizes))
    }

    /// Unwrapped mesh with uniform bandwidth 1 (mirrors [`Torus::mesh`]).
    pub fn mesh(sizes: &[usize]) -> Network {
        Network::Torus(Torus::mesh(sizes))
    }

    /// Torus with explicit wrap flags and bandwidth model (mirrors
    /// [`Torus::new`]).
    pub fn new(sizes: Vec<usize>, wrap: Vec<bool>, bw: BwModel) -> Network {
        Network::Torus(Torus::new(sizes, wrap, bw))
    }

    /// The torus inside, if this network is one. Torus-only code paths
    /// (coordinate transforms, BG/Q allocation, the batched f32 kernel)
    /// gate on this.
    pub fn as_torus(&self) -> Option<&Torus> {
        match self {
            Network::Torus(t) => Some(t),
            _ => None,
        }
    }

    /// View as a trait object (handy where a field stores `&dyn Topology`).
    pub fn topo(&self) -> &dyn Topology {
        match self {
            Network::Torus(t) => t,
            Network::FatTree(f) => f,
            Network::Dragonfly(d) => d,
        }
    }
}

impl From<Torus> for Network {
    fn from(t: Torus) -> Network {
        Network::Torus(t)
    }
}

impl From<FatTree> for Network {
    fn from(f: FatTree) -> Network {
        Network::FatTree(f)
    }
}

impl From<Dragonfly> for Network {
    fn from(d: Dragonfly) -> Network {
        Network::Dragonfly(d)
    }
}

macro_rules! delegate {
    ($self:ident, $t:ident => $e:expr) => {
        match $self {
            Network::Torus($t) => $e,
            Network::FatTree($t) => $e,
            Network::Dragonfly($t) => $e,
        }
    };
}

impl Topology for Network {
    fn num_routers(&self) -> usize {
        delegate!(self, t => t.num_routers())
    }

    fn hop_dist_ids(&self, a: usize, b: usize) -> u64 {
        delegate!(self, t => Topology::hop_dist_ids(t, a, b))
    }

    fn num_directed_links(&self) -> usize {
        delegate!(self, t => Topology::num_directed_links(t))
    }

    fn route_ids(&self, a: usize, b: usize, visit: &mut dyn FnMut(usize)) {
        delegate!(self, t => t.route_ids(a, b, visit))
    }

    fn for_each_link(&self, visit: &mut dyn FnMut(usize, usize, usize, f64)) {
        delegate!(self, t => t.for_each_link(visit))
    }

    fn num_link_classes(&self) -> usize {
        delegate!(self, t => t.num_link_classes())
    }

    fn embed_dim(&self) -> usize {
        delegate!(self, t => t.embed_dim())
    }

    fn embed_coords(&self, id: usize, out: &mut [f64]) {
        delegate!(self, t => t.embed_coords(id, out))
    }

    fn coord_dim(&self) -> usize {
        delegate!(self, t => t.coord_dim())
    }

    fn router_of_coords(&self, coords: &[usize]) -> Option<usize> {
        delegate!(self, t => t.router_of_coords(coords))
    }

    fn kind_name(&self) -> &'static str {
        delegate!(self, t => t.kind_name())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Trait-conformance suite: every implementation must satisfy the
    /// contracts the scoring stack leans on.
    fn check_conformance(topo: &dyn Topology) {
        let n = topo.num_routers();
        assert!(n >= 1);
        let nlinks = topo.num_directed_links();
        // Distance: identity, symmetry, triangle inequality on minimal
        // routes (sampled pairs/triples to keep the suite fast).
        let stride = (n / 12).max(1);
        let sample: Vec<usize> = (0..n).step_by(stride).collect();
        for &a in &sample {
            assert_eq!(topo.hop_dist_ids(a, a), 0, "self-distance at {a}");
            for &b in &sample {
                let d = topo.hop_dist_ids(a, b);
                assert_eq!(d, topo.hop_dist_ids(b, a), "symmetry {a}<->{b}");
                if a != b {
                    assert!(d > 0, "distinct routers at distance 0: {a},{b}");
                }
                for &c in &sample {
                    assert!(
                        d <= topo.hop_dist_ids(a, c) + topo.hop_dist_ids(c, b),
                        "triangle violated: d({a},{b}) > d({a},{c}) + d({c},{b})"
                    );
                }
            }
        }
        // Routes yield in-range link indices and never repeat a link.
        for &a in &sample {
            for &b in &sample {
                let mut seen = std::collections::HashSet::new();
                topo.route_ids(a, b, &mut |l| {
                    assert!(l < nlinks, "route link {l} out of range {nlinks}");
                    assert!(seen.insert(l), "route {a}->{b} repeats link {l}");
                });
                if a == b {
                    assert!(seen.is_empty(), "self-route {a} traverses links");
                }
            }
        }
        // Link enumeration: indices bijective (no slot visited twice), in
        // range, classes in range, bandwidths positive.
        let mut seen = vec![false; nlinks];
        let classes = topo.num_link_classes();
        let mut count = 0usize;
        topo.for_each_link(&mut |l, class, dir, bw| {
            assert!(l < nlinks);
            assert!(!seen[l], "link {l} enumerated twice");
            seen[l] = true;
            assert!(class < classes);
            assert!(dir < 2);
            assert!(bw > 0.0);
            count += 1;
        });
        assert!(count > 0 || n == 1);
        // Every routed link is an enumerated link.
        for &a in &sample {
            for &b in &sample {
                topo.route_ids(a, b, &mut |l| {
                    assert!(seen[l], "route {a}->{b} uses unenumerated link {l}");
                });
            }
        }
        // Embedding has the declared arity and is finite.
        let mut out = vec![f64::NAN; topo.embed_dim()];
        for &a in &sample {
            topo.embed_coords(a, &mut out);
            assert!(out.iter().all(|v| v.is_finite()), "embedding of {a}");
        }
    }

    #[test]
    fn torus_conforms() {
        check_conformance(&Torus::torus(&[4, 3, 2]));
        check_conformance(&Torus::mesh(&[5, 4]));
        check_conformance(&Torus::torus(&[1, 6])); // size-1 dimension
    }

    #[test]
    fn fattree_conforms() {
        check_conformance(&FatTree::new(2, 4));
        check_conformance(&FatTree::new(3, 2));
    }

    #[test]
    fn dragonfly_conforms() {
        check_conformance(&Dragonfly::new(4, 4, 2));
        check_conformance(&Dragonfly::new(3, 5, 1).with_global_cost(3));
        check_conformance(&Dragonfly::new(5, 3, 1).with_valiant(true));
    }

    #[test]
    fn network_delegates_to_torus_bit_for_bit() {
        // The Network wrapper must be transparent: identical distances,
        // routes, link enumeration, and embeddings.
        let t = Torus::new(vec![4, 3], vec![true, false], BwModel::PerDim(vec![2.0, 4.0]));
        let net: Network = t.clone().into();
        assert_eq!(net.num_routers(), Torus::num_routers(&t));
        assert_eq!(
            Topology::num_directed_links(&net),
            Torus::num_directed_links(&t)
        );
        for a in 0..Torus::num_routers(&t) {
            for b in 0..Torus::num_routers(&t) {
                assert_eq!(
                    Topology::hop_dist_ids(&net, a, b),
                    Torus::hop_dist_ids(&t, a, b)
                );
                let (mut la, mut lb) = (Vec::new(), Vec::new());
                net.route_ids(a, b, &mut |l| la.push(l));
                Topology::route_ids(&t, a, b, &mut |l| lb.push(l));
                assert_eq!(la, lb);
            }
        }
        let (mut ea, mut eb) = (Vec::new(), Vec::new());
        net.for_each_link(&mut |l, c, d, bw| ea.push((l, c, d, bw.to_bits())));
        Topology::for_each_link(&t, &mut |l, c, d, bw| eb.push((l, c, d, bw.to_bits())));
        assert_eq!(ea, eb);
    }

    #[test]
    fn torus_route_ids_matches_route_plus_link_index() {
        let t = Torus::torus(&[4, 3, 5]);
        for (a, b) in [(0usize, 37usize), (11, 11), (59, 3), (20, 41)] {
            let mut via_ids = Vec::new();
            Topology::route_ids(&t, a, b, &mut |l| via_ids.push(l));
            let mut via_route = Vec::new();
            t.route(&t.coords_of(a), &t.coords_of(b), |id, d, dir| {
                via_route.push(t.link_index(id, d, dir))
            });
            assert_eq!(via_ids, via_route);
        }
    }

    #[test]
    fn network_constructors_mirror_torus() {
        assert!(matches!(Network::torus(&[4]), Network::Torus(_)));
        assert!(matches!(Network::mesh(&[4]), Network::Torus(_)));
        let n = Network::new(vec![2, 2], vec![true, false], BwModel::Uniform(3.0));
        assert_eq!(n.as_torus().unwrap().wrap, vec![true, false]);
        assert!(Network::from(FatTree::new(2, 2)).as_torus().is_none());
        assert_eq!(Network::from(Dragonfly::new(2, 2, 1)).kind_name(), "dragonfly");
    }
}
