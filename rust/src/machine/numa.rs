//! NUMA distance model: the cost structure *inside* a node.
//!
//! The paper's Section 3 model prices intra-node messages at zero — correct
//! for the network, but real nodes are not flat: the XK7's Interlagos
//! processor is two NUMA dies bridged by HyperTransport, so a message
//! between ranks on different dies crosses a link that same-die messages
//! never touch. [`NumaTopology`] captures that third level with per-level
//! unit costs, in the same spirit as the tree-distance models of the
//! shared-memory hierarchical-mapping line of work (arXiv:2504.01726,
//! arXiv:1702.04164):
//!
//! * **node level** — `hop_cost` per network hop per unit message weight
//!   (1.0 keeps the network term equal to the Section 3 WeightedHops);
//! * **socket level** — `socket_cost` per unit weight for messages between
//!   ranks of the same node but different sockets;
//! * **core level** — `core_cost` per unit weight for messages within one
//!   socket (usually 0: shared L3 traffic is treated as free).
//!
//! Ranks are assigned to sockets by their position in the node's default
//! rank order: the first `ranks_per_socket` ranks of a node form socket 0,
//! the next form socket 1, and so on (positions past
//! `sockets_per_node * ranks_per_socket` — possible on heterogeneous
//! allocations — clamp into the last socket). This matches how MPI
//! launchers fill NUMA domains in core order.
//!
//! The model is consumed in three places: the depth-3 hierarchical mapper
//! ([`crate::hier::HierConfig::numa`]), the [`crate::objective::NumaAware`]
//! objective that scores finished mappings, and the node-level rotation
//! sweep, which prices still-unsplit intra-node edges at `socket_cost`
//! (the upper bound the socket-level split then tightens) via
//! [`NumaTopology::node_level_costs`].

use super::allocation::Allocation;

/// Per-level NUMA cost model of one compute node. `Copy` so it travels
/// through the `Copy` sweep configuration like the objective handle does.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct NumaTopology {
    /// NUMA domains per node (XK7 Interlagos: 2 dies).
    pub sockets_per_node: usize,
    /// Ranks per NUMA domain in default rank order.
    pub ranks_per_socket: usize,
    /// Cost per unit message weight between sockets of one node.
    pub socket_cost: f64,
    /// Cost per unit message weight within one socket (usually 0).
    pub core_cost: f64,
    /// Cost per network hop per unit message weight for inter-node
    /// messages (1.0 = the Section 3 WeightedHops scale).
    pub hop_cost: f64,
}

/// Node-level view of a [`NumaTopology`]: what the node-level rotation
/// sweep and `MinVolume` refinement price edges with *before* the socket
/// split exists — inter-node edges at `hop` per hop, intra-node edges at
/// the flat `socket` upper bound.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct NumaNodeCosts {
    /// Cost per network hop per unit weight (inter-node edges).
    pub hop: f64,
    /// Flat cost per unit weight for intra-node edges.
    pub socket: f64,
}

impl NumaTopology {
    /// Build a topology, checking the invariants the mapper relies on.
    pub fn new(
        sockets_per_node: usize,
        ranks_per_socket: usize,
        socket_cost: f64,
        core_cost: f64,
        hop_cost: f64,
    ) -> NumaTopology {
        assert!(sockets_per_node >= 1, "need at least one socket per node");
        assert!(ranks_per_socket >= 1, "need at least one rank per socket");
        assert!(
            socket_cost.is_finite() && core_cost.is_finite() && hop_cost.is_finite(),
            "NUMA costs must be finite"
        );
        assert!(
            socket_cost >= core_cost && core_cost >= 0.0,
            "costs must satisfy socket_cost >= core_cost >= 0 \
             (got socket {socket_cost}, core {core_cost})"
        );
        assert!(hop_cost > 0.0, "hop_cost must be positive");
        NumaTopology {
            sockets_per_node,
            ranks_per_socket,
            socket_cost,
            core_cost,
            hop_cost,
        }
    }

    /// Cray XK7 node: one AMD Opteron 6274 (Interlagos) = 2 NUMA dies of 8
    /// integer cores each. The cross-die HyperTransport hop is priced at
    /// half a Gemini network hop — a model parameter, not a measurement.
    pub fn xk7() -> NumaTopology {
        NumaTopology::new(2, 8, 0.5, 0.0, 1.0)
    }

    /// IBM BG/Q node: a single 16-core A2 chip with a crossbar to a shared
    /// L2 — one NUMA domain, so the socket level degenerates and depth-3
    /// mapping reduces to the two-level mapper.
    pub fn bgq() -> NumaTopology {
        NumaTopology::new(1, 16, 0.0, 0.0, 1.0)
    }

    /// Parse a service/CLI preset name.
    pub fn preset(name: &str) -> Option<NumaTopology> {
        match name.to_ascii_lowercase().as_str() {
            "xk7" => Some(NumaTopology::xk7()),
            "bgq" => Some(NumaTopology::bgq()),
            _ => None,
        }
    }

    /// Nominal ranks per node implied by the socket grid.
    pub fn ranks_per_node(&self) -> usize {
        self.sockets_per_node * self.ranks_per_socket
    }

    /// Socket of the rank at position `pos` in its node's default rank
    /// order. Positions past the socket grid clamp into the last socket.
    #[inline]
    pub fn socket_of_pos(&self, pos: usize) -> usize {
        (pos / self.ranks_per_socket).min(self.sockets_per_node - 1)
    }

    /// Within-node socket index of every rank of `alloc`, by position in
    /// each node's default rank order (the assignment the depth-3 mapper
    /// and [`crate::objective::eval_numa`] agree on).
    pub fn socket_of_ranks(&self, alloc: &Allocation) -> Vec<u32> {
        let mut out = vec![0u32; alloc.num_ranks()];
        for group in alloc.ranks_by_node() {
            for (pos, &r) in group.iter().enumerate() {
                out[r as usize] = self.socket_of_pos(pos) as u32;
            }
        }
        out
    }

    /// The node-level pricing the sweep and node refinement use while the
    /// socket split is still undecided (see [`NumaNodeCosts`]).
    pub fn node_level_costs(&self) -> NumaNodeCosts {
        NumaNodeCosts {
            hop: self.hop_cost,
            socket: self.socket_cost,
        }
    }

    /// Objective gain per unit Δ(cross-socket weight) of a within-node
    /// swap — what the socket-level refinement scales its deltas by. A
    /// within-node swap moves nothing between nodes, so the network term
    /// (hop-priced or routed) is unchanged and this is the *entire*
    /// blended-evaluator gain of such a swap.
    pub fn swap_gain_scale(&self) -> f64 {
        self.socket_cost - self.core_cost
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::machine::{SparseAllocator, Torus};

    #[test]
    fn presets_are_consistent() {
        let x = NumaTopology::xk7();
        assert_eq!((x.sockets_per_node, x.ranks_per_socket), (2, 8));
        assert_eq!(x.ranks_per_node(), 16);
        let b = NumaTopology::bgq();
        assert_eq!(b.ranks_per_node(), 16);
        assert_eq!(b.socket_cost, 0.0);
        assert_eq!(NumaTopology::preset("xk7"), Some(x));
        assert_eq!(NumaTopology::preset("BGQ"), Some(b));
        assert_eq!(NumaTopology::preset("knl"), None);
    }

    #[test]
    fn socket_positions_clamp() {
        let t = NumaTopology::new(2, 4, 0.5, 0.0, 1.0);
        assert_eq!(t.socket_of_pos(0), 0);
        assert_eq!(t.socket_of_pos(3), 0);
        assert_eq!(t.socket_of_pos(4), 1);
        assert_eq!(t.socket_of_pos(7), 1);
        // Beyond the grid (heterogeneous over-full node): last socket.
        assert_eq!(t.socket_of_pos(11), 1);
    }

    #[test]
    fn rank_sockets_follow_node_position() {
        let alloc = SparseAllocator {
            machine: Torus::torus(&[4, 4, 4]),
            nodes_per_router: 2,
            ranks_per_node: 8,
            occupancy: 0.2,
        }
        .allocate(6, 3);
        let t = NumaTopology::new(2, 4, 0.5, 0.0, 1.0);
        let socks = t.socket_of_ranks(&alloc);
        for group in alloc.ranks_by_node() {
            for (pos, &r) in group.iter().enumerate() {
                assert_eq!(socks[r as usize] as usize, pos / 4);
            }
        }
    }

    #[test]
    #[should_panic(expected = "socket_cost >= core_cost")]
    fn rejects_inverted_costs() {
        NumaTopology::new(2, 8, 0.1, 0.5, 1.0);
    }
}
