//! Default MPI rank orderings.
//!
//! * BG/Q `ABCDET`-style built-in orderings: a permutation string over the
//!   five torus dimensions A–E plus T (ranks within a node); the **last**
//!   letter varies fastest. The machine default `ABCDET` therefore places
//!   consecutive ranks within a node first, then along E, D, C, B, A
//!   (Section 1 and 5.2).
//! * Cray Gemini / ALPS placement curve: ALPS orders the allocated nodes
//!   along a space-filling curve that traverses a small `a x 2 x 4` box of
//!   routers before crossing slow Y links (Section 5.3.1). We reproduce it
//!   as: routers grouped into 2x2x4 boxes, boxes visited in Hilbert order
//!   over the box grid, routers within a box in x-fastest order.

use super::torus::Torus;
use crate::sfc::hilbert::hilbert_index;

/// Structured parse errors for `ABCDET`-style rank-order strings. These
/// used to be panics (`bad rank-order letter`), which crashed the whole
/// process — including the mapping service — on a malformed order string;
/// callers now get a value they can surface as a validation error.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum RankOrderError {
    /// The order string is not exactly 6 letters.
    BadLength { got: usize },
    /// A letter outside {A, B, C, D, E, T}.
    BadLetter { letter: char },
    /// A letter appears more than once (the order must be a permutation —
    /// a repeated letter would silently skip part of the block).
    DuplicateLetter { letter: char },
}

impl std::fmt::Display for RankOrderError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RankOrderError::BadLength { got } => {
                write!(f, "rank order must be 6 letters over ABCDET, got {got}")
            }
            RankOrderError::BadLetter { letter } => {
                write!(f, "bad rank-order letter {letter:?} (want one of ABCDET)")
            }
            RankOrderError::DuplicateLetter { letter } => {
                write!(f, "rank-order letter {letter:?} appears more than once")
            }
        }
    }
}

impl std::error::Error for RankOrderError {}

/// Validate an `ABCDET`-style rank-order string: exactly 6 letters, a
/// permutation of {A, B, C, D, E, T}. Returns the validated bytes.
pub fn parse_rank_order(perm: &str) -> Result<[u8; 6], RankOrderError> {
    let bytes = perm.as_bytes();
    if bytes.len() != 6 {
        return Err(RankOrderError::BadLength {
            got: perm.chars().count(),
        });
    }
    let mut out = [0u8; 6];
    let mut seen = [false; 6];
    for (i, &c) in bytes.iter().enumerate() {
        let slot = match c {
            b'A' => 0,
            b'B' => 1,
            b'C' => 2,
            b'D' => 3,
            b'E' => 4,
            b'T' => 5,
            _ => {
                return Err(RankOrderError::BadLetter {
                    letter: c as char,
                })
            }
        };
        if seen[slot] {
            return Err(RankOrderError::DuplicateLetter {
                letter: c as char,
            });
        }
        seen[slot] = true;
        out[i] = c;
    }
    Ok(out)
}

/// Enumerate BG/Q rank placements for a job block.
///
/// `block` are the A,B,C,D,E extents of the allocated block; `t` is the
/// number of ranks per node; `perm` is a string over {A,B,C,D,E,T} whose
/// last letter varies fastest (e.g. the default `"ABCDET"`). A malformed
/// order string returns a structured [`RankOrderError`] instead of
/// panicking.
///
/// Returns, for each rank, the router id (in the block torus, dimension
/// order A,B,C,D,E with A *slowest*; we store coords as [a,b,c,d,e] and use
/// `Torus::id_of` with dimension 0 = A fastest-varying id convention — the
/// mapping is internally consistent).
pub fn bgq_rank_placement(
    block: &[usize; 5],
    t: usize,
    perm: &str,
) -> Result<Vec<usize>, RankOrderError> {
    let perm = parse_rank_order(perm)?;
    // Extent per (validated) letter.
    let extent = |ch: u8| -> usize {
        match ch {
            b'A' => block[0],
            b'B' => block[1],
            b'C' => block[2],
            b'D' => block[3],
            b'E' => block[4],
            b'T' => t,
            _ => unreachable!("parse_rank_order validated the letters"),
        }
    };
    let total: usize = block.iter().product::<usize>() * t;
    let torus = Torus::torus(block);
    let mut out = Vec::with_capacity(total);
    // Odometer over the permutation letters, last letter fastest.
    let radices: Vec<usize> = perm.iter().map(|&c| extent(c)).collect();
    let mut digits = vec![0usize; 6];
    for _ in 0..total {
        // Translate digits -> (a,b,c,d,e) coords; T digit selects the rank
        // slot within the node and does not affect the router.
        let mut coords = [0usize; 5];
        for (li, &letter) in perm.iter().enumerate() {
            let v = digits[li];
            match letter {
                b'A' => coords[0] = v,
                b'B' => coords[1] = v,
                b'C' => coords[2] = v,
                b'D' => coords[3] = v,
                b'E' => coords[4] = v,
                b'T' => {}
                _ => unreachable!(),
            }
        }
        out.push(torus.id_of(&coords));
        // Increment odometer (last letter fastest).
        for li in (0..6).rev() {
            digits[li] += 1;
            if digits[li] < radices[li] {
                break;
            }
            digits[li] = 0;
        }
    }
    Ok(out)
}

/// ALPS-style placement curve over a 3D Gemini torus: the order in which the
/// scheduler considers routers when assigning nodes to jobs.
pub fn gemini_curve_order(torus: &Torus) -> Vec<usize> {
    assert_eq!(torus.dim(), 3, "gemini curve is defined for 3D");
    let (sx, sy, sz) = (torus.sizes[0], torus.sizes[1], torus.sizes[2]);
    let (bx, by, bz) = (2usize, 2usize, 4usize);
    let nbx = sx.div_ceil(bx);
    let nby = sy.div_ceil(by);
    let nbz = sz.div_ceil(bz);
    let bits = 1 + (nbx.max(nby).max(nbz) as u64).next_power_of_two().trailing_zeros();
    // Order boxes by Hilbert index over the box grid.
    let mut boxes: Vec<(u128, usize, usize, usize)> = Vec::with_capacity(nbx * nby * nbz);
    for gz in 0..nbz {
        for gy in 0..nby {
            for gx in 0..nbx {
                let h = hilbert_index(&[gx as u64, gy as u64, gz as u64], bits);
                boxes.push((h, gx, gy, gz));
            }
        }
    }
    boxes.sort_unstable();
    let mut order = Vec::with_capacity(torus.num_routers());
    for (_, gx, gy, gz) in boxes {
        // Within a box: x fastest (cheap links first), then y, then z.
        for z in (gz * bz)..((gz * bz + bz).min(sz)) {
            for y in (gy * by)..((gy * by + by).min(sy)) {
                for x in (gx * bx)..((gx * bx + bx).min(sx)) {
                    order.push(torus.id_of(&[x, y, z]));
                }
            }
        }
    }
    order
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bgq_default_places_within_node_first() {
        let block = [2, 2, 2, 2, 2];
        let ranks = bgq_rank_placement(&block, 4, "ABCDET").unwrap();
        // First 4 ranks share a router (T fastest), next 4 differ only in E.
        assert_eq!(ranks[0], ranks[1]);
        assert_eq!(ranks[0], ranks[3]);
        assert_ne!(ranks[3], ranks[4]);
        let t = Torus::torus(&block);
        let c0 = t.coords_of(ranks[0]);
        let c4 = t.coords_of(ranks[4]);
        assert_eq!(c0[..4], c4[..4]); // A..D equal
        assert_ne!(c0[4], c4[4]); // E differs
    }

    #[test]
    fn bgq_placement_covers_all_ranks() {
        let block = [2, 2, 4, 4, 2];
        let t = 4;
        let ranks = bgq_rank_placement(&block, t, "ABCDET").unwrap();
        assert_eq!(ranks.len(), 2 * 2 * 4 * 4 * 2 * t);
        // Every router appears exactly t times.
        let mut counts = vec![0usize; 2 * 2 * 4 * 4 * 2];
        for &r in &ranks {
            counts[r] += 1;
        }
        assert!(counts.iter().all(|&c| c == t));
    }

    #[test]
    fn bgq_tabcde_strides_through_nodes() {
        // TABCDE: T slowest -> first num_nodes ranks all hit distinct
        // routers.
        let block = [2, 2, 2, 2, 2];
        let ranks = bgq_rank_placement(&block, 2, "TABCDE").unwrap();
        let nodes = 32;
        let mut seen: Vec<usize> = ranks[..nodes].to_vec();
        seen.sort_unstable();
        seen.dedup();
        assert_eq!(seen.len(), nodes);
    }

    #[test]
    fn malformed_rank_orders_are_structured_errors() {
        let block = [2, 2, 2, 2, 2];
        // Bad letter (the old panic path).
        assert_eq!(
            bgq_rank_placement(&block, 2, "ABCDEX"),
            Err(RankOrderError::BadLetter { letter: 'X' })
        );
        // Wrong length.
        assert_eq!(
            bgq_rank_placement(&block, 2, "ABC"),
            Err(RankOrderError::BadLength { got: 3 })
        );
        // Duplicate letter (previously silently skipped part of the block).
        assert_eq!(
            bgq_rank_placement(&block, 2, "AABCDE"),
            Err(RankOrderError::DuplicateLetter { letter: 'A' })
        );
        // Errors render as readable messages.
        assert!(RankOrderError::BadLetter { letter: 'X' }
            .to_string()
            .contains("bad rank-order letter"));
        // Lowercase is rejected too (orders are canonical uppercase).
        assert!(parse_rank_order("abcdet").is_err());
        assert!(parse_rank_order("ABCDET").is_ok());
    }

    #[test]
    fn gemini_curve_is_permutation() {
        let t = Torus::torus(&[6, 4, 8]);
        let order = gemini_curve_order(&t);
        let mut s = order.clone();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), t.num_routers());
    }

    #[test]
    fn gemini_curve_keeps_box_locality() {
        // Consecutive routers in curve order should usually be close: the
        // average hop distance between consecutive entries must be far below
        // random placement.
        let t = Torus::torus(&[8, 8, 8]);
        let order = gemini_curve_order(&t);
        let mut total = 0u64;
        for w in order.windows(2) {
            total += t.hop_dist_ids(w[0], w[1]);
        }
        let avg = total as f64 / (order.len() - 1) as f64;
        assert!(avg < 2.5, "curve locality poor: avg consecutive dist {avg}");
    }
}
