//! Machine presets for the paper's two platforms.

use super::allocation::SparseAllocator;
use super::torus::{BwModel, Torus};

/// A Cray XK7 Gemini torus of the given shape (wrapped in all dimensions,
/// heterogeneous Gemini link bandwidths).
pub fn cray_xk7(sizes: &[usize; 3]) -> Torus {
    Torus::new(sizes.to_vec(), vec![true; 3], BwModel::Gemini)
}

/// Titan's full Gemini torus: 25 x 16 x 24 routers = 9600 Geminis, 2 nodes
/// each = 19,200 node slots (18,688 compute nodes in the real machine; the
/// difference is service nodes, which the allocator's occupancy absorbs).
pub fn titan_full() -> SparseAllocator {
    SparseAllocator {
        machine: cray_xk7(&[25, 16, 24]),
        nodes_per_router: 2,
        ranks_per_node: 16,
        occupancy: 0.45,
    }
}

/// BG/Q block dimensions for a node count, following Mira's convention
/// (Section 5.2): complete 5D sub-toruses, power-of-two extents, E = 2.
/// 512 nodes -> 4x4x4x4x2 and 2048 -> 4x4x4x16x2, as the paper states.
pub fn bgq_block(num_nodes: usize) -> [usize; 5] {
    match num_nodes {
        128 => [2, 4, 4, 2, 2],
        256 => [4, 4, 4, 2, 2],
        512 => [4, 4, 4, 4, 2],
        1024 => [4, 4, 4, 8, 2],
        2048 => [4, 4, 4, 16, 2],
        4096 => [4, 4, 8, 16, 2],
        8192 => [4, 8, 8, 16, 2],
        16384 => [8, 8, 8, 16, 2],
        _ => {
            // General: split powers of two across A..D greedily, E = 2.
            assert!(
                num_nodes.is_power_of_two() && num_nodes >= 32,
                "BG/Q blocks are power-of-two node counts >= 32, got {num_nodes}"
            );
            let mut rem = num_nodes / 2;
            let mut dims = [1usize; 5];
            dims[4] = 2;
            let mut d = 3;
            while rem > 1 {
                if dims[d] < 16 {
                    dims[d] *= 2;
                    rem /= 2;
                }
                d = if d == 0 { 3 } else { d - 1 };
            }
            dims
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_block_shapes() {
        assert_eq!(bgq_block(512), [4, 4, 4, 4, 2]);
        assert_eq!(bgq_block(2048), [4, 4, 4, 16, 2]);
    }

    #[test]
    fn block_product_matches() {
        for n in [128usize, 256, 512, 1024, 2048, 4096, 8192, 16384] {
            assert_eq!(bgq_block(n).iter().product::<usize>(), n);
        }
    }

    #[test]
    fn generic_block_product_matches() {
        for n in [32usize, 64, 32768] {
            assert_eq!(bgq_block(n).iter().product::<usize>(), n, "n={n}");
        }
    }

    #[test]
    fn titan_shape() {
        let t = titan_full();
        assert_eq!(t.machine.num_routers(), 9600);
        assert_eq!(t.machine.dim(), 3);
    }

    #[test]
    fn xk7_links_are_gemini() {
        let t = cray_xk7(&[4, 4, 4]);
        assert_eq!(t.bw.bandwidth(2, 0), 120.0);
    }
}
