//! Job allocations: the set of nodes (and their routers) a job runs on, in
//! default rank order.
//!
//! An `Allocation` is the bridge between the machine model and the mapping
//! algorithm: it provides each MPI rank's router coordinates (the "machine
//! coordinates" of Section 4) and records node boundaries so metrics can
//! distinguish intra-node from network communication.

use super::rank_order::{bgq_rank_placement, gemini_curve_order, RankOrderError};
use super::topology::{Network, Topology};
use super::torus::Torus;
use crate::geom::Coords;
use crate::testutil::Rng;

/// A job's processor allocation. Ranks are indexed `0..num_ranks()` in the
/// platform's **default rank order** (ALPS placement order on Cray; the
/// chosen `ABCDET` permutation on BG/Q), so "default mapping" means
/// `task i -> rank i`.
///
/// Allocations may be **heterogeneous**: nodes are allowed to host
/// different rank counts (build one with [`Allocation::heterogeneous`]).
/// `ranks_per_node` is then the *nominal* (largest) node size; the exact
/// per-node structure always lives in `core_node`.
#[derive(Clone, Debug)]
pub struct Allocation {
    /// The machine (or job block) network — any [`Topology`]
    /// implementation; torus-only features gate on [`Network::as_torus`].
    pub machine: Network,
    /// Router id per rank.
    pub core_router: Vec<u32>,
    /// Node id per rank (nodes may share a router: 2 nodes/Gemini on XK7).
    pub core_node: Vec<u32>,
    /// Nominal ranks per node: the exact size of every node on uniform
    /// allocations, the largest node size on heterogeneous ones.
    pub ranks_per_node: usize,
}

/// Structured allocation-consistency errors (no silent truncation: a
/// `ranks_per_node` that does not divide the rank count used to make
/// `num_nodes` quietly drop the trailing node).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum AllocError {
    /// `num_ranks` is not a multiple of `ranks_per_node`, so a uniform
    /// node count is undefined.
    RaggedNodes {
        num_ranks: usize,
        ranks_per_node: usize,
    },
    /// `ranks_per_node` is zero or does not match the largest node size.
    BadRanksPerNode { claimed: usize, largest: usize },
    /// Some node id in `0..num_nodes()` has no ranks.
    EmptyNode { node: usize },
    /// Ranks of one node sit on different routers (which would let real
    /// network traffic be priced as free intra-node traffic).
    MixedRouters { node: usize },
    /// A heterogeneous constructor input mismatch.
    BadShape(String),
    /// A malformed BG/Q rank-order string (previously a process-crashing
    /// panic deep in `rank_order`).
    RankOrder(RankOrderError),
}

impl std::fmt::Display for AllocError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            AllocError::RaggedNodes {
                num_ranks,
                ranks_per_node,
            } => write!(
                f,
                "ranks_per_node {ranks_per_node} does not divide the {num_ranks} ranks"
            ),
            AllocError::BadRanksPerNode { claimed, largest } => write!(
                f,
                "ranks_per_node {claimed} does not match the largest node size {largest}"
            ),
            AllocError::EmptyNode { node } => write!(f, "node {node} has no ranks"),
            AllocError::MixedRouters { node } => {
                write!(f, "ranks of node {node} sit on different routers")
            }
            AllocError::BadShape(msg) => write!(f, "{msg}"),
            AllocError::RankOrder(e) => write!(f, "{e}"),
        }
    }
}

impl From<RankOrderError> for AllocError {
    fn from(e: RankOrderError) -> AllocError {
        AllocError::RankOrder(e)
    }
}

impl std::error::Error for AllocError {}

impl Allocation {
    pub fn num_ranks(&self) -> usize {
        self.core_router.len()
    }

    /// Exact number of nodes, derived from the per-rank node ids. (This
    /// used to be `num_ranks / ranks_per_node`, which silently truncated —
    /// and dropped the trailing node — whenever the rank count was not a
    /// multiple; see [`Allocation::uniform_num_nodes`] for the checked
    /// uniform view.)
    pub fn num_nodes(&self) -> usize {
        self.core_node.iter().map(|&n| n as usize + 1).max().unwrap_or(0)
    }

    /// The uniform node count `num_ranks / ranks_per_node`, as a structured
    /// error instead of a silent truncation when `ranks_per_node` does not
    /// divide the rank count. Heterogeneous allocations should use
    /// [`Allocation::num_nodes`].
    pub fn uniform_num_nodes(&self) -> Result<usize, AllocError> {
        if self.ranks_per_node == 0 || self.num_ranks() % self.ranks_per_node != 0 {
            return Err(AllocError::RaggedNodes {
                num_ranks: self.num_ranks(),
                ranks_per_node: self.ranks_per_node,
            });
        }
        Ok(self.num_ranks() / self.ranks_per_node)
    }

    /// Rank count of every node (ascending node id).
    pub fn node_sizes(&self) -> Vec<usize> {
        let mut sizes = vec![0usize; self.num_nodes()];
        for &n in &self.core_node {
            sizes[n as usize] += 1;
        }
        sizes
    }

    /// Whether every node hosts exactly `ranks_per_node` ranks.
    pub fn is_uniform(&self) -> bool {
        self.node_sizes().iter().all(|&s| s == self.ranks_per_node)
    }

    /// Check the allocation invariants the mapper and metrics rely on,
    /// returning the first violation as a structured error.
    pub fn validate(&self) -> Result<(), AllocError> {
        let sizes = self.node_sizes();
        if let Some(node) = sizes.iter().position(|&s| s == 0) {
            return Err(AllocError::EmptyNode { node });
        }
        let largest = sizes.iter().copied().max().unwrap_or(0);
        if self.ranks_per_node != largest {
            return Err(AllocError::BadRanksPerNode {
                claimed: self.ranks_per_node,
                largest,
            });
        }
        let mut routers = vec![u32::MAX; sizes.len()];
        for (rank, &node) in self.core_node.iter().enumerate() {
            let slot = &mut routers[node as usize];
            if *slot == u32::MAX {
                *slot = self.core_router[rank];
            } else if *slot != self.core_router[rank] {
                return Err(AllocError::MixedRouters {
                    node: node as usize,
                });
            }
        }
        Ok(())
    }

    /// Build a **heterogeneous** allocation: node `n` sits at router
    /// `node_routers[n]` and hosts `node_sizes[n]` ranks, in node-major
    /// default rank order. `ranks_per_node` is set to the largest node
    /// size (the nominal capacity the node-level mapper balances against).
    pub fn heterogeneous(
        machine: impl Into<Network>,
        node_routers: &[u32],
        node_sizes: &[usize],
    ) -> Result<Allocation, AllocError> {
        let machine = machine.into();
        if node_routers.len() != node_sizes.len() {
            return Err(AllocError::BadShape(format!(
                "{} routers for {} node sizes",
                node_routers.len(),
                node_sizes.len()
            )));
        }
        if node_sizes.is_empty() {
            return Err(AllocError::BadShape("no nodes".into()));
        }
        if let Some(node) = node_sizes.iter().position(|&s| s == 0) {
            return Err(AllocError::EmptyNode { node });
        }
        if let Some((node, &r)) = node_routers
            .iter()
            .enumerate()
            .find(|&(_, &r)| r as usize >= machine.num_routers())
        {
            return Err(AllocError::BadShape(format!(
                "node {node}: router {r} outside the {}-router network",
                machine.num_routers()
            )));
        }
        let total: usize = node_sizes.iter().sum();
        let mut core_router = Vec::with_capacity(total);
        let mut core_node = Vec::with_capacity(total);
        for (n, (&router, &size)) in node_routers.iter().zip(node_sizes).enumerate() {
            for _ in 0..size {
                core_router.push(router);
                core_node.push(n as u32);
            }
        }
        Ok(Allocation {
            machine,
            core_router,
            core_node,
            ranks_per_node: node_sizes.iter().copied().max().unwrap(),
        })
    }

    /// Geometric embedding of every rank's router as f64 points — the
    /// `pcoords` input of Algorithm 1 ([`Topology::embed_coords`]; for a
    /// torus these are the literal router coordinates). Ranks in the same
    /// node share coordinates; MJ's deterministic tie-breaking keeps them
    /// in the same part.
    pub fn proc_coords(&self) -> Coords {
        let dim = self.machine.embed_dim();
        let mut axes = vec![Vec::with_capacity(self.num_ranks()); dim];
        let mut buf = vec![0f64; dim];
        for &r in &self.core_router {
            self.machine.embed_coords(r as usize, &mut buf);
            for d in 0..dim {
                axes[d].push(buf[d]);
            }
        }
        Coords::from_axes(axes)
    }

    /// Router id of every node. Node ids must be dense in
    /// `0..num_nodes()` (both allocators uphold this); all ranks of a node
    /// share a router, so the first rank encountered defines it.
    pub fn node_routers(&self) -> Vec<u32> {
        let nn = self.num_nodes();
        let mut routers = vec![u32::MAX; nn];
        for (rank, &node) in self.core_node.iter().enumerate() {
            let slot = &mut routers[node as usize];
            if *slot == u32::MAX {
                *slot = self.core_router[rank];
            }
        }
        assert!(
            routers.iter().all(|&r| r != u32::MAX),
            "node ids must be dense in 0..num_nodes"
        );
        routers
    }

    /// Geometric embedding of every **node**'s router as f64 points — the
    /// machine side of the hierarchical (node-level) mapper, one point per
    /// node instead of one per rank.
    pub fn node_coords(&self) -> Coords {
        let dim = self.machine.embed_dim();
        let routers = self.node_routers();
        let mut axes = vec![Vec::with_capacity(routers.len()); dim];
        let mut buf = vec![0f64; dim];
        for &r in &routers {
            self.machine.embed_coords(r as usize, &mut buf);
            for d in 0..dim {
                axes[d].push(buf[d]);
            }
        }
        Coords::from_axes(axes)
    }

    /// Ranks grouped by node, each group in ascending rank order. Rank
    /// order within a node is the platform's default order, which is what
    /// the hierarchical mapper's intra-node strategies permute against.
    pub fn ranks_by_node(&self) -> Vec<Vec<u32>> {
        let mut by_node = vec![Vec::with_capacity(self.ranks_per_node); self.num_nodes()];
        for (rank, &node) in self.core_node.iter().enumerate() {
            by_node[node as usize].push(rank as u32);
        }
        by_node
    }

    /// Contiguous BG/Q block allocation (the whole job block is a complete
    /// torus — Section 2) with the given rank-order permutation. A
    /// malformed order string is a structured [`AllocError::RankOrder`]
    /// instead of a panic.
    pub fn bgq(
        block: [usize; 5],
        ranks_per_node: usize,
        perm: &str,
    ) -> Result<Allocation, AllocError> {
        let routers = bgq_rank_placement(&block, ranks_per_node, perm)?;
        let machine = Network::torus(&block);
        // On BG/Q one compute node attaches to each router.
        let core_node = routers.iter().map(|&r| r as u32).collect();
        Ok(Allocation {
            machine,
            core_router: routers.iter().map(|&r| r as u32).collect(),
            core_node,
            ranks_per_node,
        })
    }
}

/// ALPS-style sparse allocator for Cray systems (Section 2): available nodes
/// are selected in space-filling-curve order; other jobs' nodes fragment the
/// allocation. `occupancy` is the fraction of the machine already in use.
#[derive(Clone, Debug)]
pub struct SparseAllocator {
    pub machine: Torus,
    pub nodes_per_router: usize,
    pub ranks_per_node: usize,
    /// Fraction of machine nodes held by other jobs (0.0 = empty machine =>
    /// contiguous-ish allocation; higher = sparser).
    pub occupancy: f64,
}

impl SparseAllocator {
    /// Allocate one job per `(num_nodes, seed)` entry, fanned out over the
    /// thread budget. Each job is deterministic per seed and results land
    /// in input order, so batch allocation is thread-count-invariant —
    /// this is what the coordinator's experiment sweeps call instead of a
    /// sequential allocate-per-seed loop.
    pub fn allocate_batch(
        &self,
        jobs: &[(usize, u64)],
        par: crate::par::Parallelism,
    ) -> Vec<Allocation> {
        crate::par::map(par, jobs, |_, &(nodes, seed)| self.allocate(nodes, seed))
    }

    /// Allocate `num_nodes` nodes for a job. Deterministic per seed.
    pub fn allocate(&self, num_nodes: usize, seed: u64) -> Allocation {
        let mut rng = Rng::new(seed);
        let curve = gemini_curve_order(&self.machine);
        // Node slots in curve order: nodes attached to the same router are
        // consecutive (ALPS assigns both Gemini nodes together).
        let total_nodes = curve.len() * self.nodes_per_router;
        assert!(
            num_nodes <= total_nodes,
            "requested {num_nodes} nodes > machine capacity {total_nodes}"
        );
        // Mark pre-occupied nodes. We occupy in contiguous curve runs (jobs
        // are curve-contiguous), which is what fragments real allocations.
        let mut occupied = vec![false; total_nodes];
        let target_occupied =
            ((total_nodes as f64) * self.occupancy).round() as usize;
        let mut occupied_count = 0usize;
        while occupied_count < target_occupied {
            // Random job: curve-contiguous run of 4..=256 nodes.
            let len = 4usize << rng.below(7); // 4..256
            let start = rng.below(total_nodes);
            for i in 0..len.min(target_occupied - occupied_count + len) {
                let slot = (start + i) % total_nodes;
                if !occupied[slot] {
                    occupied[slot] = true;
                    occupied_count += 1;
                    if occupied_count >= target_occupied {
                        break;
                    }
                }
            }
        }
        // Allocate our job: first free nodes in curve order from a random
        // start offset (ALPS scans from its current position, not 0).
        let start = rng.below(total_nodes);
        let mut node_slots = Vec::with_capacity(num_nodes);
        for i in 0..total_nodes {
            let slot = (start + i) % total_nodes;
            if !occupied[slot] {
                node_slots.push(slot);
                if node_slots.len() == num_nodes {
                    break;
                }
            }
        }
        assert_eq!(
            node_slots.len(),
            num_nodes,
            "machine too full: only {} of {num_nodes} nodes free",
            node_slots.len()
        );
        let mut core_router = Vec::with_capacity(num_nodes * self.ranks_per_node);
        let mut core_node = Vec::with_capacity(num_nodes * self.ranks_per_node);
        for (node_idx, &slot) in node_slots.iter().enumerate() {
            let router = curve[slot / self.nodes_per_router];
            for _ in 0..self.ranks_per_node {
                core_router.push(router as u32);
                core_node.push(node_idx as u32);
            }
        }
        Allocation {
            machine: self.machine.clone().into(),
            core_router,
            core_node,
            ranks_per_node: self.ranks_per_node,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bgq_allocation_shape() {
        let a = Allocation::bgq([2, 2, 2, 4, 2], 4, "ABCDET").unwrap();
        assert_eq!(a.num_ranks(), 64 * 4);
        assert_eq!(a.num_nodes(), 64);
        assert_eq!(a.proc_coords().dim(), 5);
        assert_eq!(a.proc_coords().len(), 256);
    }

    #[test]
    fn bgq_consecutive_ranks_share_node() {
        let a = Allocation::bgq([2, 2, 2, 2, 2], 8, "ABCDET").unwrap();
        for r in 0..8 {
            assert_eq!(a.core_node[r], a.core_node[0]);
        }
        assert_ne!(a.core_node[8], a.core_node[0]);
    }

    #[test]
    fn node_views_are_consistent() {
        let alloc = SparseAllocator {
            machine: Torus::torus(&[6, 6, 6]),
            nodes_per_router: 2,
            ranks_per_node: 4,
            occupancy: 0.3,
        }
        .allocate(20, 13);
        let routers = alloc.node_routers();
        let coords = alloc.node_coords();
        let groups = alloc.ranks_by_node();
        assert_eq!(routers.len(), 20);
        assert_eq!(coords.len(), 20);
        assert_eq!(coords.dim(), 3);
        assert_eq!(groups.len(), 20);
        for (node, group) in groups.iter().enumerate() {
            assert_eq!(group.len(), 4, "node {node}");
            for &rank in group {
                assert_eq!(alloc.core_node[rank as usize] as usize, node);
                assert_eq!(alloc.core_router[rank as usize], routers[node]);
            }
            // Ascending rank order within the node.
            for w in group.windows(2) {
                assert!(w[0] < w[1]);
            }
            // Node coordinates are the router's embedding (its torus
            // coordinates here), read through the scratch entry point.
            let mut want = vec![0f64; alloc.machine.embed_dim()];
            alloc.machine.embed_coords(routers[node] as usize, &mut want);
            assert_eq!(coords.point_vec(node), want);
        }
    }

    #[test]
    fn node_views_cover_bgq_permuted_orders() {
        // With T first in the permutation, the ranks of one node are not
        // contiguous; the node views must still group them correctly.
        let a = Allocation::bgq([2, 2, 2, 2, 2], 4, "TABCDE").unwrap();
        let groups = a.ranks_by_node();
        assert_eq!(groups.len(), a.num_nodes());
        let mut seen = 0usize;
        for (node, group) in groups.iter().enumerate() {
            assert_eq!(group.len(), 4, "node {node}");
            seen += group.len();
            for &rank in group {
                assert_eq!(a.core_node[rank as usize] as usize, node);
            }
        }
        assert_eq!(seen, a.num_ranks());
    }

    #[test]
    fn num_nodes_is_exact_not_truncated() {
        // 10 ranks over nodes of sizes 4/3/3 with nominal ranks_per_node 4:
        // the old `num_ranks / ranks_per_node` would report 2 nodes and
        // silently drop node 2; the derived count is exact.
        let a = Allocation::heterogeneous(Torus::torus(&[4]), &[0, 1, 2], &[4, 3, 3]).unwrap();
        assert_eq!(a.num_ranks(), 10);
        assert_eq!(a.num_nodes(), 3);
        assert_eq!(a.node_sizes(), vec![4, 3, 3]);
        assert!(!a.is_uniform());
        assert!(a.validate().is_ok());
        // The uniform view errors instead of truncating.
        assert_eq!(
            a.uniform_num_nodes(),
            Err(AllocError::RaggedNodes {
                num_ranks: 10,
                ranks_per_node: 4
            })
        );
        // Node views stay consistent on heterogeneous shapes.
        assert_eq!(a.node_routers(), vec![0, 1, 2]);
        let groups = a.ranks_by_node();
        assert_eq!(groups.iter().map(Vec::len).collect::<Vec<_>>(), vec![4, 3, 3]);
    }

    #[test]
    fn uniform_num_nodes_accepts_divisible() {
        let a = Allocation::bgq([2, 2, 2, 2, 2], 4, "ABCDET").unwrap();
        assert_eq!(a.uniform_num_nodes(), Ok(32));
        assert!(a.is_uniform());
        assert!(a.validate().is_ok());
    }

    #[test]
    fn heterogeneous_rejects_bad_shapes() {
        let torus = Torus::torus(&[4]);
        assert!(matches!(
            Allocation::heterogeneous(torus.clone(), &[0, 1], &[2]),
            Err(AllocError::BadShape(_))
        ));
        assert!(matches!(
            Allocation::heterogeneous(torus.clone(), &[0, 1], &[2, 0]),
            Err(AllocError::EmptyNode { node: 1 })
        ));
        assert!(matches!(
            Allocation::heterogeneous(torus, &[0, 9], &[2, 2]),
            Err(AllocError::BadShape(_))
        ));
    }

    #[test]
    fn validate_reports_structured_errors() {
        let mut a =
            Allocation::heterogeneous(Torus::torus(&[4]), &[0, 1], &[2, 2]).unwrap();
        a.ranks_per_node = 3;
        assert_eq!(
            a.validate(),
            Err(AllocError::BadRanksPerNode {
                claimed: 3,
                largest: 2
            })
        );
        a.ranks_per_node = 2;
        a.core_router[1] = 2; // split node 0 across routers
        assert_eq!(a.validate(), Err(AllocError::MixedRouters { node: 0 }));
        // Errors render as readable messages.
        assert!(AllocError::RaggedNodes {
            num_ranks: 10,
            ranks_per_node: 4
        }
        .to_string()
        .contains("does not divide"));
    }

    #[test]
    fn sparse_allocation_deterministic() {
        let alloc = SparseAllocator {
            machine: Torus::torus(&[8, 8, 8]),
            nodes_per_router: 2,
            ranks_per_node: 4,
            occupancy: 0.4,
        };
        let a = alloc.allocate(100, 42);
        let b = alloc.allocate(100, 42);
        assert_eq!(a.core_router, b.core_router);
        let c = alloc.allocate(100, 43);
        assert_ne!(a.core_router, c.core_router);
    }

    #[test]
    fn sparse_allocation_distinct_nodes() {
        let alloc = SparseAllocator {
            machine: Torus::torus(&[6, 4, 8]),
            nodes_per_router: 2,
            ranks_per_node: 2,
            occupancy: 0.3,
        };
        let a = alloc.allocate(50, 7);
        assert_eq!(a.num_nodes(), 50);
        assert_eq!(a.num_ranks(), 100);
        // Nodes ids are 0..50 in order; each appears ranks_per_node times.
        for (i, &n) in a.core_node.iter().enumerate() {
            assert_eq!(n as usize, i / 2);
        }
    }

    #[test]
    fn zero_occupancy_is_curve_contiguous() {
        let machine = Torus::torus(&[8, 8, 8]);
        let alloc = SparseAllocator {
            machine: machine.clone(),
            nodes_per_router: 2,
            ranks_per_node: 1,
            occupancy: 0.0,
        };
        let a = alloc.allocate(64, 1);
        // With an empty machine the allocation is a contiguous curve run, so
        // consecutive allocated routers stay close.
        let mut total = 0u64;
        let mut cnt = 0u64;
        for w in a.core_router.windows(2) {
            if w[0] != w[1] {
                total += machine.hop_dist_ids(w[0] as usize, w[1] as usize);
                cnt += 1;
            }
        }
        assert!((total as f64 / cnt as f64) < 3.0);
    }

    #[test]
    fn higher_occupancy_spreads_allocation() {
        let machine = Torus::torus(&[12, 8, 12]);
        let mk = |occ: f64| SparseAllocator {
            machine: machine.clone(),
            nodes_per_router: 2,
            ranks_per_node: 1,
            occupancy: occ,
        };
        let spread = |a: &Allocation| -> f64 {
            let mut total = 0u64;
            for w in a.core_router.windows(2) {
                total += machine.hop_dist_ids(w[0] as usize, w[1] as usize);
            }
            total as f64 / (a.core_router.len() - 1) as f64
        };
        // Average over seeds to avoid flakiness.
        let avg = |occ: f64| -> f64 {
            (0..5).map(|s| spread(&mk(occ).allocate(128, s))).sum::<f64>() / 5.0
        };
        assert!(avg(0.6) > avg(0.0), "sparse allocation should spread");
    }
}
