//! Fat-tree (k-ary tree) network model.
//!
//! A `FatTree::new(levels, radix)` is a complete `radix`-ary tree of
//! `levels` switch levels below the root; the `radix^levels` leaves are the
//! routers compute nodes attach to. Distance between two leaves is
//! `2 * (levels above the nearest common ancestor)` — up to the NCA, down
//! again — matching the classic static fat-tree hop count.
//!
//! **Embedding** (what the geometric sweep partitions): each leaf maps to
//! its `levels` base-`radix` digits, most-significant (top-level pod)
//! first. Leaves sharing a pod prefix are co-located along the leading
//! axes, so multisection cuts separate top-level pods before subpods —
//! geometric locality in the embedding is subtree locality in the tree,
//! which is exactly what minimizes up/down traffic.
//!
//! **Links**: every non-root tree node `m` (heap-style numbering, root 0)
//! owns two directed links — up `2(m-1)` toward its parent and down
//! `2(m-1)+1` from its parent. Link class = the child node's level - 1
//! (`levels` classes: class 0 = links below the root), dir 0 = up,
//! 1 = down. Bandwidth is uniform 1.0 (an ideal fully-provisioned
//! fat-tree; congestion contrast comes from path multiplicity, not link
//! speeds).

use super::topology::Topology;

/// Complete k-ary fat-tree; routers are the leaves.
#[derive(Clone, Debug)]
pub struct FatTree {
    levels: usize,
    radix: usize,
    /// `radix^l` for `l in 0..=levels`.
    pows: Vec<usize>,
    /// First tree-node index of each level: `offset[l] = (k^l - 1)/(k - 1)`,
    /// plus a final entry holding the total node count.
    offsets: Vec<usize>,
}

impl FatTree {
    /// A tree of `levels >= 1` switch levels with `radix >= 2` children per
    /// switch: `radix^levels` leaf routers.
    pub fn new(levels: usize, radix: usize) -> FatTree {
        assert!(levels >= 1, "fat-tree needs at least one level");
        assert!(radix >= 2, "fat-tree radix must be >= 2");
        let mut pows = Vec::with_capacity(levels + 1);
        let mut p = 1usize;
        for _ in 0..=levels {
            pows.push(p);
            p = p.checked_mul(radix).expect("fat-tree size overflow");
        }
        let mut offsets = Vec::with_capacity(levels + 2);
        let mut off = 0usize;
        for l in 0..=levels + 1 {
            offsets.push(off);
            if l <= levels {
                off += pows[l];
            }
        }
        FatTree {
            levels,
            radix,
            pows,
            offsets,
        }
    }

    pub fn levels(&self) -> usize {
        self.levels
    }

    pub fn radix(&self) -> usize {
        self.radix
    }

    /// Total switch/leaf nodes in the tree.
    fn num_nodes(&self) -> usize {
        self.offsets[self.levels + 1]
    }

    /// Tree-node index of leaf `x`'s ancestor at `level` (0 = root,
    /// `levels` = the leaf itself).
    #[inline]
    fn ancestor(&self, leaf: usize, level: usize) -> usize {
        self.offsets[level] + leaf / self.pows[self.levels - level]
    }

    /// Level of the nearest common ancestor of two leaves.
    #[inline]
    fn nca_level(&self, a: usize, b: usize) -> usize {
        let mut l = self.levels;
        let (mut a, mut b) = (a, b);
        while a != b {
            a /= self.radix;
            b /= self.radix;
            l -= 1;
        }
        l
    }

    /// Level of tree node `m`.
    #[inline]
    fn level_of(&self, m: usize) -> usize {
        // levels is small (a handful); linear scan beats binary search.
        let mut l = 0usize;
        while self.offsets[l + 1] <= m {
            l += 1;
        }
        l
    }
}

impl Topology for FatTree {
    fn num_routers(&self) -> usize {
        self.pows[self.levels]
    }

    fn hop_dist_ids(&self, a: usize, b: usize) -> u64 {
        2 * (self.levels - self.nca_level(a, b)) as u64
    }

    fn num_directed_links(&self) -> usize {
        2 * (self.num_nodes() - 1)
    }

    fn route_ids(&self, a: usize, b: usize, visit: &mut dyn FnMut(usize)) {
        if a == b {
            return;
        }
        let nca = self.nca_level(a, b);
        // Ascend: up-links of a's ancestors, leaf-side first.
        for level in (nca + 1..=self.levels).rev() {
            let m = self.ancestor(a, level);
            visit(2 * (m - 1));
        }
        // Descend: down-links of b's ancestors, NCA-side first.
        for level in nca + 1..=self.levels {
            let m = self.ancestor(b, level);
            visit(2 * (m - 1) + 1);
        }
    }

    fn for_each_link(&self, visit: &mut dyn FnMut(usize, usize, usize, f64)) {
        for m in 1..self.num_nodes() {
            let class = self.level_of(m) - 1;
            visit(2 * (m - 1), class, 0, 1.0);
            visit(2 * (m - 1) + 1, class, 1, 1.0);
        }
    }

    fn num_link_classes(&self) -> usize {
        self.levels
    }

    fn embed_dim(&self) -> usize {
        self.levels
    }

    fn embed_coords(&self, id: usize, out: &mut [f64]) {
        // Base-radix digits, most-significant (top pod) first.
        let mut r = id;
        for l in (0..self.levels).rev() {
            out[l] = (r % self.radix) as f64;
            r /= self.radix;
        }
    }

    fn coord_dim(&self) -> usize {
        1
    }

    fn router_of_coords(&self, coords: &[usize]) -> Option<usize> {
        match coords {
            [leaf] if *leaf < self.pows[self.levels] => Some(*leaf),
            _ => None,
        }
    }

    fn kind_name(&self) -> &'static str {
        "fattree"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn distance_is_twice_levels_above_nca() {
        // 2-level binary tree: leaves 0..4.
        let t = FatTree::new(2, 2);
        assert_eq!(t.num_routers(), 4);
        assert_eq!(t.hop_dist_ids(0, 0), 0);
        assert_eq!(t.hop_dist_ids(0, 1), 2); // siblings: NCA one level up
        assert_eq!(t.hop_dist_ids(0, 2), 4); // NCA = root
        assert_eq!(t.hop_dist_ids(1, 3), 4);
        assert_eq!(t.hop_dist_ids(2, 3), 2);
    }

    #[test]
    fn route_length_matches_distance_and_is_up_then_down() {
        let t = FatTree::new(3, 3);
        for (a, b) in [(0usize, 1usize), (0, 26), (5, 14), (7, 7), (13, 12)] {
            let mut links = Vec::new();
            t.route_ids(a, b, &mut |l| links.push(l));
            assert_eq!(links.len() as u64, t.hop_dist_ids(a, b), "{a}->{b}");
            // Up-links (even index) strictly before down-links (odd).
            let first_down = links.iter().position(|l| l % 2 == 1);
            if let Some(fd) = first_down {
                assert!(links[..fd].iter().all(|l| l % 2 == 0));
                assert!(links[fd..].iter().all(|l| l % 2 == 1));
            }
        }
    }

    #[test]
    fn link_space_is_dense_and_fully_used() {
        let t = FatTree::new(2, 3);
        // 13 tree nodes -> 24 directed links, all existing.
        assert_eq!(t.num_directed_links(), 24);
        let mut seen = vec![false; 24];
        t.for_each_link(&mut |l, class, dir, bw| {
            assert!(!seen[l]);
            seen[l] = true;
            assert!(class < 2);
            assert_eq!(l % 2, dir);
            assert_eq!(bw, 1.0);
        });
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn embedding_is_pod_digits_msb_first() {
        let t = FatTree::new(2, 4);
        let mut out = [0f64; 2];
        t.embed_coords(0, &mut out);
        assert_eq!(out, [0.0, 0.0]);
        t.embed_coords(7, &mut out); // 7 = 1*4 + 3
        assert_eq!(out, [1.0, 3.0]);
        t.embed_coords(14, &mut out); // 14 = 3*4 + 2
        assert_eq!(out, [3.0, 2.0]);
    }

    #[test]
    fn coords_name_leaves() {
        let t = FatTree::new(2, 4);
        assert_eq!(t.router_of_coords(&[11]), Some(11));
        assert_eq!(t.router_of_coords(&[16]), None);
        assert_eq!(t.router_of_coords(&[1, 2]), None);
    }

    #[test]
    fn sibling_routes_share_no_links_with_far_routes_start() {
        // A sibling route stays below the level-1 switch; a cross-pod route
        // must climb to the root.
        let t = FatTree::new(2, 2);
        let mut sib = Vec::new();
        t.route_ids(0, 1, &mut |l| sib.push(l));
        assert_eq!(sib.len(), 2);
        let mut far = Vec::new();
        t.route_ids(0, 3, &mut |l| far.push(l));
        assert_eq!(far.len(), 4);
        // The far route's second up-link is a level-0-class link.
        let mut class_of = std::collections::HashMap::new();
        t.for_each_link(&mut |l, c, _, _| {
            class_of.insert(l, c);
        });
        assert_eq!(class_of[&far[1]], 0);
        assert_eq!(class_of[&far[0]], 1);
    }
}
