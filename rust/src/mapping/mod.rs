//! Task mapping (Section 4.2, Algorithm 1): partition task coordinates and
//! processor coordinates into the same number of parts, then assign tasks
//! to the ranks holding the same part number.
//!
//! Submodules implement the quality improvements of Section 4.3:
//! * [`shift`] — torus wraparound coordinate shifting,
//! * [`rotations`] — the td!·pd! rotation sweep scored by WeightedHops,
//! * [`transforms`] — bandwidth scaling, Z2_3 box transform, axis dropping,
//! * [`kmeans`] — core-subset selection for the `tnum < pnum` case,
//! * [`pipeline`] — the named Z2 strategy bundles (Z2_1/Z2_2/Z2_3, +E).
//!
//! # Hot-path structure
//!
//! The rotation sweep evaluates up to `td!·pd!` candidates, but candidates
//! sharing a processor-axis permutation share an identical processor-side
//! partition. [`prepare_proc_partition`] computes that proc side once per
//! distinct permutation (kept in a [`ProcPartitionCache`]) and
//! [`map_tasks_with_proc`] joins each candidate's task partition against
//! it — turning up to 6× redundant processor partitions into cache hits.
//! Both halves run through the [`MjScratch`]/[`MappingScratch`] arenas so
//! steady-state mapping allocates only its output vector.

pub mod kmeans;
pub mod pipeline;
pub mod rotations;
pub mod shift;
pub mod transforms;

use crate::geom::Coords;
use crate::mj::{mj_partition_axes_into, MjConfig, MjScratch};
use crate::par::Parallelism;
use crate::sfc::hilbert::hilbert_sort_f64;
use crate::sfc::PartOrdering;
use std::collections::HashMap;
use std::sync::{Arc, Mutex};

/// Configuration for Algorithm 1.
#[derive(Clone, Copy, Debug)]
pub struct MapConfig {
    /// Part numbering for the task partition.
    pub task_ordering: PartOrdering,
    /// Part numbering for the processor partition.
    pub proc_ordering: PartOrdering,
    /// Longest-dimension cut selection (Section 4.3).
    pub longest_dim: bool,
    /// Uneven bisection by largest prime divisor (Z2_2/Z2_3).
    pub uneven_prime: bool,
}

impl Default for MapConfig {
    fn default() -> Self {
        MapConfig {
            task_ordering: PartOrdering::FZ,
            proc_ordering: PartOrdering::FZ,
            longest_dim: true,
            uneven_prime: false,
        }
    }
}

impl MapConfig {
    /// Uniform ordering on both sides.
    pub fn with_ordering(ordering: PartOrdering) -> Self {
        MapConfig {
            task_ordering: ordering,
            proc_ordering: ordering,
            ..Default::default()
        }
    }

    fn mj(&self, ordering: PartOrdering) -> MjConfig {
        MjConfig {
            ordering,
            longest_dim: self.longest_dim,
            uneven_prime: self.uneven_prime,
        }
    }
}

/// The shared mapping knobs every pipeline level consumes: what to
/// optimize (`objective` × `numa`), the worker-thread budget, and the
/// optional multilevel coarsening pre-pass. [`rotations::SweepConfig`],
/// [`pipeline::Z2Config`], and [`crate::hier::HierConfig`] each embed one
/// `MapSpec` (and convert from one via `From`), so these knobs are
/// declared — and documented — exactly once instead of once per config.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct MapSpec {
    /// What the mapper minimizes. `WeightedHops` (the paper's Eqn. 3)
    /// scores through the batched f32 kernel backend on torus machines;
    /// routed objectives — and every objective on non-torus topologies —
    /// score through the sequential f64 evaluator, so results stay
    /// bit-identical at every thread count either way.
    pub objective: crate::objective::ObjectiveKind,
    /// NUMA model of a node: when set, node-level scoring prices
    /// still-unsplit intra-node edges at the topology's socket cost, and
    /// the hierarchical mapper runs at depth 3 (socket split + refinement
    /// inside each node). See [`crate::objective::EvalSpec::validate`]
    /// for the supported `objective` × `numa` combinations.
    pub numa: Option<crate::machine::NumaTopology>,
    /// Worker threads: `0` = auto (`TASKMAP_THREADS` or the machine's
    /// parallelism), `1` = the sequential reference path. Every mapper
    /// is bit-identical at every thread count.
    pub threads: usize,
    /// Multilevel coarsening V-cycle in front of the node-level sweep
    /// ([`crate::coarsen`]); implies hierarchical mode in the Z2
    /// pipeline. Ignored by the flat rotation sweep itself.
    pub coarsen: Option<crate::coarsen::CoarsenConfig>,
}

impl MapSpec {
    /// The thread budget as a [`Parallelism`].
    pub fn parallelism(&self) -> Parallelism {
        match self.threads {
            0 => Parallelism::auto(),
            n => Parallelism::threads(n),
        }
    }

    /// The unified-evaluator spec: the objective plus the node-level
    /// NUMA costs derived from the topology (if any).
    pub fn eval_spec(&self) -> crate::objective::eval::EvalSpec {
        crate::objective::eval::EvalSpec::new(
            self.objective,
            self.numa.map(|t| t.node_level_costs()),
        )
    }
}

/// Chop a coordinate set into `np` balanced parts along the Hilbert curve,
/// writing part ids into `part`.
fn hilbert_partition_into(coords: &Coords, np: usize, part: &mut Vec<u32>) {
    let bits = (128 / coords.dim().max(1)).min(16) as u32;
    let order = hilbert_sort_f64(coords, bits);
    let n = coords.len();
    let base = n / np;
    let extra = n % np;
    part.clear();
    part.resize(n, 0);
    let mut pos = 0usize;
    for p in 0..np {
        let len = base + usize::from(p < extra);
        for _ in 0..len {
            part[order[pos]] = p as u32;
            pos += 1;
        }
    }
}

fn is_identity(perm: &[usize]) -> bool {
    perm.iter().enumerate().all(|(i, &p)| i == p)
}

/// Partition a coordinate set into `np` parts under the given ordering.
/// `Hilbert` ranks points along the Hilbert curve and chops the order into
/// balanced chunks; everything else is an MJ bisection numbering.
pub fn partition_ordered(
    coords: &Coords,
    np: usize,
    ordering: PartOrdering,
    cfg: &MapConfig,
) -> Vec<u32> {
    let ident: Vec<usize> = (0..coords.dim()).collect();
    let mut scratch = MjScratch::new();
    let mut part = Vec::new();
    partition_ordered_axes_into(
        coords,
        &ident,
        np,
        ordering,
        cfg,
        Parallelism::auto(),
        &mut scratch,
        &mut part,
    );
    part
}

/// [`partition_ordered`] through an axis permutation, into reused buffers.
/// Equivalent to `partition_ordered(&coords.permute_axes(perm), ..)` but
/// (for the MJ orderings) without materializing the permuted coordinates.
#[allow(clippy::too_many_arguments)]
pub fn partition_ordered_axes_into(
    coords: &Coords,
    perm: &[usize],
    np: usize,
    ordering: PartOrdering,
    cfg: &MapConfig,
    par: Parallelism,
    scratch: &mut MjScratch,
    part: &mut Vec<u32>,
) {
    match ordering {
        PartOrdering::Hilbert => {
            // The Hilbert index depends on axis order, so the permuted view
            // must be materialized here (rare path: no Z2 strategy uses it).
            if is_identity(perm) {
                hilbert_partition_into(coords, np, part);
            } else {
                hilbert_partition_into(&coords.permute_axes(perm), np, part);
            }
        }
        _ => mj_partition_axes_into(coords, perm, np, &cfg.mj(ordering), par, scratch, part),
    }
}

/// The processor side of Algorithm 1, precomputed for a fixed
/// `(pcoords, pperm, tnum, cfg)`: the partition of the (possibly
/// subset-restricted) processor coordinates, plus the closest-subset rank
/// selection when `tnum < pnum`. Candidates of a rotation sweep that share
/// a processor-axis permutation share this value — see
/// [`ProcPartitionCache`].
#[derive(Clone, Debug)]
pub struct ProcPartition {
    /// `Some(subset)` iff `tnum < pnum`: global rank ids of the compact
    /// k-means subset actually used (Section 4.2 case 3).
    subset: Option<Vec<usize>>,
    /// Part id per (subset) rank, `np` parts.
    proc_part: Vec<u32>,
    /// Number of parts both sides are split into.
    np: usize,
}

impl ProcPartition {
    pub fn np(&self) -> usize {
        self.np
    }
}

/// Compute the processor side for mapping `tnum` tasks onto `pcoords`
/// viewed through the axis permutation `pperm`.
pub fn prepare_proc_partition(
    pcoords: &Coords,
    pperm: &[usize],
    tnum: usize,
    cfg: &MapConfig,
    par: Parallelism,
    scratch: &mut MjScratch,
) -> ProcPartition {
    let pnum = pcoords.len();
    assert!(tnum > 0 && pnum > 0);
    let mut proc_part = Vec::new();
    if tnum < pnum {
        // Section 4.2 case 3: choose the most compact tnum-rank subset,
        // then partition it. k-means distances sum per-axis, so the subset
        // is computed on the materialized permuted view to keep results
        // identical to mapping `pcoords.permute_axes(pperm)` directly.
        let permuted = pcoords.permute_axes(pperm);
        let subset = kmeans::closest_subset(&permuted, tnum, 20);
        let sub = permuted.gather(&subset);
        let ident: Vec<usize> = (0..sub.dim()).collect();
        partition_ordered_axes_into(
            &sub,
            &ident,
            tnum,
            cfg.proc_ordering,
            cfg,
            par,
            scratch,
            &mut proc_part,
        );
        ProcPartition {
            subset: Some(subset),
            proc_part,
            np: tnum,
        }
    } else {
        partition_ordered_axes_into(
            pcoords,
            pperm,
            pnum,
            cfg.proc_ordering,
            cfg,
            par,
            scratch,
            &mut proc_part,
        );
        ProcPartition {
            subset: None,
            proc_part,
            np: pnum,
        }
    }
}

/// Reusable buffers for the task side of [`map_tasks_with_proc`].
#[derive(Default)]
pub struct MappingScratch {
    mj: MjScratch,
    task_part: Vec<u32>,
}

impl MappingScratch {
    pub fn new() -> Self {
        MappingScratch::default()
    }
}

/// Algorithm 1 against a precomputed processor side: partition the task
/// coordinates (viewed through `tperm`) into `proc.np()` parts and join on
/// part number. Requires `tcoords.len() >= proc.np()`.
pub fn map_tasks_with_proc(
    tcoords: &Coords,
    tperm: &[usize],
    proc: &ProcPartition,
    cfg: &MapConfig,
    par: Parallelism,
    scratch: &mut MappingScratch,
) -> Vec<u32> {
    let np = proc.np;
    partition_ordered_axes_into(
        tcoords,
        tperm,
        np,
        cfg.task_ordering,
        cfg,
        par,
        &mut scratch.mj,
        &mut scratch.task_part,
    );
    let mapped = get_mapping_arrays(&scratch.task_part, &proc.proc_part, np);
    match &proc.subset {
        Some(subset) => mapped
            .into_iter()
            .map(|r| subset[r as usize] as u32)
            .collect(),
        None => mapped,
    }
}

/// Memoizes [`ProcPartition`]s per processor-axis permutation, for a fixed
/// `(pcoords, tnum, cfg)` context (one rotation sweep). Keys are the
/// permutation vectors; values are shared via `Arc` so concurrent candidate
/// workers borrow the same partition. Concurrent misses may compute the
/// same entry twice — results are deterministic, so either wins.
#[derive(Default)]
pub struct ProcPartitionCache {
    entries: Mutex<HashMap<Vec<usize>, Arc<ProcPartition>>>,
}

impl ProcPartitionCache {
    pub fn new() -> Self {
        ProcPartitionCache::default()
    }

    pub fn get(&self, pperm: &[usize]) -> Option<Arc<ProcPartition>> {
        self.entries.lock().unwrap().get(pperm).cloned()
    }

    pub fn insert(&self, pperm: Vec<usize>, proc: ProcPartition) -> Arc<ProcPartition> {
        let arc = Arc::new(proc);
        self.entries
            .lock()
            .unwrap()
            .entry(pperm)
            .or_insert_with(|| arc.clone())
            .clone()
    }

    /// Lookup, computing and caching on miss (the computation runs outside
    /// the lock).
    pub fn get_or_compute(
        &self,
        pcoords: &Coords,
        pperm: &[usize],
        tnum: usize,
        cfg: &MapConfig,
        par: Parallelism,
        scratch: &mut MjScratch,
    ) -> Arc<ProcPartition> {
        if let Some(hit) = self.get(pperm) {
            return hit;
        }
        let computed = prepare_proc_partition(pcoords, pperm, tnum, cfg, par, scratch);
        self.insert(pperm.to_vec(), computed)
    }

    pub fn len(&self) -> usize {
        self.entries.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Algorithm 1: map `tnum` tasks onto `pnum` ranks. Returns
/// `task_to_rank`. Handles all three cardinality cases:
///
/// 1. `tnum == pnum` — one-to-one;
/// 2. `tnum >  pnum` — both sides are split into `pnum` parts; every task
///    in a part is assigned to that part's rank (simultaneous partitioning
///    and mapping);
/// 3. `tnum <  pnum` — a closest subset of `tnum` ranks is selected by
///    k-means (Section 4.2 case 3) and the one-to-one mapping runs within
///    the subset; remaining ranks are idle.
pub fn map_tasks(tcoords: &Coords, pcoords: &Coords, cfg: &MapConfig) -> Vec<u32> {
    map_tasks_par(tcoords, pcoords, cfg, Parallelism::auto())
}

/// [`map_tasks`] with an explicit thread budget (the result does not depend
/// on the budget).
pub fn map_tasks_par(
    tcoords: &Coords,
    pcoords: &Coords,
    cfg: &MapConfig,
    par: Parallelism,
) -> Vec<u32> {
    let tnum = tcoords.len();
    let pnum = pcoords.len();
    assert!(tnum > 0 && pnum > 0);
    let mut mj = MjScratch::new();
    let pperm: Vec<usize> = (0..pcoords.dim()).collect();
    let proc = prepare_proc_partition(pcoords, &pperm, tnum, cfg, par, &mut mj);
    let mut scratch = MappingScratch {
        mj,
        task_part: Vec::new(),
    };
    let tperm: Vec<usize> = (0..tcoords.dim()).collect();
    map_tasks_with_proc(tcoords, &tperm, &proc, cfg, par, &mut scratch)
}

/// GetMappingArrays (Algorithm 1): join task parts and processor parts on
/// part number. Within a part, tasks and ranks are paired in index order;
/// when a part holds several tasks per rank they are distributed
/// round-robin.
pub fn get_mapping_arrays(task_part: &[u32], proc_part: &[u32], np: usize) -> Vec<u32> {
    // Bucket ranks by part (counting sort, index order preserved).
    let mut rank_count = vec![0u32; np];
    for &p in proc_part {
        rank_count[p as usize] += 1;
    }
    let mut rank_off = vec![0u32; np + 1];
    for p in 0..np {
        rank_off[p + 1] = rank_off[p] + rank_count[p];
    }
    let mut ranks_by_part = vec![0u32; proc_part.len()];
    let mut cursor = rank_off.clone();
    for (rank, &p) in proc_part.iter().enumerate() {
        ranks_by_part[cursor[p as usize] as usize] = rank as u32;
        cursor[p as usize] += 1;
    }
    // Assign tasks.
    let mut task_to_rank = vec![0u32; task_part.len()];
    let mut next_in_part = vec![0u32; np];
    for (task, &p) in task_part.iter().enumerate() {
        let p = p as usize;
        let nranks = rank_count[p];
        assert!(nranks > 0, "part {p} has tasks but no ranks");
        let slot = next_in_part[p] % nranks;
        task_to_rank[task] = ranks_by_part[(rank_off[p] + slot) as usize];
        next_in_part[p] += 1;
    }
    task_to_rank
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::stencil::stencil_graph;

    fn grid(dims: &[usize]) -> Coords {
        stencil_graph(dims, false, 1.0).coords
    }

    #[test]
    fn one_to_one_is_bijection() {
        let t = grid(&[8, 8]);
        let p = grid(&[4, 4, 4]);
        let m = map_tasks(&t, &p, &MapConfig::default());
        let mut s = m.clone();
        s.sort_unstable();
        assert_eq!(s, (0..64u32).collect::<Vec<_>>());
    }

    #[test]
    fn more_tasks_than_ranks_balances() {
        let t = grid(&[16, 16]); // 256 tasks
        let p = grid(&[4, 4]); // 16 ranks
        let m = map_tasks(&t, &p, &MapConfig::default());
        let mut loads = vec![0usize; 16];
        for &r in &m {
            loads[r as usize] += 1;
        }
        assert!(loads.iter().all(|&l| l == 16), "{loads:?}");
    }

    #[test]
    fn more_tasks_keeps_locality() {
        // Tasks assigned to one rank must be spatially compact: the average
        // intra-rank spread should be near the 4x4 block ideal.
        let t = grid(&[16, 16]);
        let p = grid(&[4, 4]);
        let m = map_tasks(&t, &p, &MapConfig::default());
        for rank in 0..16u32 {
            let pts: Vec<usize> = (0..256).filter(|&i| m[i] == rank).collect();
            let xs: Vec<f64> = pts.iter().map(|&i| t.get(0, i)).collect();
            let ys: Vec<f64> = pts.iter().map(|&i| t.get(1, i)).collect();
            let ext = |v: &[f64]| {
                v.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
                    - v.iter().cloned().fold(f64::INFINITY, f64::min)
            };
            assert!(ext(&xs) <= 4.0 && ext(&ys) <= 4.0, "rank {rank} spread");
        }
    }

    #[test]
    fn fewer_tasks_than_ranks_uses_subset() {
        let t = grid(&[4, 4]); // 16 tasks
        let p = grid(&[8, 8]); // 64 ranks
        let m = map_tasks(&t, &p, &MapConfig::default());
        // 16 distinct ranks used.
        let mut used = m.clone();
        used.sort_unstable();
        used.dedup();
        assert_eq!(used.len(), 16);
        // The chosen subset is compact (k-means "closest subset"): max
        // pairwise L1 distance bounded well below the full grid spread.
        let mut maxd = 0.0f64;
        for &a in &used {
            for &b in &used {
                let (pa, pb) = (p.point_vec(a as usize), p.point_vec(b as usize));
                let d = (pa[0] - pb[0]).abs() + (pa[1] - pb[1]).abs();
                maxd = maxd.max(d);
            }
        }
        assert!(maxd <= 8.0, "subset spread {maxd}");
    }

    #[test]
    fn hilbert_ordering_both_sides() {
        let t = grid(&[8, 8]);
        let p = grid(&[8, 8]);
        let cfg = MapConfig::with_ordering(PartOrdering::Hilbert);
        let m = map_tasks(&t, &p, &cfg);
        let mut s = m.clone();
        s.sort_unstable();
        assert_eq!(s, (0..64u32).collect::<Vec<_>>());
        // Identical geometry + identical curve => identity-ish mapping:
        // every task maps to the rank at its own grid position.
        for i in 0..64usize {
            assert_eq!(t.point_vec(i), p.point_vec(m[i] as usize));
        }
    }

    #[test]
    fn get_mapping_arrays_round_robin() {
        // 2 parts, 2 ranks each, 8 tasks: 2 tasks per rank.
        let task_part = [0, 0, 0, 0, 1, 1, 1, 1].map(|x| x as u32);
        let proc_part = [0, 1, 0, 1].map(|x| x as u32);
        let m = get_mapping_arrays(&task_part, &proc_part, 2);
        assert_eq!(m, vec![0, 2, 0, 2, 1, 3, 1, 3]);
    }

    #[test]
    fn mapping_deterministic() {
        let t = grid(&[9, 9]);
        let p = grid(&[3, 27]);
        let a = map_tasks(&t, &p, &MapConfig::default());
        let b = map_tasks(&t, &p, &MapConfig::default());
        assert_eq!(a, b);
    }

    #[test]
    fn memoized_proc_side_matches_direct_mapping() {
        // map_tasks_with_proc over a cached proc partition must reproduce
        // map_tasks on materialized permuted coordinates — for all three
        // cardinality cases.
        let cases: Vec<(Coords, Coords)> = vec![
            (grid(&[8, 8]), grid(&[4, 4, 4])), // tnum == pnum
            (grid(&[16, 8]), grid(&[4, 4])),   // tnum >  pnum
            (grid(&[4, 4]), grid(&[8, 8])),    // tnum <  pnum
        ];
        let cfg = MapConfig::default();
        for (t, p) in &cases {
            // One cache per (pcoords, tnum, cfg) context — that is its
            // contract (one rotation sweep).
            let cache = ProcPartitionCache::new();
            let tperm: Vec<usize> = (0..t.dim()).rev().collect();
            let pperm: Vec<usize> = (0..p.dim()).rev().collect();
            let mut mj = MjScratch::new();
            let proc = cache.get_or_compute(
                p,
                &pperm,
                t.len(),
                &cfg,
                Parallelism::sequential(),
                &mut mj,
            );
            // Second lookup must hit.
            assert!(cache.get(&pperm).is_some());
            assert_eq!(cache.len(), 1);
            let mut scratch = MappingScratch::new();
            let got = map_tasks_with_proc(
                t,
                &tperm,
                &proc,
                &cfg,
                Parallelism::sequential(),
                &mut scratch,
            );
            let want = map_tasks(&t.permute_axes(&tperm), &p.permute_axes(&pperm), &cfg);
            assert_eq!(got, want);
        }
    }
}
