//! Task mapping (Section 4.2, Algorithm 1): partition task coordinates and
//! processor coordinates into the same number of parts, then assign tasks
//! to the ranks holding the same part number.
//!
//! Submodules implement the quality improvements of Section 4.3:
//! * [`shift`] — torus wraparound coordinate shifting,
//! * [`rotations`] — the td!·pd! rotation sweep scored by WeightedHops,
//! * [`transforms`] — bandwidth scaling, Z2_3 box transform, axis dropping,
//! * [`kmeans`] — core-subset selection for the `tnum < pnum` case,
//! * [`pipeline`] — the named Z2 strategy bundles (Z2_1/Z2_2/Z2_3, +E).

pub mod kmeans;
pub mod pipeline;
pub mod rotations;
pub mod shift;
pub mod transforms;

use crate::geom::Coords;
use crate::mj::{mj_partition, MjConfig};
use crate::sfc::hilbert::hilbert_sort_f64;
use crate::sfc::PartOrdering;

/// Configuration for Algorithm 1.
#[derive(Clone, Copy, Debug)]
pub struct MapConfig {
    /// Part numbering for the task partition.
    pub task_ordering: PartOrdering,
    /// Part numbering for the processor partition.
    pub proc_ordering: PartOrdering,
    /// Longest-dimension cut selection (Section 4.3).
    pub longest_dim: bool,
    /// Uneven bisection by largest prime divisor (Z2_2/Z2_3).
    pub uneven_prime: bool,
}

impl Default for MapConfig {
    fn default() -> Self {
        MapConfig {
            task_ordering: PartOrdering::FZ,
            proc_ordering: PartOrdering::FZ,
            longest_dim: true,
            uneven_prime: false,
        }
    }
}

impl MapConfig {
    /// Uniform ordering on both sides.
    pub fn with_ordering(ordering: PartOrdering) -> Self {
        MapConfig {
            task_ordering: ordering,
            proc_ordering: ordering,
            ..Default::default()
        }
    }

    fn mj(&self, ordering: PartOrdering) -> MjConfig {
        MjConfig {
            ordering,
            longest_dim: self.longest_dim,
            uneven_prime: self.uneven_prime,
        }
    }
}

/// Partition a coordinate set into `np` parts under the given ordering.
/// `Hilbert` ranks points along the Hilbert curve and chops the order into
/// balanced chunks; everything else is an MJ bisection numbering.
pub fn partition_ordered(
    coords: &Coords,
    np: usize,
    ordering: PartOrdering,
    cfg: &MapConfig,
) -> Vec<u32> {
    match ordering {
        PartOrdering::Hilbert => {
            let bits = (128 / coords.dim().max(1)).min(16) as u32;
            let order = hilbert_sort_f64(coords, bits);
            let n = coords.len();
            let base = n / np;
            let extra = n % np;
            let mut part = vec![0u32; n];
            let mut pos = 0usize;
            for p in 0..np {
                let len = base + usize::from(p < extra);
                for _ in 0..len {
                    part[order[pos]] = p as u32;
                    pos += 1;
                }
            }
            part
        }
        _ => mj_partition(coords, np, &cfg.mj(ordering)),
    }
}

/// Algorithm 1: map `tnum` tasks onto `pnum` ranks. Returns
/// `task_to_rank`. Handles all three cardinality cases:
///
/// 1. `tnum == pnum` — one-to-one;
/// 2. `tnum >  pnum` — both sides are split into `pnum` parts; every task
///    in a part is assigned to that part's rank (simultaneous partitioning
///    and mapping);
/// 3. `tnum <  pnum` — a closest subset of `tnum` ranks is selected by
///    k-means (Section 4.2 case 3) and the one-to-one mapping runs within
///    the subset; remaining ranks are idle.
pub fn map_tasks(tcoords: &Coords, pcoords: &Coords, cfg: &MapConfig) -> Vec<u32> {
    let tnum = tcoords.len();
    let pnum = pcoords.len();
    assert!(tnum > 0 && pnum > 0);
    if tnum < pnum {
        let subset = kmeans::closest_subset(pcoords, tnum, 20);
        let sub_coords = pcoords.gather(&subset);
        let sub_map = map_tasks(tcoords, &sub_coords, cfg);
        return sub_map
            .into_iter()
            .map(|r| subset[r as usize] as u32)
            .collect();
    }
    let np = pnum;
    let task_part = partition_ordered(tcoords, np, cfg.task_ordering, cfg);
    let proc_part = partition_ordered(pcoords, np, cfg.proc_ordering, cfg);
    get_mapping_arrays(&task_part, &proc_part, np)
}

/// GetMappingArrays (Algorithm 1): join task parts and processor parts on
/// part number. Within a part, tasks and ranks are paired in index order;
/// when a part holds several tasks per rank they are distributed
/// round-robin.
pub fn get_mapping_arrays(task_part: &[u32], proc_part: &[u32], np: usize) -> Vec<u32> {
    // Bucket ranks by part (counting sort, index order preserved).
    let mut rank_count = vec![0u32; np];
    for &p in proc_part {
        rank_count[p as usize] += 1;
    }
    let mut rank_off = vec![0u32; np + 1];
    for p in 0..np {
        rank_off[p + 1] = rank_off[p] + rank_count[p];
    }
    let mut ranks_by_part = vec![0u32; proc_part.len()];
    let mut cursor = rank_off.clone();
    for (rank, &p) in proc_part.iter().enumerate() {
        ranks_by_part[cursor[p as usize] as usize] = rank as u32;
        cursor[p as usize] += 1;
    }
    // Assign tasks.
    let mut task_to_rank = vec![0u32; task_part.len()];
    let mut next_in_part = vec![0u32; np];
    for (task, &p) in task_part.iter().enumerate() {
        let p = p as usize;
        let nranks = rank_count[p];
        assert!(nranks > 0, "part {p} has tasks but no ranks");
        let slot = next_in_part[p] % nranks;
        task_to_rank[task] = ranks_by_part[(rank_off[p] + slot) as usize];
        next_in_part[p] += 1;
    }
    task_to_rank
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::stencil::stencil_graph;

    fn grid(dims: &[usize]) -> Coords {
        stencil_graph(dims, false, 1.0).coords
    }

    #[test]
    fn one_to_one_is_bijection() {
        let t = grid(&[8, 8]);
        let p = grid(&[4, 4, 4]);
        let m = map_tasks(&t, &p, &MapConfig::default());
        let mut s = m.clone();
        s.sort_unstable();
        assert_eq!(s, (0..64u32).collect::<Vec<_>>());
    }

    #[test]
    fn more_tasks_than_ranks_balances() {
        let t = grid(&[16, 16]); // 256 tasks
        let p = grid(&[4, 4]); // 16 ranks
        let m = map_tasks(&t, &p, &MapConfig::default());
        let mut loads = vec![0usize; 16];
        for &r in &m {
            loads[r as usize] += 1;
        }
        assert!(loads.iter().all(|&l| l == 16), "{loads:?}");
    }

    #[test]
    fn more_tasks_keeps_locality() {
        // Tasks assigned to one rank must be spatially compact: the average
        // intra-rank spread should be near the 4x4 block ideal.
        let t = grid(&[16, 16]);
        let p = grid(&[4, 4]);
        let m = map_tasks(&t, &p, &MapConfig::default());
        for rank in 0..16u32 {
            let pts: Vec<usize> = (0..256).filter(|&i| m[i] == rank).collect();
            let xs: Vec<f64> = pts.iter().map(|&i| t.get(0, i)).collect();
            let ys: Vec<f64> = pts.iter().map(|&i| t.get(1, i)).collect();
            let ext = |v: &[f64]| {
                v.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
                    - v.iter().cloned().fold(f64::INFINITY, f64::min)
            };
            assert!(ext(&xs) <= 4.0 && ext(&ys) <= 4.0, "rank {rank} spread");
        }
    }

    #[test]
    fn fewer_tasks_than_ranks_uses_subset() {
        let t = grid(&[4, 4]); // 16 tasks
        let p = grid(&[8, 8]); // 64 ranks
        let m = map_tasks(&t, &p, &MapConfig::default());
        // 16 distinct ranks used.
        let mut used = m.clone();
        used.sort_unstable();
        used.dedup();
        assert_eq!(used.len(), 16);
        // The chosen subset is compact (k-means "closest subset"): max
        // pairwise L1 distance bounded well below the full grid spread.
        let mut maxd = 0.0f64;
        for &a in &used {
            for &b in &used {
                let (pa, pb) = (p.point_vec(a as usize), p.point_vec(b as usize));
                let d = (pa[0] - pb[0]).abs() + (pa[1] - pb[1]).abs();
                maxd = maxd.max(d);
            }
        }
        assert!(maxd <= 8.0, "subset spread {maxd}");
    }

    #[test]
    fn hilbert_ordering_both_sides() {
        let t = grid(&[8, 8]);
        let p = grid(&[8, 8]);
        let cfg = MapConfig::with_ordering(PartOrdering::Hilbert);
        let m = map_tasks(&t, &p, &cfg);
        let mut s = m.clone();
        s.sort_unstable();
        assert_eq!(s, (0..64u32).collect::<Vec<_>>());
        // Identical geometry + identical curve => identity-ish mapping:
        // every task maps to the rank at its own grid position.
        for i in 0..64usize {
            assert_eq!(t.point_vec(i), p.point_vec(m[i] as usize));
        }
    }

    #[test]
    fn get_mapping_arrays_round_robin() {
        // 2 parts, 2 ranks each, 8 tasks: 2 tasks per rank.
        let task_part = [0, 0, 0, 0, 1, 1, 1, 1].map(|x| x as u32);
        let proc_part = [0, 1, 0, 1].map(|x| x as u32);
        let m = get_mapping_arrays(&task_part, &proc_part, 2);
        assert_eq!(m, vec![0, 2, 0, 2, 1, 3, 1, 3]);
    }

    #[test]
    fn mapping_deterministic() {
        let t = grid(&[9, 9]);
        let p = grid(&[3, 27]);
        let a = map_tasks(&t, &p, &MapConfig::default());
        let b = map_tasks(&t, &p, &MapConfig::default());
        assert_eq!(a, b);
    }
}
