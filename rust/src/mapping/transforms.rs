//! Coordinate transforms that specialize the mapper to an architecture or
//! application (Sections 4.3, 5.2, 5.3.1).

use crate::geom::Coords;
use crate::machine::Torus;

/// Bandwidth scaling (Z2_2, Section 5.3.1): replace integer router
/// coordinates with cumulative path costs, so nodes across fast links
/// appear closer together. The cost of moving from coordinate `c` to `c+1`
/// along dimension `d` is `ref_bw / bw(d, c)` (normalized so a
/// reference-speed link costs 1).
///
/// The returned table covers `0..2*size` so it can be applied after a torus
/// shift (shifted coordinates extend past `size`; the cost keeps
/// accumulating around the ring).
pub fn bandwidth_table(torus: &Torus, dim: usize, ref_bw: f64) -> Vec<f64> {
    let size = torus.sizes[dim];
    let mut table = Vec::with_capacity(2 * size);
    let mut acc = 0.0;
    table.push(0.0);
    for c in 0..(2 * size - 1) {
        acc += ref_bw / torus.bw.bandwidth(dim, c % size);
        table.push(acc);
    }
    table
}

/// Apply bandwidth scaling to every dimension of a machine coordinate set.
/// `ref_bw` defaults to the maximum link bandwidth so all costs are >= 1.
pub fn bandwidth_scale(coords: &mut Coords, torus: &Torus, ref_bw: Option<f64>) {
    let rb = ref_bw.unwrap_or_else(|| {
        let mut m: f64 = 0.0;
        for d in 0..torus.dim() {
            for c in 0..torus.sizes[d] {
                m = m.max(torus.bw.bandwidth(d, c));
            }
        }
        m
    });
    for d in 0..coords.dim().min(torus.dim()) {
        let table = bandwidth_table(torus, d, rb);
        coords.remap_axis(d, &table);
    }
}

/// The Z2_3 box transform (Section 5.3.1): group routers into
/// `bx x by x bz` boxes and lift 3D coordinates to 6D — three in-box
/// coordinates plus three box coordinates scaled by `outer_scale`, guiding
/// the partitioner to cut between boxes before cutting within them.
///
/// Expects raw integer router coordinates (applied before any shift).
pub fn box_transform(coords: &Coords, boxes: [usize; 3], outer_scale: f64) -> Coords {
    assert_eq!(coords.dim(), 3, "box transform is defined for 3D routers");
    let n = coords.len();
    let mut axes: Vec<Vec<f64>> = vec![Vec::with_capacity(n); 6];
    for i in 0..n {
        for d in 0..3 {
            let c = coords.get(d, i);
            debug_assert!(c.fract() == 0.0 && c >= 0.0);
            let c = c as usize;
            axes[d].push((c % boxes[d]) as f64);
            axes[d + 3].push((c / boxes[d]) as f64 * outer_scale);
        }
    }
    Coords::from_axes(axes)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::machine::BwModel;

    #[test]
    fn bandwidth_table_uniform_is_identity_spacing() {
        let t = Torus::new(vec![8], vec![true], BwModel::Uniform(4.0));
        let table = bandwidth_table(&t, 0, 4.0);
        for (c, &v) in table.iter().enumerate() {
            assert!((v - c as f64).abs() < 1e-12);
        }
    }

    #[test]
    fn bandwidth_table_slow_links_stretch() {
        // Gemini Y: mezzanine (75) then cable (37.5) alternating. With
        // ref_bw 75, steps cost 1, 2, 1, 2, ...
        let t = Torus::new(vec![4], vec![true], BwModel::PerDim(vec![75.0]));
        let _ = t; // (PerDim has no position dependence; use Gemini dim 1)
        let g = Torus::new(vec![4, 4, 4], vec![true; 3], BwModel::Gemini);
        let table = bandwidth_table(&g, 1, 75.0);
        assert_eq!(table[0], 0.0);
        assert!((table[1] - 1.0).abs() < 1e-12); // mezzanine step
        assert!((table[2] - 3.0).abs() < 1e-12); // + cable step (2x)
        assert!((table[3] - 4.0).abs() < 1e-12);
    }

    #[test]
    fn bandwidth_scale_makes_fast_dims_shorter() {
        // Z backplane links (120) are faster than X cables (75): after
        // scaling with ref 120, the Z extent shrinks relative to X.
        let g = Torus::new(vec![8, 8, 8], vec![true; 3], BwModel::Gemini);
        let mut c = Coords::from_axes(vec![
            vec![0.0, 7.0],
            vec![0.0, 0.0],
            vec![0.0, 7.0],
        ]);
        bandwidth_scale(&mut c, &g, Some(120.0));
        let x_ext = c.get(0, 1) - c.get(0, 0);
        let z_ext = c.get(2, 1) - c.get(2, 0);
        assert!(z_ext < x_ext, "z {z_ext} !< x {x_ext}");
    }

    #[test]
    fn box_transform_shape() {
        let c = Coords::from_axes(vec![
            vec![0.0, 3.0, 5.0],
            vec![0.0, 1.0, 3.0],
            vec![0.0, 9.0, 15.0],
        ]);
        let b = box_transform(&c, [2, 2, 8], 10.0);
        assert_eq!(b.dim(), 6);
        // Point 1 = (3,1,9): in-box (1,1,1), box (1,0,1)*10.
        assert_eq!(b.point_vec(1), vec![1.0, 1.0, 1.0, 10.0, 0.0, 10.0]);
    }

    #[test]
    fn box_transform_separates_boxes_strongly() {
        // Two routers in the same box are closer (in the lifted space) than
        // two in different boxes.
        let c = Coords::from_axes(vec![
            vec![0.0, 1.0, 2.0],
            vec![0.0, 0.0, 0.0],
            vec![0.0, 0.0, 0.0],
        ]);
        let b = box_transform(&c, [2, 2, 8], 10.0);
        let d = |i: usize, j: usize| -> f64 {
            (0..6)
                .map(|k| (b.get(k, i) - b.get(k, j)).abs())
                .sum::<f64>()
        };
        assert!(d(0, 1) < d(1, 2)); // same box vs. box boundary
    }
}
