//! Named mapping strategies: the Z2 variants evaluated in Section 5.
//!
//! * **Z2_1** — the base geometric mapper: FZ ordering, longest-dimension
//!   cuts, torus shift, rotation sweep (Section 5.3.1).
//! * **Z2_2** — Z2_1 + uneven bisection by largest prime divisor + link-
//!   bandwidth coordinate scaling.
//! * **Z2_3** — Z2_2 + the 2x2x8 box transform lifting 3D router
//!   coordinates to 6D so cuts happen between boxes first.
//! * **SFC+Z2** — keep the application's own partition (e.g. HOMME's
//!   Hilbert SFC) and use the geometric mapper only to place parts on
//!   nodes (Section 5.2).
//!
//! The "+E" architecture optimization (ignore the BG/Q E dimension when
//! partitioning processors) is `drop_proc_dims: vec![4]`.
//!
//! Strategies with `max_rotations > 1` run the parallel rotation sweep
//! ([`MapSpec::threads`], 0 = auto); the chosen mapping is bit-identical
//! at every thread count, so strategy outputs stay exactly reproducible.

use super::rotations::{rotation_sweep, SweepConfig, WhopsBackend};
use super::shift::shift_torus_coords;
use super::transforms::{bandwidth_scale, box_transform};
use super::{MapConfig, MapSpec};
use crate::apps::TaskGraph;
use crate::geom::Coords;
use crate::machine::Allocation;
use crate::sfc::PartOrdering;

/// Full strategy configuration.
#[derive(Clone, Debug)]
pub struct Z2Config {
    pub ordering: PartOrdering,
    pub longest_dim: bool,
    /// Uneven bisection by largest prime divisor (Z2_2/Z2_3).
    pub uneven_prime: bool,
    /// Scale machine coordinates by cumulative 1/bandwidth (Z2_2/Z2_3).
    pub bw_scale: bool,
    /// Lift 3D router coordinates to 6D box coordinates (Z2_3):
    /// (box extents, outer scale).
    pub box_transform: Option<([usize; 3], f64)>,
    /// Processor dimensions to ignore while partitioning ("+E" on BG/Q).
    pub drop_proc_dims: Vec<usize>,
    /// Torus wraparound shift of the machine coordinates.
    pub shift: bool,
    /// Rotation-sweep candidate cap (1 = identity rotation only).
    pub max_rotations: usize,
    /// The shared knobs: objective × NUMA pricing, worker threads, and the
    /// coarsening pre-pass. `spec.coarsen` implies hierarchical mode —
    /// when set without `hier`, the default `MinVolume` intra-node
    /// strategy is used.
    pub spec: MapSpec,
    /// Hierarchical node→core mode: when set, the strategy runs the
    /// two-level [`crate::hier`] mapper (node-level MJ sweep + the given
    /// intra-node strategy) instead of the flat rank-level partition.
    /// `ordering`/`longest_dim`/`uneven_prime`/`shift`/`drop_proc_dims`/
    /// `max_rotations`/`spec` all carry over to the node level;
    /// `bw_scale` and `box_transform` are rank-level transforms and are
    /// ignored in hierarchical mode.
    pub hier: Option<crate::hier::IntraNodeStrategy>,
}

impl From<MapSpec> for Z2Config {
    fn from(spec: MapSpec) -> Self {
        Z2Config {
            spec,
            ..Z2Config::z2_1()
        }
    }
}

impl Z2Config {
    /// Z2_1 of Section 5.3.1 (also the plain "Z2" of Section 5.2).
    pub fn z2_1() -> Self {
        Z2Config {
            ordering: PartOrdering::FZ,
            longest_dim: true,
            uneven_prime: false,
            bw_scale: false,
            box_transform: None,
            drop_proc_dims: vec![],
            shift: true,
            max_rotations: 36,
            spec: MapSpec::default(),
            hier: None,
        }
    }

    /// Z2_2: uneven prime bisection + bandwidth scaling.
    pub fn z2_2() -> Self {
        Z2Config {
            uneven_prime: true,
            bw_scale: true,
            ..Z2Config::z2_1()
        }
    }

    /// Z2_3: Z2_2 + the 2x2x8 box transform.
    pub fn z2_3() -> Self {
        Z2Config {
            box_transform: Some(([2, 2, 8], 8.0)),
            ..Z2Config::z2_2()
        }
    }

    /// Add the "+E" optimization (BG/Q: ignore dimension 4).
    pub fn plus_e(mut self) -> Self {
        self.drop_proc_dims = vec![4];
        self
    }

    fn map_cfg(&self) -> MapConfig {
        MapConfig {
            task_ordering: self.ordering,
            proc_ordering: self.ordering,
            longest_dim: self.longest_dim,
            uneven_prime: self.uneven_prime,
        }
    }
}

/// Prepare processor coordinates per the strategy: box transform or
/// (shift + bandwidth scale), then axis dropping. The shift and bandwidth
/// scale consume torus geometry and are skipped on non-torus machines
/// (their embeddings already encode the hierarchy — see
/// [`crate::machine::Topology::embed_coords`]).
pub fn prepare_proc_coords(alloc: &Allocation, cfg: &Z2Config) -> Coords {
    let mut pcoords = alloc.proc_coords();
    if let Some((boxes, outer_scale)) = cfg.box_transform {
        // Box transform consumes raw integer coordinates; the box grid
        // already encodes the machine hierarchy, so no shift on top.
        pcoords = box_transform(&pcoords, boxes, outer_scale);
    } else if let Some(torus) = alloc.machine.as_torus() {
        if cfg.shift {
            shift_torus_coords(&mut pcoords, &torus.sizes, &torus.wrap);
        }
        if cfg.bw_scale {
            bandwidth_scale(&mut pcoords, torus, None);
        }
    }
    if !cfg.drop_proc_dims.is_empty() {
        let keep: Vec<usize> = (0..pcoords.dim())
            .filter(|d| !cfg.drop_proc_dims.contains(d))
            .collect();
        pcoords = pcoords.select_axes(&keep);
    }
    pcoords
}

/// Run the strategy: returns `task_to_rank`. With `cfg.hier` (or
/// `cfg.coarsen`, which implies hierarchical mode) set, the two-level
/// hierarchical mapper runs instead of the flat partition.
pub fn z2_map(
    graph: &TaskGraph,
    tcoords: &Coords,
    alloc: &Allocation,
    cfg: &Z2Config,
    backend: &dyn WhopsBackend,
) -> Vec<u32> {
    if cfg.hier.is_some() || cfg.spec.coarsen.is_some() {
        let intra = cfg
            .hier
            .unwrap_or(crate::hier::IntraNodeStrategy::MinVolume { passes: 4 });
        let hcfg = crate::hier::HierConfig {
            node_map: cfg.map_cfg(),
            intra,
            shift: cfg.shift,
            drop_node_dims: cfg.drop_proc_dims.clone(),
            max_rotations: cfg.max_rotations,
            spec: cfg.spec,
            ..crate::hier::HierConfig::default()
        };
        return crate::hier::map_hierarchical(graph, tcoords, alloc, &hcfg, backend)
            .task_to_rank;
    }
    let pcoords = prepare_proc_coords(alloc, cfg);
    let map_cfg = cfg.map_cfg();
    if cfg.max_rotations <= 1 {
        return super::map_tasks(tcoords, &pcoords, &map_cfg);
    }
    let sweep = SweepConfig {
        max_candidates: cfg.max_rotations,
        spec: cfg.spec,
        ..Default::default()
    };
    rotation_sweep(graph, tcoords, &pcoords, alloc, &map_cfg, &sweep, backend).task_to_rank
}

/// SFC+Z2 (Section 5.2): keep an existing application partition
/// (`part_of_task`, `num_parts` parts) and geometrically map *parts* to
/// ranks. Part coordinates are the centroids of their tasks' coordinates.
/// Returns `task_to_rank`.
pub fn sfc_plus_z2(
    graph: &TaskGraph,
    tcoords: &Coords,
    part_of_task: &[u32],
    num_parts: usize,
    alloc: &Allocation,
    cfg: &Z2Config,
    backend: &dyn WhopsBackend,
) -> Vec<u32> {
    assert_eq!(alloc.num_ranks(), num_parts, "SFC+Z2 maps one part per rank");
    let centroids = part_centroids(tcoords, part_of_task, num_parts);
    // Build the part-level quotient graph for scoring the rotation sweep.
    let mut pg_edges: std::collections::HashMap<(u32, u32), f64> = std::collections::HashMap::new();
    for e in &graph.edges {
        let (pu, pv) = (part_of_task[e.u as usize], part_of_task[e.v as usize]);
        if pu != pv {
            let key = (pu.min(pv), pu.max(pv));
            *pg_edges.entry(key).or_insert(0.0) += e.w;
        }
    }
    let part_graph = TaskGraph {
        num_tasks: num_parts,
        edges: pg_edges
            .into_iter()
            .map(|((u, v), w)| crate::apps::Edge { u, v, w })
            .collect(),
        coords: centroids.clone(),
    };
    let part_to_rank = z2_map(&part_graph, &centroids, alloc, cfg, backend);
    part_of_task
        .iter()
        .map(|&p| part_to_rank[p as usize])
        .collect()
}

/// Centroid coordinates of each part.
pub fn part_centroids(coords: &Coords, part_of: &[u32], num_parts: usize) -> Coords {
    let dim = coords.dim();
    let mut sums = vec![vec![0f64; num_parts]; dim];
    let mut counts = vec![0usize; num_parts];
    for (i, &p) in part_of.iter().enumerate() {
        counts[p as usize] += 1;
        for d in 0..dim {
            sums[d][p as usize] += coords.get(d, i);
        }
    }
    for p in 0..num_parts {
        assert!(counts[p] > 0, "empty part {p}");
        for axis in sums.iter_mut() {
            axis[p] /= counts[p] as f64;
        }
    }
    Coords::from_axes(sums)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::stencil::stencil_graph;
    use crate::machine::{Allocation, Network, SparseAllocator, Torus};
    use crate::mapping::rotations::NativeBackend;
    use crate::metrics::eval_hops;

    fn toy_alloc() -> Allocation {
        SparseAllocator {
            machine: Torus::torus(&[8, 8, 8]),
            nodes_per_router: 2,
            ranks_per_node: 4,
            occupancy: 0.3,
        }
        .allocate(16, 11)
    }

    #[test]
    fn z2_variants_produce_bijections() {
        let alloc = toy_alloc(); // 64 ranks
        let g = stencil_graph(&[4, 4, 4], false, 1.0);
        for cfg in [Z2Config::z2_1(), Z2Config::z2_2(), Z2Config::z2_3()] {
            let mut cfg = cfg;
            cfg.max_rotations = 4; // keep the test quick
            let m = z2_map(&g, &g.coords, &alloc, &cfg, &NativeBackend);
            let mut s = m.clone();
            s.sort_unstable();
            assert_eq!(s, (0..64u32).collect::<Vec<_>>(), "{cfg:?}");
        }
    }

    #[test]
    fn z2_beats_random_mapping() {
        let alloc = toy_alloc();
        let g = stencil_graph(&[4, 4, 4], false, 1.0);
        let mut cfg = Z2Config::z2_1();
        cfg.max_rotations = 8;
        let m = z2_map(&g, &g.coords, &alloc, &cfg, &NativeBackend);
        let good = eval_hops(&g, &m, &alloc);
        // Scrambled mapping for comparison.
        let mut rng = crate::testutil::Rng::new(5);
        let mut bad: Vec<u32> = (0..64).collect();
        rng.shuffle(&mut bad);
        let rand = eval_hops(&g, &bad, &alloc);
        assert!(
            good.weighted_hops < rand.weighted_hops,
            "Z2 {} !< random {}",
            good.weighted_hops,
            rand.weighted_hops
        );
    }

    #[test]
    fn hier_mode_routes_to_two_level_mapper() {
        // The hierarchical variant must produce a bijection that keeps
        // intra-node communication off the network at least as well as the
        // default order does.
        let alloc = toy_alloc(); // 64 ranks, 16 nodes of 4
        let g = stencil_graph(&[4, 4, 4], false, 1.0);
        let mut cfg = Z2Config::z2_1();
        cfg.max_rotations = 4;
        cfg.hier = Some(crate::hier::IntraNodeStrategy::MinVolume { passes: 2 });
        let m = z2_map(&g, &g.coords, &alloc, &cfg, &NativeBackend);
        let mut s = m.clone();
        s.sort_unstable();
        assert_eq!(s, (0..64u32).collect::<Vec<_>>());
        // Every node's 4 tasks communicate over at most the node boundary:
        // the task count per node is exact.
        let mut per_node = vec![0usize; alloc.num_nodes()];
        for &r in &m {
            per_node[alloc.core_node[r as usize] as usize] += 1;
        }
        assert!(per_node.iter().all(|&c| c == 4), "{per_node:?}");
    }

    #[test]
    fn z2_runs_under_routed_objective_flat_and_hier() {
        // Z2Config::objective threads through both the flat rotation sweep
        // and the hierarchical mapper; each still yields a bijection.
        use crate::objective::ObjectiveKind;
        let alloc = toy_alloc(); // 64 ranks
        let g = stencil_graph(&[4, 4, 4], false, 1.0);
        for hier in [None, Some(crate::hier::IntraNodeStrategy::MinVolume { passes: 2 })] {
            let mut cfg = Z2Config::z2_1();
            cfg.max_rotations = 4;
            cfg.spec.objective = ObjectiveKind::MaxLinkLoad;
            cfg.hier = hier;
            let m = z2_map(&g, &g.coords, &alloc, &cfg, &NativeBackend);
            let mut s = m.clone();
            s.sort_unstable();
            assert_eq!(s, (0..64u32).collect::<Vec<_>>(), "hier={hier:?}");
        }
    }

    #[test]
    fn plus_e_drops_dimension() {
        let cfg = Z2Config::z2_1().plus_e();
        let alloc = Allocation::bgq([2, 2, 2, 2, 2], 2, "ABCDET").unwrap();
        let p = prepare_proc_coords(&alloc, &cfg);
        assert_eq!(p.dim(), 4);
    }

    #[test]
    fn box_transform_lifts_to_6d() {
        let cfg = Z2Config::z2_3();
        let alloc = toy_alloc();
        let p = prepare_proc_coords(&alloc, &cfg);
        assert_eq!(p.dim(), 6);
    }

    #[test]
    fn part_centroids_average() {
        let coords = Coords::from_axes(vec![vec![0.0, 2.0, 10.0], vec![1.0, 3.0, 5.0]]);
        let parts = [0u32, 0, 1];
        let c = part_centroids(&coords, &parts, 2);
        assert_eq!(c.point_vec(0), vec![1.0, 2.0]);
        assert_eq!(c.point_vec(1), vec![10.0, 5.0]);
    }

    #[test]
    fn sfc_plus_z2_respects_partition() {
        // Tasks in the same SFC part must land on the same rank.
        let g = stencil_graph(&[8, 8], false, 1.0);
        let alloc = Allocation {
            machine: Network::torus(&[4, 4]),
            core_router: (0..16u32).collect(),
            core_node: (0..16u32).collect(),
            ranks_per_node: 1,
        };
        // Simple 16-part partition: 2x2 blocks.
        let part_of: Vec<u32> = (0..64)
            .map(|i| {
                let (x, y) = (i % 8, i / 8);
                ((x / 2) * 4 + y / 2) as u32
            })
            .collect();
        let mut cfg = Z2Config::z2_1();
        cfg.max_rotations = 2;
        let m = sfc_plus_z2(&g, &g.coords, &part_of, 16, &alloc, &cfg, &NativeBackend);
        for i in 0..64 {
            for j in 0..64 {
                if part_of[i] == part_of[j] {
                    assert_eq!(m[i], m[j]);
                }
            }
        }
    }
}
