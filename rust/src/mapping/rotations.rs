//! Rotation sweep (Section 4.3, "Rotating the machine and task
//! coordinates"): the quality of an MJ mapping depends on the order the cut
//! dimensions are visited, so up to `td!·pd!` axis-permutation candidates
//! are generated and the one with the lowest WeightedHops (Eqn. 3) wins.
//!
//! In the paper each MPI process computes one rotation and an Allreduce
//! picks the winner; here the sweep is a batch: candidate mappings are
//! scored together by the `batched_weighted_hops` kernel — either the AOT
//! PJRT artifact (`runtime::PjrtBackend`) or the bit-equivalent native
//! fallback.

use super::MapConfig;
use crate::apps::TaskGraph;
use crate::geom::Coords;
use crate::machine::Allocation;
use crate::metrics::native::batched_weighted_hops_native;

/// Backend for batched WeightedHops evaluation. Implementations: the
/// in-process native evaluator (below) and the PJRT artifact executor
/// (`crate::runtime::PjrtBackend`).
pub trait WhopsBackend {
    /// `src`/`dst`: `[r*e*d]` candidate-major coordinate arrays; `w`: `[e]`;
    /// `dims`/`wrap`: `[d]`. Returns one score per candidate.
    fn eval_batch(
        &self,
        src: &[f32],
        dst: &[f32],
        w: &[f32],
        dims: &[f32],
        wrap: &[f32],
        r: usize,
        e: usize,
        d: usize,
    ) -> Vec<f32>;

    fn name(&self) -> &'static str {
        "native"
    }
}

/// Pure-rust backend (always available; arbiter in tests).
pub struct NativeBackend;

impl WhopsBackend for NativeBackend {
    fn eval_batch(
        &self,
        src: &[f32],
        dst: &[f32],
        w: &[f32],
        dims: &[f32],
        wrap: &[f32],
        r: usize,
        e: usize,
        d: usize,
    ) -> Vec<f32> {
        batched_weighted_hops_native(src, dst, w, dims, wrap, r, e, d)
    }
}

/// All permutations of `0..d` in lexicographic order.
pub fn axis_permutations(d: usize) -> Vec<Vec<usize>> {
    assert!(d >= 1 && d <= 7, "d={d} would generate too many permutations");
    let mut perms = Vec::new();
    let mut cur: Vec<usize> = (0..d).collect();
    loop {
        perms.push(cur.clone());
        // next_permutation
        let Some(i) = (0..d - 1).rev().find(|&i| cur[i] < cur[i + 1]) else {
            break;
        };
        let j = (i + 1..d).rev().find(|&j| cur[j] > cur[i]).unwrap();
        cur.swap(i, j);
        cur[i + 1..].reverse();
    }
    perms
}

/// Sweep configuration.
#[derive(Clone, Copy, Debug)]
pub struct SweepConfig {
    /// Cap on the number of (task-perm, proc-perm) candidates. The full
    /// product is subsampled with a deterministic stride when it exceeds
    /// the cap (the paper's sweep is naturally capped by the process-group
    /// size `rp`).
    pub max_candidates: usize,
    /// Edge-chunk size for batched scoring (bounds peak memory and matches
    /// the AOT artifact padding).
    pub chunk_edges: usize,
}

impl Default for SweepConfig {
    fn default() -> Self {
        SweepConfig {
            max_candidates: 36,
            chunk_edges: 32768,
        }
    }
}

/// Result of a rotation sweep.
#[derive(Clone, Debug)]
pub struct SweepResult {
    pub task_to_rank: Vec<u32>,
    /// Index of the winning candidate.
    pub chosen: usize,
    /// WeightedHops score per candidate.
    pub scores: Vec<f64>,
    /// The (task_perm, proc_perm) of each candidate.
    pub candidates: Vec<(Vec<usize>, Vec<usize>)>,
}

/// Enumerate capped (tperm, pperm) candidate pairs deterministically.
pub fn candidate_rotations(td: usize, pd: usize, cap: usize) -> Vec<(Vec<usize>, Vec<usize>)> {
    let tperms = axis_permutations(td);
    let pperms = axis_permutations(pd);
    let total = tperms.len() * pperms.len();
    let take = total.min(cap.max(1));
    // Stride subsample over the full product, always including index 0
    // (the identity rotation).
    let mut out = Vec::with_capacity(take);
    for k in 0..take {
        let idx = k * total / take;
        out.push((
            tperms[idx / pperms.len()].clone(),
            pperms[idx % pperms.len()].clone(),
        ));
    }
    out
}

/// Score a set of candidate mappings by WeightedHops on the allocation's
/// network. Returns f64 accumulations of the backend's per-chunk f32 sums.
pub fn score_mappings(
    graph: &TaskGraph,
    mappings: &[Vec<u32>],
    alloc: &Allocation,
    backend: &dyn WhopsBackend,
    chunk_edges: usize,
) -> Vec<f64> {
    let r = mappings.len();
    let d = alloc.torus.dim();
    let ne = graph.edges.len();
    let dims: Vec<f32> = alloc.torus.sizes.iter().map(|&s| s as f32).collect();
    let wrap: Vec<f32> = alloc
        .torus
        .wrap
        .iter()
        .map(|&w| if w { 1.0 } else { 0.0 })
        .collect();
    // Per-rank router coordinates, f32, rank-major.
    let nranks = alloc.num_ranks();
    let mut rank_coords = vec![0f32; nranks * d];
    let mut buf = vec![0usize; d];
    for rank in 0..nranks {
        alloc
            .torus
            .coords_into(alloc.core_router[rank] as usize, &mut buf);
        for k in 0..d {
            rank_coords[rank * d + k] = buf[k] as f32;
        }
    }
    let mut scores = vec![0f64; r];
    let chunk = chunk_edges.max(1);
    let mut src = vec![0f32; r * chunk * d];
    let mut dst = vec![0f32; r * chunk * d];
    let mut w = vec![0f32; chunk];
    let mut lo = 0usize;
    while lo < ne {
        let hi = (lo + chunk).min(ne);
        let len = hi - lo;
        // Zero-fill the padding region (w=0 edges contribute nothing).
        w[len..].fill(0.0);
        for (k, e) in graph.edges[lo..hi].iter().enumerate() {
            w[k] = e.w as f32;
        }
        for (ri, m) in mappings.iter().enumerate() {
            let base = ri * chunk * d;
            for (k, e) in graph.edges[lo..hi].iter().enumerate() {
                let ra = m[e.u as usize] as usize;
                let rb = m[e.v as usize] as usize;
                src[base + k * d..base + (k + 1) * d]
                    .copy_from_slice(&rank_coords[ra * d..(ra + 1) * d]);
                dst[base + k * d..base + (k + 1) * d]
                    .copy_from_slice(&rank_coords[rb * d..(rb + 1) * d]);
            }
            // Padding coords can stay stale: their weights are zero.
        }
        let part = backend.eval_batch(&src, &dst, &w, &dims, &wrap, r, chunk, d);
        for (ri, &p) in part.iter().enumerate() {
            scores[ri] += p as f64;
        }
        lo = hi;
    }
    scores
}

/// The full rotation sweep: generate candidates, map, score, pick the best.
/// `pcoords` are the (possibly transformed) processor coordinates used for
/// partitioning; scoring always uses the true router coordinates from
/// `alloc`.
pub fn rotation_sweep(
    graph: &TaskGraph,
    tcoords: &Coords,
    pcoords: &Coords,
    alloc: &Allocation,
    map_cfg: &MapConfig,
    sweep: &SweepConfig,
    backend: &dyn WhopsBackend,
) -> SweepResult {
    let candidates = candidate_rotations(tcoords.dim(), pcoords.dim(), sweep.max_candidates);
    let mappings: Vec<Vec<u32>> = candidates
        .iter()
        .map(|(tp, pp)| {
            super::map_tasks(&tcoords.permute_axes(tp), &pcoords.permute_axes(pp), map_cfg)
        })
        .collect();
    let scores = score_mappings(graph, &mappings, alloc, backend, sweep.chunk_edges);
    let chosen = scores
        .iter()
        .enumerate()
        .min_by(|a, b| a.1.partial_cmp(b.1).unwrap().then(a.0.cmp(&b.0)))
        .map(|(i, _)| i)
        .unwrap();
    SweepResult {
        task_to_rank: mappings.into_iter().nth(chosen).unwrap(),
        chosen,
        scores,
        candidates,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::stencil::stencil_graph;
    use crate::machine::{Allocation, Torus};
    use crate::metrics::eval_hops;

    fn line_alloc(n: usize) -> Allocation {
        Allocation {
            torus: Torus::torus(&[n]),
            core_router: (0..n as u32).collect(),
            core_node: (0..n as u32).collect(),
            ranks_per_node: 1,
        }
    }

    #[test]
    fn permutation_count() {
        assert_eq!(axis_permutations(1).len(), 1);
        assert_eq!(axis_permutations(3).len(), 6);
        assert_eq!(axis_permutations(5).len(), 120);
        assert_eq!(axis_permutations(3)[0], vec![0, 1, 2]);
    }

    #[test]
    fn candidates_capped_and_include_identity() {
        let c = candidate_rotations(3, 3, 10);
        assert_eq!(c.len(), 10);
        assert_eq!(c[0], ((0..3).collect::<Vec<_>>(), (0..3).collect()));
        let full = candidate_rotations(3, 3, 100);
        assert_eq!(full.len(), 36);
    }

    #[test]
    fn scores_match_eval_hops_weighted() {
        // score_mappings must agree with the metrics engine on WeightedHops
        // (for one-rank-per-node allocations where intra-node never
        // triggers).
        let g = stencil_graph(&[4, 4], false, 3.0);
        let alloc = line_alloc(16);
        let m: Vec<u32> = (0..16u32).rev().collect();
        let scores = score_mappings(&g, &[m.clone()], &alloc, &NativeBackend, 7);
        let metric = eval_hops(&g, &m, &alloc);
        assert!(
            (scores[0] - metric.weighted_hops).abs() < 1e-3,
            "{} vs {}",
            scores[0],
            metric.weighted_hops
        );
    }

    #[test]
    fn chunking_invariant() {
        let g = stencil_graph(&[8, 8], false, 1.5);
        let alloc = line_alloc(64);
        let m: Vec<u32> = (0..64u32).map(|i| (i * 7) % 64).collect();
        let a = score_mappings(&g, &[m.clone()], &alloc, &NativeBackend, 1000);
        let b = score_mappings(&g, &[m.clone()], &alloc, &NativeBackend, 13);
        assert!((a[0] - b[0]).abs() < 1e-2);
    }

    #[test]
    fn sweep_picks_minimum() {
        // 2D tasks onto a 2D grid of ranks: the sweep must return the
        // candidate whose score equals the min of all scores.
        let g = stencil_graph(&[4, 8], false, 1.0);
        let alloc = Allocation {
            torus: Torus::torus(&[8, 4]),
            core_router: (0..32u32).collect(),
            core_node: (0..32u32).collect(),
            ranks_per_node: 1,
        };
        let t = g.coords.clone();
        let p = alloc.proc_coords();
        let res = rotation_sweep(
            &g,
            &t,
            &p,
            &alloc,
            &MapConfig::default(),
            &SweepConfig::default(),
            &NativeBackend,
        );
        let min = res.scores.iter().cloned().fold(f64::INFINITY, f64::min);
        assert_eq!(res.scores[res.chosen], min);
        // And the returned mapping really has that WeightedHops.
        let m = eval_hops(&g, &res.task_to_rank, &alloc);
        assert!((m.weighted_hops - min).abs() < 1e-3);
    }

    #[test]
    fn sweep_beats_worst_rotation() {
        // On an anisotropic problem the best rotation must strictly beat
        // the worst one (otherwise the sweep is pointless).
        let g = stencil_graph(&[2, 16], false, 1.0);
        let alloc = Allocation {
            torus: Torus::torus(&[16, 2]),
            core_router: (0..32u32).collect(),
            core_node: (0..32u32).collect(),
            ranks_per_node: 1,
        };
        let res = rotation_sweep(
            &g,
            &g.coords,
            &alloc.proc_coords(),
            &alloc,
            &MapConfig {
                longest_dim: false, // make rotation matter
                ..Default::default()
            },
            &SweepConfig::default(),
            &NativeBackend,
        );
        let max = res.scores.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        assert!(res.scores[res.chosen] < max);
    }
}
