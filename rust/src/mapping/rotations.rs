//! Rotation sweep (Section 4.3, "Rotating the machine and task
//! coordinates"): the quality of an MJ mapping depends on the order the cut
//! dimensions are visited, so up to `td!·pd!` axis-permutation candidates
//! are generated and the one with the lowest objective value wins —
//! WeightedHops (Eqn. 3) by default, or any routed
//! [`crate::objective::ObjectiveKind`] via [`MapSpec::objective`].
//!
//! In the paper each MPI process computes one rotation and an Allreduce
//! picks the winner; here the sweep fans the candidates out across a
//! [`crate::par`] thread budget instead: each worker maps and scores its
//! candidates with its own reused scratch arenas, and the reduction
//! (argmin with index tie-break over an index-addressed score vector) is
//! deterministic, so the chosen candidate, the scores, and the returned
//! mapping are **bit-identical to the sequential path at every thread
//! count**.
//!
//! Per-candidate cost is kept allocation-free in steady state:
//! * the processor-side partition is memoized per distinct processor-axis
//!   permutation (candidates share up to `td!` of them) in a
//!   [`SweepCache`] (keyed by task count + permutation, shareable across
//!   sweeps on the same allocation),
//! * task partitions run through per-worker [`MappingScratch`] arenas and
//!   the zero-copy permuted-axes MJ entry point,
//! * scoring streams edge chunks through per-worker [`ScoreScratch`]
//!   buffers against a shared [`BatchScorer`] (per-rank router coordinates
//!   computed once per sweep, not once per candidate).
//!
//! WeightedHops scoring runs on the `batched_weighted_hops` kernel —
//! either the AOT artifact runtime (`runtime::PjrtBackend`) or the
//! bit-equivalent native fallback — when the allocation's machine is a
//! torus (the kernel encodes torus geometry directly). Every other
//! combination — routed objectives (`MaxLinkLoad`, `CongestionBlend`),
//! NUMA node-level pricing, the blended routed × NUMA spec, and *any*
//! objective on a non-torus [`crate::machine::Topology`] (fat-tree,
//! dragonfly) — scores each candidate with one sequential f64 pass
//! through the unified evaluator ([`crate::objective::eval`], per-worker
//! [`crate::metrics::LinkAccumulator`] scratch) or a plain
//! `Σ w · hop_dist` loop; either way a candidate's score is a pure
//! function of its mapping, so the sweep stays bit-identical at every
//! thread count.

use super::{
    map_tasks_with_proc, prepare_proc_partition, MapConfig, MapSpec, MappingScratch,
    ProcPartition,
};
use crate::apps::TaskGraph;
use crate::geom::Coords;
use crate::machine::{Allocation, Topology};
use crate::metrics::native::batched_weighted_hops_native_par;
use crate::metrics::LinkAccumulator;
use crate::mj::MjScratch;
use crate::objective::eval::{blended_candidate_score, EvalSpec};
use crate::objective::{LinkCosts, ObjectiveKind};
use crate::par::{self, Parallelism};

pub use crate::objective::eval::numa_node_score;

/// Backend for batched WeightedHops evaluation. Implementations: the
/// in-process native evaluator (below) and the artifact executor
/// (`crate::runtime::PjrtBackend`). Backends are shared across sweep
/// workers, hence the `Sync` bound; implementations must be safe to call
/// concurrently.
pub trait WhopsBackend: Sync {
    /// `src`/`dst`: `[r*e*d]` candidate-major coordinate arrays; `w`: `[e]`;
    /// `dims`/`wrap`: `[d]`. Returns one score per candidate.
    #[allow(clippy::too_many_arguments)]
    fn eval_batch(
        &self,
        src: &[f32],
        dst: &[f32],
        w: &[f32],
        dims: &[f32],
        wrap: &[f32],
        r: usize,
        e: usize,
        d: usize,
    ) -> Vec<f32>;

    fn name(&self) -> &'static str {
        "native"
    }
}

/// Pure-rust backend (always available; arbiter in tests). Multi-candidate
/// batches fan out across the auto thread budget; single-candidate calls
/// (the per-worker sweep path) stay on the sequential row kernel. Either
/// way the scores are bit-identical.
pub struct NativeBackend;

impl WhopsBackend for NativeBackend {
    fn eval_batch(
        &self,
        src: &[f32],
        dst: &[f32],
        w: &[f32],
        dims: &[f32],
        wrap: &[f32],
        r: usize,
        e: usize,
        d: usize,
    ) -> Vec<f32> {
        batched_weighted_hops_native_par(
            src,
            dst,
            w,
            dims,
            wrap,
            r,
            e,
            d,
            Parallelism::auto(),
        )
    }
}

/// All permutations of `0..d` in lexicographic order.
pub fn axis_permutations(d: usize) -> Vec<Vec<usize>> {
    assert!(d >= 1 && d <= 7, "d={d} would generate too many permutations");
    let mut perms = Vec::new();
    let mut cur: Vec<usize> = (0..d).collect();
    loop {
        perms.push(cur.clone());
        // next_permutation
        let Some(i) = (0..d - 1).rev().find(|&i| cur[i] < cur[i + 1]) else {
            break;
        };
        let j = (i + 1..d).rev().find(|&j| cur[j] > cur[i]).unwrap();
        cur.swap(i, j);
        cur[i + 1..].reverse();
    }
    perms
}

/// Sweep configuration.
#[derive(Clone, Copy, Debug)]
pub struct SweepConfig {
    /// Cap on the number of (task-perm, proc-perm) candidates. The full
    /// product is subsampled with a deterministic stride when it exceeds
    /// the cap (the paper's sweep is naturally capped by the process-group
    /// size `rp`).
    pub max_candidates: usize,
    /// Edge-chunk size for batched scoring (bounds peak memory and matches
    /// the AOT artifact padding).
    pub chunk_edges: usize,
    /// The shared knobs: objective × NUMA pricing and the worker-thread
    /// budget ([`MapSpec::coarsen`] is ignored here — coarsening wraps the
    /// sweep from [`crate::hier`], it does not run inside it).
    pub spec: MapSpec,
}

impl Default for SweepConfig {
    fn default() -> Self {
        SweepConfig {
            max_candidates: 36,
            chunk_edges: 32768,
            spec: MapSpec::default(),
        }
    }
}

impl From<MapSpec> for SweepConfig {
    fn from(spec: MapSpec) -> Self {
        SweepConfig {
            spec,
            ..Default::default()
        }
    }
}

impl SweepConfig {
    fn parallelism(&self) -> Parallelism {
        self.spec.parallelism()
    }
}

/// Result of a rotation sweep.
#[derive(Clone, Debug)]
pub struct SweepResult {
    pub task_to_rank: Vec<u32>,
    /// Index of the winning candidate.
    pub chosen: usize,
    /// Objective value per candidate ([`MapSpec::objective`];
    /// WeightedHops by default).
    pub scores: Vec<f64>,
    /// The (task_perm, proc_perm) of each candidate.
    pub candidates: Vec<(Vec<usize>, Vec<usize>)>,
}

/// Enumerate capped (tperm, pperm) candidate pairs deterministically.
pub fn candidate_rotations(td: usize, pd: usize, cap: usize) -> Vec<(Vec<usize>, Vec<usize>)> {
    let tperms = axis_permutations(td);
    let pperms = axis_permutations(pd);
    let total = tperms.len() * pperms.len();
    let take = total.min(cap.max(1));
    // Stride subsample over the full product, always including index 0
    // (the identity rotation).
    let mut out = Vec::with_capacity(take);
    for k in 0..take {
        let idx = k * total / take;
        out.push((
            tperms[idx / pperms.len()].clone(),
            pperms[idx % pperms.len()].clone(),
        ));
    }
    out
}

/// Reusable per-worker buffers for [`BatchScorer::score_one`]: the chunked
/// candidate-major coordinate/weight arrays handed to the kernel. Reuse
/// across candidates; never share between concurrent workers.
#[derive(Default)]
pub struct ScoreScratch {
    src: Vec<f32>,
    dst: Vec<f32>,
    w: Vec<f32>,
    /// Zero watermark: `w[w_dirty..]` is always all zeros. The per-chunk
    /// padding clear only has to touch `w[len..w_dirty]` — on full chunks
    /// (the steady state at large edge counts) that is empty, so the tail
    /// re-zeroing the `sweep.candidate` spans used to show is gone.
    w_dirty: usize,
}

impl ScoreScratch {
    pub fn new() -> Self {
        ScoreScratch::default()
    }
}

/// Per-worker candidate-scoring scratch, generalized from [`ScoreScratch`]:
/// the f32 kernel buffers plus (allocated on first use) the dense routed
/// link accumulator the routed objectives score through. One per sweep
/// worker; never shared between concurrent workers.
#[derive(Default)]
pub struct ObjectiveScratch {
    score: ScoreScratch,
    routed: Option<LinkAccumulator>,
}

impl ObjectiveScratch {
    pub fn new() -> Self {
        ObjectiveScratch::default()
    }
}

/// Per-sweep candidate scorer, collapsed onto the unified evaluator: the
/// plain-WeightedHops spec on a torus machine keeps the kernel-backend
/// path (and its f32 accumulation semantics, so default-objective torus
/// sweeps score exactly as before); every other [`EvalSpec`] combination —
/// routed, NUMA, the blended routed × NUMA, and any spec on a non-torus
/// topology — evaluates through one sequential f64 pass per candidate.
enum CandidateScorer<'a> {
    Whops(BatchScorer<'a>),
    Eval {
        graph: &'a TaskGraph,
        alloc: &'a Allocation,
        spec: EvalSpec,
        /// Per-link inverse bandwidths, built once per sweep (routed
        /// network terms only).
        costs: Option<LinkCosts>,
    },
}

impl<'a> CandidateScorer<'a> {
    fn new(
        graph: &'a TaskGraph,
        alloc: &'a Allocation,
        sweep: &SweepConfig,
    ) -> CandidateScorer<'a> {
        let spec = sweep.spec.eval_spec();
        if let Err(e) = spec.validate() {
            panic!("unsupported sweep objective combination: {e}");
        }
        if spec == EvalSpec::default() && alloc.machine.as_torus().is_some() {
            return CandidateScorer::Whops(BatchScorer::new(graph, alloc, sweep.chunk_edges));
        }
        let costs = spec
            .objective
            .get()
            .needs_routing()
            .then(|| LinkCosts::new(&alloc.machine));
        CandidateScorer::Eval {
            graph,
            alloc,
            spec,
            costs,
        }
    }

    fn score(
        &self,
        mapping: &[u32],
        backend: &dyn WhopsBackend,
        scratch: &mut ObjectiveScratch,
    ) -> f64 {
        match self {
            CandidateScorer::Whops(scorer) => {
                scorer.score_one(mapping, backend, &mut scratch.score)
            }
            CandidateScorer::Eval {
                graph,
                alloc,
                spec,
                costs,
            } => match (spec.objective, spec.numa) {
                (ObjectiveKind::WeightedHops, None) => {
                    // Plain WeightedHops on a non-torus topology: one
                    // sequential f64 pass in edge order over the machine's
                    // hop metric (intra-node edges share a router, so they
                    // price at zero exactly like the kernel path).
                    let machine = &alloc.machine;
                    graph
                        .edges
                        .iter()
                        .map(|e| {
                            let qa = alloc.core_router[mapping[e.u as usize] as usize] as usize;
                            let qb = alloc.core_router[mapping[e.v as usize] as usize] as usize;
                            e.w * machine.hop_dist_ids(qa, qb) as f64
                        })
                        .sum()
                }
                (ObjectiveKind::WeightedHops, Some(c)) => {
                    numa_node_score(graph, mapping, alloc, c)
                }
                (kind, numa) => {
                    let costs = costs.as_ref().expect("routed objectives build LinkCosts");
                    let acc = scratch
                        .routed
                        .get_or_insert_with(|| LinkAccumulator::new(&alloc.machine));
                    match numa {
                        None => kind.get().score_one(graph, mapping, alloc, costs, acc),
                        Some(c) => blended_candidate_score(
                            graph, mapping, alloc, kind, c.socket, costs, acc,
                        ),
                    }
                }
            },
        }
    }
}

/// Per-sweep scoring context: everything that depends only on
/// `(graph, alloc, chunk_edges)` — per-rank router coordinates, torus
/// extents/wrap flags — computed once and shared (immutably) by all
/// candidate workers.
pub struct BatchScorer<'a> {
    graph: &'a TaskGraph,
    dims: Vec<f32>,
    wrap: Vec<f32>,
    /// Per-rank router coordinates, f32, rank-major.
    rank_coords: Vec<f32>,
    d: usize,
    chunk: usize,
}

impl<'a> BatchScorer<'a> {
    pub fn new(graph: &'a TaskGraph, alloc: &Allocation, chunk_edges: usize) -> Self {
        let torus = alloc
            .machine
            .as_torus()
            .expect("BatchScorer consumes torus geometry; non-torus sweeps use the f64 evaluator");
        let d = torus.dim();
        let dims: Vec<f32> = torus.sizes.iter().map(|&s| s as f32).collect();
        let wrap: Vec<f32> = torus
            .wrap
            .iter()
            .map(|&w| if w { 1.0 } else { 0.0 })
            .collect();
        let nranks = alloc.num_ranks();
        let mut rank_coords = vec![0f32; nranks * d];
        let mut buf = vec![0usize; d];
        for rank in 0..nranks {
            torus.coords_into(alloc.core_router[rank] as usize, &mut buf);
            for k in 0..d {
                rank_coords[rank * d + k] = buf[k] as f32;
            }
        }
        BatchScorer {
            graph,
            dims,
            wrap,
            rank_coords,
            d,
            chunk: chunk_edges.max(1),
        }
    }

    /// WeightedHops of one mapping: f64 accumulation of the backend's
    /// per-chunk f32 sums. For backends whose per-row result is
    /// independent of the batch shape (the native kernel), this is
    /// bit-identical to scoring the mapping as one row of a candidate
    /// batch with the same `chunk_edges`. The artifact runtime picks its
    /// padded shape per request, so its f32 partial-sum grouping — and
    /// thus the low-order bits — can differ between r=1 and batched
    /// calls (both stay within the kernel's f32 tolerance).
    pub fn score_one(
        &self,
        mapping: &[u32],
        backend: &dyn WhopsBackend,
        scratch: &mut ScoreScratch,
    ) -> f64 {
        let d = self.d;
        let chunk = self.chunk;
        let ne = self.graph.edges.len();
        scratch.src.resize(chunk * d, 0.0);
        scratch.dst.resize(chunk * d, 0.0);
        if scratch.w.len() != chunk {
            // Re-establish the watermark invariant from scratch: resize
            // alone would keep stale prefix values on shrink.
            scratch.w.clear();
            scratch.w.resize(chunk, 0.0);
            scratch.w_dirty = 0;
        }
        let mut total = 0f64;
        let mut lo = 0usize;
        while lo < ne {
            let hi = (lo + chunk).min(ne);
            let len = hi - lo;
            // Zero only the previously written part of the padding region
            // (w=0 edges contribute nothing; padding coords can stay stale
            // for the same reason): `w[w_dirty..]` is already zero, across
            // chunks and across calls reusing this scratch.
            if scratch.w_dirty > len {
                scratch.w[len..scratch.w_dirty].fill(0.0);
            }
            scratch.w_dirty = len;
            for (k, e) in self.graph.edges[lo..hi].iter().enumerate() {
                scratch.w[k] = e.w as f32;
                let ra = mapping[e.u as usize] as usize;
                let rb = mapping[e.v as usize] as usize;
                scratch.src[k * d..(k + 1) * d]
                    .copy_from_slice(&self.rank_coords[ra * d..(ra + 1) * d]);
                scratch.dst[k * d..(k + 1) * d]
                    .copy_from_slice(&self.rank_coords[rb * d..(rb + 1) * d]);
            }
            let part = backend.eval_batch(
                &scratch.src,
                &scratch.dst,
                &scratch.w,
                &self.dims,
                &self.wrap,
                1,
                chunk,
                d,
            );
            total += part[0] as f64;
            lo = hi;
        }
        total
    }
}

/// Score a set of candidate mappings by WeightedHops on the allocation's
/// network. Returns f64 accumulations of the backend's per-chunk f32 sums.
/// Mappings are scored concurrently under the auto thread budget; the
/// scores do not depend on the budget.
pub fn score_mappings(
    graph: &TaskGraph,
    mappings: &[Vec<u32>],
    alloc: &Allocation,
    backend: &dyn WhopsBackend,
    chunk_edges: usize,
) -> Vec<f64> {
    score_mappings_par(
        graph,
        mappings,
        alloc,
        backend,
        chunk_edges,
        Parallelism::auto(),
    )
}

/// [`score_mappings`] with an explicit thread budget.
pub fn score_mappings_par(
    graph: &TaskGraph,
    mappings: &[Vec<u32>],
    alloc: &Allocation,
    backend: &dyn WhopsBackend,
    chunk_edges: usize,
    par: Parallelism,
) -> Vec<f64> {
    let scorer = BatchScorer::new(graph, alloc, chunk_edges);
    par::map_with(par, mappings, ScoreScratch::new, |scratch, _i, m| {
        scorer.score_one(m, backend, scratch)
    })
}

/// Cross-sweep memo of proc-side partitions for a fixed
/// `(pcoords, map_cfg)` context. Unlike [`super::ProcPartitionCache`] —
/// which is
/// scoped to one sweep and keys on the permutation alone — the task count
/// is part of the key, so a single cache can serve several sweeps over
/// *different* graphs against the same allocation (the service's batching
/// stage). A partition is a pure function of `(pcoords, pperm, tnum,
/// cfg)`, so a memoized entry is bit-identical to a freshly computed one
/// and reuse can never change a mapping.
#[derive(Default)]
pub struct SweepCache {
    entries: std::sync::Mutex<
        std::collections::HashMap<(usize, Vec<usize>), std::sync::Arc<ProcPartition>>,
    >,
}

impl SweepCache {
    pub fn new() -> SweepCache {
        SweepCache::default()
    }

    pub fn get(&self, tnum: usize, pperm: &[usize]) -> Option<std::sync::Arc<ProcPartition>> {
        self.entries
            .lock()
            .unwrap()
            .get(&(tnum, pperm.to_vec()))
            .cloned()
    }

    /// Lookup, computing and caching on miss (outside the lock; concurrent
    /// misses may compute twice — the results are identical, either wins).
    pub fn get_or_compute(
        &self,
        pcoords: &Coords,
        pperm: &[usize],
        tnum: usize,
        cfg: &MapConfig,
        par: Parallelism,
        scratch: &mut MjScratch,
    ) -> std::sync::Arc<ProcPartition> {
        if let Some(hit) = self.get(tnum, pperm) {
            return hit;
        }
        let computed = prepare_proc_partition(pcoords, pperm, tnum, cfg, par, scratch);
        self.entries
            .lock()
            .unwrap()
            .entry((tnum, pperm.to_vec()))
            .or_insert_with(|| std::sync::Arc::new(computed))
            .clone()
    }

    pub fn len(&self) -> usize {
        self.entries.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// The full rotation sweep: generate candidates, map, score, pick the best
/// under [`SweepConfig::objective`]. `pcoords` are the (possibly
/// transformed) processor coordinates used for partitioning; scoring always
/// uses the true router coordinates from `alloc`. Candidates fan out across
/// [`MapSpec::threads`] workers; the result is bit-identical at every thread
/// count.
pub fn rotation_sweep(
    graph: &TaskGraph,
    tcoords: &Coords,
    pcoords: &Coords,
    alloc: &Allocation,
    map_cfg: &MapConfig,
    sweep: &SweepConfig,
    backend: &dyn WhopsBackend,
) -> SweepResult {
    rotation_sweep_cached(
        graph,
        tcoords,
        pcoords,
        alloc,
        map_cfg,
        sweep,
        backend,
        &SweepCache::new(),
    )
}

/// [`rotation_sweep`] with a caller-held [`SweepCache`]: proc-side
/// partitions missing from the cache are computed and left in it, so
/// consecutive sweeps against the same `(pcoords, map_cfg)` — the batched
/// service path — skip phase 1 entirely after the first graph of each task
/// count. With a fresh cache this *is* `rotation_sweep`.
#[allow(clippy::too_many_arguments)]
pub fn rotation_sweep_cached(
    graph: &TaskGraph,
    tcoords: &Coords,
    pcoords: &Coords,
    alloc: &Allocation,
    map_cfg: &MapConfig,
    sweep: &SweepConfig,
    backend: &dyn WhopsBackend,
    cache: &SweepCache,
) -> SweepResult {
    let par = sweep.parallelism();
    let candidates = candidate_rotations(tcoords.dim(), pcoords.dim(), sweep.max_candidates);
    let tnum = tcoords.len();

    // Phase 1: the processor-side partition depends only on the proc
    // permutation (and the task count), so compute it once per distinct
    // permutation (in parallel) and memoize.
    let mut distinct: Vec<Vec<usize>> = Vec::new();
    for (_, pp) in &candidates {
        if !distinct.iter().any(|q| q == pp) {
            distinct.push(pp.clone());
        }
    }
    par::map_with(par, &distinct, MjScratch::new, |scratch, _i, pp| {
        cache.get_or_compute(pcoords, pp, tnum, map_cfg, Parallelism::sequential(), scratch);
    });

    // Phase 2: per-candidate task partition + join + score, fanned out with
    // per-worker scratch arenas. Within a candidate the work is sequential:
    // the candidate-level fan-out already saturates the budget.
    //
    // Observability: workers measure per-candidate elapsed time as plain
    // data (only when the recorder is live — the timing reads never run on
    // the cold path) and the calling thread emits the `sweep.candidate`
    // instants after the reduction, in candidate-index order, so traces are
    // deterministic at every thread count.
    let recording = crate::obs::recording();
    let scorer = CandidateScorer::new(graph, alloc, sweep);
    let results: Vec<(Vec<u32>, f64, u64)> = par::map_with(
        par,
        &candidates,
        || (MappingScratch::new(), ObjectiveScratch::new()),
        |(map_scratch, score_scratch), _i, (tp, pp)| {
            let t0 = recording.then(std::time::Instant::now);
            let proc = cache
                .get(tnum, pp)
                .expect("proc partition precomputed in phase 1");
            let mapping = map_tasks_with_proc(
                tcoords,
                tp,
                &proc,
                map_cfg,
                Parallelism::sequential(),
                map_scratch,
            );
            let score = scorer.score(&mapping, backend, score_scratch);
            let elapsed_us = t0.map_or(0, |t| t.elapsed().as_micros() as u64);
            (mapping, score, elapsed_us)
        },
    );
    if recording {
        for (i, (_, score, elapsed_us)) in results.iter().enumerate() {
            crate::obs::instant(
                "sweep.candidate",
                &[
                    ("index", i as f64),
                    ("score", *score),
                    ("elapsed_us", *elapsed_us as f64),
                ],
            );
        }
    }

    // Deterministic reduction: argmin with index tie-break over the
    // index-addressed score vector.
    let scores: Vec<f64> = results.iter().map(|(_, s, _)| *s).collect();
    let chosen = scores
        .iter()
        .enumerate()
        .min_by(|a, b| a.1.partial_cmp(b.1).unwrap().then(a.0.cmp(&b.0)))
        .map(|(i, _)| i)
        .unwrap();
    SweepResult {
        task_to_rank: results.into_iter().nth(chosen).unwrap().0,
        chosen,
        scores,
        candidates,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::stencil::stencil_graph;
    use crate::machine::{Allocation, Network, NumaNodeCosts, NumaTopology};
    use crate::metrics::eval_hops;

    fn line_alloc(n: usize) -> Allocation {
        Allocation {
            machine: Network::torus(&[n]),
            core_router: (0..n as u32).collect(),
            core_node: (0..n as u32).collect(),
            ranks_per_node: 1,
        }
    }

    #[test]
    fn permutation_count() {
        assert_eq!(axis_permutations(1).len(), 1);
        assert_eq!(axis_permutations(3).len(), 6);
        assert_eq!(axis_permutations(5).len(), 120);
        assert_eq!(axis_permutations(3)[0], vec![0, 1, 2]);
    }

    #[test]
    fn candidates_capped_and_include_identity() {
        let c = candidate_rotations(3, 3, 10);
        assert_eq!(c.len(), 10);
        assert_eq!(c[0], ((0..3).collect::<Vec<_>>(), (0..3).collect()));
        let full = candidate_rotations(3, 3, 100);
        assert_eq!(full.len(), 36);
    }

    #[test]
    fn scores_match_eval_hops_weighted() {
        // score_mappings must agree with the metrics engine on WeightedHops
        // (for one-rank-per-node allocations where intra-node never
        // triggers).
        let g = stencil_graph(&[4, 4], false, 3.0);
        let alloc = line_alloc(16);
        let m: Vec<u32> = (0..16u32).rev().collect();
        let scores = score_mappings(&g, &[m.clone()], &alloc, &NativeBackend, 7);
        let metric = eval_hops(&g, &m, &alloc);
        assert!(
            (scores[0] - metric.weighted_hops).abs() < 1e-3,
            "{} vs {}",
            scores[0],
            metric.weighted_hops
        );
    }

    #[test]
    fn chunking_invariant() {
        let g = stencil_graph(&[8, 8], false, 1.5);
        let alloc = line_alloc(64);
        let m: Vec<u32> = (0..64u32).map(|i| (i * 7) % 64).collect();
        let a = score_mappings(&g, &[m.clone()], &alloc, &NativeBackend, 1000);
        let b = score_mappings(&g, &[m.clone()], &alloc, &NativeBackend, 13);
        assert!((a[0] - b[0]).abs() < 1e-2);
    }

    #[test]
    fn scratch_reuse_never_leaks_weights_across_calls() {
        // The w watermark must make a reused scratch score exactly like a
        // fresh one — across scorers with different chunk sizes, graphs
        // with shrinking edge counts, and repeat calls that leave a short
        // dirty prefix behind.
        let alloc = line_alloc(64);
        let big = stencil_graph(&[8, 8], false, 1.5);
        let small = stencil_graph(&[2, 8], false, 1.5);
        let m_big: Vec<u32> = (0..64u32).map(|i| (i * 7) % 64).collect();
        let m_small: Vec<u32> = (0..16u32).collect();
        let mut reused = ScoreScratch::new();
        let cases: [(usize, &TaskGraph, &Vec<u32>); 5] = [
            (128, &big, &m_big),     // one partial chunk: dirty prefix left
            (13, &big, &m_big),      // chunk shrink: w reallocated
            (13, &small, &m_small),  // same chunk, fewer edges: stale tail
            (128, &small, &m_small), // chunk grow: w reallocated
            (128, &big, &m_big),     // longer edge list over a short dirty prefix
        ];
        for (chunk, g, map) in cases {
            let scorer = BatchScorer::new(g, &alloc, chunk);
            let got = scorer.score_one(map, &NativeBackend, &mut reused);
            let fresh = scorer.score_one(map, &NativeBackend, &mut ScoreScratch::new());
            assert_eq!(got, fresh, "chunk={chunk}");
        }
    }

    #[test]
    fn parallel_scoring_bit_identical() {
        let g = stencil_graph(&[8, 8], false, 1.5);
        let alloc = line_alloc(64);
        let mappings: Vec<Vec<u32>> = (0..9)
            .map(|s| (0..64u32).map(|i| (i * 7 + s) % 64).collect())
            .collect();
        let seq = score_mappings_par(
            &g,
            &mappings,
            &alloc,
            &NativeBackend,
            128,
            Parallelism::sequential(),
        );
        for threads in [2, 8] {
            let par = score_mappings_par(
                &g,
                &mappings,
                &alloc,
                &NativeBackend,
                128,
                Parallelism::threads(threads),
            );
            assert_eq!(par, seq, "threads={threads}");
        }
    }

    #[test]
    fn sweep_picks_minimum() {
        // 2D tasks onto a 2D grid of ranks: the sweep must return the
        // candidate whose score equals the min of all scores.
        let g = stencil_graph(&[4, 8], false, 1.0);
        let alloc = Allocation {
            machine: Network::torus(&[8, 4]),
            core_router: (0..32u32).collect(),
            core_node: (0..32u32).collect(),
            ranks_per_node: 1,
        };
        let t = g.coords.clone();
        let p = alloc.proc_coords();
        let res = rotation_sweep(
            &g,
            &t,
            &p,
            &alloc,
            &MapConfig::default(),
            &SweepConfig::default(),
            &NativeBackend,
        );
        let min = res.scores.iter().cloned().fold(f64::INFINITY, f64::min);
        assert_eq!(res.scores[res.chosen], min);
        // And the returned mapping really has that WeightedHops.
        let m = eval_hops(&g, &res.task_to_rank, &alloc);
        assert!((m.weighted_hops - min).abs() < 1e-3);
    }

    #[test]
    fn sweep_beats_worst_rotation() {
        // On an anisotropic problem the best rotation must strictly beat
        // the worst one (otherwise the sweep is pointless).
        let g = stencil_graph(&[2, 16], false, 1.0);
        let alloc = Allocation {
            machine: Network::torus(&[16, 2]),
            core_router: (0..32u32).collect(),
            core_node: (0..32u32).collect(),
            ranks_per_node: 1,
        };
        let res = rotation_sweep(
            &g,
            &g.coords,
            &alloc.proc_coords(),
            &alloc,
            &MapConfig {
                longest_dim: false, // make rotation matter
                ..Default::default()
            },
            &SweepConfig::default(),
            &NativeBackend,
        );
        let max = res.scores.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        assert!(res.scores[res.chosen] < max);
    }

    #[test]
    fn sweep_under_routed_objective_picks_its_own_minimum() {
        // Under MaxLinkLoad the chosen candidate must minimize the routed
        // bottleneck latency (verified against the metrics engine), not
        // WeightedHops.
        use crate::metrics::eval_full;
        use crate::objective::ObjectiveKind;
        let g = stencil_graph(&[2, 16], false, 1.0);
        let alloc = Allocation {
            machine: Network::torus(&[16, 2]),
            core_router: (0..32u32).collect(),
            core_node: (0..32u32).collect(),
            ranks_per_node: 1,
        };
        let map_cfg = MapConfig {
            longest_dim: false,
            ..Default::default()
        };
        for objective in [ObjectiveKind::MaxLinkLoad, ObjectiveKind::CongestionBlend] {
            let sweep = SweepConfig {
                spec: MapSpec {
                    objective,
                    ..Default::default()
                },
                ..Default::default()
            };
            let res = rotation_sweep(
                &g,
                &g.coords,
                &alloc.proc_coords(),
                &alloc,
                &map_cfg,
                &sweep,
                &NativeBackend,
            );
            let min = res.scores.iter().cloned().fold(f64::INFINITY, f64::min);
            assert_eq!(res.scores[res.chosen], min, "{objective:?}");
            let m = eval_full(&g, &res.task_to_rank, &alloc);
            let want = objective.value_from_metrics(&m);
            assert!(
                (res.scores[res.chosen] - want).abs() <= 1e-9 * want.max(1.0),
                "{objective:?}: sweep score {} vs metrics {want}",
                res.scores[res.chosen]
            );
        }
    }

    #[test]
    fn sweep_under_numa_pricing_picks_its_own_minimum() {
        // With numa node costs set, the chosen candidate minimizes the
        // numa_node_score (intra-node edges at the flat socket cost), and
        // the winning score matches a re-evaluation of the mapping.
        let g = stencil_graph(&[2, 16], false, 1.0);
        // 16 nodes of 2 ranks each on a 16-ring.
        let alloc = Allocation {
            machine: Network::torus(&[16]),
            core_router: (0..32u32).map(|r| r / 2).collect(),
            core_node: (0..32u32).map(|r| r / 2).collect(),
            ranks_per_node: 2,
        };
        let costs = NumaNodeCosts {
            hop: 1.0,
            socket: 0.5,
        };
        // node_level_costs() of this topology is exactly `costs`.
        let sweep = SweepConfig {
            spec: MapSpec {
                numa: Some(NumaTopology::new(1, 2, 0.5, 0.0, 1.0)),
                ..Default::default()
            },
            ..Default::default()
        };
        let map_cfg = MapConfig {
            longest_dim: false,
            ..Default::default()
        };
        let res = rotation_sweep(
            &g,
            &g.coords,
            &alloc.proc_coords(),
            &alloc,
            &map_cfg,
            &sweep,
            &NativeBackend,
        );
        let min = res.scores.iter().cloned().fold(f64::INFINITY, f64::min);
        assert_eq!(res.scores[res.chosen], min);
        assert_eq!(
            res.scores[res.chosen],
            numa_node_score(&g, &res.task_to_rank, &alloc, costs)
        );
    }

    #[test]
    fn sweep_under_blended_pricing_picks_its_own_minimum() {
        // Routed congestion x NUMA: the chosen candidate minimizes the
        // blended score, and the winning score matches a re-evaluation of
        // the mapping through the evaluator's full-candidate scorer.
        use crate::metrics::LinkAccumulator;
        use crate::objective::LinkCosts;
        let g = stencil_graph(&[2, 16], false, 1.0);
        // 16 nodes of 2 ranks each on a 16-ring.
        let alloc = Allocation {
            machine: Network::torus(&[16]),
            core_router: (0..32u32).map(|r| r / 2).collect(),
            core_node: (0..32u32).map(|r| r / 2).collect(),
            ranks_per_node: 2,
        };
        let costs = NumaNodeCosts {
            hop: 1.0,
            socket: 0.5,
        };
        for objective in [ObjectiveKind::MaxLinkLoad, ObjectiveKind::CongestionBlend] {
            let sweep = SweepConfig {
                spec: MapSpec {
                    objective,
                    numa: Some(NumaTopology::new(1, 2, 0.5, 0.0, 1.0)),
                    ..Default::default()
                },
                ..Default::default()
            };
            let map_cfg = MapConfig {
                longest_dim: false,
                ..Default::default()
            };
            let res = rotation_sweep(
                &g,
                &g.coords,
                &alloc.proc_coords(),
                &alloc,
                &map_cfg,
                &sweep,
                &NativeBackend,
            );
            let min = res.scores.iter().cloned().fold(f64::INFINITY, f64::min);
            assert_eq!(res.scores[res.chosen], min, "{objective:?}");
            let link_costs = LinkCosts::new(&alloc.machine);
            let mut acc = LinkAccumulator::new(&alloc.machine);
            let want = blended_candidate_score(
                &g,
                &res.task_to_rank,
                &alloc,
                objective,
                costs.socket,
                &link_costs,
                &mut acc,
            );
            assert_eq!(res.scores[res.chosen], want, "{objective:?}");
        }
    }

    #[test]
    fn sweep_parallel_bit_identical_and_matches_direct_mapping() {
        let g = stencil_graph(&[4, 8], false, 1.0);
        let alloc = Allocation {
            machine: Network::torus(&[8, 4]),
            core_router: (0..32u32).collect(),
            core_node: (0..32u32).collect(),
            ranks_per_node: 1,
        };
        let p = alloc.proc_coords();
        let map_cfg = MapConfig {
            longest_dim: false, // make rotation matter
            ..Default::default()
        };
        let mk = |threads| SweepConfig {
            spec: MapSpec {
                threads,
                ..Default::default()
            },
            ..Default::default()
        };
        let seq = rotation_sweep(&g, &g.coords, &p, &alloc, &map_cfg, &mk(1), &NativeBackend);
        for threads in [2, 8] {
            let par =
                rotation_sweep(&g, &g.coords, &p, &alloc, &map_cfg, &mk(threads), &NativeBackend);
            assert_eq!(par.chosen, seq.chosen, "threads={threads}");
            assert_eq!(par.scores, seq.scores, "threads={threads}");
            assert_eq!(par.task_to_rank, seq.task_to_rank, "threads={threads}");
        }
        // The memoized proc-side path must agree with mapping materialized
        // permuted coordinates directly.
        let (tp, pp) = &seq.candidates[seq.chosen];
        let direct = super::super::map_tasks(
            &g.coords.permute_axes(tp),
            &p.permute_axes(pp),
            &map_cfg,
        );
        assert_eq!(seq.task_to_rank, direct);
    }

    #[test]
    fn sweep_emits_candidate_instants_in_index_order() {
        let g = stencil_graph(&[4, 8], false, 1.0);
        let alloc = Allocation {
            machine: Network::torus(&[8, 4]),
            core_router: (0..32u32).collect(),
            core_node: (0..32u32).collect(),
            ranks_per_node: 1,
        };
        let p = alloc.proc_coords();
        let run = || {
            rotation_sweep(
                &g,
                &g.coords,
                &p,
                &alloc,
                &MapConfig::default(),
                &SweepConfig {
                    spec: MapSpec {
                        threads: 2,
                        ..Default::default()
                    },
                    ..Default::default()
                },
                &NativeBackend,
            )
        };
        let baseline = run();
        let (traced, events) = crate::obs::capture(run);
        assert_eq!(traced.task_to_rank, baseline.task_to_rank);
        assert_eq!(traced.scores, baseline.scores);
        let instants: Vec<&crate::obs::Event> = events
            .iter()
            .filter(|e| e.name == "sweep.candidate")
            .collect();
        assert_eq!(instants.len(), baseline.scores.len());
        for (i, e) in instants.iter().enumerate() {
            let field = |k: &str| {
                e.fields
                    .iter()
                    .find(|(n, _)| *n == k)
                    .map(|(_, v)| *v)
                    .unwrap()
            };
            assert_eq!(field("index"), i as f64);
            assert_eq!(field("score"), baseline.scores[i]);
            assert!(field("elapsed_us") >= 0.0);
        }
    }
}
