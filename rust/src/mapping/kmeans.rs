//! Closest-subset selection for the `tnum < pnum` case (Section 4.2,
//! case 3): when there are more ranks than tasks, choose the most compact
//! subset of `k` ranks and leave the rest idle.
//!
//! The paper cites a modified K-means (Hartigan–Wong): we iterate
//! "pick the k points nearest the current centroid; recenter on the picked
//! set" to convergence, which is exactly 1-means with a cardinality
//! constraint.

use crate::geom::Coords;

/// Indices of the `k` most compact points. Deterministic.
pub fn closest_subset(coords: &Coords, k: usize, max_iters: usize) -> Vec<usize> {
    let n = coords.len();
    let dim = coords.dim();
    assert!(k >= 1 && k <= n);
    if k == n {
        return (0..n).collect();
    }
    // Start from the global centroid.
    let mut centroid: Vec<f64> = (0..dim)
        .map(|d| coords.axis(d).iter().sum::<f64>() / n as f64)
        .collect();
    let mut chosen: Vec<usize> = Vec::new();
    for _ in 0..max_iters {
        // k nearest to the centroid (squared Euclidean; ties by index).
        let mut keyed: Vec<(f64, usize)> = (0..n)
            .map(|i| {
                let mut d2 = 0.0;
                for d in 0..dim {
                    let dx = coords.get(d, i) - centroid[d];
                    d2 += dx * dx;
                }
                (d2, i)
            })
            .collect();
        keyed.select_nth_unstable_by(k - 1, |a, b| {
            a.0.partial_cmp(&b.0).unwrap().then(a.1.cmp(&b.1))
        });
        let mut next: Vec<usize> = keyed[..k].iter().map(|&(_, i)| i).collect();
        next.sort_unstable();
        if next == chosen {
            break;
        }
        // Recenter on the chosen subset.
        for d in 0..dim {
            centroid[d] =
                next.iter().map(|&i| coords.get(d, i)).sum::<f64>() / k as f64;
        }
        chosen = next;
    }
    chosen
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn picks_the_tight_cluster() {
        // 5 points near the origin, 5 far away: k=5 must pick the cluster.
        let mut c = Coords::new(2);
        for i in 0..5 {
            c.push(&[i as f64 * 0.1, 0.0]);
        }
        for i in 0..5 {
            c.push(&[100.0 + i as f64, 50.0]);
        }
        let s = closest_subset(&c, 5, 20);
        assert_eq!(s, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn k_equals_n_returns_all() {
        let c = Coords::from_axes(vec![vec![0.0, 1.0, 2.0]]);
        assert_eq!(closest_subset(&c, 3, 5), vec![0, 1, 2]);
    }

    #[test]
    fn deterministic() {
        let c = Coords::from_axes(vec![
            (0..50).map(|i| ((i * 37) % 50) as f64).collect(),
            (0..50).map(|i| ((i * 13) % 50) as f64).collect(),
        ]);
        assert_eq!(closest_subset(&c, 10, 20), closest_subset(&c, 10, 20));
    }

    #[test]
    fn subset_is_compact() {
        // On a 10x10 grid, the best 25-subset has spread ~5; accept <= 7.
        let mut c = Coords::new(2);
        for y in 0..10 {
            for x in 0..10 {
                c.push(&[x as f64, y as f64]);
            }
        }
        let s = closest_subset(&c, 25, 20);
        assert_eq!(s.len(), 25);
        let xs: Vec<f64> = s.iter().map(|&i| c.get(0, i)).collect();
        let ys: Vec<f64> = s.iter().map(|&i| c.get(1, i)).collect();
        let ext = |v: &[f64]| {
            v.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
                - v.iter().cloned().fold(f64::INFINITY, f64::min)
        };
        assert!(ext(&xs) <= 7.0 && ext(&ys) <= 7.0);
    }
}
