//! Torus coordinate shifting (Section 4.3, "Shifting the machine
//! coordinates").
//!
//! MJ sees only coordinates, not wraparound links, so an allocation that
//! straddles the torus seam looks torn apart. The fix: per dimension, find
//! the largest cyclic gap in the occupied coordinates and, if it is larger
//! than one, translate the coordinates on the low side of the gap by the
//! dimension extent — making the occupied set contiguous.

use crate::geom::Coords;

/// Shift one dimension's coordinates in place. `size` is the torus extent.
/// Returns the gap (start, length) that was opened at the seam, if any
/// shift was applied.
pub fn shift_dim(values: &mut [f64], size: usize) -> Option<(usize, usize)> {
    // Occupied integer coordinates.
    let mut present = vec![false; size];
    for &v in values.iter() {
        let c = v as usize;
        assert!(c < size && v.fract() == 0.0, "shift_dim needs integer coords < size");
        present[c] = true;
    }
    // Largest cyclic run of absent coordinates.
    let occupied: Vec<usize> = (0..size).filter(|&c| present[c]).collect();
    if occupied.is_empty() || occupied.len() == size {
        return None;
    }
    let mut best_len = 0usize;
    let mut best_after = 0usize; // occupied coordinate preceding the gap
    for (k, &c) in occupied.iter().enumerate() {
        let next = occupied[(k + 1) % occupied.len()];
        let gap = (next + size - c - 1) % size;
        if gap > best_len {
            best_len = gap;
            best_after = c;
        }
    }
    if best_len <= 1 {
        return None; // paper: only shift when the largest gap exceeds one
    }
    // Translate everything at or below `best_after` up by `size`, so the
    // occupied set becomes contiguous starting just after the gap.
    for v in values.iter_mut() {
        if (*v as usize) <= best_after {
            *v += size as f64;
        }
    }
    Some((best_after + 1, best_len))
}

/// Shift every dimension of a machine coordinate set (wrapped dims only).
pub fn shift_torus_coords(coords: &mut Coords, sizes: &[usize], wrap: &[bool]) {
    assert_eq!(coords.dim(), sizes.len());
    for d in 0..coords.dim() {
        if wrap[d] {
            shift_dim(coords.axis_mut(d), sizes[d]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shifts_seam_straddling_set() {
        // Occupied {6,7,0,1} on a ring of 8: gap 2..5 (len 4). After the
        // shift, {6,7,8,9} — contiguous.
        let mut v = vec![6.0, 7.0, 0.0, 1.0];
        let got = shift_dim(&mut v, 8);
        assert_eq!(got, Some((2, 4)));
        assert_eq!(v, vec![6.0, 7.0, 8.0, 9.0]);
    }

    #[test]
    fn no_shift_when_contiguous() {
        let mut v = vec![2.0, 3.0, 4.0];
        let orig = v.clone();
        // Gap is 5..1 cyclically (len 6) — the shift translates 2,3,4 up.
        // Wait: occupied {2,3,4}: gap after 4 wraps to 2, len 5 > 1 => shift
        // of everything <= 4 ... which is the whole set: a pure translation.
        let got = shift_dim(&mut v, 8);
        assert!(got.is_some());
        // A pure translation preserves pairwise distances.
        for i in 0..v.len() {
            for j in 0..v.len() {
                assert_eq!(v[i] - v[j], orig[i] - orig[j]);
            }
        }
    }

    #[test]
    fn preserves_cyclic_adjacency() {
        // After shifting, torus-adjacent occupied coords must be adjacent
        // in the shifted (linear) coordinates.
        let mut v = vec![7.0, 0.0];
        shift_dim(&mut v, 8);
        assert_eq!((v[1] - v[0]).abs(), 1.0);
    }

    #[test]
    fn no_shift_for_full_ring() {
        let mut v: Vec<f64> = (0..8).map(|x| x as f64).collect();
        assert_eq!(shift_dim(&mut v, 8), None);
        assert_eq!(v, (0..8).map(|x| x as f64).collect::<Vec<_>>());
    }

    #[test]
    fn gap_of_one_not_shifted() {
        // Occupied {0,1,2,3,5,6,7}: the only gap is {4}, length 1.
        let mut v = vec![0.0, 1.0, 2.0, 3.0, 5.0, 6.0, 7.0];
        assert_eq!(shift_dim(&mut v, 8), None);
    }

    #[test]
    fn shift_torus_coords_only_wrapped_dims() {
        let mut c = Coords::from_axes(vec![vec![7.0, 0.0], vec![7.0, 0.0]]);
        shift_torus_coords(&mut c, &[8, 8], &[true, false]);
        assert_eq!(c.axis(0), &[7.0, 8.0]); // shifted
        assert_eq!(c.axis(1), &[7.0, 0.0]); // mesh dim untouched
    }

    #[test]
    fn duplicate_coords_shift_together() {
        let mut v = vec![7.0, 7.0, 0.0, 0.0, 1.0];
        shift_dim(&mut v, 8);
        assert_eq!(v, vec![7.0, 7.0, 8.0, 8.0, 9.0]);
    }
}
