//! Content-addressed result cache for `op:"map"` replies.
//!
//! Keyed on the canonical fingerprint of the *full request identity*
//! ([`crate::util::fingerprint`] over the request object minus the
//! `"cache"`/`"profile"` control fields): task coords, weights, edges,
//! allocation — heterogeneous node sizes included — topology, objective,
//! numa, hier, and coarsen config all land in the key, so two requests
//! share an entry only when they describe the same computation. Every
//! mapping path in the crate is bit-identical at every thread count, so a
//! cached reply is byte-for-byte the reply a cold run would produce —
//! caching is pure routing, never an approximation.
//!
//! Shape:
//!
//! * **Sharded** — the key picks a shard (after one extra splitmix64 round
//!   so low-entropy fingerprints still spread), each shard is an
//!   independently locked map; workers on different keys rarely contend.
//! * **Capacity-bounded LRU** — a global logical clock stamps entries on
//!   insert and on hit; when a shard overflows its slice of the capacity,
//!   the stalest *ready* entry is evicted (in-flight entries are never
//!   evicted). Shards are small (capacity/shards entries), so the O(shard)
//!   eviction scan is a few dozen comparisons.
//! * **Single-flight** — the first miss installs an in-flight [`Flight`]
//!   and computes; concurrent identical requests park on its condvar
//!   (bounded by their own deadlines) instead of running N sweeps. The
//!   leader's [`LeaderGuard`] is RAII: if the leader unwinds before
//!   completing (an injected `service.cache.leader.panic`, say), `Drop`
//!   removes the in-flight entry and resolves waiters to
//!   [`FlightOutcome::Failed`] — followers get a structured `internal`
//!   error, never a hang, and the poisoned key is recomputed from scratch
//!   by the next request.
//!
//! Error replies (`"ok":false`) propagate to coalesced waiters — they
//! asked for the identical computation and get its actual outcome — but
//! are **never stored**: a deadline blip must not serve failures to the
//! future.

use crate::obs;
use crate::testutil::json::Json;
use crate::util::hash::splitmix64;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::time::Duration;

use crate::par::Deadline;

/// How often parked followers re-check their deadline.
const WAIT_POLL: Duration = Duration::from_millis(5);

/// Mutex lock that shrugs off poisoning: cache state is a `Json` clone +
/// counters, valid at every step, so a panicking holder leaves nothing
/// half-written worth propagating.
fn lock_ok<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    match m.lock() {
        Ok(g) => g,
        Err(poisoned) => poisoned.into_inner(),
    }
}

#[derive(Default)]
struct Shard {
    entries: HashMap<u64, Entry>,
}

enum Entry {
    /// A completed reply, LRU-stamped.
    Ready { resp: Json, stamp: u64 },
    /// A computation in progress; identical requests park on it.
    InFlight(Arc<Flight>),
}

/// What a single-flight leader eventually tells its followers.
#[derive(Clone)]
pub enum FlightOutcome {
    /// The leader's reply (success or a structured error), verbatim.
    Reply(Json),
    /// The leader unwound before producing a reply.
    Failed,
}

/// Rendezvous for requests coalesced onto one in-flight computation.
pub struct Flight {
    outcome: Mutex<Option<FlightOutcome>>,
    ready: Condvar,
}

impl Flight {
    fn new() -> Flight {
        Flight {
            outcome: Mutex::new(None),
            ready: Condvar::new(),
        }
    }

    fn resolve(&self, out: FlightOutcome) {
        let mut g = lock_ok(&self.outcome);
        if g.is_none() {
            *g = Some(out);
        }
        drop(g);
        self.ready.notify_all();
    }

    /// Park until the leader publishes an outcome, or `deadline` expires
    /// (`None` — the caller turns that into `deadline_exceeded`).
    pub fn wait(&self, deadline: Deadline) -> Option<FlightOutcome> {
        let mut g = lock_ok(&self.outcome);
        loop {
            if let Some(out) = g.as_ref() {
                return Some(out.clone());
            }
            if deadline.expired() {
                return None;
            }
            g = match self.ready.wait_timeout(g, WAIT_POLL) {
                Ok((g, _)) => g,
                Err(poisoned) => poisoned.into_inner().0,
            };
        }
    }
}

/// Result of [`MapCache::lookup_or_begin`].
pub enum Lookup<'a> {
    /// Ready entry: the reply to send, already cloned out of the shard.
    Hit(Json),
    /// An identical request is in flight; park on it.
    Wait(Arc<Flight>),
    /// This request leads the computation; it must call
    /// [`LeaderGuard::complete`] (or unwind and let `Drop` clean up).
    Miss(LeaderGuard<'a>),
}

/// Sharded, capacity-bounded, single-flight LRU of map replies.
pub struct MapCache {
    shards: Vec<Mutex<Shard>>,
    capacity: usize,
    shard_capacity: usize,
    clock: AtomicU64,
    hits: AtomicU64,
    misses: AtomicU64,
    coalesced: AtomicU64,
    inserts: AtomicU64,
    evictions: AtomicU64,
    bypass: AtomicU64,
    leader_failures: AtomicU64,
}

impl MapCache {
    /// `capacity` total ready entries across `shards` shards (both clamped
    /// to at least 1; a capacity-0 cache is represented by not
    /// constructing one).
    pub fn new(capacity: usize, shards: usize) -> MapCache {
        let capacity = capacity.max(1);
        let nshards = shards.clamp(1, capacity);
        MapCache {
            shards: (0..nshards).map(|_| Mutex::new(Shard::default())).collect(),
            capacity,
            shard_capacity: capacity.div_ceil(nshards),
            clock: AtomicU64::new(0),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            coalesced: AtomicU64::new(0),
            inserts: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
            bypass: AtomicU64::new(0),
            leader_failures: AtomicU64::new(0),
        }
    }

    fn tick(&self) -> u64 {
        self.clock.fetch_add(1, Ordering::Relaxed) + 1
    }

    fn shard(&self, key: u64) -> &Mutex<Shard> {
        &self.shards[(splitmix64(key) % self.shards.len() as u64) as usize]
    }

    fn count(name: &'static str) {
        if obs::recording() {
            obs::metrics().add(name, 1);
        }
    }

    /// One cache transaction: hit (LRU-bumped reply clone), coalesce onto
    /// an in-flight computation, or become the leader for this key.
    pub fn lookup_or_begin(&self, key: u64) -> Lookup<'_> {
        let mut span = obs::span("cache.lookup");
        let mut shard = lock_ok(self.shard(key));
        match shard.entries.get_mut(&key) {
            Some(Entry::Ready { resp, stamp }) => {
                *stamp = self.tick();
                self.hits.fetch_add(1, Ordering::Relaxed);
                span.record("hit", 1.0);
                Self::count("service.cache.hit");
                Lookup::Hit(resp.clone())
            }
            Some(Entry::InFlight(flight)) => {
                self.coalesced.fetch_add(1, Ordering::Relaxed);
                span.record("coalesced", 1.0);
                Self::count("service.cache.coalesced");
                Lookup::Wait(Arc::clone(flight))
            }
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                span.record("hit", 0.0);
                Self::count("service.cache.miss");
                let flight = Arc::new(Flight::new());
                shard
                    .entries
                    .insert(key, Entry::InFlight(Arc::clone(&flight)));
                Lookup::Miss(LeaderGuard {
                    cache: self,
                    key,
                    flight,
                    done: false,
                })
            }
        }
    }

    /// A request skipped the cache (`"cache":false` or `"profile":true`).
    pub fn note_bypass(&self) {
        self.bypass.fetch_add(1, Ordering::Relaxed);
        Self::count("service.cache.bypass");
    }

    /// Evict stalest ready entries until `shard` fits its capacity slice.
    /// In-flight entries are pinned; if a shard is somehow all in-flight
    /// it may transiently exceed capacity rather than drop live waiters.
    fn evict_excess(&self, shard: &mut Shard) {
        while shard.entries.len() > self.shard_capacity {
            let victim = shard
                .entries
                .iter()
                .filter_map(|(k, e)| match e {
                    Entry::Ready { stamp, .. } => Some((*stamp, *k)),
                    Entry::InFlight(_) => None,
                })
                .min();
            let Some((_, k)) = victim else { break };
            shard.entries.remove(&k);
            self.evictions.fetch_add(1, Ordering::Relaxed);
            Self::count("service.cache.eviction");
        }
    }

    /// The `cache` section of `{"op":"stats"}`.
    pub fn stats_json(&self) -> Json {
        let entries: usize = self
            .shards
            .iter()
            .map(|s| lock_ok(s).entries.len())
            .sum();
        let n = |c: &AtomicU64| Json::Num(c.load(Ordering::Relaxed) as f64);
        Json::obj(vec![
            ("capacity", Json::Num(self.capacity as f64)),
            ("shards", Json::Num(self.shards.len() as f64)),
            ("entries", Json::Num(entries as f64)),
            ("hits", n(&self.hits)),
            ("misses", n(&self.misses)),
            ("coalesced", n(&self.coalesced)),
            ("inserts", n(&self.inserts)),
            ("evictions", n(&self.evictions)),
            ("bypass", n(&self.bypass)),
            ("leader_failures", n(&self.leader_failures)),
        ])
    }

    /// Hits counter (for tests/benches reconciling against stats).
    pub fn hit_count(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }
}

/// RAII handle held by the request that owns an in-flight computation.
pub struct LeaderGuard<'a> {
    cache: &'a MapCache,
    key: u64,
    flight: Arc<Flight>,
    done: bool,
}

impl LeaderGuard<'_> {
    /// Is this guard's flight still the one installed under the key? A
    /// leader failure may have been replaced by a newer computation; never
    /// clobber someone else's entry.
    fn owns_entry(&self, shard: &Shard) -> bool {
        matches!(
            shard.entries.get(&self.key),
            Some(Entry::InFlight(f)) if Arc::ptr_eq(f, &self.flight)
        )
    }

    /// Publish the computed reply to coalesced waiters and — when it is a
    /// success — store it in the LRU. Error replies reach the waiters
    /// (they coalesced onto exactly this computation) but are never
    /// cached.
    pub fn complete(mut self, resp: &Json) {
        self.done = true;
        let store = resp.get("ok") == Some(&Json::Bool(true));
        {
            let mut span = obs::span("cache.insert");
            span.record("stored", if store { 1.0 } else { 0.0 });
            let mut shard = lock_ok(self.cache.shard(self.key));
            if self.owns_entry(&shard) {
                if store {
                    let stamp = self.cache.tick();
                    shard.entries.insert(
                        self.key,
                        Entry::Ready {
                            resp: resp.clone(),
                            stamp,
                        },
                    );
                    self.cache.inserts.fetch_add(1, Ordering::Relaxed);
                    MapCache::count("service.cache.insert");
                    self.cache.evict_excess(&mut shard);
                } else {
                    shard.entries.remove(&self.key);
                }
            }
        }
        self.flight.resolve(FlightOutcome::Reply(resp.clone()));
    }
}

impl Drop for LeaderGuard<'_> {
    fn drop(&mut self) {
        if self.done {
            return;
        }
        // The leader unwound before completing: un-poison the key and fail
        // the waiters over to a structured error instead of a hang.
        self.cache.leader_failures.fetch_add(1, Ordering::Relaxed);
        MapCache::count("service.cache.leader_failure");
        {
            let mut shard = lock_ok(self.cache.shard(self.key));
            if self.owns_entry(&shard) {
                shard.entries.remove(&self.key);
            }
        }
        self.flight.resolve(FlightOutcome::Failed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    fn ok_reply(tag: f64) -> Json {
        Json::obj(vec![("ok", Json::Bool(true)), ("tag", Json::Num(tag))])
    }

    #[test]
    fn miss_then_hit_returns_the_stored_reply() {
        let c = MapCache::new(8, 2);
        let Lookup::Miss(leader) = c.lookup_or_begin(1) else {
            panic!("first lookup must miss");
        };
        leader.complete(&ok_reply(7.0));
        match c.lookup_or_begin(1) {
            Lookup::Hit(resp) => assert_eq!(resp, ok_reply(7.0)),
            _ => panic!("second lookup must hit"),
        }
        assert_eq!(c.hit_count(), 1);
    }

    #[test]
    fn error_replies_propagate_but_are_not_stored() {
        let c = MapCache::new(8, 1);
        let Lookup::Miss(leader) = c.lookup_or_begin(3) else {
            panic!("miss");
        };
        let err = Json::obj(vec![("ok", Json::Bool(false))]);
        leader.complete(&err);
        assert!(matches!(c.lookup_or_begin(3), Lookup::Miss(_)));
    }

    #[test]
    fn dropped_leader_unpoisons_and_fails_waiters() {
        let c = MapCache::new(8, 1);
        let Lookup::Miss(leader) = c.lookup_or_begin(5) else {
            panic!("miss");
        };
        let Lookup::Wait(flight) = c.lookup_or_begin(5) else {
            panic!("second identical request must coalesce");
        };
        drop(leader); // simulated panic-unwind
        match flight.wait(Deadline::unlimited()) {
            Some(FlightOutcome::Failed) => {}
            _ => panic!("waiter must observe the failure"),
        }
        // Key is clean again — next request recomputes.
        assert!(matches!(c.lookup_or_begin(5), Lookup::Miss(_)));
        assert_eq!(c.stats_json().get("leader_failures"), Some(&Json::Num(1.0)));
    }

    #[test]
    fn followers_receive_the_leaders_reply() {
        let c = Arc::new(MapCache::new(8, 1));
        let Lookup::Miss(leader) = c.lookup_or_begin(9) else {
            panic!("miss");
        };
        let got = Arc::new(AtomicUsize::new(0));
        let mut joins = Vec::new();
        for _ in 0..4 {
            let c = Arc::clone(&c);
            let got = Arc::clone(&got);
            joins.push(std::thread::spawn(move || {
                let Lookup::Wait(flight) = c.lookup_or_begin(9) else {
                    panic!("must coalesce while in flight");
                };
                match flight.wait(Deadline::unlimited()) {
                    Some(FlightOutcome::Reply(resp)) => {
                        assert_eq!(resp, ok_reply(1.0));
                        got.fetch_add(1, Ordering::SeqCst);
                    }
                    _ => panic!("must see the reply"),
                }
            }));
        }
        // Let followers park, then publish.
        std::thread::sleep(Duration::from_millis(20));
        leader.complete(&ok_reply(1.0));
        for j in joins {
            j.join().unwrap();
        }
        assert_eq!(got.load(Ordering::SeqCst), 4);
        assert_eq!(c.stats_json().get("coalesced"), Some(&Json::Num(4.0)));
    }

    #[test]
    fn lru_evicts_stalest_ready_entry() {
        let c = MapCache::new(2, 1);
        for key in [1u64, 2] {
            let Lookup::Miss(leader) = c.lookup_or_begin(key) else {
                panic!("miss");
            };
            leader.complete(&ok_reply(key as f64));
        }
        // Touch key 1 so key 2 is stalest, then overflow.
        assert!(matches!(c.lookup_or_begin(1), Lookup::Hit(_)));
        let Lookup::Miss(leader) = c.lookup_or_begin(3) else {
            panic!("miss");
        };
        leader.complete(&ok_reply(3.0));
        assert!(matches!(c.lookup_or_begin(1), Lookup::Hit(_)));
        assert!(matches!(c.lookup_or_begin(3), Lookup::Hit(_)));
        assert!(matches!(c.lookup_or_begin(2), Lookup::Miss(_)));
        assert_eq!(c.stats_json().get("evictions"), Some(&Json::Num(1.0)));
    }

    #[test]
    fn follower_wait_respects_deadline() {
        let c = MapCache::new(8, 1);
        let Lookup::Miss(_leader) = c.lookup_or_begin(11) else {
            panic!("miss");
        };
        let Lookup::Wait(flight) = c.lookup_or_begin(11) else {
            panic!("coalesce");
        };
        assert!(flight
            .wait(Deadline::within(Duration::from_millis(15)))
            .is_none());
    }
}
