//! Window batching for compatible small hierarchical `map` requests.
//!
//! Compatible = same batching fingerprint: the request object minus its
//! task set (`"tcoords"`/`"edges"`) and control fields — i.e. the same
//! allocation, topology, objective, numa, hier, and coarsen config.
//! Requests landing inside a short window are queued per fingerprint; the
//! first arrival becomes the flush leader, sleeps out the window, then
//! fans every queued graph through **one**
//! [`crate::hier::map_hierarchical_batch`] invocation, amortizing the
//! allocation-derived state (node coords, node-level allocation) and the
//! proc-side partition memo across the whole batch while the per-worker
//! sweep scratch arenas do what they always do. Followers park on a
//! per-job slot, bounded by their own deadlines.
//!
//! Batched mappings are **bit-identical** to solo execution — see
//! `map_hierarchical_batch`'s contract — so batching trades latency
//! (up to one window) for throughput without changing a single reply
//! byte. It is off by default ([`super::ServiceConfig::batch_window`] =
//! zero) and the flush leader is panic-isolated: an unwind mid-flush
//! resolves every unfilled slot to a structured failure (the
//! [`FlushGuard`] RAII below), so followers never hang on a dead leader.

use crate::apps::TaskGraph;
use crate::hier::{map_hierarchical_batch, HierConfig, HierJob};
use crate::machine::Allocation;
use crate::mapping::rotations::NativeBackend;
use crate::obs;
use crate::par::{Deadline, DeadlineExceeded};
use crate::testutil::json::Json;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::time::Duration;

/// How often parked followers re-check their deadline.
const WAIT_POLL: Duration = Duration::from_millis(5);

fn lock_ok<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    match m.lock() {
        Ok(g) => g,
        Err(poisoned) => poisoned.into_inner(),
    }
}

/// What a submitted job resolves to.
pub enum BatchOutcome {
    /// The batched pipeline's mapping — bit-identical to a solo run.
    Mapped(Box<crate::hier::HierMapping>),
    /// This job's own compute budget expired inside the pipeline.
    Deadline(DeadlineExceeded),
    /// The flush leader unwound before filling this slot.
    LeaderFailed,
    /// This job's budget expired while parked waiting for the flush.
    WaitExpired,
}

enum SlotState {
    Pending,
    Done(Result<crate::hier::HierMapping, DeadlineExceeded>),
    LeaderFailed,
}

/// Per-job rendezvous between a parked submitter and the flush leader.
struct Slot {
    state: Mutex<SlotState>,
    ready: Condvar,
}

impl Slot {
    fn new() -> Slot {
        Slot {
            state: Mutex::new(SlotState::Pending),
            ready: Condvar::new(),
        }
    }

    fn fill(&self, s: SlotState) {
        let mut g = lock_ok(&self.state);
        if matches!(*g, SlotState::Pending) {
            *g = s;
        }
        drop(g);
        self.ready.notify_all();
    }
}

struct PendingJob {
    graph: TaskGraph,
    deadline: Deadline,
    slot: Arc<Slot>,
}

#[derive(Default)]
struct GroupState {
    jobs: Vec<PendingJob>,
    /// A leader is sleeping out the window for this group.
    leader: bool,
    /// The group was flushed and removed from the map; late pushers that
    /// still hold its `Arc` must re-fetch instead of enqueueing into a
    /// group nobody will ever flush again.
    closed: bool,
}

#[derive(Default)]
struct Group {
    state: Mutex<GroupState>,
}

/// The batching stage: per-fingerprint queues with window-flush leaders.
pub struct Batcher {
    window: Duration,
    max_tasks: usize,
    groups: Mutex<HashMap<u64, Arc<Group>>>,
    jobs: AtomicU64,
    flushes: AtomicU64,
    coalesced: AtomicU64,
    leader_failures: AtomicU64,
}

impl Batcher {
    pub fn new(window: Duration, max_tasks: usize) -> Batcher {
        Batcher {
            window,
            max_tasks,
            groups: Mutex::new(HashMap::new()),
            jobs: AtomicU64::new(0),
            flushes: AtomicU64::new(0),
            coalesced: AtomicU64::new(0),
            leader_failures: AtomicU64::new(0),
        }
    }

    /// Largest task count eligible for batching (big sweeps dominate their
    /// own runtime; batching them would only add window latency).
    pub fn max_tasks(&self) -> usize {
        self.max_tasks
    }

    /// Enqueue one hierarchical map job under its compatibility `key` and
    /// block until it resolves. The first submitter per open group leads:
    /// it sleeps out the window, flushes everything queued by then through
    /// one `map_hierarchical_batch` call, and fills every slot.
    pub fn submit(
        &self,
        key: u64,
        graph: TaskGraph,
        deadline: Deadline,
        alloc: &Allocation,
        cfg: &HierConfig,
    ) -> BatchOutcome {
        self.jobs.fetch_add(1, Ordering::Relaxed);
        let slot = Arc::new(Slot::new());
        let mut pending = Some(PendingJob {
            graph,
            deadline,
            slot: Arc::clone(&slot),
        });
        let leader_of = loop {
            let group = {
                let mut groups = lock_ok(&self.groups);
                Arc::clone(groups.entry(key).or_default())
            };
            let mut st = lock_ok(&group.state);
            if st.closed {
                // Lost the race against this group's flush; the map entry
                // is gone, so retry against a fresh group.
                continue;
            }
            st.jobs.push(pending.take().expect("pushed at most once"));
            if st.leader {
                break None;
            }
            st.leader = true;
            break Some(group);
        };

        if let Some(group) = leader_of {
            self.lead_flush(key, &group, alloc, cfg);
        }
        self.wait(&slot, deadline)
    }

    /// Leader path: sleep out the window, atomically close + detach the
    /// group, run the batch, fill the slots.
    fn lead_flush(&self, key: u64, group: &Arc<Group>, alloc: &Allocation, cfg: &HierConfig) {
        std::thread::sleep(self.window);
        let taken: Vec<PendingJob> = {
            // groups → state nesting; `submit` never holds state while
            // taking groups, so the order is consistent crate-wide.
            let mut groups = lock_ok(&self.groups);
            let mut st = lock_ok(&group.state);
            st.closed = true;
            if let Some(current) = groups.get(&key) {
                if Arc::ptr_eq(current, group) {
                    groups.remove(&key);
                }
            }
            std::mem::take(&mut st.jobs)
        };
        self.flushes.fetch_add(1, Ordering::Relaxed);
        if taken.len() > 1 {
            self.coalesced
                .fetch_add(taken.len() as u64 - 1, Ordering::Relaxed);
        }
        if obs::recording() {
            obs::metrics().add("service.batch.jobs", taken.len() as u64);
        }
        let mut span = obs::span("batch.flush");
        span.record("jobs", taken.len() as f64);

        // Panic isolation: if the mapping library unwinds mid-flush, every
        // slot not yet filled resolves to LeaderFailed — followers get a
        // structured internal error, never a hang. (The leader's own
        // request surfaces the panic through the handler's catch_unwind.)
        let mut guard = FlushGuard {
            batcher: self,
            slots: taken.iter().map(|j| Arc::clone(&j.slot)).collect(),
            armed: true,
        };
        let jobs: Vec<HierJob<'_>> = taken
            .iter()
            .map(|j| HierJob {
                graph: &j.graph,
                tcoords: &j.graph.coords,
                deadline: j.deadline,
            })
            .collect();
        let results = map_hierarchical_batch(&jobs, alloc, cfg, &NativeBackend);
        for (job, result) in taken.iter().zip(results) {
            job.slot.fill(SlotState::Done(result));
        }
        guard.armed = false;
    }

    /// Park on `slot` until it fills or `deadline` expires.
    fn wait(&self, slot: &Slot, deadline: Deadline) -> BatchOutcome {
        let mut g = lock_ok(&slot.state);
        loop {
            match std::mem::replace(&mut *g, SlotState::Pending) {
                SlotState::Done(Ok(m)) => return BatchOutcome::Mapped(Box::new(m)),
                SlotState::Done(Err(e)) => return BatchOutcome::Deadline(e),
                SlotState::LeaderFailed => return BatchOutcome::LeaderFailed,
                SlotState::Pending => {}
            }
            if deadline.expired() {
                return BatchOutcome::WaitExpired;
            }
            g = match slot.ready.wait_timeout(g, WAIT_POLL) {
                Ok((g, _)) => g,
                Err(poisoned) => poisoned.into_inner().0,
            };
        }
    }

    /// The `batch` section of `{"op":"stats"}`.
    pub fn stats_json(&self) -> Json {
        let n = |c: &AtomicU64| Json::Num(c.load(Ordering::Relaxed) as f64);
        Json::obj(vec![
            ("window_ms", Json::Num(self.window.as_secs_f64() * 1e3)),
            ("max_tasks", Json::Num(self.max_tasks as f64)),
            ("jobs", n(&self.jobs)),
            ("flushes", n(&self.flushes)),
            ("coalesced", n(&self.coalesced)),
            ("leader_failures", n(&self.leader_failures)),
        ])
    }
}

/// Fills every slot of an unwinding flush with `LeaderFailed`.
struct FlushGuard<'a> {
    batcher: &'a Batcher,
    slots: Vec<Arc<Slot>>,
    armed: bool,
}

impl Drop for FlushGuard<'_> {
    fn drop(&mut self) {
        if !self.armed {
            return;
        }
        self.batcher.leader_failures.fetch_add(1, Ordering::Relaxed);
        for slot in &self.slots {
            slot.fill(SlotState::LeaderFailed);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::stencil::stencil_graph;
    use crate::hier::map_hierarchical_budgeted;
    use crate::machine::Network;
    use crate::mapping::MapSpec;

    fn small_alloc(nodes: usize, rpn: usize) -> Allocation {
        let machine = Network::torus(&[nodes]);
        Allocation {
            core_router: (0..nodes as u32).flat_map(|n| vec![n; rpn]).collect(),
            core_node: (0..nodes as u32).flat_map(|n| vec![n; rpn]).collect(),
            ranks_per_node: rpn,
            machine,
        }
    }

    fn cfg() -> HierConfig {
        HierConfig {
            spec: MapSpec {
                threads: 1,
                ..MapSpec::default()
            },
            ..HierConfig::default()
        }
    }

    #[test]
    fn batched_results_bit_identical_to_solo() {
        let alloc = small_alloc(4, 2);
        let cfg = cfg();
        let graphs: Vec<TaskGraph> = [(2usize, 4usize), (4, 2), (2, 2)]
            .iter()
            .map(|&(x, y)| stencil_graph(&[x, y], false, 1.0))
            .collect();
        let jobs: Vec<HierJob<'_>> = graphs
            .iter()
            .map(|g| HierJob {
                graph: g,
                tcoords: &g.coords,
                deadline: Deadline::unlimited(),
            })
            .collect();
        let batched = map_hierarchical_batch(&jobs, &alloc, &cfg, &NativeBackend);
        for (g, b) in graphs.iter().zip(batched) {
            let solo = map_hierarchical_budgeted(
                g,
                &g.coords,
                &alloc,
                &cfg,
                &NativeBackend,
                Deadline::unlimited(),
            )
            .expect("unlimited");
            let b = b.expect("unlimited");
            assert_eq!(b.task_to_rank, solo.task_to_rank);
            assert_eq!(b.task_to_node, solo.task_to_node);
            assert_eq!(b.node_score.to_bits(), solo.node_score.to_bits());
        }
    }

    #[test]
    fn concurrent_submits_coalesce_into_one_flush() {
        let alloc = Arc::new(small_alloc(4, 1));
        let cfg = Arc::new(cfg());
        let b = Arc::new(Batcher::new(Duration::from_millis(40), 1024));
        let mut joins = Vec::new();
        for i in 0..3usize {
            let (b, alloc, cfg) = (Arc::clone(&b), Arc::clone(&alloc), Arc::clone(&cfg));
            joins.push(std::thread::spawn(move || {
                let g = stencil_graph(&[2 + i, 2], false, 1.0);
                let solo = map_hierarchical_budgeted(
                    &g,
                    &g.coords,
                    &alloc,
                    &cfg,
                    &NativeBackend,
                    Deadline::unlimited(),
                )
                .expect("unlimited");
                match b.submit(7, g, Deadline::unlimited(), &alloc, &cfg) {
                    BatchOutcome::Mapped(m) => {
                        assert_eq!(m.task_to_rank, solo.task_to_rank)
                    }
                    _ => panic!("batched job must map"),
                }
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        let stats = b.stats_json();
        assert_eq!(stats.get("jobs"), Some(&Json::Num(3.0)));
        // All three raced the same 40ms window; at least one flush ran and
        // jobs − flushes were coalesced (exact split is scheduling-
        // dependent, the counters always reconcile).
        let flushes = stats.get("flushes").and_then(Json::as_f64).unwrap();
        let coalesced = stats.get("coalesced").and_then(Json::as_f64).unwrap();
        assert!(flushes >= 1.0);
        assert_eq!(flushes + coalesced, 3.0);
    }

    #[test]
    fn late_submit_after_flush_gets_a_fresh_group() {
        let alloc = small_alloc(4, 1);
        let cfg = cfg();
        let b = Batcher::new(Duration::from_millis(1), 1024);
        for _ in 0..2 {
            let g = stencil_graph(&[2, 2], false, 1.0);
            match b.submit(9, g, Deadline::unlimited(), &alloc, &cfg) {
                BatchOutcome::Mapped(_) => {}
                _ => panic!("sequential submits must both map"),
            }
        }
        assert_eq!(b.stats_json().get("flushes"), Some(&Json::Num(2.0)));
        assert!(lock_ok(&b.groups).is_empty(), "flushed groups are removed");
    }
}
