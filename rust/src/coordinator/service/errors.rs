//! Structured error taxonomy for the mapping service.
//!
//! Every failure the service can produce is one of five [`ErrorKind`]s,
//! each tagged retryable or not, serialized as an object instead of a flat
//! string:
//!
//! ```json
//! {"ok":false,"error":{"kind":"overloaded","message":"...",
//!                      "retryable":true,"retry_after_ms":50}}
//! ```
//!
//! `retry_after_ms` appears only on `overloaded` replies — it is the
//! server's backpressure hint, honored by
//! [`super::client::request_with_retry`]. Clients that predate the
//! taxonomy keep working: `"ok"` is still the success discriminator, and
//! the human-readable message is still present (under
//! `error.message`).

use crate::testutil::json::Json;

/// The five failure classes of the service (see the module docs of
/// [`super`] for the full table).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ErrorKind {
    /// The request itself is malformed (bad JSON, unknown fields, invalid
    /// values, oversized payload). Retrying the same bytes cannot succeed.
    InvalidRequest,
    /// The bounded queue is full and the request was shed before any work
    /// started. Retryable — the reply carries `retry_after_ms`.
    Overloaded,
    /// The request was valid but its compute budget expired at a phase
    /// boundary. Not retryable as-is: the same request will time out again.
    DeadlineExceeded,
    /// The service is draining for shutdown. Retryable against a replica
    /// (or after a restart).
    ShuttingDown,
    /// A handler panicked (a library bug, not a client error). The panic
    /// message is logged to the diagnostics ring buffer.
    Internal,
}

impl ErrorKind {
    pub const ALL: [ErrorKind; 5] = [
        ErrorKind::InvalidRequest,
        ErrorKind::Overloaded,
        ErrorKind::DeadlineExceeded,
        ErrorKind::ShuttingDown,
        ErrorKind::Internal,
    ];

    /// Wire name (`snake_case`), used as `error.kind`.
    pub fn name(&self) -> &'static str {
        match self {
            ErrorKind::InvalidRequest => "invalid_request",
            ErrorKind::Overloaded => "overloaded",
            ErrorKind::DeadlineExceeded => "deadline_exceeded",
            ErrorKind::ShuttingDown => "shutting_down",
            ErrorKind::Internal => "internal",
        }
    }

    pub fn parse(s: &str) -> Option<ErrorKind> {
        ErrorKind::ALL.iter().copied().find(|k| k.name() == s)
    }

    /// May the client expect a retry of the *same* request to succeed?
    /// Only the two transient conditions qualify; malformed requests,
    /// expired budgets, and internal bugs reproduce on retry.
    pub fn retryable(&self) -> bool {
        matches!(self, ErrorKind::Overloaded | ErrorKind::ShuttingDown)
    }

    /// Stable index into per-kind counter arrays (diagnostics).
    pub fn index(&self) -> usize {
        match self {
            ErrorKind::InvalidRequest => 0,
            ErrorKind::Overloaded => 1,
            ErrorKind::DeadlineExceeded => 2,
            ErrorKind::ShuttingDown => 3,
            ErrorKind::Internal => 4,
        }
    }
}

/// A structured service error, ready to serialize as the reply.
#[derive(Clone, Debug)]
pub struct ServiceError {
    pub kind: ErrorKind,
    pub message: String,
    /// Backpressure hint: how long the client should wait before retrying
    /// (only set on `overloaded`).
    pub retry_after_ms: Option<u64>,
}

impl ServiceError {
    pub fn invalid_request(msg: &str) -> ServiceError {
        ServiceError {
            kind: ErrorKind::InvalidRequest,
            message: msg.to_string(),
            retry_after_ms: None,
        }
    }

    pub fn overloaded(retry_after_ms: u64) -> ServiceError {
        ServiceError {
            kind: ErrorKind::Overloaded,
            message: "request queue full, shed before processing".to_string(),
            retry_after_ms: Some(retry_after_ms),
        }
    }

    pub fn deadline_exceeded(msg: &str) -> ServiceError {
        ServiceError {
            kind: ErrorKind::DeadlineExceeded,
            message: msg.to_string(),
            retry_after_ms: None,
        }
    }

    pub fn shutting_down() -> ServiceError {
        ServiceError {
            kind: ErrorKind::ShuttingDown,
            message: "service is draining for shutdown".to_string(),
            retry_after_ms: None,
        }
    }

    pub fn internal(msg: &str) -> ServiceError {
        ServiceError {
            kind: ErrorKind::Internal,
            message: msg.to_string(),
            retry_after_ms: None,
        }
    }

    pub fn to_json(&self) -> Json {
        let mut inner = vec![
            ("kind", Json::Str(self.kind.name().into())),
            ("message", Json::Str(self.message.clone())),
            ("retryable", Json::Bool(self.kind.retryable())),
        ];
        if let Some(ms) = self.retry_after_ms {
            inner.push(("retry_after_ms", Json::Num(ms as f64)));
        }
        Json::obj(vec![
            ("ok", Json::Bool(false)),
            ("error", Json::obj(inner)),
        ])
    }
}

/// Read the error kind of a reply (`None` on success replies or replies
/// without a recognizable error object).
pub fn error_kind(resp: &Json) -> Option<ErrorKind> {
    resp.get("error")?
        .get("kind")?
        .as_str()
        .and_then(ErrorKind::parse)
}

/// Read the human-readable error message of a reply.
pub fn error_message(resp: &Json) -> Option<&str> {
    resp.get("error")?.get("message")?.as_str()
}

/// Read the `retry_after_ms` backpressure hint of a reply.
pub fn error_retry_after_ms(resp: &Json) -> Option<u64> {
    resp.get("error")?
        .get("retry_after_ms")?
        .as_f64()
        .map(|x| x as u64)
}

/// Shorthand used throughout the request parsers: every validation failure
/// is an `invalid_request`.
pub(crate) fn err(msg: &str) -> Json {
    ServiceError::invalid_request(msg).to_json()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_names_round_trip() {
        for kind in ErrorKind::ALL {
            assert_eq!(ErrorKind::parse(kind.name()), Some(kind));
        }
        assert_eq!(ErrorKind::parse("nope"), None);
    }

    #[test]
    fn only_transient_kinds_are_retryable() {
        let retryable: Vec<ErrorKind> = ErrorKind::ALL
            .into_iter()
            .filter(ErrorKind::retryable)
            .collect();
        assert_eq!(retryable, vec![ErrorKind::Overloaded, ErrorKind::ShuttingDown]);
    }

    #[test]
    fn indices_are_distinct_and_dense() {
        let mut idx: Vec<usize> = ErrorKind::ALL.iter().map(ErrorKind::index).collect();
        idx.sort_unstable();
        assert_eq!(idx, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn error_json_shape_and_readers() {
        let resp = ServiceError::overloaded(75).to_json();
        assert_eq!(resp.get("ok"), Some(&Json::Bool(false)));
        assert_eq!(error_kind(&resp), Some(ErrorKind::Overloaded));
        assert_eq!(error_retry_after_ms(&resp), Some(75));
        assert_eq!(
            resp.get("error").and_then(|e| e.get("retryable")),
            Some(&Json::Bool(true))
        );

        let resp = err("bad field");
        assert_eq!(error_kind(&resp), Some(ErrorKind::InvalidRequest));
        assert_eq!(error_message(&resp), Some("bad field"));
        assert_eq!(error_retry_after_ms(&resp), None);
        assert_eq!(
            resp.get("error").and_then(|e| e.get("retryable")),
            Some(&Json::Bool(false))
        );
    }

    #[test]
    fn readers_tolerate_success_and_legacy_replies() {
        let ok = Json::obj(vec![("ok", Json::Bool(true))]);
        assert_eq!(error_kind(&ok), None);
        assert_eq!(error_message(&ok), None);
        // A flat string error (pre-taxonomy shape) is not misread.
        let legacy = Json::obj(vec![
            ("ok", Json::Bool(false)),
            ("error", Json::Str("oops".into())),
        ]);
        assert_eq!(error_kind(&legacy), None);
        assert_eq!(error_message(&legacy), None);
    }
}
