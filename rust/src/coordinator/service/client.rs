//! Blocking client for the mapping service, plus a retry helper that
//! understands the error taxonomy: only `retryable` errors are retried,
//! with exponential backoff, seeded jitter, and the server's
//! `retry_after_ms` backpressure hint as the floor of each delay.

use super::errors::{error_kind, error_message, error_retry_after_ms};
use crate::sfc::PartOrdering;
use crate::testutil::json::Json;
use crate::testutil::rng::Rng;
use std::io::{self, BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpStream};
use std::time::Duration;

/// A blocking newline-delimited-JSON client connection.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    pub fn connect(addr: SocketAddr) -> io::Result<Client> {
        let stream = TcpStream::connect(addr)?;
        let reader = BufReader::new(stream.try_clone()?);
        Ok(Client {
            reader,
            writer: stream,
        })
    }

    /// Send one request object and read one reply object.
    pub fn request(&mut self, req: &Json) -> io::Result<Json> {
        let mut line = req.to_string();
        line.push('\n');
        self.writer.write_all(line.as_bytes())?;
        self.writer.flush()?;
        let mut resp = String::new();
        let n = self.reader.read_line(&mut resp)?;
        if n == 0 {
            return Err(io::Error::new(
                io::ErrorKind::UnexpectedEof,
                "server closed the connection",
            ));
        }
        Json::parse(resp.trim())
            .map_err(|e| io::Error::new(io::ErrorKind::InvalidData, format!("bad reply: {e}")))
    }

    /// Convenience wrapper for a flat map request.
    pub fn map(
        &mut self,
        tcoords: &[Vec<f64>],
        pcoords: &[Vec<f64>],
        ordering: PartOrdering,
    ) -> io::Result<Vec<u32>> {
        let coord_json = |rows: &[Vec<f64>]| {
            Json::Arr(
                rows.iter()
                    .map(|r| Json::Arr(r.iter().map(|&x| Json::Num(x)).collect()))
                    .collect(),
            )
        };
        let req = Json::obj(vec![
            ("op", Json::Str("map".into())),
            ("tcoords", coord_json(tcoords)),
            ("pcoords", coord_json(pcoords)),
            ("ordering", Json::Str(ordering.name().into())),
        ]);
        let resp = self.request(&req)?;
        if resp.get("ok") != Some(&Json::Bool(true)) {
            let msg = error_message(&resp).unwrap_or("unknown error");
            return Err(io::Error::other(msg.to_string()));
        }
        let arr = resp
            .get("map")
            .and_then(|m| m.as_arr())
            .ok_or_else(|| io::Error::new(io::ErrorKind::InvalidData, "reply missing map"))?;
        arr.iter()
            .map(|v| {
                v.as_usize()
                    .map(|r| r as u32)
                    .ok_or_else(|| io::Error::new(io::ErrorKind::InvalidData, "bad rank in map"))
            })
            .collect()
    }
}

/// Backoff policy for [`request_with_retry`]. Deterministic for a given
/// seed: the jitter comes from the in-tree seeded generator, so tests (and
/// the chaos suite) reproduce delays bit-for-bit.
#[derive(Clone, Debug)]
pub struct RetryPolicy {
    /// Total attempts, including the first (minimum 1).
    pub max_attempts: u32,
    /// Backoff base: attempt `k` waits about `base * 2^k`.
    pub base_delay_ms: u64,
    /// Cap on any single delay.
    pub max_delay_ms: u64,
    /// Jitter seed.
    pub seed: u64,
}

impl Default for RetryPolicy {
    fn default() -> RetryPolicy {
        RetryPolicy {
            max_attempts: 5,
            base_delay_ms: 10,
            max_delay_ms: 1000,
            seed: 0x5EED,
        }
    }
}

impl RetryPolicy {
    /// Delay before the retry following attempt `attempt` (0-based):
    /// exponential base capped at `max_delay_ms`, floored by the server's
    /// `retry_after_ms` hint, plus up to +50% deterministic jitter.
    fn delay_ms(&self, attempt: u32, retry_after: Option<u64>, rng: &mut Rng) -> u64 {
        let exp = self
            .base_delay_ms
            .saturating_mul(1u64 << attempt.min(16) as u64);
        let base = exp.max(retry_after.unwrap_or(0)).min(self.max_delay_ms);
        base + rng.below((base / 2 + 1) as usize) as u64
    }
}

/// Issue `req`, reconnecting and retrying on transient failures.
///
/// Retries happen when the connection fails outright (the pool force-closed
/// it, the listener is mid-restart) or the reply is a structured error
/// marked `retryable` (`overloaded`, `shutting_down`). Non-retryable errors
/// (`invalid_request`, `deadline_exceeded`, `internal`) and success replies
/// return immediately — resending malformed bytes cannot help.
pub fn request_with_retry(
    addr: SocketAddr,
    req: &Json,
    policy: &RetryPolicy,
) -> io::Result<Json> {
    let mut rng = Rng::new(policy.seed);
    let attempts = policy.max_attempts.max(1);
    let mut last_err: Option<io::Error> = None;
    for attempt in 0..attempts {
        let retry_after = match Client::connect(addr).and_then(|mut c| c.request(req)) {
            Ok(resp) => {
                let transient = error_kind(&resp).is_some_and(|k| k.retryable());
                if !transient || attempt + 1 == attempts {
                    return Ok(resp);
                }
                error_retry_after_ms(&resp)
            }
            Err(e) => {
                if attempt + 1 == attempts {
                    return Err(e);
                }
                last_err = Some(e);
                None
            }
        };
        std::thread::sleep(Duration::from_millis(policy.delay_ms(
            attempt,
            retry_after,
            &mut rng,
        )));
    }
    // Unreachable: the last attempt always returns above. Keep a real
    // error anyway in case `max_attempts` is somehow 0.
    Err(last_err.unwrap_or_else(|| io::Error::other("no attempts made")))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn delays_grow_are_floored_and_capped() {
        let policy = RetryPolicy {
            max_attempts: 5,
            base_delay_ms: 10,
            max_delay_ms: 100,
            seed: 1,
        };
        let mut rng = Rng::new(policy.seed);
        // Attempt 0: base 10, jitter < 6.
        let d0 = policy.delay_ms(0, None, &mut rng);
        assert!((10..16).contains(&d0), "{d0}");
        // The server hint floors the delay.
        let d1 = policy.delay_ms(0, Some(40), &mut rng);
        assert!((40..61).contains(&d1), "{d1}");
        // Large attempts cap at max_delay_ms (+50% jitter).
        let d2 = policy.delay_ms(10, None, &mut rng);
        assert!((100..151).contains(&d2), "{d2}");
        // Deterministic for a given seed.
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        assert_eq!(policy.delay_ms(2, None, &mut a), policy.delay_ms(2, None, &mut b));
    }

    #[test]
    fn huge_attempt_exponent_does_not_overflow() {
        let policy = RetryPolicy {
            max_attempts: 100,
            base_delay_ms: u64::MAX / 2,
            max_delay_ms: 50,
            seed: 1,
        };
        let mut rng = Rng::new(1);
        let d = policy.delay_ms(99, None, &mut rng);
        assert!(d <= 75, "{d}");
    }

    #[test]
    fn connect_failure_is_reported_after_retries() {
        // A port nobody listens on: every attempt fails fast with
        // connection refused; the helper must give up and return the error.
        let addr: SocketAddr = "127.0.0.1:1".parse().unwrap();
        let policy = RetryPolicy {
            max_attempts: 2,
            base_delay_ms: 1,
            max_delay_ms: 2,
            seed: 3,
        };
        let req = Json::obj(vec![("op", Json::Str("ping".into()))]);
        assert!(request_with_retry(addr, &req, &policy).is_err());
    }
}
