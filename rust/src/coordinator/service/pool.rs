//! The bounded worker pool behind the accept loop.
//!
//! A fixed set of worker threads (sized off the [`crate::par`] budget)
//! pulls accepted connections from a bounded queue. The accept loop never
//! spawns; when the queue is full it sheds the connection with a
//! structured `overloaded` reply, so a connect flood can never grow the
//! thread count — the hard cap on concurrent connections is
//! `workers + queue_capacity`.
//!
//! Each worker owns its connection until the client disconnects, times
//! out, or the service drains: socket read/write timeouts plus an overall
//! per-frame deadline (trickle traffic cannot stretch one request forever)
//! and a payload cap bound every request, and the request handler runs
//! under `catch_unwind`, so neither a stalled client nor a library panic
//! can take a worker out of the pool.

use super::batch::Batcher;
use super::cache::MapCache;
use super::diagnostics::{Diagnostics, PoolSnapshot};
use super::errors::{err, ServiceError};
use super::handlers::{self, RequestCtx};
use super::ServiceConfig;
use crate::par::Deadline;
use crate::testutil::faults;
use crate::testutil::json::Json;
use std::collections::{HashMap, VecDeque};
use std::io::{BufRead, BufReader, Write};
use std::net::{Shutdown, TcpStream};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, AtomicU8, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

const STATE_RUNNING: u8 = 0;
const STATE_DRAINING: u8 = 1;
const STATE_STOPPED: u8 = 2;

/// Lock tolerating poison: the pool must keep functioning after any panic.
fn lock_ok<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    match m.lock() {
        Ok(g) => g,
        Err(poisoned) => poisoned.into_inner(),
    }
}

/// State shared between the accept loop and the workers.
pub(super) struct PoolShared {
    cfg: ServiceConfig,
    workers: usize,
    queue: Mutex<VecDeque<TcpStream>>,
    job_ready: Condvar,
    state: AtomicU8,
    /// Connections currently owned by a worker.
    active: AtomicUsize,
    /// Socket clones of every live worker-owned connection, for the
    /// force-close step of [`WorkerPool::drain`].
    conns: Mutex<HashMap<u64, TcpStream>>,
    next_conn_id: AtomicU64,
    diag: Arc<Diagnostics>,
    /// Shared result cache for `map` requests (None = disabled).
    cache: Option<Arc<MapCache>>,
    /// Shared batching stage for compatible small `map` requests
    /// (None = disabled).
    batcher: Option<Arc<Batcher>>,
}

impl PoolShared {
    /// Hand an accepted connection to the pool. `Err` returns the stream
    /// when the queue is full — the caller sheds it with an `overloaded`
    /// reply.
    pub(super) fn try_dispatch(&self, stream: TcpStream) -> Result<(), TcpStream> {
        {
            let mut q = lock_ok(&self.queue);
            if q.len() >= self.cfg.queue_capacity {
                return Err(stream);
            }
            q.push_back(stream);
        }
        self.job_ready.notify_one();
        Ok(())
    }

    pub(super) fn snapshot(&self) -> PoolSnapshot {
        PoolSnapshot {
            workers: self.workers,
            queue_capacity: self.cfg.queue_capacity,
            queue_depth: lock_ok(&self.queue).len(),
            active_connections: self.active.load(Ordering::SeqCst),
        }
    }
}

/// Write one newline-delimited JSON reply.
pub(super) fn write_reply<W: Write>(w: &mut W, resp: &Json) -> std::io::Result<()> {
    let mut line = resp.to_string();
    line.push('\n');
    w.write_all(line.as_bytes())?;
    w.flush()
}

/// The fixed worker pool. Created by [`super::Service::start_with`]; torn
/// down by [`WorkerPool::drain`].
pub(super) struct WorkerPool {
    shared: Arc<PoolShared>,
    handles: Vec<std::thread::JoinHandle<()>>,
}

impl WorkerPool {
    pub(super) fn start(
        cfg: ServiceConfig,
        diag: Arc<Diagnostics>,
        cache: Option<Arc<MapCache>>,
        batcher: Option<Arc<Batcher>>,
    ) -> WorkerPool {
        let workers = cfg.resolved_workers();
        let shared = Arc::new(PoolShared {
            cfg,
            workers,
            queue: Mutex::new(VecDeque::new()),
            job_ready: Condvar::new(),
            state: AtomicU8::new(STATE_RUNNING),
            active: AtomicUsize::new(0),
            conns: Mutex::new(HashMap::new()),
            next_conn_id: AtomicU64::new(0),
            diag,
            cache,
            batcher,
        });
        let handles = (0..workers)
            .map(|_| {
                let shared = Arc::clone(&shared);
                std::thread::spawn(move || worker_loop(shared))
            })
            .collect();
        WorkerPool { shared, handles }
    }

    pub(super) fn shared(&self) -> Arc<PoolShared> {
        Arc::clone(&self.shared)
    }

    /// Graceful shutdown: stop handing out jobs, refuse what is still
    /// queued with `shutting_down`, give in-flight connections up to
    /// `drain_timeout` to finish, then force-close the stragglers' sockets
    /// and join every worker.
    ///
    /// The client-observable invariant is that every accepted socket is
    /// answered or closed by `drain_timeout` after drain begins. The final
    /// `join` can run slightly longer when a handler is mid-compute (its
    /// socket is already force-closed; the compute finishes and the reply
    /// write fails) — cooperative request budgets keep that tail bounded.
    pub(super) fn drain(mut self) {
        faults::failpoint("service.shutdown");
        self.shared.state.store(STATE_DRAINING, Ordering::SeqCst);
        self.shared.job_ready.notify_all();
        // Queued-but-unserved connections get a structured refusal.
        let queued: Vec<TcpStream> = lock_ok(&self.shared.queue).drain(..).collect();
        let refusal = ServiceError::shutting_down().to_json();
        for mut stream in queued {
            let _ = stream.set_write_timeout(Some(self.shared.cfg.write_timeout));
            let _ = write_reply(&mut stream, &refusal);
            self.shared
                .diag
                .record_reply("(queued)", &refusal, Duration::ZERO);
        }
        // Grace period for in-flight connections.
        let deadline = Instant::now() + self.shared.cfg.drain_timeout;
        while self.shared.active.load(Ordering::SeqCst) > 0 && Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(2));
        }
        if self.shared.active.load(Ordering::SeqCst) > 0 {
            let conns = lock_ok(&self.shared.conns);
            for stream in conns.values() {
                let _ = stream.shutdown(Shutdown::Both);
            }
            self.shared.diag.record_event(&format!(
                "drain deadline expired; force-closed {} connection(s)",
                conns.len()
            ));
        }
        self.shared.state.store(STATE_STOPPED, Ordering::SeqCst);
        self.shared.job_ready.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(shared: Arc<PoolShared>) {
    loop {
        let job = {
            let mut q = lock_ok(&shared.queue);
            loop {
                if let Some(stream) = q.pop_front() {
                    break Some(stream);
                }
                if shared.state.load(Ordering::SeqCst) != STATE_RUNNING {
                    break None;
                }
                q = match shared.job_ready.wait(q) {
                    Ok(g) => g,
                    Err(poisoned) => poisoned.into_inner(),
                };
            }
        };
        let Some(stream) = job else { return };
        shared.active.fetch_add(1, Ordering::SeqCst);
        // Belt and braces: handlers already run under catch_unwind, but no
        // panic anywhere in connection handling may kill the worker.
        let result = catch_unwind(AssertUnwindSafe(|| serve_conn(&shared, stream)));
        shared.active.fetch_sub(1, Ordering::SeqCst);
        if result.is_err() {
            shared
                .diag
                .record_event("worker survived a connection-level panic");
        }
    }
}

/// Serve one connection until it disconnects, misbehaves, or the service
/// drains. Keep-alive: many requests per connection, one reply per line.
fn serve_conn(shared: &PoolShared, stream: TcpStream) {
    let _ = stream.set_read_timeout(Some(shared.cfg.read_timeout));
    let _ = stream.set_write_timeout(Some(shared.cfg.write_timeout));
    let conn_id = shared.next_conn_id.fetch_add(1, Ordering::Relaxed);
    if let Ok(clone) = stream.try_clone() {
        lock_ok(&shared.conns).insert(conn_id, clone);
    }
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => {
            lock_ok(&shared.conns).remove(&conn_id);
            return;
        }
    };
    let mut reader = BufReader::new(stream);
    loop {
        if shared.state.load(Ordering::SeqCst) != STATE_RUNNING {
            let _ = write_reply(&mut writer, &ServiceError::shutting_down().to_json());
            break;
        }
        let frame = read_frame(
            &mut reader,
            shared.cfg.max_payload,
            Deadline::within(shared.cfg.frame_timeout),
        );
        match frame {
            Frame::Line(line) => {
                let ctx = RequestCtx {
                    deadline: Deadline::within(shared.cfg.request_budget),
                    diag: Arc::clone(&shared.diag),
                    pool: Some(shared.snapshot()),
                    cache: shared.cache.clone(),
                    batcher: shared.batcher.clone(),
                };
                let resp = handlers::handle_request_with(&line, &ctx);
                if write_reply(&mut writer, &resp).is_err() {
                    break;
                }
            }
            Frame::Eof | Frame::Closed { .. } => break,
            Frame::TooLong => {
                let resp = err(&format!(
                    "request exceeds the {} byte payload limit",
                    shared.cfg.max_payload
                ));
                let _ = write_reply(&mut writer, &resp);
                // The remainder of the oversized frame is unread; the only
                // safe continuation is to close.
                break;
            }
            Frame::TimedOut { partial } => {
                if partial {
                    // Mid-frame stall: tell the client its request was
                    // truncated, then release the worker.
                    let _ = write_reply(
                        &mut writer,
                        &err("timed out mid-frame (truncated request)"),
                    );
                }
                break;
            }
        }
    }
    lock_ok(&shared.conns).remove(&conn_id);
}

/// Outcome of reading one newline-delimited frame.
#[derive(Debug)]
enum Frame {
    /// A complete non-empty line (trimmed, newline stripped).
    Line(String),
    /// Clean close at a frame boundary.
    Eof,
    /// Connection dropped; `partial` = bytes of an unfinished frame were
    /// already received (mid-request disconnect).
    Closed { partial: bool },
    /// The frame exceeded the payload cap.
    TooLong,
    /// No complete frame within the socket read timeout / overall frame
    /// deadline; `partial` distinguishes a stalled frame from a clean idle.
    TimedOut { partial: bool },
}

/// Read one frame through `BufReader::fill_buf`, enforcing the payload cap
/// incrementally (an oversized frame is rejected as soon as the cap is
/// crossed, without buffering it) and an overall deadline per frame (a
/// client trickling one byte per read-timeout window still cannot hold the
/// worker past `overall`). Blank lines are skipped, matching the legacy
/// line protocol.
fn read_frame(reader: &mut BufReader<TcpStream>, max_payload: usize, overall: Deadline) -> Frame {
    let mut buf: Vec<u8> = Vec::new();
    loop {
        if overall.expired() {
            return Frame::TimedOut {
                partial: !buf.is_empty(),
            };
        }
        let available = match reader.fill_buf() {
            Ok(b) => b,
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) =>
            {
                return Frame::TimedOut {
                    partial: !buf.is_empty(),
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(_) => {
                return Frame::Closed {
                    partial: !buf.is_empty(),
                }
            }
        };
        if available.is_empty() {
            return if buf.is_empty() {
                Frame::Eof
            } else {
                Frame::Closed { partial: true }
            };
        }
        match available.iter().position(|&b| b == b'\n') {
            Some(pos) => {
                if buf.len() + pos > max_payload {
                    reader.consume(pos + 1);
                    return Frame::TooLong;
                }
                buf.extend_from_slice(&available[..pos]);
                reader.consume(pos + 1);
                // Garbage bytes are fine here: the JSON parser turns them
                // into a structured invalid_request reply downstream.
                let line = String::from_utf8_lossy(&buf).trim().to_string();
                if line.is_empty() {
                    buf.clear();
                    continue;
                }
                return Frame::Line(line);
            }
            None => {
                let n = available.len();
                if buf.len() + n > max_payload {
                    reader.consume(n);
                    return Frame::TooLong;
                }
                buf.extend_from_slice(available);
                reader.consume(n);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::net::TcpListener;

    /// A connected (client, server) TCP pair on localhost.
    fn socket_pair() -> (TcpStream, TcpStream) {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let client = TcpStream::connect(addr).unwrap();
        let (server, _) = listener.accept().unwrap();
        (client, server)
    }

    #[test]
    fn read_frame_returns_complete_lines() {
        let (mut client, server) = socket_pair();
        server
            .set_read_timeout(Some(Duration::from_millis(500)))
            .unwrap();
        client.write_all(b"hello world\n{\"x\":1}\n").unwrap();
        let mut reader = BufReader::new(server);
        let overall = Deadline::within(Duration::from_secs(5));
        match read_frame(&mut reader, 1024, overall) {
            Frame::Line(l) => assert_eq!(l, "hello world"),
            other => panic!("unexpected frame {other:?}"),
        }
        match read_frame(&mut reader, 1024, overall) {
            Frame::Line(l) => assert_eq!(l, "{\"x\":1}"),
            other => panic!("unexpected frame {other:?}"),
        }
    }

    #[test]
    fn read_frame_skips_blank_lines_and_reports_eof() {
        let (mut client, server) = socket_pair();
        server
            .set_read_timeout(Some(Duration::from_millis(500)))
            .unwrap();
        client.write_all(b"\n  \nping\n").unwrap();
        drop(client); // half: EOF after the last line
        let mut reader = BufReader::new(server);
        let overall = Deadline::within(Duration::from_secs(5));
        match read_frame(&mut reader, 1024, overall) {
            Frame::Line(l) => assert_eq!(l, "ping"),
            other => panic!("unexpected frame {other:?}"),
        }
        assert!(matches!(read_frame(&mut reader, 1024, overall), Frame::Eof));
    }

    #[test]
    fn read_frame_caps_payload() {
        let (mut client, server) = socket_pair();
        server
            .set_read_timeout(Some(Duration::from_millis(500)))
            .unwrap();
        client.write_all(&[b'x'; 64]).unwrap();
        client.write_all(b"\n").unwrap();
        let mut reader = BufReader::new(server);
        let overall = Deadline::within(Duration::from_secs(5));
        assert!(matches!(
            read_frame(&mut reader, 16, overall),
            Frame::TooLong
        ));
    }

    #[test]
    fn read_frame_times_out_on_partial_frame() {
        let (mut client, server) = socket_pair();
        server
            .set_read_timeout(Some(Duration::from_millis(30)))
            .unwrap();
        client.write_all(b"{\"op\":").unwrap(); // never finishes the line
        let mut reader = BufReader::new(server);
        match read_frame(&mut reader, 1024, Deadline::within(Duration::from_secs(5))) {
            Frame::TimedOut { partial } => assert!(partial),
            other => panic!("unexpected frame {other:?}"),
        }
    }

    #[test]
    fn read_frame_detects_mid_frame_disconnect() {
        let (mut client, server) = socket_pair();
        server
            .set_read_timeout(Some(Duration::from_millis(500)))
            .unwrap();
        client.write_all(b"{\"op\":\"ma").unwrap();
        drop(client);
        let mut reader = BufReader::new(server);
        match read_frame(&mut reader, 1024, Deadline::within(Duration::from_secs(5))) {
            Frame::Closed { partial } => assert!(partial),
            other => panic!("unexpected frame {other:?}"),
        }
    }
}
