//! Request parsing and dispatch: one JSON line in, one JSON reply out.
//!
//! Every request runs with a [`RequestCtx`]: a cooperative compute
//! [`Deadline`] (checked at the mapping pipeline's phase boundaries, so a
//! pathological `map` returns `deadline_exceeded` instead of pinning a
//! worker), the service [`Diagnostics`], and an optional pool snapshot for
//! `{"op":"stats"}`. The dispatch itself runs under `catch_unwind`: a
//! library panic becomes a structured `internal` error with the panic
//! message logged to the diagnostics ring buffer — the worker survives.
//!
//! **Validation is strict**: unknown or malformed fields — top-level or
//! inside `"hier"`/`"numa"`/`"bgq"` — return a structured
//! `invalid_request` instead of being silently ignored, so a typo like
//! `"objectiv"` can never quietly change what a production mapping run
//! optimizes. Coordinates and edge weights must be finite, torus volumes
//! are capped, and `ranks_per_node` must divide the rank count exactly.

use super::diagnostics::{Diagnostics, PoolSnapshot};
use super::errors::{err, ServiceError};
use crate::apps::{Edge, TaskGraph};
use crate::coarsen::{CoarsenConfig, MatchingKind};
use crate::geom::Coords;
use crate::hier::{map_hierarchical_budgeted, HierConfig, IntraNodeStrategy};
use crate::machine::{Allocation, Dragonfly, FatTree, Network, NumaTopology, Topology, Torus};
use crate::mapping::rotations::NativeBackend;
use crate::mapping::{map_tasks, MapConfig};
use crate::metrics::eval_full;
use crate::objective::{combined_value, eval_numa, EvalSpec, ObjectiveKind};
use crate::par::Deadline;
use crate::sfc::PartOrdering;
use crate::testutil::faults;
use crate::testutil::json::Json;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::Arc;
use std::time::Instant;

/// Per-request context threaded through every handler.
pub struct RequestCtx {
    /// Compute budget for this request (checked at phase boundaries).
    pub deadline: Deadline,
    /// Shared service telemetry.
    pub diag: Arc<Diagnostics>,
    /// Pool view sampled when the request started (for `stats`).
    pub pool: Option<PoolSnapshot>,
    /// Content-addressed result cache for `map` replies (`None` when
    /// disabled via [`super::ServiceConfig::cache_capacity`] = 0).
    pub cache: Option<Arc<super::cache::MapCache>>,
    /// Window batcher for compatible small hierarchical `map` requests
    /// (`None` unless [`super::ServiceConfig::batch_window`] is set).
    pub batcher: Option<Arc<super::batch::Batcher>>,
}

impl Default for RequestCtx {
    /// Direct (non-service) callers: unlimited budget, private telemetry,
    /// no cache or batching.
    fn default() -> RequestCtx {
        RequestCtx {
            deadline: Deadline::unlimited(),
            diag: Arc::new(Diagnostics::new()),
            pool: None,
            cache: None,
            batcher: None,
        }
    }
}

/// Fields each op accepts. Anything else is a structured error — silently
/// ignoring unknown fields would let typos change production mapping runs.
const MAP_FIELDS: &[&str] = &[
    "op", "tcoords", "pcoords", "ordering", "longest_dim", "uneven_prime", "edges", "torus",
    "hier", "objective", "numa", "bgq", "coarsen", "profile", "topology", "cache",
];
const EVAL_FIELDS: &[&str] = &[
    "op", "map", "edges", "pcoords", "torus", "ranks_per_node", "objective", "numa", "bgq",
    "profile", "topology",
];
const STATS_FIELDS: &[&str] = &["op"];
const TRACE_FIELDS: &[&str] = &["op"];
const HIER_FIELDS: &[&str] = &["ranks_per_node", "strategy", "passes", "rotations"];
const NUMA_FIELDS: &[&str] = &[
    "sockets_per_node",
    "ranks_per_socket",
    "socket_cost",
    "core_cost",
    "hop_cost",
];
const BGQ_FIELDS: &[&str] = &["block", "ranks_per_node", "order"];
const COARSEN_FIELDS: &[&str] = &["target_tasks", "max_levels", "matching"];
const FATTREE_FIELDS: &[&str] = &["levels", "radix"];
const DRAGONFLY_FIELDS: &[&str] = &[
    "groups",
    "routers_per_group",
    "terminals_per_router",
    "global_cost",
    "valiant",
];

/// Keep service-built BG/Q blocks to a sane size: the block is expanded
/// into per-rank tables, so an enormous request would balloon memory
/// before any real work starts.
const MAX_BGQ_RANKS: usize = 1 << 20;

/// Same policy for client-declared torus shapes: routed objectives build
/// per-link tables proportional to the router volume, so an absurd
/// `"torus"` (or a derived shape from absurd `pcoords`) must be rejected
/// before it can balloon memory.
const MAX_TORUS_ROUTERS: usize = 1 << 20;

/// Handle one request line with an unlimited budget and private telemetry
/// (exposed for direct unit testing and embedding).
pub fn handle_request(line: &str) -> Json {
    handle_request_with(line, &RequestCtx::default())
}

/// Handle one request line under a request context. This is the single
/// entry point of the worker pool: it never panics (dispatch runs under
/// `catch_unwind`) and always returns exactly one reply.
pub fn handle_request_with(line: &str, ctx: &RequestCtx) -> Json {
    let start = Instant::now();
    ctx.diag.begin_request();
    let (op, resp) = match Json::parse(line) {
        Err(e) => ("(parse)".to_string(), err(&format!("bad json: {e}"))),
        Ok(req) => {
            let op = req
                .get("op")
                .and_then(|o| o.as_str())
                .unwrap_or("(missing)")
                .to_string();
            let resp = match catch_unwind(AssertUnwindSafe(|| dispatch(&op, &req, ctx))) {
                Ok(resp) => resp,
                Err(payload) => {
                    let msg = panic_message(payload.as_ref());
                    ctx.diag.record_panic(&op, &msg);
                    ServiceError::internal(&format!("panic in op \"{op}\": {msg}")).to_json()
                }
            };
            (op, resp)
        }
    };
    let elapsed = start.elapsed();
    ctx.diag.record_reply(&op, &resp, elapsed);
    if crate::obs::recording() {
        let metrics = crate::obs::metrics();
        metrics.add("service.requests", 1);
        metrics.observe_us(
            "service.request_us",
            elapsed.as_micros().min(u128::from(u64::MAX)) as u64,
        );
    }
    ctx.diag.end_request();
    resp
}

fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

fn dispatch(op: &str, req: &Json, ctx: &RequestCtx) -> Json {
    // Failpoints for the chaos suite: an injected sleep models a slow
    // handler, an injected panic proves the catch_unwind isolation.
    faults::failpoint("service.handler");
    faults::failpoint("service.handler.panic");
    match op {
        "ping" => Json::obj(vec![("ok", Json::Bool(true)), ("pong", Json::Bool(true))]),
        "stats" => check_fields(req, STATS_FIELDS, "stats").unwrap_or_else(|| {
            let mut resp = ctx.diag.snapshot_json(ctx.pool);
            super::attach_cache_stats(&mut resp, ctx.cache.as_deref(), ctx.batcher.as_deref());
            resp
        }),
        "map" => {
            check_fields(req, MAP_FIELDS, "map").unwrap_or_else(|| handle_map_cached(req, ctx))
        }
        "eval" => check_fields(req, EVAL_FIELDS, "eval")
            .unwrap_or_else(|| with_profile(req, "service.eval", || handle_eval(req, ctx))),
        "trace" => check_fields(req, TRACE_FIELDS, "trace").unwrap_or_else(handle_trace),
        "(missing)" => err("missing op"),
        other => err(&format!("unknown op {other}")),
    }
}

/// `{"op":"trace"}`: the recent span tree from the global event ring (what
/// the `TASKMAP_TRACE` recorder has seen lately), plus the metrics
/// registry snapshot. Always answers — with an empty forest when the
/// recorder is off.
fn handle_trace() -> Json {
    Json::obj(vec![
        ("ok", Json::Bool(true)),
        ("enabled", Json::Bool(crate::obs::enabled())),
        (
            "traces",
            crate::obs::trace::span_tree_json(&crate::obs::recent_events()),
        ),
        (
            "events_dropped",
            Json::Num(crate::obs::events_dropped() as f64),
        ),
        ("metrics", crate::obs::metrics().snapshot_json()),
    ])
}

/// Honor an optional `"profile": true` on `map`/`eval`: run the handler
/// under a fresh trace id inside an [`crate::obs::capture`] with a root
/// span, and attach a `"profile"` object — the per-phase breakdown (the
/// End events one level under the root: sweep, hier levels, refinement,
/// response evaluation, each with its recorded fields) plus the measured
/// total — and the `trace_id` to a successful reply. Phases nest inside
/// the measured interval, so their elapsed times sum to at most
/// `total_us`. Without the flag the handler runs exactly as before (the
/// recorder stays cold unless globally enabled).
fn with_profile(req: &Json, root: &'static str, f: impl FnOnce() -> Json) -> Json {
    let profile = match parse_bool(req, "profile", false) {
        Ok(b) => b,
        Err(e) => return e,
    };
    if !profile {
        return f();
    }
    let trace_id = crate::obs::next_trace_id();
    let start = Instant::now();
    let (mut resp, events) = crate::obs::capture(|| {
        crate::obs::with_trace(trace_id, || {
            let _root = crate::obs::span(root);
            f()
        })
    });
    let total_us = start.elapsed().as_micros().min(u128::from(u64::MAX)) as u64;
    if resp.get("ok") == Some(&Json::Bool(true)) {
        if let Json::Obj(m) = &mut resp {
            m.insert("trace_id".to_string(), Json::Num(trace_id as f64));
            m.insert("profile".to_string(), profile_json(&events, total_us));
        }
    }
    resp
}

/// The `"profile"` object: one entry per phase span (End events at depth 1
/// — direct children of the handler's root span), in completion order,
/// carrying the span's recorded fields.
fn profile_json(events: &[crate::obs::Event], total_us: u64) -> Json {
    let phases: Vec<Json> = events
        .iter()
        .filter(|e| e.kind == crate::obs::EventKind::End && e.depth == 1)
        .map(|e| {
            let mut fields = vec![
                ("name", Json::Str(e.name.to_string())),
                ("elapsed_us", Json::Num(e.dur_us as f64)),
            ];
            for &(k, v) in &e.fields {
                fields.push((k, Json::Num(v)));
            }
            Json::obj(fields)
        })
        .collect();
    Json::obj(vec![
        ("total_us", Json::Num(total_us as f64)),
        ("phases", Json::Arr(phases)),
    ])
}

/// Reject fields outside `allowed` (`what` names the object in the error).
fn check_fields(obj: &Json, allowed: &[&str], what: &str) -> Option<Json> {
    if let Json::Obj(m) = obj {
        for k in m.keys() {
            if !allowed.contains(&k.as_str()) {
                return Some(err(&format!("unknown {what} field \"{k}\"")));
            }
        }
    }
    None
}

/// Parse an optional `"numa"` field (preset name or explicit object) with
/// strict validation. The socket grid must tile `ranks_per_node` exactly —
/// a grid that silently over- or under-covers the node would change which
/// messages are priced as cross-socket.
fn parse_numa(req: &Json, ranks_per_node: usize) -> Result<Option<NumaTopology>, Json> {
    let v = match req.get("numa") {
        None => return Ok(None),
        Some(v) => v,
    };
    let topo = match v {
        Json::Str(name) => match NumaTopology::preset(name) {
            Some(t) => t,
            None => return Err(err("unknown numa preset (want xk7|bgq)")),
        },
        Json::Obj(_) => {
            if let Some(e) = check_fields(v, NUMA_FIELDS, "numa") {
                return Err(e);
            }
            let spn = match v.get("sockets_per_node").map(as_index) {
                Some(Some(s)) if s >= 1 => s,
                _ => return Err(err("numa.sockets_per_node must be a positive integer")),
            };
            let rps = match v.get("ranks_per_socket").map(as_index) {
                Some(Some(r)) if r >= 1 => r,
                _ => return Err(err("numa.ranks_per_socket must be a positive integer")),
            };
            let cost = |key: &str, default: f64| -> Result<f64, Json> {
                match v.get(key) {
                    None => Ok(default),
                    Some(c) => match c.as_f64() {
                        Some(x) if x.is_finite() && x >= 0.0 => Ok(x),
                        _ => Err(err(&format!(
                            "numa.{key} must be a finite non-negative number"
                        ))),
                    },
                }
            };
            let socket_cost = cost("socket_cost", 0.5)?;
            let core_cost = cost("core_cost", 0.0)?;
            let hop_cost = cost("hop_cost", 1.0)?;
            if hop_cost <= 0.0 {
                return Err(err("numa.hop_cost must be positive"));
            }
            if core_cost > socket_cost {
                return Err(err("numa.core_cost must not exceed numa.socket_cost"));
            }
            NumaTopology::new(spn, rps, socket_cost, core_cost, hop_cost)
        }
        _ => return Err(err("numa must be an object or a preset name")),
    };
    if topo.ranks_per_node() != ranks_per_node {
        return Err(err(&format!(
            "numa socket grid covers {} ranks per node, allocation has {ranks_per_node}",
            topo.ranks_per_node()
        )));
    }
    Ok(Some(topo))
}

/// Parse an optional `"coarsen"` object with strict validation: the
/// multilevel V-cycle knobs (`target_tasks`, `max_levels`, `matching`).
/// Absent fields keep the library defaults; zero would disable coarsening
/// in a way the caller almost certainly did not intend, so the two integer
/// knobs must be >= 1.
fn parse_coarsen(req: &Json) -> Result<Option<CoarsenConfig>, Json> {
    let v = match req.get("coarsen") {
        None => return Ok(None),
        Some(v) => v,
    };
    if !matches!(v, Json::Obj(_)) {
        return Err(err("coarsen must be an object"));
    }
    if let Some(e) = check_fields(v, COARSEN_FIELDS, "coarsen") {
        return Err(e);
    }
    let mut cfg = CoarsenConfig::default();
    if let Some(t) = v.get("target_tasks") {
        match as_index(t) {
            Some(x) if x >= 1 => cfg.target_tasks = x,
            _ => return Err(err("coarsen.target_tasks must be a positive integer")),
        }
    }
    if let Some(l) = v.get("max_levels") {
        match as_index(l) {
            Some(x) if x >= 1 => cfg.max_levels = x,
            _ => return Err(err("coarsen.max_levels must be a positive integer")),
        }
    }
    if let Some(m) = v.get("matching") {
        match m.as_str().and_then(MatchingKind::parse) {
            Some(kind) => cfg.matching = kind,
            None => return Err(err("coarsen.matching must be heavy_edge|geometric")),
        }
    }
    Ok(Some(cfg))
}

/// Parse an optional `"topology"` field with strict validation. `"torus"`
/// (the default) returns `None` — router coordinates keep coming from
/// `pcoords` plus the optional `"torus"` size array exactly as before. A
/// one-key object selects a non-torus network:
/// `{"fattree":{"levels":L,"radix":K}}` or
/// `{"dragonfly":{"groups":G,"routers_per_group":R,...}}`. Router and
/// directed-link counts are capped like torus volumes — the routed
/// per-link tables scale the same way.
fn parse_topology(req: &Json) -> Result<Option<Network>, Json> {
    let v = match req.get("topology") {
        None => return Ok(None),
        Some(v) => v,
    };
    match v {
        Json::Str(name) if name == "torus" => return Ok(None),
        Json::Obj(m) if m.len() == 1 => {}
        Json::Obj(_) => {
            return Err(err(
                "topology object must have exactly one key (fattree|dragonfly)",
            ))
        }
        _ => return Err(err("topology must be \"torus\" or a fattree/dragonfly object")),
    }
    if let Some(ft) = v.get("fattree") {
        if !matches!(ft, Json::Obj(_)) {
            return Err(err("topology.fattree must be an object"));
        }
        if let Some(e) = check_fields(ft, FATTREE_FIELDS, "topology.fattree") {
            return Err(e);
        }
        let levels = match ft.get("levels").map(as_index) {
            Some(Some(l)) if l >= 1 => l,
            _ => return Err(err("fattree.levels must be an integer >= 1")),
        };
        let radix = match ft.get("radix").map(as_index) {
            Some(Some(r)) if r >= 2 => r,
            _ => return Err(err("fattree.radix must be an integer >= 2")),
        };
        // radix^levels leaves, checked: overflow must not bypass the cap.
        let leaves = (0..levels)
            .try_fold(1usize, |acc, _| acc.checked_mul(radix))
            .filter(|&n| n <= MAX_TORUS_ROUTERS);
        if leaves.is_none() {
            return Err(err(&format!(
                "fattree exceeds the service limit of {MAX_TORUS_ROUTERS} routers"
            )));
        }
        return Ok(Some(FatTree::new(levels, radix).into()));
    }
    if let Some(df) = v.get("dragonfly") {
        if !matches!(df, Json::Obj(_)) {
            return Err(err("topology.dragonfly must be an object"));
        }
        if let Some(e) = check_fields(df, DRAGONFLY_FIELDS, "topology.dragonfly") {
            return Err(e);
        }
        let groups = match df.get("groups").map(as_index) {
            Some(Some(g)) if g >= 1 => g,
            _ => return Err(err("dragonfly.groups must be an integer >= 1")),
        };
        let rpg = match df.get("routers_per_group").map(as_index) {
            Some(Some(r)) if r >= 1 => r,
            _ => return Err(err("dragonfly.routers_per_group must be an integer >= 1")),
        };
        let tpr = match df.get("terminals_per_router").map(as_index) {
            None => 1,
            Some(Some(t)) if t >= 1 => t,
            _ => return Err(err("dragonfly.terminals_per_router must be an integer >= 1")),
        };
        let global_cost = match df.get("global_cost").map(as_index) {
            None => 2,
            Some(Some(c)) if c >= 1 => c as u64,
            _ => return Err(err("dragonfly.global_cost must be an integer >= 1")),
        };
        let valiant = match parse_bool(df, "valiant", false) {
            Ok(b) => b,
            Err(_) => return Err(err("dragonfly.valiant must be a boolean")),
        };
        // Cap routers AND the dense port table (routers x (R + G) directed
        // link slots), checked: overflow must not bypass either cap.
        let ok = groups
            .checked_mul(rpg)
            .filter(|&n| n <= MAX_TORUS_ROUTERS)
            .and_then(|n| n.checked_mul(rpg + groups))
            .filter(|&slots| slots <= 8 * MAX_TORUS_ROUTERS);
        if ok.is_none() {
            return Err(err(&format!(
                "dragonfly exceeds the service limit of {MAX_TORUS_ROUTERS} routers"
            )));
        }
        return Ok(Some(
            Dragonfly::new(groups, rpg, tpr)
                .with_global_cost(global_cost)
                .with_valiant(valiant)
                .into(),
        ));
    }
    Err(err("topology object key must be fattree or dragonfly"))
}

/// Parse an optional top-level `"objective"` with strict validation.
fn parse_objective(req: &Json) -> Result<ObjectiveKind, Json> {
    match req.get("objective") {
        None => Ok(ObjectiveKind::WeightedHops),
        Some(v) => match v.as_str().and_then(ObjectiveKind::parse) {
            Some(kind) => Ok(kind),
            None => Err(err("objective must be whops|maxload|blend")),
        },
    }
}

/// Reject an `objective` × `numa` combination the unified evaluator does
/// not support, instead of silently scoring under a different objective.
/// (Today that is exactly a routed objective with a non-unit
/// `numa.hop_cost` — see [`EvalSpec::validate`].)
fn check_objective_numa(objective: ObjectiveKind, numa: Option<&NumaTopology>) -> Option<Json> {
    let spec = EvalSpec::new(objective, numa.map(|t| t.node_level_costs()));
    spec.validate().err().map(|e| err(&e))
}

/// Parse an optional `"bgq"` allocation object — a contiguous BG/Q block
/// (`{"block":[a,b,c,d,e],"ranks_per_node":T,"order":"ABCDET"}`) built by
/// the library's [`Allocation::bgq`] constructor, so a malformed
/// rank-order string surfaces as a structured validation error here
/// instead of crashing the process.
fn parse_bgq(req: &Json) -> Result<Option<Allocation>, Json> {
    let v = match req.get("bgq") {
        None => return Ok(None),
        Some(v) => v,
    };
    if !matches!(v, Json::Obj(_)) {
        return Err(err("bgq must be an object"));
    }
    if let Some(e) = check_fields(v, BGQ_FIELDS, "bgq") {
        return Err(e);
    }
    let block_arr = match v.get("block").and_then(|b| b.as_arr()) {
        Some(arr) if arr.len() == 5 => arr,
        _ => return Err(err("bgq.block must be an array of 5 extents")),
    };
    let mut block = [0usize; 5];
    for (d, cell) in block_arr.iter().enumerate() {
        match as_index(cell) {
            Some(x) if x >= 1 => block[d] = x,
            _ => return Err(err("bgq.block extents must be integers >= 1")),
        }
    }
    let rpn = match v.get("ranks_per_node").map(as_index) {
        Some(Some(r)) if r >= 1 => r,
        _ => return Err(err("bgq.ranks_per_node must be a positive integer")),
    };
    let order = match v.get("order") {
        None => "ABCDET",
        Some(o) => match o.as_str() {
            Some(s) => s,
            None => return Err(err("bgq.order must be a string over ABCDET")),
        },
    };
    // Checked product: enormous extents must hit the limit error, not
    // overflow (a debug-build panic / wrapped release value would bypass
    // the guard entirely).
    let total = block
        .iter()
        .try_fold(rpn, |acc, &x| acc.checked_mul(x))
        .filter(|&t| t <= MAX_BGQ_RANKS);
    let Some(_total) = total else {
        return Err(err(&format!(
            "bgq block exceeds the service limit of {MAX_BGQ_RANKS} ranks"
        )));
    };
    match Allocation::bgq(block, rpn, order) {
        Ok(a) => Ok(Some(a)),
        Err(e) => Err(err(&format!("bgq: {e}"))),
    }
}

fn parse_coords(v: &Json) -> Result<Coords, String> {
    let rows = v.as_arr().ok_or("coords must be an array")?;
    if rows.is_empty() {
        return Err("empty coords".into());
    }
    let dim = rows[0].as_arr().ok_or("coord rows must be arrays")?.len();
    if dim == 0 {
        return Err("zero-dimensional coords".into());
    }
    let mut coords = Coords::with_capacity(dim, rows.len());
    let mut buf = vec![0f64; dim];
    for row in rows {
        let vals = row.as_arr().ok_or("coord rows must be arrays")?;
        if vals.len() != dim {
            return Err("ragged coords".into());
        }
        for (k, x) in vals.iter().enumerate() {
            // Non-finite coordinates (1e999 parses as inf) would poison
            // every distance downstream; reject them here.
            buf[k] = x
                .as_f64()
                .filter(|v| v.is_finite())
                .ok_or("coords must be finite numbers")?;
        }
        coords.push(&buf);
    }
    Ok(coords)
}

/// Strict non-negative integer from a JSON number: rejects fractional
/// values instead of truncating them (`Json::as_usize` truncates, which
/// would make malformed requests succeed with silently different
/// semantics).
fn as_index(v: &Json) -> Option<usize> {
    let x = v.as_f64()?;
    if x >= 0.0 && x.fract() == 0.0 && x < 9e15 {
        Some(x as usize)
    } else {
        None
    }
}

/// Parse `[u, v, weight]` edge rows (weight optional, default 1.0) into a
/// task graph over `num_tasks` tasks. Metrics and the node-level sweep only
/// read edges, so task coordinates are supplied by the caller (or dummy).
fn parse_edges(v: &Json, num_tasks: usize) -> Result<Vec<Edge>, String> {
    let rows = v.as_arr().ok_or("edges must be an array")?;
    let mut edges = Vec::with_capacity(rows.len());
    for row in rows {
        let cells = row.as_arr().ok_or("edge rows must be arrays")?;
        if cells.len() < 2 || cells.len() > 3 {
            return Err("edge rows must be [u, v] or [u, v, weight]".into());
        }
        let u = as_index(&cells[0]).ok_or("edge endpoints must be integer indices")?;
        let v = as_index(&cells[1]).ok_or("edge endpoints must be integer indices")?;
        if u >= num_tasks || v >= num_tasks || u == v {
            return Err(format!("bad edge ({u}, {v}) over {num_tasks} tasks"));
        }
        let w = match cells.get(2) {
            Some(c) => c.as_f64().ok_or("edge weight must be a number")?,
            None => 1.0,
        };
        // Finite and positive: an `inf` weight (1e999 in the wire JSON)
        // would turn every score it touches into inf/NaN.
        if !(w > 0.0 && w.is_finite()) {
            return Err(format!("edge weight {w} must be positive and finite"));
        }
        edges.push(Edge {
            u: u as u32,
            v: v as u32,
            w,
        });
    }
    Ok(edges)
}

/// Build an `Allocation` from per-rank integer router coordinates
/// (`pcoords`), an optional explicit `"torus"` size array, and
/// `ranks_per_node` (consecutive ranks share a node). Used by the
/// hierarchical map extension and `op:eval`. With a non-torus `topology`
/// the coordinate columns are the network's external router naming
/// ([`Topology::coord_dim`]: fat-tree = `[leaf]`, dragonfly =
/// `[group, router]`) resolved through [`Topology::router_of_coords`].
fn parse_alloc(
    pcoords: &Coords,
    req: &Json,
    ranks_per_node: usize,
    topology: Option<Network>,
) -> Result<Allocation, String> {
    let nranks = pcoords.len();
    let dim = pcoords.dim();
    if ranks_per_node == 0 || nranks % ranks_per_node != 0 {
        return Err(format!(
            "ranks_per_node {ranks_per_node} must divide the {nranks} ranks"
        ));
    }
    if let Some(net) = topology {
        if req.get("torus").is_some() {
            return Err(format!(
                "a \"torus\" size array cannot combine with the {} topology",
                net.kind_name()
            ));
        }
        if dim != net.coord_dim() {
            return Err(format!(
                "{} pcoords need {} coordinate column(s), got {dim}",
                net.kind_name(),
                net.coord_dim()
            ));
        }
        let mut core_router = Vec::with_capacity(nranks);
        let mut buf = vec![0usize; dim];
        for i in 0..nranks {
            for (d, slot) in buf.iter_mut().enumerate() {
                let v = pcoords.get(d, i);
                let q = v.round();
                if q < 0.0 || (q - v).abs() > 1e-9 || q >= 9e15 {
                    return Err(format!(
                        "pcoords[{i}][{d}] = {v} is not an integer router coordinate"
                    ));
                }
                *slot = q as usize;
            }
            match net.router_of_coords(&buf) {
                Some(id) => core_router.push(id as u32),
                None => {
                    return Err(format!(
                        "pcoords[{i}] = {buf:?} does not name a {} router",
                        net.kind_name()
                    ))
                }
            }
        }
        return finish_alloc(net, core_router, nranks, ranks_per_node);
    }
    let sizes: Vec<usize> = match req.get("torus") {
        Some(v) => {
            let arr = v.as_arr().ok_or("torus must be a size array")?;
            if arr.len() != dim {
                return Err(format!("torus has {} sizes for {dim}-d pcoords", arr.len()));
            }
            arr.iter()
                .map(|s| {
                    as_index(s)
                        .filter(|&x| x >= 1)
                        .ok_or("torus sizes must be integers >= 1")
                })
                .collect::<Result<_, _>>()?
        }
        None => (0..dim)
            .map(|d| {
                let m = pcoords.axis(d).iter().fold(0f64, |m, &v| m.max(v));
                // parse_coords guarantees finite values; bound the
                // magnitude so the +1 below cannot overflow.
                if m >= 9e15 {
                    return Err(format!("pcoords[{d}] magnitude {m} is absurd"));
                }
                Ok(m.round() as usize + 1)
            })
            .collect::<Result<_, _>>()?,
    };
    // Routed objectives build per-link tables proportional to the router
    // volume — cap it (checked product: overflow must not bypass the cap).
    let volume = sizes
        .iter()
        .try_fold(1usize, |acc, &s| acc.checked_mul(s))
        .filter(|&v| v <= MAX_TORUS_ROUTERS);
    if volume.is_none() {
        return Err(format!(
            "torus volume exceeds the service limit of {MAX_TORUS_ROUTERS} routers"
        ));
    }
    let torus = Torus::torus(&sizes);
    let mut core_router = Vec::with_capacity(nranks);
    let mut buf = vec![0usize; dim];
    for i in 0..nranks {
        for (d, slot) in buf.iter_mut().enumerate() {
            let v = pcoords.get(d, i);
            let q = v.round();
            if q < 0.0 || (q - v).abs() > 1e-9 || q as usize >= sizes[d] {
                return Err(format!(
                    "pcoords[{i}][{d}] = {v} is not an integer router coordinate in [0, {})",
                    sizes[d]
                ));
            }
            *slot = q as usize;
        }
        core_router.push(torus.id_of(&buf) as u32);
    }
    finish_alloc(torus.into(), core_router, nranks, ranks_per_node)
}

/// Node-grouping invariant check + `Allocation` assembly shared by the
/// torus and non-torus arms of [`parse_alloc`].
fn finish_alloc(
    machine: Network,
    core_router: Vec<u32>,
    nranks: usize,
    ranks_per_node: usize,
) -> Result<Allocation, String> {
    // The Allocation invariant (and what makes intra-node edges free): all
    // ranks of a node sit on one router. Reject inconsistent groupings
    // instead of silently zeroing real network traffic.
    for node in 0..(nranks / ranks_per_node) {
        let base = core_router[node * ranks_per_node];
        for r in 1..ranks_per_node {
            if core_router[node * ranks_per_node + r] != base {
                return Err(format!(
                    "ranks of node {node} have different router coordinates; \
                     every ranks_per_node consecutive ranks must share a router"
                ));
            }
        }
    }
    let core_node: Vec<u32> = (0..nranks).map(|i| (i / ranks_per_node) as u32).collect();
    Ok(Allocation {
        machine,
        core_router,
        core_node,
        ranks_per_node,
    })
}

/// Top-level object keys excluded from the *batching compatibility* key:
/// the per-request task set plus the cache-control fields. Two requests
/// sharing this fingerprint ask for different graphs mapped under the
/// same allocation/topology/objective/numa/hier/coarsen config — exactly
/// what [`crate::hier::map_hierarchical_batch`] fans through one
/// invocation.
const BATCH_COMPAT_SKIP: &[&str] = &["tcoords", "edges", "cache", "profile"];

/// Run the hierarchical pipeline for one request — through the service's
/// batching stage when one is configured and the request is small enough,
/// solo otherwise. Batched results are bit-identical to solo execution
/// (see `map_hierarchical_batch`), so the reply never says which path ran.
/// `Err` carries the finished error reply.
fn run_hier(
    req: &Json,
    ctx: &RequestCtx,
    graph: &TaskGraph,
    tcoords: &Coords,
    alloc: &Allocation,
    cfg: &HierConfig,
) -> Result<crate::hier::HierMapping, Json> {
    if let Some(batcher) = ctx.batcher.as_deref() {
        if graph.num_tasks <= batcher.max_tasks() {
            use super::batch::BatchOutcome;
            let key = crate::util::fingerprint::fingerprint_excluding(req, BATCH_COMPAT_SKIP);
            return match batcher.submit(key, graph.clone(), ctx.deadline, alloc, cfg) {
                BatchOutcome::Mapped(m) => Ok(*m),
                BatchOutcome::Deadline(e) => {
                    Err(ServiceError::deadline_exceeded(&e.to_string()).to_json())
                }
                BatchOutcome::WaitExpired => Err(ServiceError::deadline_exceeded(
                    "compute budget exhausted waiting for the batch window to flush",
                )
                .to_json()),
                BatchOutcome::LeaderFailed => Err(ServiceError::internal(
                    "batch flush leader failed before computing this request; retry",
                )
                .to_json()),
            };
        }
    }
    map_hierarchical_budgeted(graph, tcoords, alloc, cfg, &NativeBackend, ctx.deadline)
        .map_err(|e| ServiceError::deadline_exceeded(&e.to_string()).to_json())
}

/// The `"hier"` extension of `op:map`: two-level node→core mapping. The
/// top-level `ordering`/`longest_dim`/`uneven_prime` knobs (already parsed
/// into `map_cfg`) configure the node-level partition.
#[allow(clippy::too_many_arguments)]
fn handle_map_hier(
    req: &Json,
    hier: &Json,
    tcoords: &Coords,
    pcoords: Option<&Coords>,
    map_cfg: MapConfig,
    objective: ObjectiveKind,
    ctx: &RequestCtx,
) -> Json {
    let topology = match parse_topology(req) {
        Ok(t) => t,
        Err(e) => return e,
    };
    let alloc = match parse_bgq(req) {
        Err(e) => return e,
        Ok(Some(a)) => {
            // The block fully defines the allocation; a second source of
            // the same information could silently disagree with it.
            if pcoords.is_some() || req.get("torus").is_some() {
                return err("bgq replaces pcoords/torus (the block defines the allocation)");
            }
            if topology.is_some() {
                return err("bgq defines a torus allocation; it cannot combine with topology");
            }
            if hier.get("ranks_per_node").is_some() {
                return err("bgq.ranks_per_node replaces hier.ranks_per_node");
            }
            a
        }
        Ok(None) => {
            let rpn = match hier.get("ranks_per_node").map(as_index) {
                Some(Some(r)) => r,
                Some(None) => return err("hier.ranks_per_node must be a positive integer"),
                None => 1,
            };
            let Some(pcoords) = pcoords else {
                return err("missing pcoords");
            };
            match parse_alloc(pcoords, req, rpn, topology) {
                Ok(a) => a,
                Err(e) => return err(&format!("hier: {e}")),
            }
        }
    };
    let rpn = alloc.ranks_per_node;
    let numa = match parse_numa(req, rpn) {
        Ok(n) => n,
        Err(e) => return e,
    };
    if let Some(e) = check_objective_numa(objective, numa.as_ref()) {
        return e;
    }
    let coarsen = match parse_coarsen(req) {
        Ok(c) => c,
        Err(e) => return e,
    };
    let mut cfg = HierConfig {
        node_map: map_cfg,
        ..HierConfig::default()
    };
    cfg.spec.objective = objective;
    cfg.spec.numa = numa;
    cfg.spec.coarsen = coarsen;
    if let Some(s) = hier.get("strategy") {
        match s.as_str().and_then(IntraNodeStrategy::parse) {
            Some(intra) => cfg.intra = intra,
            None => return err("hier.strategy must be default|sfc|minvol"),
        }
    }
    if let Some(v) = hier.get("passes") {
        match as_index(v) {
            // Only MinVolume refines; passes is a harmless no-op otherwise.
            Some(p) => {
                if let IntraNodeStrategy::MinVolume { .. } = cfg.intra {
                    cfg.intra = IntraNodeStrategy::MinVolume { passes: p };
                }
            }
            None => return err("hier.passes must be a non-negative integer"),
        }
    }
    if let Some(v) = hier.get("rotations") {
        match as_index(v) {
            Some(r) => cfg.max_rotations = r.max(1),
            None => return err("hier.rotations must be a non-negative integer"),
        }
    }
    let edges = match req.get("edges") {
        Some(v) => match parse_edges(v, tcoords.len()) {
            Ok(e) => e,
            Err(e) => return err(&format!("edges: {e}")),
        },
        None => Vec::new(),
    };
    if objective.get().needs_routing() && edges.is_empty() {
        // Without a task graph every candidate scores 0.0 under a routed
        // objective — reject the silent no-op, same policy as the flat op.
        return err("a routed objective requires a non-empty \"edges\" array");
    }
    if cfg.spec.coarsen.is_some() && edges.is_empty() {
        // Matching contracts edges; with none, the V-cycle would silently
        // degrade to the direct sweep. Reject the no-op instead.
        return err("coarsen requires a non-empty \"edges\" array (matching contracts edges)");
    }
    let graph = TaskGraph {
        num_tasks: tcoords.len(),
        edges,
        coords: tcoords.clone(),
    };
    let m = match run_hier(req, ctx, &graph, tcoords, &alloc, &cfg) {
        Ok(m) => m,
        Err(resp) => return resp,
    };
    // Combined breakdown: the final mapping's value under the requested
    // objective × numa composition (see `objective::combined_value`), the
    // routed bottleneck latency, and — at depth 3 — the per-level NUMA
    // weights, all in one response.
    let mut eval_span = crate::obs::span("map.eval");
    let full = eval_full(&graph, &m.task_to_rank, &alloc);
    let lm = full.link.as_ref().expect("eval_full computes link metrics");
    let nm = numa.map(|topo| (topo, eval_numa(&graph, &m.task_to_rank, &alloc, &topo)));
    let objective_value =
        combined_value(objective, &full, nm.as_ref().map(|(t, n)| (t, n)));
    eval_span.record("objective_value", objective_value);
    // The sweep winner's score minus the final value: what refinement and
    // the lower levels bought under the composed objective.
    eval_span.record("objective_delta", m.node_score - objective_value);
    drop(eval_span);
    let mut fields = vec![
        ("ok", Json::Bool(true)),
        (
            "map",
            Json::Arr(m.task_to_rank.iter().map(|&r| Json::Num(r as f64)).collect()),
        ),
        (
            "nodes",
            Json::Arr(m.task_to_node.iter().map(|&n| Json::Num(n as f64)).collect()),
        ),
        ("swaps", Json::Num(m.swaps_applied as f64)),
        ("objective", Json::Str(objective.name().into())),
        ("objective_value", Json::Num(objective_value)),
        ("max_link_load", Json::Num(lm.max_latency)),
        ("topology", Json::Str(alloc.machine.kind_name().into())),
    ];
    if !m.coarsen_levels.is_empty() {
        // Per-level coarse task counts, finest first — how the V-cycle
        // shrank the instance before the sweep ran.
        fields.push((
            "coarsen_levels",
            Json::Arr(m.coarsen_levels.iter().map(|&n| Json::Num(n as f64)).collect()),
        ));
    }
    if let Some(socks) = &m.task_to_socket {
        fields.push((
            "sockets",
            Json::Arr(socks.iter().map(|&s| Json::Num(s as f64)).collect()),
        ));
        fields.push(("socket_swaps", Json::Num(m.socket_swaps as f64)));
    }
    if let Some((_, n)) = &nm {
        fields.push(("numa_value", Json::Num(n.value)));
        fields.push(("socket_weight", Json::Num(n.socket_weight)));
        fields.push(("core_weight", Json::Num(n.core_weight)));
    }
    Json::obj(fields)
}

/// `op:eval`: Section 3 metrics scalars for a submitted mapping.
fn handle_eval(req: &Json, ctx: &RequestCtx) -> Json {
    let mapping: Vec<u32> = match req.get("map").and_then(|m| m.as_arr()) {
        Some(arr) => {
            let mut out = Vec::with_capacity(arr.len());
            for v in arr {
                // Range-check before the u32 cast: values >= 2^32 must
                // error, not wrap around into valid ranks.
                match as_index(v) {
                    Some(r) if r <= u32::MAX as usize => out.push(r as u32),
                    _ => return err("map entries must be integer rank indices"),
                }
            }
            out
        }
        None => return err("missing map"),
    };
    if mapping.is_empty() {
        return err("empty map");
    }
    let topology = match parse_topology(req) {
        Ok(t) => t,
        Err(e) => return e,
    };
    let alloc = match parse_bgq(req) {
        Err(e) => return e,
        Ok(Some(a)) => {
            if req.get("pcoords").is_some()
                || req.get("torus").is_some()
                || req.get("ranks_per_node").is_some()
            {
                return err("bgq replaces pcoords/torus/ranks_per_node");
            }
            if topology.is_some() {
                return err("bgq defines a torus allocation; it cannot combine with topology");
            }
            a
        }
        Ok(None) => {
            let pcoords = match req.get("pcoords").map(parse_coords) {
                Some(Ok(c)) => c,
                Some(Err(e)) => return err(&format!("pcoords: {e}")),
                None => return err("missing pcoords"),
            };
            let rpn = match req.get("ranks_per_node").map(as_index) {
                Some(Some(r)) => r,
                Some(None) => return err("ranks_per_node must be a positive integer"),
                None => 1,
            };
            match parse_alloc(&pcoords, req, rpn, topology) {
                Ok(a) => a,
                Err(e) => return err(&e),
            }
        }
    };
    let rpn = alloc.ranks_per_node;
    if let Some(&r) = mapping.iter().find(|&&r| r as usize >= alloc.num_ranks()) {
        return err(&format!("map rank {r} out of range {}", alloc.num_ranks()));
    }
    let num_tasks = mapping.len();
    let edges = match req.get("edges") {
        Some(v) => match parse_edges(v, num_tasks) {
            Ok(e) => e,
            Err(e) => return err(&format!("edges: {e}")),
        },
        None => return err("missing edges"),
    };
    let objective = match parse_objective(req) {
        Ok(k) => k,
        Err(e) => return e,
    };
    let numa = match parse_numa(req, rpn) {
        Ok(n) => n,
        Err(e) => return e,
    };
    if let Some(e) = check_objective_numa(objective, numa.as_ref()) {
        return e;
    }
    if let Err(e) = ctx.deadline.check("eval.metrics") {
        return ServiceError::deadline_exceeded(&e.to_string()).to_json();
    }
    let graph = TaskGraph {
        num_tasks,
        edges,
        coords: Coords::from_axes(vec![vec![0.0; num_tasks]]),
    };
    let mut eval_span = crate::obs::span("map.eval");
    let m = eval_full(&graph, &mapping, &alloc);
    let lm = m.link.as_ref().expect("eval_full computes link metrics");
    // `objective_value` composes the network objective with the NUMA term
    // when a numa model is given (see `objective::combined_value`) —
    // previously the numa fields rode alongside a value scored under the
    // *plain* objective, a silently different number than the depth-3
    // mapper optimizes.
    let nm = numa.map(|topo| (topo, eval_numa(&graph, &mapping, &alloc, &topo)));
    let objective_value = combined_value(objective, &m, nm.as_ref().map(|(t, n)| (t, n)));
    eval_span.record("objective_value", objective_value);
    drop(eval_span);
    let mut fields = vec![
        ("ok", Json::Bool(true)),
        ("total_hops", Json::Num(m.total_hops)),
        ("avg_hops", Json::Num(m.avg_hops)),
        ("weighted_hops", Json::Num(m.weighted_hops)),
        ("total_messages", Json::Num(m.total_messages as f64)),
        ("num_edges", Json::Num(m.num_edges as f64)),
        ("max_data", Json::Num(lm.max_data)),
        ("avg_data", Json::Num(lm.avg_data)),
        ("max_latency", Json::Num(lm.max_latency)),
        ("max_link_load", Json::Num(lm.max_latency)),
        ("objective", Json::Str(objective.name().into())),
        ("objective_value", Json::Num(objective_value)),
        ("topology", Json::Str(alloc.machine.kind_name().into())),
    ];
    if let Some((_, nm)) = &nm {
        fields.push(("numa_value", Json::Num(nm.value)));
        fields.push(("socket_weight", Json::Num(nm.socket_weight)));
        fields.push(("core_weight", Json::Num(nm.core_weight)));
    }
    Json::obj(fields)
}

/// Strict optional bool: present means it must be a JSON bool.
fn parse_bool(req: &Json, key: &str, default: bool) -> Result<bool, Json> {
    match req.get(key) {
        None => Ok(default),
        Some(Json::Bool(b)) => Ok(*b),
        Some(_) => Err(err(&format!("{key} must be a boolean"))),
    }
}

/// Top-level object keys excluded from the cache key: the cache-control
/// flag itself and `"profile"` (profiled replies carry a fresh trace id,
/// so they are computed fresh and never cached). Everything else — task
/// coords/weights/edges, allocation, topology, objective, numa, hier,
/// coarsen — is request identity and lands in the fingerprint.
const CACHE_KEY_SKIP: &[&str] = &["cache", "profile"];

/// The `map` entry point behind the result cache: hit → the stored reply
/// verbatim (bit-identical to a cold run, so hits are unmarked); identical
/// request in flight → coalesce onto it; miss → lead the computation and
/// publish. `"cache":false`, `"profile":true`, or a service without a
/// cache bypass straight to the handler. Both control fields are strictly
/// validated *before* any lookup so a cache hit can never mask an
/// `invalid_request`.
fn handle_map_cached(req: &Json, ctx: &RequestCtx) -> Json {
    use super::cache::{FlightOutcome, Lookup};
    let use_cache = match parse_bool(req, "cache", true) {
        Ok(b) => b,
        Err(e) => return e,
    };
    let profiled = match parse_bool(req, "profile", false) {
        Ok(b) => b,
        Err(e) => return e,
    };
    let run = || with_profile(req, "service.map", || handle_map(req, ctx));
    let Some(cache) = ctx.cache.as_deref() else {
        return run();
    };
    faults::failpoint("service.cache.lookup");
    if !use_cache || profiled {
        cache.note_bypass();
        return run();
    }
    let key = crate::util::fingerprint::fingerprint_excluding(req, CACHE_KEY_SKIP);
    match cache.lookup_or_begin(key) {
        Lookup::Hit(resp) => resp,
        Lookup::Wait(flight) => match flight.wait(ctx.deadline) {
            Some(FlightOutcome::Reply(resp)) => resp,
            Some(FlightOutcome::Failed) => ServiceError::internal(
                "coalesced onto an identical in-flight request whose leader failed; retry",
            )
            .to_json(),
            None => ServiceError::deadline_exceeded(
                "compute budget exhausted waiting for an identical in-flight request",
            )
            .to_json(),
        },
        Lookup::Miss(leader) => {
            faults::failpoint("service.cache.leader.panic");
            let resp = run();
            leader.complete(&resp);
            resp
        }
    }
}

fn handle_map(req: &Json, ctx: &RequestCtx) -> Json {
    let tcoords = match req.get("tcoords").map(parse_coords) {
        Some(Ok(c)) => c,
        Some(Err(e)) => return err(&format!("tcoords: {e}")),
        None => return err("missing tcoords"),
    };
    // pcoords stays optional until we know the mode: a "bgq" block can
    // replace it in hierarchical mode.
    let pcoords = match req.get("pcoords").map(parse_coords) {
        Some(Ok(c)) => Some(c),
        Some(Err(e)) => return err(&format!("pcoords: {e}")),
        None => None,
    };
    let ordering = match req.get("ordering") {
        None => PartOrdering::FZ,
        Some(v) => match v.as_str().and_then(PartOrdering::parse) {
            Some(o) => o,
            None => return err("unknown ordering (want Z|Gray|FZ|MFZ|Hilbert)"),
        },
    };
    let longest_dim = match parse_bool(req, "longest_dim", true) {
        Ok(b) => b,
        Err(e) => return e,
    };
    let uneven_prime = match parse_bool(req, "uneven_prime", false) {
        Ok(b) => b,
        Err(e) => return e,
    };
    let objective = match parse_objective(req) {
        Ok(k) => k,
        Err(e) => return e,
    };
    let cfg = MapConfig {
        task_ordering: ordering,
        proc_ordering: ordering,
        longest_dim,
        uneven_prime,
    };
    if let Some(h) = req.get("hier") {
        if !matches!(h, Json::Obj(_)) {
            return err("hier must be an object");
        }
        if let Some(e) = check_fields(h, HIER_FIELDS, "hier") {
            return e;
        }
        return handle_map_hier(req, h, &tcoords, pcoords.as_ref(), cfg, objective, ctx);
    }
    if objective != ObjectiveKind::WeightedHops {
        // The flat map op runs no rotation sweep, so a non-default
        // objective would be a silent no-op — reject it instead.
        return err("objective requires \"hier\" (the flat map op does not score candidates)");
    }
    if req.get("numa").is_some() {
        // Depth-3 mapping needs the node structure only hier mode has.
        return err("numa requires \"hier\" (the flat map op has no node level)");
    }
    if req.get("bgq").is_some() {
        // The flat map op partitions pcoords directly; a BG/Q block only
        // describes an allocation, which is a hierarchical-mode concept.
        return err("bgq requires \"hier\" (the flat map op partitions pcoords directly)");
    }
    if req.get("coarsen").is_some() {
        // The V-cycle runs in front of the node-level sweep; the flat op
        // has no sweep to accelerate, so the knob would be a silent no-op.
        return err("coarsen requires \"hier\" (the V-cycle fronts the node-level sweep)");
    }
    if req.get("topology").is_some() {
        // The flat op partitions pcoords as raw geometry — no network model
        // is consulted, so a topology selection would be a silent no-op.
        return err("topology requires \"hier\" (the flat map op partitions pcoords directly)");
    }
    let Some(pcoords) = pcoords else {
        return err("missing pcoords");
    };
    if let Err(e) = ctx.deadline.check("map.partition") {
        return ServiceError::deadline_exceeded(&e.to_string()).to_json();
    }
    let partition_span = crate::obs::span("map.partition");
    let mapping = map_tasks(&tcoords, &pcoords, &cfg);
    drop(partition_span);
    Json::obj(vec![
        ("ok", Json::Bool(true)),
        (
            "map",
            Json::Arr(mapping.into_iter().map(|r| Json::Num(r as f64)).collect()),
        ),
    ])
}

#[cfg(test)]
mod tests {
    use super::super::errors::{error_kind, error_message, ErrorKind};
    use super::*;
    use crate::testutil::faults::{install, FaultAction, FaultPlan};

    /// The error message of a structured error reply (panics on success
    /// replies — tests always know which they expect).
    fn emsg(resp: &Json) -> &str {
        error_message(resp).expect("structured error reply")
    }

    #[test]
    fn ping_pong() {
        let resp = handle_request(r#"{"op":"ping"}"#);
        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)));
        assert_eq!(resp.get("pong"), Some(&Json::Bool(true)));
    }

    #[test]
    fn bad_json_is_an_invalid_request() {
        let resp = handle_request("{nope");
        assert_eq!(resp.get("ok"), Some(&Json::Bool(false)));
        assert_eq!(error_kind(&resp), Some(ErrorKind::InvalidRequest));
        assert!(emsg(&resp).contains("bad json"));
        // Pure garbage bytes too.
        let resp = handle_request("\u{1}\u{2}garbage");
        assert_eq!(error_kind(&resp), Some(ErrorKind::InvalidRequest));
    }

    #[test]
    fn unknown_and_missing_ops_are_invalid_requests() {
        let resp = handle_request(r#"{"op":"frobnicate"}"#);
        assert_eq!(error_kind(&resp), Some(ErrorKind::InvalidRequest));
        assert!(emsg(&resp).contains("frobnicate"));
        let resp = handle_request(r#"{"x":1}"#);
        assert_eq!(error_kind(&resp), Some(ErrorKind::InvalidRequest));
        assert!(emsg(&resp).contains("missing op"));
    }

    #[test]
    fn map_request_roundtrip() {
        let resp = handle_request(
            r#"{"op":"map","tcoords":[[0,0],[0,1],[1,0],[1,1]],
                "pcoords":[[5,5],[5,6],[6,5],[6,6]],"ordering":"FZ"}"#,
        );
        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)), "{resp:?}");
        let m = resp.get("map").unwrap().as_arr().unwrap();
        assert_eq!(m.len(), 4);
    }

    #[test]
    fn hier_map_round_trip() {
        // 8 tasks on a chain, 4 ranks on 2 nodes (2 ranks each) at routers
        // 0 and 1 of a 2-ring: the hierarchical mapper must fill each node
        // with 4 tasks round-robin over its 2 ranks.
        let resp = handle_request(
            r#"{"op":"map",
                "tcoords":[[0],[1],[2],[3],[4],[5],[6],[7]],
                "pcoords":[[0],[0],[1],[1]],
                "edges":[[0,1],[1,2],[2,3],[3,4],[4,5],[5,6],[6,7]],
                "hier":{"ranks_per_node":2,"strategy":"minvol","rotations":2}}"#,
        );
        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)), "{resp:?}");
        let m: Vec<usize> = resp
            .get("map")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|v| v.as_usize().unwrap())
            .collect();
        let nodes: Vec<usize> = resp
            .get("nodes")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|v| v.as_usize().unwrap())
            .collect();
        assert_eq!(m.len(), 8);
        assert_eq!(nodes.len(), 8);
        // Node assignment respects the rank mapping (ranks 0,1 = node 0).
        for t in 0..8 {
            assert_eq!(m[t] / 2, nodes[t]);
        }
        // Chain halves should stay together: exactly one cut edge.
        let cuts = (0..7).filter(|&t| nodes[t] != nodes[t + 1]).count();
        assert_eq!(cuts, 1, "nodes: {nodes:?}");
    }

    #[test]
    fn hier_rejects_bad_strategy_and_rpn() {
        let resp = handle_request(
            r#"{"op":"map","tcoords":[[0],[1]],"pcoords":[[0],[1]],
                "hier":{"strategy":"bogus"}}"#,
        );
        assert_eq!(resp.get("ok"), Some(&Json::Bool(false)));
        // A non-object hier value must error, not silently enable
        // hierarchical mode with defaults.
        let resp = handle_request(
            r#"{"op":"map","tcoords":[[0],[1]],"pcoords":[[0],[1]],"hier":"minvol"}"#,
        );
        assert_eq!(resp.get("ok"), Some(&Json::Bool(false)));
        let resp = handle_request(
            r#"{"op":"map","tcoords":[[0],[1]],"pcoords":[[0],[1],[2]],
                "hier":{"ranks_per_node":2}}"#,
        );
        assert_eq!(resp.get("ok"), Some(&Json::Bool(false)));
    }

    #[test]
    fn coarsen_map_round_trip_reports_levels() {
        // 32 tasks on a chain over 4 nodes x 8 ranks (routers 0..3 of a
        // 4-ring). target_tasks 8 with 4 nodes gives floor 8, so the
        // V-cycle coarsens 32 -> 16 -> 8 before the sweep runs.
        let tcoords: Vec<String> = (0..32).map(|i| format!("[{i}]")).collect();
        let pcoords: Vec<String> = (0..32).map(|i| format!("[{}]", i / 8)).collect();
        let edges: Vec<String> = (0..31).map(|i| format!("[{i},{}]", i + 1)).collect();
        let base = format!(
            r#""tcoords":[{}],"pcoords":[{}],"edges":[{}],"torus":[4],
                "hier":{{"ranks_per_node":8,"strategy":"minvol","rotations":2}},
                "coarsen":{{"target_tasks":8,"max_levels":10,"matching":"heavy_edge"}}"#,
            tcoords.join(","),
            pcoords.join(","),
            edges.join(","),
        );
        let resp = handle_request(&format!(r#"{{"op":"map",{base}}}"#));
        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)), "{resp:?}");
        let m: Vec<usize> = resp
            .get("map")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|v| v.as_usize().unwrap())
            .collect();
        // A full bijection whose node assignment matches the rank grouping.
        let mut s = m.clone();
        s.sort_unstable();
        assert_eq!(s, (0..32).collect::<Vec<_>>());
        let nodes = resp.get("nodes").unwrap().as_arr().unwrap();
        for (t, &rank) in m.iter().enumerate() {
            assert_eq!(nodes[t].as_usize().unwrap(), rank / 8, "task {t}");
        }
        // The level schedule: strictly decreasing supertask counts, never
        // under the floor of max(target_tasks, nodes) = 8.
        let levels: Vec<usize> = resp
            .get("coarsen_levels")
            .expect("coarsen_levels in reply")
            .as_arr()
            .unwrap()
            .iter()
            .map(|v| v.as_usize().unwrap())
            .collect();
        assert!(!levels.is_empty());
        assert!(levels[0] < 32);
        for w in levels.windows(2) {
            assert!(w[1] < w[0], "levels not strictly decreasing: {levels:?}");
        }
        assert!(*levels.last().unwrap() >= 8, "{levels:?}");
        // The profile breakdown exposes the V-cycle phases: one
        // coarsen.level and one uncoarsen.refine span per level, and the
        // sweep ran once (on the coarsest graph).
        let resp = handle_request(&format!(r#"{{"op":"map","profile":true,{base}}}"#));
        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)), "{resp:?}");
        assert_eq!(
            resp.get("coarsen_levels").unwrap().as_arr().unwrap().len(),
            levels.len()
        );
        let phases = resp
            .get("profile")
            .expect("profile object")
            .get("phases")
            .unwrap()
            .as_arr()
            .unwrap();
        let names: Vec<&str> = phases
            .iter()
            .map(|p| p.get("name").and_then(|v| v.as_str()).unwrap())
            .collect();
        let count = |n: &str| names.iter().filter(|&&x| x == n).count();
        assert_eq!(count("coarsen.level"), levels.len(), "{names:?}");
        assert_eq!(count("uncoarsen.refine"), levels.len(), "{names:?}");
        assert_eq!(count("hier.sweep"), 1, "{names:?}");
        // Each coarsen.level phase carries its supertask count.
        let tasks: Vec<usize> = phases
            .iter()
            .filter(|p| p.get("name").and_then(|v| v.as_str()) == Some("coarsen.level"))
            .map(|p| p.get("tasks").and_then(|v| v.as_f64()).unwrap() as usize)
            .collect();
        assert_eq!(tasks, levels, "{phases:?}");
        // A graph already within the size budget takes the direct path:
        // same request shape, default target_tasks (4096) swallows it.
        let resp = handle_request(&format!(
            r#"{{"op":"map",{}}}"#,
            base.replace(
                r#""coarsen":{"target_tasks":8,"max_levels":10,"matching":"heavy_edge"}"#,
                r#""coarsen":{}"#
            )
        ));
        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)), "{resp:?}");
        assert!(resp.get("coarsen_levels").is_none(), "{resp:?}");
    }

    #[test]
    fn coarsen_field_validated_strictly() {
        let base = r#""tcoords":[[0],[1],[2],[3]],"pcoords":[[0],[0],[1],[1]],
                       "edges":[[0,1],[1,2],[2,3]]"#;
        // coarsen without hier: the flat op has no sweep to accelerate.
        let resp = handle_request(&format!(
            r#"{{"op":"map",{base},"coarsen":{{"target_tasks":2}}}}"#
        ));
        assert_eq!(resp.get("ok"), Some(&Json::Bool(false)), "{resp:?}");
        assert!(emsg(&resp).contains("hier"), "{resp:?}");
        // coarsen with no edges: matching would contract nothing.
        let resp = handle_request(
            r#"{"op":"map","tcoords":[[0],[1],[2],[3]],"pcoords":[[0],[0],[1],[1]],
                "hier":{"ranks_per_node":2},"coarsen":{"target_tasks":2}}"#,
        );
        assert_eq!(resp.get("ok"), Some(&Json::Bool(false)), "{resp:?}");
        assert!(emsg(&resp).contains("edges"), "{resp:?}");
        // Unknown sub-field, bad matching name, zero knobs, wrong type —
        // all structured errors, never silently-defaulted knobs.
        for coarsen in [
            r#"{"target_task":8}"#,
            r#"{"matching":"heaviest"}"#,
            r#"{"target_tasks":0}"#,
            r#"{"max_levels":0}"#,
            r#"{"target_tasks":2.5}"#,
            r#""geometric""#,
        ] {
            let resp = handle_request(&format!(
                r#"{{"op":"map",{base},"hier":{{"ranks_per_node":2}},"coarsen":{coarsen}}}"#
            ));
            assert_eq!(resp.get("ok"), Some(&Json::Bool(false)), "{coarsen}: {resp:?}");
        }
    }

    #[test]
    fn eval_round_trip() {
        // Two ranks per node on a 4-ring: edge (0,1) is intra-node (free),
        // edge (1,2) crosses routers 0 -> 1 (1 hop, weight 3).
        let resp = handle_request(
            r#"{"op":"eval","map":[0,1,2,3],
                "edges":[[0,1,5.0],[1,2,3.0]],
                "pcoords":[[0],[0],[1],[1]],
                "torus":[4],
                "ranks_per_node":2}"#,
        );
        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)), "{resp:?}");
        assert_eq!(resp.get("total_hops").and_then(|v| v.as_f64()), Some(1.0));
        assert_eq!(
            resp.get("weighted_hops").and_then(|v| v.as_f64()),
            Some(3.0)
        );
        assert_eq!(
            resp.get("total_messages").and_then(|v| v.as_f64()),
            Some(2.0)
        );
        assert_eq!(resp.get("max_data").and_then(|v| v.as_f64()), Some(3.0));
    }

    #[test]
    fn strict_integer_and_node_grouping_validation() {
        // Fractional ranks_per_node must not silently truncate.
        let resp = handle_request(
            r#"{"op":"eval","map":[0,1],"edges":[[0,1]],
                "pcoords":[[0],[0]],"ranks_per_node":1.7}"#,
        );
        assert_eq!(resp.get("ok"), Some(&Json::Bool(false)));
        // Ranks grouped into one node must share a router: routers 0 and 1
        // in one "node" would silently zero real network traffic.
        let resp = handle_request(
            r#"{"op":"eval","map":[0,1],"edges":[[0,1]],
                "pcoords":[[0],[1]],"ranks_per_node":2}"#,
        );
        assert_eq!(resp.get("ok"), Some(&Json::Bool(false)));
        // Fractional edge endpoints rejected too.
        let resp = handle_request(
            r#"{"op":"eval","map":[0,1],"edges":[[0.5,1]],"pcoords":[[0],[1]]}"#,
        );
        assert_eq!(resp.get("ok"), Some(&Json::Bool(false)));
        // Malformed hier tuning knobs error instead of silently using
        // defaults.
        let resp = handle_request(
            r#"{"op":"map","tcoords":[[0],[1]],"pcoords":[[0],[1]],
                "hier":{"strategy":"minvol","passes":2.5}}"#,
        );
        assert_eq!(resp.get("ok"), Some(&Json::Bool(false)));
        let resp = handle_request(
            r#"{"op":"map","tcoords":[[0],[1]],"pcoords":[[0],[1]],
                "hier":{"rotations":-3}}"#,
        );
        assert_eq!(resp.get("ok"), Some(&Json::Bool(false)));
    }

    #[test]
    fn hostile_numeric_inputs_are_structured_errors() {
        // Non-finite coordinates: 1e999 parses as +inf in JSON numbers.
        let resp = handle_request(
            r#"{"op":"map","tcoords":[[1e999],[1]],"pcoords":[[0],[1]]}"#,
        );
        assert_eq!(error_kind(&resp), Some(ErrorKind::InvalidRequest), "{resp:?}");
        assert!(emsg(&resp).contains("finite"), "{resp:?}");
        // Non-finite edge weight.
        let resp = handle_request(
            r#"{"op":"eval","map":[0,1],"edges":[[0,1,1e999]],"pcoords":[[0],[1]]}"#,
        );
        assert_eq!(error_kind(&resp), Some(ErrorKind::InvalidRequest), "{resp:?}");
        assert!(emsg(&resp).contains("finite"), "{resp:?}");
        // An absurd explicit torus volume is rejected before it can
        // balloon per-link tables (checked product: no overflow bypass).
        let resp = handle_request(
            r#"{"op":"eval","map":[0,1],"edges":[[0,1]],
                "pcoords":[[0,0,0],[1,1,1]],"torus":[100000,100000,100000]}"#,
        );
        assert_eq!(error_kind(&resp), Some(ErrorKind::InvalidRequest), "{resp:?}");
        assert!(emsg(&resp).contains("torus volume"), "{resp:?}");
        // Derived torus sizes from huge (but finite) pcoords hit the same
        // guard instead of overflowing the size computation.
        let resp = handle_request(
            r#"{"op":"eval","map":[0,1],"edges":[[0,1]],"pcoords":[[0],[8e15]]}"#,
        );
        assert_eq!(error_kind(&resp), Some(ErrorKind::InvalidRequest), "{resp:?}");
    }

    #[test]
    fn unknown_fields_are_structured_errors() {
        // Top-level typos must not be silently ignored on either op.
        let resp = handle_request(
            r#"{"op":"map","tcoords":[[0],[1]],"pcoords":[[0],[1]],"objectiv":"maxload"}"#,
        );
        assert_eq!(resp.get("ok"), Some(&Json::Bool(false)), "{resp:?}");
        assert!(emsg(&resp).contains("objectiv"), "{resp:?}");
        let resp = handle_request(
            r#"{"op":"eval","map":[0,1],"edges":[[0,1]],"pcoords":[[0],[1]],"bogus":1}"#,
        );
        assert_eq!(resp.get("ok"), Some(&Json::Bool(false)));
        // ...and inside the hier object.
        let resp = handle_request(
            r#"{"op":"map","tcoords":[[0],[1]],"pcoords":[[0],[1]],
                "hier":{"strateg":"minvol"}}"#,
        );
        assert_eq!(resp.get("ok"), Some(&Json::Bool(false)));
        // Malformed ordering / flag types error too.
        let resp = handle_request(
            r#"{"op":"map","tcoords":[[0],[1]],"pcoords":[[0],[1]],"ordering":"XYZ"}"#,
        );
        assert_eq!(resp.get("ok"), Some(&Json::Bool(false)));
        let resp = handle_request(
            r#"{"op":"map","tcoords":[[0],[1]],"pcoords":[[0],[1]],"longest_dim":3}"#,
        );
        assert_eq!(resp.get("ok"), Some(&Json::Bool(false)));
    }

    #[test]
    fn objective_field_validated_and_threaded() {
        // Unknown objective: structured error.
        let resp = handle_request(
            r#"{"op":"map","tcoords":[[0],[1]],"pcoords":[[0],[1]],
                "objective":"fastest"}"#,
        );
        assert_eq!(resp.get("ok"), Some(&Json::Bool(false)));
        // Non-default objective without hier: error, not a silent no-op.
        let resp = handle_request(
            r#"{"op":"map","tcoords":[[0],[1]],"pcoords":[[0],[1]],
                "objective":"maxload"}"#,
        );
        assert_eq!(resp.get("ok"), Some(&Json::Bool(false)));
        // Routed objective with hier but no edges: every candidate would
        // score 0.0 — rejected, not silently accepted.
        let resp = handle_request(
            r#"{"op":"map","tcoords":[[0],[1]],"pcoords":[[0],[1]],
                "objective":"maxload","hier":{"ranks_per_node":1}}"#,
        );
        assert_eq!(resp.get("ok"), Some(&Json::Bool(false)));
        // Hierarchical map under maxload runs end to end.
        let resp = handle_request(
            r#"{"op":"map",
                "tcoords":[[0],[1],[2],[3],[4],[5],[6],[7]],
                "pcoords":[[0],[0],[1],[1]],
                "edges":[[0,1],[1,2],[2,3],[3,4],[4,5],[5,6],[6,7]],
                "objective":"maxload",
                "hier":{"ranks_per_node":2,"strategy":"minvol","rotations":2}}"#,
        );
        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)), "{resp:?}");
        assert_eq!(resp.get("map").unwrap().as_arr().unwrap().len(), 8);
        // Eval reports the requested objective's value.
        let resp = handle_request(
            r#"{"op":"eval","map":[0,1,2,3],
                "edges":[[0,1,5.0],[1,2,3.0]],
                "pcoords":[[0],[0],[1],[1]],
                "torus":[4],
                "ranks_per_node":2,
                "objective":"maxload"}"#,
        );
        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)), "{resp:?}");
        assert_eq!(resp.get("objective").and_then(|v| v.as_str()), Some("maxload"));
        // Only edge (1,2) crosses: 3.0 on a unit-bandwidth link.
        assert_eq!(
            resp.get("objective_value").and_then(|v| v.as_f64()),
            Some(3.0)
        );
        // Default objective reports weighted hops.
        let resp = handle_request(
            r#"{"op":"eval","map":[0,1,2,3],
                "edges":[[0,1,5.0],[1,2,3.0]],
                "pcoords":[[0],[0],[1],[1]],
                "torus":[4],
                "ranks_per_node":2}"#,
        );
        assert_eq!(
            resp.get("objective_value").and_then(|v| v.as_f64()),
            resp.get("weighted_hops").and_then(|v| v.as_f64())
        );
    }

    #[test]
    fn numa_map_round_trip() {
        // 8 tasks on a chain, 2 nodes of 2 ranks, 2 sockets x 1 rank each:
        // depth-3 mapping reports each task's socket, and the socket must
        // match the assigned rank's position in its node.
        let resp = handle_request(
            r#"{"op":"map",
                "tcoords":[[0],[1],[2],[3],[4],[5],[6],[7]],
                "pcoords":[[0],[0],[1],[1]],
                "edges":[[0,1],[1,2],[2,3],[3,4],[4,5],[5,6],[6,7]],
                "hier":{"ranks_per_node":2,"strategy":"minvol","rotations":2},
                "numa":{"sockets_per_node":2,"ranks_per_socket":1,"socket_cost":0.5}}"#,
        );
        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)), "{resp:?}");
        let m: Vec<usize> = resp
            .get("map")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|v| v.as_usize().unwrap())
            .collect();
        let socks: Vec<usize> = resp
            .get("sockets")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|v| v.as_usize().unwrap())
            .collect();
        assert_eq!(m.len(), 8);
        assert_eq!(socks.len(), 8);
        // With one rank per socket, a rank's socket is its position in the
        // node: rank % 2.
        for t in 0..8 {
            assert_eq!(socks[t], m[t] % 2, "task {t}");
        }
        assert!(resp.get("socket_swaps").is_some());
    }

    #[test]
    fn numa_field_validated_strictly() {
        let base = r#""tcoords":[[0],[1],[2],[3]],"pcoords":[[0],[0],[1],[1]],
                       "edges":[[0,1],[1,2],[2,3]]"#;
        // numa without hier: error, not a silent no-op.
        let resp = handle_request(&format!(
            r#"{{"op":"map",{base},"numa":{{"sockets_per_node":2,"ranks_per_socket":1}}}}"#
        ));
        assert_eq!(resp.get("ok"), Some(&Json::Bool(false)), "{resp:?}");
        // Unknown numa sub-field.
        let resp = handle_request(&format!(
            r#"{{"op":"map",{base},"hier":{{"ranks_per_node":2}},
                 "numa":{{"sockets_per_node":2,"ranks_per_socket":1,"socket_cos":0.5}}}}"#
        ));
        assert_eq!(resp.get("ok"), Some(&Json::Bool(false)));
        // Socket grid must tile ranks_per_node (2 x 2 != 2).
        let resp = handle_request(&format!(
            r#"{{"op":"map",{base},"hier":{{"ranks_per_node":2}},
                 "numa":{{"sockets_per_node":2,"ranks_per_socket":2}}}}"#
        ));
        assert_eq!(resp.get("ok"), Some(&Json::Bool(false)));
        // Preset with the wrong ranks_per_node (xk7 = 16).
        let resp = handle_request(&format!(
            r#"{{"op":"map",{base},"hier":{{"ranks_per_node":2}},"numa":"xk7"}}"#
        ));
        assert_eq!(resp.get("ok"), Some(&Json::Bool(false)));
        // Unknown preset / wrong type.
        let resp = handle_request(&format!(
            r#"{{"op":"map",{base},"hier":{{"ranks_per_node":2}},"numa":"knl"}}"#
        ));
        assert_eq!(resp.get("ok"), Some(&Json::Bool(false)));
        let resp = handle_request(&format!(
            r#"{{"op":"map",{base},"hier":{{"ranks_per_node":2}},"numa":7}}"#
        ));
        assert_eq!(resp.get("ok"), Some(&Json::Bool(false)));
        // Inverted costs rejected before they can panic the library.
        let resp = handle_request(&format!(
            r#"{{"op":"map",{base},"hier":{{"ranks_per_node":2}},
                 "numa":{{"sockets_per_node":2,"ranks_per_socket":1,
                          "socket_cost":0.1,"core_cost":0.5}}}}"#
        ));
        assert_eq!(resp.get("ok"), Some(&Json::Bool(false)));
        // A routed objective cannot compose with a non-unit hop_cost (the
        // one combination the evaluator does not express) — rejected with
        // a clear message, not silently scored differently.
        let resp = handle_request(&format!(
            r#"{{"op":"map",{base},"hier":{{"ranks_per_node":2}},"objective":"maxload",
                 "numa":{{"sockets_per_node":2,"ranks_per_socket":1,"hop_cost":0.5}}}}"#
        ));
        assert_eq!(resp.get("ok"), Some(&Json::Bool(false)), "{resp:?}");
        assert!(emsg(&resp).contains("hop_cost"), "{resp:?}");
    }

    #[test]
    fn blended_map_runs_end_to_end_with_combined_breakdown() {
        // Acceptance: {"op":"map","objective":"maxlinkload","numa":"xk7"}
        // (and congestionblend) runs through the depth-3 mapper and
        // returns the combined breakdown. xk7 = 2 sockets x 8 ranks, so 2
        // nodes of 16 ranks = 32 ranks/tasks.
        let tcoords: Vec<String> = (0..32).map(|i| format!("[{i}]")).collect();
        let pcoords: Vec<String> =
            (0..32).map(|i| format!("[{}]", i / 16)).collect();
        let edges: Vec<String> = (0..31).map(|i| format!("[{i},{}]", i + 1)).collect();
        for objective in ["maxlinkload", "congestionblend"] {
            let req = format!(
                r#"{{"op":"map","tcoords":[{}],"pcoords":[{}],"edges":[{}],
                     "objective":"{objective}",
                     "hier":{{"ranks_per_node":16,"strategy":"minvol","rotations":2}},
                     "numa":"xk7"}}"#,
                tcoords.join(","),
                pcoords.join(","),
                edges.join(","),
            );
            let resp = handle_request(&req);
            assert_eq!(resp.get("ok"), Some(&Json::Bool(true)), "{objective}: {resp:?}");
            // A full bijection that respects nodes and sockets.
            let m: Vec<usize> = resp
                .get("map")
                .unwrap()
                .as_arr()
                .unwrap()
                .iter()
                .map(|v| v.as_usize().unwrap())
                .collect();
            let mut s = m.clone();
            s.sort_unstable();
            assert_eq!(s, (0..32).collect::<Vec<_>>(), "{objective}");
            let socks = resp.get("sockets").unwrap().as_arr().unwrap();
            assert_eq!(socks.len(), 32, "{objective}");
            for (t, &rank) in m.iter().enumerate() {
                // xk7: socket = (rank position in node) / 8.
                assert_eq!(
                    socks[t].as_usize().unwrap(),
                    (rank % 16) / 8,
                    "{objective}: task {t}"
                );
            }
            // The combined breakdown is all present and consistent: the
            // blended value is the routed objective plus the socket term.
            let ov = resp.get("objective_value").and_then(|v| v.as_f64()).unwrap();
            let mll = resp.get("max_link_load").and_then(|v| v.as_f64()).unwrap();
            let sw = resp.get("socket_weight").and_then(|v| v.as_f64()).unwrap();
            assert!(ov.is_finite() && mll.is_finite() && sw >= 0.0, "{objective}");
            if objective == "maxlinkload" {
                // xk7: socket_cost 0.5, core_cost 0.
                assert!(
                    (ov - (mll + 0.5 * sw)).abs() <= 1e-9 * ov.abs().max(1.0),
                    "{objective}: {ov} != {mll} + 0.5*{sw}"
                );
            }
            assert!(resp.get("numa_value").is_some(), "{objective}");
        }
    }

    #[test]
    fn eval_composes_every_objective_numa_combination() {
        // Satellite: one service-level check per objective x numa
        // combination — the reported objective_value must be the composed
        // value, never the plain objective silently standing in for it.
        // Setup: edge (0,1) cross-socket weight 5 inside node 0; edge
        // (1,2) crosses nodes at 1 hop, weight 3, on a unit-bandwidth
        // 4-ring (so its latency is 3).
        let base = r#""map":[0,1,2,3],"edges":[[0,1,5.0],[1,2,3.0]],
                      "pcoords":[[0],[0],[1],[1]],"torus":[4],"ranks_per_node":2"#;
        let numa = r#""numa":{"sockets_per_node":2,"ranks_per_socket":1,"socket_cost":0.5}"#;
        // (objective, with numa?, expected objective_value). Weighted
        // hops = 3; max link latency = 3 (both directions of the 0->1
        // link carry 3); blend = 0.5*max + 0.5*avg over 8 links.
        let avg = (3.0 + 3.0) / 8.0;
        let cases: Vec<(&str, bool, f64)> = vec![
            ("whops", false, 3.0),
            ("maxload", false, 3.0),
            ("blend", false, 0.5 * 3.0 + 0.5 * avg),
            // With numa: socket_weight 5 at cost 0.5 joins the value.
            ("whops", true, 3.0 + 0.5 * 5.0),
            ("maxload", true, 3.0 + 0.5 * 5.0),
            ("blend", true, 0.5 * 3.0 + 0.5 * avg + 0.5 * 5.0),
        ];
        for (objective, with_numa, want) in cases {
            let req = if with_numa {
                format!(r#"{{"op":"eval",{base},"objective":"{objective}",{numa}}}"#)
            } else {
                format!(r#"{{"op":"eval",{base},"objective":"{objective}"}}"#)
            };
            let resp = handle_request(&req);
            assert_eq!(
                resp.get("ok"),
                Some(&Json::Bool(true)),
                "{objective} numa={with_numa}: {resp:?}"
            );
            let got = resp.get("objective_value").and_then(|v| v.as_f64()).unwrap();
            assert!(
                (got - want).abs() <= 1e-9 * want.abs().max(1.0),
                "{objective} numa={with_numa}: objective_value {got} != {want}"
            );
            // max_link_load is always reported.
            assert_eq!(
                resp.get("max_link_load").and_then(|v| v.as_f64()),
                Some(3.0),
                "{objective} numa={with_numa}"
            );
        }
        // The unsupported combination errors on eval too.
        let resp = handle_request(&format!(
            r#"{{"op":"eval",{base},"objective":"maxload",
                 "numa":{{"sockets_per_node":2,"ranks_per_socket":1,"hop_cost":2.0}}}}"#
        ));
        assert_eq!(resp.get("ok"), Some(&Json::Bool(false)), "{resp:?}");
        assert!(emsg(&resp).contains("hop_cost"), "{resp:?}");
    }

    #[test]
    fn bgq_allocation_field_round_trips_and_validates() {
        // eval over a BG/Q block: 32 routers x 2 ranks on a 2^5 torus.
        // Ranks 0,1 share node 0 (ABCDET: T fastest), so edge (0,1) is
        // free and edge (1,2) crosses one E-link.
        let resp = handle_request(
            r#"{"op":"eval","map":[0,1,2,3],
                "edges":[[0,1,5.0],[1,2,3.0]],
                "bgq":{"block":[2,2,2,2,2],"ranks_per_node":2}}"#,
        );
        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)), "{resp:?}");
        assert_eq!(resp.get("weighted_hops").and_then(|v| v.as_f64()), Some(3.0));
        // A bad rank-order letter is a structured validation error — this
        // used to be a process-crashing panic in machine::rank_order.
        let resp = handle_request(
            r#"{"op":"eval","map":[0,1],"edges":[[0,1]],
                "bgq":{"block":[2,2,2,2,2],"ranks_per_node":2,"order":"ABCDEX"}}"#,
        );
        assert_eq!(resp.get("ok"), Some(&Json::Bool(false)), "{resp:?}");
        assert!(emsg(&resp).contains("rank-order"), "{resp:?}");
        // Duplicate letters and bad lengths are rejected the same way.
        for order in ["AABCDE", "ABC"] {
            let resp = handle_request(&format!(
                r#"{{"op":"eval","map":[0,1],"edges":[[0,1]],
                    "bgq":{{"block":[2,2,2,2,2],"ranks_per_node":2,"order":"{order}"}}}}"#
            ));
            assert_eq!(resp.get("ok"), Some(&Json::Bool(false)), "{order}: {resp:?}");
        }
        // bgq conflicts with the per-rank allocation fields.
        let resp = handle_request(
            r#"{"op":"eval","map":[0,1],"edges":[[0,1]],"pcoords":[[0],[1]],
                "bgq":{"block":[2,2,2,2,2],"ranks_per_node":2}}"#,
        );
        assert_eq!(resp.get("ok"), Some(&Json::Bool(false)));
        // bgq without hier on map is an error, not a silent no-op.
        let resp = handle_request(
            r#"{"op":"map","tcoords":[[0],[1]],
                "bgq":{"block":[2,2,2,2,2],"ranks_per_node":2}}"#,
        );
        assert_eq!(resp.get("ok"), Some(&Json::Bool(false)));
        // Hierarchical map over a bgq block needs no pcoords at all.
        let tcoords: Vec<String> = (0..64).map(|i| format!("[{i}]")).collect();
        let edges: Vec<String> = (0..63).map(|i| format!("[{i},{}]", i + 1)).collect();
        let resp = handle_request(&format!(
            r#"{{"op":"map","tcoords":[{}],"edges":[{}],
                 "bgq":{{"block":[2,2,2,2,2],"ranks_per_node":2}},
                 "hier":{{"strategy":"minvol","rotations":2}}}}"#,
            tcoords.join(","),
            edges.join(","),
        ));
        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)), "{resp:?}");
        let m = resp.get("map").unwrap().as_arr().unwrap();
        assert_eq!(m.len(), 64);
        // Malformed blocks rejected.
        let resp = handle_request(
            r#"{"op":"eval","map":[0,1],"edges":[[0,1]],
                "bgq":{"block":[2,2,2,2],"ranks_per_node":2}}"#,
        );
        assert_eq!(resp.get("ok"), Some(&Json::Bool(false)));
        let resp = handle_request(
            r#"{"op":"eval","map":[0,1],"edges":[[0,1]],
                "bgq":{"block":[2,2,2,2,2],"ranks_per_node":0}}"#,
        );
        assert_eq!(resp.get("ok"), Some(&Json::Bool(false)));
    }

    #[test]
    fn topology_fattree_maps_and_evals_end_to_end() {
        // 8 leaves of a 3-level binary fat-tree, one rank per leaf; a chain
        // of 8 tasks must come back as a bijection with the topology named.
        let tcoords: Vec<String> = (0..8).map(|i| format!("[{i}]")).collect();
        let pcoords: Vec<String> = (0..8).map(|i| format!("[{i}]")).collect();
        let edges: Vec<String> = (0..7).map(|i| format!("[{i},{}]", i + 1)).collect();
        let resp = handle_request(&format!(
            r#"{{"op":"map","tcoords":[{}],"pcoords":[{}],"edges":[{}],
                 "topology":{{"fattree":{{"levels":3,"radix":2}}}},
                 "hier":{{"ranks_per_node":1,"strategy":"minvol","rotations":2}}}}"#,
            tcoords.join(","),
            pcoords.join(","),
            edges.join(","),
        ));
        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)), "{resp:?}");
        assert_eq!(resp.get("topology").and_then(|v| v.as_str()), Some("fattree"));
        let mut m: Vec<usize> = resp
            .get("map")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|v| v.as_usize().unwrap())
            .collect();
        m.sort_unstable();
        assert_eq!(m, (0..8).collect::<Vec<_>>());
        // eval prices hops as 2 x (levels above the NCA): leaves 0,1 are
        // siblings (2 hops), leaves 1,2 meet at the level-1 switch (4).
        let resp = handle_request(
            r#"{"op":"eval","map":[0,1,2,3],
                "edges":[[0,1,5.0],[1,2,3.0]],
                "pcoords":[[0],[1],[2],[3]],
                "topology":{"fattree":{"levels":2,"radix":2}}}"#,
        );
        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)), "{resp:?}");
        assert_eq!(resp.get("topology").and_then(|v| v.as_str()), Some("fattree"));
        assert_eq!(
            resp.get("weighted_hops").and_then(|v| v.as_f64()),
            Some(5.0 * 2.0 + 3.0 * 4.0)
        );
    }

    #[test]
    fn topology_dragonfly_maps_and_evals_end_to_end() {
        // 2 groups x 2 routers, pcoords are (group, router) pairs. Edge
        // (0,1) is one local hop; edge (1,2) crosses groups between the two
        // gateway-adjacent routers: exactly the global hop.
        let base = r#""map":[0,1,2,3],"edges":[[0,1,5.0],[1,2,3.0]],
                      "pcoords":[[0,0],[0,1],[1,0],[1,1]]"#;
        let resp = handle_request(&format!(
            r#"{{"op":"eval",{base},
                 "topology":{{"dragonfly":{{"groups":2,"routers_per_group":2}}}}}}"#
        ));
        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)), "{resp:?}");
        assert_eq!(
            resp.get("topology").and_then(|v| v.as_str()),
            Some("dragonfly")
        );
        // Default global_cost 2: 5*1 + 3*2.
        assert_eq!(resp.get("weighted_hops").and_then(|v| v.as_f64()), Some(11.0));
        // global_cost 1 reprices the global hop.
        let resp = handle_request(&format!(
            r#"{{"op":"eval",{base},
                 "topology":{{"dragonfly":{{"groups":2,"routers_per_group":2,
                                            "global_cost":1}}}}}}"#
        ));
        assert_eq!(resp.get("weighted_hops").and_then(|v| v.as_f64()), Some(8.0));
        // A hierarchical map under a routed objective runs end to end on
        // the valiant path set.
        let tcoords: Vec<String> = (0..8).map(|i| format!("[{i}]")).collect();
        let pcoords: Vec<String> = (0..8)
            .map(|i| format!("[{},{}]", i / 2, (i / 2) % 2))
            .collect();
        let edges: Vec<String> = (0..7).map(|i| format!("[{i},{}]", i + 1)).collect();
        let resp = handle_request(&format!(
            r#"{{"op":"map","tcoords":[{}],"pcoords":[{}],"edges":[{}],
                 "objective":"maxload",
                 "topology":{{"dragonfly":{{"groups":4,"routers_per_group":2,
                                            "valiant":true}}}},
                 "hier":{{"ranks_per_node":2,"strategy":"minvol","rotations":2}}}}"#,
            tcoords.join(","),
            pcoords.join(","),
            edges.join(","),
        ));
        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)), "{resp:?}");
        assert_eq!(
            resp.get("topology").and_then(|v| v.as_str()),
            Some("dragonfly")
        );
        let mut m: Vec<usize> = resp
            .get("map")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|v| v.as_usize().unwrap())
            .collect();
        m.sort_unstable();
        assert_eq!(m, (0..8).collect::<Vec<_>>());
    }

    #[test]
    fn topology_field_validated_strictly() {
        let base = r#""tcoords":[[0],[1],[2],[3]],"edges":[[0,1],[1,2],[2,3]]"#;
        // The default spelling is accepted and changes nothing.
        let resp = handle_request(&format!(
            r#"{{"op":"map",{base},"pcoords":[[0],[0],[1],[1]],"topology":"torus",
                 "hier":{{"ranks_per_node":2}}}}"#
        ));
        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)), "{resp:?}");
        assert_eq!(resp.get("topology").and_then(|v| v.as_str()), Some("torus"));
        // Structured errors: unknown family, two keys, unknown sub-field,
        // bad knob values, wrong value type.
        for topology in [
            r#""hypercube""#,
            r#"{"fattree":{"levels":2,"radix":2},"dragonfly":{"groups":2,"routers_per_group":1}}"#,
            r#"{"fattree":{"levels":2,"radix":2,"bw":3}}"#,
            r#"{"fattree":{"levels":0,"radix":2}}"#,
            r#"{"fattree":{"levels":2,"radix":1}}"#,
            r#"{"fattree":{"levels":40,"radix":16}}"#,
            r#"{"dragonfly":{"groups":0,"routers_per_group":2}}"#,
            r#"{"dragonfly":{"groups":2,"routers_per_group":2,"global_cost":0}}"#,
            r#"{"dragonfly":{"groups":2,"routers_per_group":2,"valiant":1}}"#,
            r#"{"dragonfly":{"groups":100000,"routers_per_group":100000}}"#,
            r#"7"#,
        ] {
            let resp = handle_request(&format!(
                r#"{{"op":"map",{base},"pcoords":[[0],[1],[2],[3]],"topology":{topology},
                     "hier":{{"ranks_per_node":1}}}}"#
            ));
            assert_eq!(resp.get("ok"), Some(&Json::Bool(false)), "{topology}: {resp:?}");
        }
        // topology without hier on map: error, not a silent no-op.
        let resp = handle_request(&format!(
            r#"{{"op":"map",{base},"pcoords":[[0],[1],[2],[3]],
                 "topology":{{"fattree":{{"levels":2,"radix":2}}}}}}"#
        ));
        assert_eq!(resp.get("ok"), Some(&Json::Bool(false)), "{resp:?}");
        assert!(emsg(&resp).contains("hier"), "{resp:?}");
        // A "torus" size array cannot combine with a non-torus topology.
        let resp = handle_request(
            r#"{"op":"eval","map":[0,1],"edges":[[0,1]],"pcoords":[[0],[1]],
                "torus":[4],"topology":{"fattree":{"levels":2,"radix":2}}}"#,
        );
        assert_eq!(resp.get("ok"), Some(&Json::Bool(false)), "{resp:?}");
        // Nor can a bgq block.
        let resp = handle_request(
            r#"{"op":"eval","map":[0,1],"edges":[[0,1]],
                "bgq":{"block":[2,2,2,2,2],"ranks_per_node":2},
                "topology":{"fattree":{"levels":2,"radix":2}}}"#,
        );
        assert_eq!(resp.get("ok"), Some(&Json::Bool(false)), "{resp:?}");
        // Coordinate arity follows the topology: a fat-tree leaf is one
        // column, a dragonfly router two.
        let resp = handle_request(
            r#"{"op":"eval","map":[0,1],"edges":[[0,1]],"pcoords":[[0,0],[1,1]],
                "topology":{"fattree":{"levels":2,"radix":2}}}"#,
        );
        assert_eq!(resp.get("ok"), Some(&Json::Bool(false)), "{resp:?}");
        // Out-of-range router names are rejected, not wrapped.
        let resp = handle_request(
            r#"{"op":"eval","map":[0,1],"edges":[[0,1]],"pcoords":[[0],[4]],
                "topology":{"fattree":{"levels":2,"radix":2}}}"#,
        );
        assert_eq!(resp.get("ok"), Some(&Json::Bool(false)), "{resp:?}");
        assert!(emsg(&resp).contains("router"), "{resp:?}");
    }

    #[test]
    fn numa_eval_reports_breakdown() {
        // Ranks 0,1 share node 0 (sockets 0,1); edge (0,1) is cross-socket
        // weight 5; edge (1,2) crosses nodes at 1 hop, weight 3.
        let resp = handle_request(
            r#"{"op":"eval","map":[0,1,2,3],
                "edges":[[0,1,5.0],[1,2,3.0]],
                "pcoords":[[0],[0],[1],[1]],
                "torus":[4],
                "ranks_per_node":2,
                "numa":{"sockets_per_node":2,"ranks_per_socket":1,"socket_cost":0.5}}"#,
        );
        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)), "{resp:?}");
        assert_eq!(resp.get("socket_weight").and_then(|v| v.as_f64()), Some(5.0));
        assert_eq!(resp.get("core_weight").and_then(|v| v.as_f64()), Some(0.0));
        assert_eq!(
            resp.get("numa_value").and_then(|v| v.as_f64()),
            Some(3.0 + 0.5 * 5.0)
        );
        // Without numa the response stays as before.
        let resp = handle_request(
            r#"{"op":"eval","map":[0,1,2,3],
                "edges":[[0,1,5.0],[1,2,3.0]],
                "pcoords":[[0],[0],[1],[1]],
                "torus":[4],
                "ranks_per_node":2}"#,
        );
        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)));
        assert!(resp.get("numa_value").is_none());
    }

    #[test]
    fn eval_rejects_bad_requests() {
        // Missing edges.
        let resp =
            handle_request(r#"{"op":"eval","map":[0,1],"pcoords":[[0],[1]]}"#);
        assert_eq!(resp.get("ok"), Some(&Json::Bool(false)));
        // Rank out of range.
        let resp = handle_request(
            r#"{"op":"eval","map":[0,9],"edges":[[0,1]],"pcoords":[[0],[1]]}"#,
        );
        assert_eq!(resp.get("ok"), Some(&Json::Bool(false)));
        // Non-integer router coordinate.
        let resp = handle_request(
            r#"{"op":"eval","map":[0,1],"edges":[[0,1]],"pcoords":[[0.5],[1]]}"#,
        );
        assert_eq!(resp.get("ok"), Some(&Json::Bool(false)));
    }

    #[test]
    fn ragged_coords_rejected() {
        let resp =
            handle_request(r#"{"op":"map","tcoords":[[0,0],[1]],"pcoords":[[0,0],[1,1]]}"#);
        assert_eq!(resp.get("ok"), Some(&Json::Bool(false)));
    }

    #[test]
    fn expired_deadline_returns_deadline_exceeded() {
        let ctx = RequestCtx {
            deadline: Deadline::within(std::time::Duration::ZERO),
            ..RequestCtx::default()
        };
        // Flat map: checked before the partition runs.
        let resp = handle_request_with(
            r#"{"op":"map","tcoords":[[0],[1]],"pcoords":[[0],[1]]}"#,
            &ctx,
        );
        assert_eq!(error_kind(&resp), Some(ErrorKind::DeadlineExceeded), "{resp:?}");
        assert_eq!(
            resp.get("error").and_then(|e| e.get("retryable")),
            Some(&Json::Bool(false))
        );
        // Hierarchical map: checked at the sweep phase boundary.
        let resp = handle_request_with(
            r#"{"op":"map","tcoords":[[0],[1],[2],[3]],"pcoords":[[0],[0],[1],[1]],
                "edges":[[0,1],[1,2],[2,3]],"hier":{"ranks_per_node":2}}"#,
            &ctx,
        );
        assert_eq!(error_kind(&resp), Some(ErrorKind::DeadlineExceeded), "{resp:?}");
        assert!(emsg(&resp).contains("hier.sweep"), "{resp:?}");
        // Eval: checked before the metrics engine runs.
        let resp = handle_request_with(
            r#"{"op":"eval","map":[0,1],"edges":[[0,1]],"pcoords":[[0],[1]]}"#,
            &ctx,
        );
        assert_eq!(error_kind(&resp), Some(ErrorKind::DeadlineExceeded), "{resp:?}");
        // Validation still wins over the deadline: a malformed request is
        // invalid_request even under an expired budget.
        let resp = handle_request_with(r#"{"op":"map"}"#, &ctx);
        assert_eq!(error_kind(&resp), Some(ErrorKind::InvalidRequest), "{resp:?}");
        // Ping never needs a budget.
        let resp = handle_request_with(r#"{"op":"ping"}"#, &ctx);
        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)));
    }

    #[test]
    fn stats_op_reports_counters_and_latency() {
        let ctx = RequestCtx::default();
        let resp = handle_request_with(r#"{"op":"ping"}"#, &ctx);
        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)));
        let resp = handle_request_with("{bad", &ctx);
        assert_eq!(error_kind(&resp), Some(ErrorKind::InvalidRequest));
        let stats = handle_request_with(r#"{"op":"stats"}"#, &ctx);
        assert_eq!(stats.get("ok"), Some(&Json::Bool(true)), "{stats:?}");
        // The two earlier requests completed; stats itself is in flight.
        assert_eq!(stats.get("completed").and_then(|v| v.as_f64()), Some(2.0));
        assert_eq!(stats.get("active").and_then(|v| v.as_f64()), Some(1.0));
        assert_eq!(
            stats
                .get("errors")
                .and_then(|e| e.get("invalid_request"))
                .and_then(|v| v.as_f64()),
            Some(1.0)
        );
        let ops = stats.get("ops").unwrap();
        assert!(ops.get("ping").is_some(), "{stats:?}");
        assert!(ops.get("(parse)").is_some(), "{stats:?}");
        // Unknown stats fields are rejected like everywhere else.
        let resp = handle_request_with(r#"{"op":"stats","verbose":true}"#, &ctx);
        assert_eq!(error_kind(&resp), Some(ErrorKind::InvalidRequest));
    }

    #[test]
    fn profile_flag_returns_phase_breakdown() {
        let base = r#""tcoords":[[0],[1],[2],[3],[4],[5],[6],[7]],
                "pcoords":[[0],[0],[1],[1]],
                "edges":[[0,1],[1,2],[2,3],[3,4],[4,5],[5,6],[6,7]],
                "hier":{"ranks_per_node":2,"strategy":"minvol","rotations":2}"#;
        let plain = handle_request(&format!(r#"{{"op":"map",{base}}}"#));
        assert_eq!(plain.get("ok"), Some(&Json::Bool(true)), "{plain:?}");
        assert!(plain.get("profile").is_none());
        assert!(plain.get("trace_id").is_none());
        let resp = handle_request(&format!(r#"{{"op":"map","profile":true,{base}}}"#));
        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)), "{resp:?}");
        // Profiling never changes the mapping.
        assert_eq!(resp.get("map"), plain.get("map"));
        assert!(resp.get("trace_id").and_then(|v| v.as_f64()).unwrap() >= 1.0);
        let profile = resp.get("profile").expect("profile object");
        let total = profile.get("total_us").and_then(|v| v.as_f64()).unwrap();
        let phases = profile.get("phases").unwrap().as_arr().unwrap();
        let names: Vec<&str> = phases
            .iter()
            .map(|p| p.get("name").and_then(|v| v.as_str()).unwrap())
            .collect();
        assert_eq!(names, vec!["hier.sweep", "hier.refine", "hier.place", "map.eval"]);
        // Phases nest inside the measured request interval, so their
        // elapsed times sum to at most the total.
        let sum: f64 = phases
            .iter()
            .map(|p| p.get("elapsed_us").and_then(|v| v.as_f64()).unwrap())
            .sum();
        assert!(sum <= total, "phase sum {sum} > total {total}");
        // Span fields ride along: the sweep phase carries its node score
        // and candidate count, map.eval the objective delta.
        let sweep = &phases[0];
        assert_eq!(sweep.get("candidates").and_then(|v| v.as_f64()), Some(2.0));
        assert!(sweep.get("node_score").is_some());
        assert!(phases[3].get("objective_delta").is_some());
        // profile:false behaves exactly like no profile field.
        let off = handle_request(&format!(r#"{{"op":"map","profile":false,{base}}}"#));
        assert!(off.get("profile").is_none());
        // Non-bool profile is a structured error.
        let bad = handle_request(&format!(r#"{{"op":"map","profile":1,{base}}}"#));
        assert_eq!(error_kind(&bad), Some(ErrorKind::InvalidRequest));
    }

    #[test]
    fn profile_flag_works_on_eval() {
        let resp = handle_request(
            r#"{"op":"eval","map":[0,1,2,3],"profile":true,
                "edges":[[0,1,5.0],[1,2,3.0]],
                "pcoords":[[0],[0],[1],[1]],
                "torus":[4],
                "ranks_per_node":2}"#,
        );
        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)), "{resp:?}");
        let phases = resp
            .get("profile")
            .and_then(|p| p.get("phases"))
            .unwrap()
            .as_arr()
            .unwrap();
        assert_eq!(phases.len(), 1);
        assert_eq!(phases[0].get("name").and_then(|v| v.as_str()), Some("map.eval"));
        assert_eq!(
            phases[0].get("objective_value").and_then(|v| v.as_f64()),
            resp.get("objective_value").and_then(|v| v.as_f64())
        );
    }

    #[test]
    fn trace_op_serves_recent_spans_and_metrics() {
        // With the recorder off the op still answers (possibly with spans
        // other concurrently-running tests recorded).
        let resp = handle_request(r#"{"op":"trace"}"#);
        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)), "{resp:?}");
        assert!(resp.get("traces").unwrap().as_arr().is_some());
        assert!(resp.get("events_dropped").and_then(|v| v.as_f64()).unwrap() >= 0.0);
        assert!(resp.get("metrics").unwrap().get("counters").is_some());
        // Unknown fields rejected like every other op.
        let resp = handle_request(r#"{"op":"trace","verbose":true}"#);
        assert_eq!(error_kind(&resp), Some(ErrorKind::InvalidRequest));
        // With the global recorder on, a profiled request's spans land in
        // the ring and come back as a span tree.
        crate::obs::set_enabled(true);
        let resp = handle_request(
            r#"{"op":"map","profile":true,
                "tcoords":[[0],[1],[2],[3]],"pcoords":[[0],[0],[1],[1]],
                "edges":[[0,1],[1,2],[2,3]],"hier":{"ranks_per_node":2}}"#,
        );
        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)), "{resp:?}");
        let trace_id = resp.get("trace_id").and_then(|v| v.as_f64()).unwrap();
        let traces = handle_request(r#"{"op":"trace"}"#);
        crate::obs::set_enabled(false);
        assert_eq!(traces.get("ok"), Some(&Json::Bool(true)));
        let forest = traces.get("traces").unwrap().as_arr().unwrap();
        let ours = forest
            .iter()
            .find(|t| t.get("trace").and_then(|v| v.as_f64()) == Some(trace_id))
            .expect("profiled request's trace in the ring");
        let roots = ours.get("spans").unwrap().as_arr().unwrap();
        assert_eq!(
            roots[0].get("name").and_then(|v| v.as_str()),
            Some("service.map")
        );
        // The metrics registry saw the profiled request.
        assert!(
            traces
                .get("metrics")
                .and_then(|m| m.get("counters"))
                .and_then(|c| c.get("service.requests"))
                .and_then(|v| v.as_f64())
                .unwrap_or(0.0)
                >= 1.0
        );
    }

    #[test]
    fn injected_panic_becomes_internal_error_and_is_logged() {
        let guard = install(
            FaultPlan::new(77).site("service.handler.panic", FaultAction::Panic, 1.0),
        );
        let ctx = RequestCtx::default();
        let resp = handle_request_with(r#"{"op":"ping"}"#, &ctx);
        assert_eq!(error_kind(&resp), Some(ErrorKind::Internal), "{resp:?}");
        assert!(emsg(&resp).contains("panic in op \"ping\""), "{resp:?}");
        assert_eq!(ctx.diag.panic_count(), 1);
        drop(guard);
        // With the plan uninstalled the same request succeeds — the
        // handler state survived the panic.
        let resp = handle_request_with(r#"{"op":"ping"}"#, &ctx);
        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)));
        // The panic message is in the stats ring buffer.
        let stats = handle_request_with(r#"{"op":"stats"}"#, &ctx);
        let recent = stats.get("recent").unwrap().as_arr().unwrap();
        assert!(
            recent.iter().any(|e| e.as_str().unwrap().contains("service.handler.panic")),
            "{recent:?}"
        );
        assert_eq!(stats.get("panics").and_then(|v| v.as_f64()), Some(1.0));
    }
}
