//! Mapping service: the library exposed as a long-running daemon.
//!
//! Real deployments call the mapper from job launch scripts; this service
//! mirrors that: a TCP server speaking newline-delimited JSON (the offline
//! vendor set has no tokio; the event loop is std::net + threads), hardened
//! for production use — bounded worker pool, per-request deadlines, panic
//! isolation, load shedding, and graceful drain.
//!
//! Protocol (one JSON object per line):
//! ```json
//! {"op":"map","tcoords":[[0,0],[0,1]],"pcoords":[[3,3],[3,4]],
//!  "ordering":"FZ","longest_dim":true,"uneven_prime":false}
//! -> {"ok":true,"map":[0,1]}
//! {"op":"ping"} -> {"ok":true,"pong":true}
//! ```
//!
//! **Hierarchical mapping** — add a `"hier"` object to `"map"`. `pcoords`
//! are then per-rank integer router coordinates on a torus (sizes derived
//! as per-axis max+1, or given explicitly as `"torus":[..]`), consecutive
//! `ranks_per_node` ranks form a node, and the optional `"edges"` array
//! (`[u,v,weight]` rows) supplies the task graph the node-level sweep and
//! `MinVolume` refinement score against:
//! ```json
//! {"op":"map","tcoords":[[0,0],[0,1],[1,0],[1,1]],
//!  "pcoords":[[0,0],[0,0],[1,0],[1,0]],
//!  "edges":[[0,1,2.5],[2,3,1.0]],
//!  "hier":{"ranks_per_node":2,"strategy":"minvol","rotations":4}}
//! -> {"ok":true,"map":[0,1,2,3],"nodes":[0,0,1,1]}
//! ```
//!
//! **Evaluation** — `{"op":"eval"}` scores a submitted mapping with the
//! Section 3 metrics engine (same allocation encoding as hierarchical
//! map):
//! ```json
//! {"op":"eval","map":[0,1,2,3],"edges":[[0,1,2.5]],
//!  "pcoords":[[0,0],[0,0],[1,0],[1,0]],"ranks_per_node":2}
//! -> {"ok":true,"total_hops":0,"weighted_hops":0,...}
//! ```
//!
//! **Objectives** — both ops accept an `"objective"` field
//! (`"whops" | "maxload" | "blend"`, see [`crate::objective`]). On `map`
//! it selects what the hierarchical sweep and `MinVolume` refinement
//! optimize (hierarchical mode only: the flat `map` op never scores, so a
//! non-default objective there is an error, not a silent no-op). On `eval`
//! the response additionally reports the mapping's value under that
//! objective (`"objective_value"`) and the routed bottleneck
//! (`"max_link_load"`).
//!
//! **NUMA depth 3** — both ops accept a `"numa"` field: a preset name
//! (`"xk7"` — 2 sockets × 8 ranks, `"bgq"` — 1 × 16) or an object
//! `{"sockets_per_node":S,"ranks_per_socket":R,"socket_cost":...,
//! "core_cost":...,"hop_cost":...}` (costs optional: 0.5 / 0.0 / 1.0).
//! The socket grid must tile `ranks_per_node` exactly. On `map` (requires
//! `"hier"`) the mapper runs at depth 3 — socket split plus cross-socket
//! refinement inside each node — and the response adds each task's
//! within-node socket plus the socket-swap count.
//!
//! **Objective × NUMA composition** — `"objective"` and `"numa"` compose
//! on both ops through the unified evaluator
//! ([`crate::objective::eval`]): `{"objective":"maxload","numa":"xk7"}`
//! runs the blended (routed congestion × NUMA) depth-3 mapper end to end.
//! Responses carry the combined breakdown in one place —
//! `"objective_value"` is the *composed* value
//! ([`crate::objective::combined_value`]), `"max_link_load"` the routed
//! bottleneck, and with `"numa"` also `"numa_value"`,
//! `"socket_weight"`, `"core_weight"`. A combination the evaluator cannot
//! express (today: a routed objective with a non-unit `numa.hop_cost`) is
//! rejected with a clear message instead of silently scoring under a
//! different objective.
//!
//! **Multilevel coarsening** — `"hier"` map accepts a `"coarsen"` object
//! (`{"target_tasks":N,"max_levels":L,"matching":"heavy_edge"|"geometric"}`,
//! every field optional — see [`crate::coarsen::CoarsenConfig`] for the
//! defaults) that runs the V-cycle in front of the node-level sweep: the
//! task graph is contracted level by level until it fits the size budget,
//! the sweep solves the coarsest instance, and bounded `MinVolume`
//! refinement polishes the projected mapping on the way back up. Requires
//! a non-empty `"edges"` array (matching contracts edges) and `"hier"`
//! (the flat op has no sweep to accelerate). When the V-cycle actually
//! ran, the reply carries `"coarsen_levels"` — the supertask count per
//! level, finest first — and a `"profile":true` breakdown shows one
//! `coarsen.level` and one `uncoarsen.refine` phase per level. A graph
//! already within the budget silently takes the direct path (no
//! `"coarsen_levels"` in the reply).
//!
//! **BG/Q block allocations** — `"hier"` map and `eval` accept a `"bgq"`
//! object in place of `pcoords`/`torus`/`ranks_per_node`:
//! `{"block":[a,b,c,d,e],"ranks_per_node":T,"order":"ABCDET"}` builds the
//! contiguous-block allocation via
//! [`Allocation::bgq`](crate::machine::Allocation::bgq); a malformed
//! `order` string (bad letter, wrong length, duplicate) returns a
//! structured validation error — previously that letter panicked deep in
//! `machine::rank_order` and crashed the process.
//!
//! **Topologies** — `"map"` (hierarchical mode) and `"eval"` accept a
//! `"topology"` field selecting the network model behind the allocation
//! (see [`crate::machine::Topology`]). `"torus"` (the default) keeps the
//! torus/mesh path; `{"fattree":{...}}` and `{"dragonfly":{...}}` switch
//! the distance/routing model and the meaning of `pcoords`: a fat-tree
//! rank is named by its leaf index (one coordinate column), a dragonfly
//! rank by its `[group, router]` pair (two columns). A `"torus"` size
//! array or a `"bgq"` block cannot combine with a non-torus topology, and
//! on `"map"` a topology requires `"hier"` (the flat op partitions
//! `pcoords` as raw geometry — no network model is consulted). Responses
//! echo the resolved kind as `"topology"`.
//!
//! **Validation is strict**: unknown or malformed fields — top-level or
//! inside `"hier"`/`"numa"`/`"bgq"`/`"topology"` — return a structured
//! error instead of being silently ignored, so a typo like `"objectiv"`
//! can never quietly change what a production mapping run optimizes. In
//! the same spirit, `ranks_per_node` must divide the rank count exactly
//! (the library's [`crate::machine::AllocError`] policy: no silent node
//! truncation).
//!
//! # Request schema
//!
//! The full JSON surface, one row per field. "Ops" says where the field
//! is accepted; any other placement (or any field not listed) is an
//! `invalid_request` error. Ops with no fields beyond `"op"`: `"ping"`,
//! `"stats"`, `"trace"`.
//!
//! | field                  | ops        | type / values                         | rules                                                       |
//! |------------------------|------------|---------------------------------------|-------------------------------------------------------------|
//! | `op`                   | all        | `"map"` `"eval"` `"ping"` `"stats"` `"trace"` | required                                            |
//! | `tcoords`              | map        | array of equal-length float rows      | required; one row per task                                  |
//! | `pcoords`              | map, eval  | array of equal-length rows            | flat map: floats (raw geometry). hier map / eval: integer router coordinates — torus axes, fat-tree `[leaf]`, dragonfly `[group, router]`; column count must match the topology; consecutive `ranks_per_node` rows must share a router |
//! | `ordering`             | map (flat) | `"Z"` `"Gray"` `"FZ"` `"MFZ"` `"Hilbert"` | default `"FZ"`                                          |
//! | `longest_dim`          | map (flat) | bool                                  | default false                                               |
//! | `uneven_prime`         | map (flat) | bool                                  | default false                                               |
//! | `edges`                | map, eval  | `[u, v]` or `[u, v, w]` rows          | task graph; indices in range, `w` finite ≥ 0. Required by `"coarsen"` and by scoring objectives |
//! | `torus`                | map (hier), eval | array of positive sizes         | explicit torus extents (else per-axis max+1); torus topology only |
//! | `topology`             | map (hier), eval | `"torus"` \| `{"fattree":{...}}` \| `{"dragonfly":{...}}` | exactly one family key; conflicts with `"torus"` array and `"bgq"`; flat map rejects it |
//! | ├ `fattree.levels`     |            | int ≥ 1                               | required; `radix^levels` leaves, capped like torus routers  |
//! | ├ `fattree.radix`      |            | int ≥ 2                               | required                                                    |
//! | ├ `dragonfly.groups`   |            | int ≥ 1                               | required; `groups × routers_per_group` routers under the same cap |
//! | ├ `dragonfly.routers_per_group` |   | int ≥ 1                               | required                                                    |
//! | ├ `dragonfly.terminals_per_router` || int ≥ 1                               | default 1                                                   |
//! | ├ `dragonfly.global_cost` |         | int ≥ 1                               | default 2; prices the global hop in distances               |
//! | └ `dragonfly.valiant`  |            | bool                                  | default false; one-hop-Valiant routed load, minimal distances |
//! | `hier`                 | map        | object                                | enables hierarchical mode                                   |
//! | ├ `ranks_per_node`     |            | int ≥ 1                               | must divide the rank count                                  |
//! | ├ `strategy`           |            | `"default"` `"sfc"` `"minvol"`        | intra-node placement / refinement                           |
//! | ├ `passes`             |            | int ≥ 0                               | `minvol` refinement passes (default 2)                      |
//! | └ `rotations`          |            | int ≥ 1                               | node-level sweep rotation budget                            |
//! | `ranks_per_node`       | eval       | int ≥ 1                               | top-level on eval (no `"hier"` object there)                |
//! | `objective`            | map (hier), eval | `"whops"` `"maxload"` `"blend"` | flat map rejects a non-default objective                    |
//! | `numa`                 | map (hier), eval | `"xk7"` \| `"bgq"` \| object    | object keys: `sockets_per_node`, `ranks_per_socket`, `socket_cost`, `core_cost`, `hop_cost`; grid must tile `ranks_per_node` |
//! | `bgq`                  | map (hier), eval | `{"block":[a,b,c,d,e], "ranks_per_node":T, "order":"ABCDET"}` | replaces `pcoords`/`torus`/`ranks_per_node`; conflicts with `"topology"` |
//! | `coarsen`              | map (hier) | `{"target_tasks":N, "max_levels":L, "matching":"heavy_edge"\|"geometric"}` | all optional; needs non-empty `"edges"`  |
//! | `profile`              | map, eval  | bool                                  | attach `"trace_id"` + per-phase `"profile"` breakdown       |
//! | `cache`                | map        | bool                                  | default true; `false` bypasses the result cache for this request |
//!
//! Success responses: `map` → `"map"` (+ `"nodes"`, `"sockets"`,
//! `"socket_swaps"`, `"coarsen_levels"`, `"topology"` when applicable);
//! `eval` → the Section 3 metrics (`"total_hops"`, `"weighted_hops"`,
//! `"avg_hops"`, `"max_hops"`, link metrics) plus `"objective_value"`,
//! `"max_link_load"`, NUMA breakdown, and `"topology"` as requested.
//! Failures use the error taxonomy below.
//!
//! # Request pipeline
//!
//! ```text
//! accept loop ──► bounded queue ──► fixed worker pool ──► handler
//!     │  (queue full: shed with       (one conn per        (catch_unwind,
//!     │   "overloaded" + retry hint)   worker at a time)    deadline checks)
//!     └── never spawns per connection
//! ```
//!
//! The accept loop never spawns threads. Accepted connections enter a
//! bounded queue drained by a fixed pool of [`ServiceConfig::workers`]
//! threads (default: the [`crate::par`] thread budget, so
//! `TASKMAP_THREADS` sizes the service too). When the queue is full the
//! connection is *shed immediately* with an `overloaded` error carrying a
//! `retry_after_ms` hint, then closed — a connect flood cannot grow the
//! thread count or the memory footprint; the hard cap on concurrent
//! connections is `workers + queue_capacity`.
//!
//! Every connection is bounded in time and space: socket read/write
//! timeouts, an overall per-frame deadline (a client trickling bytes
//! cannot hold a worker forever), a payload cap enforced incrementally,
//! and a per-request compute budget ([`ServiceConfig::request_budget`])
//! checked at the mapping pipeline's phase boundaries — an oversized
//! mapping job fails fast with `deadline_exceeded` instead of pinning a
//! worker. Handlers run under `catch_unwind`: a library panic becomes a
//! structured `internal` error, the message lands in the diagnostics ring
//! buffer, and the worker lives on.
//!
//! # Result cache & request batching
//!
//! Every parallel path in the library is bit-identical to its
//! sequential counterpart, so a `map` reply is a pure function of the
//! request content. The service exploits that with a sharded,
//! capacity-bounded LRU **result cache** keyed on a canonical
//! fingerprint of the full request identity
//! ([`crate::util::fingerprint`]) — task coordinates/weights/edges,
//! allocation (including heterogeneous node sizes), topology,
//! objective, NUMA, hier and coarsen config all feed the key; `"cache"`
//! and `"profile"` do not. A repeated request is answered from memory;
//! concurrent identical requests are **single-flighted** — one leader
//! computes, followers wait and receive the same bytes. Only `ok:true`
//! replies are stored; a leader that fails (panic, deadline) un-poisons
//! the entry and followers get a retryable-by-resubmit `internal` /
//! `deadline_exceeded` error, never a poisoned reply. Cached replies
//! are bit-identical to cold execution at every worker count. Sizing:
//! [`ServiceConfig::cache_capacity`] (0 disables),
//! [`ServiceConfig::cache_shards`]. Per-request opt-out:
//! `"cache":false`. `"profile":true` also bypasses (a trace id is
//! per-execution).
//!
//! **Batching** ([`ServiceConfig::batch_window`], default off):
//! compatible small hierarchical `map` requests — same
//! allocation/topology/config fingerprint, different task sets, at most
//! [`ServiceConfig::batch_max_tasks`] tasks each — arriving within the
//! window are queued and fanned through **one** shared-setup sweep
//! invocation ([`crate::hier::map_hierarchical_batch`]): the node-level
//! allocation, router table and rotation partitions are prepared once
//! and reused across the group. Each caller still receives exactly the
//! reply a solo run would have produced — batching is a
//! setup-amortization, never a result change. Per flush of `n` jobs the
//! `coalesced` counter grows by `n-1`, so
//! `flushes + coalesced == jobs` always reconciles.
//!
//! Both stages are observable: `cache.lookup` / `cache.insert` /
//! `batch.flush` spans, `service.cache.*` / `service.batch.*` metrics
//! counters, and `"cache"` / `"batch"` sections in `{"op":"stats"}`.
//!
//! # Error taxonomy
//!
//! Every failure is `{"ok":false,"error":{"kind":...,"message":...,
//! "retryable":...}}`; see [`ErrorKind`]:
//!
//! | kind                | retryable | meaning                                    |
//! |---------------------|-----------|--------------------------------------------|
//! | `invalid_request`   | no        | malformed JSON / fields / values / payload |
//! | `overloaded`        | **yes**   | queue full, shed; carries `retry_after_ms` |
//! | `deadline_exceeded` | no        | compute budget expired at a phase boundary |
//! | `shutting_down`     | **yes**   | service draining; retry against a replica  |
//! | `internal`          | no        | handler panic (library bug, logged)        |
//!
//! `retry_after_ms` appears only on `overloaded` replies and is the
//! server's backpressure hint; [`request_with_retry`] honors it as the
//! floor of its exponential-backoff delay.
//!
//! # Stats
//!
//! `{"op":"stats"}` returns service telemetry:
//! ```json
//! {"ok":true,"version":"0.1.0","uptime_s":X,
//!  "accepted":N,"completed":N,"shed":N,"panics":N,"active":N,
//!  "events_dropped":N,
//!  "errors":{"invalid_request":N,"overloaded":N,...},
//!  "ops":{"map":{"count":N,"total_us":N,"max_us":N,"mean_us":X,
//!                "p50_us":N,"p95_us":N,"p99_us":N},...},
//!  "recent":["panic in op ...","drain deadline expired; ..."],
//!  "pool":{"workers":N,"queue_capacity":N,"queue_depth":N,
//!          "active_connections":N}}
//! ```
//!
//! | field            | meaning                                               |
//! |------------------|-------------------------------------------------------|
//! | `version`        | crate version (`CARGO_PKG_VERSION`) of the build      |
//! | `uptime_s`       | seconds since this `Diagnostics` instance started     |
//! | `accepted`       | connections accepted by the listener                  |
//! | `completed`      | requests answered (success or error)                  |
//! | `shed`/`panics`  | queue-full refusals / caught handler panics           |
//! | `active`         | requests currently inside a handler                   |
//! | `events_dropped` | `recent` ring evictions since start (counted on wrap) |
//! | `errors`         | error replies by kind                                 |
//! | `ops.<op>`       | per-op latency histogram: exact `count`/`total_us`/   |
//! |                  | `max_us`/`mean_us` plus log2-bucketed `p50_us`/       |
//! |                  | `p95_us`/`p99_us` (≤2× overestimates, clamped to max) |
//! | `recent`         | last 64 noteworthy events (panics, force-closes)      |
//! | `pool`           | worker-pool view (attached when the request arrives   |
//! |                  | through the service; direct [`handle_request`] calls  |
//! |                  | have no pool to report)                               |
//! | `cache`          | result-cache counters (present when the cache is on): |
//! |                  | `capacity`/`shards`/`entries` plus monotonic `hits`/  |
//! |                  | `misses`/`coalesced`/`inserts`/`evictions`/`bypass`/  |
//! |                  | `leader_failures`                                     |
//! | `batch`          | batching counters (present when batching is on):      |
//! |                  | `window_ms`/`max_tasks` plus monotonic `jobs`/        |
//! |                  | `flushes`/`coalesced`/`leader_failures`; the invariant|
//! |                  | `flushes + coalesced == jobs` always holds            |
//!
//! The pre-histogram fields (`count`/`total_us`/`max_us`/`mean_us` and
//! everything top-level) are unchanged, so existing consumers keep
//! working.
//!
//! # Observability
//!
//! Three tracing surfaces (see [`crate::obs`]):
//! * **`"profile": true`** on `map`/`eval` runs the handler under a
//!   fresh trace id and attaches `"trace_id"` plus
//!   `{"profile":{"total_us":N,"phases":[{"name":"hier.sweep",
//!   "elapsed_us":N,"node_score":X,"candidates":N},...]}}` — one entry
//!   per pipeline phase span (sweep, refinement, socket, placement,
//!   response evaluation) with its recorded fields; phase elapsed times
//!   sum to at most `total_us`.
//! * **`{"op":"trace"}`** returns the recent span forest from the global
//!   event ring (`"traces"`, populated while the global recorder is on),
//!   the ring's `"events_dropped"` count, and the metrics-registry
//!   snapshot.
//! * **`TASKMAP_TRACE=<path>`** makes [`Service::start`] enable the
//!   global recorder and stream every completed span/instant as JSONL
//!   convertible to `chrome://tracing`
//!   ([`crate::obs::trace::validate_jsonl`] checks the schema).
//!
//! # Shutdown
//!
//! [`Service::stop`] (and `Drop`) drains gracefully: stop accepting,
//! refuse queued-but-unserved connections with `shutting_down`, give
//! in-flight requests up to [`ServiceConfig::drain_timeout`] to finish,
//! then force-close the stragglers' sockets. The client-observable
//! invariant: every accepted connection is answered or closed within the
//! drain deadline.
//!
//! # Fault injection
//!
//! The handlers and lifecycle carry named failpoints
//! (`"service.handler"`, `"service.handler.panic"`, `"service.accept"`,
//! `"service.shutdown"`, `"service.cache.lookup"`,
//! `"service.cache.leader.panic"`) wired to the deterministic, seeded
//! [`crate::testutil::faults`] harness. They are inert unless a test
//! installs a [`FaultPlan`](crate::testutil::faults::FaultPlan) — the
//! chaos suite (`tests/chaos.rs`) uses them to prove the invariants above
//! under injected panics, stalls, and overload, bit-reproducibly at every
//! thread count.

mod batch;
mod cache;
mod client;
mod diagnostics;
mod errors;
mod handlers;
mod pool;

pub use batch::{BatchOutcome, Batcher};
pub use cache::{Flight, FlightOutcome, LeaderGuard, Lookup, MapCache};
pub use client::{request_with_retry, Client, RetryPolicy};
pub use diagnostics::{Diagnostics, PoolSnapshot};
pub use errors::{error_kind, error_message, error_retry_after_ms, ErrorKind, ServiceError};
pub use handlers::{handle_request, handle_request_with, RequestCtx};

use crate::par::Parallelism;
use crate::testutil::faults;
use pool::{write_reply, WorkerPool};
use std::net::{TcpListener, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// Tunables of the hardened service. `Default` is production-shaped;
/// tests shrink the limits to exercise the edges quickly.
#[derive(Clone, Debug)]
pub struct ServiceConfig {
    /// Worker threads. 0 means "use the [`crate::par`] budget"
    /// (`TASKMAP_THREADS` / available parallelism).
    pub workers: usize,
    /// Accepted connections waiting for a worker. Beyond this, new
    /// connections are shed with `overloaded`.
    pub queue_capacity: usize,
    /// Socket read timeout (one blocking read).
    pub read_timeout: Duration,
    /// Socket write timeout (one blocking write).
    pub write_timeout: Duration,
    /// Overall deadline for assembling one request frame — bounds trickle
    /// traffic that stays under `read_timeout` per byte.
    pub frame_timeout: Duration,
    /// Maximum request line size in bytes; larger frames are rejected
    /// without being buffered.
    pub max_payload: usize,
    /// Compute budget per request, checked at mapping phase boundaries.
    pub request_budget: Duration,
    /// Backpressure hint attached to `overloaded` replies.
    pub retry_after_ms: u64,
    /// Grace period for in-flight connections at shutdown before their
    /// sockets are force-closed.
    pub drain_timeout: Duration,
    /// Result-cache capacity in entries (`ok:true` map replies). 0
    /// disables the cache entirely. Replies served from the cache are
    /// bit-identical to cold execution, so the cache is on by default.
    pub cache_capacity: usize,
    /// Lock shards for the result cache (clamped to `[1, capacity]`).
    pub cache_shards: usize,
    /// Batching window for compatible small hierarchical `map`
    /// requests. `Duration::ZERO` (the default) disables batching —
    /// it trades up to one window of added latency for shared-setup
    /// throughput, so it is opt-in.
    pub batch_window: Duration,
    /// Largest task count eligible for batching; bigger requests run
    /// solo (their setup cost is already amortized by their size).
    pub batch_max_tasks: usize,
}

impl Default for ServiceConfig {
    fn default() -> ServiceConfig {
        ServiceConfig {
            workers: 0,
            queue_capacity: 64,
            read_timeout: Duration::from_secs(30),
            write_timeout: Duration::from_secs(10),
            frame_timeout: Duration::from_secs(60),
            max_payload: 8 << 20,
            request_budget: Duration::from_secs(30),
            retry_after_ms: 50,
            drain_timeout: Duration::from_secs(5),
            cache_capacity: 256,
            cache_shards: 8,
            batch_window: Duration::ZERO,
            batch_max_tasks: 2048,
        }
    }
}

impl ServiceConfig {
    /// The actual worker count: an explicit setting wins (minimum 1),
    /// otherwise the shared `par` thread budget.
    pub fn resolved_workers(&self) -> usize {
        if self.workers == 0 {
            Parallelism::auto().num_threads().max(1)
        } else {
            self.workers
        }
    }
}

/// Server handle: the bound address plus the accept loop and worker pool.
/// Dropping it (or calling [`Service::stop`]) drains gracefully.
pub struct Service {
    pub addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    accept: Option<std::thread::JoinHandle<()>>,
    pool: Option<WorkerPool>,
    diag: Arc<Diagnostics>,
    cache: Option<Arc<MapCache>>,
    batcher: Option<Arc<Batcher>>,
}

impl Service {
    /// Bind and serve with the default config. Pass port 0 for an
    /// ephemeral port (tests).
    pub fn start<A: ToSocketAddrs>(addr: A) -> std::io::Result<Service> {
        Service::start_with(addr, ServiceConfig::default())
    }

    /// Bind and serve with an explicit config.
    pub fn start_with<A: ToSocketAddrs>(addr: A, cfg: ServiceConfig) -> std::io::Result<Service> {
        // TASKMAP_TRACE=<path>: install the JSONL trace sink and turn the
        // global recorder on for the service's lifetime (idempotent).
        crate::obs::init_from_env();
        let listener = TcpListener::bind(addr)?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let diag = Arc::new(Diagnostics::new());
        let cache = (cfg.cache_capacity > 0)
            .then(|| Arc::new(MapCache::new(cfg.cache_capacity, cfg.cache_shards)));
        let batcher = (!cfg.batch_window.is_zero())
            .then(|| Arc::new(Batcher::new(cfg.batch_window, cfg.batch_max_tasks)));
        let pool =
            WorkerPool::start(cfg.clone(), Arc::clone(&diag), cache.clone(), batcher.clone());
        let shared = pool.shared();
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let diag2 = Arc::clone(&diag);
        let accept = std::thread::spawn(move || {
            // Idle backoff: start responsive (1 ms), double up to 50 ms
            // while no clients arrive, reset on every accept. Bounds both
            // the idle CPU burn and the shutdown-flag poll latency.
            let mut idle_ms = 1u64;
            while !stop2.load(Ordering::Relaxed) {
                match listener.accept() {
                    Ok((stream, _)) => {
                        idle_ms = 1;
                        faults::failpoint("service.accept");
                        diag2.record_accepted();
                        if let Err(mut stream) = shared.try_dispatch(stream) {
                            // Queue full: shed right here, on the accept
                            // thread — a cheap write, never a spawn.
                            diag2.record_shed();
                            let refusal = ServiceError::overloaded(cfg.retry_after_ms).to_json();
                            let _ = stream.set_write_timeout(Some(cfg.write_timeout));
                            let _ = write_reply(&mut stream, &refusal);
                            diag2.record_reply("(shed)", &refusal, Duration::ZERO);
                        }
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(Duration::from_millis(idle_ms));
                        idle_ms = (idle_ms * 2).min(50);
                    }
                    Err(_) => break,
                }
            }
        });
        Ok(Service {
            addr,
            stop,
            accept: Some(accept),
            pool: Some(pool),
            diag,
            cache,
            batcher,
        })
    }

    /// A point-in-time stats snapshot (same schema as `{"op":"stats"}`).
    pub fn stats(&self) -> crate::testutil::json::Json {
        let pool = self.pool.as_ref().map(|p| p.shared().snapshot());
        let mut resp = self.diag.snapshot_json(pool);
        attach_cache_stats(&mut resp, self.cache.as_deref(), self.batcher.as_deref());
        resp
    }

    /// Graceful shutdown: stop accepting, drain in-flight work up to
    /// [`ServiceConfig::drain_timeout`], force-close stragglers, join.
    pub fn stop(mut self) {
        self.shutdown_impl();
    }

    fn shutdown_impl(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        if let Some(pool) = self.pool.take() {
            pool.drain();
        }
    }
}

impl Drop for Service {
    fn drop(&mut self) {
        self.shutdown_impl();
    }
}

/// Merge `"cache"` / `"batch"` sections into a stats reply. Absent
/// stages contribute nothing, so consumers can feature-detect by key.
fn attach_cache_stats(
    resp: &mut crate::testutil::json::Json,
    cache: Option<&MapCache>,
    batcher: Option<&Batcher>,
) {
    use crate::testutil::json::Json;
    if let Json::Obj(map) = resp {
        if let Some(c) = cache {
            map.insert("cache".to_string(), c.stats_json());
        }
        if let Some(b) = batcher {
            map.insert("batch".to_string(), b.stats_json());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::json::Json;
    use std::io::{BufRead, BufReader, Write};
    use std::net::TcpStream;

    #[test]
    fn end_to_end_over_tcp() {
        let svc = Service::start("127.0.0.1:0").unwrap();
        let mut client = Client::connect(svc.addr).unwrap();
        // 1D lines: tasks 0..8 left to right, procs 0..8 right to left.
        let tcoords: Vec<Vec<f64>> = (0..8).map(|i| vec![i as f64]).collect();
        let pcoords: Vec<Vec<f64>> = (0..8).map(|i| vec![(7 - i) as f64]).collect();
        let m = client
            .map(&tcoords, &pcoords, crate::sfc::PartOrdering::FZ)
            .unwrap();
        assert_eq!(m, vec![7, 6, 5, 4, 3, 2, 1, 0]);
        svc.stop();
    }

    #[test]
    fn stats_report_pool_shape_over_tcp() {
        let svc = Service::start_with(
            "127.0.0.1:0",
            ServiceConfig {
                workers: 2,
                queue_capacity: 3,
                ..ServiceConfig::default()
            },
        )
        .unwrap();
        let mut client = Client::connect(svc.addr).unwrap();
        let ping = Json::obj(vec![("op", Json::Str("ping".into()))]);
        assert_eq!(
            client.request(&ping).unwrap().get("ok"),
            Some(&Json::Bool(true))
        );
        let stats = client
            .request(&Json::obj(vec![("op", Json::Str("stats".into()))]))
            .unwrap();
        assert_eq!(stats.get("ok"), Some(&Json::Bool(true)), "{stats:?}");
        let pool = stats.get("pool").expect("service stats carry a pool view");
        assert_eq!(pool.get("workers").and_then(|v| v.as_f64()), Some(2.0));
        assert_eq!(
            pool.get("queue_capacity").and_then(|v| v.as_f64()),
            Some(3.0)
        );
        assert!(stats.get("accepted").and_then(|v| v.as_f64()).unwrap() >= 1.0);
        // The in-process snapshot agrees.
        let snap = svc.stats();
        assert_eq!(snap.get("ok"), Some(&Json::Bool(true)));
        assert!(snap.get("pool").is_some());
        svc.stop();
    }

    #[test]
    fn oversized_payload_is_rejected_with_structured_error() {
        let svc = Service::start_with(
            "127.0.0.1:0",
            ServiceConfig {
                workers: 1,
                max_payload: 256,
                ..ServiceConfig::default()
            },
        )
        .unwrap();
        let mut stream = TcpStream::connect(svc.addr).unwrap();
        let big = format!("{{\"op\":\"map\",\"x\":\"{}\"}}\n", "y".repeat(1024));
        stream.write_all(big.as_bytes()).unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let resp = Json::parse(line.trim()).unwrap();
        assert_eq!(error_kind(&resp), Some(ErrorKind::InvalidRequest), "{resp:?}");
        assert!(
            error_message(&resp).unwrap().contains("payload limit"),
            "{resp:?}"
        );
        // The server closes after an oversized frame.
        line.clear();
        assert_eq!(reader.read_line(&mut line).unwrap(), 0);
        svc.stop();
    }

    #[test]
    fn stopped_service_refuses_then_closes() {
        let svc = Service::start("127.0.0.1:0").unwrap();
        let addr = svc.addr;
        let mut client = Client::connect(addr).unwrap();
        let ping = Json::obj(vec![("op", Json::Str("ping".into()))]);
        assert_eq!(
            client.request(&ping).unwrap().get("ok"),
            Some(&Json::Bool(true))
        );
        svc.stop();
        // The connected client gets shutting_down or a closed socket —
        // never silence: stop() already answered or closed every
        // connection before returning.
        match client.request(&ping) {
            Ok(resp) => assert_eq!(error_kind(&resp), Some(ErrorKind::ShuttingDown), "{resp:?}"),
            Err(e) => assert!(
                matches!(
                    e.kind(),
                    std::io::ErrorKind::UnexpectedEof
                        | std::io::ErrorKind::BrokenPipe
                        | std::io::ErrorKind::ConnectionReset
                        | std::io::ErrorKind::ConnectionAborted
                ),
                "{e:?}"
            ),
        }
    }
}
