//! Service observability: counters, per-op latency histograms
//! ([`crate::obs::Histogram`] — log2 µs buckets with exact
//! count/total/max plus p50/p95/p99), and a ring buffer of recent
//! noteworthy events (panic messages, force-closes) that counts — never
//! silently drops — evictions, surfaced to clients via `{"op":"stats"}`.
//!
//! Everything here is designed to be written from many worker threads at
//! once: plain counters are relaxed atomics; the ring buffer and the
//! per-op latency table take short mutexes only on the paths that already
//! did real work (a completed request, a panic), never on the accept fast
//! path.

use super::errors::ErrorKind;
use crate::obs::Histogram;
use crate::testutil::json::Json;
use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// Most recent events kept for `stats.recent`.
const RING_CAPACITY: usize = 64;

/// A point-in-time view of the worker pool, attached to `stats` replies.
#[derive(Clone, Copy, Debug)]
pub struct PoolSnapshot {
    pub workers: usize,
    pub queue_capacity: usize,
    pub queue_depth: usize,
    pub active_connections: usize,
}

/// Shared service telemetry. One instance per [`super::Service`]; handlers
/// reach it through [`super::handlers::RequestCtx`].
pub struct Diagnostics {
    /// Connections accepted by the listener.
    accepted: AtomicU64,
    /// Requests answered (any reply, success or error).
    completed: AtomicU64,
    /// Connections shed because the queue was full.
    shed: AtomicU64,
    /// Handler panics caught and converted to `internal` errors.
    panics: AtomicU64,
    /// Requests currently inside a handler.
    active: AtomicU64,
    /// Events evicted from the `recent` ring since start (wraps are
    /// counted, never silent).
    events_dropped: AtomicU64,
    /// Error replies by kind (indexed by [`ErrorKind::index`]).
    errors: [AtomicU64; 5],
    recent: Mutex<VecDeque<String>>,
    /// Per-op latency histograms (log2 µs buckets; exact count/sum/max).
    ops: Mutex<BTreeMap<String, Histogram>>,
    started: Instant,
}

impl Default for Diagnostics {
    fn default() -> Diagnostics {
        Diagnostics {
            accepted: AtomicU64::new(0),
            completed: AtomicU64::new(0),
            shed: AtomicU64::new(0),
            panics: AtomicU64::new(0),
            active: AtomicU64::new(0),
            events_dropped: AtomicU64::new(0),
            errors: Default::default(),
            recent: Mutex::new(VecDeque::new()),
            ops: Mutex::new(BTreeMap::new()),
            started: Instant::now(),
        }
    }
}

/// Lock a mutex, tolerating poison: diagnostics must stay usable after a
/// panic elsewhere — that is exactly when they matter most.
fn lock_ok<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    match m.lock() {
        Ok(g) => g,
        Err(poisoned) => poisoned.into_inner(),
    }
}

impl Diagnostics {
    pub fn new() -> Diagnostics {
        Diagnostics::default()
    }

    pub fn record_accepted(&self) {
        self.accepted.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_shed(&self) {
        self.shed.fetch_add(1, Ordering::Relaxed);
    }

    pub fn begin_request(&self) {
        self.active.fetch_add(1, Ordering::Relaxed);
    }

    pub fn end_request(&self) {
        self.active.fetch_sub(1, Ordering::Relaxed);
    }

    /// Log a noteworthy event into the bounded ring buffer, counting the
    /// eviction when the ring wraps.
    pub fn record_event(&self, event: &str) {
        let mut ring = lock_ok(&self.recent);
        if ring.len() == RING_CAPACITY {
            ring.pop_front();
            self.events_dropped.fetch_add(1, Ordering::Relaxed);
        }
        ring.push_back(event.to_string());
    }

    /// A handler panicked: count it and keep the message for `stats`.
    pub fn record_panic(&self, op: &str, msg: &str) {
        self.panics.fetch_add(1, Ordering::Relaxed);
        self.record_event(&format!("panic in op \"{op}\": {msg}"));
    }

    /// A reply went out: bump the completion counter, the per-kind error
    /// counter if it is an error, and the op's latency aggregate.
    pub fn record_reply(&self, op: &str, resp: &Json, elapsed: Duration) {
        self.completed.fetch_add(1, Ordering::Relaxed);
        if let Some(kind) = super::errors::error_kind(resp) {
            self.errors[kind.index()].fetch_add(1, Ordering::Relaxed);
        }
        let us = elapsed.as_micros().min(u128::from(u64::MAX)) as u64;
        lock_ok(&self.ops).entry(op.to_string()).or_default().record(us);
    }

    pub fn panic_count(&self) -> u64 {
        self.panics.load(Ordering::Relaxed)
    }

    pub fn shed_count(&self) -> u64 {
        self.shed.load(Ordering::Relaxed)
    }

    pub fn completed_count(&self) -> u64 {
        self.completed.load(Ordering::Relaxed)
    }

    /// The `{"op":"stats"}` reply (schema documented in the module docs of
    /// [`super`]).
    pub fn snapshot_json(&self, pool: Option<PoolSnapshot>) -> Json {
        let errors = Json::obj(
            ErrorKind::ALL
                .iter()
                .map(|k| (k.name(), Json::Num(self.errors[k.index()].load(Ordering::Relaxed) as f64)))
                .collect(),
        );
        let ops = Json::Obj(
            lock_ok(&self.ops)
                .iter()
                .map(|(op, h)| {
                    (
                        op.clone(),
                        Json::obj(vec![
                            ("count", Json::Num(h.count() as f64)),
                            ("total_us", Json::Num(h.sum() as f64)),
                            ("max_us", Json::Num(h.max() as f64)),
                            ("mean_us", Json::Num(h.mean())),
                            ("p50_us", Json::Num(h.quantile(0.50) as f64)),
                            ("p95_us", Json::Num(h.quantile(0.95) as f64)),
                            ("p99_us", Json::Num(h.quantile(0.99) as f64)),
                        ]),
                    )
                })
                .collect(),
        );
        let recent = Json::Arr(
            lock_ok(&self.recent)
                .iter()
                .map(|e| Json::Str(e.clone()))
                .collect(),
        );
        let mut fields = vec![
            ("ok", Json::Bool(true)),
            ("version", Json::Str(env!("CARGO_PKG_VERSION").to_string())),
            ("uptime_s", Json::Num(self.started.elapsed().as_secs_f64())),
            ("accepted", Json::Num(self.accepted.load(Ordering::Relaxed) as f64)),
            ("completed", Json::Num(self.completed.load(Ordering::Relaxed) as f64)),
            ("shed", Json::Num(self.shed.load(Ordering::Relaxed) as f64)),
            ("panics", Json::Num(self.panics.load(Ordering::Relaxed) as f64)),
            ("active", Json::Num(self.active.load(Ordering::Relaxed) as f64)),
            (
                "events_dropped",
                Json::Num(self.events_dropped.load(Ordering::Relaxed) as f64),
            ),
            ("errors", errors),
            ("ops", ops),
            ("recent", recent),
        ];
        if let Some(p) = pool {
            fields.push((
                "pool",
                Json::obj(vec![
                    ("workers", Json::Num(p.workers as f64)),
                    ("queue_capacity", Json::Num(p.queue_capacity as f64)),
                    ("queue_depth", Json::Num(p.queue_depth as f64)),
                    ("active_connections", Json::Num(p.active_connections as f64)),
                ]),
            ));
        }
        Json::obj(fields)
    }
}

#[cfg(test)]
mod tests {
    use super::super::errors::ServiceError;
    use super::*;

    #[test]
    fn counters_flow_into_snapshot() {
        let d = Diagnostics::new();
        d.record_accepted();
        d.record_accepted();
        d.record_shed();
        d.record_reply("ping", &Json::obj(vec![("ok", Json::Bool(true))]), Duration::from_micros(10));
        d.record_reply(
            "map",
            &ServiceError::internal("boom").to_json(),
            Duration::from_micros(30),
        );
        d.record_panic("map", "boom");
        let snap = d.snapshot_json(None);
        assert_eq!(snap.get("accepted").and_then(|v| v.as_f64()), Some(2.0));
        assert_eq!(snap.get("shed").and_then(|v| v.as_f64()), Some(1.0));
        assert_eq!(snap.get("completed").and_then(|v| v.as_f64()), Some(2.0));
        assert_eq!(snap.get("panics").and_then(|v| v.as_f64()), Some(1.0));
        let errs = snap.get("errors").unwrap();
        assert_eq!(errs.get("internal").and_then(|v| v.as_f64()), Some(1.0));
        assert_eq!(errs.get("overloaded").and_then(|v| v.as_f64()), Some(0.0));
        let ops = snap.get("ops").unwrap();
        assert_eq!(
            ops.get("ping").and_then(|o| o.get("count")).and_then(|v| v.as_f64()),
            Some(1.0)
        );
        assert_eq!(
            ops.get("map").and_then(|o| o.get("max_us")).and_then(|v| v.as_f64()),
            Some(30.0)
        );
        let recent = snap.get("recent").unwrap().as_arr().unwrap();
        assert_eq!(recent.len(), 1);
        assert!(recent[0].as_str().unwrap().contains("boom"));
    }

    #[test]
    fn ring_buffer_is_bounded() {
        let d = Diagnostics::new();
        for i in 0..(RING_CAPACITY + 10) {
            d.record_event(&format!("event {i}"));
        }
        let snap = d.snapshot_json(None);
        let recent = snap.get("recent").unwrap().as_arr().unwrap();
        assert_eq!(recent.len(), RING_CAPACITY);
        // Oldest entries were evicted — and the drops were counted.
        assert_eq!(recent[0].as_str(), Some("event 10"));
        assert_eq!(snap.get("events_dropped").and_then(|v| v.as_f64()), Some(10.0));
    }

    #[test]
    fn op_latency_quantiles_and_identity_fields() {
        let d = Diagnostics::new();
        let ok = Json::obj(vec![("ok", Json::Bool(true))]);
        for us in [10u64, 20, 30, 40, 5000] {
            d.record_reply("map", &ok, Duration::from_micros(us));
        }
        let snap = d.snapshot_json(None);
        let map = snap.get("ops").and_then(|o| o.get("map")).unwrap();
        let f = |k: &str| map.get(k).and_then(|v| v.as_f64()).unwrap();
        assert_eq!(f("count"), 5.0);
        assert_eq!(f("total_us"), 5100.0);
        assert_eq!(f("max_us"), 5000.0);
        assert_eq!(f("mean_us"), 1020.0);
        // Log-bucket quantiles: upper bound of the rank's bucket, within
        // 2x of the true value and never above the observed max.
        assert!(f("p50_us") >= 30.0 && f("p50_us") <= 60.0);
        assert!(f("p99_us") >= 5000.0 && f("p99_us") <= 8192.0);
        assert_eq!(snap.get("version").and_then(|v| v.as_str()), Some(env!("CARGO_PKG_VERSION")));
        assert!(snap.get("uptime_s").and_then(|v| v.as_f64()).unwrap() >= 0.0);
        assert_eq!(snap.get("events_dropped").and_then(|v| v.as_f64()), Some(0.0));
    }

    #[test]
    fn pool_snapshot_is_reported() {
        let d = Diagnostics::new();
        let snap = d.snapshot_json(Some(PoolSnapshot {
            workers: 4,
            queue_capacity: 16,
            queue_depth: 3,
            active_connections: 2,
        }));
        let pool = snap.get("pool").unwrap();
        assert_eq!(pool.get("workers").and_then(|v| v.as_f64()), Some(4.0));
        assert_eq!(pool.get("queue_depth").and_then(|v| v.as_f64()), Some(3.0));
    }
}
