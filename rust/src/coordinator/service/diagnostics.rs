//! Service observability: counters, per-op latency summaries, and a ring
//! buffer of recent noteworthy events (panic messages, force-closes),
//! surfaced to clients via `{"op":"stats"}`.
//!
//! Everything here is designed to be written from many worker threads at
//! once: plain counters are relaxed atomics; the ring buffer and the
//! per-op latency table take short mutexes only on the paths that already
//! did real work (a completed request, a panic), never on the accept fast
//! path.

use super::errors::ErrorKind;
use crate::testutil::json::Json;
use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

/// Most recent events kept for `stats.recent`.
const RING_CAPACITY: usize = 64;

/// Per-op latency aggregate (microseconds).
#[derive(Clone, Copy, Debug, Default)]
struct OpStat {
    count: u64,
    total_us: u64,
    max_us: u64,
}

/// A point-in-time view of the worker pool, attached to `stats` replies.
#[derive(Clone, Copy, Debug)]
pub struct PoolSnapshot {
    pub workers: usize,
    pub queue_capacity: usize,
    pub queue_depth: usize,
    pub active_connections: usize,
}

/// Shared service telemetry. One instance per [`super::Service`]; handlers
/// reach it through [`super::handlers::RequestCtx`].
#[derive(Default)]
pub struct Diagnostics {
    /// Connections accepted by the listener.
    accepted: AtomicU64,
    /// Requests answered (any reply, success or error).
    completed: AtomicU64,
    /// Connections shed because the queue was full.
    shed: AtomicU64,
    /// Handler panics caught and converted to `internal` errors.
    panics: AtomicU64,
    /// Requests currently inside a handler.
    active: AtomicU64,
    /// Error replies by kind (indexed by [`ErrorKind::index`]).
    errors: [AtomicU64; 5],
    recent: Mutex<VecDeque<String>>,
    ops: Mutex<BTreeMap<String, OpStat>>,
}

/// Lock a mutex, tolerating poison: diagnostics must stay usable after a
/// panic elsewhere — that is exactly when they matter most.
fn lock_ok<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    match m.lock() {
        Ok(g) => g,
        Err(poisoned) => poisoned.into_inner(),
    }
}

impl Diagnostics {
    pub fn new() -> Diagnostics {
        Diagnostics::default()
    }

    pub fn record_accepted(&self) {
        self.accepted.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_shed(&self) {
        self.shed.fetch_add(1, Ordering::Relaxed);
    }

    pub fn begin_request(&self) {
        self.active.fetch_add(1, Ordering::Relaxed);
    }

    pub fn end_request(&self) {
        self.active.fetch_sub(1, Ordering::Relaxed);
    }

    /// Log a noteworthy event into the bounded ring buffer.
    pub fn record_event(&self, event: &str) {
        let mut ring = lock_ok(&self.recent);
        if ring.len() == RING_CAPACITY {
            ring.pop_front();
        }
        ring.push_back(event.to_string());
    }

    /// A handler panicked: count it and keep the message for `stats`.
    pub fn record_panic(&self, op: &str, msg: &str) {
        self.panics.fetch_add(1, Ordering::Relaxed);
        self.record_event(&format!("panic in op \"{op}\": {msg}"));
    }

    /// A reply went out: bump the completion counter, the per-kind error
    /// counter if it is an error, and the op's latency aggregate.
    pub fn record_reply(&self, op: &str, resp: &Json, elapsed: Duration) {
        self.completed.fetch_add(1, Ordering::Relaxed);
        if let Some(kind) = super::errors::error_kind(resp) {
            self.errors[kind.index()].fetch_add(1, Ordering::Relaxed);
        }
        let us = elapsed.as_micros().min(u128::from(u64::MAX)) as u64;
        let mut ops = lock_ok(&self.ops);
        let stat = ops.entry(op.to_string()).or_default();
        stat.count += 1;
        stat.total_us = stat.total_us.saturating_add(us);
        stat.max_us = stat.max_us.max(us);
    }

    pub fn panic_count(&self) -> u64 {
        self.panics.load(Ordering::Relaxed)
    }

    pub fn shed_count(&self) -> u64 {
        self.shed.load(Ordering::Relaxed)
    }

    pub fn completed_count(&self) -> u64 {
        self.completed.load(Ordering::Relaxed)
    }

    /// The `{"op":"stats"}` reply (schema documented in the module docs of
    /// [`super`]).
    pub fn snapshot_json(&self, pool: Option<PoolSnapshot>) -> Json {
        let errors = Json::obj(
            ErrorKind::ALL
                .iter()
                .map(|k| (k.name(), Json::Num(self.errors[k.index()].load(Ordering::Relaxed) as f64)))
                .collect(),
        );
        let ops = Json::Obj(
            lock_ok(&self.ops)
                .iter()
                .map(|(op, s)| {
                    let mean = if s.count > 0 {
                        s.total_us as f64 / s.count as f64
                    } else {
                        0.0
                    };
                    (
                        op.clone(),
                        Json::obj(vec![
                            ("count", Json::Num(s.count as f64)),
                            ("total_us", Json::Num(s.total_us as f64)),
                            ("max_us", Json::Num(s.max_us as f64)),
                            ("mean_us", Json::Num(mean)),
                        ]),
                    )
                })
                .collect(),
        );
        let recent = Json::Arr(
            lock_ok(&self.recent)
                .iter()
                .map(|e| Json::Str(e.clone()))
                .collect(),
        );
        let mut fields = vec![
            ("ok", Json::Bool(true)),
            ("accepted", Json::Num(self.accepted.load(Ordering::Relaxed) as f64)),
            ("completed", Json::Num(self.completed.load(Ordering::Relaxed) as f64)),
            ("shed", Json::Num(self.shed.load(Ordering::Relaxed) as f64)),
            ("panics", Json::Num(self.panics.load(Ordering::Relaxed) as f64)),
            ("active", Json::Num(self.active.load(Ordering::Relaxed) as f64)),
            ("errors", errors),
            ("ops", ops),
            ("recent", recent),
        ];
        if let Some(p) = pool {
            fields.push((
                "pool",
                Json::obj(vec![
                    ("workers", Json::Num(p.workers as f64)),
                    ("queue_capacity", Json::Num(p.queue_capacity as f64)),
                    ("queue_depth", Json::Num(p.queue_depth as f64)),
                    ("active_connections", Json::Num(p.active_connections as f64)),
                ]),
            ));
        }
        Json::obj(fields)
    }
}

#[cfg(test)]
mod tests {
    use super::super::errors::ServiceError;
    use super::*;

    #[test]
    fn counters_flow_into_snapshot() {
        let d = Diagnostics::new();
        d.record_accepted();
        d.record_accepted();
        d.record_shed();
        d.record_reply("ping", &Json::obj(vec![("ok", Json::Bool(true))]), Duration::from_micros(10));
        d.record_reply(
            "map",
            &ServiceError::internal("boom").to_json(),
            Duration::from_micros(30),
        );
        d.record_panic("map", "boom");
        let snap = d.snapshot_json(None);
        assert_eq!(snap.get("accepted").and_then(|v| v.as_f64()), Some(2.0));
        assert_eq!(snap.get("shed").and_then(|v| v.as_f64()), Some(1.0));
        assert_eq!(snap.get("completed").and_then(|v| v.as_f64()), Some(2.0));
        assert_eq!(snap.get("panics").and_then(|v| v.as_f64()), Some(1.0));
        let errs = snap.get("errors").unwrap();
        assert_eq!(errs.get("internal").and_then(|v| v.as_f64()), Some(1.0));
        assert_eq!(errs.get("overloaded").and_then(|v| v.as_f64()), Some(0.0));
        let ops = snap.get("ops").unwrap();
        assert_eq!(
            ops.get("ping").and_then(|o| o.get("count")).and_then(|v| v.as_f64()),
            Some(1.0)
        );
        assert_eq!(
            ops.get("map").and_then(|o| o.get("max_us")).and_then(|v| v.as_f64()),
            Some(30.0)
        );
        let recent = snap.get("recent").unwrap().as_arr().unwrap();
        assert_eq!(recent.len(), 1);
        assert!(recent[0].as_str().unwrap().contains("boom"));
    }

    #[test]
    fn ring_buffer_is_bounded() {
        let d = Diagnostics::new();
        for i in 0..(RING_CAPACITY + 10) {
            d.record_event(&format!("event {i}"));
        }
        let snap = d.snapshot_json(None);
        let recent = snap.get("recent").unwrap().as_arr().unwrap();
        assert_eq!(recent.len(), RING_CAPACITY);
        // Oldest entries were evicted.
        assert_eq!(recent[0].as_str(), Some("event 10"));
    }

    #[test]
    fn pool_snapshot_is_reported() {
        let d = Diagnostics::new();
        let snap = d.snapshot_json(Some(PoolSnapshot {
            workers: 4,
            queue_capacity: 16,
            queue_depth: 3,
            active_connections: 2,
        }));
        let pool = snap.get("pool").unwrap();
        assert_eq!(pool.get("workers").and_then(|v| v.as_f64()), Some(4.0));
        assert_eq!(pool.get("queue_depth").and_then(|v| v.as_f64()), Some(3.0));
    }
}
