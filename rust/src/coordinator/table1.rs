//! Table 1: AverageHops of geometric mapping under different SFC orderings
//! (Hilbert, Z, FZ, MFZ) for td-dimensional stencil tasks one-to-one mapped
//! onto pd-dimensional block-allocated nodes, for mesh->mesh, mesh->torus,
//! and torus->torus connectivity.

use super::report::{f2, Table};
use super::Ctx;
use crate::apps::stencil::stencil_graph;
use crate::machine::{Allocation, Torus};
use crate::mapping::{map_tasks, MapConfig};
use crate::metrics::eval_hops;
use crate::sfc::PartOrdering;

/// The (num_tasks, pd, td) rows of the paper's Table 1.
pub const PAPER_ROWS: &[(usize, usize, usize)] = &[
    (262_144, 1, 2),
    (32_768, 1, 3),
    (1_048_576, 1, 4),
    (32_768, 1, 5),
    (262_144, 1, 6),
    (65_536, 1, 8),
    (262_144, 2, 1),
    (262_144, 2, 3),
    (1_048_576, 2, 4),
    (1_048_576, 2, 5),
    (262_144, 2, 6),
    (65_536, 2, 8),
    (32_768, 3, 1),
    (262_144, 3, 2),
    (4_096, 3, 4),
    (32_768, 3, 5),
    (262_144, 3, 6),
    (262_144, 3, 9),
    (1_048_576, 4, 1),
    (1_048_576, 4, 2),
    (4_096, 4, 3),
    (1_048_576, 4, 5),
    (4_096, 4, 6),
    (65_536, 4, 8),
    (32_768, 5, 1),
    (1_048_576, 5, 2),
    (32_768, 5, 3),
    (1_048_576, 5, 4),
    (1_048_576, 5, 10),
    (262_144, 6, 1),
    (262_144, 6, 2),
    (262_144, 6, 3),
    (4_096, 6, 4),
    (262_144, 6, 9),
    (65_536, 8, 1),
    (65_536, 8, 2),
    (65_536, 8, 4),
    (262_144, 9, 1),
    (262_144, 9, 2),
    (262_144, 9, 3),
    (262_144, 9, 6),
    (1_048_576, 10, 1),
    (1_048_576, 10, 2),
    (1_048_576, 10, 4),
    (1_048_576, 10, 5),
];

/// Distribute `l` total log2-extent over `d` dimensions as evenly as
/// possible (first `l mod d` dims get one extra bit).
pub fn grid_dims(l: u32, d: usize) -> Vec<usize> {
    let base = l as usize / d;
    let extra = l as usize % d;
    (0..d)
        .map(|k| 1usize << (base + usize::from(k < extra)))
        .collect()
}

/// Connectivity of tasks and nodes for one column group.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Connectivity {
    MeshToMesh,
    MeshToTorus,
    TorusToTorus,
}

impl Connectivity {
    pub const ALL: [Connectivity; 3] = [
        Connectivity::MeshToMesh,
        Connectivity::MeshToTorus,
        Connectivity::TorusToTorus,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            Connectivity::MeshToMesh => "MeshToMesh",
            Connectivity::MeshToTorus => "MeshToTorus",
            Connectivity::TorusToTorus => "TorusToTorus",
        }
    }

    fn tasks_torus(&self) -> bool {
        matches!(self, Connectivity::TorusToTorus)
    }

    fn nodes_torus(&self) -> bool {
        !matches!(self, Connectivity::MeshToMesh)
    }
}

/// Compute AverageHops for one (size, pd, td, connectivity, ordering) cell.
pub fn average_hops_cell(
    num_tasks: usize,
    pd: usize,
    td: usize,
    conn: Connectivity,
    ordering: PartOrdering,
) -> f64 {
    let l = num_tasks.trailing_zeros();
    assert_eq!(1usize << l, num_tasks, "Table 1 sizes are powers of two");
    let tdims = grid_dims(l, td);
    let pdims = grid_dims(l, pd);
    let graph = stencil_graph(&tdims, conn.tasks_torus(), 1.0);
    let torus = if conn.nodes_torus() {
        Torus::torus(&pdims)
    } else {
        Torus::mesh(&pdims)
    };
    let n = torus.num_routers();
    let alloc = Allocation {
        machine: torus.into(),
        core_router: (0..n as u32).collect(),
        core_node: (0..n as u32).collect(),
        ranks_per_node: 1,
    };
    // MFZ: tasks numbered MFZ, nodes FZ — the paper applies the
    // modification to one coordinate set only (Section 4.3), and only when
    // pd is a multiple of td (otherwise MFZ == FZ).
    let cfg = match ordering {
        PartOrdering::MFZ => MapConfig {
            task_ordering: PartOrdering::MFZ,
            proc_ordering: PartOrdering::FZ,
            longest_dim: false,
            uneven_prime: false,
        },
        o => MapConfig {
            task_ordering: o,
            proc_ordering: o,
            longest_dim: false,
            uneven_prime: false,
        },
    };
    let mapping = map_tasks(&graph.coords, &alloc.proc_coords(), &cfg);
    eval_hops(&graph, &mapping, &alloc).avg_hops
}

/// Run Table 1. Small mode uses 2^12-task rows (same td/pd combinations);
/// full mode uses the paper's sizes.
pub fn run(ctx: &Ctx) -> Vec<Table> {
    let orderings = [
        PartOrdering::Hilbert,
        PartOrdering::Z,
        PartOrdering::FZ,
        PartOrdering::MFZ,
    ];
    let mut headers: Vec<String> = vec!["#task".into(), "pd".into(), "td".into()];
    for conn in Connectivity::ALL {
        for o in orderings {
            headers.push(format!("{}:{}", conn.name(), o.name()));
        }
    }
    let mut table = Table::new(
        if ctx.full {
            "Table 1: AverageHops by SFC ordering (paper sizes)"
        } else {
            "Table 1: AverageHops by SFC ordering (small sizes)"
        },
        &headers.iter().map(|s| s.as_str()).collect::<Vec<_>>(),
    );
    // Geomean accumulators per column.
    let ncols = Connectivity::ALL.len() * orderings.len();
    let mut log_sums = vec![0f64; ncols];
    let mut counts = vec![0usize; ncols];

    for &(paper_n, pd, td) in PAPER_ROWS {
        let n = if ctx.full {
            paper_n
        } else {
            1usize << 12 // 4096 tasks: every row fast, same structure
        };
        let mut row = vec![n.to_string(), pd.to_string(), td.to_string()];
        let mut col = 0usize;
        for conn in Connectivity::ALL {
            for o in orderings {
                // MFZ differs from FZ only when pd % td == 0 (paper note).
                let is_mfz_case = pd % td == 0 && pd != td;
                let v = if o == PartOrdering::MFZ && !is_mfz_case {
                    f64::NAN // shown blank, like the paper
                } else {
                    average_hops_cell(n, pd, td, conn, o)
                };
                if v.is_nan() {
                    row.push(String::new());
                } else {
                    row.push(f2(v));
                    log_sums[col] += v.max(1e-12).ln();
                    counts[col] += 1;
                }
                col += 1;
            }
        }
        table.push_row(row);
    }
    // Geomean row (per column, over the rows where the ordering applies).
    let mut geo_row = vec!["GEOMEAN".into(), String::new(), String::new()];
    for c in 0..ncols {
        geo_row.push(if counts[c] > 0 {
            f2((log_sums[c] / counts[c] as f64).exp())
        } else {
            String::new()
        });
    }
    table.push_row(geo_row);
    vec![table]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_dims_splits_bits() {
        assert_eq!(grid_dims(12, 3), vec![16, 16, 16]);
        assert_eq!(grid_dims(12, 5), vec![8, 8, 4, 4, 4]);
        assert_eq!(
            grid_dims(12, 5).iter().product::<usize>(),
            4096
        );
    }

    #[test]
    fn paper_rows_are_consistent_powers() {
        for &(n, pd, td) in PAPER_ROWS {
            let l = n.trailing_zeros() as usize;
            assert_eq!(1usize << l, n);
            // Paper sizes give equal extents along every dimension.
            assert_eq!(l % pd, 0, "row ({n},{pd},{td})");
            assert_eq!(l % td, 0, "row ({n},{pd},{td})");
        }
    }

    #[test]
    fn identity_case_td_eq_pd_unit_hops() {
        // td == pd == 2, same grid: Z mapping is identity-like; every
        // neighbor pair lands on adjacent nodes => AverageHops == 1.
        let v = average_hops_cell(256, 2, 2, Connectivity::MeshToMesh, PartOrdering::Z);
        assert!((v - 1.0).abs() < 1e-9, "got {v}");
    }

    #[test]
    fn hilbert_1d_tasks_unit_hops() {
        // Paper: Hilbert is continuous, so 1D tasks onto anything give
        // AverageHops 1.00.
        for pd in [2usize, 3] {
            let v = average_hops_cell(
                4096,
                pd,
                1,
                Connectivity::MeshToMesh,
                PartOrdering::Hilbert,
            );
            assert!((v - 1.0).abs() < 1e-9, "pd={pd}: {v}");
        }
    }

    #[test]
    fn z_1d_tasks_two_hops() {
        // Paper Table 1: Z ordering of 1D tasks gives AverageHops ~2.
        let v = average_hops_cell(4096, 2, 1, Connectivity::MeshToMesh, PartOrdering::Z);
        assert!((v - 2.0).abs() < 0.1, "got {v}");
    }

    #[test]
    fn fz_beats_z_on_mismatched_dims() {
        // td=2, pd=3 (neither divides the other): FZ < Z, the paper's
        // headline ordering result.
        let z = average_hops_cell(4096, 3, 2, Connectivity::MeshToTorus, PartOrdering::Z);
        let fz = average_hops_cell(4096, 3, 2, Connectivity::MeshToTorus, PartOrdering::FZ);
        assert!(fz < z, "FZ {fz} !< Z {z}");
    }
}
