//! Objective-comparison experiment (`objective`): the same mapper run
//! under each [`crate::objective::ObjectiveKind`], reporting WeightedHops
//! and the routed bottleneck latency **side by side** for every run.
//!
//! Per (case, seed, strategy) the WeightedHops-objective run is the ratio
//! denominator, so the table reads as "what does optimizing congestion
//! cost in hops, and what does it buy on the bottleneck link" — the
//! trade-off arXiv:1702.04164 and arXiv:2005.10413 show diverges
//! materially from hop-based scoring. Strategies: the flat Z2_1 rotation
//! sweep, the hierarchical mapper with `MinVolume` refinement, and the
//! depth-3 NUMA mapper under the XK7 Interlagos node model — all
//! scoring/refining under the row's objective end to end, the last
//! through the blended (network × NUMA) evaluator for the routed rows.

use super::report::{f2, sci, Table};
use super::Ctx;
use crate::apps::homme::{Homme, HommeCoords};
use crate::apps::minighost::MiniGhost;
use crate::apps::TaskGraph;
use crate::geom::Coords;
use crate::hier::{map_hierarchical, HierConfig, IntraNodeStrategy};
use crate::machine::{cray_xk7, titan_full, Allocation, NumaTopology, SparseAllocator};
use crate::mapping::pipeline::{z2_map, Z2Config};
use crate::metrics::eval_full;
use crate::objective::ObjectiveKind;
use crate::par::Parallelism;

const ROT: usize = 8;
const PASSES: usize = 4;

fn headers() -> [&'static str; 9] {
    [
        "case",
        "seed",
        "strategy",
        "objective",
        "WH",
        "Lat(M)",
        "WH/whops",
        "Lat/whops",
        "swaps",
    ]
}

/// Run all three strategies under every objective on one case; rows
/// normalize against the same strategy's WeightedHops-objective run.
fn run_case(
    ctx: &Ctx,
    table: &mut Table,
    case: &str,
    seed: u64,
    graph: &TaskGraph,
    tcoords: &Coords,
    alloc: &Allocation,
) {
    for strategy in ["flat", "hier-minvol", "hier-numa"] {
        let mut denom: Option<(f64, f64)> = None;
        for kind in ObjectiveKind::ALL {
            let (mapping, swaps) = match strategy {
                "flat" => {
                    let mut cfg = Z2Config::z2_1();
                    cfg.max_rotations = ROT;
                    cfg.spec.objective = kind;
                    (z2_map(graph, tcoords, alloc, &cfg, ctx.backend()), None)
                }
                _ => {
                    let mut cfg = HierConfig {
                        intra: IntraNodeStrategy::MinVolume { passes: PASSES },
                        max_rotations: ROT,
                        ..HierConfig::default()
                    };
                    cfg.spec.objective = kind;
                    // "hier-numa": depth 3 under the XK7 node model —
                    // the routed rows run the blended evaluator.
                    cfg.spec.numa = (strategy == "hier-numa").then(NumaTopology::xk7);
                    let m = map_hierarchical(graph, tcoords, alloc, &cfg, ctx.backend());
                    (m.task_to_rank, Some(m.swaps_applied))
                }
            };
            let m = eval_full(graph, &mapping, alloc);
            let lat = m.link.as_ref().unwrap().max_latency;
            let (wh0, lat0) = *denom.get_or_insert((m.weighted_hops, lat));
            table.push_row(vec![
                case.to_string(),
                seed.to_string(),
                strategy.to_string(),
                kind.name().to_string(),
                f2(m.weighted_hops),
                sci(lat),
                f2(m.weighted_hops / wh0),
                f2(lat / lat0),
                swaps.map_or_else(|| "-".to_string(), |s| s.to_string()),
            ]);
        }
    }
}

/// The `objective` experiment: MiniGhost and HOMME cases on the XK7 model.
pub fn run(ctx: &Ctx) -> Vec<Table> {
    let mut table = Table::new(
        "Objective: WeightedHops vs routed congestion objectives (XK7)",
        &headers(),
    );
    let allocator = if ctx.full {
        titan_full()
    } else {
        SparseAllocator {
            machine: cray_xk7(&[10, 8, 10]),
            nodes_per_router: 2,
            ranks_per_node: 16,
            occupancy: 0.4,
        }
    };
    let mg_dims: [usize; 3] = if ctx.full { [32, 16, 16] } else { [8, 8, 8] };
    let homme_ne = if ctx.full { 24 } else { 12 };
    let seeds = [ctx.seed, ctx.seed + 1];

    let mg = MiniGhost::weak_scaling(mg_dims);
    let mg_graph = mg.graph();
    let homme = Homme::new(homme_ne);
    let homme_graph = homme.graph();
    let homme_coords = homme.coords(HommeCoords::Cube);

    // The allocation simulator runs fan out over the par budget (one
    // deterministic allocation per (case, seed) — results are identical at
    // every thread count).
    let jobs: Vec<(usize, u64)> = seeds
        .iter()
        .flat_map(|&s| {
            [
                (mg.num_tasks() / allocator.ranks_per_node, s),
                (homme.num_tasks() / allocator.ranks_per_node, s),
            ]
        })
        .collect();
    let allocs: Vec<Allocation> = allocator.allocate_batch(&jobs, Parallelism::auto());

    for (i, &seed) in seeds.iter().enumerate() {
        run_case(
            ctx,
            &mut table,
            &format!("mg-{}", mg.num_tasks()),
            seed,
            &mg_graph,
            &mg_graph.coords,
            &allocs[2 * i],
        );
    }
    for (i, &seed) in seeds.iter().enumerate() {
        run_case(
            ctx,
            &mut table,
            &format!("homme-{}", homme.num_tasks()),
            seed,
            &homme_graph,
            &homme_coords,
            &allocs[2 * i + 1],
        );
    }
    vec![table]
}
