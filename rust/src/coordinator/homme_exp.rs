//! HOMME experiments: Table 2 and Fig. 8/9 (BG/Q, contiguous blocks) and
//! Figs 10–12 (Titan, sparse allocations).

use super::report::{f2, f3, sci, Table};
use super::Ctx;
use crate::apps::homme::{Homme, HommeCoords};
use crate::apps::TaskGraph;
use crate::machine::{bgq_block, cray_xk7, titan_full, Allocation, SparseAllocator};
use crate::mapping::pipeline::{sfc_plus_z2, z2_map, Z2Config};
use crate::metrics::{eval_full, Metrics};
use crate::simulate::{comm_time, CommModel, CommTime};

/// BG/Q experiment shape.
struct BgqSetup {
    ne: usize,
    /// (ranks, ranks_per_node) per scaling point.
    points: Vec<(usize, usize)>,
}

fn bgq_setup(full: bool, hybrid: bool) -> BgqSetup {
    if full {
        if hybrid {
            // Fig 8: 1024..8192 nodes, 4 ranks/node.
            BgqSetup {
                ne: 128,
                points: vec![(4096, 4), (8192, 4), (16384, 4), (32768, 4)],
            }
        } else {
            // Table 2: MPI-only, 16 ranks/node.
            BgqSetup {
                ne: 128,
                points: vec![(8192, 16), (16384, 16), (32768, 16)],
            }
        }
    } else if hybrid {
        BgqSetup {
            ne: 32,
            points: vec![(256, 4), (512, 4), (1024, 4), (2048, 4)],
        }
    } else {
        BgqSetup {
            ne: 32,
            points: vec![(512, 16), (1024, 16), (2048, 16)],
        }
    }
}

fn bgq_alloc(ranks: usize, ranks_per_node: usize) -> Allocation {
    let nodes = ranks / ranks_per_node;
    Allocation::bgq(bgq_block(nodes), ranks_per_node, "ABCDET")
        .expect("ABCDET is a valid rank order")
}

/// Rotation cap: the full td!*pd! sweep is expensive at paper scale; the
/// paper itself spreads it over process groups. 12 candidates keep the
/// rotation benefit with tractable single-core runtime.
const ROT: usize = 12;

fn z2_cfg_bgq(plus_e: bool) -> Z2Config {
    let mut cfg = Z2Config::z2_1();
    cfg.max_rotations = ROT;
    // BG/Q links are uniform: no bandwidth scaling or box transform.
    if plus_e {
        cfg = cfg.plus_e();
    }
    cfg
}

/// Simulated communication time for a HOMME mapping on an allocation.
fn homme_time(graph: &TaskGraph, mapping: &[u32], alloc: &Allocation) -> CommTime {
    // HOMME exchanges boundaries many times per simulated day; rounds only
    // scales absolute values (results are reported normalized).
    let model = CommModel {
        rounds: 100.0,
        ..Default::default()
    };
    comm_time(graph, mapping, alloc, &model)
}

/// All strategy mappings for one BG/Q configuration. Returns
/// (label, task_to_rank).
fn bgq_mappings(
    ctx: &Ctx,
    homme: &Homme,
    graph: &TaskGraph,
    alloc: &Allocation,
    variants: &[(HommeCoords, bool)],
    include_all: bool,
) -> Vec<(String, Vec<u32>)> {
    let nranks = alloc.num_ranks();
    let mut out = Vec::new();
    // SFC: HOMME's own Hilbert partition; rank = part number under the
    // machine's default ABCDET ordering.
    let sfc = homme.sfc_partition(nranks);
    out.push(("SFC".to_string(), sfc.clone()));
    for &(coords, plus_e) in variants {
        let tcoords = homme.coords(coords);
        let cfg = z2_cfg_bgq(plus_e);
        let e_tag = if plus_e { "+E" } else { "" };
        if include_all {
            let m = sfc_plus_z2(graph, &tcoords, &sfc, nranks, alloc, &cfg, ctx.backend());
            out.push((format!("SFC+Z2 {}{e_tag}", coords.name()), m));
        }
        let m = z2_map(graph, &tcoords, alloc, &cfg, ctx.backend());
        out.push((format!("Z2 {}{e_tag}", coords.name()), m));
    }
    out
}

const ALL_VARIANTS: [(HommeCoords, bool); 6] = [
    (HommeCoords::Sphere, false),
    (HommeCoords::Sphere, true),
    (HommeCoords::Cube, false),
    (HommeCoords::Cube, true),
    (HommeCoords::Face2D, false),
    (HommeCoords::Face2D, true),
];

/// Table 2: MPI-only HOMME on BG/Q, all strategy/transform variants,
/// normalized to SFC at the smallest rank count.
pub fn table2(ctx: &Ctx) -> Vec<Table> {
    let setup = bgq_setup(ctx.full, false);
    let homme = Homme::new(setup.ne);
    let graph = homme.graph();
    let mut rows: Vec<(usize, Vec<(String, f64)>)> = Vec::new();
    let mut labels: Vec<String> = Vec::new();
    for &(ranks, rpn) in &setup.points {
        let alloc = bgq_alloc(ranks, rpn);
        let maps = bgq_mappings(ctx, &homme, &graph, &alloc, &ALL_VARIANTS, true);
        let times: Vec<(String, f64)> = maps
            .iter()
            .map(|(label, m)| (label.clone(), homme_time(&graph, m, &alloc).total))
            .collect();
        if labels.is_empty() {
            labels = times.iter().map(|(l, _)| l.clone()).collect();
        }
        rows.push((ranks, times));
    }
    let reference = rows[0].1[0].1; // SFC at the smallest count
    let mut headers: Vec<&str> = vec!["ranks"];
    let owned: Vec<String> = labels.clone();
    headers.extend(owned.iter().map(|s| s.as_str()));
    let mut t = Table::new(
        "Table 2: HOMME BG/Q communication time (normalized to SFC at smallest scale)",
        &headers,
    );
    for (ranks, times) in &rows {
        let mut row = vec![ranks.to_string()];
        row.extend(times.iter().map(|(_, v)| f2(v / reference)));
        t.push_row(row);
    }
    vec![t]
}

/// Fig 8: hybrid HOMME (4 ranks/node), best variants only, normalized to
/// SFC at the smallest scale.
pub fn fig8(ctx: &Ctx) -> Vec<Table> {
    let setup = bgq_setup(ctx.full, true);
    let homme = Homme::new(setup.ne);
    let graph = homme.graph();
    // Best variants per the paper: SFC+Z2 uses Cube+E, Z2 uses 2DFace+E.
    let mut t = Table::new(
        "Fig 8: Hybrid HOMME BG/Q communication time (normalized to SFC at smallest scale)",
        &["ranks", "SFC", "SFC+Z2 Cube+E", "Z2 2DFace+E", "SFC_seconds"],
    );
    let mut reference = None;
    for &(ranks, rpn) in &setup.points {
        let alloc = bgq_alloc(ranks, rpn);
        let nranks = alloc.num_ranks();
        let sfc = homme.sfc_partition(nranks);
        let t_sfc = homme_time(&graph, &sfc, &alloc).total;
        let cube = homme.coords(HommeCoords::Cube);
        let face = homme.coords(HommeCoords::Face2D);
        let m_sfcz2 = sfc_plus_z2(
            &graph,
            &cube,
            &sfc,
            nranks,
            &alloc,
            &z2_cfg_bgq(true),
            ctx.backend(),
        );
        let m_z2 = z2_map(&graph, &face, &alloc, &z2_cfg_bgq(true), ctx.backend());
        let t_sfcz2 = homme_time(&graph, &m_sfcz2, &alloc).total;
        let t_z2 = homme_time(&graph, &m_z2, &alloc).total;
        let reference = *reference.get_or_insert(t_sfc);
        t.push_row(vec![
            ranks.to_string(),
            f2(t_sfc / reference),
            f2(t_sfcz2 / reference),
            f2(t_z2 / reference),
            f3(t_sfc),
        ]);
    }
    vec![t]
}

/// Fig 9: max and average link Data per BG/Q dimension (A..E) at the
/// largest hybrid scale.
pub fn fig9(ctx: &Ctx) -> Vec<Table> {
    let setup = bgq_setup(ctx.full, true);
    let homme = Homme::new(setup.ne);
    let graph = homme.graph();
    let &(ranks, rpn) = setup.points.last().unwrap();
    let alloc = bgq_alloc(ranks, rpn);
    let nranks = alloc.num_ranks();
    let sfc = homme.sfc_partition(nranks);
    let cube = homme.coords(HommeCoords::Cube);
    let face = homme.coords(HommeCoords::Face2D);
    let strategies: Vec<(&str, Vec<u32>)> = vec![
        ("SFC", sfc.clone()),
        (
            "SFC+Z2",
            sfc_plus_z2(
                &graph,
                &cube,
                &sfc,
                nranks,
                &alloc,
                &z2_cfg_bgq(true),
                ctx.backend(),
            ),
        ),
        (
            "Z2",
            z2_map(&graph, &face, &alloc, &z2_cfg_bgq(true), ctx.backend()),
        ),
    ];
    let dims = ["A", "B", "C", "D", "E"];
    let mut tmax = Table::new(
        "Fig 9a: Max link Data per BG/Q dimension (bytes)",
        &["strategy", "A", "B", "C", "D", "E", "Data(M)"],
    );
    let mut tavg = Table::new(
        "Fig 9b: Avg link Data per BG/Q dimension (bytes)",
        &["strategy", "A", "B", "C", "D", "E"],
    );
    for (name, m) in &strategies {
        let metrics = eval_full(&graph, m, &alloc);
        let lm = metrics.link.unwrap();
        let mut row_max = vec![name.to_string()];
        let mut row_avg = vec![name.to_string()];
        for d in 0..dims.len() {
            let mx = lm.per_dim[d][0].max_data.max(lm.per_dim[d][1].max_data);
            let av = 0.5 * (lm.per_dim[d][0].avg_data + lm.per_dim[d][1].avg_data);
            row_max.push(sci(mx));
            row_avg.push(sci(av));
        }
        row_max.push(sci(lm.max_data));
        tmax.push_row(row_max);
        tavg.push_row(row_avg);
    }
    vec![tmax, tavg]
}

// ---------------------------------------------------------------------------
// Titan (Figs 10-12)
// ---------------------------------------------------------------------------

struct TitanSetup {
    ne: usize,
    proc_counts: Vec<usize>,
    allocator: SparseAllocator,
    seeds: Vec<u64>,
}

fn titan_setup(ctx: &Ctx) -> TitanSetup {
    if ctx.full {
        TitanSetup {
            ne: 120, // 86,400 surface elements, the paper's Titan case
            proc_counts: vec![10_800, 21_600, 43_200, 86_400],
            allocator: titan_full(),
            seeds: vec![ctx.seed, ctx.seed + 1, ctx.seed + 2],
        }
    } else {
        TitanSetup {
            ne: 24, // 3,456 elements
            proc_counts: vec![432, 864, 1728, 3456],
            allocator: SparseAllocator {
                machine: cray_xk7(&[10, 8, 10]),
                nodes_per_router: 2,
                ranks_per_node: 16,
                occupancy: 0.4,
            },
            seeds: vec![ctx.seed, ctx.seed + 1],
        }
    }
}

fn titan_z2_cfgs() -> Vec<(&'static str, Z2Config)> {
    let mut z1 = Z2Config::z2_1();
    z1.max_rotations = ROT;
    let mut z2 = Z2Config::z2_2();
    z2.max_rotations = ROT;
    let mut z3 = Z2Config::z2_3();
    z3.max_rotations = ROT;
    vec![("Z2_1", z1), ("Z2_2", z2), ("Z2_3", z3)]
}

struct TitanRun {
    procs: usize,
    seed: u64,
    /// (strategy, comm time, metrics)
    results: Vec<(String, f64, Metrics)>,
}

fn titan_runs(ctx: &Ctx) -> (Homme, Vec<TitanRun>) {
    let setup = titan_setup(ctx);
    let homme = Homme::new(setup.ne);
    let graph = homme.graph();
    // Cube-projected task coordinates: Section 5.2 found that slicing raw
    // sphere coordinates partitions poorly; the cube projection is the
    // transform HOMME itself uses before its SFC.
    let tcoords = homme.coords(HommeCoords::Cube);
    // The allocation simulator runs (one per (procs, seed), expensive on
    // the --full Titan machine) fan out over the par budget; each is
    // deterministic per seed, so the sweep is thread-count-invariant.
    let cases: Vec<(usize, u64)> = setup
        .proc_counts
        .iter()
        .flat_map(|&procs| setup.seeds.iter().map(move |&seed| (procs, seed)))
        .collect();
    let jobs: Vec<(usize, u64)> = cases
        .iter()
        .map(|&(procs, seed)| (procs / setup.allocator.ranks_per_node, seed))
        .collect();
    let allocs: Vec<Allocation> = setup
        .allocator
        .allocate_batch(&jobs, crate::par::Parallelism::auto());
    let mut runs = Vec::new();
    for (&(procs, seed), alloc) in cases.iter().zip(&allocs) {
        let mut results = Vec::new();
        // SFC: HOMME's Hilbert partition onto the ALPS default order.
        let sfc = homme.sfc_partition(procs);
        let t = homme_time(&graph, &sfc, alloc);
        results.push((
            "SFC".to_string(),
            t.total,
            eval_full(&graph, &sfc, alloc),
        ));
        for (name, cfg) in titan_z2_cfgs() {
            let m = z2_map(&graph, &tcoords, alloc, &cfg, ctx.backend());
            let t = homme_time(&graph, &m, alloc);
            results.push((name.to_string(), t.total, eval_full(&graph, &m, alloc)));
        }
        runs.push(TitanRun {
            procs,
            seed,
            results,
        });
    }
    (homme, runs)
}

/// Fig 10: HOMME Titan communication time per strategy, normalized to SFC
/// within each allocation; averaged across allocations per proc count.
pub fn fig10(ctx: &Ctx) -> Vec<Table> {
    let (_, runs) = titan_runs(ctx);
    let labels: Vec<String> = runs[0].results.iter().map(|(l, _, _)| l.clone()).collect();
    let mut headers: Vec<&str> = vec!["procs", "allocs"];
    let owned = labels.clone();
    headers.extend(owned.iter().map(|s| s.as_str()));
    headers.push("SFC_seconds");
    let mut t = Table::new(
        "Fig 10: HOMME Titan communication time (normalized to SFC per allocation)",
        &headers,
    );
    let mut procs_seen: Vec<usize> = runs.iter().map(|r| r.procs).collect();
    procs_seen.dedup();
    for procs in procs_seen {
        let group: Vec<&TitanRun> = runs.iter().filter(|r| r.procs == procs).collect();
        let mut row = vec![procs.to_string(), group.len().to_string()];
        for (i, _) in labels.iter().enumerate() {
            let avg: f64 = group
                .iter()
                .map(|r| r.results[i].1 / r.results[0].1)
                .sum::<f64>()
                / group.len() as f64;
            row.push(f2(avg));
        }
        let sfc_avg: f64 =
            group.iter().map(|r| r.results[0].1).sum::<f64>() / group.len() as f64;
        row.push(f3(sfc_avg));
        t.push_row(row);
    }
    vec![t]
}

/// Fig 11: Z2_3's communication metrics normalized to SFC, per allocation.
pub fn fig11(ctx: &Ctx) -> Vec<Table> {
    let (_, runs) = titan_runs(ctx);
    let mut t = Table::new(
        "Fig 11: HOMME Titan Z2_3 metrics normalized to SFC",
        &["procs", "seed", "WH", "TM", "Data(M)", "Latency(M)"],
    );
    for run in &runs {
        let sfc = &run.results[0].2;
        let z3 = &run
            .results
            .iter()
            .find(|(l, _, _)| l == "Z2_3")
            .unwrap()
            .2;
        let (sl, zl) = (sfc.link.as_ref().unwrap(), z3.link.as_ref().unwrap());
        t.push_row(vec![
            run.procs.to_string(),
            run.seed.to_string(),
            f2(z3.weighted_hops / sfc.weighted_hops),
            f2(z3.total_messages as f64 / sfc.total_messages as f64),
            f2(zl.max_data / sl.max_data),
            f2(zl.max_latency / sl.max_latency),
        ]);
    }
    vec![t]
}

/// Fig 12: per-dimension (X+..Z-) Data and Latency for SFC and Z2_3 at the
/// largest proc count, normalized to SFC X+.
pub fn fig12(ctx: &Ctx) -> Vec<Table> {
    let (_, runs) = titan_runs(ctx);
    let last_procs = runs.last().unwrap().procs;
    let run = runs.iter().find(|r| r.procs == last_procs).unwrap();
    let mut tables = Vec::new();
    for (metric, pick) in [
        ("Data", 0usize),
        ("Latency", 1usize),
    ] {
        let mut t = Table::new(
            &format!("Fig 12: HOMME Titan per-dimension {metric} (normalized to SFC X+)"),
            &["strategy", "X+", "X-", "Y+", "Y-", "Z+", "Z-"],
        );
        let sfc_lm = run.results[0].2.link.as_ref().unwrap();
        let norm = if pick == 0 {
            sfc_lm.per_dim[0][0].max_data
        } else {
            sfc_lm.per_dim[0][0].max_latency
        };
        for (label, _, metrics) in &run.results {
            if label != "SFC" && label != "Z2_3" {
                continue;
            }
            let lm = metrics.link.as_ref().unwrap();
            let mut row = vec![label.clone()];
            for d in 0..3 {
                for dir in 0..2 {
                    let v = if pick == 0 {
                        lm.per_dim[d][dir].max_data
                    } else {
                        lm.per_dim[d][dir].max_latency
                    };
                    row.push(f2(v / norm));
                }
            }
            t.push_row(row);
        }
        tables.push(t);
    }
    tables
}
