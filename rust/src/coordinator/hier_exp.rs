//! Hierarchical-vs-flat mapping experiment (`hier`): the two-level
//! node→core mapper of [`crate::hier`] against the paper's flat Z2_1
//! strategy, on the MiniGhost (Cray XK7) and HOMME (Titan) presets.
//!
//! Both mappers see the same task graph, coordinates, allocation, and
//! rotation budget; the tables report the Section 3 metrics that the
//! hierarchy targets — inter-node WeightedHops, Data(M), Latency(M) — with
//! per-row ratios against the flat mapper (< 1.00 = hierarchical wins).

use super::report::{f2, sci, Table};
use super::Ctx;
use crate::apps::homme::{Homme, HommeCoords};
use crate::apps::minighost::MiniGhost;
use crate::apps::TaskGraph;
use crate::geom::Coords;
use crate::hier::{map_hierarchical, place_within_nodes, refine, HierConfig, IntraNodeStrategy};
use crate::machine::{cray_xk7, titan_full, Allocation, SparseAllocator};
use crate::mapping::pipeline::{z2_map, Z2Config};
use crate::metrics::{eval_full, Metrics};
use crate::par::Parallelism;

const ROT: usize = 12;
const PASSES: usize = 4;

/// Run all strategies on one (graph, coords, allocation) case and append
/// rows to `table`. The flat strategy is row 0 and the ratio denominator.
/// The three hierarchical variants share one node-level rotation sweep
/// (the dominant cost — identical by construction) and differ only in
/// refinement and intra-node placement.
fn run_case(
    ctx: &Ctx,
    table: &mut Table,
    case: &str,
    seed: u64,
    graph: &TaskGraph,
    tcoords: &Coords,
    alloc: &Allocation,
) {
    let mut flat_cfg = Z2Config::z2_1();
    flat_cfg.max_rotations = ROT;
    let flat_map = z2_map(graph, tcoords, alloc, &flat_cfg, ctx.backend());

    let hcfg = HierConfig {
        intra: IntraNodeStrategy::DefaultOrder,
        max_rotations: ROT,
        ..HierConfig::default()
    };
    let base = map_hierarchical(graph, tcoords, alloc, &hcfg, ctx.backend());
    let par = Parallelism::auto();
    let sfc_map = place_within_nodes(
        tcoords,
        &base.task_to_node,
        alloc,
        IntraNodeStrategy::SfcOrder,
        par,
    );
    let mut refined = base.task_to_node.clone();
    refine::min_volume_refine(
        graph,
        &mut refined,
        &alloc.node_routers(),
        &alloc.machine,
        PASSES,
        par,
    );
    let minvol_map =
        place_within_nodes(tcoords, &refined, alloc, IntraNodeStrategy::DefaultOrder, par);

    let rows: [(&str, &[u32]); 4] = [
        ("Flat Z2_1", &flat_map),
        ("Hier default", &base.task_to_rank),
        ("Hier sfc", &sfc_map),
        ("Hier minvol", &minvol_map),
    ];
    let mut flat: Option<Metrics> = None;
    for (name, mapping) in rows {
        let m = eval_full(graph, mapping, alloc);
        let lm = m.link.clone().expect("eval_full computes link metrics");
        let denom = flat.get_or_insert_with(|| m.clone());
        let denom_lm = denom.link.clone().unwrap();
        table.push_row(vec![
            case.to_string(),
            seed.to_string(),
            name.to_string(),
            f2(m.weighted_hops),
            sci(lm.max_data),
            sci(lm.max_latency),
            f2(m.weighted_hops / denom.weighted_hops),
            f2(lm.max_data / denom_lm.max_data),
            f2(lm.max_latency / denom_lm.max_latency),
        ]);
    }
}

fn headers() -> [&'static str; 9] {
    [
        "case",
        "seed",
        "strategy",
        "WH",
        "Data(M)",
        "Latency(M)",
        "WH/flat",
        "Data/flat",
        "Lat/flat",
    ]
}

/// The `hier` experiment: one table per preset.
pub fn run(ctx: &Ctx) -> Vec<Table> {
    let mut mg_table = Table::new(
        "Hier: MiniGhost XK7, hierarchical node-core mapping vs flat Z2_1",
        &headers(),
    );
    let allocator = if ctx.full {
        titan_full()
    } else {
        SparseAllocator {
            machine: cray_xk7(&[10, 8, 10]),
            nodes_per_router: 2,
            ranks_per_node: 16,
            occupancy: 0.4,
        }
    };
    let mg_points: Vec<(usize, [usize; 3])> = if ctx.full {
        vec![(8_192, [32, 16, 16]), (32_768, [32, 32, 32])]
    } else {
        vec![(512, [8, 8, 8]), (2_048, [16, 16, 8])]
    };
    let seeds = [ctx.seed, ctx.seed + 1];
    // One rank per element so the mapping is a bijection (the paper's
    // largest Titan point does the same: 86,400 ranks for ne=120).
    let ne = if ctx.full { 120 } else { 24 };
    let homme = Homme::new(ne);
    // Allocation simulator runs for *both* presets, fanned out over the
    // par budget (deterministic per seed => thread-count-invariant). Order:
    // mg points x seeds, then homme x seeds.
    let rpn = allocator.ranks_per_node;
    let jobs: Vec<(usize, u64)> = mg_points
        .iter()
        .map(|&(procs, _)| procs)
        .chain([homme.num_tasks()])
        .flat_map(|procs| seeds.iter().map(move |&seed| (procs / rpn, seed)))
        .collect();
    let allocs: Vec<Allocation> = allocator.allocate_batch(&jobs, Parallelism::auto());

    for (pi, &(procs, tdims)) in mg_points.iter().enumerate() {
        let mg = MiniGhost::weak_scaling(tdims);
        let graph = mg.graph();
        for (si, &seed) in seeds.iter().enumerate() {
            run_case(
                ctx,
                &mut mg_table,
                &format!("mg-{procs}"),
                seed,
                &graph,
                &graph.coords,
                &allocs[pi * seeds.len() + si],
            );
        }
    }

    let mut homme_table = Table::new(
        "Hier: HOMME Titan, hierarchical node-core mapping vs flat Z2_1",
        &headers(),
    );
    let graph = homme.graph();
    let tcoords = homme.coords(HommeCoords::Cube);
    let procs = homme.num_tasks();
    for (si, &seed) in seeds.iter().enumerate() {
        run_case(
            ctx,
            &mut homme_table,
            &format!("homme-{procs}"),
            seed,
            &graph,
            &tcoords,
            &allocs[mg_points.len() * seeds.len() + si],
        );
    }
    vec![mg_table, homme_table]
}
