//! MiniGhost weak-scaling experiments on the Cray XK7 model
//! (Section 5.3.2): Figs 13 (communication time), 14 (AverageHops and
//! Latency), and 15 (per-dimension time).

use super::report::{f2, sci, Table};
use super::Ctx;
use crate::apps::minighost::MiniGhost;
use crate::machine::{cray_xk7, titan_full, SparseAllocator};
use crate::mapping::pipeline::{z2_map, Z2Config};
use crate::metrics::eval_full;
use crate::simulate::{comm_time, CommModel, CommTime};

struct Setup {
    /// (procs, task grid dims).
    points: Vec<(usize, [usize; 3])>,
    allocator: SparseAllocator,
    seeds: Vec<u64>,
}

fn setup(ctx: &Ctx) -> Setup {
    if ctx.full {
        Setup {
            points: vec![
                (8_192, [32, 16, 16]),
                (16_384, [32, 32, 16]),
                (32_768, [32, 32, 32]),
                (65_536, [64, 32, 32]),
                (131_072, [64, 64, 32]),
            ],
            allocator: titan_full(),
            seeds: vec![ctx.seed, ctx.seed + 1],
        }
    } else {
        Setup {
            points: vec![
                (512, [8, 8, 8]),
                (1_024, [16, 8, 8]),
                (2_048, [16, 16, 8]),
                (4_096, [16, 16, 16]),
            ],
            allocator: SparseAllocator {
                machine: cray_xk7(&[10, 8, 10]),
                nodes_per_router: 2,
                ranks_per_node: 16,
                occupancy: 0.4,
            },
            seeds: vec![ctx.seed, ctx.seed + 1],
        }
    }
}

/// MiniGhost simulation model: 20 timesteps per run (the paper's
/// configuration).
fn model() -> CommModel {
    CommModel {
        rounds: 20.0,
        ..Default::default()
    }
}

const ROT: usize = 12;

fn strategies() -> Vec<(&'static str, Option<Z2Config>)> {
    let mut z1 = Z2Config::z2_1();
    z1.max_rotations = ROT;
    let mut z2 = Z2Config::z2_2();
    z2.max_rotations = ROT;
    let mut z3 = Z2Config::z2_3();
    z3.max_rotations = ROT;
    vec![
        ("Default", None),
        ("Group", None),
        ("Z2_1", Some(z1)),
        ("Z2_2", Some(z2)),
        ("Z2_3", Some(z3)),
    ]
}

pub struct MgRun {
    pub procs: usize,
    pub seed: u64,
    /// (strategy, comm time breakdown, metrics).
    pub results: Vec<(String, CommTime, crate::metrics::Metrics)>,
}

/// Run every strategy on every (scale, allocation) pair.
pub fn runs(ctx: &Ctx) -> Vec<MgRun> {
    let setup = setup(ctx);
    // Fan the allocation simulator out over the par budget: one
    // deterministic allocation per (point, seed), results in input order,
    // so the sweep is thread-count-invariant (the --full Titan machine
    // makes each allocate a real cost). Jobs iterate seeds innermost,
    // matching the loop below.
    let rpn = setup.allocator.ranks_per_node;
    let jobs: Vec<(usize, u64)> = setup
        .points
        .iter()
        .flat_map(|&(procs, _)| setup.seeds.iter().map(move |&seed| (procs / rpn, seed)))
        .collect();
    let allocs = setup
        .allocator
        .allocate_batch(&jobs, crate::par::Parallelism::auto());
    let mut out = Vec::new();
    for (pi, &(procs, tdims)) in setup.points.iter().enumerate() {
        let mg = MiniGhost::weak_scaling(tdims);
        assert_eq!(mg.num_tasks(), procs);
        let graph = mg.graph();
        for (si, &seed) in setup.seeds.iter().enumerate() {
            // jobs iterate seeds innermost, so this is that flat index.
            let alloc = &allocs[pi * setup.seeds.len() + si];
            let mut results = Vec::new();
            for (name, cfg) in strategies() {
                let mapping = match (name, &cfg) {
                    ("Default", _) => mg.default_order(),
                    ("Group", _) => mg.group_order(),
                    (_, Some(cfg)) => z2_map(&graph, &graph.coords, alloc, cfg, ctx.backend()),
                    _ => unreachable!(),
                };
                let t = comm_time(&graph, &mapping, alloc, &model());
                let m = eval_full(&graph, &mapping, alloc);
                results.push((name.to_string(), t, m));
            }
            out.push(MgRun {
                procs,
                seed,
                results,
            });
        }
    }
    out
}

fn labels(runs: &[MgRun]) -> Vec<String> {
    runs[0].results.iter().map(|(l, _, _)| l.clone()).collect()
}

/// Fig 13: maximum communication time (seconds) per strategy, averaged over
/// allocations per weak-scaling point.
pub fn fig13(ctx: &Ctx) -> Vec<Table> {
    let runs = runs(ctx);
    let labels = labels(&runs);
    let mut headers: Vec<&str> = vec!["procs", "allocs"];
    headers.extend(labels.iter().map(|s| s.as_str()));
    let mut t = Table::new(
        "Fig 13: MiniGhost max communication time, seconds (weak scaling)",
        &headers,
    );
    let mut procs_seen: Vec<usize> = runs.iter().map(|r| r.procs).collect();
    procs_seen.dedup();
    for procs in procs_seen {
        let group: Vec<&MgRun> = runs.iter().filter(|r| r.procs == procs).collect();
        let mut row = vec![procs.to_string(), group.len().to_string()];
        for i in 0..labels.len() {
            let avg: f64 =
                group.iter().map(|r| r.results[i].1.total).sum::<f64>() / group.len() as f64;
            row.push(format!("{avg:.4}"));
        }
        t.push_row(row);
    }
    vec![t]
}

/// Fig 14: AverageHops and Latency(M) per strategy per scale.
pub fn fig14(ctx: &Ctx) -> Vec<Table> {
    let runs = runs(ctx);
    let labels = labels(&runs);
    let mut tables = Vec::new();
    for which in ["AverageHops", "Latency"] {
        let mut headers: Vec<&str> = vec!["procs"];
        headers.extend(labels.iter().map(|s| s.as_str()));
        let mut t = Table::new(
            &format!("Fig 14: MiniGhost {which} (weak scaling)"),
            &headers,
        );
        let mut procs_seen: Vec<usize> = runs.iter().map(|r| r.procs).collect();
        procs_seen.dedup();
        for procs in procs_seen {
            let group: Vec<&MgRun> = runs.iter().filter(|r| r.procs == procs).collect();
            let mut row = vec![procs.to_string()];
            for i in 0..labels.len() {
                let avg: f64 = group
                    .iter()
                    .map(|r| {
                        if which == "AverageHops" {
                            r.results[i].2.avg_hops
                        } else {
                            r.results[i].2.link.as_ref().unwrap().max_latency
                        }
                    })
                    .sum::<f64>()
                    / group.len() as f64;
                row.push(if which == "AverageHops" {
                    f2(avg)
                } else {
                    sci(avg)
                });
            }
            t.push_row(row);
        }
        tables.push(t);
    }
    tables
}

/// Fig 15: average per-dimension communication time at the largest scale.
pub fn fig15(ctx: &Ctx) -> Vec<Table> {
    let runs = runs(ctx);
    let last_procs = runs.last().unwrap().procs;
    let run = runs.iter().find(|r| r.procs == last_procs).unwrap();
    let mut t = Table::new(
        "Fig 15: MiniGhost per-dimension communication time, seconds (largest scale)",
        &["strategy", "X_serial", "Y_serial", "Z_serial", "X_msg", "Y_msg", "Z_msg"],
    );
    for (label, time, _) in &run.results {
        let mut row = vec![label.clone()];
        for d in 0..3 {
            row.push(sci(time.per_dim_serial[d][0].max(time.per_dim_serial[d][1])));
        }
        for d in 0..3 {
            row.push(sci(time.per_dim_msg[d]));
        }
        t.push_row(row);
    }
    vec![t]
}
