//! Experiment registry: name -> runner, used by the CLI and the smoke
//! tests. Every table and figure of the paper's evaluation section appears
//! here (DESIGN.md section 4 is the index).

use super::report::Table;
use super::{hier_exp, homme_exp, minighost_exp, numa_exp, objective_exp, table1, Ctx};

/// All experiment ids: the paper artifacts in paper order, then the
/// beyond-the-paper studies (`hier` — hierarchical node→core mapping vs
/// the flat mapper; `objective` — WeightedHops vs routed congestion
/// objectives; `numa` — depth-2 vs depth-3 mapping under the NUMA node
/// model).
pub const ALL: &[&str] = &[
    "table1", "table2", "fig8", "fig9", "fig10", "fig11", "fig12", "fig13", "fig14", "fig15",
    "hier", "objective", "numa",
];

/// Run one experiment by id.
pub fn run(id: &str, ctx: &Ctx) -> Option<Vec<Table>> {
    match id {
        "table1" => Some(table1::run(ctx)),
        "hier" => Some(hier_exp::run(ctx)),
        "objective" => Some(objective_exp::run(ctx)),
        "numa" => Some(numa_exp::run(ctx)),
        "table2" => Some(homme_exp::table2(ctx)),
        "fig8" => Some(homme_exp::fig8(ctx)),
        "fig9" => Some(homme_exp::fig9(ctx)),
        "fig10" => Some(homme_exp::fig10(ctx)),
        "fig11" => Some(homme_exp::fig11(ctx)),
        "fig12" => Some(homme_exp::fig12(ctx)),
        "fig13" => Some(minighost_exp::fig13(ctx)),
        "fig14" => Some(minighost_exp::fig14(ctx)),
        "fig15" => Some(minighost_exp::fig15(ctx)),
        _ => None,
    }
}
