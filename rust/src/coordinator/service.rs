//! Mapping service: the library exposed as a long-running daemon.
//!
//! Real deployments call the mapper from job launch scripts; this service
//! mirrors that: a thread-per-connection TCP server speaking
//! newline-delimited JSON (the offline vendor set has no tokio; the event
//! loop is std::net + threads).
//!
//! Protocol (one JSON object per line):
//! ```json
//! {"op":"map","tcoords":[[0,0],[0,1]],"pcoords":[[3,3],[3,4]],
//!  "ordering":"FZ","longest_dim":true,"uneven_prime":false}
//! -> {"ok":true,"map":[0,1]}
//! {"op":"ping"} -> {"ok":true,"pong":true}
//! ```

use crate::geom::Coords;
use crate::mapping::{map_tasks, MapConfig};
use crate::sfc::PartOrdering;
use crate::testutil::json::Json;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

/// Server handle: the bound address and a shutdown flag.
pub struct Service {
    pub addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl Service {
    /// Bind and serve in background threads. Pass port 0 for an ephemeral
    /// port (tests).
    pub fn start<A: ToSocketAddrs>(addr: A) -> std::io::Result<Service> {
        let listener = TcpListener::bind(addr)?;
        let addr = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        listener.set_nonblocking(true)?;
        let handle = std::thread::spawn(move || {
            while !stop2.load(Ordering::Relaxed) {
                match listener.accept() {
                    Ok((stream, _)) => {
                        // Detached: the worker exits when its client
                        // disconnects (read_line returns 0). Joining here
                        // would deadlock shutdown on long-lived clients.
                        std::thread::spawn(move || {
                            let _ = handle_conn(stream);
                        });
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(std::time::Duration::from_millis(10));
                    }
                    Err(_) => break,
                }
            }
        });
        Ok(Service {
            addr,
            stop,
            handle: Some(handle),
        })
    }

    pub fn stop(mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Service {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

fn handle_conn(stream: TcpStream) -> std::io::Result<()> {
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = stream;
    let mut line = String::new();
    loop {
        line.clear();
        if reader.read_line(&mut line)? == 0 {
            return Ok(()); // client closed
        }
        let trimmed = line.trim();
        if trimmed.is_empty() {
            continue;
        }
        let reply = handle_request(trimmed);
        writer.write_all(reply.to_string().as_bytes())?;
        writer.write_all(b"\n")?;
        writer.flush()?;
    }
}

fn err(msg: &str) -> Json {
    Json::obj(vec![("ok", Json::Bool(false)), ("error", Json::Str(msg.into()))])
}

/// Handle one request line (exposed for direct unit testing).
pub fn handle_request(line: &str) -> Json {
    let req = match Json::parse(line) {
        Ok(j) => j,
        Err(e) => return err(&format!("bad json: {e}")),
    };
    match req.get("op").and_then(|o| o.as_str()) {
        Some("ping") => Json::obj(vec![("ok", Json::Bool(true)), ("pong", Json::Bool(true))]),
        Some("map") => handle_map(&req),
        Some(op) => err(&format!("unknown op {op}")),
        None => err("missing op"),
    }
}

fn parse_coords(v: &Json) -> Result<Coords, String> {
    let rows = v.as_arr().ok_or("coords must be an array")?;
    if rows.is_empty() {
        return Err("empty coords".into());
    }
    let dim = rows[0].as_arr().ok_or("coord rows must be arrays")?.len();
    if dim == 0 {
        return Err("zero-dimensional coords".into());
    }
    let mut coords = Coords::with_capacity(dim, rows.len());
    let mut buf = vec![0f64; dim];
    for row in rows {
        let vals = row.as_arr().ok_or("coord rows must be arrays")?;
        if vals.len() != dim {
            return Err("ragged coords".into());
        }
        for (k, x) in vals.iter().enumerate() {
            buf[k] = x.as_f64().ok_or("coords must be numbers")?;
        }
        coords.push(&buf);
    }
    Ok(coords)
}

fn handle_map(req: &Json) -> Json {
    let tcoords = match req.get("tcoords").map(parse_coords) {
        Some(Ok(c)) => c,
        Some(Err(e)) => return err(&format!("tcoords: {e}")),
        None => return err("missing tcoords"),
    };
    let pcoords = match req.get("pcoords").map(parse_coords) {
        Some(Ok(c)) => c,
        Some(Err(e)) => return err(&format!("pcoords: {e}")),
        None => return err("missing pcoords"),
    };
    let ordering = req
        .get("ordering")
        .and_then(|o| o.as_str())
        .and_then(PartOrdering::parse)
        .unwrap_or(PartOrdering::FZ);
    let cfg = MapConfig {
        task_ordering: ordering,
        proc_ordering: ordering,
        longest_dim: req
            .get("longest_dim")
            .map(|b| b == &Json::Bool(true))
            .unwrap_or(true),
        uneven_prime: req
            .get("uneven_prime")
            .map(|b| b == &Json::Bool(true))
            .unwrap_or(false),
    };
    let mapping = map_tasks(&tcoords, &pcoords, &cfg);
    Json::obj(vec![
        ("ok", Json::Bool(true)),
        (
            "map",
            Json::Arr(mapping.into_iter().map(|r| Json::Num(r as f64)).collect()),
        ),
    ])
}

/// Simple blocking client.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    pub fn connect<A: ToSocketAddrs>(addr: A) -> std::io::Result<Client> {
        let stream = TcpStream::connect(addr)?;
        Ok(Client {
            reader: BufReader::new(stream.try_clone()?),
            writer: stream,
        })
    }

    pub fn request(&mut self, req: &Json) -> std::io::Result<Json> {
        self.writer.write_all(req.to_string().as_bytes())?;
        self.writer.write_all(b"\n")?;
        self.writer.flush()?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        Json::parse(line.trim())
            .map_err(|e| std::io::Error::new(std::io::ErrorKind::InvalidData, e))
    }

    /// Map tasks to ranks over the wire.
    pub fn map(
        &mut self,
        tcoords: &[Vec<f64>],
        pcoords: &[Vec<f64>],
        ordering: PartOrdering,
    ) -> std::io::Result<Vec<u32>> {
        let mk = |rows: &[Vec<f64>]| {
            Json::Arr(
                rows.iter()
                    .map(|r| Json::Arr(r.iter().map(|&x| Json::Num(x)).collect()))
                    .collect(),
            )
        };
        let req = Json::obj(vec![
            ("op", Json::Str("map".into())),
            ("tcoords", mk(tcoords)),
            ("pcoords", mk(pcoords)),
            ("ordering", Json::Str(ordering.name().into())),
        ]);
        let resp = self.request(&req)?;
        if resp.get("ok") != Some(&Json::Bool(true)) {
            return Err(std::io::Error::other(
                resp.get("error")
                    .and_then(|e| e.as_str())
                    .unwrap_or("unknown error")
                    .to_string(),
            ));
        }
        Ok(resp
            .get("map")
            .and_then(|m| m.as_arr())
            .map(|a| a.iter().filter_map(|x| x.as_f64()).map(|x| x as u32).collect())
            .unwrap_or_default())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ping_pong() {
        let resp = handle_request(r#"{"op":"ping"}"#);
        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)));
        assert_eq!(resp.get("pong"), Some(&Json::Bool(true)));
    }

    #[test]
    fn bad_json_is_an_error() {
        let resp = handle_request("{nope");
        assert_eq!(resp.get("ok"), Some(&Json::Bool(false)));
    }

    #[test]
    fn map_request_roundtrip() {
        let resp = handle_request(
            r#"{"op":"map","tcoords":[[0,0],[0,1],[1,0],[1,1]],
                "pcoords":[[5,5],[5,6],[6,5],[6,6]],"ordering":"FZ"}"#,
        );
        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)), "{resp:?}");
        let m = resp.get("map").unwrap().as_arr().unwrap();
        assert_eq!(m.len(), 4);
    }

    #[test]
    fn ragged_coords_rejected() {
        let resp =
            handle_request(r#"{"op":"map","tcoords":[[0,0],[1]],"pcoords":[[0,0],[1,1]]}"#);
        assert_eq!(resp.get("ok"), Some(&Json::Bool(false)));
    }

    #[test]
    fn end_to_end_over_tcp() {
        let svc = Service::start("127.0.0.1:0").unwrap();
        let mut client = Client::connect(svc.addr).unwrap();
        let t: Vec<Vec<f64>> = (0..8).map(|i| vec![i as f64]).collect();
        let p: Vec<Vec<f64>> = (0..8).map(|i| vec![(7 - i) as f64]).collect();
        let m = client.map(&t, &p, PartOrdering::FZ).unwrap();
        // Both sides are 1D lines: the mapping must pair them monotonically
        // (reversed proc coordinates => task i -> rank 7-i).
        assert_eq!(m, vec![7, 6, 5, 4, 3, 2, 1, 0]);
        svc.stop();
    }
}
