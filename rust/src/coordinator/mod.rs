//! Experiment coordinator: definitions of every table and figure in the
//! paper's evaluation (Section 5), the report renderer, and the mapping
//! service.
//!
//! Each experiment is a pure function from a config (+ seed) to [`report::Table`]s,
//! so `repro <experiment>` output is exactly reproducible. DESIGN.md §4
//! maps each experiment id to the paper artifact it regenerates.

pub mod experiments;
pub mod hier_exp;
pub mod homme_exp;
pub mod minighost_exp;
pub mod numa_exp;
pub mod objective_exp;
pub mod report;
pub mod service;
pub mod table1;

use crate::mapping::rotations::{NativeBackend, WhopsBackend};
use crate::runtime::PjrtBackend;

/// Shared experiment context.
pub struct Ctx {
    /// Paper-scale (`--full`) or laptop-scale (default) workloads.
    pub full: bool,
    /// Base RNG seed for allocations.
    pub seed: u64,
    /// WeightedHops backend: PJRT artifacts when available, else native.
    backend: Backend,
}

enum Backend {
    Pjrt(PjrtBackend),
    Native(NativeBackend),
}

impl Ctx {
    /// Build a context; loads PJRT artifacts when present unless
    /// `force_native`.
    pub fn new(full: bool, seed: u64, force_native: bool) -> Self {
        let backend = if force_native {
            Backend::Native(NativeBackend)
        } else {
            match PjrtBackend::try_default() {
                Some(b) => Backend::Pjrt(b),
                None => Backend::Native(NativeBackend),
            }
        };
        Ctx {
            full,
            seed,
            backend,
        }
    }

    pub fn backend(&self) -> &dyn WhopsBackend {
        match &self.backend {
            Backend::Pjrt(b) => b,
            Backend::Native(b) => b,
        }
    }

    pub fn backend_name(&self) -> &'static str {
        self.backend().name()
    }
}
