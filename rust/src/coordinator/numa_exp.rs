//! NUMA depth-2 vs depth-3 experiment (`numa`): the two-level mapper
//! against the three-level (node→socket→core) mapper of
//! [`crate::hier::HierConfig::numa`], on the MiniGhost (Cray XK7) and
//! HOMME (Titan) presets under the XK7 Interlagos node model.
//!
//! Both depths see the same task graph, coordinates, allocation, rotation
//! budget, and refinement passes; rows report the
//! [`crate::objective::NumaAware`] value and its per-level breakdown —
//! network weighted hops and cross-socket weight — with per-(case, seed)
//! ratios against the depth-2 run (< 1.00 = depth 3 wins). Depth 2 places
//! within nodes blind to sockets, so its cross-socket weight is whatever
//! round-robin rank order happens to produce; depth 3 splits and refines
//! sockets explicitly.

use super::report::{f2, Table};
use super::Ctx;
use crate::apps::homme::{Homme, HommeCoords};
use crate::apps::minighost::MiniGhost;
use crate::apps::TaskGraph;
use crate::geom::Coords;
use crate::hier::{map_hierarchical, HierConfig, IntraNodeStrategy};
use crate::machine::{cray_xk7, titan_full, Allocation, NumaTopology, SparseAllocator};
use crate::objective::eval_numa;
use crate::par::Parallelism;

const ROT: usize = 12;
const PASSES: usize = 4;

fn headers() -> [&'static str; 8] {
    [
        "case",
        "seed",
        "depth",
        "NumaVal",
        "NetWH",
        "XSockW",
        "Numa/d2",
        "XSock/d2",
    ]
}

/// Ratio against the depth-2 denominator; a zero denominator (nothing to
/// improve) reports 1.00 instead of NaN.
fn ratio(v: f64, denom: f64) -> f64 {
    if denom > 0.0 {
        v / denom
    } else {
        1.0
    }
}

/// Run depth 2 and depth 3 on one (graph, coords, allocation) case and
/// append both rows; the depth-2 row is the ratio denominator.
#[allow(clippy::too_many_arguments)]
fn run_case(
    ctx: &Ctx,
    table: &mut Table,
    case: &str,
    seed: u64,
    graph: &TaskGraph,
    tcoords: &Coords,
    alloc: &Allocation,
    topo: NumaTopology,
) {
    let mk = |numa: Option<NumaTopology>| HierConfig {
        intra: IntraNodeStrategy::MinVolume { passes: PASSES },
        max_rotations: ROT,
        numa,
        ..HierConfig::default()
    };
    let d2 = map_hierarchical(graph, tcoords, alloc, &mk(None), ctx.backend());
    let d3 = map_hierarchical(graph, tcoords, alloc, &mk(Some(topo)), ctx.backend());
    let m2 = eval_numa(graph, &d2.task_to_rank, alloc, &topo);
    let m3 = eval_numa(graph, &d3.task_to_rank, alloc, &topo);
    for (depth, m) in [("depth-2", &m2), ("depth-3", &m3)] {
        table.push_row(vec![
            case.to_string(),
            seed.to_string(),
            depth.to_string(),
            f2(m.value),
            f2(m.network_weighted_hops),
            f2(m.socket_weight),
            f2(ratio(m.value, m2.value)),
            f2(ratio(m.socket_weight, m2.socket_weight)),
        ]);
    }
}

/// The `numa` experiment: one table per preset, XK7 Interlagos node model.
pub fn run(ctx: &Ctx) -> Vec<Table> {
    let topo = NumaTopology::xk7();
    let allocator = if ctx.full {
        titan_full()
    } else {
        SparseAllocator {
            machine: cray_xk7(&[10, 8, 10]),
            nodes_per_router: 2,
            ranks_per_node: 16,
            occupancy: 0.4,
        }
    };
    let mg_points: Vec<(usize, [usize; 3])> = if ctx.full {
        vec![(8_192, [32, 16, 16]), (32_768, [32, 32, 32])]
    } else {
        vec![(512, [8, 8, 8]), (2_048, [16, 16, 8])]
    };
    let seeds = [ctx.seed, ctx.seed + 1];
    let ne = if ctx.full { 120 } else { 24 };
    let homme = Homme::new(ne);
    let rpn = allocator.ranks_per_node;
    let jobs: Vec<(usize, u64)> = mg_points
        .iter()
        .map(|&(procs, _)| procs)
        .chain([homme.num_tasks()])
        .flat_map(|procs| seeds.iter().map(move |&seed| (procs / rpn, seed)))
        .collect();
    let allocs: Vec<Allocation> = allocator.allocate_batch(&jobs, Parallelism::auto());

    let mut mg_table = Table::new(
        "NUMA: MiniGhost XK7, depth-2 vs depth-3 under the Interlagos node model",
        &headers(),
    );
    for (pi, &(procs, tdims)) in mg_points.iter().enumerate() {
        let mg = MiniGhost::weak_scaling(tdims);
        let graph = mg.graph();
        for (si, &seed) in seeds.iter().enumerate() {
            run_case(
                ctx,
                &mut mg_table,
                &format!("mg-{procs}"),
                seed,
                &graph,
                &graph.coords,
                &allocs[pi * seeds.len() + si],
                topo,
            );
        }
    }

    let mut homme_table = Table::new(
        "NUMA: HOMME Titan, depth-2 vs depth-3 under the Interlagos node model",
        &headers(),
    );
    let graph = homme.graph();
    let tcoords = homme.coords(HommeCoords::Cube);
    let procs = homme.num_tasks();
    for (si, &seed) in seeds.iter().enumerate() {
        run_case(
            ctx,
            &mut homme_table,
            &format!("homme-{procs}"),
            seed,
            &graph,
            &tcoords,
            &allocs[mg_points.len() * seeds.len() + si],
            topo,
        );
    }
    vec![mg_table, homme_table]
}
