//! NUMA depth-2 vs depth-3 experiment (`numa`): the two-level mapper
//! against the three-level (node→socket→core) mapper of
//! [`crate::hier::HierConfig::numa`], on the MiniGhost (Cray XK7) and
//! HOMME (Titan) presets under the XK7 Interlagos node model — including
//! the **blended** depth-3 run (routed `MaxLinkLoad` network term × NUMA
//! intra-node term through the unified evaluator).
//!
//! All runs see the same task graph, coordinates, allocation, rotation
//! budget, and refinement passes; rows report the
//! [`crate::objective::NumaAware`] value, its per-level breakdown —
//! network weighted hops and cross-socket weight — and the routed
//! bottleneck latency, with per-(case, seed) ratios against the depth-2
//! run (< 1.00 = the run wins). Depth 2 places within nodes blind to
//! sockets; depth 3 splits and refines sockets explicitly; the blended
//! depth-3 run trades some weighted hops for bottleneck relief while
//! keeping the socket structure.

use super::report::{f2, sci, Table};
use super::Ctx;
use crate::apps::homme::{Homme, HommeCoords};
use crate::apps::minighost::MiniGhost;
use crate::apps::TaskGraph;
use crate::geom::Coords;
use crate::hier::{map_hierarchical, HierConfig, IntraNodeStrategy};
use crate::machine::{cray_xk7, titan_full, Allocation, NumaTopology, SparseAllocator};
use crate::metrics::eval_full;
use crate::objective::{eval_numa, ObjectiveKind};
use crate::par::Parallelism;

const ROT: usize = 12;
const PASSES: usize = 4;

fn headers() -> [&'static str; 11] {
    [
        "case",
        "seed",
        "depth",
        "objective",
        "NumaVal",
        "NetWH",
        "XSockW",
        "MaxLat",
        "Numa/d2",
        "XSock/d2",
        "Lat/d2",
    ]
}

/// Ratio against the depth-2 denominator; a zero denominator (nothing to
/// improve) reports 1.00 instead of NaN.
fn ratio(v: f64, denom: f64) -> f64 {
    if denom > 0.0 {
        v / denom
    } else {
        1.0
    }
}

/// Run depth 2, depth 3, and the blended depth 3 on one (graph, coords,
/// allocation) case and append all three rows; the depth-2 row is the
/// ratio denominator.
#[allow(clippy::too_many_arguments)]
fn run_case(
    ctx: &Ctx,
    table: &mut Table,
    case: &str,
    seed: u64,
    graph: &TaskGraph,
    tcoords: &Coords,
    alloc: &Allocation,
    topo: NumaTopology,
) {
    let mk = |numa: Option<NumaTopology>, objective: ObjectiveKind| {
        let mut cfg = HierConfig {
            intra: IntraNodeStrategy::MinVolume { passes: PASSES },
            max_rotations: ROT,
            ..HierConfig::default()
        };
        cfg.spec.numa = numa;
        cfg.spec.objective = objective;
        cfg
    };
    let runs = [
        ("depth-2", "whops", mk(None, ObjectiveKind::WeightedHops)),
        ("depth-3", "whops", mk(Some(topo), ObjectiveKind::WeightedHops)),
        (
            "depth-3",
            "maxload",
            mk(Some(topo), ObjectiveKind::MaxLinkLoad),
        ),
    ];
    let mut denom: Option<(f64, f64, f64)> = None;
    for (depth, objective, cfg) in runs {
        let m = map_hierarchical(graph, tcoords, alloc, &cfg, ctx.backend());
        let nm = eval_numa(graph, &m.task_to_rank, alloc, &topo);
        let lat = eval_full(graph, &m.task_to_rank, alloc)
            .link
            .expect("eval_full computes link metrics")
            .max_latency;
        let (v2, x2, l2) = *denom.get_or_insert((nm.value, nm.socket_weight, lat));
        table.push_row(vec![
            case.to_string(),
            seed.to_string(),
            depth.to_string(),
            objective.to_string(),
            f2(nm.value),
            f2(nm.network_weighted_hops),
            f2(nm.socket_weight),
            sci(lat),
            f2(ratio(nm.value, v2)),
            f2(ratio(nm.socket_weight, x2)),
            f2(ratio(lat, l2)),
        ]);
    }
}

/// The `numa` experiment: one table per preset, XK7 Interlagos node model.
pub fn run(ctx: &Ctx) -> Vec<Table> {
    let topo = NumaTopology::xk7();
    let allocator = if ctx.full {
        titan_full()
    } else {
        SparseAllocator {
            machine: cray_xk7(&[10, 8, 10]),
            nodes_per_router: 2,
            ranks_per_node: 16,
            occupancy: 0.4,
        }
    };
    let mg_points: Vec<(usize, [usize; 3])> = if ctx.full {
        vec![(8_192, [32, 16, 16]), (32_768, [32, 32, 32])]
    } else {
        vec![(512, [8, 8, 8]), (2_048, [16, 16, 8])]
    };
    let seeds = [ctx.seed, ctx.seed + 1];
    let ne = if ctx.full { 120 } else { 24 };
    let homme = Homme::new(ne);
    let rpn = allocator.ranks_per_node;
    let jobs: Vec<(usize, u64)> = mg_points
        .iter()
        .map(|&(procs, _)| procs)
        .chain([homme.num_tasks()])
        .flat_map(|procs| seeds.iter().map(move |&seed| (procs / rpn, seed)))
        .collect();
    let allocs: Vec<Allocation> = allocator.allocate_batch(&jobs, Parallelism::auto());

    let mut mg_table = Table::new(
        "NUMA: MiniGhost XK7, depth-2 vs depth-3 under the Interlagos node model",
        &headers(),
    );
    for (pi, &(procs, tdims)) in mg_points.iter().enumerate() {
        let mg = MiniGhost::weak_scaling(tdims);
        let graph = mg.graph();
        for (si, &seed) in seeds.iter().enumerate() {
            run_case(
                ctx,
                &mut mg_table,
                &format!("mg-{procs}"),
                seed,
                &graph,
                &graph.coords,
                &allocs[pi * seeds.len() + si],
                topo,
            );
        }
    }

    let mut homme_table = Table::new(
        "NUMA: HOMME Titan, depth-2 vs depth-3 under the Interlagos node model",
        &headers(),
    );
    let graph = homme.graph();
    let tcoords = homme.coords(HommeCoords::Cube);
    let procs = homme.num_tasks();
    for (si, &seed) in seeds.iter().enumerate() {
        run_case(
            ctx,
            &mut homme_table,
            &format!("homme-{procs}"),
            seed,
            &graph,
            &tcoords,
            &allocs[mg_points.len() * seeds.len() + si],
            topo,
        );
    }
    vec![mg_table, homme_table]
}
