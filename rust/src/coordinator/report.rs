//! Report tables: the textual equivalent of the paper's tables/figures.

use std::fmt::Write as _;
use std::path::Path;

/// A rendered experiment result.
#[derive(Clone, Debug)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn push_row(&mut self, row: Vec<String>) {
        assert_eq!(row.len(), self.headers.len(), "ragged row in {}", self.title);
        self.rows.push(row);
    }

    /// GitHub-flavored markdown rendering.
    pub fn markdown(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(out, "### {}\n", self.title);
        let widths: Vec<usize> = self
            .headers
            .iter()
            .enumerate()
            .map(|(i, h)| {
                self.rows
                    .iter()
                    .map(|r| r[i].len())
                    .chain([h.len()])
                    .max()
                    .unwrap_or(1)
            })
            .collect();
        let line = |cells: &[String], out: &mut String| {
            let _ = write!(out, "|");
            for (i, c) in cells.iter().enumerate() {
                let _ = write!(out, " {:>w$} |", c, w = widths[i]);
            }
            let _ = writeln!(out);
        };
        line(&self.headers, &mut out);
        let _ = writeln!(
            out,
            "|{}",
            widths
                .iter()
                .map(|w| format!("{:-<w$}|", "", w = w + 2))
                .collect::<String>()
        );
        for r in &self.rows {
            line(r, &mut out);
        }
        out
    }

    /// Tab-separated rendering (for plotting scripts).
    pub fn tsv(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(out, "# {}", self.title);
        let _ = writeln!(out, "{}", self.headers.join("\t"));
        for r in &self.rows {
            let _ = writeln!(out, "{}", r.join("\t"));
        }
        out
    }

    /// Write TSV to `dir/<slug>.tsv`.
    pub fn write_tsv(&self, dir: &Path) -> std::io::Result<()> {
        std::fs::create_dir_all(dir)?;
        let slug: String = self
            .title
            .chars()
            .map(|c| if c.is_alphanumeric() { c.to_ascii_lowercase() } else { '_' })
            .collect::<String>()
            .split('_')
            .filter(|s| !s.is_empty())
            .collect::<Vec<_>>()
            .join("_");
        std::fs::write(dir.join(format!("{slug}.tsv")), self.tsv())
    }
}

/// Format helpers.
pub fn f2(x: f64) -> String {
    format!("{x:.2}")
}

pub fn f3(x: f64) -> String {
    format!("{x:.3}")
}

pub fn sci(x: f64) -> String {
    format!("{x:.3e}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn markdown_renders() {
        let mut t = Table::new("Demo", &["a", "b"]);
        t.push_row(vec!["1".into(), "2.50".into()]);
        let md = t.markdown();
        assert!(md.contains("### Demo"));
        assert!(md.contains("| 1 | 2.50 |"));
    }

    #[test]
    fn tsv_renders() {
        let mut t = Table::new("Demo", &["a", "b"]);
        t.push_row(vec!["1".into(), "2".into()]);
        assert_eq!(t.tsv(), "# Demo\na\tb\n1\t2\n");
    }

    #[test]
    #[should_panic(expected = "ragged")]
    fn ragged_rows_rejected() {
        let mut t = Table::new("Demo", &["a", "b"]);
        t.push_row(vec!["1".into()]);
    }
}
