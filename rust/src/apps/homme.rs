//! E3SM/HOMME atmospheric dynamical core (Sections 5.2–5.3.1): a spectral
//! element mesh on the cube-sphere.
//!
//! The sphere is projected onto a cube with six `ne x ne` faces of
//! quadrilateral surface elements; each element is a vertical atmosphere
//! column and one task. Tasks communicate with edge-adjacent elements
//! (including across cube-face boundaries).
//!
//! Coordinate representations (Fig. 7):
//! * `sphere` — 3D element centroids on the unit sphere,
//! * `cube`   — 3D centroids on the cube surface (before normalization),
//! * `face2d` — the cube unfolded: the four equatorial faces form a ring in
//!   x (which connects the furthest elements along x, matching the torus
//!   wraparound exploited by the mapper), with the polar faces above/below
//!   face 0.
//!
//! The default HOMME partition/mapping uses per-face Hilbert SFCs
//! (Section 5.2, "SFC").

use super::{Edge, TaskGraph};
use crate::geom::Coords;
use crate::sfc::hilbert::hilbert_index;
use std::collections::HashMap;

/// Which geometric representation of the elements to expose as task
/// coordinates (Fig. 7 and the "Application Specific Optimizations" of
/// Section 5.2).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum HommeCoords {
    Sphere,
    Cube,
    Face2D,
}

impl HommeCoords {
    pub fn name(&self) -> &'static str {
        match self {
            HommeCoords::Sphere => "Sphere",
            HommeCoords::Cube => "Cube",
            HommeCoords::Face2D => "2DFace",
        }
    }
}

/// HOMME cube-sphere workload.
#[derive(Clone, Copy, Debug)]
pub struct Homme {
    /// Elements along each edge of each cube face (paper: 128 on Mira,
    /// 120 on Titan).
    pub ne: usize,
    /// Message volume per element-edge exchange, bytes. HOMME's halo
    /// exchanges carry np x nlev spectral data for several fields; the
    /// paper's operative fact is that messages are *large* (Section 5.3.1),
    /// so the default is 64 KiB per element edge.
    pub edge_bytes: f64,
}

/// Face axes: (center, u-tangent, v-tangent) of each cube face. The four
/// equatorial faces 0..3 ring the equator west-to-east; 4 is the north pole,
/// 5 the south.
const FACES: [([f64; 3], [f64; 3], [f64; 3]); 6] = [
    ([1., 0., 0.], [0., 1., 0.], [0., 0., 1.]),   // +X
    ([0., 1., 0.], [-1., 0., 0.], [0., 0., 1.]),  // +Y
    ([-1., 0., 0.], [0., -1., 0.], [0., 0., 1.]), // -X
    ([0., -1., 0.], [1., 0., 0.], [0., 0., 1.]),  // -Y
    ([0., 0., 1.], [0., 1., 0.], [-1., 0., 0.]),  // +Z (north)
    ([0., 0., -1.], [0., 1., 0.], [1., 0., 0.]),  // -Z (south)
];

impl Homme {
    pub fn new(ne: usize) -> Self {
        Homme {
            ne,
            edge_bytes: 65536.0,
        }
    }

    pub fn num_tasks(&self) -> usize {
        6 * self.ne * self.ne
    }

    #[inline]
    fn elem_id(&self, face: usize, i: usize, j: usize) -> usize {
        (face * self.ne + j) * self.ne + i
    }

    /// 3D cube-surface position of the center of element `(face, i, j)`,
    /// scaled by `2*ne` so all element centers and edge midpoints are exact
    /// integers (used for watertight cross-face adjacency).
    fn cube_center_scaled(&self, face: usize, i: usize, j: usize) -> [i64; 3] {
        // Local coordinates in (-ne, ne): center of cell (i,j) is at
        // (2i+1-ne, 2j+1-ne); the face itself is at +/- ne along its axis.
        let (c, u, v) = (FACES[face].0, FACES[face].1, FACES[face].2);
        let a = (2 * i as i64 + 1) - self.ne as i64;
        let b = (2 * j as i64 + 1) - self.ne as i64;
        let ne = self.ne as i64;
        let mut p = [0i64; 3];
        for k in 0..3 {
            p[k] = (c[k] as i64) * ne + a * (u[k] as i64) + b * (v[k] as i64);
        }
        p
    }

    /// The four edge-midpoints of element `(face, i, j)` on the scaled cube
    /// surface. Elements sharing an edge — within a face or across faces —
    /// share exactly one midpoint, which makes adjacency a hash join rather
    /// than a per-face-pair orientation table.
    fn edge_midpoints_scaled(&self, face: usize, i: usize, j: usize) -> [[i64; 3]; 4] {
        let (c, u, v) = (FACES[face].0, FACES[face].1, FACES[face].2);
        let ne = self.ne as i64;
        let a = (2 * i as i64 + 1) - ne;
        let b = (2 * j as i64 + 1) - ne;
        let mk = |da: i64, db: i64| -> [i64; 3] {
            let mut p = [0i64; 3];
            for k in 0..3 {
                p[k] = (c[k] as i64) * ne + (a + da) * (u[k] as i64) + (b + db) * (v[k] as i64);
            }
            // Clamp to the cube surface: midpoints on a face edge stick out
            // along the tangent; project them onto the cube (|coord| <= ne).
            for x in &mut p {
                *x = (*x).clamp(-ne, ne);
            }
            p
        };
        [mk(-1, 0), mk(1, 0), mk(0, -1), mk(0, 1)]
    }

    /// Build the element communication graph (edge-adjacent elements).
    pub fn graph(&self) -> TaskGraph {
        let ne = self.ne;
        let mut mid_owner: HashMap<[i64; 3], u32> = HashMap::with_capacity(self.num_tasks() * 2);
        let mut edges = Vec::with_capacity(self.num_tasks() * 2);
        for face in 0..6 {
            for j in 0..ne {
                for i in 0..ne {
                    let id = self.elem_id(face, i, j) as u32;
                    for mid in self.edge_midpoints_scaled(face, i, j) {
                        match mid_owner.entry(mid) {
                            std::collections::hash_map::Entry::Occupied(o) => {
                                let other = *o.get();
                                debug_assert_ne!(other, id);
                                edges.push(Edge {
                                    u: other.min(id),
                                    v: other.max(id),
                                    w: self.edge_bytes,
                                });
                            }
                            std::collections::hash_map::Entry::Vacant(s) => {
                                s.insert(id);
                            }
                        }
                    }
                }
            }
        }
        TaskGraph {
            num_tasks: self.num_tasks(),
            edges,
            coords: self.coords(HommeCoords::Sphere),
        }
    }

    /// Task coordinates under the chosen representation.
    pub fn coords(&self, which: HommeCoords) -> Coords {
        let ne = self.ne;
        match which {
            HommeCoords::Cube | HommeCoords::Sphere => {
                let mut c = Coords::with_capacity(3, self.num_tasks());
                for face in 0..6 {
                    for j in 0..ne {
                        for i in 0..ne {
                            let p = self.cube_center_scaled(face, i, j);
                            let mut v = [
                                p[0] as f64 / (2 * ne) as f64,
                                p[1] as f64 / (2 * ne) as f64,
                                p[2] as f64 / (2 * ne) as f64,
                            ];
                            if which == HommeCoords::Sphere {
                                let norm =
                                    (v[0] * v[0] + v[1] * v[1] + v[2] * v[2]).sqrt();
                                for x in &mut v {
                                    *x /= norm;
                                }
                            }
                            c.push(&v);
                        }
                    }
                }
                c
            }
            HommeCoords::Face2D => {
                // Unfold: equatorial faces 0..3 side by side (x ring of
                // extent 4*ne); polar faces above/below face 0.
                let mut c = Coords::with_capacity(2, self.num_tasks());
                for face in 0..6 {
                    for j in 0..ne {
                        for i in 0..ne {
                            let (x, y) = match face {
                                0..=3 => ((face * ne + i) as f64, j as f64),
                                4 => (i as f64, (ne + j) as f64), // north above
                                _ => (i as f64, -((ne - j) as f64)), // south below
                            };
                            c.push(&[x, y]);
                        }
                    }
                }
                c
            }
        }
    }

    /// HOMME's default partition+mapping: per-face Hilbert SFC. Elements are
    /// ordered face by face, Hilbert within the face; the order is chopped
    /// into `num_parts` contiguous chunks; rank = chunk index (Section 5.2,
    /// "the mapping is the output part number from the SFC").
    ///
    /// Returns `part_of_task` (which is also `rank_of_task` when one part
    /// per rank).
    pub fn sfc_partition(&self, num_parts: usize) -> Vec<u32> {
        let ne = self.ne;
        let n = self.num_tasks();
        assert!(num_parts >= 1 && num_parts <= n);
        let bits = 1 + (ne as u64).next_power_of_two().trailing_zeros();
        // Global element order: faces in sequence, Hilbert within each.
        let mut order = Vec::with_capacity(n);
        for face in 0..6 {
            let mut keyed: Vec<(u128, usize)> = Vec::with_capacity(ne * ne);
            for j in 0..ne {
                for i in 0..ne {
                    keyed.push((
                        hilbert_index(&[i as u64, j as u64], bits),
                        self.elem_id(face, i, j),
                    ));
                }
            }
            keyed.sort_unstable();
            order.extend(keyed.into_iter().map(|(_, id)| id));
        }
        // Chop into equal chunks (remainder spread over the first chunks).
        let mut part_of = vec![0u32; n];
        let base = n / num_parts;
        let extra = n % num_parts;
        let mut pos = 0usize;
        for p in 0..num_parts {
            let len = base + usize::from(p < extra);
            for _ in 0..len {
                part_of[order[pos]] = p as u32;
                pos += 1;
            }
        }
        part_of
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn element_count() {
        let h = Homme::new(8);
        assert_eq!(h.num_tasks(), 384);
    }

    #[test]
    fn graph_is_4_regular() {
        // Every cube-sphere element has exactly 4 edge neighbors (closed
        // surface, no boundary).
        let h = Homme::new(6);
        let g = h.graph();
        g.validate().unwrap();
        let deg = g.degrees();
        assert!(
            deg.iter().all(|&d| d == 4),
            "degrees: min {} max {}",
            deg.iter().min().unwrap(),
            deg.iter().max().unwrap()
        );
        // Closed surface: |E| = 2 * |V|.
        assert_eq!(g.edges.len(), 2 * g.num_tasks);
    }

    #[test]
    fn no_duplicate_edges() {
        let h = Homme::new(4);
        let g = h.graph();
        let mut seen = std::collections::HashSet::new();
        for e in &g.edges {
            assert!(seen.insert((e.u, e.v)), "dup edge {:?}", (e.u, e.v));
        }
    }

    #[test]
    fn sphere_coords_unit_norm() {
        let h = Homme::new(4);
        let c = h.coords(HommeCoords::Sphere);
        for i in 0..c.len() {
            let p = c.point_vec(i);
            let n = (p[0] * p[0] + p[1] * p[1] + p[2] * p[2]).sqrt();
            assert!((n - 1.0).abs() < 1e-12);
        }
    }

    #[test]
    fn cube_coords_on_surface() {
        let h = Homme::new(4);
        let c = h.coords(HommeCoords::Cube);
        for i in 0..c.len() {
            let p = c.point_vec(i);
            let m = p.iter().fold(0.0f64, |a, &b| a.max(b.abs()));
            assert!((m - 0.5).abs() < 1e-12, "not on cube surface: {p:?}");
        }
    }

    #[test]
    fn face2d_ring_extent() {
        let h = Homme::new(8);
        let c = h.coords(HommeCoords::Face2D);
        let bb = c.bbox();
        assert_eq!(bb.hi[0] - bb.lo[0] + 1.0, 32.0); // 4*ne ring
    }

    #[test]
    fn sfc_partition_balanced() {
        let h = Homme::new(8);
        let parts = h.sfc_partition(16);
        let mut counts = vec![0usize; 16];
        for &p in &parts {
            counts[p as usize] += 1;
        }
        assert!(counts.iter().all(|&c| c == 384 / 16));
    }

    #[test]
    fn sfc_partition_is_connected_within_face() {
        // Parts from a Hilbert SFC on one face should be compact: the
        // average intra-part spread must be far below random assignment.
        let h = Homme::new(16);
        let parts = h.sfc_partition(96); // 16 elements per part
        let g = h.graph();
        // Count cut edges; SFC partition should cut far fewer than half.
        let cut = g
            .edges
            .iter()
            .filter(|e| parts[e.u as usize] != parts[e.v as usize])
            .count();
        assert!(
            (cut as f64) < 0.35 * g.edges.len() as f64,
            "cut fraction {}",
            cut as f64 / g.edges.len() as f64
        );
    }
}
