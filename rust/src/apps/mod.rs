//! Application workload generators: the task-communication graphs and task
//! coordinates (Section 3's `G_t` plus the geometric representation of
//! Section 4) for the paper's workloads.
//!
//! * `stencil` — generic td-dimensional mesh/torus nearest-neighbor graphs
//!   (the Table 1 workloads).
//! * `minighost` — the MiniGhost proxy app: 3D 7-point stencil, x-then-y-
//!   then-z task numbering, `Group` 2x2x4 reordering (Section 5.3.2).
//! * `homme` — E3SM/HOMME: cube-sphere spectral-element mesh, sphere/cube/
//!   2D-face coordinates (Fig. 7), default Hilbert SFC partition
//!   (Sections 5.2–5.3.1).

pub mod homme;
pub mod minighost;
pub mod stencil;

use crate::geom::Coords;

/// An undirected communication edge between two tasks with a message volume
/// (bytes per exchange, the `w(t1,t2)` of Section 3).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Edge {
    pub u: u32,
    pub v: u32,
    pub w: f64,
}

/// The task communication graph `G_t` plus task coordinates.
#[derive(Clone, Debug)]
pub struct TaskGraph {
    pub num_tasks: usize,
    pub edges: Vec<Edge>,
    /// Task coordinates (`tcoords` of Algorithm 1): the centroid of each
    /// task's application domain.
    pub coords: Coords,
}

impl TaskGraph {
    /// Validate internal consistency (debug/test helper).
    pub fn validate(&self) -> Result<(), String> {
        if self.coords.len() != self.num_tasks {
            return Err(format!(
                "coords len {} != num_tasks {}",
                self.coords.len(),
                self.num_tasks
            ));
        }
        for e in &self.edges {
            if e.u as usize >= self.num_tasks || e.v as usize >= self.num_tasks {
                return Err(format!("edge ({}, {}) out of range", e.u, e.v));
            }
            if e.u == e.v {
                return Err(format!("self-loop at {}", e.u));
            }
            if !(e.w > 0.0) {
                return Err(format!("non-positive weight {} on ({},{})", e.w, e.u, e.v));
            }
        }
        Ok(())
    }

    /// Total communication volume (sum of edge weights).
    pub fn total_volume(&self) -> f64 {
        self.edges.iter().map(|e| e.w).sum()
    }

    /// Degree of each task.
    pub fn degrees(&self) -> Vec<u32> {
        let mut deg = vec![0u32; self.num_tasks];
        for e in &self.edges {
            deg[e.u as usize] += 1;
            deg[e.v as usize] += 1;
        }
        deg
    }
}
