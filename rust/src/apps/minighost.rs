//! MiniGhost proxy application (Section 5.3.2): a 3D seven-point-stencil
//! finite-difference mini-app with explicit time stepping.
//!
//! Tasks own `cells^3`-cell subgrids of a `tx x ty x tz` task grid; subgrids
//! are assigned to tasks sweeping x first, then y, then z, so task `i`
//! communicates with `i±1`, `i±tx`, `i±tx·ty` (non-periodic boundaries).
//! Per exchange, a face of `cells^2` points for each of `nvars` variables is
//! sent (8-byte values): with the paper's 60^3 / 40-variable configuration
//! that is 60·60·8·40 = 1.152 MB — the "about 1 MB" messages of
//! Section 5.3.2.

use super::{stencil::stencil_graph, TaskGraph};

/// MiniGhost workload configuration.
#[derive(Clone, Copy, Debug)]
pub struct MiniGhost {
    /// Task grid extents (tnum_x, tnum_y, tnum_z).
    pub tdims: [usize; 3],
    /// Cells per task per dimension (paper: 60).
    pub cells: usize,
    /// Variables per grid point (paper: 40).
    pub nvars: usize,
}

impl MiniGhost {
    /// The paper's weak-scaling configuration for a given task count:
    /// 60x60x60 cells per task, 40 variables.
    pub fn weak_scaling(tdims: [usize; 3]) -> Self {
        MiniGhost {
            tdims,
            cells: 60,
            nvars: 40,
        }
    }

    pub fn num_tasks(&self) -> usize {
        self.tdims.iter().product()
    }

    /// Face-exchange message volume in bytes.
    pub fn face_bytes(&self) -> f64 {
        (self.cells * self.cells * self.nvars * 8) as f64
    }

    /// The task communication graph: 3D mesh stencil (non-periodic), task
    /// coordinates = subgrid indices (the subgrid center in units of
    /// subgrids — identical geometry, cheaper numbers).
    pub fn graph(&self) -> TaskGraph {
        stencil_graph(&self.tdims, false, self.face_bytes())
    }

    /// Default MiniGhost mapping: task `i` is performed by rank `i`.
    pub fn default_order(&self) -> Vec<u32> {
        (0..self.num_tasks() as u32).collect()
    }

    /// MiniGhost's application-specific `Group` mapping for multicore nodes
    /// (Section 5.3.2): tasks are reordered into 2x2x4 blocks so the 16
    /// tasks of a block land on the 16 cores of one node.
    ///
    /// Returns `rank_of_task`: task `t` runs on rank `group[t]`.
    pub fn group_order(&self) -> Vec<u32> {
        self.block_order([2, 2, 4])
    }

    /// General block reorder: tasks are visited block-by-block (blocks in
    /// x-then-y-then-z order, tasks within a block likewise) and assigned
    /// consecutive ranks. Handles non-divisible extents with partial edge
    /// blocks.
    pub fn block_order(&self, block: [usize; 3]) -> Vec<u32> {
        let [tx, ty, tz] = self.tdims;
        let nb = [tx.div_ceil(block[0]), ty.div_ceil(block[1]), tz.div_ceil(block[2])];
        let mut rank_of_task = vec![0u32; self.num_tasks()];
        let mut next_rank = 0u32;
        for bz in 0..nb[2] {
            for by in 0..nb[1] {
                for bx in 0..nb[0] {
                    for z in (bz * block[2])..((bz * block[2] + block[2]).min(tz)) {
                        for y in (by * block[1])..((by * block[1] + block[1]).min(ty)) {
                            for x in (bx * block[0])..((bx * block[0] + block[0]).min(tx)) {
                                let task = x + tx * (y + ty * z);
                                rank_of_task[task] = next_rank;
                                next_rank += 1;
                            }
                        }
                    }
                }
            }
        }
        rank_of_task
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_message_size() {
        let mg = MiniGhost::weak_scaling([8, 8, 8]);
        assert_eq!(mg.face_bytes(), 1_152_000.0); // ~1 MB, as in the paper
    }

    #[test]
    fn graph_shape() {
        let mg = MiniGhost::weak_scaling([4, 4, 2]);
        let g = mg.graph();
        assert_eq!(g.num_tasks, 32);
        g.validate().unwrap();
        // Interior tasks have 6 neighbors, corners 3.
        let deg = g.degrees();
        assert_eq!(deg[0], 3);
    }

    #[test]
    fn default_order_is_identity() {
        let mg = MiniGhost::weak_scaling([2, 2, 2]);
        assert_eq!(mg.default_order(), vec![0, 1, 2, 3, 4, 5, 6, 7]);
    }

    #[test]
    fn group_order_is_permutation() {
        let mg = MiniGhost::weak_scaling([4, 4, 8]);
        let order = mg.group_order();
        let mut s = order.clone();
        s.sort_unstable();
        assert_eq!(s, (0..mg.num_tasks() as u32).collect::<Vec<_>>());
    }

    #[test]
    fn group_blocks_are_rank_contiguous() {
        // The 16 tasks of the first 2x2x4 block must get ranks 0..16.
        let mg = MiniGhost::weak_scaling([4, 4, 8]);
        let order = mg.group_order();
        let mut block_ranks = Vec::new();
        for z in 0..4 {
            for y in 0..2 {
                for x in 0..2 {
                    let task = x + 4 * (y + 4 * z);
                    block_ranks.push(order[task]);
                }
            }
        }
        block_ranks.sort_unstable();
        assert_eq!(block_ranks, (0..16).collect::<Vec<u32>>());
    }

    #[test]
    fn group_handles_non_divisible() {
        let mg = MiniGhost::weak_scaling([3, 3, 5]);
        let order = mg.group_order();
        let mut s = order.clone();
        s.sort_unstable();
        assert_eq!(s, (0..45u32).collect::<Vec<_>>());
    }
}
