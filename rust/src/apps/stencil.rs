//! Generic td-dimensional stencil task graphs (the Table 1 workloads):
//! tasks on a regular grid, each communicating with its immediate neighbors
//! along every dimension; optional wraparound ("torus-connected tasks").

use super::{Edge, TaskGraph};
use crate::geom::Coords;

/// Build a td-dimensional stencil graph over a `dims` grid. Tasks are
/// numbered mixed-radix with dimension 0 fastest. If `torus`, tasks on the
/// boundary also communicate with their wraparound neighbor (unless the
/// dimension has extent <= 2, where the wrap edge would duplicate the mesh
/// edge). All messages have volume `weight`.
pub fn stencil_graph(dims: &[usize], torus: bool, weight: f64) -> TaskGraph {
    let d = dims.len();
    let n: usize = dims.iter().product();
    let mut coords = Coords::with_capacity(d, n);
    let mut idx = vec![0usize; d];
    let mut point = vec![0f64; d];
    for _ in 0..n {
        for k in 0..d {
            point[k] = idx[k] as f64;
        }
        coords.push(&point);
        for k in 0..d {
            idx[k] += 1;
            if idx[k] < dims[k] {
                break;
            }
            idx[k] = 0;
        }
    }
    // Edges: +1 neighbor per dimension (each undirected pair once).
    let mut edges = Vec::with_capacity(n * d);
    let mut strides = vec![1usize; d];
    for k in 1..d {
        strides[k] = strides[k - 1] * dims[k - 1];
    }
    let mut idx = vec![0usize; d];
    for t in 0..n {
        for k in 0..d {
            if idx[k] + 1 < dims[k] {
                edges.push(Edge {
                    u: t as u32,
                    v: (t + strides[k]) as u32,
                    w: weight,
                });
            } else if torus && dims[k] > 2 {
                // wrap edge from the last cell back to the first
                let v = t - (dims[k] - 1) * strides[k];
                edges.push(Edge {
                    u: v as u32,
                    v: t as u32,
                    w: weight,
                });
            }
        }
        for k in 0..d {
            idx[k] += 1;
            if idx[k] < dims[k] {
                break;
            }
            idx[k] = 0;
        }
    }
    TaskGraph {
        num_tasks: n,
        edges,
        coords,
    }
}

/// Equal-extent grid helper: `k` cells along each of `d` dimensions.
pub fn cube_dims(d: usize, total: usize) -> Vec<usize> {
    let k = (total as f64).powf(1.0 / d as f64).round() as usize;
    assert_eq!(
        k.pow(d as u32),
        total,
        "total {total} is not a perfect {d}-th power"
    );
    vec![k; d]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mesh_edge_count_1d() {
        let g = stencil_graph(&[8], false, 1.0);
        assert_eq!(g.num_tasks, 8);
        assert_eq!(g.edges.len(), 7);
        g.validate().unwrap();
    }

    #[test]
    fn torus_edge_count_1d() {
        let g = stencil_graph(&[8], true, 1.0);
        assert_eq!(g.edges.len(), 8);
        g.validate().unwrap();
    }

    #[test]
    fn mesh_edge_count_3d() {
        // 4x4x4 mesh: 3 * 4*4*3 = 144 edges.
        let g = stencil_graph(&[4, 4, 4], false, 1.0);
        assert_eq!(g.edges.len(), 144);
        g.validate().unwrap();
    }

    #[test]
    fn torus_edge_count_3d() {
        // 4x4x4 torus: 3 * 64 = 192 edges.
        let g = stencil_graph(&[4, 4, 4], true, 1.0);
        assert_eq!(g.edges.len(), 192);
    }

    #[test]
    fn no_duplicate_wrap_for_extent_2() {
        // Extent-2 ring: wrap edge == mesh edge, must not duplicate.
        let g = stencil_graph(&[2], true, 1.0);
        assert_eq!(g.edges.len(), 1);
    }

    #[test]
    fn interior_degree_is_2d() {
        let g = stencil_graph(&[5, 5], false, 1.0);
        let deg = g.degrees();
        // Center task (2,2) = 2 + 2*5 = 12 has degree 4.
        assert_eq!(deg[12], 4);
        // Corner has degree 2.
        assert_eq!(deg[0], 2);
    }

    #[test]
    fn torus_degree_uniform() {
        let g = stencil_graph(&[4, 4, 4], true, 1.0);
        let deg = g.degrees();
        assert!(deg.iter().all(|&d| d == 6), "every task has 6 neighbors");
    }

    #[test]
    fn coords_match_task_numbering() {
        let g = stencil_graph(&[3, 2], false, 1.0);
        // task 4 = (1, 1)
        assert_eq!(g.coords.point_vec(4), vec![1.0, 1.0]);
    }

    #[test]
    fn cube_dims_exact() {
        assert_eq!(cube_dims(3, 4096), vec![16, 16, 16]);
    }

    #[test]
    #[should_panic]
    fn cube_dims_rejects_non_power() {
        cube_dims(3, 100);
    }
}
