//! Geometric primitives: multi-dimensional point sets in structure-of-arrays
//! layout, axis transforms (permute / flip / scale), and bounding boxes.
//!
//! The paper's algorithms (MJ partitioning, coordinate shifting, rotations,
//! bandwidth scaling, box transforms) all operate per-axis, so coordinates
//! are stored one contiguous `Vec<f64>` per axis.

pub mod coords;

pub use coords::{BoundingBox, Coords};

/// Maximum supported dimensionality. Table 1 of the paper uses up to
/// 10-dimensional task/processor sets.
pub const MAX_DIM: usize = 16;
