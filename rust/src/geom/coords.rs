//! Structure-of-arrays coordinate sets.

use super::MAX_DIM;

/// A set of `n` points in `dim` dimensions, one contiguous array per axis.
#[derive(Clone, Debug, PartialEq)]
pub struct Coords {
    axes: Vec<Vec<f64>>,
}

impl Coords {
    /// Empty coordinate set of a given dimensionality.
    pub fn new(dim: usize) -> Self {
        assert!(dim >= 1 && dim <= MAX_DIM, "dim {dim} out of range");
        Coords {
            axes: vec![Vec::new(); dim],
        }
    }

    /// Pre-allocated empty set.
    pub fn with_capacity(dim: usize, n: usize) -> Self {
        assert!(dim >= 1 && dim <= MAX_DIM, "dim {dim} out of range");
        Coords {
            axes: vec![Vec::with_capacity(n); dim],
        }
    }

    /// Build from per-axis arrays (all must be equal length).
    pub fn from_axes(axes: Vec<Vec<f64>>) -> Self {
        assert!(!axes.is_empty() && axes.len() <= MAX_DIM);
        let n = axes[0].len();
        assert!(axes.iter().all(|a| a.len() == n), "ragged axes");
        Coords { axes }
    }

    /// Build from a point iterator (row-major).
    pub fn from_points<I>(dim: usize, points: I) -> Self
    where
        I: IntoIterator<Item = Vec<f64>>,
    {
        let mut c = Coords::new(dim);
        for p in points {
            c.push(&p);
        }
        c
    }

    pub fn dim(&self) -> usize {
        self.axes.len()
    }

    pub fn len(&self) -> usize {
        self.axes[0].len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn push(&mut self, p: &[f64]) {
        assert_eq!(p.len(), self.dim());
        for (axis, &v) in self.axes.iter_mut().zip(p) {
            axis.push(v);
        }
    }

    #[inline]
    pub fn axis(&self, d: usize) -> &[f64] {
        &self.axes[d]
    }

    #[inline]
    pub fn axis_mut(&mut self, d: usize) -> &mut [f64] {
        &mut self.axes[d]
    }

    #[inline]
    pub fn get(&self, d: usize, i: usize) -> f64 {
        self.axes[d][i]
    }

    /// Copy point `i` into a fixed-size buffer, returning the filled slice.
    pub fn point<'a>(&self, i: usize, buf: &'a mut [f64; MAX_DIM]) -> &'a [f64] {
        for (d, axis) in self.axes.iter().enumerate() {
            buf[d] = axis[i];
        }
        &buf[..self.dim()]
    }

    /// Point as a fresh Vec (convenience for tests / examples).
    pub fn point_vec(&self, i: usize) -> Vec<f64> {
        self.axes.iter().map(|a| a[i]).collect()
    }

    /// Reorder axes: output axis `d` = input axis `perm[d]`.
    pub fn permute_axes(&self, perm: &[usize]) -> Coords {
        assert_eq!(perm.len(), self.dim());
        Coords {
            axes: perm.iter().map(|&p| self.axes[p].clone()).collect(),
        }
    }

    /// Keep only the listed axes (used by the "+E" optimization, which drops
    /// the BG/Q E dimension before partitioning the processors).
    pub fn select_axes(&self, keep: &[usize]) -> Coords {
        assert!(!keep.is_empty());
        Coords {
            axes: keep.iter().map(|&d| self.axes[d].clone()).collect(),
        }
    }

    /// Append extra axes (used by the Z2_3 box transform, 3D -> 6D).
    pub fn extend_axes(&mut self, extra: Vec<Vec<f64>>) {
        for a in &extra {
            assert_eq!(a.len(), self.len());
        }
        self.axes.extend(extra);
        assert!(self.dim() <= MAX_DIM);
    }

    /// Multiply every coordinate of axis `d` by `s`.
    pub fn scale_axis(&mut self, d: usize, s: f64) {
        for v in &mut self.axes[d] {
            *v *= s;
        }
    }

    /// Map axis `d` through a monotone table: `v -> table[v as usize]`.
    /// Used by bandwidth scaling, where integer router coordinates become
    /// cumulative 1/bandwidth path costs.
    pub fn remap_axis(&mut self, d: usize, table: &[f64]) {
        for v in &mut self.axes[d] {
            let idx = *v as usize;
            debug_assert!(idx < table.len(), "coordinate {v} outside table");
            *v = table[idx.min(table.len() - 1)];
        }
    }

    /// Axis-aligned bounding box.
    pub fn bbox(&self) -> BoundingBox {
        let dim = self.dim();
        let mut lo = vec![f64::INFINITY; dim];
        let mut hi = vec![f64::NEG_INFINITY; dim];
        for d in 0..dim {
            for &v in &self.axes[d] {
                if v < lo[d] {
                    lo[d] = v;
                }
                if v > hi[d] {
                    hi[d] = v;
                }
            }
        }
        BoundingBox { lo, hi }
    }

    /// Gather a subset of points by index.
    pub fn gather(&self, idx: &[usize]) -> Coords {
        Coords {
            axes: self
                .axes
                .iter()
                .map(|a| idx.iter().map(|&i| a[i]).collect())
                .collect(),
        }
    }
}

/// Axis-aligned bounding box.
#[derive(Clone, Debug, PartialEq)]
pub struct BoundingBox {
    pub lo: Vec<f64>,
    pub hi: Vec<f64>,
}

impl BoundingBox {
    pub fn extent(&self, d: usize) -> f64 {
        self.hi[d] - self.lo[d]
    }

    /// Dimension with the largest extent (ties: lowest index), the
    /// "longest dimension" rule of Section 4.3.
    pub fn longest_dim(&self) -> usize {
        let mut best = 0;
        let mut best_ext = f64::NEG_INFINITY;
        for d in 0..self.lo.len() {
            let e = self.extent(d);
            if e > best_ext {
                best_ext = e;
                best = d;
            }
        }
        best
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn grid2x3() -> Coords {
        // points (x,y): (0,0),(1,0),(2,0),(0,1),(1,1),(2,1)
        Coords::from_axes(vec![
            vec![0., 1., 2., 0., 1., 2.],
            vec![0., 0., 0., 1., 1., 1.],
        ])
    }

    #[test]
    fn push_and_get() {
        let mut c = Coords::new(3);
        c.push(&[1.0, 2.0, 3.0]);
        c.push(&[4.0, 5.0, 6.0]);
        assert_eq!(c.len(), 2);
        assert_eq!(c.get(1, 0), 2.0);
        assert_eq!(c.point_vec(1), vec![4.0, 5.0, 6.0]);
    }

    #[test]
    fn bbox_and_longest_dim() {
        let c = grid2x3();
        let bb = c.bbox();
        assert_eq!(bb.lo, vec![0.0, 0.0]);
        assert_eq!(bb.hi, vec![2.0, 1.0]);
        assert_eq!(bb.longest_dim(), 0);
    }

    #[test]
    fn permute_axes_swaps() {
        let c = grid2x3();
        let p = c.permute_axes(&[1, 0]);
        assert_eq!(p.axis(0), c.axis(1));
        assert_eq!(p.axis(1), c.axis(0));
    }

    #[test]
    fn select_axes_drops() {
        let c = grid2x3();
        let s = c.select_axes(&[1]);
        assert_eq!(s.dim(), 1);
        assert_eq!(s.axis(0), c.axis(1));
    }

    #[test]
    fn remap_axis_applies_table() {
        let mut c = grid2x3();
        c.remap_axis(0, &[0.0, 10.0, 15.0]);
        assert_eq!(c.axis(0), &[0.0, 10.0, 15.0, 0.0, 10.0, 15.0]);
    }

    #[test]
    fn gather_subset() {
        let c = grid2x3();
        let g = c.gather(&[5, 0]);
        assert_eq!(g.point_vec(0), vec![2.0, 1.0]);
        assert_eq!(g.point_vec(1), vec![0.0, 0.0]);
    }

    #[test]
    #[should_panic]
    fn ragged_axes_rejected() {
        Coords::from_axes(vec![vec![0.0], vec![]]);
    }
}
