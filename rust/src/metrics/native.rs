//! Native (pure-rust) twin of the L1/L2 `batched_weighted_hops` artifact.
//!
//! Same contract as `python/compile/model.py::batched_weighted_hops`: f32
//! arithmetic, identical padding semantics (zero-weight edges and size-1
//! torus dims contribute nothing). Used as (a) the arbiter the PJRT path is
//! tested against, and (b) the fallback when no artifact fits a request.

/// Batched WeightedHops over flat arrays.
///
/// * `src`, `dst`: `[r * e * d]` router coordinates, candidate-major.
/// * `w`: `[e]` message volumes shared across candidates.
/// * `dims`: `[d]` extents; `wrap`: `[d]` 1.0 = torus ring.
///
/// Returns one f32 sum per candidate, accumulated in f32 to mirror the
/// kernel exactly.
pub fn batched_weighted_hops_native(
    src: &[f32],
    dst: &[f32],
    w: &[f32],
    dims: &[f32],
    wrap: &[f32],
    r: usize,
    e: usize,
    d: usize,
) -> Vec<f32> {
    assert_eq!(src.len(), r * e * d);
    assert_eq!(dst.len(), r * e * d);
    assert_eq!(w.len(), e);
    assert_eq!(dims.len(), d);
    assert_eq!(wrap.len(), d);
    // Dispatch to const-D bodies for the common dimensionalities so LLVM
    // can unroll + vectorize the inner loop (EXPERIMENTS.md §Perf: ~3x on
    // the rotation-sweep hot path vs the dynamic-D loop).
    match d {
        1 => whops_const::<1>(src, dst, w, dims, wrap, r, e),
        2 => whops_const::<2>(src, dst, w, dims, wrap, r, e),
        3 => whops_const::<3>(src, dst, w, dims, wrap, r, e),
        4 => whops_const::<4>(src, dst, w, dims, wrap, r, e),
        5 => whops_const::<5>(src, dst, w, dims, wrap, r, e),
        6 => whops_const::<6>(src, dst, w, dims, wrap, r, e),
        _ => whops_dyn(src, dst, w, dims, wrap, r, e, d),
    }
}

fn whops_const<const D: usize>(
    src: &[f32],
    dst: &[f32],
    w: &[f32],
    dims: &[f32],
    wrap: &[f32],
    r: usize,
    e: usize,
) -> Vec<f32> {
    let mut dims_a = [0f32; D];
    let mut mesh = [false; D];
    for k in 0..D {
        dims_a[k] = dims[k];
        mesh[k] = wrap[k] <= 0.0;
    }
    let mut out = vec![0f32; r];
    for (ri, o) in out.iter_mut().enumerate() {
        let base = ri * e * D;
        let s = &src[base..base + e * D];
        let t = &dst[base..base + e * D];
        let mut acc = 0f32;
        for ei in 0..e {
            let off = ei * D;
            let mut hops = 0f32;
            for k in 0..D {
                let ad = (s[off + k] - t[off + k]).abs();
                let th = ad.min(dims_a[k] - ad);
                hops += if mesh[k] { ad } else { th };
            }
            acc += w[ei] * hops;
        }
        *o = acc;
    }
    out
}

fn whops_dyn(
    src: &[f32],
    dst: &[f32],
    w: &[f32],
    dims: &[f32],
    wrap: &[f32],
    r: usize,
    e: usize,
    d: usize,
) -> Vec<f32> {
    let mut out = vec![0f32; r];
    for ri in 0..r {
        let base = ri * e * d;
        let mut acc = 0f32;
        for ei in 0..e {
            let off = base + ei * d;
            let mut hops = 0f32;
            for di in 0..d {
                let ad = (src[off + di] - dst[off + di]).abs();
                let th = ad.min(dims[di] - ad);
                hops += if wrap[di] > 0.0 { th } else { ad };
            }
            acc += w[ei] * hops;
        }
        out[ri] = acc;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_distance() {
        // 0 -> 7 on a ring of 8: 1 hop (torus), 7 (mesh).
        let src = vec![0f32];
        let dst = vec![7f32];
        let w = vec![1f32];
        let t = batched_weighted_hops_native(&src, &dst, &w, &[8.0], &[1.0], 1, 1, 1);
        assert_eq!(t, vec![1.0]);
        let m = batched_weighted_hops_native(&src, &dst, &w, &[8.0], &[0.0], 1, 1, 1);
        assert_eq!(m, vec![7.0]);
    }

    #[test]
    fn padding_contract() {
        // Zero-weight edges and size-1 wrapped dims contribute nothing.
        let src = vec![3.0, 0.0, 1.0, 0.0];
        let dst = vec![5.0, 0.0, 9.0, 0.0];
        let w = vec![2.0, 0.0];
        let out = batched_weighted_hops_native(&src, &dst, &w, &[16.0, 1.0], &[1.0, 1.0], 1, 2, 2);
        assert_eq!(out, vec![4.0]); // only edge 0, |3-5| = 2, w=2
    }

    #[test]
    fn batch_candidates_independent() {
        let src = vec![0.0, 0.0];
        let dst = vec![1.0, 3.0];
        let w = vec![1.0];
        let out = batched_weighted_hops_native(&src, &dst, &w, &[8.0], &[1.0], 2, 1, 1);
        assert_eq!(out, vec![1.0, 3.0]);
    }
}
