//! Native (pure-rust) twin of the L1/L2 `batched_weighted_hops` artifact.
//!
//! Same contract as `python/compile/model.py::batched_weighted_hops`: f32
//! arithmetic, identical padding semantics (zero-weight edges and size-1
//! torus dims contribute nothing). Used as (a) the arbiter the artifact
//! path is tested against, and (b) the fallback when no artifact fits a
//! request.
//!
//! Candidates are independent rows, so the batch parallelizes across them
//! without changing any row's f32 accumulation order:
//! [`batched_weighted_hops_native_par`] is bit-identical to the sequential
//! kernel at every thread count. [`NativeBackend`]
//! (`mapping::rotations::NativeBackend`) routes through it with the auto
//! budget; large multi-candidate batches (e.g. the raw-kernel benches and
//! `score_mappings` on pre-built mapping sets) pick the parallelism up for
//! free, while single-candidate calls from an already-fanned-out rotation
//! sweep stay on the sequential row kernel.

use crate::par::{self, Parallelism};

/// Below this much work (`r * e` weighted edge evaluations) the batch is
/// not worth fanning out.
const PAR_MIN_WORK: usize = 1 << 14;

/// Batched WeightedHops over flat arrays.
///
/// * `src`, `dst`: `[r * e * d]` router coordinates, candidate-major.
/// * `w`: `[e]` message volumes shared across candidates.
/// * `dims`: `[d]` extents; `wrap`: `[d]` 1.0 = torus ring.
///
/// Returns one f32 sum per candidate, accumulated in f32 to mirror the
/// kernel exactly.
#[allow(clippy::too_many_arguments)]
pub fn batched_weighted_hops_native(
    src: &[f32],
    dst: &[f32],
    w: &[f32],
    dims: &[f32],
    wrap: &[f32],
    r: usize,
    e: usize,
    d: usize,
) -> Vec<f32> {
    batched_weighted_hops_native_par(src, dst, w, dims, wrap, r, e, d, Parallelism::sequential())
}

/// [`batched_weighted_hops_native`] with candidate rows fanned out across a
/// thread budget. Each row's accumulation is untouched, so the result is
/// bit-identical to the sequential kernel.
#[allow(clippy::too_many_arguments)]
pub fn batched_weighted_hops_native_par(
    src: &[f32],
    dst: &[f32],
    w: &[f32],
    dims: &[f32],
    wrap: &[f32],
    r: usize,
    e: usize,
    d: usize,
    par: Parallelism,
) -> Vec<f32> {
    assert_eq!(src.len(), r * e * d);
    assert_eq!(dst.len(), r * e * d);
    assert_eq!(w.len(), e);
    assert_eq!(dims.len(), d);
    assert_eq!(wrap.len(), d);
    if par.num_threads() < 2 || r < 2 || r * e < PAR_MIN_WORK {
        // Sequential fast path: no fan-out machinery. This is the shape
        // the rotation sweep's per-worker r=1 chunk calls take, so it must
        // stay free of per-call allocation beyond the output vector.
        return (0..r).map(|ri| score_row(src, dst, w, dims, wrap, ri, e, d)).collect();
    }
    let rows: Vec<usize> = (0..r).collect();
    par::map(par, &rows, |_, &ri| score_row(src, dst, w, dims, wrap, ri, e, d))
}

/// One candidate row, dispatched to a const-D body for the common
/// dimensionalities so LLVM can unroll + vectorize the inner loop
/// (EXPERIMENTS.md §Perf: ~3x on the rotation-sweep hot path vs the
/// dynamic-D loop).
#[allow(clippy::too_many_arguments)]
#[inline]
fn score_row(
    src: &[f32],
    dst: &[f32],
    w: &[f32],
    dims: &[f32],
    wrap: &[f32],
    ri: usize,
    e: usize,
    d: usize,
) -> f32 {
    let base = ri * e * d;
    let (s, t) = (&src[base..base + e * d], &dst[base..base + e * d]);
    match d {
        1 => whops_row::<1>(s, t, w, dims, wrap, e),
        2 => whops_row::<2>(s, t, w, dims, wrap, e),
        3 => whops_row::<3>(s, t, w, dims, wrap, e),
        4 => whops_row::<4>(s, t, w, dims, wrap, e),
        5 => whops_row::<5>(s, t, w, dims, wrap, e),
        6 => whops_row::<6>(s, t, w, dims, wrap, e),
        _ => whops_row_dyn(s, t, w, dims, wrap, e, d),
    }
}

/// Lanes of the unrolled accumulator: 8 independent partial sums match the
/// f32x8 width of the explicit-SIMD path, and break the loop-carried
/// `acc` dependency so LLVM can keep 8 FMAs in flight.
const LANES: usize = 8;

/// One edge's weighted hop count. Shared by the scalar lanes and by the
/// SIMD path's remainder loop, so both kernels price the tail with the
/// exact same instruction sequence.
#[inline(always)]
fn edge_whops<const D: usize>(
    src: &[f32],
    dst: &[f32],
    dims_a: &[f32; D],
    mesh: &[bool; D],
    ei: usize,
    wei: f32,
) -> f32 {
    let off = ei * D;
    let mut hops = 0f32;
    for k in 0..D {
        let ad = (src[off + k] - dst[off + k]).abs();
        let th = ad.min(dims_a[k] - ad);
        hops += if mesh[k] { ad } else { th };
    }
    wei * hops
}

/// Default row kernel: autovectorizable 8-lane unroll.
#[cfg(not(feature = "simd"))]
#[inline(always)]
fn whops_row<const D: usize>(
    src: &[f32],
    dst: &[f32],
    w: &[f32],
    dims: &[f32],
    wrap: &[f32],
    e: usize,
) -> f32 {
    whops_row_scalar::<D>(src, dst, w, dims, wrap, e)
}

/// Row kernel under `--features simd`: explicit `std::simd` f32x8 lanes
/// with the identical accumulation grouping, so results stay bit-for-bit
/// equal to the default build.
#[cfg(feature = "simd")]
#[inline(always)]
fn whops_row<const D: usize>(
    src: &[f32],
    dst: &[f32],
    w: &[f32],
    dims: &[f32],
    wrap: &[f32],
    e: usize,
) -> f32 {
    whops_row_simd::<D>(src, dst, w, dims, wrap, e)
}

#[cfg_attr(feature = "simd", allow(dead_code))] // simd builds keep it as the test arbiter
fn whops_row_scalar<const D: usize>(
    src: &[f32],
    dst: &[f32],
    w: &[f32],
    dims: &[f32],
    wrap: &[f32],
    e: usize,
) -> f32 {
    let mut dims_a = [0f32; D];
    let mut mesh = [false; D];
    for k in 0..D {
        dims_a[k] = dims[k];
        mesh[k] = wrap[k] <= 0.0;
    }
    // Manual 8-lane unroll: lane `j` accumulates edges `ei + j` of each
    // full block, the remainder runs scalar, and the lanes reduce pairwise
    // in a fixed order — a deterministic accumulation grouping (different
    // from the old single-accumulator loop only in f32 low-order bits, and
    // identical across runs and thread counts).
    let mut acc = [0f32; LANES];
    let blocks = e / LANES;
    for blk in 0..blocks {
        let base = blk * LANES;
        for (j, lane) in acc.iter_mut().enumerate() {
            let ei = base + j;
            *lane += edge_whops::<D>(src, dst, &dims_a, &mesh, ei, w[ei]);
        }
    }
    let mut tail = 0f32;
    for ei in blocks * LANES..e {
        tail += edge_whops::<D>(src, dst, &dims_a, &mesh, ei, w[ei]);
    }
    (((acc[0] + acc[1]) + (acc[2] + acc[3])) + ((acc[4] + acc[5]) + (acc[6] + acc[7]))) + tail
}

/// Explicit `std::simd` twin of [`whops_row_scalar`] (nightly-only;
/// `--features simd`). SIMD lane `j` performs exactly the operations
/// scalar lane `j` performs, in the same order — per lane: subtract, abs,
/// (torus axes) min against `dims - ad`, accumulate `hops` axis by axis,
/// multiply by the edge weight, add into the lane accumulator — and the
/// final reduction uses the identical fixed pairwise tree, so every
/// result bit matches the default build. All IEEE-exact ops, no FMA
/// contraction, no reassociation.
#[cfg(feature = "simd")]
fn whops_row_simd<const D: usize>(
    src: &[f32],
    dst: &[f32],
    w: &[f32],
    dims: &[f32],
    wrap: &[f32],
    e: usize,
) -> f32 {
    use std::simd::f32x8;
    use std::simd::num::SimdFloat;
    let mut dims_a = [0f32; D];
    let mut mesh = [false; D];
    for k in 0..D {
        dims_a[k] = dims[k];
        mesh[k] = wrap[k] <= 0.0;
    }
    let mut acc = f32x8::splat(0.0);
    let blocks = e / LANES;
    for blk in 0..blocks {
        let base = blk * LANES;
        let mut hops = f32x8::splat(0.0);
        for k in 0..D {
            // Gather the k-th coordinate of the block's 8 edges (stride D).
            let mut sa = [0f32; LANES];
            let mut ta = [0f32; LANES];
            for j in 0..LANES {
                let off = (base + j) * D + k;
                sa[j] = src[off];
                ta[j] = dst[off];
            }
            let ad = (f32x8::from_array(sa) - f32x8::from_array(ta)).abs();
            hops += if mesh[k] {
                ad
            } else {
                ad.simd_min(f32x8::splat(dims_a[k]) - ad)
            };
        }
        acc += f32x8::from_slice(&w[base..base + LANES]) * hops;
    }
    let mut tail = 0f32;
    for ei in blocks * LANES..e {
        tail += edge_whops::<D>(src, dst, &dims_a, &mesh, ei, w[ei]);
    }
    let a = acc.to_array();
    (((a[0] + a[1]) + (a[2] + a[3])) + ((a[4] + a[5]) + (a[6] + a[7]))) + tail
}

fn whops_row_dyn(
    src: &[f32],
    dst: &[f32],
    w: &[f32],
    dims: &[f32],
    wrap: &[f32],
    e: usize,
    d: usize,
) -> f32 {
    let mut acc = 0f32;
    for ei in 0..e {
        let off = ei * d;
        let mut hops = 0f32;
        for di in 0..d {
            let ad = (src[off + di] - dst[off + di]).abs();
            let th = ad.min(dims[di] - ad);
            hops += if wrap[di] > 0.0 { th } else { ad };
        }
        acc += w[ei] * hops;
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_distance() {
        // 0 -> 7 on a ring of 8: 1 hop (torus), 7 (mesh).
        let src = vec![0f32];
        let dst = vec![7f32];
        let w = vec![1f32];
        let t = batched_weighted_hops_native(&src, &dst, &w, &[8.0], &[1.0], 1, 1, 1);
        assert_eq!(t, vec![1.0]);
        let m = batched_weighted_hops_native(&src, &dst, &w, &[8.0], &[0.0], 1, 1, 1);
        assert_eq!(m, vec![7.0]);
    }

    #[test]
    fn padding_contract() {
        // Zero-weight edges and size-1 wrapped dims contribute nothing.
        let src = vec![3.0, 0.0, 1.0, 0.0];
        let dst = vec![5.0, 0.0, 9.0, 0.0];
        let w = vec![2.0, 0.0];
        let out = batched_weighted_hops_native(&src, &dst, &w, &[16.0, 1.0], &[1.0, 1.0], 1, 2, 2);
        assert_eq!(out, vec![4.0]); // only edge 0, |3-5| = 2, w=2
    }

    #[test]
    fn batch_candidates_independent() {
        let src = vec![0.0, 0.0];
        let dst = vec![1.0, 3.0];
        let w = vec![1.0];
        let out = batched_weighted_hops_native(&src, &dst, &w, &[8.0], &[1.0], 2, 1, 1);
        assert_eq!(out, vec![1.0, 3.0]);
    }

    #[test]
    fn unrolled_lanes_match_scalar_reference() {
        // Edge counts around the 8-lane block boundary (full blocks, tail,
        // tail-only): the unrolled kernel must agree with a plain f64
        // reference within f32 tolerance.
        let d = 3usize;
        let dims = [9.0f32, 7.0, 5.0];
        let wrap = [1.0f32, 0.0, 1.0];
        for e in [1usize, 7, 8, 9, 16, 37] {
            let src: Vec<f32> =
                (0..e * d).map(|k| ((k * 3) % dims[k % d] as usize) as f32).collect();
            let dst: Vec<f32> =
                (0..e * d).map(|k| ((k * 5 + 2) % dims[k % d] as usize) as f32).collect();
            let w: Vec<f32> = (0..e).map(|k| 0.25 + (k % 5) as f32).collect();
            let mut want = 0f64;
            for ei in 0..e {
                let mut hops = 0f64;
                for k in 0..d {
                    let ad = (src[ei * d + k] - dst[ei * d + k]).abs() as f64;
                    let th = ad.min(dims[k] as f64 - ad);
                    hops += if wrap[k] > 0.0 { th } else { ad };
                }
                want += w[ei] as f64 * hops;
            }
            let got = batched_weighted_hops_native(&src, &dst, &w, &dims, &wrap, 1, e, d)[0];
            assert!(
                (got as f64 - want).abs() <= 1e-3 + want.abs() * 1e-5,
                "e={e}: {got} vs {want}"
            );
        }
    }

    /// `--features simd` acceptance: the explicit f32x8 kernel must be
    /// bit-for-bit equal to the scalar 8-lane unroll across block
    /// boundaries (full blocks, tails, tail-only, large), mixed
    /// torus/mesh axes, and every const-D dispatch arm exercised here.
    #[cfg(feature = "simd")]
    #[test]
    fn simd_row_kernel_bit_identical_to_scalar() {
        use crate::testutil::rng::Rng;
        let mut rng = Rng::new(0xC0FFEE);
        for d in [1usize, 2, 3, 6] {
            let dims: Vec<f32> = (0..d).map(|k| (3 + 2 * k) as f32).collect();
            let wrap: Vec<f32> = (0..d).map(|k| if k % 2 == 0 { 1.0 } else { 0.0 }).collect();
            for e in [0usize, 1, 7, 8, 9, 16, 37, 1000] {
                let coord = |rng: &mut Rng, k: usize| rng.below(dims[k % d] as usize) as f32;
                let src: Vec<f32> = (0..e * d).map(|k| coord(&mut rng, k)).collect();
                let dst: Vec<f32> = (0..e * d).map(|k| coord(&mut rng, k)).collect();
                let w: Vec<f32> = (0..e).map(|_| rng.f64_range(0.0, 4.0) as f32).collect();
                let (scalar, simd) = match d {
                    1 => (
                        whops_row_scalar::<1>(&src, &dst, &w, &dims, &wrap, e),
                        whops_row_simd::<1>(&src, &dst, &w, &dims, &wrap, e),
                    ),
                    2 => (
                        whops_row_scalar::<2>(&src, &dst, &w, &dims, &wrap, e),
                        whops_row_simd::<2>(&src, &dst, &w, &dims, &wrap, e),
                    ),
                    3 => (
                        whops_row_scalar::<3>(&src, &dst, &w, &dims, &wrap, e),
                        whops_row_simd::<3>(&src, &dst, &w, &dims, &wrap, e),
                    ),
                    6 => (
                        whops_row_scalar::<6>(&src, &dst, &w, &dims, &wrap, e),
                        whops_row_simd::<6>(&src, &dst, &w, &dims, &wrap, e),
                    ),
                    _ => unreachable!(),
                };
                assert_eq!(
                    scalar.to_bits(),
                    simd.to_bits(),
                    "d={d} e={e}: scalar {scalar} vs simd {simd}"
                );
            }
        }
    }

    #[test]
    fn parallel_batch_bit_identical() {
        // Large enough to clear the work threshold; wrap + mesh dims mixed.
        let (r, e, d) = (8usize, 4096usize, 3usize);
        let src: Vec<f32> = (0..r * e * d).map(|k| ((k * 7) % 13) as f32).collect();
        let dst: Vec<f32> = (0..r * e * d).map(|k| ((k * 5) % 13) as f32).collect();
        let w: Vec<f32> = (0..e).map(|k| ((k % 4) as f32) * 0.5).collect();
        let dims = vec![13.0, 13.0, 13.0];
        let wrap = vec![1.0, 0.0, 1.0];
        let seq = batched_weighted_hops_native(&src, &dst, &w, &dims, &wrap, r, e, d);
        for threads in [2, 8] {
            let par = batched_weighted_hops_native_par(
                &src,
                &dst,
                &w,
                &dims,
                &wrap,
                r,
                e,
                d,
                Parallelism::threads(threads),
            );
            assert_eq!(par, seq, "threads={threads}");
        }
    }
}
