//! Mapping-quality metrics (Section 3, Eqns 1–7): hops, weighted hops,
//! per-link data, serialization latency, and per-dimension breakdowns.
//!
//! Evaluation takes a task graph, a task-to-rank assignment, and an
//! `Allocation` (which ties ranks to nodes and routers). Messages between
//! ranks in the same node never enter the network (zero hops, no link
//! data); messages between nodes follow the topology's deterministic
//! routing — dimension-ordered on the torus (static routing, single path —
//! the Section 3 assumptions), up/down on the fat-tree, minimal (or
//! one-hop-Valiant) on the dragonfly.
//!
//! # Parallel evaluation
//!
//! [`eval_full`] processes edges in fixed-size chunks
//! ([`EVAL_CHUNK_EDGES`]) fanned out over the [`crate::par`] budget via
//! `map_with`: each worker accumulates routed link loads into its own
//! dense per-worker buffer, emits them as a sparse per-chunk partial, and
//! the partials merge in chunk order. Because the chunk boundaries — and
//! therefore the floating-point reduction structure — depend only on the
//! edge count, **the result is bit-identical at every thread count**
//! (pinned by a property test). Graphs smaller than one chunk reduce in
//! plain edge order, exactly like the scalar [`eval_hops`] loop.

pub mod native;

use crate::apps::TaskGraph;
use crate::machine::{Allocation, Topology};
use crate::par::{self, Parallelism};

/// Default edge-chunk size for [`eval_full`]'s parallel fan-out. The chunk
/// grid is fixed by the edge count alone so results never depend on the
/// thread budget.
pub const EVAL_CHUNK_EDGES: usize = 8192;

/// Scalar metrics of a mapping (Eqns 1–7).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Metrics {
    /// Eqn 1: total hops over all task-graph edges.
    pub total_hops: f64,
    /// Eqn 2: `total_hops / |E_t|`.
    pub avg_hops: f64,
    /// Eqn 3: volume-weighted hops.
    pub weighted_hops: f64,
    /// Number of inter-node messages (each communicating pair exchanges a
    /// message in both directions).
    pub total_messages: u64,
    pub num_edges: usize,
    /// Link-level metrics (only when evaluated with routing).
    pub link: Option<LinkMetrics>,
}

/// Per-link data/latency aggregates (Eqns 4–7) plus per-dimension stats.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct LinkMetrics {
    /// Eqn 5: max data over any directed link.
    pub max_data: f64,
    /// Mean data over all directed links that exist in the topology.
    pub avg_data: f64,
    /// Eqn 7: max `Data(e)/bw(e)` over links (seconds when data is bytes
    /// and bw is bytes/s; the machine presets use GB/s so callers scale).
    pub max_latency: f64,
    /// Sum of `Data(e)/bw(e)` over all existing directed links — the
    /// bandwidth-aware total routed volume the `CongestionBlend` objective
    /// averages over.
    pub sum_latency: f64,
    /// Number of directed links that exist in the topology (mesh boundary
    /// routers lack the outward link).
    pub num_links: usize,
    /// Per (link class, direction). On the torus the class is the dimension
    /// and `[dim][0]`=+, `[dim][1]`=-; the fat-tree classes are tree levels
    /// (0 = below the root) with dir 0=up/1=down; the dragonfly has class
    /// 0=local, 1=global with a single direction slot 0.
    pub per_dim: Vec<[DimStats; 2]>,
}

/// Aggregates for one (link class, direction) bucket.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct DimStats {
    pub max_data: f64,
    pub avg_data: f64,
    pub max_latency: f64,
    pub avg_latency: f64,
}

/// Evaluate hop metrics only (cheap: no routing, no link arrays).
pub fn eval_hops(graph: &TaskGraph, task_to_rank: &[u32], alloc: &Allocation) -> Metrics {
    assert_eq!(task_to_rank.len(), graph.num_tasks);
    let machine = &alloc.machine;
    let mut total_hops = 0f64;
    let mut weighted_hops = 0f64;
    let mut messages = 0u64;
    for e in &graph.edges {
        let ra = task_to_rank[e.u as usize] as usize;
        let rb = task_to_rank[e.v as usize] as usize;
        if alloc.core_node[ra] == alloc.core_node[rb] {
            continue; // intra-node: zero hops, no network message
        }
        messages += 2;
        let (qa, qb) = (alloc.core_router[ra] as usize, alloc.core_router[rb] as usize);
        let h = machine.hop_dist_ids(qa, qb) as f64;
        total_hops += h;
        weighted_hops += e.w * h;
    }
    Metrics {
        total_hops,
        avg_hops: total_hops / graph.edges.len().max(1) as f64,
        weighted_hops,
        total_messages: messages,
        num_edges: graph.edges.len(),
        link: None,
    }
}

/// Evaluate all metrics, including per-link data and latency via
/// dimension-ordered routing. Each inter-node edge contributes its volume in
/// both directions (both endpoints send). Runs under the auto thread budget
/// ([`Parallelism::auto`]); the result does not depend on the budget.
pub fn eval_full(graph: &TaskGraph, task_to_rank: &[u32], alloc: &Allocation) -> Metrics {
    eval_full_par(graph, task_to_rank, alloc, Parallelism::auto())
}

/// [`eval_full`] with an explicit thread budget.
pub fn eval_full_par(
    graph: &TaskGraph,
    task_to_rank: &[u32],
    alloc: &Allocation,
    par: Parallelism,
) -> Metrics {
    eval_full_chunked(graph, task_to_rank, alloc, par, EVAL_CHUNK_EDGES)
}

/// Per-chunk partial sums of the parallel metrics engine.
struct EvalPartial {
    hops: f64,
    weighted_hops: f64,
    messages: u64,
    /// Sparse routed link loads: `(link index, data)`, each link at most
    /// once per chunk.
    load: Vec<(u32, f64)>,
}

/// Per-worker scratch: the dense link accumulator that turns each chunk's
/// routed loads into a sparse partial.
struct EvalScratch {
    acc: LinkAccumulator,
}

/// [`eval_full`] with an explicit chunk size (tests force small chunks to
/// exercise the merge on small graphs). The chunk grid is fixed by
/// `(edge count, chunk_edges)` alone, so for a given chunk size the result
/// is bit-identical at every thread count.
pub fn eval_full_chunked(
    graph: &TaskGraph,
    task_to_rank: &[u32],
    alloc: &Allocation,
    par: Parallelism,
    chunk_edges: usize,
) -> Metrics {
    assert_eq!(task_to_rank.len(), graph.num_tasks);
    let machine = &alloc.machine;
    let nlinks = machine.num_directed_links();
    let ne = graph.edges.len();
    let chunk = chunk_edges.max(1);
    let chunks: Vec<usize> = (0..ne.div_ceil(chunk)).collect();
    let partials: Vec<EvalPartial> = par::map_with(
        par,
        &chunks,
        || EvalScratch {
            acc: LinkAccumulator::new(machine),
        },
        |s, _i, &c| {
            let lo = c * chunk;
            let hi = (lo + chunk).min(ne);
            let mut p = EvalPartial {
                hops: 0.0,
                weighted_hops: 0.0,
                messages: 0,
                load: Vec::new(),
            };
            let EvalScratch { acc } = s;
            acc.reset();
            for e in &graph.edges[lo..hi] {
                let ra = task_to_rank[e.u as usize] as usize;
                let rb = task_to_rank[e.v as usize] as usize;
                if alloc.core_node[ra] == alloc.core_node[rb] {
                    continue; // intra-node: zero hops, no network message
                }
                p.messages += 2;
                let (qa, qb) =
                    (alloc.core_router[ra] as usize, alloc.core_router[rb] as usize);
                let h = machine.hop_dist_ids(qa, qb) as f64;
                p.hops += h;
                p.weighted_hops += e.w * h;
                acc.add_pair(machine, qa, qb, e.w);
            }
            // Extract the chunk's sparse loads (first-touch order, like the
            // accumulation itself); the reset at chunk start keeps the
            // worker's buffer reusable.
            p.load.reserve(acc.touched().len());
            for &l in acc.touched() {
                p.load.push((l, acc.load(l as usize)));
            }
            p
        },
    );
    // Merge in chunk order: per-link sums accumulate partials in ascending
    // chunk index, so the reduction tree is independent of the budget.
    let mut total_hops = 0f64;
    let mut weighted_hops = 0f64;
    let mut messages = 0u64;
    let mut load = vec![0f64; nlinks];
    for p in &partials {
        total_hops += p.hops;
        weighted_hops += p.weighted_hops;
        messages += p.messages;
        for &(l, v) in &p.load {
            load[l as usize] += v;
        }
    }
    Metrics {
        total_hops,
        avg_hops: total_hops / ne.max(1) as f64,
        weighted_hops,
        total_messages: messages,
        num_edges: ne,
        link: Some(summarize_links(machine, &load)),
    }
}

/// Reduce a per-directed-link load array into `LinkMetrics`. Links are
/// visited in the topology's [`Topology::for_each_link`] order — on the
/// torus that is the historical router → dimension → direction iteration,
/// so aggregates are bit-identical to the pre-trait implementation.
pub fn summarize_links(topo: &dyn Topology, load: &[f64]) -> LinkMetrics {
    let nclasses = topo.num_link_classes();
    let mut lm = LinkMetrics {
        per_dim: vec![[DimStats::default(); 2]; nclasses],
        ..Default::default()
    };
    let mut total = 0f64;
    let mut counts = vec![[0usize; 2]; nclasses];
    let mut sums = vec![[0f64; 2]; nclasses];
    let mut lat_sums = vec![[0f64; 2]; nclasses];
    topo.for_each_link(&mut |l, class, dir, bw| {
        let data = load[l];
        let lat = data / bw;
        let s = &mut lm.per_dim[class][dir];
        if data > s.max_data {
            s.max_data = data;
        }
        if lat > s.max_latency {
            s.max_latency = lat;
        }
        sums[class][dir] += data;
        lat_sums[class][dir] += lat;
        counts[class][dir] += 1;
        total += data;
        if data > lm.max_data {
            lm.max_data = data;
        }
        if lat > lm.max_latency {
            lm.max_latency = lat;
        }
    });
    let total_links: usize = counts.iter().map(|c| c[0] + c[1]).sum();
    lm.avg_data = total / total_links.max(1) as f64;
    lm.num_links = total_links;
    for class in 0..nclasses {
        for dir in 0..2 {
            let n = counts[class][dir].max(1) as f64;
            lm.per_dim[class][dir].avg_data = sums[class][dir] / n;
            lm.per_dim[class][dir].avg_latency = lat_sums[class][dir] / n;
            lm.sum_latency += lat_sums[class][dir];
        }
    }
    lm
}

/// Reusable routed-link load accumulator: a dense per-directed-link `f64`
/// buffer plus a touched-link list, so repeated accumulations (candidate
/// scoring) and **signed** re-route deltas (refinement swap gains) reuse one
/// allocation and reset in O(touched) instead of O(links).
///
/// [`add_pair`](LinkAccumulator::add_pair) is the O(path-length) primitive
/// everything else builds on: it walks the topology's deterministic route
/// between two routers in both directions and adds a (possibly negative)
/// volume to every link traversed — exactly the per-edge inner loop of
/// [`eval_full`], exposed so the [`crate::objective`] layer can re-route
/// single edges incrementally instead of re-evaluating whole mappings.
pub struct LinkAccumulator {
    load: Vec<f64>,
    /// Dedup marker per link: `touched` holds each link at most once even
    /// when deltas cancel back to exactly 0.0.
    mark: Vec<bool>,
    touched: Vec<u32>,
}

impl LinkAccumulator {
    pub fn new(topo: &dyn Topology) -> Self {
        LinkAccumulator {
            load: vec![0f64; topo.num_directed_links()],
            mark: vec![false; topo.num_directed_links()],
            touched: Vec::new(),
        }
    }

    /// Clear all accumulated loads (O(touched)).
    pub fn reset(&mut self) {
        for &l in &self.touched {
            self.load[l as usize] = 0.0;
            self.mark[l as usize] = false;
        }
        self.touched.clear();
    }

    /// Links touched since the last reset, in first-touch order.
    pub fn touched(&self) -> &[u32] {
        &self.touched
    }

    /// Accumulated load of one directed link (0.0 when untouched).
    #[inline]
    pub fn load(&self, link: usize) -> f64 {
        self.load[link]
    }

    /// Add `w` (may be negative) along the deterministic routes `qa -> qb`
    /// **and** `qb -> qa` (both endpoints send). O(path length).
    pub fn add_pair(&mut self, topo: &dyn Topology, qa: usize, qb: usize, w: f64) {
        let load = &mut self.load;
        let mark = &mut self.mark;
        let touched = &mut self.touched;
        let mut visit = |l: usize| {
            if !mark[l] {
                mark[l] = true;
                touched.push(l as u32);
            }
            load[l] += w;
        };
        topo.route_ids(qa, qb, &mut visit);
        topo.route_ids(qb, qa, &mut visit);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::stencil::stencil_graph;
    use crate::machine::{Allocation, Network};

    /// One rank per router on a ring of `n`, identity placement.
    fn ring_alloc(n: usize) -> Allocation {
        Allocation {
            machine: Network::torus(&[n]),
            core_router: (0..n as u32).collect(),
            core_node: (0..n as u32).collect(),
            ranks_per_node: 1,
        }
    }

    #[test]
    fn identity_ring_mapping_metrics() {
        // 8 tasks on an 8-ring, identity mapping: every edge is 1 hop.
        let g = stencil_graph(&[8], true, 2.0);
        let alloc = ring_alloc(8);
        let ranks: Vec<u32> = (0..8).collect();
        let m = eval_hops(&g, &ranks, &alloc);
        assert_eq!(m.total_hops, 8.0);
        assert_eq!(m.avg_hops, 1.0);
        assert_eq!(m.weighted_hops, 16.0);
        assert_eq!(m.total_messages, 16);
    }

    #[test]
    fn reversed_mapping_still_one_hop_on_ring() {
        // Reversal is an isometry of the ring.
        let g = stencil_graph(&[8], true, 1.0);
        let alloc = ring_alloc(8);
        let ranks: Vec<u32> = (0..8u32).rev().collect();
        let m = eval_hops(&g, &ranks, &alloc);
        assert_eq!(m.avg_hops, 1.0);
    }

    #[test]
    fn intra_node_edges_are_free() {
        // Two ranks per node: tasks 0,1 in node 0 communicate for free.
        let g = stencil_graph(&[4], false, 1.0);
        let alloc = Allocation {
            machine: Network::torus(&[2]),
            core_router: vec![0, 0, 1, 1],
            core_node: vec![0, 0, 1, 1],
            ranks_per_node: 2,
        };
        let ranks: Vec<u32> = (0..4).collect();
        let m = eval_hops(&g, &ranks, &alloc);
        // Edges (0,1) and (2,3) intra-node; (1,2) inter-node 1 hop.
        assert_eq!(m.total_hops, 1.0);
        assert_eq!(m.total_messages, 2);
    }

    #[test]
    fn parallel_eval_full_bit_identical() {
        // Tiny chunks force a real multi-chunk merge; the result must be
        // bitwise equal at every thread budget.
        use crate::par::Parallelism;
        let g = stencil_graph(&[6, 6], true, 1.7);
        let alloc = Allocation {
            machine: Network::torus(&[6, 6]),
            core_router: (0..36u32).collect(),
            core_node: (0..36u32).collect(),
            ranks_per_node: 1,
        };
        let m: Vec<u32> = (0..36u32).map(|i| (i * 7) % 36).collect();
        let seq = eval_full_chunked(&g, &m, &alloc, Parallelism::sequential(), 5);
        for threads in [2, 8] {
            let par = eval_full_chunked(&g, &m, &alloc, Parallelism::threads(threads), 5);
            assert_eq!(par, seq, "threads={threads}");
        }
    }

    #[test]
    fn intra_node_edges_leave_no_trace_in_link_metrics() {
        // Node-boundary coverage: ranks sharing a node must report zero
        // hops, zero messages, and zero link data — the assumption the
        // hierarchical mapper exploits.
        let g = stencil_graph(&[4], false, 9.0); // chain 0-1-2-3
        let alloc = Allocation {
            machine: Network::torus(&[4]),
            core_router: vec![0, 0, 2, 2],
            core_node: vec![0, 0, 1, 1],
            ranks_per_node: 2,
        };
        // Map tasks so every edge stays inside a node except (1,2).
        let m = eval_full(&g, &[0, 1, 2, 3], &alloc);
        assert_eq!(m.total_messages, 2); // only edge (1,2) crosses
        assert_eq!(m.total_hops, 2.0); // routers 0 -> 2 on a 4-ring
        // Now collapse everything into single nodes: all metrics vanish.
        let all_intra = Allocation {
            machine: Network::torus(&[4]),
            core_router: vec![0, 0, 0, 0],
            core_node: vec![0, 0, 0, 0],
            ranks_per_node: 4,
        };
        let z = eval_full(&g, &[0, 1, 2, 3], &all_intra);
        assert_eq!(z.total_hops, 0.0);
        assert_eq!(z.weighted_hops, 0.0);
        assert_eq!(z.total_messages, 0);
        let lm = z.link.unwrap();
        assert_eq!(lm.max_data, 0.0);
        assert_eq!(lm.avg_data, 0.0);
        assert_eq!(lm.max_latency, 0.0);
    }

    #[test]
    fn link_data_accumulates_both_directions() {
        // Ring of 4, tasks 0-1 communicate: 0->1 uses router 0's + link,
        // 1->0 uses router 1's - link. (A 2-ring would route both ways
        // through + because wrap ties break positive.)
        let g = stencil_graph(&[2], false, 3.0);
        let alloc = ring_alloc(4);
        let m = eval_full(&g, &[0, 1], &alloc);
        let lm = m.link.unwrap();
        assert_eq!(lm.max_data, 3.0);
        assert_eq!(lm.per_dim[0][0].max_data, 3.0);
        assert_eq!(lm.per_dim[0][1].max_data, 3.0);
    }

    #[test]
    fn latency_uses_bandwidth() {
        use crate::machine::BwModel;
        let machine = Network::new(vec![4], vec![true], BwModel::Uniform(2.0));
        let alloc = Allocation {
            machine,
            core_router: vec![0, 1, 2, 3],
            core_node: vec![0, 1, 2, 3],
            ranks_per_node: 1,
        };
        let g = stencil_graph(&[4], true, 10.0);
        let m = eval_full(&g, &[0, 1, 2, 3], &alloc);
        let lm = m.link.unwrap();
        assert_eq!(lm.max_latency, lm.max_data / 2.0);
    }

    #[test]
    fn mesh_boundary_links_excluded_from_avg() {
        // 1D mesh of 4 routers: 3 undirected = 6 directed links exist.
        let machine = Network::mesh(&[4]);
        let alloc = Allocation {
            machine,
            core_router: vec![0, 1, 2, 3],
            core_node: vec![0, 1, 2, 3],
            ranks_per_node: 1,
        };
        let g = stencil_graph(&[4], false, 1.0);
        let m = eval_full(&g, &[0, 1, 2, 3], &alloc);
        let lm = m.link.unwrap();
        // Every existing directed link carries exactly 1.0.
        assert!((lm.avg_data - 1.0).abs() < 1e-12);
    }

    #[test]
    fn congestion_detected_on_bad_mapping() {
        // Map a ring's communicating neighbors maximally far apart:
        // hop metrics must be strictly worse than identity.
        let g = stencil_graph(&[8], true, 1.0);
        let alloc = ring_alloc(8);
        let identity: Vec<u32> = (0..8).collect();
        let shuffle: Vec<u32> = vec![0, 4, 1, 5, 2, 6, 3, 7]; // stride-2 interleave
        let mi = eval_full(&g, &identity, &alloc);
        let ms = eval_full(&g, &shuffle, &alloc);
        assert!(ms.total_hops > mi.total_hops);
        assert!(ms.link.unwrap().max_data >= mi.link.unwrap().max_data);
    }
}
