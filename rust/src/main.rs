//! `repro` — the experiment launcher: regenerates every table and figure of
//! the paper (see DESIGN.md §4 for the index) and hosts the mapping
//! service.
//!
//! ```text
//! repro <experiment|all> [--full] [--seed N] [--native] [--out DIR]
//! repro serve [--addr HOST:PORT]
//! repro list
//! ```

use taskmap::coordinator::{experiments, service::Service, Ctx};

fn usage() -> ! {
    eprintln!(
        "usage: repro <experiment|all|list|serve> [options]\n\
         \n\
         experiments: {}\n\
         \n\
         options:\n\
           --full        paper-scale workloads (default: small/laptop scale)\n\
           --seed N      allocation seed (default 42)\n\
           --native      force the native WeightedHops backend (skip PJRT)\n\
           --out DIR     also write TSV tables into DIR\n\
           --addr A      serve: bind address (default 127.0.0.1:7777)\n\
         \n\
         env:\n\
           TASKMAP_THREADS=N  bound the mapper's default parallelism\n\
                              (1 = sequential; results are identical\n\
                              at every setting)",
        experiments::ALL.join(", ")
    );
    std::process::exit(2);
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if args.is_empty() {
        usage();
    }
    let cmd = args[0].as_str();
    let mut full = false;
    let mut seed = 42u64;
    let mut native = false;
    let mut out: Option<String> = None;
    let mut addr = "127.0.0.1:7777".to_string();
    let mut i = 1;
    while i < args.len() {
        match args[i].as_str() {
            "--full" => full = true,
            "--native" => native = true,
            "--seed" => {
                i += 1;
                seed = args.get(i).and_then(|s| s.parse().ok()).unwrap_or_else(|| usage());
            }
            "--out" => {
                i += 1;
                out = Some(args.get(i).cloned().unwrap_or_else(|| usage()));
            }
            "--addr" => {
                i += 1;
                addr = args.get(i).cloned().unwrap_or_else(|| usage());
            }
            other => {
                eprintln!("unknown option {other}");
                usage();
            }
        }
        i += 1;
    }

    match cmd {
        "list" => {
            for id in experiments::ALL {
                println!("{id}");
            }
        }
        "serve" => {
            let svc = Service::start(addr.as_str()).expect("bind service");
            println!("mapping service listening on {}", svc.addr);
            println!("protocol: newline-delimited JSON; see src/coordinator/service/");
            // Serve until killed.
            loop {
                std::thread::sleep(std::time::Duration::from_secs(3600));
            }
        }
        "all" => {
            let ctx = Ctx::new(full, seed, native);
            eprintln!("backend: {}", ctx.backend_name());
            for id in experiments::ALL {
                run_one(id, &ctx, out.as_deref());
            }
        }
        id => {
            if !experiments::ALL.contains(&id) {
                eprintln!("unknown experiment {id}");
                usage();
            }
            let ctx = Ctx::new(full, seed, native);
            eprintln!("backend: {}", ctx.backend_name());
            run_one(id, &ctx, out.as_deref());
        }
    }
}

fn run_one(id: &str, ctx: &Ctx, out: Option<&str>) {
    let start = std::time::Instant::now();
    let tables = experiments::run(id, ctx).expect("registered experiment");
    for t in &tables {
        println!("{}", t.markdown());
        if let Some(dir) = out {
            t.write_tsv(std::path::Path::new(dir)).expect("write tsv");
        }
    }
    eprintln!("[{id}] done in {:.1}s", start.elapsed().as_secs_f64());
}
