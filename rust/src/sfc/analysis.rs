//! Closed-form hop analysis of Appendix A.
//!
//! For one-to-one mappings of `2^n` td-dimensional stencil tasks onto a
//! pd-dimensional mesh (consistent, strictly-alternating cut order), the
//! appendix derives the number of hops between task neighbors separated by
//! the `j`-th cut of task dimension `i`:
//!
//! * Eqn 10/11 — `NHZ`: Z ordering (exact per-pair).
//! * Eqn 12/13 — `NHF`: FZ ordering (average over pairs).
//! * Eqn 19/23 — `TotalHopsZ/F`: totals across one task dimension when
//!   `pd = 2·td`.
//!
//! These are used by `rust/tests/appendix_formulas.rs` to validate the MJ +
//! ordering implementation against the paper's math: the measured hops of
//! actual mappings must reproduce these formulas.

/// sign(a, b) from Eqn 10: -1 if a == b, +1 otherwise.
#[inline]
fn sign(a: u64, b: u64) -> i64 {
    if a == b {
        -1
    } else {
        1
    }
}

/// Eqn 10: hops between Z-ordered task neighbors separated by cut `j` along
/// task dimension `i`, mapped onto a pd-dimensional mesh.
pub fn nhz(td: u64, pd: u64, i: u64, j: u64) -> i64 {
    assert!(i < td);
    let b = (td * j + i) % pd;
    let mut hops: i64 = 1i64 << ((td * j + i) / pd);
    for k in 0..j {
        hops += (1i64 << ((td * k + i) / pd)) * sign((td * k + i) % pd, b);
    }
    hops
}

/// Eqn 12: *average* hops between FZ-ordered task neighbors separated by
/// cut `j` along task dimension `i`.
pub fn nhf(td: u64, pd: u64, i: u64, j: u64) -> i64 {
    assert!(i < td);
    let pos = (td * j + i) / pd;
    if td == pd {
        1
    } else if pd % td == 0 {
        (1i64 << (pos + 1)) - 1
    } else {
        1i64 << pos
    }
}

/// Eqn 8/9 specialization used in A.3: number of neighbor pairs separated by
/// cut `j` of a `C`-cut dimension in the 1D sub-problem: `2^(C-j)`.
pub fn nn1d(c: u64, j: u64) -> u64 {
    1u64 << (c - j)
}

/// Eqn 19: total hops across all cuts of one task dimension for Z ordering
/// when `pd = 2 td` (m = 2), with `C` cuts in that dimension.
pub fn total_hops_z_m2(c: u64) -> i64 {
    let c_i = c as i64;
    if c % 2 == 0 {
        (1i64 << (c_i + 2)) - 4 * (1i64 << (c_i / 2))
    } else {
        (1i64 << (c_i + 2)) - 3 * (1i64 << ((c_i + 1) / 2))
    }
}

/// Eqn 23: total hops for FZ when `pd = 2 td`.
pub fn total_hops_f_m2(c: u64) -> i64 {
    let c_i = c as i64;
    if c % 2 == 0 {
        (1i64 << (c_i + 2)) - 6 * (1i64 << (c_i / 2)) + 2
    } else {
        (1i64 << (c_i + 2)) - 4 * (1i64 << ((c_i + 1) / 2)) + 2
    }
}

/// Eqn 15: NHZ for the m = 2 case in its simplified form.
pub fn nhz_m2(j: u64) -> i64 {
    if j % 2 == 0 {
        1i64 << (j / 2)
    } else {
        1i64 << ((j - 1) / 2 + 1)
    }
}

/// Eqn 13: NHF when pd mod td == 0 with m = pd/td.
pub fn nhf_mod0(m: u64, j: u64) -> i64 {
    (1i64 << (j / m + 1)) - 1
}

/// Eqn 14: NHZ when pd mod td == 0 with m = pd/td (general form).
pub fn nhz_mod0(m: u64, j: u64) -> i64 {
    let pos = (j / m) as i64;
    let m = m as i64;
    let jm = (j as i64) % m;
    (1i64 << pos) * jm + (m - 1) * (1i64 << pos) + 2 - m
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nhz_equals_one_when_td_eq_pd() {
        // Eqn 11 first case: td == pd => 1 hop for every cut.
        for td in 1..=4u64 {
            for j in 0..5 {
                for i in 0..td {
                    assert_eq!(nhz(td, td, i, j), 1, "td=pd={td} i={i} j={j}");
                }
            }
        }
    }

    #[test]
    fn nhf_equals_nhz_when_td_eq_pd() {
        for td in 1..=4u64 {
            for j in 0..5 {
                assert_eq!(nhf(td, td, 0, j), nhz(td, td, 0, j));
            }
        }
    }

    #[test]
    fn nhz_m2_matches_general_form() {
        // Eqn 15 is the m=2 specialization of Eqn 14.
        for j in 0..10u64 {
            assert_eq!(nhz_m2(j), nhz_mod0(2, j), "j={j}");
            // ... and of the fully general Eqn 10 with td=1, pd=2, i=0.
            assert_eq!(nhz_m2(j), nhz(1, 2, 0, j), "eqn10 j={j}");
        }
    }

    #[test]
    fn nhf_mod0_matches_eqn12() {
        for m in 2..=4u64 {
            for j in 0..8 {
                assert_eq!(nhf_mod0(m, j), nhf(1, m, 0, j), "m={m} j={j}");
            }
        }
    }

    #[test]
    fn totals_match_per_cut_sums_m2() {
        // Eqns 19/23 must equal the explicit sums over cuts of
        // NN1D(j) * NH(j) — this is how the appendix derives them.
        for c in 1..=12u64 {
            let mut tz = 0i64;
            let mut tf = 0i64;
            for j in 0..c {
                let nn = nn1d(c, j) as i64;
                tz += nn * nhz_m2(j);
                tf += nn * nhf_mod0(2, j);
            }
            assert_eq!(tz, total_hops_z_m2(c), "Z total C={c}");
            assert_eq!(tf, total_hops_f_m2(c), "F total C={c}");
        }
    }

    #[test]
    fn fz_beats_z_for_m2_totals() {
        // Appendix A.3's conclusion: FZ obtains fewer total hops when
        // pd = 2·td.
        for c in 2..=16u64 {
            assert!(
                total_hops_f_m2(c) < total_hops_z_m2(c),
                "C={c}: F={} Z={}",
                total_hops_f_m2(c),
                total_hops_z_m2(c)
            );
        }
    }

    #[test]
    fn fz_beats_z_when_pd_not_factor() {
        // Eqn 11 vs Eqn 12, third cases: NHF < NHZ whenever neither divides
        // the other (e.g. td=2, pd=3).
        for j in 1..6u64 {
            for i in 0..2 {
                let z = nhz(2, 3, i, j);
                let f = nhf(2, 3, i, j);
                assert!(f <= z, "td=2 pd=3 i={i} j={j}: F={f} Z={z}");
            }
        }
    }

    #[test]
    fn z_beats_fz_when_td_multiple_of_pd() {
        // Eqn 11 second case: td mod pd == 0 favors Z (e.g. 2D tasks on 1D
        // processors, td=2, pd=1).
        let mut z_total = 0i64;
        let mut f_total = 0i64;
        for j in 1..6u64 {
            z_total += nhz(2, 1, 0, j);
            f_total += nhf(2, 1, 0, j);
        }
        assert!(z_total < f_total, "Z={z_total} F={f_total}");
    }
}
