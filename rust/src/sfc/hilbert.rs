//! d-dimensional Hilbert curve via Skilling's transpose algorithm
//! ("Programming the Hilbert curve", AIP 2004).
//!
//! Used for (1) the H columns of Table 1, (2) HOMME's default per-face SFC
//! partition, and (3) the ALPS-style sparse-allocation simulator (Cray's
//! scheduler selects nodes along a space-filling curve, Section 2).

/// Hilbert index of a point with `bits`-bit coordinates in `axes.len()`
/// dimensions. `axes.len() * bits` must be <= 128.
pub fn hilbert_index(axes: &[u64], bits: u32) -> u128 {
    let n = axes.len();
    assert!(n >= 1 && (n as u32) * bits <= 128, "n={n} bits={bits}");
    if n == 1 {
        return axes[0] as u128; // 1D Hilbert is the identity
    }
    let mut x: Vec<u64> = axes.to_vec();
    axes_to_transpose(&mut x, bits);
    // Interleave bits: most significant bit of each axis first.
    let mut index: u128 = 0;
    for b in (0..bits).rev() {
        for xi in &x {
            index = (index << 1) | (((xi >> b) & 1) as u128);
        }
    }
    index
}

/// Inverse: point on the curve at `index`.
pub fn hilbert_point(index: u128, ndims: usize, bits: u32) -> Vec<u64> {
    assert!(ndims >= 1 && (ndims as u32) * bits <= 128);
    if ndims == 1 {
        return vec![index as u64];
    }
    // De-interleave into transpose form.
    let mut x = vec![0u64; ndims];
    let total = ndims as u32 * bits;
    for pos in 0..total {
        let bit = (index >> (total - 1 - pos)) & 1;
        let axis = (pos as usize) % ndims;
        let level = bits - 1 - (pos / ndims as u32);
        x[axis] |= (bit as u64) << level;
    }
    transpose_to_axes(&mut x, bits);
    x
}

/// Skilling: map axis coordinates to "transpose" Hilbert form, in place.
fn axes_to_transpose(x: &mut [u64], bits: u32) {
    let n = x.len();
    let m = 1u64 << (bits - 1);
    // Inverse undo
    let mut q = m;
    while q > 1 {
        let p = q - 1;
        for i in 0..n {
            if x[i] & q != 0 {
                x[0] ^= p;
            } else {
                let t = (x[0] ^ x[i]) & p;
                x[0] ^= t;
                x[i] ^= t;
            }
        }
        q >>= 1;
    }
    // Gray encode
    for i in 1..n {
        x[i] ^= x[i - 1];
    }
    let mut t = 0u64;
    let mut q = m;
    while q > 1 {
        if x[n - 1] & q != 0 {
            t ^= q - 1;
        }
        q >>= 1;
    }
    for xi in x.iter_mut() {
        *xi ^= t;
    }
}

/// Skilling: inverse of `axes_to_transpose`.
fn transpose_to_axes(x: &mut [u64], bits: u32) {
    let n = x.len();
    let m = 2u64 << (bits - 1);
    // Gray decode by H ^= H/2
    let mut t = x[n - 1] >> 1;
    for i in (1..n).rev() {
        x[i] ^= x[i - 1];
    }
    x[0] ^= t;
    // Undo excess work
    let mut q = 2u64;
    while q != m {
        let p = q - 1;
        for i in (0..n).rev() {
            if x[i] & q != 0 {
                x[0] ^= p;
            } else {
                t = (x[0] ^ x[i]) & p;
                x[0] ^= t;
                x[i] ^= t;
            }
        }
        q <<= 1;
    }
}

/// Rank all points of a quantized integer grid by Hilbert index: returns a
/// permutation `order` such that `order[k]` is the point index visited k-th.
pub fn hilbert_sort(points: &[Vec<u64>], bits: u32) -> Vec<usize> {
    let mut keyed: Vec<(u128, usize)> = points
        .iter()
        .enumerate()
        .map(|(i, p)| (hilbert_index(p, bits), i))
        .collect();
    keyed.sort_unstable();
    keyed.into_iter().map(|(_, i)| i).collect()
}

/// Quantize f64 coordinates (per-axis min/max) to a `bits`-bit grid and rank
/// by Hilbert index. Points are NOT assumed to be on an integer grid.
pub fn hilbert_sort_f64(coords: &crate::geom::Coords, bits: u32) -> Vec<usize> {
    let n = coords.len();
    let dim = coords.dim();
    let bb = coords.bbox();
    let scale: Vec<f64> = (0..dim)
        .map(|d| {
            let ext = bb.extent(d);
            if ext > 0.0 {
                (((1u64 << bits) - 1) as f64) / ext
            } else {
                0.0
            }
        })
        .collect();
    let mut q = vec![0u64; dim];
    let mut keyed: Vec<(u128, usize)> = (0..n)
        .map(|i| {
            for d in 0..dim {
                q[d] = ((coords.get(d, i) - bb.lo[d]) * scale[d]).round() as u64;
            }
            (hilbert_index(&q, bits), i)
        })
        .collect();
    keyed.sort_unstable();
    keyed.into_iter().map(|(_, i)| i).collect()
}

/// Sort a subset of an f64 coordinate set (given as point indices in `idx`)
/// along the Hilbert curve, in place, reusing a caller-provided key buffer.
/// Quantization uses the subset's own bounding box; ties (including the
/// degenerate all-equal subset) break by point index, so the order is fully
/// deterministic. This is the per-node ordering kernel of the hierarchical
/// mapper's `SfcOrder` strategy — `keys` is per-worker scratch there.
pub fn hilbert_sort_f64_subset_into(
    coords: &crate::geom::Coords,
    idx: &mut [u32],
    bits: u32,
    keys: &mut Vec<(u128, u32)>,
) {
    let dim = coords.dim();
    if idx.len() <= 1 {
        return;
    }
    // Subset bounding box.
    let mut lo = vec![f64::INFINITY; dim];
    let mut hi = vec![f64::NEG_INFINITY; dim];
    for &i in idx.iter() {
        for d in 0..dim {
            let v = coords.get(d, i as usize);
            if v < lo[d] {
                lo[d] = v;
            }
            if v > hi[d] {
                hi[d] = v;
            }
        }
    }
    let scale: Vec<f64> = (0..dim)
        .map(|d| {
            let ext = hi[d] - lo[d];
            if ext > 0.0 {
                (((1u64 << bits) - 1) as f64) / ext
            } else {
                0.0
            }
        })
        .collect();
    keys.clear();
    keys.reserve(idx.len());
    let mut q = vec![0u64; dim];
    for &i in idx.iter() {
        for d in 0..dim {
            q[d] = ((coords.get(d, i as usize) - lo[d]) * scale[d]).round() as u64;
        }
        keys.push((hilbert_index(&q, bits), i));
    }
    keys.sort_unstable();
    for (slot, &(_, i)) in idx.iter_mut().zip(keys.iter()) {
        *slot = i;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_2d() {
        for bits in 1..6u32 {
            let size = 1u64 << bits;
            for x in 0..size {
                for y in 0..size {
                    let idx = hilbert_index(&[x, y], bits);
                    assert_eq!(hilbert_point(idx, 2, bits), vec![x, y]);
                }
            }
        }
    }

    #[test]
    fn roundtrip_4d() {
        let bits = 3;
        for i in 0..(1u128 << (4 * bits)) {
            let p = hilbert_point(i, 4, bits as u32);
            assert_eq!(hilbert_index(&p, bits as u32), i);
        }
    }

    #[test]
    fn curve_is_continuous_2d() {
        // Consecutive Hilbert indices are grid neighbors (L1 distance 1).
        let bits = 4;
        let total = 1u128 << (2 * bits);
        let mut prev = hilbert_point(0, 2, bits);
        for i in 1..total {
            let p = hilbert_point(i, 2, bits);
            let dist: u64 = p
                .iter()
                .zip(&prev)
                .map(|(a, b)| a.abs_diff(*b))
                .sum();
            assert_eq!(dist, 1, "jump at index {i}: {prev:?} -> {p:?}");
            prev = p;
        }
    }

    #[test]
    fn curve_is_continuous_3d() {
        let bits = 3;
        let total = 1u128 << (3 * bits);
        let mut prev = hilbert_point(0, 3, bits);
        for i in 1..total {
            let p = hilbert_point(i, 3, bits);
            let dist: u64 = p.iter().zip(&prev).map(|(a, b)| a.abs_diff(*b)).sum();
            assert_eq!(dist, 1, "jump at index {i}");
            prev = p;
        }
    }

    #[test]
    fn curve_visits_all_cells() {
        let bits = 3;
        let total = 1u128 << (2 * bits);
        let mut seen = std::collections::HashSet::new();
        for i in 0..total {
            let p = hilbert_point(i, 2, bits);
            assert!(seen.insert(p.clone()), "revisited {p:?}");
        }
        assert_eq!(seen.len() as u128, total);
    }

    #[test]
    fn hilbert_sort_orders_by_index() {
        let pts: Vec<Vec<u64>> = (0..8)
            .flat_map(|x| (0..8).map(move |y| vec![x, y]))
            .collect();
        let order = hilbert_sort(&pts, 3);
        let mut prev_idx = None;
        for &i in &order {
            let idx = hilbert_index(&pts[i], 3);
            if let Some(p) = prev_idx {
                assert!(idx >= p);
            }
            prev_idx = Some(idx);
        }
    }

    #[test]
    fn one_d_is_identity() {
        for i in 0..64u64 {
            assert_eq!(hilbert_index(&[i], 6), i as u128);
            assert_eq!(hilbert_point(i as u128, 1, 6), vec![i]);
        }
    }

    #[test]
    fn hilbert_sort_f64_matches_integer_grid() {
        use crate::geom::Coords;
        let mut c = Coords::new(2);
        let mut pts = Vec::new();
        for x in 0..8u64 {
            for y in 0..8u64 {
                c.push(&[x as f64, y as f64]);
                pts.push(vec![x, y]);
            }
        }
        // bits=3 exactly represents an 8x8 grid.
        assert_eq!(hilbert_sort_f64(&c, 3), hilbert_sort(&pts, 3));
    }

    #[test]
    fn subset_sort_matches_full_sort_on_full_subset() {
        use crate::geom::Coords;
        let mut c = Coords::new(2);
        for x in 0..8u64 {
            for y in 0..8u64 {
                c.push(&[x as f64, y as f64]);
            }
        }
        let mut idx: Vec<u32> = (0..64).collect();
        let mut keys = Vec::new();
        hilbert_sort_f64_subset_into(&c, &mut idx, 3, &mut keys);
        let want: Vec<u32> = hilbert_sort_f64(&c, 3).into_iter().map(|i| i as u32).collect();
        assert_eq!(idx, want);
    }

    #[test]
    fn subset_sort_degenerate_subset_orders_by_index() {
        use crate::geom::Coords;
        // All points identical: ties must break by point index.
        let c = Coords::from_axes(vec![vec![5.0; 6], vec![1.0; 6]]);
        let mut idx: Vec<u32> = vec![4, 1, 5, 0, 3, 2];
        let mut keys = Vec::new();
        hilbert_sort_f64_subset_into(&c, &mut idx, 4, &mut keys);
        assert_eq!(idx, vec![0, 1, 2, 3, 4, 5]);
    }
}
