//! Space-filling-curve machinery: part-numbering orderings for the MJ
//! partitioner (Z, Gray, Flipped-Z, Modified-Flipped-Z — Algorithm 2 of the
//! paper), d-dimensional Hilbert curves, Gray-code utilities, and the
//! closed-form hop analysis of Appendix A.

pub mod analysis;
pub mod gray;
pub mod hilbert;

/// Part-numbering scheme applied during recursive bisection (Section 4.3,
/// Algorithm 2). Determines which coordinates are flipped for points on one
/// side of each cut:
///
/// * `Z`    — no flips; lower part numbers below the cut (Morton order).
/// * `Gray` — flip **all** coordinates of the upper half.
/// * `FZ`   — flip only the **cut dimension** of the upper half (the
///   paper's new Flipped-Z ordering).
/// * `MFZ`  — like FZ but flips the **lower** half instead; applied to one
///   coordinate set only, when `pd mod td == 0`, to cancel the conflict-bit
///   penalty (Section 4.3, "MFZ" paragraph).
/// * `Hilbert` — not an MJ flip rule: parts are numbered by the Hilbert
///   index of their quantized coordinates (used for the H columns of
///   Table 1 and as HOMME's default SFC).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum PartOrdering {
    Z,
    Gray,
    FZ,
    MFZ,
    Hilbert,
}

impl PartOrdering {
    pub fn name(&self) -> &'static str {
        match self {
            PartOrdering::Z => "Z",
            PartOrdering::Gray => "G",
            PartOrdering::FZ => "FZ",
            PartOrdering::MFZ => "MFZ",
            PartOrdering::Hilbert => "H",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_uppercase().as_str() {
            "Z" => Some(PartOrdering::Z),
            "G" | "GRAY" => Some(PartOrdering::Gray),
            "FZ" => Some(PartOrdering::FZ),
            "MFZ" => Some(PartOrdering::MFZ),
            "H" | "HILBERT" => Some(PartOrdering::Hilbert),
            _ => None,
        }
    }
}
