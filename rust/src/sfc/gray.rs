//! Gray-code utilities (Appendix A uses the binary-reflected Gray code to
//! analyze FZ ordering; Table 3 of the paper lists the first 32 values).

/// Binary-reflected Gray code of `x`.
#[inline]
pub fn to_gray(x: u64) -> u64 {
    x ^ (x >> 1)
}

/// Inverse Gray code: the rank of Gray value `g`.
#[inline]
pub fn from_gray(g: u64) -> u64 {
    let mut x = g;
    let mut shift = 1;
    while shift < 64 {
        x ^= x >> shift;
        shift <<= 1;
    }
    x
}

/// FZ rank (the "FZ" column of Table 3): the part number whose Gray code is
/// the binary representation of the rank — i.e. `from_gray` applied to the
/// binary index gives the order in which FZ visits 1D cells.
///
/// Table 3 lists, for each decimal index, the FZ value such that
/// `to_gray(index) == binary(FZ column)`; equivalently the FZ sequence is
/// the Gray-code permutation.
#[inline]
pub fn fz_rank_1d(index: u64) -> u64 {
    to_gray(index)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gray_roundtrip() {
        for x in 0..4096u64 {
            assert_eq!(from_gray(to_gray(x)), x);
        }
    }

    #[test]
    fn gray_neighbors_differ_one_bit() {
        for x in 0..4095u64 {
            let d = to_gray(x) ^ to_gray(x + 1);
            assert_eq!(d.count_ones(), 1, "gray({x}) vs gray({}) differ in >1 bit", x + 1);
        }
    }

    #[test]
    fn table3_first_values() {
        // Paper Table 3: decimal -> FZ (Gray-code) values.
        let expect = [
            (0u64, 0u64),
            (1, 1),
            (2, 3),
            (3, 2),
            (4, 6),
            (5, 7),
            (6, 5),
            (7, 4),
            (8, 12),
            (9, 13),
            (10, 15),
            (11, 14),
            (12, 10),
            (13, 11),
            (14, 9),
            (15, 8),
            (16, 24),
            (17, 25),
            (24, 20),
            (27, 22),
        ];
        for (dec, fz) in expect {
            assert_eq!(to_gray(dec), fz, "Table 3 row {dec}");
        }
        // Note: the paper's Table 3 rows 28-31 contain typos — the decimal
        // FZ column disagrees with the table's own Gray-code binary column
        // (e.g. row 28 lists FZ=22 but binary 10010=18). The binary column
        // matches to_gray; we follow it.
        assert_eq!(to_gray(28), 0b10010);
        assert_eq!(to_gray(31), 0b10000);
    }

    #[test]
    fn gray_cyclic_property() {
        // Torus-friendliness: the last and first Gray codes also differ in
        // exactly one bit (for a full 2^k ring).
        for k in 1..12u32 {
            let n = 1u64 << k;
            let d = to_gray(0) ^ to_gray(n - 1);
            assert_eq!(d.count_ones(), 1);
        }
    }
}
